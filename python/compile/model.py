"""L2: Qwen2.5-style decoder-only transformer over packed sequences.

This is the compute graph that the rust coordinator executes per micro-batch
bucket: forward + cross-entropy loss + full gradients (jax.value_and_grad),
lowered once per bucket token-length by aot.py and never re-traced at
runtime.

Interchange contract with the rust runtime (rust/src/runtime/):
  * Parameters travel as an *ordered flat list* of f32 arrays.  The order is
    defined by `param_specs(cfg)` and written into artifacts/manifest.txt —
    rust keeps params as flat host buffers and runs Adam over them.
  * train_step entry:  (p_0..p_{n-1}, tokens, targets, loss_mask,
    segment_ids, positions) -> (loss, g_0..g_{n-1}) as a single HLO tuple.

Architecture (matches Qwen2.5 structurally: the scheduler's FLOPs model,
Eq. 13, is parameterized by exactly these shapes): tied embedding, RMSNorm,
RoPE, grouped-query attention (packed flash-attention kernel from L1),
SwiGLU MLP.
"""

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from compile.kernels.flash_attention import flash_attention
from compile.kernels.ref import attention_ref


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    hidden: int = 256
    layers: int = 4
    heads: int = 8
    kv_heads: int = 2
    ffn: int = 768
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads


# The end-to-end example's model (examples/long_sft_train.rs): small enough
# to train a few hundred steps on CPU, structurally identical to Qwen2.5.
TINY = ModelConfig()


def param_specs(cfg: ModelConfig):
    """Ordered (name, shape) list — the flat interchange layout."""
    specs = [("tok_embed", (cfg.vocab, cfg.hidden))]
    hd = cfg.head_dim
    for i in range(cfg.layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1", (cfg.hidden,)),
            (p + "wq", (cfg.hidden, cfg.heads * hd)),
            (p + "wk", (cfg.hidden, cfg.kv_heads * hd)),
            (p + "wv", (cfg.hidden, cfg.kv_heads * hd)),
            (p + "wo", (cfg.heads * hd, cfg.hidden)),
            (p + "ln2", (cfg.hidden,)),
            (p + "w_gate", (cfg.hidden, cfg.ffn)),
            (p + "w_up", (cfg.hidden, cfg.ffn)),
            (p + "w_down", (cfg.ffn, cfg.hidden)),
        ]
    specs.append(("ln_f", (cfg.hidden,)))
    return specs


def num_params(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_specs(cfg))


def init_params(cfg: ModelConfig, key):
    """Flat list of f32 arrays in param_specs order."""
    specs = param_specs(cfg)
    keys = jax.random.split(key, len(specs))
    out = []
    for (name, shape), k in zip(specs, keys):
        if name.endswith(("ln1", "ln2", "ln_f")):
            out.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0]
            std = fan_in**-0.5
            out.append(jax.random.normal(k, shape, jnp.float32) * std)
    return out


def _unflatten(cfg: ModelConfig, flat):
    it = iter(flat)
    params = {"tok_embed": next(it), "layers": []}
    for _ in range(cfg.layers):
        params["layers"].append(
            {
                k: next(it)
                for k in ("ln1", "wq", "wk", "wv", "wo", "ln2", "w_gate", "w_up", "w_down")
            }
        )
    params["ln_f"] = next(it)
    return params


def _rmsnorm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _rope(x, positions, theta):
    """x: (heads, T, d) -> rotated; positions: (T,) int32."""
    d = x.shape[-1]
    inv_freq = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions.astype(jnp.float32)[:, None] * inv_freq[None, :]  # (T, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape)


def _attention_block(layer, x, segment_ids, positions, cfg, use_pallas):
    hd, h, hkv = cfg.head_dim, cfg.heads, cfg.kv_heads
    t = x.shape[0]
    xn = _rmsnorm(x, layer["ln1"])
    q = (xn @ layer["wq"]).reshape(t, h, hd).transpose(1, 0, 2)
    k = (xn @ layer["wk"]).reshape(t, hkv, hd).transpose(1, 0, 2)
    v = (xn @ layer["wv"]).reshape(t, hkv, hd).transpose(1, 0, 2)
    q = _rope(q, positions, cfg.rope_theta)
    k = _rope(k, positions, cfg.rope_theta)
    # GQA: repeat K/V to the query head count (the kernel is MHA-shaped; the
    # FLOPs model Eq.13 accounts for h_kv in the projection terms).
    rep = h // hkv
    k = jnp.repeat(k, rep, axis=0)
    v = jnp.repeat(v, rep, axis=0)
    attn = flash_attention if use_pallas else attention_ref
    o = attn(q, k, v, segment_ids)  # (h, t, hd)
    o = o.transpose(1, 0, 2).reshape(t, h * hd)
    return x + o @ layer["wo"]


def _mlp_block(layer, x):
    xn = _rmsnorm(x, layer["ln2"])
    g = jax.nn.silu(xn @ layer["w_gate"])
    u = xn @ layer["w_up"]
    return x + (g * u) @ layer["w_down"]


def forward(cfg: ModelConfig, flat_params, tokens, segment_ids, positions, use_pallas=True):
    """Packed forward pass.  tokens/segment_ids/positions: (T,) int32.

    Returns logits (T, vocab).  Padding tokens carry a shared segment id and
    are excluded from the loss by the caller's loss_mask.
    """
    params = _unflatten(cfg, flat_params)
    x = params["tok_embed"][tokens]  # (T, h)
    for layer in params["layers"]:
        x = _attention_block(layer, x, segment_ids, positions, cfg, use_pallas)
        x = _mlp_block(layer, x)
    x = _rmsnorm(x, params["ln_f"])
    return x @ params["tok_embed"].T  # tied lm head


def loss_fn(cfg, flat_params, tokens, targets, loss_mask, segment_ids, positions, use_pallas=True):
    logits = forward(cfg, flat_params, tokens, segment_ids, positions, use_pallas)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt_logit = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    nll = (logz - tgt_logit) * loss_mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(loss_mask), 1.0)


def make_train_step(cfg: ModelConfig, use_pallas=True):
    """(flat params..., tokens, targets, loss_mask, seg, pos) -> (loss, grads...)."""
    n = len(param_specs(cfg))

    def train_step(*args):
        flat = list(args[:n])
        tokens, targets, loss_mask, seg, pos = args[n:]
        loss, grads = jax.value_and_grad(
            lambda fp: loss_fn(cfg, fp, tokens, targets, loss_mask, seg, pos, use_pallas)
        )(flat)
        return (loss, *grads)

    return train_step


def example_batch(cfg: ModelConfig, t: int):
    """ShapeDtypeStructs for one packed bucket of t tokens."""
    i32 = partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    f32 = partial(jax.ShapeDtypeStruct, dtype=jnp.float32)
    return (i32((t,)), i32((t,)), f32((t,)), i32((t,)), i32((t,)))
