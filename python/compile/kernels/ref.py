"""Pure-jnp oracle for the Pallas flash-attention kernel.

This is the correctness anchor for the whole stack: the Pallas kernel (L1)
is checked against this reference by pytest/hypothesis, and the L2 model can
be built on either implementation so kernel-vs-ref is testable end-to-end
(forward AND gradients).
"""

import jax.numpy as jnp


def attention_ref(q, k, v, segment_ids, scale=None):
    """Naive packed causal attention.  Shapes match flash_attention."""
    h, t, d = q.shape
    if scale is None:
        scale = 1.0 / (d**0.5)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    pos = jnp.arange(t)
    causal = pos[:, None] >= pos[None, :]
    same_seg = segment_ids[:, None] == segment_ids[None, :]
    mask = causal & same_seg
    s = jnp.where(mask[None, :, :], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask[None, :, :], p, 0.0)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
