"""L1: FlashAttention-2-style blockwise attention as a Pallas kernel.

The paper's hot spot is the Attention module over *packed* sequences
(Appendix A.1: "we employ sequence packing to eliminate padding").  DACP
places several local sequences into one per-rank buffer, so the kernel must
support segment-id masking: token i attends to token j iff they belong to the
same packed segment AND j <= i (causal).

Hardware adaptation (GPU paper -> TPU Pallas, see DESIGN.md §4):
  * FA2's SRAM threadblock tiles become VMEM blocks expressed via BlockSpec:
    the q tile is a (BLOCK_Q, d) VMEM-resident block selected by the
    (head, q_block) grid; K/V stream through the inner fori_loop in
    (BLOCK_K, d) slices — the HBM<->VMEM schedule the paper's baseline gets
    from threadblock scheduling.
  * QK^T / PV contractions are shaped for the 128x128 MXU systolic array
    (BLOCK_Q = BLOCK_K = 128), accumulating in f32.
  * The online-softmax recurrence (running max m, normalizer l) is identical
    to FA2 — IO-awareness is hierarchy-independent.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs under the rust runtime.  Real-TPU efficiency is estimated
analytically in EXPERIMENTS.md §Perf.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _block_mask(q_pos, k_pos, q_seg, k_seg):
    """Causal + same-segment mask for a (bq, bk) tile."""
    causal = q_pos[:, None] >= k_pos[None, :]
    same_seg = q_seg[:, None] == k_seg[None, :]
    return causal & same_seg


# ---------------------------------------------------------------------------
# Forward kernel
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, qseg_ref, kseg_ref, o_ref, lse_ref, *, scale, block_k):
    bq, d = q_ref.shape
    t = k_ref.shape[0]
    nk = t // block_k
    i = pl.program_id(1)

    q = q_ref[...].astype(jnp.float32) * scale
    q_seg = qseg_ref[...]
    q_pos = i * bq + jax.lax.iota(jnp.int32, bq)

    def body(j, carry):
        acc, m, l = carry
        k = k_ref[pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        k_seg = kseg_ref[pl.ds(j * block_k, block_k)]
        k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)

        s = q @ k.T  # (bq, bk), f32 accumulation (MXU-shaped contraction)
        mask = _block_mask(q_pos, k_pos, q_seg, k_seg)
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # exp(NEG_INF - m_new) underflows to 0 unless the whole row is still
        # empty (m_new == NEG_INF); the explicit where() kills that case.
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nk, body, (acc0, m0, l0))

    l_safe = jnp.maximum(l, 1e-30)
    o_ref[...] = (acc / l_safe[:, None]).astype(o_ref.dtype)
    lse_ref[...] = m + jnp.log(l_safe)


def _fwd(q, k, v, segment_ids, scale, block_q, block_k):
    h, t, d = q.shape
    grid = (h, t // block_q)
    out, lse = pl.pallas_call(
        partial(_fwd_kernel, scale=scale, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda hh, ii: (hh, ii, 0)),
            pl.BlockSpec((None, t, d), lambda hh, ii: (hh, 0, 0)),
            pl.BlockSpec((None, t, d), lambda hh, ii: (hh, 0, 0)),
            pl.BlockSpec((block_q,), lambda hh, ii: (ii,)),
            pl.BlockSpec((t,), lambda hh, ii: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_q, d), lambda hh, ii: (hh, ii, 0)),
            pl.BlockSpec((None, block_q), lambda hh, ii: (hh, ii)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, t, d), q.dtype),
            jax.ShapeDtypeStruct((h, t), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, segment_ids, segment_ids)
    return out, lse


# ---------------------------------------------------------------------------
# Backward kernels (FA2 work partitioning: dq over q-blocks, dk/dv over
# k-blocks; delta = rowsum(dO * O) precomputed outside)
# ---------------------------------------------------------------------------


def _bwd_dq_kernel(
    q_ref, k_ref, v_ref, qseg_ref, kseg_ref, do_ref, lse_ref, delta_ref, dq_ref, *, scale, block_k
):
    bq, d = q_ref.shape
    t = k_ref.shape[0]
    nk = t // block_k
    i = pl.program_id(1)

    q = q_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    lse = lse_ref[...]
    delta = delta_ref[...]
    q_seg = qseg_ref[...]
    q_pos = i * bq + jax.lax.iota(jnp.int32, bq)

    def body(j, dq):
        k = k_ref[pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        k_seg = kseg_ref[pl.ds(j * block_k, block_k)]
        k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)

        s = (q @ k.T) * scale
        mask = _block_mask(q_pos, k_pos, q_seg, k_seg)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = do @ v.T
        ds = p * (dp - delta[:, None]) * scale
        return dq + ds @ k

    dq = jax.lax.fori_loop(0, nk, body, jnp.zeros((bq, d), jnp.float32))
    dq_ref[...] = dq.astype(dq_ref.dtype)


def _bwd_dkv_kernel(
    q_ref, k_ref, v_ref, qseg_ref, kseg_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref, *, scale, block_q
):
    bk, d = k_ref.shape
    t = q_ref.shape[0]
    nq = t // block_q
    j = pl.program_id(1)

    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    k_seg = kseg_ref[...]
    k_pos = j * bk + jax.lax.iota(jnp.int32, bk)

    def body(i, carry):
        dk, dv = carry
        q = q_ref[pl.ds(i * block_q, block_q), :].astype(jnp.float32)
        do = do_ref[pl.ds(i * block_q, block_q), :].astype(jnp.float32)
        lse = lse_ref[pl.ds(i * block_q, block_q)]
        delta = delta_ref[pl.ds(i * block_q, block_q)]
        q_seg = qseg_ref[pl.ds(i * block_q, block_q)]
        q_pos = i * block_q + jax.lax.iota(jnp.int32, block_q)

        s = (q @ k.T) * scale
        mask = _block_mask(q_pos, k_pos, q_seg, k_seg)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dv = dv + p.T @ do
        dp = do @ v.T
        ds = p * (dp - delta[:, None]) * scale
        dk = dk + ds.T @ q
        return dk, dv

    dk0 = jnp.zeros((bk, d), jnp.float32)
    dv0 = jnp.zeros((bk, d), jnp.float32)
    dk, dv = jax.lax.fori_loop(0, nq, body, (dk0, dv0))
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _bwd(q, k, v, segment_ids, out, lse, do, scale, block_q, block_k):
    h, t, d = q.shape
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)  # (h, t)

    dq = pl.pallas_call(
        partial(_bwd_dq_kernel, scale=scale, block_k=block_k),
        grid=(h, t // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda hh, ii: (hh, ii, 0)),
            pl.BlockSpec((None, t, d), lambda hh, ii: (hh, 0, 0)),
            pl.BlockSpec((None, t, d), lambda hh, ii: (hh, 0, 0)),
            pl.BlockSpec((block_q,), lambda hh, ii: (ii,)),
            pl.BlockSpec((t,), lambda hh, ii: (0,)),
            pl.BlockSpec((None, block_q, d), lambda hh, ii: (hh, ii, 0)),
            pl.BlockSpec((None, block_q), lambda hh, ii: (hh, ii)),
            pl.BlockSpec((None, block_q), lambda hh, ii: (hh, ii)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda hh, ii: (hh, ii, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t, d), q.dtype),
        interpret=True,
    )(q, k, v, segment_ids, segment_ids, do, lse, delta)

    dk, dv = pl.pallas_call(
        partial(_bwd_dkv_kernel, scale=scale, block_q=block_q),
        grid=(h, t // block_k),
        in_specs=[
            pl.BlockSpec((None, t, d), lambda hh, jj: (hh, 0, 0)),
            pl.BlockSpec((None, block_k, d), lambda hh, jj: (hh, jj, 0)),
            pl.BlockSpec((None, block_k, d), lambda hh, jj: (hh, jj, 0)),
            pl.BlockSpec((t,), lambda hh, jj: (0,)),
            pl.BlockSpec((block_k,), lambda hh, jj: (jj,)),
            pl.BlockSpec((None, t, d), lambda hh, jj: (hh, 0, 0)),
            pl.BlockSpec((None, t), lambda hh, jj: (hh, 0)),
            pl.BlockSpec((None, t), lambda hh, jj: (hh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_k, d), lambda hh, jj: (hh, jj, 0)),
            pl.BlockSpec((None, block_k, d), lambda hh, jj: (hh, jj, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, t, d), q.dtype),
            jax.ShapeDtypeStruct((h, t, d), q.dtype),
        ],
        interpret=True,
    )(q, k, v, segment_ids, segment_ids, do, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# Public API: differentiable packed causal attention
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def flash_attention(q, k, v, segment_ids, scale=None, block_q=BLOCK_Q, block_k=BLOCK_K):
    """Packed causal multi-head attention.

    Args:
      q, k, v: (heads, tokens, head_dim).  K/V must already be repeated to
        the query head count (GQA repeat happens in the model layer).
      segment_ids: (tokens,) int32 packed-segment ids; tokens attend only
        within their own segment.  Padding uses a shared id and is
        loss-masked downstream.
      scale: softmax scale, default 1/sqrt(head_dim).
      block_q, block_k: VMEM tile sizes (must divide tokens).

    Returns:
      (heads, tokens, head_dim) attention output, same dtype as q.
    """
    out, _ = _flash_fwd(q, k, v, segment_ids, scale, block_q, block_k)
    return out


def _resolve_scale(scale, d):
    return (1.0 / (d**0.5)) if scale is None else scale


def _flash_fwd(q, k, v, segment_ids, scale, block_q, block_k):
    d = q.shape[-1]
    s = _resolve_scale(scale, d)
    out, lse = _fwd(q, k, v, segment_ids, s, block_q, block_k)
    return out, (q, k, v, segment_ids, out, lse)


def _vjp_fwd(q, k, v, segment_ids, scale, block_q, block_k):
    out, res = _flash_fwd(q, k, v, segment_ids, scale, block_q, block_k)
    return out, res


def _vjp_bwd(scale, block_q, block_k, res, do):
    q, k, v, segment_ids, out, lse = res
    s = _resolve_scale(scale, q.shape[-1])
    dq, dk, dv = _bwd(q, k, v, segment_ids, out, lse, do, s, block_q, block_k)
    return dq, dk, dv, None


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
