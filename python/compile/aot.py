"""AOT pipeline: lower the L2 train step to HLO *text* artifacts for rust.

Run once via `make artifacts`; python never appears on the training path.

Interchange is HLO text, NOT a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that the image's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the HLO text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (artifacts/):
  train_step_t{T}.hlo.txt   fused fwd+bwd train step per packed bucket size T
  attn_fwd_t{T}.hlo.txt     forward-only attention microbenchmark
  params.bin                initial params, f32 LE, manifest order
  manifest.txt              model config, param layout, bucket -> artifact map
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.flash_attention import flash_attention

DEFAULT_BUCKETS = (256, 512, 1024)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_train_step(cfg: M.ModelConfig, t: int) -> str:
    step = M.make_train_step(cfg, use_pallas=True)
    param_args = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in M.param_specs(cfg)]
    batch_args = M.example_batch(cfg, t)
    lowered = jax.jit(step).lower(*param_args, *batch_args)
    return to_hlo_text(lowered)


def lower_attn_fwd(cfg: M.ModelConfig, t: int) -> str:
    h, d = cfg.heads, cfg.head_dim

    def fn(q, k, v, seg):
        return (flash_attention(q, k, v, seg),)

    spec = jax.ShapeDtypeStruct((h, t, d), jnp.float32)
    seg = jax.ShapeDtypeStruct((t,), jnp.int32)
    lowered = jax.jit(fn).lower(spec, spec, spec, seg)
    return to_hlo_text(lowered)


def write_manifest(path, cfg, buckets, attn_buckets, seed):
    lines = ["version 1"]
    lines.append(
        f"model vocab={cfg.vocab} hidden={cfg.hidden} layers={cfg.layers} "
        f"heads={cfg.heads} kv_heads={cfg.kv_heads} ffn={cfg.ffn} "
        f"head_dim={cfg.head_dim} seed={seed}"
    )
    for name, shape in M.param_specs(cfg):
        lines.append(f"param {name} {'x'.join(str(d) for d in shape)}")
    for t in buckets:
        lines.append(f"bucket {t} train_step_t{t}.hlo.txt")
    for t in attn_buckets:
        lines.append(f"attn {t} attn_fwd_t{t}.hlo.txt")
    lines.append("params params.bin")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--buckets", type=int, nargs="*", default=list(DEFAULT_BUCKETS))
    ap.add_argument("--attn-buckets", type=int, nargs="*", default=[512])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = M.TINY
    os.makedirs(args.out_dir, exist_ok=True)

    for t in args.buckets:
        assert t % 128 == 0, "bucket must be a multiple of the kernel block size"
        text = lower_train_step(cfg, t)
        path = os.path.join(args.out_dir, f"train_step_t{t}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    for t in args.attn_buckets:
        text = lower_attn_fwd(cfg, t)
        path = os.path.join(args.out_dir, f"attn_fwd_t{t}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    flat = np.concatenate([np.asarray(p, dtype=np.float32).reshape(-1) for p in params])
    bin_path = os.path.join(args.out_dir, "params.bin")
    flat.tofile(bin_path)
    print(f"wrote {bin_path} ({flat.size} f32 = {M.num_params(cfg)} params)")

    write_manifest(os.path.join(args.out_dir, "manifest.txt"), cfg, args.buckets, args.attn_buckets, args.seed)
    print("wrote manifest.txt")


if __name__ == "__main__":
    main()
