"""AOT pipeline sanity: HLO text artifacts parse, manifest matches model,
params.bin matches the init + manifest order."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_train_step_produces_hlo_text():
    text = aot.lower_train_step(M.TINY, 128)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # one input per param + 5 batch tensors must appear as parameters
    n_inputs = len(M.param_specs(M.TINY)) + 5
    assert text.count("parameter(") >= n_inputs


def test_lower_attn_fwd_produces_hlo_text():
    text = aot.lower_attn_fwd(M.TINY, 128)
    assert text.startswith("HloModule")
    # the custom-call-free property: interpret-mode pallas lowers to plain HLO
    assert "custom-call" not in text or "Mosaic" not in text


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.txt")), reason="run `make artifacts` first")
class TestArtifacts:
    def _manifest(self):
        with open(os.path.join(ART, "manifest.txt")) as f:
            return f.read().splitlines()

    def test_manifest_params_match_model(self):
        lines = [l for l in self._manifest() if l.startswith("param ")]
        specs = M.param_specs(M.TINY)
        assert len(lines) == len(specs)
        for line, (name, shape) in zip(lines, specs):
            _, n, dims = line.split()
            assert n == name
            assert tuple(int(d) for d in dims.split("x")) == tuple(shape)

    def test_params_bin_matches_init(self):
        mf = self._manifest()
        seed = int([l for l in mf if l.startswith("model ")][0].split("seed=")[1])
        flat = np.fromfile(os.path.join(ART, "params.bin"), dtype=np.float32)
        assert flat.size == M.num_params(M.TINY)
        params = M.init_params(M.TINY, jax.random.PRNGKey(seed))
        expect = np.concatenate([np.asarray(p).reshape(-1) for p in params])
        np.testing.assert_array_equal(flat, expect)

    def test_bucket_artifacts_exist(self):
        for line in self._manifest():
            if line.startswith(("bucket ", "attn ")):
                _, t, fname = line.split()
                path = os.path.join(ART, fname)
                assert os.path.exists(path), fname
                with open(path) as f:
                    head = f.read(64)
                assert head.startswith("HloModule")
