"""L2 correctness: model forward/loss/grads, pallas vs ref attention, and
the interchange contract (param specs, example batch shapes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.TINY


def make_batch(rng, t, n_seqs=3):
    lens = rng.integers(8, t // n_seqs + 1, size=n_seqs)
    lens[-1] = max(1, t - int(lens[:-1].sum()))  # fill to t exactly
    tok, seg, pos = [], [], []
    for i, L in enumerate(lens):
        tok += list(rng.integers(0, CFG.vocab, size=L))
        seg += [i] * L
        pos += list(range(L))
    tok, seg, pos = (np.array(x[:t], dtype=np.int32) for x in (tok, seg, pos))
    tgt = np.roll(tok, -1).astype(np.int32)
    # mask the last token of each segment (no next-token target across seams)
    mask = np.ones(t, np.float32)
    mask[np.where(np.diff(seg, append=seg[-1] + 1) != 0)] = 0.0
    return (jnp.asarray(tok), jnp.asarray(tgt), jnp.asarray(mask), jnp.asarray(seg), jnp.asarray(pos))


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_param_specs_cover_init(params):
    specs = M.param_specs(CFG)
    assert len(specs) == len(params)
    for (name, shape), p in zip(specs, params):
        assert tuple(shape) == p.shape, name
    assert M.num_params(CFG) == sum(int(np.prod(p.shape)) for p in params)


def test_forward_shapes(params):
    t = 128
    batch = make_batch(np.random.default_rng(0), t)
    logits = M.forward(CFG, params, batch[0], batch[3], batch[4])
    assert logits.shape == (t, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_pallas_matches_ref_forward(params):
    batch = make_batch(np.random.default_rng(1), 256)
    lp = M.loss_fn(CFG, params, *batch, use_pallas=True)
    lr = M.loss_fn(CFG, params, *batch, use_pallas=False)
    np.testing.assert_allclose(float(lp), float(lr), atol=1e-4, rtol=1e-5)


def test_pallas_matches_ref_grads(params):
    batch = make_batch(np.random.default_rng(2), 128)

    def g(use_pallas):
        return jax.grad(lambda fp: M.loss_fn(CFG, fp, *batch, use_pallas=use_pallas))(params)

    gp, gr = g(True), g(False)
    for (name, _), a, b in zip(M.param_specs(CFG), gp, gr):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-5, rtol=5e-3, err_msg=name
        )


def test_loss_mask_excludes_tokens(params):
    """Zeroing a token's mask must remove its contribution entirely."""
    t = 128
    tok, tgt, mask, seg, pos = make_batch(np.random.default_rng(3), t)
    l_full = M.loss_fn(CFG, params, tok, tgt, mask, seg, pos)
    # recompute by hand from per-token nll
    logits = M.forward(CFG, params, tok, seg, pos).astype(jnp.float32)
    nll = jax.nn.logsumexp(logits, -1) - jnp.take_along_axis(logits, tgt[:, None], 1)[:, 0]
    expect = float(jnp.sum(nll * mask) / jnp.sum(mask))
    np.testing.assert_allclose(float(l_full), expect, rtol=1e-6)


def test_packing_equivalence(params):
    """Loss over a packed pair equals the token-weighted mean of the two
    sequences computed separately — the mathematical-equivalence property
    that lets GDS/DACP reorder and pack sequences freely."""
    rng = np.random.default_rng(4)
    la, lb = 128, 128
    ta = rng.integers(0, CFG.vocab, la).astype(np.int32)
    tb = rng.integers(0, CFG.vocab, lb).astype(np.int32)

    def single(tokens):
        t = len(tokens)
        tok = jnp.asarray(tokens)
        tgt = jnp.asarray(np.roll(tokens, -1))
        mask = jnp.asarray(np.concatenate([np.ones(t - 1), [0.0]]), jnp.float32)
        seg = jnp.zeros(t, jnp.int32)
        pos = jnp.arange(t, dtype=jnp.int32)
        return M.loss_fn(CFG, params, tok, tgt, mask, seg, pos)

    packed_tok = jnp.asarray(np.concatenate([ta, tb]))
    packed_tgt = jnp.asarray(np.concatenate([np.roll(ta, -1), np.roll(tb, -1)]))
    packed_mask = jnp.asarray(
        np.concatenate([np.ones(la - 1), [0.0], np.ones(lb - 1), [0.0]]), jnp.float32
    )
    packed_seg = jnp.asarray(np.concatenate([np.zeros(la), np.ones(lb)]), jnp.int32)
    packed_pos = jnp.asarray(np.concatenate([np.arange(la), np.arange(lb)]), jnp.int32)
    l_packed = M.loss_fn(CFG, params, packed_tok, packed_tgt, packed_mask, packed_seg, packed_pos)
    l_expect = (float(single(ta)) * (la - 1) + float(single(tb)) * (lb - 1)) / (la + lb - 2)
    np.testing.assert_allclose(float(l_packed), l_expect, rtol=1e-5)


def test_train_step_outputs(params):
    batch = make_batch(np.random.default_rng(5), 128)
    step = M.make_train_step(CFG)
    out = jax.jit(step)(*params, *batch)
    assert len(out) == 1 + len(params)
    assert out[0].shape == ()
    for (name, shape), g in zip(M.param_specs(CFG), out[1:]):
        assert g.shape == tuple(shape), name
        assert bool(jnp.all(jnp.isfinite(g))), name


def test_grad_descent_reduces_loss(params):
    batch = make_batch(np.random.default_rng(6), 128)
    step = jax.jit(M.make_train_step(CFG))
    out = step(*params, *batch)
    loss0, grads = out[0], out[1:]
    p2 = [p - 0.5 * g for p, g in zip(params, grads)]
    loss1 = step(*p2, *batch)[0]
    assert float(loss1) < float(loss0)


def test_example_batch_shapes():
    shapes = M.example_batch(CFG, 256)
    assert [s.shape for s in shapes] == [(256,)] * 5
    assert [str(s.dtype) for s in shapes] == ["int32", "int32", "float32", "int32", "int32"]
