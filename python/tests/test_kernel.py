"""L1 correctness: Pallas flash-attention kernel vs the pure-jnp oracle.

hypothesis sweeps shapes/dtypes/segment layouts; every case asserts
allclose against ref.py for the forward pass and (f32) for all three
gradients through the custom VJP.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.flash_attention import flash_attention
from compile.kernels.ref import attention_ref

jax.config.update("jax_platform_name", "cpu")


def random_segments(rng, t, max_segs):
    """Random packed layout: segment ids are non-decreasing, last id pads."""
    n = rng.integers(1, max_segs + 1)
    cuts = np.sort(rng.choice(np.arange(1, t), size=n - 1, replace=False)) if n > 1 else np.array([], dtype=int)
    seg = np.zeros(t, dtype=np.int32)
    for i, c in enumerate(cuts):
        seg[c:] = i + 1
    return jnp.asarray(seg)


def make_qkv(rng, h, t, d, dtype):
    q = jnp.asarray(rng.standard_normal((h, t, d)), dtype)
    k = jnp.asarray(rng.standard_normal((h, t, d)), dtype)
    v = jnp.asarray(rng.standard_normal((h, t, d)), dtype)
    return q, k, v


@settings(max_examples=20, deadline=None)
@given(
    h=st.sampled_from([1, 2, 4]),
    t=st.sampled_from([128, 256, 384]),
    d=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
    max_segs=st.sampled_from([1, 3, 7]),
)
def test_forward_matches_ref(h, t, d, seed, max_segs):
    rng = np.random.default_rng(seed)
    q, k, v = make_qkv(rng, h, t, d, jnp.float32)
    seg = random_segments(rng, t, max_segs)
    out = flash_attention(q, k, v, seg)
    ref = attention_ref(q, k, v, seg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    h=st.sampled_from([1, 2]),
    t=st.sampled_from([128, 256]),
    d=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
    max_segs=st.sampled_from([1, 4]),
)
def test_gradients_match_ref(h, t, d, seed, max_segs):
    rng = np.random.default_rng(seed)
    q, k, v = make_qkv(rng, h, t, d, jnp.float32)
    seg = random_segments(rng, t, max_segs)
    # Nonlinear reduction so every output element contributes a distinct
    # cotangent — catches transposition/masking bugs a plain sum would hide.
    w = jnp.asarray(rng.standard_normal((h, t, d)), jnp.float32)

    def loss(attn):
        def f(q, k, v):
            return jnp.sum(jnp.tanh(attn(q, k, v, seg)) * w)

        return f

    g_ker = jax.grad(loss(flash_attention), argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss(attention_ref), argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g_ker, g_ref, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-5, rtol=5e-4, err_msg=f"d{name}"
        )


def test_bf16_forward():
    rng = np.random.default_rng(0)
    q, k, v = make_qkv(rng, 2, 256, 32, jnp.bfloat16)
    seg = random_segments(rng, 256, 3)
    out = flash_attention(q, k, v, seg)
    assert out.dtype == jnp.bfloat16
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), seg)
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32), np.asarray(ref), atol=3e-2, rtol=3e-2
    )


def test_segment_isolation():
    """Tokens in one segment must be invariant to other segments' content."""
    rng = np.random.default_rng(1)
    h, t, d = 2, 256, 32
    q, k, v = make_qkv(rng, h, t, d, jnp.float32)
    seg = jnp.where(jnp.arange(t) < 128, 0, 1).astype(jnp.int32)
    out1 = flash_attention(q, k, v, seg)
    # Perturb segment 1 only; segment 0's outputs must not move.
    noise = jnp.asarray(rng.standard_normal((h, t, d)), jnp.float32)
    bump = jnp.where(jnp.arange(t)[None, :, None] >= 128, noise, 0.0)
    out2 = flash_attention(q + bump, k + bump, v + bump, seg)
    np.testing.assert_allclose(
        np.asarray(out1[:, :128]), np.asarray(out2[:, :128]), atol=1e-6
    )
    assert not np.allclose(np.asarray(out1[:, 128:]), np.asarray(out2[:, 128:]))


def test_causality():
    """Future tokens must not influence past outputs within a segment."""
    rng = np.random.default_rng(2)
    h, t, d = 1, 128, 16
    q, k, v = make_qkv(rng, h, t, d, jnp.float32)
    seg = jnp.zeros(t, jnp.int32)
    out1 = flash_attention(q, k, v, seg)
    k2 = k.at[:, 100:].add(5.0)
    v2 = v.at[:, 100:].add(5.0)
    out2 = flash_attention(q, k2, v2, seg)
    np.testing.assert_allclose(np.asarray(out1[:, :100]), np.asarray(out2[:, :100]), atol=1e-6)


def test_matches_single_sequence_softmax():
    """One segment, no packing: equals textbook causal attention."""
    rng = np.random.default_rng(3)
    h, t, d = 2, 128, 32
    q, k, v = make_qkv(rng, h, t, d, jnp.float32)
    seg = jnp.zeros(t, jnp.int32)
    out = flash_attention(q, k, v, seg)
    s = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(d)
    mask = jnp.tril(jnp.ones((t, t), bool))
    s = jnp.where(mask[None], s, -jnp.inf)
    ref = jnp.einsum("hqk,hkd->hqd", jax.nn.softmax(s, axis=-1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("block_q,block_k", [(128, 128), (128, 64), (64, 128), (64, 64)])
def test_block_size_invariance(block_q, block_k):
    """Output must not depend on the VMEM tile decomposition."""
    rng = np.random.default_rng(4)
    q, k, v = make_qkv(rng, 2, 256, 32, jnp.float32)
    seg = random_segments(rng, 256, 4)
    out = flash_attention(q, k, v, seg, None, block_q, block_k)
    ref = attention_ref(q, k, v, seg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)
