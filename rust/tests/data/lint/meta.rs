//! Lint fixture: the suppression audit itself — reason-less, unknown,
//! unused and unparseable directives are all findings.

// skrull-lint: allow(nan-unsafe-ord)
pub fn reasonless(a: f64, b: f64) -> bool {
    a.partial_cmp(&b).is_some()
}

// skrull-lint: allow(no-such-rule) -- the rule name is a typo
pub fn unknown() {}

// skrull-lint: allow(panic-in-lib) -- nothing here panics
pub fn unused() {}

// skrull-lint allow(nan-unsafe-ord) -- missing the colon
pub fn unparseable() {}
