//! Lint fixture: `truncating-cast` fires on narrowing casts only.

pub fn narrow(x: u64) -> u32 {
    x as u32
}

pub fn widen(x: u32) -> u64 {
    x as u64
}

pub fn index(x: u64) -> usize {
    x as usize
}

pub fn clamped(x: u64) -> u32 {
    // skrull-lint: allow(truncating-cast) -- fixture: clamped to u32::MAX first, conversion is exact
    x.min(u32::MAX as u64) as u32
}
