//! Lint fixture: `nan-unsafe-ord` (plus the panic the unwrap idiom adds).

pub fn sort_bad(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

pub fn sort_good(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.total_cmp(b));
}

pub fn sort_documented(xs: &mut [f64]) {
    // skrull-lint: allow(nan-unsafe-ord) -- fixture: Equal fallback keeps the sort NaN-tolerant
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(core::cmp::Ordering::Equal));
}
