//! Lint fixture: `wall-clock-in-pure-code` fires outside sanctioned sites.

pub fn elapsed_s(t0: std::time::Instant) -> f64 {
    t0.elapsed().as_secs_f64()
}
