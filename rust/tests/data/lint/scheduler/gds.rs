//! Lint fixture: `hot-path-alloc` fires only inside the declared hot
//! function (`schedule_rank_inner` for a file named scheduler/gds.rs).

pub fn schedule_rank_inner(n: usize) -> Vec<usize> {
    let mut out = vec![0; n];
    // skrull-lint: allow(hot-path-alloc) -- fixture: arena grows once then is recycled
    let pool: Vec<usize> = Vec::new();
    out.extend(pool);
    out
}

pub fn helper(n: usize) -> Vec<usize> {
    (0..n).collect()
}
