//! Lint fixture: util/ is outside the `panic-in-lib` scope (the SPSC
//! channel's lock-poison-fatal convention; Miri covers it instead).

pub fn must(x: Option<u32>) -> u32 {
    x.unwrap()
}
