//! Lint fixture: lexer stress test.  Every rule-triggering token below is
//! hidden inside a literal or comment except one real `partial_cmp` at the
//! bottom — the file must produce exactly that single finding.

pub fn torture<'a>(s: &'a str) -> &'a str {
    let _raw = r"not findings: .unwrap() as u32 HashMap";
    let _raw_hash = r#"still " a string: partial_cmp Instant"#;
    let _raw_two = r##"nested "# quote: SystemTime"##;
    let _bytes = b"panic! vec! Box::new";
    let _braw = br#"unreachable!"#;
    let r#type = 1u32;
    let _ = r#type;
    let _ch = 'x';
    let _quote = '\'';
    let _newline = '\n';
    /* block comment: .expect("x") as u16
       /* nested: SystemTime::now() */
       still commented out: HashSet::new() */
    let _s = "string with // not a comment: .unwrap()";
    s
}

pub fn the_real_one(a: f64, b: f64) -> bool {
    a.partial_cmp(&b).is_some()
}
