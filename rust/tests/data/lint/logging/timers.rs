//! Lint fixture: logging/ is a sanctioned wall-clock site — no findings.

pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
