//! Lint fixture: `panic-in-lib`, with `#[cfg(test)]` items exempt and
//! `unwrap_or`-style methods never confused with `unwrap`.

pub fn risky(x: Option<u32>) -> u32 {
    x.unwrap()
}

pub fn fallback(x: Option<u32>) -> u32 {
    x.unwrap_or(7)
}

pub fn checked(x: Option<u32>) -> u32 {
    // skrull-lint: allow(panic-in-lib) -- fixture: caller asserts Some at the boundary
    x.expect("validated upstream")
}

pub fn boom() {
    panic!("kaboom");
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap() {
        assert_eq!(super::risky(Some(3)), 3);
        let v = vec![1u32];
        v.first().unwrap();
    }
}
