//! Lint fixture: `nondet-iteration` in a deterministic-output module.

pub fn histogram(xs: &[u64]) -> Vec<(u64, u64)> {
    let mut m = std::collections::HashMap::new();
    for &x in xs {
        *m.entry(x).or_insert(0u64) += 1;
    }
    let mut v: Vec<(u64, u64)> = m.into_iter().collect();
    v.sort_unstable();
    v
}

pub fn seen(xs: &[u64]) -> bool {
    // skrull-lint: allow(nondet-iteration) -- fixture: membership queries only, iteration order never observed
    let s: std::collections::HashSet<u64> = xs.iter().copied().collect();
    s.contains(&0)
}
