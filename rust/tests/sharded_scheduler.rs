//! Shard-count invariance and incremental re-scheduling equivalence — the
//! acceptance gates of the shared-nothing scheduler:
//!
//! * 1, 2 and N shards are byte-identical to `gds::schedule_reference`
//!   across ≥200 random workloads (arenas and pools reused throughout);
//! * incremental re-scheduling through the loader equals fresh scheduling
//!   iteration by iteration, and actually reuses work on repeats;
//! * the extreme-K regime (2^16 sequences, 128K-token outliers) agrees
//!   across shard counts without overflow.

use skrull::config::{ExperimentConfig, Policy};
use skrull::data::loader::ScheduledLoader;
use skrull::data::{Dataset, LengthDistribution, Sequence};
use skrull::model::ModelSpec;
use skrull::perfmodel::FlopsModel;
use skrull::rng::Rng;
use skrull::scheduler::gds;

fn shard_counts() -> [usize; 3] {
    [1, 2, skrull::util::par::max_threads().max(3)]
}

#[test]
fn shard_count_invariance_on_200_workloads() {
    let flops = FlopsModel::new(&ModelSpec::qwen2_5_0_5b());
    let mut rng = Rng::seed_from_u64(0x5A4D);
    // one persistent ctx (arena + pool) per shard count — recreating the
    // pool per workload would hide reuse bugs
    let mut ctxs: Vec<gds::SchedCtx> = shard_counts().iter().map(|_| Default::default()).collect();
    let mut compared = 0usize;
    for name in ["wikipedia", "lmsys", "chatqa2"] {
        let ds = Dataset::synthesize(&LengthDistribution::by_name(name).unwrap(), 20_000, 21)
            .truncated(26 * 1024 * 8);
        for trial in 0..70 {
            let k = [6usize, 16, 48, 128][trial % 4];
            let batch = ds.sample_batch(&mut rng, k);
            let mut cfg = gds::GdsConfig::new(26 * 1024, 8, 4);
            if trial % 5 == 0 {
                cfg.bucket_size = 4 * 1024; // memory-pressure regime
            }
            if trial % 3 == 0 {
                cfg.dp = 3; // dp not divisible by every shard count
            }
            let reference = gds::schedule_reference(&batch, &cfg, &flops);
            for (ctx, &shards) in ctxs.iter_mut().zip(shard_counts().iter()) {
                cfg.shards = shards;
                let got = gds::schedule_with_ctx(&batch, &cfg, &flops, ctx);
                match (&reference, &got) {
                    (Ok(a), Ok(b)) => assert_eq!(a, b, "{name} trial {trial} shards={shards}"),
                    (Err(a), Err(b)) => assert_eq!(a, b, "{name} trial {trial} shards={shards}"),
                    _ => panic!(
                        "{name} trial {trial} shards={shards}: feasibility mismatch \
                         ref={:?} sharded={:?}",
                        reference.is_ok(),
                        got.is_ok()
                    ),
                }
            }
            compared += 1;
        }
    }
    assert!(compared >= 200, "only {compared} workloads compared");
}

#[test]
fn incremental_loader_equals_fresh_loader_iteration_by_iteration() {
    let cfg0 = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "lmsys");
    let ds = Dataset::synthesize(&LengthDistribution::by_name("lmsys").unwrap(), 20_000, 5)
        .truncated(cfg0.bucket_size * cfg0.cluster.cp as u32);
    for policy in [Policy::Skrull, Policy::SkrullRefined] {
        let mut fresh_cfg = cfg0.clone();
        fresh_cfg.policy = policy;
        let mut inc_cfg = fresh_cfg.clone();
        inc_cfg.incremental = true;
        let mut fresh = ScheduledLoader::new(&ds, &fresh_cfg);
        let mut inc = ScheduledLoader::new(&ds, &inc_cfg);
        for it in 0..5 {
            // same seed → same sampling stream; schedules must agree even
            // though the incremental loader carries caches between calls
            let (batch_f, sched_f) = fresh.next_iteration().unwrap();
            let (batch_i, sched_i) = inc.next_iteration().unwrap();
            assert_eq!(batch_f, batch_i, "{policy:?} iteration {it}");
            assert_eq!(sched_f, sched_i, "{policy:?} iteration {it}");
        }
    }
}

#[test]
fn incremental_loader_reuses_work_on_repeated_batches() {
    let mut cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
    cfg.incremental = true;
    cfg.shards = 1; // in-process path so the counters are observable
    let ds = Dataset::synthesize(&LengthDistribution::wikipedia(), 20_000, 7)
        .truncated(cfg.bucket_size * cfg.cluster.cp as u32);
    let mut loader = ScheduledLoader::new(&ds, &cfg);
    loader.sched_parallel = false;
    let mut rng = Rng::seed_from_u64(0xBEEF);
    let batch = ds.sample_batch(&mut rng, cfg.cluster.batch_size);
    let first = loader.schedule_batch(&batch).unwrap();
    assert_eq!(loader.sched_partition_reuses(), 0);
    for round in 1..4 {
        let again = loader.schedule_batch(&batch).unwrap();
        assert_eq!(first, again, "round {round}");
    }
    assert_eq!(loader.sched_partition_reuses(), 3);
    assert_eq!(loader.sched_rank_cache_hits(), 3 * cfg.cluster.dp as u64);
    // partially changed batch: caches miss, result still correct
    let mut changed = batch.clone();
    let last = changed.len() - 1;
    changed[last].len = (changed[last].len / 2).max(1);
    let flops = FlopsModel::new(&cfg.model);
    let gcfg = gds::GdsConfig::new(cfg.bucket_size, cfg.cluster.cp, cfg.cluster.dp);
    let expect = gds::schedule_reference(&changed, &gcfg, &flops).unwrap();
    assert_eq!(loader.schedule_batch(&changed).unwrap(), expect);
    assert_eq!(loader.sched_partition_reuses(), 3);
}

#[test]
fn extreme_k_with_long_outliers_agrees_across_shard_counts() {
    // 2^16 sequences with 128K-token outliers: token sums overflow u32 by
    // orders of magnitude, so this doubles as the overflow regression at
    // integration level (cap = 26K·8 = 212992 > 131072, so the outliers
    // are schedulable).
    let flops = FlopsModel::new(&ModelSpec::qwen2_5_0_5b());
    let k: usize = 1 << 16;
    let mut rng = Rng::seed_from_u64(0x1046);
    let ds = Dataset::synthesize(&LengthDistribution::wikipedia(), 50_000, 13)
        .truncated(26 * 1024 * 8);
    let mut batch = ds.sample_batch(&mut rng, k);
    for i in 0..64 {
        // sprinkle maximal outliers across the batch
        batch[i * (k / 64)].len = 128 * 1024;
    }
    let mut cfg = gds::GdsConfig::new(26 * 1024, 8, 4);
    let mut baseline: Option<skrull::scheduler::plan::IterationSchedule> = None;
    for shards in shard_counts() {
        cfg.shards = shards;
        let mut ctx = gds::SchedCtx::default();
        let got = gds::schedule_with_ctx(&batch, &cfg, &flops, &mut ctx).unwrap();
        // exactly-once at scale, and every micro-batch under the cap
        let cap = cfg.bucket_size as u64 * cfg.cp as u64;
        let n_assigned: usize = got.ranks.iter().map(|r| {
            r.micro_batches.iter().map(|mb| mb.seqs.len()).sum::<usize>()
        }).sum();
        assert_eq!(n_assigned, k, "shards={shards}");
        for r in &got.ranks {
            for mb in &r.micro_batches {
                assert!(mb.total_tokens() <= cap, "shards={shards}");
            }
        }
        match &baseline {
            None => baseline = Some(got),
            Some(b) => assert_eq!(&got, b, "shards={shards} diverged from shards=1"),
        }
    }
}

#[test]
fn shard_knob_rides_through_the_loader() {
    // cfg.shards > 1 through ScheduledLoader must not change schedules
    let cfg0 = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "chatqa2");
    let ds = Dataset::synthesize(&LengthDistribution::chatqa2(), 20_000, 3)
        .truncated(cfg0.bucket_size * cfg0.cluster.cp as u32);
    let mut sharded_cfg = cfg0.clone();
    sharded_cfg.shards = 3;
    let mut plain = ScheduledLoader::new(&ds, &cfg0);
    let mut sharded = ScheduledLoader::new(&ds, &sharded_cfg);
    for it in 0..3 {
        let (batch_p, sched_p) = plain.next_iteration().unwrap();
        let (batch_s, sched_s) = sharded.next_iteration().unwrap();
        assert_eq!(batch_p, batch_s, "iteration {it}");
        assert_eq!(sched_p, sched_s, "iteration {it}");
    }
}

#[test]
fn sequences_keep_identity_through_the_sharded_path() {
    // ids survive the ownership round trip through the shard queues
    let flops = FlopsModel::new(&ModelSpec::qwen2_5_0_5b());
    let batch: Vec<Sequence> = (0..40)
        .map(|i| Sequence { id: 1000 + i as u64, len: 100 + 700 * (i as u32 % 7) })
        .collect();
    let mut cfg = gds::GdsConfig::new(8 * 1024, 4, 4);
    cfg.shards = 2;
    let mut ctx = gds::SchedCtx::default();
    let sched = gds::schedule_with_ctx(&batch, &cfg, &flops, &mut ctx).unwrap();
    let mut ids = sched.assigned_ids();
    ids.sort_unstable();
    assert_eq!(ids, (1000..1040).collect::<Vec<u64>>());
}
