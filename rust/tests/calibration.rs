//! Calibration round-trip integration tests (the PR's acceptance
//! criteria): fitting on a trace emitted by the analytic simulator must
//! reproduce the analytic cost model's per-iteration predictions, the
//! checked-in fixture must calibrate and validate, and the e2e sweep must
//! run end-to-end under `CostSource::Calibrated` with schema-v3 output.

use skrull::bench::e2e::{self, E2eOptions};
use skrull::calib::{self, EmitOptions};
use skrull::cluster::run::{simulate_run, RunConfig};
use skrull::config::{CostSource, ExperimentConfig, Policy};
use skrull::data::{Dataset, LengthDistribution};
use skrull::memplan::MemoryConfig;
use skrull::model::ModelSpec;
use skrull::perfmodel::CostModel;

fn small_sweep() -> EmitOptions {
    let mut opts = EmitOptions::default_sweep(ModelSpec::qwen2_5_0_5b());
    opts.iterations = 2;
    opts.dataset_samples = 1_500;
    opts
}

/// Emit → fit → serialize → parse: the profile as a run would load it.
fn calibrated_profile() -> calib::CalibratedProfile {
    let trace = calib::emit_calibration_sweep(&small_sweep()).unwrap();
    let profile = calib::calibrate(&trace).unwrap();
    // exercise the serialized form, not just the in-memory fit
    let text = calib::profile_io::render_profile(&profile);
    calib::profile_io::parse_profile(&text).unwrap()
}

#[test]
fn round_trip_calibration_reproduces_analytic_predictions_within_5_percent() {
    let profile = calibrated_profile();
    profile.validate(0.99).unwrap();
    let calibrated_cost_by_model = profile.cost_model(&ModelSpec::qwen2_5_0_5b());

    // across the e2e sweep's distributions: same schedules, analytic vs
    // calibrated per-iteration execution predictions
    for dataset in ["wikipedia", "lmsys", "chatqa2"] {
        let mut cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), dataset);
        cfg.policy = Policy::Skrull;
        cfg.cluster.batch_size = 16;
        let dist = LengthDistribution::by_name(dataset).unwrap();
        let ds = Dataset::synthesize(&dist, 2_000, 11)
            .truncated(cfg.bucket_size * cfg.cluster.cp as u32);
        let analytic = cfg.cost_model();
        let run = RunConfig::new(4, false);
        let truth = simulate_run(&ds, &cfg, &analytic, &run).unwrap();
        let cal = simulate_run(&ds, &cfg, &calibrated_cost_by_model, &run).unwrap();
        assert_eq!(truth.iterations.len(), cal.iterations.len());
        for (i, (t, c)) in truth.iterations.iter().zip(&cal.iterations).enumerate() {
            let rel = (c.exec_seconds - t.exec_seconds).abs() / t.exec_seconds;
            assert!(
                rel < 0.05,
                "{dataset} iter {i}: calibrated {} vs analytic {} ({rel:.4} rel)",
                c.exec_seconds,
                t.exec_seconds
            );
        }
        // the aggregate prediction is tight too
        let rel = (cal.exec_seconds - truth.exec_seconds).abs() / truth.exec_seconds;
        assert!(rel < 0.05, "{dataset}: total rel err {rel}");
    }

    // the calibrated memory fit recovers the memplan activation curve:
    // derived capacity from measurement matches the analytic derivation
    let cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "chatqa2");
    let analytic_c = cfg.mem_plan().derive_capacity().unwrap();
    let m = profile.mem.as_ref().expect("memory fit present");
    let cal_plan = cfg.mem_plan().with_calibrated(m.slope, m.intercept);
    let cal_c = cal_plan.derive_capacity().unwrap();
    let rel = (cal_c as f64 - analytic_c as f64).abs() / analytic_c as f64;
    assert!(rel < 0.05, "derived capacity {cal_c} vs analytic {analytic_c}");
}

#[test]
fn checked_in_sample_trace_calibrates_and_validates() {
    // the CI fixture: `skrull calibrate --trace ... --validate` must pass
    let trace = calib::read_trace("rust/tests/data/sample_trace.jsonl").unwrap();
    assert_eq!(trace.header.version, calib::TRACE_SCHEMA_VERSION);
    assert_eq!(trace.header.model, "qwen2.5-0.5b");
    assert_eq!(trace.records.len(), 12);
    let profile = calib::calibrate(&trace).unwrap();
    // golden coefficients the fixture was built from
    assert!((profile.comp.slope - 2.0e-15).abs() / 2.0e-15 < 1e-6, "{}", profile.comp.slope);
    assert!((profile.comp.intercept - 1.0e-5).abs() < 1e-10);
    assert!((profile.comm.slope - 1.25e-11).abs() / 1.25e-11 < 1e-6);
    assert!((profile.comm.intercept - 2.0e-5).abs() < 1e-10);
    assert!((profile.comm_inter.slope - 1.0e-10).abs() / 1.0e-10 < 1e-6);
    assert!((profile.comm_inter.intercept - 4.0e-5).abs() < 1e-10);
    assert!(!profile.inter_extrapolated);
    assert!((profile.step_overhead_s - 3.0e-3).abs() < 1e-12);
    let mem = profile.mem.as_ref().expect("memory fit");
    assert!((mem.slope - 5.0e4).abs() / 5.0e4 < 1e-6);
    assert!((mem.intercept - 6.0e9).abs() / 6.0e9 < 1e-6);
    // the validation gate the CI step runs
    let residuals = calib::report::residuals(&trace, &profile);
    calib::report::validate(&profile, &residuals, 0.95, 0.05).unwrap();
}

#[test]
fn e2e_sweep_under_calibrated_cost_source_emits_valid_schema_v4() {
    let profile = calibrated_profile();
    let opts = E2eOptions {
        model: ModelSpec::qwen2_5_0_5b(),
        datasets: vec!["chatqa2".into()],
        topologies: vec![(4, 8)],
        iterations: 2,
        batch_size: Some(16),
        dataset_samples: 2_000,
        seeds: vec![11],
        pipelined: true,
        epoch: false,
        memory: MemoryConfig::default(),
        cost: CostSource::Calibrated { path: "<in-memory>".into(), profile: profile.clone() },
        jobs: 2,
        deterministic_timing: false,
    };
    let sweep = e2e::run_sweep(&opts).unwrap();
    assert_eq!(sweep.cost_source, "calibrated");
    for c in &sweep.cells {
        // the acceptance bar: calibrated predictions track the analytic
        // ground truth within 5% in every cell
        assert!(
            c.estimator_error <= e2e::CALIBRATED_ESTIMATOR_ERROR_MAX,
            "{}: estimator_error {}",
            c.policy.name(),
            c.estimator_error
        );
        assert!(c.report.wall_seconds() > 0.0);
        // a calibrated cell schedules exactly once per iteration — the
        // estimator_error comes from *repricing* the built schedules, not
        // from a second GDS/DACP pass (the pre-split engine's ~2x work)
        assert_eq!(
            c.report.sched_invocations, 2,
            "{}: calibrated cell scheduled more than once per iteration",
            c.policy.name()
        );
    }
    // the repriced estimator_error equals the old double-run computation:
    // re-run the engine under the analytic model on an identically
    // constructed workload and compare per-iteration execution exactly
    {
        let mut cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "chatqa2");
        cfg.cluster.batch_size = 16;
        cfg.seed = 11;
        cfg.policy = Policy::Skrull;
        cfg.cost = CostSource::Calibrated { path: "<in-memory>".into(), profile };
        let dist = LengthDistribution::by_name("chatqa2").unwrap();
        let ds = Dataset::synthesize(&dist, 2_000, 11 ^ 0xD5)
            .truncated(cfg.bucket_size * cfg.cluster.cp as u32);
        let run = RunConfig::new(2, true);
        let calibrated = simulate_run(&ds, &cfg, &cfg.cost_model(), &run).unwrap();
        let analytic = CostModel::paper_default(&cfg.model);
        let truth = simulate_run(&ds, &cfg, &analytic, &run).unwrap();
        let double_run_err = calibrated
            .iterations
            .iter()
            .zip(&truth.iterations)
            .map(|(a, b)| (a.exec_seconds - b.exec_seconds).abs() / b.exec_seconds)
            .sum::<f64>()
            / calibrated.iterations.len() as f64;
        let cell = sweep.cell(Policy::Skrull, "chatqa2", 4, 8).unwrap();
        assert_eq!(
            cell.estimator_error, double_run_err,
            "repriced estimator_error diverged from the double-run value"
        );
    }
    // skrull still beats the baseline under the calibrated model
    let sk = sweep.cell(Policy::Skrull, "chatqa2", 4, 8).unwrap();
    assert!(sk.speedup_vs_baseline > 1.0, "{}", sk.speedup_vs_baseline);
    // schema-v4 output validates (including the calibrated gate)
    let json = e2e::render_json(&sweep);
    assert!(json.contains("\"schema_version\": 4"));
    assert!(json.contains("\"cost_source\": \"calibrated\""));
    assert!(json.contains("\"estimator_error\""));
    assert!(json.contains("\"sweep_seconds\""));
    assert!(json.contains("\"sched_invocations\": 2"));
    e2e::validate_json(&json).unwrap();
}

#[test]
fn analytic_cost_source_keeps_pre_calibration_schedules_byte_identical() {
    // acceptance criterion: CostSource::Analytic output is byte-identical
    // to the pre-PR engine — the loader still schedules with the paper
    // cost model, so schedules (and the sim's busy accounting) match a
    // from-scratch paper_default run exactly
    let cfg = {
        let mut c = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "chatqa2");
        c.policy = Policy::SkrullRefined; // the one policy that consults the cost model
        c.cluster.batch_size = 16;
        c
    };
    assert!(matches!(cfg.cost, CostSource::Analytic));
    let dist = LengthDistribution::by_name("chatqa2").unwrap();
    let ds = Dataset::synthesize(&dist, 2_000, 11)
        .truncated(cfg.bucket_size * cfg.cluster.cp as u32);
    let cost = cfg.cost_model();
    let run = RunConfig::new(3, true);
    let a = simulate_run(&ds, &cfg, &cost, &run).unwrap();
    let b = simulate_run(&ds, &cfg, &cost, &run).unwrap();
    assert_eq!(a.exec_seconds, b.exec_seconds);
    assert_eq!(a.data_tokens, b.data_tokens);
    assert_eq!(a.rank_busy, b.rank_busy);
    for (x, y) in a.iterations.iter().zip(&b.iterations) {
        assert_eq!(x.exec_seconds, y.exec_seconds);
        assert_eq!(x.micro_batches, y.micro_batches);
        assert_eq!(x.padded_tokens, y.padded_tokens);
    }
}
