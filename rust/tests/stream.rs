//! Streaming data plane integration tests, exercised through the public
//! API exactly as the CLI uses it:
//!
//! * a spilled-to-disk build produces **byte-identical** schedules to the
//!   in-memory build — same batches, same `IterationSchedule`s, same
//!   `schedule_digest` — for sampled and epoch modes across policies,
//!   while the page cache stays within a deliberately tiny budget that
//!   forces eviction;
//! * a corrupted spill file surfaces as `SchedError::Stream`, never as a
//!   wrong schedule;
//! * the streamed e2e sweep on the bursty non-stationary corpus fires
//!   drift events, stays within the configured RAM budget in every cell,
//!   matches the in-memory sweep digest-for-digest, and renders schema-v5
//!   JSON that passes the validator.

use skrull::bench::e2e::{self, E2eOptions};
use skrull::cluster::run::{build_run, build_run_streamed, schedule_digest, RunConfig};
use skrull::config::{ExperimentConfig, Policy};
use skrull::data::{Dataset, LengthDistribution};
use skrull::model::ModelSpec;
use skrull::scheduler::SchedError;
use skrull::stream::{ingest_dataset, StreamConfig, StreamSource};

fn tmp_path(tag: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("skrull-streamtest-{}-{tag}.spill", std::process::id()));
    p
}

fn workload(policy: Policy, dataset: &str, n: usize) -> (Dataset, ExperimentConfig) {
    let mut cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), dataset);
    cfg.policy = policy;
    cfg.cluster.dp = 2;
    cfg.cluster.cp = 2;
    cfg.cluster.batch_size = 16;
    let dist = LengthDistribution::by_name(dataset).expect("known dataset");
    let ds = Dataset::synthesize(&dist, n, 11).truncated(cfg.bucket_size * 2);
    (ds, cfg)
}

/// Small pages + a budget of only a few frames, so every run evicts.
fn tiny_stream_cfg() -> StreamConfig {
    StreamConfig { page_len: 64, ..StreamConfig::default() }
}
const TINY_BUDGET: u64 = 1024; // 64-entry pages = 256 B → 3 leader frames

#[test]
fn spilled_build_is_byte_identical_to_in_memory() {
    for policy in [Policy::Baseline, Policy::Skrull, Policy::SkrullRefined] {
        for epoch in [false, true] {
            let (ds, cfg) = workload(policy, "chatqa2", 600);
            let run = if epoch {
                RunConfig::epoch(cfg.pipelined)
            } else {
                RunConfig::new(4, cfg.pipelined)
            };
            let in_mem = build_run(&ds, &cfg, &run).expect("in-memory build");

            let path = tmp_path(&format!("ident-{}-{epoch}", policy.name()));
            let ingest =
                ingest_dataset(&ds, &path, &tiny_stream_cfg(), cfg.seed).expect("ingest");
            let mut src =
                StreamSource::open_with_budget(&path, TINY_BUDGET).expect("open spill");
            let streamed = build_run_streamed(&mut src, &ingest, &cfg, &run)
                .expect("streamed build");
            std::fs::remove_file(&path).expect("cleanup spill");

            assert_eq!(in_mem.iterations.len(), streamed.iterations.len());
            for (a, b) in in_mem.iterations.iter().zip(&streamed.iterations) {
                assert_eq!(a.batch, b.batch, "{policy:?} epoch={epoch}: batch drift");
                assert_eq!(a.schedule, b.schedule, "{policy:?} epoch={epoch}: schedule drift");
            }
            assert_eq!(
                schedule_digest(&in_mem),
                schedule_digest(&streamed),
                "{policy:?} epoch={epoch}: digest drift"
            );
            // the streamed build really went through the bounded cache
            assert_eq!(in_mem.peak_stream_rss_bytes, 0);
            assert!(streamed.peak_stream_rss_bytes > 0);
            assert!(streamed.peak_stream_rss_bytes <= TINY_BUDGET);
        }
    }
}

#[test]
fn corrupted_spill_surfaces_as_stream_error() {
    let (ds, cfg) = workload(Policy::Skrull, "chatqa2", 600);
    let path = tmp_path("corrupt");
    let ingest = ingest_dataset(&ds, &path, &tiny_stream_cfg(), cfg.seed).expect("ingest");
    // flip one byte in the last page's payload: the checksum must reject
    // it during the build, not let a wrong length reach the scheduler
    let mut bytes = std::fs::read(&path).expect("read spill");
    let n = bytes.len();
    bytes[n - 12] ^= 0x40;
    std::fs::write(&path, &bytes).expect("rewrite spill");

    let mut src = StreamSource::open_with_budget(&path, TINY_BUDGET).expect("open spill");
    let run = RunConfig::epoch(cfg.pipelined); // epoch order visits every page
    let err = build_run_streamed(&mut src, &ingest, &cfg, &run)
        .expect_err("corrupted page must fail the build");
    assert!(matches!(err, SchedError::Stream { .. }), "got {err:?}");
    std::fs::remove_file(&path).expect("cleanup spill");
}

#[test]
fn streamed_e2e_sweep_fires_drift_within_budget_and_matches_in_memory() {
    let mut opts = E2eOptions::smoke();
    opts.datasets = vec!["bursty-long".into()];
    opts.dataset_samples = 8192; // 4 bursty phases of 2048 > the 1024 window
    opts.seeds = vec![42];
    opts.jobs = 2;
    opts.deterministic_timing = true;

    let in_mem = e2e::run_sweep(&opts).expect("in-memory sweep");

    let mut dir = std::env::temp_dir();
    dir.push(format!("skrull-streamtest-e2e-{}", std::process::id()));
    let mut sopts = opts.clone();
    sopts.stream.spill_dir = Some(dir.to_string_lossy().into_owned());
    sopts.stream.ram_mb = 1;
    let streamed = e2e::run_sweep(&sopts).expect("streamed sweep");
    std::fs::remove_dir_all(&dir).expect("cleanup spill dir");

    assert!(!in_mem.streamed && streamed.streamed);
    assert_eq!(streamed.stream_ram_bytes, 1024 * 1024);
    assert_eq!(e2e::render_digests(&in_mem), e2e::render_digests(&streamed));
    for (a, b) in in_mem.cells.iter().zip(&streamed.cells) {
        assert_eq!(a.sched_digest, b.sched_digest, "{}/{:?}", a.dataset, a.policy);
        assert_eq!(a.report.data_tokens, b.report.data_tokens);
        assert_eq!(a.report.drift_events, 0);
        assert!(
            b.report.drift_events > 0,
            "{}/{:?}: bursty ingest must fire drift",
            b.dataset,
            b.policy
        );
        assert_eq!(a.report.peak_stream_rss_bytes, 0);
        assert!(b.report.peak_stream_rss_bytes > 0);
        assert!(b.report.peak_stream_rss_bytes <= streamed.stream_ram_bytes);
    }
    e2e::validate_json(&e2e::render_json(&in_mem)).expect("in-memory JSON validates");
    e2e::validate_json(&e2e::render_json(&streamed)).expect("streamed JSON validates");
}
