//! Fleet subsystem property tests, exercised through the public API
//! (`skrull::fleet` + `skrull::bench::fleet`) exactly as `skrull fleet`
//! uses it:
//!
//! * no tenant ever holds more in-flight jobs than its quota;
//! * every job is conserved (submitted = finished + rejected) and every
//!   admitted job is scheduled exactly once (build-once/price-many);
//! * the priority discipline never dispatches over a strictly
//!   higher-priority placeable entry;
//! * the rendered `BENCH_fleet.json` is byte-identical across `--jobs 1`
//!   and `--jobs 4` and across repeated same-seed sweeps, and passes the
//!   schema-v1 validator.

use skrull::bench::fleet::{render_json, run_sweep, validate_json, FleetBenchOptions};
use skrull::fleet::{simulate, synthesize, ArrivalPattern, ClusterSpec, FleetPolicy, SimOptions};

fn report(
    pattern: ArrivalPattern,
    policy: FleetPolicy,
    cluster: &str,
    n_jobs: usize,
    seed: u64,
) -> skrull::fleet::FleetReport {
    let workload = synthesize(pattern, n_jobs, seed);
    let opts = SimOptions {
        policy,
        cluster: ClusterSpec::by_name(cluster).expect("known cluster"),
        serial_scheduler: false,
    };
    simulate(&workload, &opts).expect("simulation completes")
}

#[test]
fn no_tenant_ever_exceeds_its_quota() {
    for pattern in ArrivalPattern::ALL {
        for policy in FleetPolicy::ALL {
            let workload = synthesize(pattern, 24, 7);
            let opts = SimOptions {
                policy,
                cluster: ClusterSpec::by_name("paper").expect("known cluster"),
                serial_scheduler: false,
            };
            let r = simulate(&workload, &opts).expect("simulation completes");
            for (t, stats) in r.tenants.iter().enumerate() {
                let quota = workload.tenants[t].quota;
                assert!(
                    stats.peak_in_flight <= quota,
                    "{} × {}: tenant {t} peaked at {} in-flight against quota {quota}",
                    pattern.name(),
                    policy.name(),
                    stats.peak_in_flight
                );
                assert_eq!(
                    stats.submitted,
                    stats.admitted + stats.rejected,
                    "tenant {t}: admission accounting leaked a job"
                );
                assert_eq!(stats.finished, stats.admitted, "tenant {t}: a job went missing");
            }
        }
    }
}

#[test]
fn every_job_is_conserved_and_built_exactly_once() {
    for pattern in ArrivalPattern::ALL {
        for cluster in ClusterSpec::ALL_NAMES {
            let r = report(pattern, FleetPolicy::ShortestPricedFirst, cluster, 20, 3);
            assert_eq!(r.submitted, 20);
            assert_eq!(r.submitted, r.finished + r.rejected, "conservation violated");
            assert_eq!(r.admitted, r.finished, "an admitted job never finished");
            assert_eq!(r.builds, r.admitted, "build count diverged from admissions");
            assert_eq!(r.max_builds_per_job, 1, "a job was scheduled more than once");
            assert!(
                r.pricings >= r.builds,
                "placement priced fewer times ({}) than it built ({})",
                r.pricings,
                r.builds
            );
            assert_eq!(r.queue_wait.len(), r.finished, "queue-wait sample per finished job");
        }
    }
}

#[test]
fn priority_discipline_never_inverts_and_preempts_under_load() {
    let mut preemptions = 0usize;
    for pattern in ArrivalPattern::ALL {
        let r = report(pattern, FleetPolicy::Priority, "paper", 48, 13);
        assert_eq!(
            r.priority_inversions, 0,
            "{}: priority dispatch passed over a higher-priority placeable job",
            pattern.name()
        );
        preemptions += r.preemptions;
    }
    assert!(preemptions > 0, "48-job fleets on one pool should preempt at least once");
}

#[test]
fn preempted_work_is_never_lost() {
    // Preemption re-queues a job with a checksummed resume point; the
    // simulator's end-of-run conservation gate (finished == admitted)
    // only holds if every preempted job resumes and completes.
    let mut saw_preemption = false;
    for pattern in ArrivalPattern::ALL {
        let r = report(pattern, FleetPolicy::Priority, "paper", 48, 13);
        if r.preemptions == 0 {
            continue;
        }
        saw_preemption = true;
        assert_eq!(r.finished, r.admitted, "a preempted job failed to resume");
        assert_eq!(r.max_builds_per_job, 1, "resume must reprice, never rebuild");
    }
    assert!(saw_preemption, "48-job priority fleets on one pool should preempt");
}

#[test]
fn sweep_json_is_byte_identical_across_jobs_and_repeat_runs() {
    let mut opts = FleetBenchOptions::smoke();
    opts.jobs_per_cell = 4;
    opts.jobs = 1;
    let first = render_json(&run_sweep(&opts).expect("sweep completes"));
    // repeated same-seed run
    let second = render_json(&run_sweep(&opts).expect("sweep completes"));
    assert_eq!(first, second, "same-seed sweeps diverged");
    // --jobs 4 fan-out
    opts.jobs = 4;
    let parallel = render_json(&run_sweep(&opts).expect("sweep completes"));
    assert_eq!(first, parallel, "--jobs 4 diverged from --jobs 1");
    validate_json(&first).expect("rendered sweep passes the schema-v1 validator");
    assert!(!first.contains("sweep_seconds"), "wall-clock leaked into the JSON");
}

#[test]
fn different_seeds_produce_different_fleets() {
    let a = report(ArrivalPattern::Steady, FleetPolicy::Fifo, "hetero", 16, 1);
    let b = report(ArrivalPattern::Steady, FleetPolicy::Fifo, "hetero", 16, 2);
    assert!(
        a.makespan.to_bits() != b.makespan.to_bits()
            || a.queue_wait.mean().to_bits() != b.queue_wait.mean().to_bits(),
        "two seeds produced observationally identical fleets"
    );
}
