//! Runtime integration tests: real PJRT execution of the AOT artifacts.
//! Require `make artifacts` (skipped cleanly when artifacts are absent,
//! e.g. on a fresh checkout before the first build).
//!
//! The centerpiece is `packing_equivalence_through_hlo`: the loss of two
//! sequences packed into one bucket must equal the token-weighted mean of
//! their standalone losses — validating the Pallas kernel's segment
//! masking, the packing layout, and the scheduler's core assumption, all
//! through the compiled HLO.

use skrull::config::Policy;
use skrull::coordinator::corpus::CorpusConfig;
use skrull::coordinator::{Trainer, TrainerOptions};
use skrull::data::packing::{pack, TokenSeq};
use skrull::runtime::Runtime;

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    std::path::Path::new(&dir)
        .join("manifest.txt")
        .exists()
        .then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

fn corpus_seqs(lens: &[u32]) -> Vec<TokenSeq> {
    CorpusConfig::tiny(512).corpus(7, lens)
}

#[test]
fn loads_manifest_and_compiles_smallest_bucket() {
    let dir = require_artifacts!();
    let mut rt = Runtime::load(&dir).unwrap();
    assert_eq!(rt.platform(), "cpu");
    let buckets = rt.available_buckets();
    assert!(!buckets.is_empty());
    rt.ensure_bucket(buckets[0]).unwrap();
    assert!(rt.compile_seconds > 0.0);
}

#[test]
fn train_step_returns_finite_loss_and_grads() {
    let dir = require_artifacts!();
    let mut rt = Runtime::load(&dir).unwrap();
    let params = rt.initial_params().unwrap();
    let seqs = corpus_seqs(&[100, 80]);
    let bucket = pack(&[&seqs[0], &seqs[1]], 256);
    let out = rt.train_step(&params, &bucket).unwrap();
    // random init over vocab 512: loss near ln(512) = 6.24
    assert!((4.0..9.0).contains(&out.loss), "loss {}", out.loss);
    assert_eq!(out.grads.len(), params.data.len());
    assert!(out.grads.iter().all(|g| g.is_finite()));
    let gnorm: f64 = out.grads.iter().map(|&g| (g as f64).powi(2)).sum::<f64>().sqrt();
    assert!(gnorm > 1e-6, "gradients must be nonzero");
}

#[test]
fn packing_equivalence_through_hlo() {
    let dir = require_artifacts!();
    let mut rt = Runtime::load(&dir).unwrap();
    let params = rt.initial_params().unwrap();
    let seqs = corpus_seqs(&[120, 90]);

    let separate: Vec<(f32, f64)> = seqs
        .iter()
        .map(|s| {
            let b = pack(&[s], 256);
            let w = b.loss_tokens();
            (rt.train_step(&params, &b).unwrap().loss, w)
        })
        .collect();
    let expected: f64 = separate.iter().map(|(l, w)| *l as f64 * w).sum::<f64>()
        / separate.iter().map(|(_, w)| w).sum::<f64>();

    let packed = pack(&[&seqs[0], &seqs[1]], 256);
    let got = rt.train_step(&params, &packed).unwrap().loss as f64;
    assert!(
        (got - expected).abs() < 2e-4,
        "packed {got} vs weighted separate {expected}"
    );
}

#[test]
fn padding_does_not_affect_loss() {
    // the same sequence in a 256 vs 512 bucket must give the same loss —
    // padding is segment-isolated and loss-masked end to end.
    let dir = require_artifacts!();
    let mut rt = Runtime::load(&dir).unwrap();
    let params = rt.initial_params().unwrap();
    let seqs = corpus_seqs(&[150]);
    let l256 = rt.train_step(&params, &pack(&[&seqs[0]], 256)).unwrap().loss;
    let l512 = rt.train_step(&params, &pack(&[&seqs[0]], 512)).unwrap().loss;
    assert!((l256 - l512).abs() < 2e-4, "{l256} vs {l512}");
}

#[test]
fn checkpoint_resume_is_bit_identical() {
    // train 4 steps, checkpoint, train 4 more; vs restore-from-checkpoint
    // and train the same 4 — parameters must match exactly (same rng seed
    // positioning is the caller's job; we restart the trainer to prove the
    // state file carries everything the optimizer needs).
    let dir = require_artifacts!();
    let lens: Vec<u32> = (0..24).map(|i| 30 + (i * 17) % 200).collect();
    let corpus = corpus_seqs(&lens);
    let opts = TrainerOptions {
        workers: 2,
        bucket_capacity: 512,
        policy: Policy::Skrull,
        batch_size: 6,
        ..Default::default()
    };

    let mut t1 = Trainer::new(&dir, opts.clone()).unwrap();
    t1.train(&corpus, 4).unwrap();
    let ck = t1.checkpoint();
    let path = std::env::temp_dir().join(format!("skrull_e2e_ck_{}.bin", std::process::id()));
    ck.save(&path).unwrap();

    // continue the original
    t1.train(&corpus, 4).unwrap();

    // resume a fresh trainer from the file; replay the same 4 steps.
    // NOTE: Trainer::new reseeds its batch rng, so drive the replica with
    // a trainer whose rng is at the same point — we reconstruct by
    // re-running the first 4 steps' sampling via a scratch trainer.
    let mut t2 = Trainer::new(&dir, opts.clone()).unwrap();
    t2.train(&corpus, 4).unwrap(); // advances rng identically to t1's first leg
    let loaded = skrull::coordinator::TrainState::load(&path, t2.params.data.len()).unwrap();
    t2.restore(loaded).unwrap();
    t2.train(&corpus, 4).unwrap();

    assert_eq!(t1.params.data, t2.params.data, "resume diverged");
    std::fs::remove_file(path).ok();
}

#[test]
fn three_step_training_decreases_loss_for_both_policies() {
    let dir = require_artifacts!();
    let lens: Vec<u32> = (0..48).map(|i| 40 + (i * 13) % 400).collect();
    let corpus = corpus_seqs(&lens);
    let mut finals = Vec::new();
    for policy in [Policy::Baseline, Policy::Skrull] {
        let opts = TrainerOptions {
            workers: 2,
            bucket_capacity: 512,
            policy,
            lr: 5e-3,
            seed: 3,
            batch_size: 8,
            ..Default::default()
        };
        let mut trainer = Trainer::new(&dir, opts).unwrap();
        let report = trainer.train(&corpus, 6).unwrap();
        let first = report.metrics.first_loss().unwrap();
        let last = report.metrics.final_loss(2).unwrap();
        assert!(last < first, "{policy:?}: {first} -> {last}");
        finals.push(last);
    }
    // same seed, same data: both policies optimize the same objective;
    // curves differ only through batch composition, not direction.
    assert!(finals.iter().all(|l| l.is_finite()));
}
