//! Build-once/price-many regression tests: the split run engine
//! (`build_run` + `price_run`) must reproduce the pre-refactor
//! `simulate_run` accounting byte-identically for every policy and
//! topology, repricing must equal re-running, and the parallel e2e sweep
//! must emit byte-identical output regardless of the `--jobs` count.

use skrull::bench::e2e::{self, E2eOptions};
use skrull::cluster::run::{build_run, price_run, simulate_run, RunConfig, RunReport};
use skrull::cluster::simulate_iteration;
use skrull::cluster::sim::simulate_iteration_on;
use skrull::config::{CostSource, ExperimentConfig, Policy};
use skrull::data::loader::ScheduledLoader;
use skrull::data::{Dataset, LengthDistribution};
use skrull::memplan::{self, MemoryConfig};
use skrull::model::ModelSpec;
use skrull::perfmodel::CostModel;

const POLICIES: [Policy; 5] = [
    Policy::Baseline,
    Policy::SortedBatching,
    Policy::DacpOnly,
    Policy::Skrull,
    Policy::SkrullRefined,
];

fn workload(policy: Policy, dp: usize, cp: usize) -> (Dataset, ExperimentConfig, CostModel) {
    let mut cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "chatqa2");
    cfg.policy = policy;
    cfg.cluster.dp = dp;
    cfg.cluster.cp = cp;
    cfg.cluster.batch_size = 16;
    let ds = Dataset::synthesize(&LengthDistribution::chatqa2(), 2_000, 11)
        .truncated(cfg.bucket_size * cp as u32);
    let cost = CostModel::paper_default(&cfg.model);
    (ds, cfg, cost)
}

/// The pre-refactor engine, transcribed: drive a fresh loader
/// synchronously and accumulate per-iteration pricing inline — the oracle
/// `price_run(build_run(..))` is checked against.
struct LegacyRun {
    exec_seconds: Vec<f64>,
    grad_sync: Vec<f64>,
    utilization: Vec<f64>,
    dp_imbalance: Vec<f64>,
    micro_batches: Vec<usize>,
    data_tokens: u64,
    padded_tokens: u64,
    bucket_tokens: u64,
    rank_busy: Vec<f64>,
    rank_peak: Vec<f64>,
    oom_count: usize,
}

fn legacy_run(
    ds: &Dataset,
    cfg: &ExperimentConfig,
    cost: &CostModel,
    iterations: usize,
) -> LegacyRun {
    let cfg = cfg.resolve_capacity().unwrap();
    let (dp, cp, bucket) = (cfg.cluster.dp, cfg.cluster.cp, cfg.bucket_size);
    let mem = cfg.mem_plan();
    let topo = cfg.cluster.topology().unwrap();
    let mut out = LegacyRun {
        exec_seconds: Vec::new(),
        grad_sync: Vec::new(),
        utilization: Vec::new(),
        dp_imbalance: Vec::new(),
        micro_batches: Vec::new(),
        data_tokens: 0,
        padded_tokens: 0,
        bucket_tokens: 0,
        rank_busy: vec![0.0; dp * cp],
        rank_peak: vec![0.0; dp * cp],
        oom_count: 0,
    };
    let mut loader = ScheduledLoader::new(ds, &cfg);
    loader
        .run_synchronous(iterations, |i, batch, sched, _| {
            let sim = if topo.dp == sched.ranks.len() {
                simulate_iteration_on(sched, cost, &topo)
            } else {
                simulate_iteration(sched, cost, cp)
            };
            let imem = memplan::iteration_memory(sched, &mem, bucket, cp, i);
            let mut n_mb = 0;
            for rank in &sched.ranks {
                for mb in &rank.micro_batches {
                    n_mb += 1;
                    for used in mb.rank_used_tokens(cp) {
                        let cap = (bucket as u64).max(used);
                        out.padded_tokens += cap - used;
                        out.bucket_tokens += cap;
                    }
                }
            }
            for (d, sims) in sim.micro_batches.iter().enumerate() {
                for mbs in sims {
                    for (j, &busy) in mbs.busy.iter().enumerate() {
                        out.rank_busy[d * cp + j] += busy;
                    }
                }
            }
            for (g, &p) in imem.rank_peak_bytes.iter().enumerate() {
                if p > out.rank_peak[g] {
                    out.rank_peak[g] = p;
                }
            }
            out.oom_count += imem.events.len();
            out.data_tokens += batch.iter().map(|s| s.len as u64).sum::<u64>();
            out.exec_seconds.push(sim.total_time);
            out.grad_sync.push(sim.grad_sync);
            out.utilization.push(sim.compute_utilization);
            out.dp_imbalance.push(sim.dp_imbalance);
            out.micro_batches.push(n_mb);
        })
        .unwrap();
    out
}

fn assert_matches_legacy(r: &RunReport, legacy: &LegacyRun, tag: &str) {
    assert_eq!(r.iterations.len(), legacy.exec_seconds.len(), "{tag}");
    for (i, rec) in r.iterations.iter().enumerate() {
        assert_eq!(rec.exec_seconds, legacy.exec_seconds[i], "{tag} iter {i}");
        assert_eq!(rec.grad_sync_seconds, legacy.grad_sync[i], "{tag} iter {i}");
        assert_eq!(rec.utilization, legacy.utilization[i], "{tag} iter {i}");
        assert_eq!(rec.dp_imbalance, legacy.dp_imbalance[i], "{tag} iter {i}");
        assert_eq!(rec.micro_batches, legacy.micro_batches[i], "{tag} iter {i}");
    }
    assert_eq!(r.data_tokens, legacy.data_tokens, "{tag}");
    assert_eq!(r.padded_tokens, legacy.padded_tokens, "{tag}");
    assert_eq!(r.bucket_tokens, legacy.bucket_tokens, "{tag}");
    assert_eq!(r.rank_busy, legacy.rank_busy, "{tag}");
    assert_eq!(r.rank_peak_bytes, legacy.rank_peak, "{tag}");
    assert_eq!(r.oom_count(), legacy.oom_count, "{tag}");
}

#[test]
fn price_of_built_run_reproduces_the_legacy_engine_for_every_policy_and_topology() {
    for &(dp, cp) in &[(4usize, 8usize), (2, 16)] {
        for policy in POLICIES {
            let (ds, cfg, cost) = workload(policy, dp, cp);
            let tag = format!("{} <DP={dp},CP={cp}>", policy.name());
            let legacy = legacy_run(&ds, &cfg, &cost, 3);
            // the composed one-shot path ...
            let via_simulate =
                simulate_run(&ds, &cfg, &cost, &RunConfig::new(3, false)).unwrap();
            assert_matches_legacy(&via_simulate, &legacy, &tag);
            // ... and the explicit build → price split, pipelined too
            // (schedules are byte-identical across loader modes)
            for pipelined in [false, true] {
                let built = build_run(&ds, &cfg, &RunConfig::new(3, pipelined)).unwrap();
                assert_eq!(built.sched_invocations, 3, "{tag}");
                let priced = price_run(&built, &cost, &built.topology);
                assert_matches_legacy(&priced, &legacy, &tag);
            }
        }
    }
}

#[test]
fn repricing_equals_rerunning_for_estimator_error() {
    // the calibrated sweep's estimator_error used to come from a second
    // full scheduler run under the reference model; repricing the built
    // schedules must give exactly the same per-iteration numbers
    let (ds, cfg, cost_a) = workload(Policy::SkrullRefined, 4, 8);
    let cost_b = cost_a.with_cross_node_cp(); // any second model will do
    let run = RunConfig::new(4, false);

    // old path: two independent engine runs (the loader schedules twice)
    let rerun_a = simulate_run(&ds, &cfg, &cost_a, &run).unwrap();
    let rerun_b = simulate_run(&ds, &cfg, &cost_b, &run).unwrap();

    // new path: one build, two pricings
    let built = build_run(&ds, &cfg, &run).unwrap();
    let price_a = price_run(&built, &cost_a, &built.topology);
    let price_b = price_run(&built, &cost_b, &built.topology);

    let err = |x: &RunReport, y: &RunReport| -> f64 {
        x.iterations
            .iter()
            .zip(&y.iterations)
            .map(|(a, b)| (a.exec_seconds - b.exec_seconds).abs() / b.exec_seconds)
            .sum::<f64>()
            / x.iterations.len() as f64
    };
    for (reprice, rerun) in [(&price_a, &rerun_a), (&price_b, &rerun_b)] {
        for (p, r) in reprice.iterations.iter().zip(&rerun.iterations) {
            assert_eq!(p.exec_seconds, r.exec_seconds);
            assert_eq!(p.data_tokens, r.data_tokens);
        }
    }
    assert_eq!(err(&price_b, &price_a), err(&rerun_b, &rerun_a));
    // and the scheduling-work ledger shows why the split wins: the old
    // path scheduled 2 × 4 times, the new one exactly 4
    assert_eq!(built.sched_invocations, 4);
    assert_eq!(rerun_a.sched_invocations + rerun_b.sched_invocations, 8);
}

#[test]
fn sweep_output_is_byte_identical_across_job_counts() {
    // --jobs is a wall-clock lever only: with the one nondeterministic
    // input (measured scheduling time) pinned, serial and parallel sweeps
    // emit the same BENCH_e2e.json byte for byte
    let mut opts = E2eOptions {
        model: ModelSpec::qwen2_5_0_5b(),
        datasets: vec!["chatqa2".into(), "wikipedia".into()],
        topologies: vec![(4, 8), (2, 16)],
        iterations: 2,
        batch_size: Some(16),
        dataset_samples: 1_500,
        seeds: vec![11, 12],
        pipelined: true,
        epoch: false,
        memory: MemoryConfig::default(),
        cost: CostSource::Analytic,
        jobs: 1,
        deterministic_timing: true,
    };
    let serial = e2e::render_json(&e2e::run_sweep(&opts).unwrap());
    e2e::validate_json(&serial).unwrap();
    opts.jobs = 4;
    let parallel = e2e::render_json(&e2e::run_sweep(&opts).unwrap());
    assert_eq!(serial, parallel, "--jobs 4 diverged from --jobs 1");
    // schema v4 markers are present in the pinned output too
    assert!(serial.contains("\"schema_version\": 4"));
    assert!(serial.contains("\"sweep_seconds\": 0e0"));
    assert!(serial.contains("\"sched_invocations\": 2"));
}

#[test]
fn analytic_sweep_cells_schedule_exactly_once_per_iteration() {
    let opts = E2eOptions {
        model: ModelSpec::qwen2_5_0_5b(),
        datasets: vec!["chatqa2".into()],
        topologies: vec![(4, 8)],
        iterations: 3,
        batch_size: Some(16),
        dataset_samples: 1_500,
        seeds: vec![7],
        pipelined: true,
        epoch: false,
        memory: MemoryConfig::default(),
        cost: CostSource::Analytic,
        jobs: 2,
        deterministic_timing: false,
    };
    let sweep = e2e::run_sweep(&opts).unwrap();
    for c in &sweep.cells {
        assert_eq!(
            c.report.sched_invocations, 3,
            "{}: expected one GDS/DACP pass per iteration",
            c.policy.name()
        );
    }
    assert!(sweep.sweep_seconds > 0.0);
}
