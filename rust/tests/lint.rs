//! `skrull lint` end-to-end: every rule fires and suppresses against the
//! fixture corpus under `rust/tests/data/lint/`, the corpus reproduces
//! the golden `lint_golden.json` report exactly, and — the CI gate in
//! test form — the real source tree lints clean.

use std::path::{Path, PathBuf};

use skrull::analysis::{
    lint_source, lint_tree, parse_report, render_json, validate_json, HOT_FUNCTIONS, LintOutcome,
};

fn manifest_path(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join(rel)
}

#[test]
fn fixture_corpus_matches_golden_report() {
    let outcome = lint_tree(&manifest_path("rust/tests/data/lint")).expect("fixture tree lints");
    let live = parse_report(&render_json(&outcome)).expect("own report round-trips");
    let golden_text =
        std::fs::read_to_string(manifest_path("rust/tests/data/lint_golden.json"))
            .expect("golden report present");
    let golden = parse_report(&golden_text).expect("golden report parses");
    assert_eq!(live.files_scanned, golden.files_scanned);
    assert_eq!(live.findings, golden.findings);
}

#[test]
fn each_rule_fires_and_a_justified_suppression_silences_it() {
    // (rule, file the source pretends to live at, offending line)
    let cases: &[(&str, &str, &str)] = &[
        ("nan-unsafe-ord", "scheduler/x.rs", "fn f(a: f64, b: f64) { a.partial_cmp(&b); }"),
        ("truncating-cast", "scheduler/x.rs", "fn f(x: u64) -> u32 { x as u32 }"),
        ("hot-path-alloc", "scheduler/gds.rs", "fn schedule_rank_inner() { let v = vec![1]; }"),
        ("nondet-iteration", "data/x.rs", "fn f(m: HashMap<u32, u32>) {}"),
        ("wall-clock-in-pure-code", "cluster/x.rs", "fn f(t: Instant) {}"),
        ("panic-in-lib", "calib/x.rs", "fn f(x: Option<u32>) { x.unwrap(); }"),
    ];
    for (rule, rel, line) in cases {
        let fired = lint_source(rel, line);
        assert!(
            fired.iter().any(|f| f.rule == *rule && !f.suppressed),
            "{rule} should fire on {line:?}: {fired:?}"
        );

        let src = format!("// skrull-lint: allow({rule}) -- test justification\n{line}\n");
        let silenced = lint_source(rel, &src);
        assert!(
            silenced.iter().filter(|f| f.rule == *rule).all(|f| f.suppressed),
            "{rule} should be suppressed in {src:?}: {silenced:?}"
        );
        assert!(
            silenced.iter().all(|f| f.rule != "unused-suppression"),
            "the suppression was used: {silenced:?}"
        );
        assert!(
            silenced
                .iter()
                .filter(|f| f.suppressed)
                .all(|f| f.reason.as_deref() == Some("test justification")),
            "suppressed findings carry the written reason: {silenced:?}"
        );
    }
}

#[test]
fn the_source_tree_lints_clean() {
    let outcome = lint_tree(&manifest_path("rust/src")).expect("source tree lints");
    let offenders: Vec<_> = outcome.findings.iter().filter(|f| !f.suppressed).collect();
    assert!(
        offenders.is_empty(),
        "unsuppressed lint findings in rust/src (fix or add a justified \
         `// skrull-lint: allow(<rule>) -- <reason>`):\n{offenders:#?}"
    );
    for f in outcome.findings.iter().filter(|f| f.suppressed) {
        assert!(
            f.reason.as_deref().is_some_and(|r| !r.is_empty()),
            "suppressed finding without a written justification: {f:?}"
        );
    }
}

#[test]
fn declared_hot_functions_still_exist() {
    for (file, func) in HOT_FUNCTIONS {
        let src = std::fs::read_to_string(manifest_path("rust/src").join(file))
            .expect("hot-path file exists");
        assert!(
            src.contains(&format!("fn {func}")),
            "{file} no longer defines fn {func}; update analysis::rules::HOT_FUNCTIONS"
        );
    }
}

#[test]
fn validate_json_gates_on_unsuppressed_findings() {
    let clean = LintOutcome { findings: lint_source("util/x.rs", "fn f() {}"), files_scanned: 1 };
    validate_json(&render_json(&clean)).expect("clean report validates");

    let dirty = LintOutcome {
        findings: lint_source("scheduler/x.rs", "fn f(x: Option<u32>) { x.unwrap(); }"),
        files_scanned: 1,
    };
    let err = validate_json(&render_json(&dirty)).expect_err("dirty report rejected");
    assert!(err.to_string().contains("unsuppressed"), "{err}");

    validate_json("{not json").expect_err("garbage rejected");
}
