//! Steady-state allocation audit for the scheduling hot path.
//!
//! A counting `#[global_allocator]` measures one warm
//! `ScheduledLoader::schedule_batch` call on the serial in-process path
//! (shards = 1, `sched_parallel = false`).  After warm-up, the arenas in
//! `SchedCtx`/`RankCtx`/`DacpScratch`/`BinpackScratch` must absorb all
//! scheduler-internal work: the only allocations left are the returned
//! schedule itself — 1 (ranks Vec) + dp (micro-batch Vecs) + 2 per
//! micro-batch (seqs + plan assignment) — plus a small slack.
//!
//! This file is its own test binary with EXACTLY ONE test: the global
//! allocator is process-wide, so a sibling test running on another thread
//! would pollute the counter.  Keep it that way.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use skrull::config::ExperimentConfig;
use skrull::data::loader::ScheduledLoader;
use skrull::data::{Dataset, LengthDistribution};
use skrull::model::ModelSpec;
use skrull::rng::Rng;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static COUNTING: AtomicBool = AtomicBool::new(false);

// SAFETY: delegates every operation to `System`, which upholds the
// GlobalAlloc contract; the counter is a Relaxed atomic side effect that
// never touches the returned memory.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // a realloc is a fresh acquisition from the arena's point of view
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn warm_schedule_batch_allocates_only_the_returned_schedule() {
    let cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
    let ds = Dataset::synthesize(&LengthDistribution::wikipedia(), 20_000, 7)
        .truncated(cfg.bucket_size * cfg.cluster.cp as u32);
    let mut loader = ScheduledLoader::new(&ds, &cfg);
    loader.sched_parallel = false; // serial in-process path (shards = 1)

    let mut rng = Rng::seed_from_u64(0xA110C);
    let batch = ds.sample_batch(&mut rng, cfg.cluster.batch_size);

    // warm the arenas: after a few calls every scratch buffer has reached
    // its high-water mark for this batch
    for _ in 0..3 {
        let _ = loader.schedule_batch(&batch).unwrap();
    }

    ALLOCS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    let sched = loader.schedule_batch(&batch).unwrap();
    COUNTING.store(false, Ordering::SeqCst);
    let allocs = ALLOCS.load(Ordering::SeqCst);

    let dp = cfg.cluster.dp as u64;
    let n_mbs: u64 = sched
        .ranks
        .iter()
        .map(|r| r.micro_batches.len() as u64)
        .sum();
    assert!(n_mbs > 0, "empty schedule proves nothing");
    // 1 ranks Vec + dp micro-batch Vecs + (seqs + plan) per micro-batch,
    // with a small slack for harness noise; anything materially above
    // this means a scratch buffer stopped being reused
    let budget = 1 + dp + 2 * n_mbs + 8;
    assert!(
        allocs <= budget,
        "warm schedule_batch made {allocs} allocations, budget {budget} \
         (dp={dp}, micro-batches={n_mbs}) — the steady state is supposed to \
         allocate only the returned schedule"
    );
}
