//! Crash-recovery integration tests for the serve daemon.
//!
//! The heavy hammer here is the kill-point sweep: kill the daemon at
//! EVERY journal append index (cycling through all three tear modes),
//! recover in a fresh process, and require the final cell payload to be
//! byte-identical to the batch simulator's.  There is no "mostly
//! recovers" — a single diverging byte at any crash site fails the
//! sweep, which is the keystone invariant stated in `serve/mod.rs`:
//! the daemon must never out-decide the simulator.

use skrull::fleet::{ArrivalPattern, FleetPolicy};
use skrull::serve::daemon::{self, DaemonOptions, Outcome};
use skrull::serve::{FaultPlan, Journal, TearMode};

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("skrull_serve_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn restart_clean(lines: &[String], state_dir: &std::path::Path, snapshot_every: usize) -> String {
    let opts = DaemonOptions {
        state_dir: state_dir.to_path_buf(),
        snapshot_every,
        fault: FaultPlan::none(),
    };
    match daemon::run(lines, &opts).unwrap() {
        Outcome::Completed { cell_json } => cell_json,
        Outcome::Killed => panic!("a fault-free restart cannot be killed"),
    }
}

/// Kill at every append index until a kill index past the final append
/// lets the run complete uninterrupted; every crash site must recover to
/// the simulator's exact bytes.
#[test]
fn kill_point_sweep_recovers_byte_identical_at_every_append() {
    let base = tmp_dir("sweep");
    let lines =
        daemon::record_log(ArrivalPattern::Bursty, FleetPolicy::Priority, "paper", 6, 17)
            .unwrap();
    let reference = daemon::replay_via_sim(&lines).unwrap();

    let mut kill: u64 = 0;
    loop {
        let mode = TearMode::ALL[(kill % 3) as usize];
        let dir = base.join(format!("k{kill}"));
        let opts = DaemonOptions {
            state_dir: dir.clone(),
            snapshot_every: 2,
            fault: FaultPlan { seed: kill, kill_at: Some((kill, mode)), transient_every: 0 },
        };
        match daemon::run(&lines, &opts).unwrap() {
            // the kill index is past the last append: the log has been
            // fully processed and the sweep has covered every crash site
            Outcome::Completed { cell_json } => {
                assert_eq!(cell_json, reference, "uninterrupted run diverged");
                break;
            }
            Outcome::Killed => {
                let recovered = restart_clean(&lines, &dir, 2);
                assert_eq!(
                    recovered, reference,
                    "recovery diverged after a {mode:?} kill at append {kill}"
                );
            }
        }
        kill += 1;
        assert!(kill < 10_000, "kill sweep failed to terminate");
    }
    assert!(kill > 10, "sweep ended after only {kill} appends — the log is too trivial");
    std::fs::remove_dir_all(base).ok();
}

/// A torn tail is truncated back to the last fully-valid record, and the
/// daemon then completes byte-identically from what survived.
#[test]
fn torn_tail_truncates_to_the_last_valid_record() {
    let base = tmp_dir("torn");
    let lines =
        daemon::record_log(ArrivalPattern::Steady, FleetPolicy::Fifo, "paper", 4, 5).unwrap();
    let reference = daemon::replay_via_sim(&lines).unwrap();

    let opts = DaemonOptions {
        state_dir: base.clone(),
        snapshot_every: 0,
        fault: FaultPlan::kill_at(3, TearMode::Torn),
    };
    match daemon::run(&lines, &opts).unwrap() {
        Outcome::Killed => {}
        other => panic!("expected the plan to kill the daemon, got {other:?}"),
    }
    let journal_path = base.join("fleet.journal");
    let len_torn = std::fs::metadata(&journal_path).unwrap().len();
    {
        let (records, _j) = Journal::recover(&journal_path, FaultPlan::none()).unwrap();
        assert_eq!(records.len(), 3, "appends 0..3 landed whole; the torn 4th must drop");
    }
    let len_clean = std::fs::metadata(&journal_path).unwrap().len();
    assert!(
        len_clean < len_torn,
        "recovery must physically truncate the torn tail ({len_torn} -> {len_clean})"
    );

    let recovered = restart_clean(&lines, &base, 0);
    assert_eq!(recovered, reference);
    std::fs::remove_dir_all(base).ok();
}

/// Crash long after a snapshot: recovery loads the snapshot, replays only
/// the journal suffix, and still lands on the simulator's exact bytes.
#[test]
fn snapshot_plus_suffix_replay_matches_the_uninterrupted_run() {
    let base = tmp_dir("snap");
    let lines =
        daemon::record_log(ArrivalPattern::HeavyTailed, FleetPolicy::BestFitPrice, "hetero", 6, 29)
            .unwrap();
    let reference = daemon::replay_via_sim(&lines).unwrap();

    let opts = DaemonOptions {
        state_dir: base.clone(),
        snapshot_every: 2,
        fault: FaultPlan::kill_at(20, TearMode::BitFlip),
    };
    match daemon::run(&lines, &opts).unwrap() {
        Outcome::Killed => {}
        other => panic!("expected the plan to kill the daemon, got {other:?}"),
    }
    assert!(
        base.join("fleet.snap").exists(),
        "by append 20 at snapshot_every=2 a snapshot must have been taken"
    );
    let recovered = restart_clean(&lines, &base, 2);
    assert_eq!(recovered, reference);
    std::fs::remove_dir_all(base).ok();
}

/// Transient write faults are retried behind virtual backoff and leave no
/// trace in the output: a transient-heavy run matches the simulator.
#[test]
fn transient_faults_are_invisible_in_the_output() {
    let base = tmp_dir("transient");
    let lines =
        daemon::record_log(ArrivalPattern::Bursty, FleetPolicy::ShortestPricedFirst, "paper", 5, 13)
            .unwrap();
    let reference = daemon::replay_via_sim(&lines).unwrap();
    let got =
        daemon::run_to_completion(&lines, &base, FaultPlan::transient_heavy(9), 0).unwrap();
    assert_eq!(got, reference);
    std::fs::remove_dir_all(base).ok();
}
