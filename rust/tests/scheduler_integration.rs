//! Cross-module integration tests: loader → scheduler → simulator, across
//! policies, datasets and parallel settings — the invariants of the joint
//! formulation (Eq. 6/7/9/10) plus the paper's qualitative claims.

use skrull::cluster::simulate_iteration;
use skrull::config::{ExperimentConfig, Policy};
use skrull::data::loader::ScheduledLoader;
use skrull::data::{Dataset, LengthDistribution};
use skrull::model::ModelSpec;
use skrull::perfmodel::{CostModel, FlopsModel};
use skrull::rng::Rng;
use skrull::scheduler::{gds, solver};

fn all_datasets() -> Vec<Dataset> {
    ["wikipedia", "lmsys", "chatqa2"]
        .iter()
        .map(|n| Dataset::synthesize(&LengthDistribution::by_name(n).unwrap(), 20_000, 9))
        .collect()
}

#[test]
fn full_pipeline_invariants_all_policies_all_datasets() {
    for ds in all_datasets() {
        for model in [ModelSpec::qwen2_5_0_5b(), ModelSpec::qwen2_5_7b()] {
            let cfg0 = ExperimentConfig::paper_default(model, &ds.name);
            let ds = ds.truncated(cfg0.bucket_size * cfg0.cluster.cp as u32);
            for policy in [Policy::Baseline, Policy::DacpOnly, Policy::Skrull, Policy::SkrullRefined, Policy::SortedBatching]
            {
                let mut cfg = cfg0.clone();
                cfg.policy = policy;
                let cp = cfg.cluster.cp;
                let bucket = cfg.bucket_size;
                let mut loader = ScheduledLoader::new(&ds, &cfg);
                for _ in 0..3 {
                    let (batch, sched) = loader.next_iteration().expect("schedule");
                    // Eq. 9: every sequence exactly once
                    let mut want: Vec<u64> = batch.iter().map(|s| s.id).collect();
                    want.sort_unstable();
                    assert_eq!(sched.assigned_ids(), want, "{policy:?} on {}", ds.name);
                    // Eq. 7/10: memory constraint on every micro-batch
                    for r in &sched.ranks {
                        for mb in &r.micro_batches {
                            mb.plan
                                .validate(&mb.lens(), bucket, cp)
                                .unwrap_or_else(|e| panic!("{policy:?} on {}: {e}", ds.name));
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn skrull_never_loses_to_baseline_in_simulation() {
    // The headline claim, as an invariant over seeds and datasets: mean
    // simulated iteration time under Skrull ≤ baseline.
    for ds in all_datasets() {
        let cfg0 = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), &ds.name);
        let ds = ds.truncated(cfg0.bucket_size * cfg0.cluster.cp as u32);
        let cost = CostModel::paper_default(&cfg0.model);
        let mut means = Vec::new();
        for policy in [Policy::Baseline, Policy::Skrull] {
            let mut cfg = cfg0.clone();
            cfg.policy = policy;
            let mut loader = ScheduledLoader::new(&ds, &cfg);
            let mut total = 0.0;
            for _ in 0..8 {
                let (_, sched) = loader.next_iteration().unwrap();
                total += simulate_iteration(&sched, &cost, cfg0.cluster.cp).total_time;
            }
            means.push(total / 8.0);
        }
        assert!(
            means[1] < means[0],
            "{}: skrull {} >= baseline {}",
            ds.name,
            means[1],
            means[0]
        );
    }
}

#[test]
fn utilization_improves_under_skrull() {
    let ds = Dataset::synthesize(&LengthDistribution::chatqa2(), 20_000, 3);
    let cfg0 = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "chatqa2");
    let ds = ds.truncated(cfg0.bucket_size * cfg0.cluster.cp as u32);
    let cost = CostModel::paper_default(&cfg0.model);
    let mut utils = Vec::new();
    for policy in [Policy::Baseline, Policy::Skrull] {
        let mut cfg = cfg0.clone();
        cfg.policy = policy;
        let mut loader = ScheduledLoader::new(&ds, &cfg);
        let mut u = 0.0;
        for _ in 0..5 {
            let (_, sched) = loader.next_iteration().unwrap();
            u += simulate_iteration(&sched, &cost, cfg0.cluster.cp).compute_utilization;
        }
        utils.push(u / 5.0);
    }
    assert!(utils[1] > utils[0], "skrull {} <= baseline {}", utils[1], utils[0]);
}

#[test]
fn gds_beats_or_matches_exact_solver_feasibility() {
    // wherever the exact solver finds any feasible DACP plan for a GDS
    // micro-batch, the heuristic must have found one too (it produced the
    // micro-batch), and the heuristic plan's cost must be ≥ optimal.
    let spec = ModelSpec::qwen2_5_0_5b();
    let cost = CostModel::paper_default(&spec);
    let flops = FlopsModel::new(&spec);
    let ds = Dataset::synthesize(&LengthDistribution::chatqa2(), 20_000, 5).truncated(26 * 1024 * 4);
    let mut rng = Rng::seed_from_u64(17);
    let gcfg = gds::GdsConfig::new(26 * 1024, 4, 2);
    for _ in 0..5 {
        let batch = ds.sample_batch(&mut rng, 12);
        let sched = gds::schedule(&batch, &gcfg, &flops).unwrap();
        for r in &sched.ranks {
            for mb in &r.micro_batches {
                let lens = mb.lens();
                if lens.len() > 9 {
                    continue; // keep the solver tractable
                }
                if let Some(sol) = solver::solve(&lens, 26 * 1024, 4, &cost, 3_000_000) {
                    let h = cost.tdacp(&lens, &mb.plan, 4);
                    assert!(h >= sol.cost - 1e-12, "heuristic beat the optimum?");
                }
            }
        }
    }
}

#[test]
fn fast_path_oracle_matches_reference_on_200_workloads() {
    // Acceptance gate for the scheduling fast path: across ≥200 random
    // workloads drawn from the paper's dataset distributions, the
    // allocation-lean/galloping/parallel `gds::schedule` produces plans
    // byte-identical to the retained reference transcription of
    // Algorithm 2 (which trivially implies "no worse under tdacp").
    let flops = FlopsModel::new(&ModelSpec::qwen2_5_0_5b());
    let mut rng = Rng::seed_from_u64(0x04AC1E);
    let mut ctx = gds::SchedCtx::default();
    let mut compared = 0usize;
    for ds in all_datasets() {
        let ds = ds.truncated(26 * 1024 * 8);
        for trial in 0..70 {
            let k = [8usize, 24, 64, 160][trial % 4];
            let batch = ds.sample_batch(&mut rng, k);
            let mut cfg = gds::GdsConfig::new(26 * 1024, 8, 4);
            if trial % 5 == 0 {
                cfg.bucket_size = 4 * 1024; // memory-pressure regime
            }
            let reference = gds::schedule_reference(&batch, &cfg, &flops);
            let fast = gds::schedule_with_ctx(&batch, &cfg, &flops, &mut ctx);
            match (reference, fast) {
                (Ok(a), Ok(b)) => assert_eq!(a, b, "{} trial {trial}", ds.name),
                (Err(a), Err(b)) => assert_eq!(a, b, "{} trial {trial}", ds.name),
                (a, b) => panic!(
                    "{} trial {trial}: feasibility mismatch ref={:?} fast={:?}",
                    ds.name,
                    a.is_ok(),
                    b.is_ok()
                ),
            }
            compared += 1;
        }
    }
    assert!(compared >= 200, "only {compared} workloads compared");
}

#[test]
fn seeded_determinism_end_to_end() {
    let ds = Dataset::synthesize(&LengthDistribution::wikipedia(), 10_000, 1);
    let cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
    let run = || {
        let mut loader = ScheduledLoader::new(&ds, &cfg);
        let cost = CostModel::paper_default(&cfg.model);
        let mut times = Vec::new();
        for _ in 0..4 {
            let (_, sched) = loader.next_iteration().unwrap();
            times.push(simulate_iteration(&sched, &cost, cfg.cluster.cp).total_time);
        }
        times
    };
    assert_eq!(run(), run());
}

#[test]
fn bigger_bucket_never_hurts_with_refinement() {
    // More memory (larger C) should not slow an iteration down.  This is
    // NOT true for the paper's Algorithm 1 alone: with a big bucket, the
    // avoid-sharding principle keeps huge sequences local and one rank's
    // attention dominates the makespan (see the ablations bench).  With
    // the cost-aware refinement extension the monotonicity holds.
    let ds = Dataset::synthesize(&LengthDistribution::chatqa2(), 20_000, 2).truncated(13 * 1024 * 8);
    let cost = CostModel::paper_default(&ModelSpec::qwen2_5_0_5b());
    let mut last = f64::INFINITY;
    for c in [13 * 1024u32, 26 * 1024, 52 * 1024] {
        let mut cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "chatqa2");
        cfg.bucket_size = c;
        cfg.policy = Policy::SkrullRefined;
        let mut loader = ScheduledLoader::new(&ds, &cfg);
        let mut total = 0.0;
        for _ in 0..5 {
            let (_, sched) = loader.next_iteration().unwrap();
            total += simulate_iteration(&sched, &cost, cfg.cluster.cp).total_time;
        }
        let mean = total / 5.0;
        assert!(mean <= last * 1.05, "C={c}: {mean} vs smaller bucket {last}");
        last = mean;
    }
}

#[test]
fn refined_policy_never_loses_to_plain_skrull() {
    for ds in all_datasets() {
        let cfg0 = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), &ds.name);
        let ds = ds.truncated(cfg0.bucket_size * cfg0.cluster.cp as u32);
        let cost = CostModel::paper_default(&cfg0.model);
        let mut means = Vec::new();
        for policy in [Policy::Skrull, Policy::SkrullRefined] {
            let mut cfg = cfg0.clone();
            cfg.policy = policy;
            let mut loader = ScheduledLoader::new(&ds, &cfg);
            let mut total = 0.0;
            for _ in 0..6 {
                let (_, sched) = loader.next_iteration().unwrap();
                total += simulate_iteration(&sched, &cost, cfg0.cluster.cp).total_time;
            }
            means.push(total / 6.0);
        }
        assert!(
            means[1] <= means[0] * 1.01,
            "{}: refined {} > plain {}",
            ds.name,
            means[1],
            means[0]
        );
    }
}

#[test]
fn fixed_capacity_source_reproduces_hand_set_schedules_byte_identically() {
    // Regression for the memplan subsystem: with the default
    // CapacitySource::Fixed, the loader must behave exactly as before the
    // capacity authority existed — same RNG draw order, same batches, and
    // schedules byte-identical to gds::schedule called directly with the
    // hand-set bucket size.
    use skrull::memplan::CapacitySource;

    let ds = Dataset::synthesize(&LengthDistribution::chatqa2(), 20_000, 9);
    let cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "chatqa2");
    assert_eq!(cfg.memory.source, CapacitySource::Fixed);
    let ds = ds.truncated(cfg.bucket_size * cfg.cluster.cp as u32);
    let flops = FlopsModel::new(&cfg.model);
    let mut loader = ScheduledLoader::new(&ds, &cfg);
    assert_eq!(*loader.capacity().as_ref().unwrap(), cfg.bucket_size);

    // replicate the loader's sampling stream independently
    let mut rng = Rng::seed_from_u64(cfg.seed);
    for _ in 0..4 {
        let (batch, sched) = loader.next_iteration().unwrap();
        let expect_batch = ds.sample_batch(&mut rng, cfg.cluster.batch_size);
        assert_eq!(batch, expect_batch, "sampling stream drifted");
        let gcfg = gds::GdsConfig::new(cfg.bucket_size, cfg.cluster.cp, cfg.cluster.dp);
        let expect = gds::schedule(&expect_batch, &gcfg, &flops).unwrap();
        assert_eq!(sched, expect, "schedule drifted from the hand-set bucket path");
    }
}
