//! Flat parameter storage: all model parameters live in one contiguous f32
//! buffer (manifest order), sliced per-tensor when building PJRT literals.
//! Adam runs directly over this buffer (coordinator/optimizer.rs).

use crate::runtime::manifest::Manifest;

#[derive(Debug)]
pub enum ParamsError {
    Io(std::io::Error),
    SizeMismatch { got: usize, want: usize },
}

impl std::fmt::Display for ParamsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParamsError::Io(e) => write!(f, "io: {e}"),
            ParamsError::SizeMismatch { got, want } => {
                write!(f, "params.bin holds {got} f32s, manifest expects {want}")
            }
        }
    }
}

impl std::error::Error for ParamsError {}

impl From<std::io::Error> for ParamsError {
    fn from(e: std::io::Error) -> Self {
        ParamsError::Io(e)
    }
}

/// Flat f32 parameter (or gradient) buffer with per-tensor offsets.
#[derive(Clone, Debug)]
pub struct FlatParams {
    pub data: Vec<f32>,
    /// (offset, numel) per manifest param, in order.
    pub spans: Vec<(usize, usize)>,
}

impl FlatParams {
    pub fn zeros_like(manifest: &Manifest) -> Self {
        let spans = Self::spans_of(manifest);
        let total = manifest.total_params();
        FlatParams { data: vec![0.0; total], spans }
    }

    fn spans_of(manifest: &Manifest) -> Vec<(usize, usize)> {
        let mut spans = Vec::with_capacity(manifest.params.len());
        let mut off = 0;
        for p in &manifest.params {
            spans.push((off, p.numel()));
            off += p.numel();
        }
        spans
    }

    /// Load params.bin (f32 LE, manifest order).
    pub fn load(manifest: &Manifest) -> Result<Self, ParamsError> {
        let bytes = std::fs::read(&manifest.params_bin)?;
        let want = manifest.total_params();
        if bytes.len() != want * 4 {
            return Err(ParamsError::SizeMismatch { got: bytes.len() / 4, want });
        }
        let mut data = vec![0f32; want];
        for (i, ch) in bytes.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
        }
        Ok(FlatParams { data, spans: Self::spans_of(manifest) })
    }

    pub fn tensor(&self, idx: usize) -> &[f32] {
        let (off, n) = self.spans[idx];
        &self.data[off..off + n]
    }

    pub fn num_tensors(&self) -> usize {
        self.spans.len()
    }

    pub fn l2_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tiny_manifest(dir: PathBuf) -> Manifest {
        Manifest::parse(
            "version 1\nmodel vocab=4\nparam a 2x3\nparam b 4\nbucket 128 x.hlo.txt\nparams params.bin\n",
            dir,
        )
        .unwrap()
    }

    #[test]
    fn zeros_like_has_right_layout() {
        let m = tiny_manifest(PathBuf::from("/tmp"));
        let p = FlatParams::zeros_like(&m);
        assert_eq!(p.data.len(), 10);
        assert_eq!(p.spans, vec![(0, 6), (6, 4)]);
        assert_eq!(p.tensor(1).len(), 4);
        assert_eq!(p.num_tensors(), 2);
        assert_eq!(p.l2_norm(), 0.0);
    }

    #[test]
    fn load_round_trips_le_f32() {
        let dir = std::env::temp_dir().join(format!("skrull_params_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let vals: Vec<f32> = (0..10).map(|i| i as f32 * 0.5).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join("params.bin"), bytes).unwrap();
        let m = tiny_manifest(dir.clone());
        let p = FlatParams::load(&m).unwrap();
        assert_eq!(p.data, vals);
        assert_eq!(p.tensor(0), &vals[..6]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn size_mismatch_is_detected() {
        let dir = std::env::temp_dir().join(format!("skrull_params_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("params.bin"), [0u8; 8]).unwrap();
        let m = tiny_manifest(dir.clone());
        assert!(matches!(
            FlatParams::load(&m),
            Err(ParamsError::SizeMismatch { got: 2, want: 10 })
        ));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn loads_real_params_if_present() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(dir).join("manifest.txt").exists() {
            let m = Manifest::load(dir).unwrap();
            let p = FlatParams::load(&m).unwrap();
            assert_eq!(p.data.len(), 3_148_032);
            assert!(p.l2_norm() > 0.0);
            assert!(p.data.iter().all(|x| x.is_finite()));
        }
    }
}
