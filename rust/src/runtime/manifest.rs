//! artifacts/manifest.txt parser — the AOT interchange contract with
//! python/compile/aot.py (see that file for the writer).
//!
//! Format (line-oriented, whitespace-separated):
//!   version 1
//!   model vocab=512 hidden=256 layers=4 ... seed=0
//!   param <name> <d0>x<d1>...
//!   bucket <tokens> <hlo file>
//!   attn <tokens> <hlo file>
//!   params <bin file>

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub enum ManifestError {
    Io(std::io::Error),
    Parse(usize, String),
    Version(String),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io(e) => write!(f, "io: {e}"),
            ManifestError::Parse(line, msg) => write!(f, "manifest line {line}: {msg}"),
            ManifestError::Version(v) => write!(f, "unsupported manifest version {v}"),
        }
    }
}

impl std::error::Error for ManifestError {}

impl From<std::io::Error> for ManifestError {
    fn from(e: std::io::Error) -> Self {
        ManifestError::Io(e)
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    /// model config key=value pairs from the `model` line
    pub model: BTreeMap<String, String>,
    pub params: Vec<ParamSpec>,
    /// bucket token count -> train_step HLO path
    pub buckets: BTreeMap<u32, PathBuf>,
    /// attention microbench artifacts
    pub attn: BTreeMap<u32, PathBuf>,
    pub params_bin: PathBuf,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, ManifestError> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.txt"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Self, ManifestError> {
        let mut model = BTreeMap::new();
        let mut params = Vec::new();
        let mut buckets = BTreeMap::new();
        let mut attn = BTreeMap::new();
        let mut params_bin = None;
        for (i, line) in text.lines().enumerate() {
            let ln = i + 1;
            let mut toks = line.split_whitespace();
            let Some(kind) = toks.next() else { continue };
            let err = |m: &str| ManifestError::Parse(ln, m.to_string());
            match kind {
                "version" => {
                    let v = toks.next().ok_or_else(|| err("missing version"))?;
                    if v != "1" {
                        return Err(ManifestError::Version(v.to_string()));
                    }
                }
                "model" => {
                    for kv in toks {
                        let (k, v) = kv
                            .split_once('=')
                            .ok_or_else(|| err(&format!("bad model kv {kv:?}")))?;
                        model.insert(k.to_string(), v.to_string());
                    }
                }
                "param" => {
                    let name = toks.next().ok_or_else(|| err("missing param name"))?;
                    let dims = toks.next().ok_or_else(|| err("missing param shape"))?;
                    let shape = dims
                        .split('x')
                        .map(|d| d.parse::<usize>())
                        .collect::<Result<Vec<_>, _>>()
                        .map_err(|e| err(&format!("bad shape {dims:?}: {e}")))?;
                    params.push(ParamSpec { name: name.to_string(), shape });
                }
                "bucket" | "attn" => {
                    let t = toks
                        .next()
                        .and_then(|t| t.parse::<u32>().ok())
                        .ok_or_else(|| err("missing/invalid token count"))?;
                    let file = toks.next().ok_or_else(|| err("missing file"))?;
                    let map = if kind == "bucket" { &mut buckets } else { &mut attn };
                    map.insert(t, dir.join(file));
                }
                "params" => {
                    let file = toks.next().ok_or_else(|| err("missing params file"))?;
                    params_bin = Some(dir.join(file));
                }
                other => return Err(err(&format!("unknown record {other:?}"))),
            }
        }
        Ok(Manifest {
            dir,
            model,
            params,
            buckets,
            attn,
            params_bin: params_bin.ok_or(ManifestError::Parse(0, "no params line".into()))?,
        })
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    pub fn model_u64(&self, key: &str) -> Option<u64> {
        self.model.get(key).and_then(|v| v.parse().ok())
    }

    /// Smallest bucket that can hold `tokens`, if any.
    pub fn bucket_for(&self, tokens: u32) -> Option<u32> {
        self.buckets.keys().copied().find(|&b| b >= tokens)
    }

    pub fn largest_bucket(&self) -> Option<u32> {
        self.buckets.keys().copied().max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
version 1
model vocab=512 hidden=256 layers=4 seed=0
param tok_embed 512x256
param layer0.ln1 256
bucket 256 train_step_t256.hlo.txt
bucket 512 train_step_t512.hlo.txt
attn 512 attn_fwd_t512.hlo.txt
params params.bin
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        assert_eq!(m.model_u64("vocab"), Some(512));
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].numel(), 512 * 256);
        assert_eq!(m.total_params(), 512 * 256 + 256);
        assert_eq!(m.buckets.len(), 2);
        assert_eq!(m.bucket_for(300), Some(512));
        assert_eq!(m.bucket_for(100), Some(256));
        assert_eq!(m.bucket_for(9999), None);
        assert_eq!(m.largest_bucket(), Some(512));
        assert_eq!(m.params_bin, PathBuf::from("/a/params.bin"));
    }

    #[test]
    fn rejects_bad_version() {
        let e = Manifest::parse("version 9\nparams p.bin\n", PathBuf::new());
        assert!(matches!(e, Err(ManifestError::Version(_))));
    }

    #[test]
    fn rejects_unknown_record() {
        let e = Manifest::parse("version 1\nwat 3\n", PathBuf::new());
        assert!(matches!(e, Err(ManifestError::Parse(2, _))));
    }

    #[test]
    fn requires_params_line() {
        let e = Manifest::parse("version 1\n", PathBuf::new());
        assert!(e.is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(dir).join("manifest.txt").exists() {
            let m = Manifest::load(dir).unwrap();
            assert_eq!(m.total_params(), 3_148_032);
            assert!(m.largest_bucket().unwrap() >= 256);
            for p in m.buckets.values() {
                assert!(p.exists(), "{p:?}");
            }
            assert!(m.params_bin.exists());
        }
    }
}
