//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The build image has no xla_extension, so the real bindings cannot be
//! compiled here.  This module mirrors exactly the API surface
//! `runtime::pjrt` consumes; every entry point fails fast with a clear
//! message at client construction, so the scheduler/simulator paths (which
//! never touch PJRT) are unaffected and the e2e tests skip themselves when
//! artifacts are absent.  Build with `--features xla` (and an `xla`
//! dependency in Cargo.toml) to restore real execution.

use crate::util::error::Result;

const UNAVAILABLE: &str = "PJRT runtime unavailable: skrull was built without the `xla` \
     feature (no xla_extension in this environment); scheduling and simulation are unaffected";

fn unavailable<T>() -> Result<T> {
    Err(crate::anyhow!("{UNAVAILABLE}"))
}

/// Device-resident buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Host-side literal (tuple of tensors in the train-step output).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<std::path::Path>) -> Result<Self> {
        unavailable()
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Always fails in the stub — the single choke point that keeps every
    /// other method unreachable at runtime.
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("PJRT runtime unavailable"));
    }

    #[test]
    fn parse_entry_point_reports_unavailable() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
