//! The PJRT execution engine: compile HLO-text artifacts once per bucket
//! size, then execute train steps from the coordinator's hot loop.
//!
//! Interchange is HLO *text* — the image's xla_extension 0.5.1 rejects
//! jax≥0.5's serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §3).
//!
//! Execution goes through `execute_b` with rust-owned `PjRtBuffer`s, NOT
//! the crate's `execute(&[Literal])`: that path's C++ wrapper `release()`s
//! the input device buffers it creates and never frees them, leaking the
//! full parameter set (~12.6 MB for the tiny model) on every call
//! (EXPERIMENTS.md §Perf).  Owning the buffers also lets the trainer
//! upload parameters once per optimizer step and share them across all of
//! the step's micro-batch executions.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use crate::util::error::{Context, Result};

// The real PJRT bindings need the external `xla` crate, which the offline
// build cannot fetch; the stub mirrors its API and fails fast at client
// construction (see runtime::xla_stub).
#[cfg(not(feature = "xla"))]
use crate::runtime::xla_stub as xla;

// The feature is a placeholder gate: turning it on only makes sense once a
// real `xla` dependency is added to Cargo.toml, so fail with a clear
// message instead of a wall of unresolved-path errors.
#[cfg(feature = "xla")]
compile_error!(
    "the `xla` feature requires adding the `xla` (xla_extension) crate to Cargo.toml \
     and replacing runtime::xla_stub with it"
);

use crate::data::packing::PackedBucket;
use crate::runtime::manifest::Manifest;
use crate::runtime::params::FlatParams;

/// Output of one executed train step.
#[derive(Debug)]
pub struct StepOutput {
    pub loss: f32,
    /// flat gradient buffer, same layout as FlatParams
    pub grads: Vec<f32>,
    /// pure execute() wall time (excludes literal marshalling)
    pub exec_seconds: f64,
}

/// Device-resident model parameters (one buffer per tensor, manifest
/// order).  Upload once per optimizer step, reuse for every micro-batch.
pub struct DeviceParams {
    buffers: Vec<xla::PjRtBuffer>,
}

pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: HashMap<u32, xla::PjRtLoadedExecutable>,
    /// cumulative compile seconds (reported by the e2e example)
    pub compile_seconds: f64,
    /// cumulative host->device parameter upload seconds
    pub upload_seconds: f64,
}

impl Runtime {
    /// Load the manifest and create the CPU PJRT client.  Executables are
    /// compiled lazily per bucket (call `ensure_bucket` to force).
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir.as_ref())
            .with_context(|| format!("loading manifest from {:?}", artifacts_dir.as_ref()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            manifest,
            client,
            executables: HashMap::new(),
            compile_seconds: 0.0,
            upload_seconds: 0.0,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile the train-step executable for bucket size `t` if needed.
    pub fn ensure_bucket(&mut self, t: u32) -> Result<()> {
        if self.executables.contains_key(&t) {
            return Ok(());
        }
        let path = self
            .manifest
            .buckets
            .get(&t)
            .with_context(|| format!("no artifact for bucket {t}"))?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).context("PJRT compile")?;
        self.compile_seconds += t0.elapsed().as_secs_f64();
        self.executables.insert(t, exe);
        Ok(())
    }

    /// Test/bench access to a compiled executable (panics if not compiled).
    pub fn executable_for_test(&self, t: u32) -> &xla::PjRtLoadedExecutable {
        &self.executables[&t]
    }

    pub fn available_buckets(&self) -> Vec<u32> {
        self.manifest.buckets.keys().copied().collect()
    }

    /// Load the initial parameters written by aot.py.
    pub fn initial_params(&self) -> Result<FlatParams> {
        Ok(FlatParams::load(&self.manifest)?)
    }

    /// Upload the flat parameters to the device once (per optimizer step).
    pub fn upload_params(&mut self, params: &FlatParams) -> Result<DeviceParams> {
        let t0 = Instant::now();
        let mut buffers = Vec::with_capacity(self.manifest.params.len());
        for (i, spec) in self.manifest.params.iter().enumerate() {
            buffers.push(self.client.buffer_from_host_buffer(
                params.tensor(i),
                &spec.shape,
                None,
            )?);
        }
        self.upload_seconds += t0.elapsed().as_secs_f64();
        Ok(DeviceParams { buffers })
    }

    /// Execute one train step on a packed bucket with pre-uploaded params.
    /// The bucket's capacity must match a compiled artifact exactly (HLO
    /// shapes are static).
    pub fn train_step_on(
        &mut self,
        params: &DeviceParams,
        bucket: &PackedBucket,
    ) -> Result<StepOutput> {
        let t = bucket.capacity as u32;
        self.ensure_bucket(t)?;

        // batch inputs: tokens, targets, loss_mask, segment_ids, positions
        let cap = [bucket.capacity];
        let mut inputs = Vec::with_capacity(5);
        inputs.push(self.client.buffer_from_host_buffer(&bucket.tokens, &cap, None)?);
        inputs.push(self.client.buffer_from_host_buffer(&bucket.targets, &cap, None)?);
        inputs.push(self.client.buffer_from_host_buffer(&bucket.loss_mask, &cap, None)?);
        inputs.push(self.client.buffer_from_host_buffer(&bucket.segment_ids, &cap, None)?);
        inputs.push(self.client.buffer_from_host_buffer(&bucket.positions, &cap, None)?);

        let exe = &self.executables[&t];
        let args: Vec<&xla::PjRtBuffer> =
            params.buffers.iter().chain(inputs.iter()).collect();

        let t0 = Instant::now();
        let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?;
        let exec_seconds = t0.elapsed().as_secs_f64();

        // aot.py lowers with return_tuple=True: a single tuple root of
        // (loss, grad_0, ..., grad_{n-1})
        let root = result[0][0].to_literal_sync()?;
        let parts = root.to_tuple()?;
        let n_tensors = params.buffers.len();
        crate::ensure!(
            parts.len() == 1 + n_tensors,
            "expected {} outputs, got {}",
            1 + n_tensors,
            parts.len()
        );
        let loss = parts[0].to_vec::<f32>()?[0];
        let total: usize = self.manifest.total_params();
        let mut grads = vec![0f32; total];
        let mut off = 0;
        for (i, part) in parts[1..].iter().enumerate() {
            let n = self.manifest.params[i].numel();
            let v = part.to_vec::<f32>()?;
            crate::ensure!(v.len() == n, "grad {i}: {} vs {}", v.len(), n);
            grads[off..off + n].copy_from_slice(&v);
            off += n;
        }
        Ok(StepOutput { loss, grads, exec_seconds })
    }

    /// Convenience: upload + execute in one call (tests, one-shot use).
    pub fn train_step(&mut self, params: &FlatParams, bucket: &PackedBucket) -> Result<StepOutput> {
        let dev = self.upload_params(params)?;
        self.train_step_on(&dev, bucket)
    }
}

// NOTE: integration tests that actually execute artifacts live in
// rust/tests/runtime_e2e.rs (they need `make artifacts` to have run).
