//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (HLO text + params.bin + manifest) and executes train steps on the CPU
//! PJRT client.  Python never runs here — the rust binary is self-contained
//! once artifacts exist.

pub mod manifest;
pub mod params;
pub mod pjrt;
#[cfg(not(feature = "xla"))]
pub mod xla_stub;

pub use manifest::Manifest;
pub use params::FlatParams;
pub use pjrt::{Runtime, StepOutput};
