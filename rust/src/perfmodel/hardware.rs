//! Hardware ground truth for the cluster simulator.
//!
//! The paper's testbed is 4 nodes × 8 H100 (NVLink 900 GB/s).  We model a
//! GPU as peak FLOP/s degraded by a kernel-size-dependent efficiency curve:
//! small per-rank kernels cannot fill the device (Section 3.2 / Fig. 1b:
//! "higher CP degree exacerbates kernel execution efficiency").
//!
//!   eff(w) = eff_max · w / (w + w_half)
//!
//! is a saturating curve in the per-kernel FLOPs w, calibrated so that
//! FlashAttention-2-style kernels reach ≈eff_max at multi-GFLOP sizes and
//! a few percent at tiny shard sizes — the shape that drives the paper's
//! entire observation section.

#[derive(Clone, Debug)]
pub struct Hardware {
    /// Peak dense bf16 FLOP/s per GPU (H100 SXM: 989e12).
    pub peak_flops: f64,
    /// Max achievable fraction of peak for the transformer kernels.
    pub eff_max: f64,
    /// Per-kernel FLOPs at which efficiency reaches eff_max/2.
    pub w_half: f64,
    /// Per-kernel launch overhead (s) — floors tiny kernels.
    pub launch_overhead_s: f64,
    /// Per-micro-batch framework overhead (s): the fixed cost one
    /// fwd+bwd dispatch pays in a DeepSpeed-style driver (python step
    /// loop, per-layer launch cascades, grad-accum bookkeeping).  This is
    /// what GDS's "fewer micro-batches" principle (Section 4.3.2 iii)
    /// attacks; measured DeepSpeed step floors on small models are in the
    /// low milliseconds.
    pub step_overhead_s: f64,
}

impl Hardware {
    pub fn h100() -> Self {
        Hardware {
            peak_flops: 989e12,
            eff_max: 0.70,
            w_half: 3.0e9,
            launch_overhead_s: 12e-6,
            step_overhead_s: 3e-3,
        }
    }

    /// Efficiency (fraction of peak) for one kernel of `w` FLOPs.
    pub fn efficiency(&self, w: f64) -> f64 {
        if w <= 0.0 {
            return self.eff_max;
        }
        self.eff_max * w / (w + self.w_half)
    }

    /// Wall-clock seconds to execute one kernel of `w` FLOPs.
    pub fn kernel_time(&self, w: f64) -> f64 {
        if w <= 0.0 {
            return 0.0;
        }
        w / (self.peak_flops * self.efficiency(w)) + self.launch_overhead_s
    }

    /// Achieved FLOP/s for a kernel of `w` FLOPs (Fig. 1b's y-axis).
    pub fn achieved_flops(&self, w: f64) -> f64 {
        if w <= 0.0 {
            return 0.0;
        }
        w / self.kernel_time(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_saturates() {
        let hw = Hardware::h100();
        assert!(hw.efficiency(1e12) > 0.99 * hw.eff_max);
        let half = hw.efficiency(hw.w_half);
        assert!((half - hw.eff_max / 2.0).abs() < 1e-12);
        assert!(hw.efficiency(1e6) < 0.01);
    }

    #[test]
    fn kernel_time_monotone_in_flops() {
        let hw = Hardware::h100();
        let mut prev = 0.0;
        for w in [1e6, 1e8, 1e10, 1e12] {
            let t = hw.kernel_time(w);
            assert!(t > prev);
            prev = t;
        }
    }

    #[test]
    fn achieved_flops_increase_with_kernel_size() {
        // Fig. 1b's core shape: bigger per-rank work => higher FLOPS.
        let hw = Hardware::h100();
        let small = hw.achieved_flops(1e8);
        let big = hw.achieved_flops(1e12);
        assert!(big > 10.0 * small);
        assert!(big <= hw.peak_flops * hw.eff_max);
    }

    #[test]
    fn launch_overhead_floors_tiny_kernels() {
        let hw = Hardware::h100();
        assert!(hw.kernel_time(1.0) >= hw.launch_overhead_s);
    }
}
