//! Eq. 12 — activation memory vs sequence length and the BucketSize C.
//!
//!   Memory(S) = α·S + β
//!
//! With FlashAttention + sequence packing everything activation-side is
//! linear in tokens, so per-rank memory capacity maps to a token budget C
//! ("BucketSize"), the memory constraint of Eq. 7/10.  α depends on the
//! model + recomputation strategy and comes from offline profiling
//! (perfmodel/profile.rs); β is "usually negligible" (App. A.1).
//!
//! This module is the Eq.-12 *estimator*; the capacity *authority* —
//! recompute policies, CP ring buffers, HBM-derived capacities, per-
//! iteration peak simulation — lives in `crate::memplan` and is pinned to
//! [`selective_kept_elems_per_token_layer`] so the two cannot drift.

use crate::model::ModelSpec;

/// Kept activation elements per token per layer under selective
/// recomputation (attention recomputed, linear activations kept):
/// layer input + post-attention residual (2h), QKV projections
/// (h + 2·h_kv), and the SwiGLU gate/up pair (2·ffn).  Shared with
/// `memplan::activation` as the default recompute policy's curve.
pub fn selective_kept_elems_per_token_layer(spec: &ModelSpec) -> f64 {
    let h = spec.hidden as f64;
    let hkv = spec.kv_hidden() as f64;
    2.0 * h + (h + 2.0 * hkv) + 2.0 * spec.ffn as f64
}

#[derive(Clone, Debug)]
pub struct MemoryModel {
    /// Activation bytes per token (α of Eq. 12).
    pub alpha_bytes_per_token: f64,
    /// Fixed activation bytes (β of Eq. 12).
    pub beta_bytes: f64,
    /// Device memory available for activations after static memory.
    pub activation_budget_bytes: f64,
}

impl MemoryModel {
    /// Activation bytes for a packed span of `s` tokens (Eq. 12).
    pub fn activation_bytes(&self, s: u64) -> f64 {
        self.alpha_bytes_per_token * s as f64 + self.beta_bytes
    }

    /// BucketSize C: the largest token count whose activations fit.
    pub fn bucket_size(&self) -> u32 {
        let per_token = self.alpha_bytes_per_token;
        let tokens = ((self.activation_budget_bytes - self.beta_bytes) / per_token).max(0.0);
        // skrull-lint: allow(truncating-cast) -- f64-to-u32 `as` saturates; .max(0.0) clamps negatives and the ratio is bounded by physical HBM
        tokens as u32
    }

    /// Static memory per rank under ZeRO-2 (params replicated; optimizer
    /// states + gradients sharded across `dp`): bf16 params + sharded f32
    /// Adam m/v + sharded f32 grads + f32 master weights.
    pub fn zero2_static_bytes(spec: &ModelSpec, dp: usize) -> f64 {
        let p = spec.num_params() as f64;
        let sharded = (4.0 + 4.0 + 4.0 + 4.0) * p / dp as f64; // master + m + v + grad
        2.0 * p + sharded
    }

    /// Static memory with LoRA-style PEFT (the paper's future-work lever
    /// for extending BucketSize): frozen bf16 base + optimizer/gradient
    /// state only for the adapters (`trainable_frac` of params).
    pub fn peft_static_bytes(spec: &ModelSpec, dp: usize, trainable_frac: f64) -> f64 {
        let p = spec.num_params() as f64;
        let sharded = 16.0 * p * trainable_frac / dp as f64;
        2.0 * p + sharded
    }

    /// Derive the model's memory coefficients analytically (selective
    /// recomputation: attention recomputed, linear activations kept) and
    /// calibrate the budget so the paper's published BucketSize is
    /// recovered.  `hbm_bytes` is per-GPU memory (80 GB H100).
    pub fn for_model(spec: &ModelSpec, dp: usize, hbm_bytes: f64) -> Self {
        // Kept activations per token per layer (bf16): input, qkv out,
        // attn out, mlp hidden pair — ≈ (2h + q+k+v + 2·ffn) elements.
        let alpha = 2.0 * selective_kept_elems_per_token_layer(spec) * spec.layers as f64;
        let budget = (hbm_bytes - Self::zero2_static_bytes(spec, dp)).max(0.0) * 0.9;
        MemoryModel {
            alpha_bytes_per_token: alpha,
            beta_bytes: 0.0,
            activation_budget_bytes: budget,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;

    const GB: f64 = 1024.0 * 1024.0 * 1024.0;

    #[test]
    fn bucket_size_inverts_activation_bytes() {
        let m = MemoryModel {
            alpha_bytes_per_token: 1000.0,
            beta_bytes: 500.0,
            activation_budget_bytes: 1_000_500.0,
        };
        assert_eq!(m.bucket_size(), 1000);
        assert!((m.activation_bytes(1000) - 1_000_500.0).abs() < 1e-6);
    }

    #[test]
    fn paper_bucket_sizes_ordering_and_magnitude() {
        // Section 5 publishes C = 26K (0.5B) and 13K (7B) on 80GB H100s;
        // those exact values are pinned in perfmodel::profile.  The
        // analytic α here is a first-principles estimate — we require the
        // right *ordering* and order of magnitude, not the point values
        // (the paper's profiled α includes framework overheads we cannot
        // derive analytically).
        let c05 = MemoryModel::for_model(&ModelSpec::qwen2_5_0_5b(), 4, 80.0 * GB).bucket_size();
        let c7 = MemoryModel::for_model(&ModelSpec::qwen2_5_7b(), 4, 80.0 * GB).bucket_size();
        assert!((8_000..400_000).contains(&c05), "0.5B bucket {c05}");
        assert!((1_000..100_000).contains(&c7), "7B bucket {c7}");
        // bigger model => smaller bucket, and roughly the paper's 2x gap
        assert!(c7 < c05);
        let ratio = c05 as f64 / c7 as f64;
        assert!((1.5..60.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn zero2_static_shrinks_with_dp() {
        let spec = ModelSpec::qwen2_5_7b();
        let s1 = MemoryModel::zero2_static_bytes(&spec, 1);
        let s4 = MemoryModel::zero2_static_bytes(&spec, 4);
        assert!(s4 < s1);
        // params replicated part stays
        assert!(s4 > 2.0 * spec.num_params() as f64);
    }

    #[test]
    fn peft_frees_optimizer_memory() {
        // LoRA at 1% trainable params frees almost the entire sharded
        // optimizer state — the mechanism behind the paper's "extend the
        // BucketSize by combining ... PEFT" future work.
        let spec = ModelSpec::qwen2_5_7b();
        let full = MemoryModel::zero2_static_bytes(&spec, 4);
        let peft = MemoryModel::peft_static_bytes(&spec, 4, 0.01);
        assert!(peft < full);
        let freed = full - peft;
        // freed ≈ sharded optimizer/grad state (16·p·0.99 / dp)
        let expect = 16.0 * spec.num_params() as f64 * 0.99 / 4.0;
        assert!((freed - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn beta_negligible_claim_holds_for_our_models() {
        // App. A.1: "β is usually negligible" — our analytic model sets 0.
        let m = MemoryModel::for_model(&ModelSpec::qwen2_5_0_5b(), 4, 80.0 * GB);
        assert_eq!(m.beta_bytes, 0.0);
    }
}
