//! The joint cost function: TDACP for one micro-batch (Eq. 1–7) and the
//! per-iteration objective (Eq. 8–11).  This is both the simulator's ground
//! truth and the exact solver's objective.
//!
//! Semantics of Eq. 2 — for every CP rank j:
//!
//!   Time_j = max( T_comm(V), T_comp(Local_j) ) + T_comp(Dist)
//!
//! i.e. the CP communication for *distributed* sequences overlaps with the
//! rank's *local* computation (they are independent, Fig. 2d), and the
//! distributed computation runs after both complete.
//!
//! Granularity: following Eq. 3/4, FLOPs are summed per rank (local) and
//! per shard (distributed) *before* applying the latency function — all of
//! a rank's local sequences are packed into one buffer, so they share
//! kernels.  T_comp itself is evaluated per transformer layer: the GPU
//! executes `layers` kernels of (aggregate per-layer FLOPs) each, and the
//! kernel-size-dependent efficiency (Hardware::efficiency, Fig. 1b) is a
//! per-kernel property.  Likewise T_comm launches one K/V exchange per
//! layer (Eq. 16's fixed overhead is per collective).

use crate::model::ModelSpec;
use crate::perfmodel::{CommModel, FlopsModel, Hardware};
use crate::scheduler::plan::DacpPlan;

/// Which context-parallel attention implementation carries the K/V
/// exchange.  DACP is orthogonal to the choice (Section 2); the simulator
/// models both so that claim is checkable (`ablations` bench).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommPattern {
    /// DeepSpeed-Ulysses: two all-to-alls per attention layer.
    Ulysses,
    /// RingAttention: N-1 pipelined chunk exchanges per layer.
    Ring { cp: usize },
}

#[derive(Clone, Debug)]
pub struct CostModel {
    pub flops: FlopsModel,
    pub hw: Hardware,
    pub comm: CommModel,
    /// The network between nodes (IB, not NVLink) — charged for the K/V
    /// exchange when `cross_node_cp` is set, i.e. when
    /// `Topology::cp_group_crosses_nodes` holds for the rank's CP group.
    pub inter_comm: CommModel,
    /// This model prices a CP group that spans node boundaries.
    pub cross_node_cp: bool,
    /// This model prices a DP group that spans node boundaries: the ZeRO-2
    /// gradient reduce-scatter runs at inter-node (IB) instead of
    /// intra-node (NVLink) speed (`Topology::dp_group_crosses_nodes`).
    pub cross_node_dp: bool,
    pub kv_hidden: u64,
    pub layers: u64,
    pub num_params: u64,
    pub pattern: CommPattern,
}

/// Per-rank time decomposition for one micro-batch (for utilization stats).
#[derive(Clone, Debug, Default)]
pub struct RankTime {
    pub local_comp: f64,
    pub dist_comp: f64,
    pub comm: f64,
    /// Eq. 2 total (with overlap).
    pub total: f64,
}

impl CostModel {
    pub fn new(spec: &ModelSpec, hw: Hardware, comm: CommModel) -> Self {
        CostModel {
            flops: FlopsModel::new(spec),
            kv_hidden: spec.kv_hidden(),
            layers: spec.layers,
            num_params: spec.num_params(),
            hw,
            comm,
            inter_comm: CommModel::paper_inter_node(),
            cross_node_cp: false,
            cross_node_dp: false,
            pattern: CommPattern::Ulysses,
        }
    }

    /// A copy of this model pricing a CP group that spans node boundaries:
    /// the per-layer K/V exchange runs at inter-node (IB) instead of
    /// intra-node (NVLink) speed.  Scheduling-side estimators keep the
    /// intra-node fit; the simulator charges the actual topology
    /// (`cluster::sim::simulate_iteration_on`).
    pub fn with_cross_node_cp(&self) -> Self {
        let mut c = self.clone();
        c.cross_node_cp = true;
        c
    }

    /// A copy of this model pricing a DP group that spans node boundaries:
    /// the gradient reduce-scatter runs at inter-node (IB) speed.  Compute
    /// and the CP K/V exchange are untouched.
    pub fn with_cross_node_dp(&self) -> Self {
        let mut c = self.clone();
        c.cross_node_dp = true;
        c
    }

    pub fn paper_default(spec: &ModelSpec) -> Self {
        Self::new(spec, Hardware::h100(), CommModel::paper_default())
    }

    /// Seconds to execute `per_layer_flops` of work in each of the model's
    /// layers (one fused kernel per layer at that kernel's efficiency).
    /// Public because the scheduler's incremental refinement evaluates
    /// candidate moves from maintained per-rank FLOPs sums.
    pub fn t_comp_per_layer(&self, per_layer_flops: f64) -> f64 {
        if per_layer_flops <= 0.0 {
            return 0.0;
        }
        self.layers as f64 * self.hw.kernel_time(per_layer_flops)
    }

    /// Per-layer FLOPs of one whole (local) sequence.
    pub fn seq_layer_flops(&self, s: u32) -> f64 {
        self.flops.linear_per_layer(s) + self.flops.attn_per_layer(s)
    }

    /// T_comp of a rank's packed local sequences (Eq. 3 then Eq. 14).
    pub fn t_comp_local_agg(&self, lens: impl Iterator<Item = u32>) -> f64 {
        self.t_comp_per_layer(lens.map(|s| self.seq_layer_flops(s)).sum())
    }

    /// T_comp of one rank's share of the distributed sequences (Eq. 4).
    pub fn t_comp_dist_agg(&self, lens: impl Iterator<Item = u32>, n: usize) -> f64 {
        let w: f64 = lens.map(|s| self.seq_layer_flops(s)).sum::<f64>() / n as f64;
        self.t_comp_per_layer(w)
    }

    /// Convenience (Fig. 1b, solver bounds): one sequence alone.
    pub fn t_comp_local(&self, s: u32) -> f64 {
        self.t_comp_local_agg(std::iter::once(s))
    }

    /// Convenience: one sequence's per-rank sharded time.
    pub fn t_comp_shard(&self, s: u32, n: usize) -> f64 {
        self.t_comp_dist_agg(std::iter::once(s), n)
    }

    /// T_comm(V) for the distributed tokens of a micro-batch (Eq. 5/16):
    /// one K/V collective per layer.
    ///
    /// NOTE: the launch structure here is mirrored by
    /// [`CostModel::kv_launches_and_bytes`] (the calibration emitter's
    /// feature decomposition).  They are kept as two copies deliberately —
    /// rewriting this in terms of the decomposition would change fp
    /// rounding and perturb SkrullRefined's cost comparisons — so any
    /// change to the pattern math or the bf16/tensor constants must touch
    /// both; the `kv_launches_and_bytes_mirror_t_comm_dist` test fails on
    /// drift.
    pub fn t_comm_dist(&self, total_dist_tokens: u64) -> f64 {
        if total_dist_tokens == 0 {
            return 0.0;
        }
        const BYTES: f64 = 2.0; // bf16
        const KV_TENSORS: f64 = 2.0;
        let v_layer = total_dist_tokens as f64 * self.kv_hidden as f64 * BYTES * KV_TENSORS;
        let comm = if self.cross_node_cp { &self.inter_comm } else { &self.comm };
        let per_layer = match self.pattern {
            // two all-to-alls per attention layer (scatter before, gather
            // after); the volume splits between them but each pays the
            // fixed launch overhead
            CommPattern::Ulysses => 2.0 * comm.latency(v_layer / 2.0),
            // N-1 pipelined ring steps, each moving one 1/N chunk; only
            // the non-overlappable critical path is charged here — ring
            // overlap *within* attention is part of the kernel, so the
            // exposed cost is the chunk chain
            CommPattern::Ring { cp } => {
                let n = cp.max(2) as f64;
                (n - 1.0) * comm.latency(v_layer / n)
            }
        };
        self.layers as f64 * per_layer
    }

    /// Mirror of [`CostModel::t_comm_dist`]'s launch structure: the total
    /// number of collective launches and the total bytes they move across
    /// all layers for a micro-batch's distributed tokens.  The calibration
    /// trace emitter records these so `T_comm(V) = α·V + T_fixed` can be
    /// re-fit from the trace (each launch pays α·bytes + fixed, so the
    /// aggregate is α·total_bytes + fixed·launches).
    pub fn kv_launches_and_bytes(&self, total_dist_tokens: u64) -> (f64, f64) {
        if total_dist_tokens == 0 {
            return (0.0, 0.0);
        }
        const BYTES: f64 = 2.0; // bf16
        const KV_TENSORS: f64 = 2.0;
        let v_layer = total_dist_tokens as f64 * self.kv_hidden as f64 * BYTES * KV_TENSORS;
        let l = self.layers as f64;
        match self.pattern {
            CommPattern::Ulysses => (2.0 * l, l * v_layer),
            CommPattern::Ring { cp } => {
                let n = cp.max(2) as f64;
                ((n - 1.0) * l, l * (n - 1.0) * v_layer / n)
            }
        }
    }

    /// Per-rank Eq. 2 decomposition for a planned micro-batch.  Non-empty
    /// micro-batches additionally pay the per-dispatch framework overhead
    /// (Hardware::step_overhead_s).
    pub fn rank_times(&self, lens: &[u32], plan: &DacpPlan, n: usize) -> Vec<RankTime> {
        let dist_tokens: u64 = plan.distributed().map(|i| lens[i] as u64).sum();
        let t_comm = self.t_comm_dist(dist_tokens);
        let t_dist = self.t_comp_dist_agg(plan.distributed().map(|i| lens[i]), n);
        let overhead = if lens.is_empty() { 0.0 } else { self.hw.step_overhead_s };
        (0..n)
            .map(|j| {
                let local = self.t_comp_local_agg(plan.locals_of(j).map(|i| lens[i]));
                RankTime {
                    local_comp: local,
                    dist_comp: t_dist,
                    comm: t_comm,
                    total: local.max(t_comm) + t_dist + overhead,
                }
            })
            .collect()
    }

    /// TDACP (Eq. 1): makespan over CP ranks of a planned micro-batch.
    pub fn tdacp(&self, lens: &[u32], plan: &DacpPlan, n: usize) -> f64 {
        self.rank_times(lens, plan, n)
            .iter()
            .map(|r| r.total)
            .fold(0.0, f64::max)
    }

    /// Bytes the ZeRO-2 gradient reduce-scatter moves per iteration.
    pub fn grad_sync_bytes(&self, dp: usize) -> f64 {
        if dp <= 1 {
            return 0.0;
        }
        self.num_params as f64 * 2.0 * (dp as f64 - 1.0) / dp as f64
    }

    /// ZeRO-2 gradient synchronization per iteration: reduce-scatter of
    /// bf16 gradients across the DP group (identical for every policy).
    /// Priced at inter-node bandwidth when `cross_node_dp` is set, i.e.
    /// when `Topology::any_dp_group_crosses_nodes` holds for the layout.
    pub fn grad_sync_time(&self, dp: usize) -> f64 {
        if dp <= 1 {
            return 0.0;
        }
        let comm = if self.cross_node_dp { &self.inter_comm } else { &self.comm };
        comm.latency(self.grad_sync_bytes(dp))
    }

    /// Eq. 8 over pre-computed per-rank micro-batch times: the iteration is
    /// gated by the slowest DP rank's accumulated time + gradient sync.
    pub fn iteration_time(&self, per_rank_mb_times: &[Vec<f64>], dp: usize) -> f64 {
        let slowest = per_rank_mb_times
            .iter()
            .map(|ts| ts.iter().sum::<f64>())
            .fold(0.0, f64::max);
        slowest + self.grad_sync_time(dp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::plan::DISTRIBUTED;

    fn cm() -> CostModel {
        CostModel::paper_default(&ModelSpec::qwen2_5_0_5b())
    }

    #[test]
    fn local_beats_sharded_for_short_sequences() {
        // Section 3.2: CP degrades short sequences — a 512-token sequence
        // is faster computed whole on one rank than sharded 8 ways with its
        // per-layer K/V collectives.
        let m = cm();
        let lens = [512u32];
        let local = m.tdacp(&lens, &DacpPlan { assign: vec![0] }, 8);
        let sharded = m.tdacp(&lens, &DacpPlan::all_distributed(1), 8);
        assert!(local < sharded, "local {local} vs sharded {sharded}");
    }

    #[test]
    fn sharding_wins_for_long_sequences() {
        // For a 64K sequence the quadratic work dominates; splitting over 8
        // ranks is a large win despite comm.
        let m = cm();
        let lens = [64 * 1024u32];
        let local = m.tdacp(&lens, &DacpPlan { assign: vec![0] }, 8);
        let sharded = m.tdacp(&lens, &DacpPlan::all_distributed(1), 8);
        assert!(sharded < local / 3.0, "local {local} sharded {sharded}");
    }

    #[test]
    fn packing_beats_separate_kernels() {
        // Aggregation matters: two 256-token locals on one rank cost less
        // than twice one 512-token local? No — they cost *at most* the sum
        // of separate executions and share the efficiency of the bigger
        // aggregate kernel.
        let m = cm();
        let packed = m.t_comp_local_agg([256u32, 256].into_iter());
        let separate = 2.0 * m.t_comp_local(256);
        assert!(packed < separate, "packed {packed} vs separate {separate}");
    }

    #[test]
    fn tdacp_is_makespan() {
        let m = cm();
        let lens = [1000, 1000, 30_000];
        let plan = DacpPlan { assign: vec![0, 1, DISTRIBUTED] };
        let times = m.rank_times(&lens, &plan, 2);
        let t = m.tdacp(&lens, &plan, 2);
        assert_eq!(t, times.iter().map(|r| r.total).fold(0.0, f64::max));
        // both ranks carry the same dist component
        assert!((times[0].dist_comp - times[1].dist_comp).abs() < 1e-12);
    }

    #[test]
    fn overlap_hides_comm_under_local_compute() {
        let m = cm();
        // rank 0 has heavy local work; comm should hide under it (Eq. 2)
        let lens = [20_000, 8_000];
        let plan = DacpPlan { assign: vec![0, DISTRIBUTED] };
        let times = m.rank_times(&lens, &plan, 2);
        let oh = m.hw.step_overhead_s;
        let r0 = &times[0];
        assert!(r0.local_comp > r0.comm);
        assert!((r0.total - (r0.local_comp + r0.dist_comp + oh)).abs() < 1e-12);
        // rank 1 has no local work: comm is exposed
        let r1 = &times[1];
        assert!((r1.total - (r1.comm + r1.dist_comp + oh)).abs() < 1e-12);
    }

    #[test]
    fn empty_microbatch_costs_nothing() {
        let m = cm();
        let plan = DacpPlan { assign: vec![] };
        assert_eq!(m.tdacp(&[], &plan, 8), 0.0);
    }

    #[test]
    fn iteration_time_is_slowest_rank_plus_sync() {
        let m = cm();
        let times = vec![vec![1.0, 2.0], vec![0.5], vec![2.5, 1.0]];
        let t = m.iteration_time(&times, 4);
        assert!((t - (3.5 + m.grad_sync_time(4))).abs() < 1e-12);
        assert_eq!(m.grad_sync_time(1), 0.0);
    }

    #[test]
    fn ring_and_ulysses_orthogonality() {
        // DACP's *decisions* are orthogonal to the CP implementation
        // (Section 2): both patterns agree that shorts prefer local and
        // longs prefer sharded; only the magnitudes differ.
        let mut ring = cm();
        ring.pattern = CommPattern::Ring { cp: 8 };
        let ulysses = cm();
        for m in [&ring, &ulysses] {
            let short_local = m.tdacp(&[512], &DacpPlan { assign: vec![0] }, 8);
            let short_dist = m.tdacp(&[512], &DacpPlan::all_distributed(1), 8);
            assert!(short_local < short_dist);
            let long_local = m.tdacp(&[65_536], &DacpPlan { assign: vec![0] }, 8);
            let long_dist = m.tdacp(&[65_536], &DacpPlan::all_distributed(1), 8);
            assert!(long_dist < long_local);
        }
        // ring pays more fixed overheads (N-1 vs 2 launches per layer)
        assert!(ring.t_comm_dist(512) > ulysses.t_comm_dist(512));
    }

    #[test]
    fn cross_node_cp_comm_is_strictly_slower() {
        // ROADMAP item: a CP group spanning node boundaries pays IB, not
        // NVLink — for both patterns, and in particular ring attention.
        let ulysses = cm();
        let mut ring = cm();
        ring.pattern = CommPattern::Ring { cp: 16 };
        for m in [&ulysses, &ring] {
            let x = m.with_cross_node_cp();
            assert!(x.cross_node_cp && !m.cross_node_cp);
            for tokens in [512u64, 10_000, 1_000_000] {
                assert!(
                    x.t_comm_dist(tokens) > m.t_comm_dist(tokens),
                    "{:?} tokens {tokens}",
                    m.pattern
                );
            }
            // computation is untouched: only the exchange slows down
            assert_eq!(x.t_comp_local(4096), m.t_comp_local(4096));
        }
    }

    #[test]
    fn cross_node_dp_grad_sync_is_strictly_slower() {
        // ROADMAP item: a DP group spanning node boundaries pays IB for the
        // ZeRO-2 reduce-scatter, like PR 3 did for CP rings.
        let m = cm();
        let x = m.with_cross_node_dp();
        assert!(x.cross_node_dp && !m.cross_node_dp);
        for dp in [2usize, 4, 8] {
            assert!(
                x.grad_sync_time(dp) > m.grad_sync_time(dp),
                "dp={dp}: {} vs {}",
                x.grad_sync_time(dp),
                m.grad_sync_time(dp)
            );
        }
        // dp=1 has no collective either way
        assert_eq!(x.grad_sync_time(1), 0.0);
        // the K/V exchange and compute are untouched by the DP flag
        assert_eq!(x.t_comm_dist(10_000), m.t_comm_dist(10_000));
        assert_eq!(x.t_comp_local(4096), m.t_comp_local(4096));
    }

    #[test]
    fn kv_launches_and_bytes_mirror_t_comm_dist() {
        // The emitter's (launches, bytes) decomposition must reproduce the
        // charged latency exactly: seconds = α·bytes + fixed·launches.
        let mut ring = cm();
        ring.pattern = CommPattern::Ring { cp: 8 };
        let ulysses = cm();
        for m in [&ulysses, &ring] {
            for tokens in [1u64, 512, 10_000, 1_000_000] {
                let (launches, bytes) = m.kv_launches_and_bytes(tokens);
                let rebuilt = m.comm.alpha_s_per_byte * bytes + m.comm.fixed_s * launches;
                let charged = m.t_comm_dist(tokens);
                assert!(
                    (rebuilt - charged).abs() <= 1e-12 * charged.max(1e-30),
                    "{:?} tokens {tokens}: {rebuilt} vs {charged}",
                    m.pattern
                );
            }
            assert_eq!(m.kv_launches_and_bytes(0), (0.0, 0.0));
        }
    }

    #[test]
    fn comm_scales_with_distributed_tokens() {
        let m = cm();
        let t1 = m.t_comm_dist(1_000);
        let t2 = m.t_comm_dist(100_000);
        assert!(t2 > t1);
        assert_eq!(m.t_comm_dist(0), 0.0);
        // fixed overhead per layer floors small volumes
        assert!(t1 >= m.layers as f64 * m.comm.fixed_s);
    }
}
