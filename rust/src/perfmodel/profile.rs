//! Offline profiling (Fig. 2a / Appendix A): measure the substrate, fit the
//! scheduler's estimator coefficients.
//!
//! The paper profiles the real cluster once before training; here the
//! "cluster" is the simulator's hardware ground truth (or, in the e2e
//! example, real PJRT executions), and the fits recover Eq. 12/14/16's
//! α/β.  Keeping estimator and ground truth separate mirrors the paper and
//! lets the benches quantify estimator error.

use crate::model::ModelSpec;
use crate::perfmodel::{CommModel, CostModel, FlopsModel, Hardware, MemoryModel};
use crate::util::stats::linear_fit;

/// The scheduler-facing estimator: T_comp = α·FLOPs + β (Eq. 14).
#[derive(Clone, Debug)]
pub struct CompEstimator {
    pub alpha_s_per_flop: f64,
    pub beta_s: f64,
    pub r2: f64,
}

impl CompEstimator {
    pub fn estimate(&self, flops: f64) -> f64 {
        self.alpha_s_per_flop * flops + self.beta_s
    }
}

/// Offline profile of one (model, hardware) pair.
#[derive(Clone, Debug)]
pub struct Profile {
    pub comp: CompEstimator,
    pub memory: MemoryModel,
    pub comm: CommModel,
    pub bucket_size: u32,
}

/// Run the offline profiling pass against a measurement oracle:
/// `measure(seq_len) -> seconds` for whole-sequence execution.
pub fn profile_comp<F: Fn(u32) -> f64>(
    flops: &FlopsModel,
    sample_lens: &[u32],
    measure: F,
) -> CompEstimator {
    let xs: Vec<f64> = sample_lens.iter().map(|&s| flops.seq(s)).collect();
    let ys: Vec<f64> = sample_lens.iter().map(|&s| measure(s)).collect();
    let (a, b, r2) = linear_fit(&xs, &ys);
    CompEstimator { alpha_s_per_flop: a.max(0.0), beta_s: b.max(0.0), r2 }
}

/// Full offline profiling against the simulated hardware (the default for
/// all benches; the e2e example re-profiles against real PJRT timings).
pub fn profile_model(spec: &ModelSpec, dp: usize) -> Profile {
    let hw = Hardware::h100();
    let flops = FlopsModel::new(spec);
    let lens: Vec<u32> = vec![256, 512, 1024, 2048, 4096, 8192, 16_384, 32_768];
    let comp = profile_comp(&flops, &lens, |s| hw.kernel_time(flops.seq(s)));
    let memory = MemoryModel::for_model(spec, dp, 80.0 * 1024.0 * 1024.0 * 1024.0);
    let comm = CommModel::paper_default();
    // paper's published BucketSize where known, else the memory model's
    let bucket_size = match spec.name {
        "qwen2.5-0.5b" => 26 * 1024,
        "qwen2.5-7b" => 13 * 1024,
        _ => memory.bucket_size(),
    };
    Profile { comp, memory, comm, bucket_size }
}

/// Convenience: the simulator-side cost model for a spec.
pub fn cost_model(spec: &ModelSpec) -> CostModel {
    CostModel::paper_default(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comp_fit_tracks_ground_truth_at_scale() {
        let spec = ModelSpec::qwen2_5_0_5b();
        let p = profile_model(&spec, 4);
        let hw = Hardware::h100();
        let flops = FlopsModel::new(&spec);
        // the linear estimator should be within 2x of ground truth across
        // the profiled range (it cannot capture the efficiency curve, which
        // is exactly the estimation error the paper tolerates)
        for s in [512u32, 2048, 8192, 32_768] {
            let est = p.comp.estimate(flops.seq(s));
            let truth = hw.kernel_time(flops.seq(s));
            let ratio = est / truth;
            assert!((0.4..2.5).contains(&ratio), "S={s}: est {est} truth {truth}");
        }
    }

    #[test]
    fn fit_quality_reported() {
        let p = profile_model(&ModelSpec::qwen2_5_0_5b(), 4);
        assert!(p.comp.r2 > 0.95, "r2 {}", p.comp.r2);
    }

    #[test]
    fn paper_bucket_sizes_used_for_qwen() {
        assert_eq!(profile_model(&ModelSpec::qwen2_5_0_5b(), 4).bucket_size, 26 * 1024);
        assert_eq!(profile_model(&ModelSpec::qwen2_5_7b(), 4).bucket_size, 13 * 1024);
    }

    #[test]
    fn profile_comp_recovers_linear_oracle() {
        let spec = ModelSpec::tiny();
        let flops = FlopsModel::new(&spec);
        let lens = [128u32, 256, 512, 1024];
        let est = profile_comp(&flops, &lens, |s| 2e-12 * flops.seq(s) + 1e-4);
        assert!((est.alpha_s_per_flop - 2e-12).abs() / 2e-12 < 1e-6);
        assert!((est.beta_s - 1e-4).abs() < 1e-9);
        assert!(est.r2 > 0.999999);
    }
}
