//! Performance modeling (paper Appendix A): FLOPs (Eq. 13), activation
//! memory -> BucketSize (Eq. 12), communication volume/latency (Eq. 15/16),
//! the hardware ground-truth used by the cluster simulator, and the joint
//! cost function TDACP (Eq. 1–7) / iteration time (Eq. 8–11).

pub mod comm;
pub mod cost;
pub mod flops;
pub mod hardware;
pub mod memory;
pub mod profile;

pub use comm::CommModel;
pub use cost::CostModel;
pub use flops::FlopsModel;
pub use hardware::Hardware;
pub use memory::MemoryModel;
