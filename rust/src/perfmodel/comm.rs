//! Eq. 15/16 — communication volume and latency.
//!
//!   Volume(S) = b · S · h_kv          (elements, per layer, per K/V tensor)
//!   T_comm(V) = α·V + T_fixed         (fixed overhead dominates small V)
//!
//! α and T_fixed are fit to the paper's own collective-latency profile
//! (Table 3) so the simulator's network matches the paper's testbed
//! (NVLink 900 GB/s intra-node, IB inter-node).

/// The paper's Table 3, all_gather column: (size MiB, latency µs).
pub const TABLE3_ALL_GATHER: &[(f64, f64)] = &[
    (2.0, 53.29),
    (4.0, 72.52),
    (8.0, 97.86),
    (16.0, 199.3),
    (32.0, 286.2),
    (64.0, 488.6),
    (128.0, 910.6),
    (256.0, 1758.4),
    (512.0, 3416.4),
    (1024.0, 6467.9),
];

/// Table 3, all_to_all column (Ulysses-style CP uses all-to-all).
pub const TABLE3_ALL_TO_ALL: &[(f64, f64)] = &[
    (2.0, 80.62),
    (4.0, 78.63),
    (8.0, 110.9),
    (16.0, 163.2),
    (32.0, 277.5),
    (64.0, 502.4),
    (128.0, 939.2),
    (256.0, 1803.9),
    (512.0, 3411.2),
    (1024.0, 6629.6),
];

/// Table 3, reduce_scatter column (ZeRO-2 gradient sync).
pub const TABLE3_REDUCE_SCATTER: &[(f64, f64)] = &[
    (2.0, 59.48),
    (4.0, 79.26),
    (8.0, 104.7),
    (16.0, 177.4),
    (32.0, 269.5),
    (64.0, 458.8),
    (128.0, 864.3),
    (256.0, 1663.9),
    (512.0, 3239.5),
    (1024.0, 6294.3),
];

const MIB: f64 = 1024.0 * 1024.0;

/// NVLink-to-InfiniBand bandwidth ratio for cross-node collectives
/// (900 GB/s NVLink vs ≈100 GB/s effective IB).
pub const INTER_NODE_BW_RATIO: f64 = 8.0;

#[derive(Clone, Debug)]
pub struct CommModel {
    /// Seconds per byte (α of Eq. 16).
    pub alpha_s_per_byte: f64,
    /// Fixed launch/sync overhead in seconds (T_fixed of Eq. 16).
    pub fixed_s: f64,
}

impl CommModel {
    /// Fit Eq. 16 to a (MiB, µs) profile table the way App. A.3 describes:
    /// the slope α comes from the bandwidth-bound region (large messages),
    /// T_fixed from the median residual of the latency-bound region — a
    /// plain OLS over the whole table lets the 1 GiB point swamp the small
    /// sizes where the fixed overhead dominates.
    pub fn fit(points: &[(f64, f64)]) -> Self {
        let big: Vec<(f64, f64)> = points.iter().filter(|p| p.0 >= 32.0).cloned().collect();
        let xs: Vec<f64> = big.iter().map(|p| p.0 * MIB).collect();
        let ys: Vec<f64> = big.iter().map(|p| p.1 * 1e-6).collect();
        let (a, _, _) = crate::util::stats::linear_fit(&xs, &ys);
        let a = a.max(0.0);
        let mut residuals: Vec<f64> = points
            .iter()
            .filter(|p| p.0 < 32.0)
            .map(|p| p.1 * 1e-6 - a * p.0 * MIB)
            .collect();
        residuals.sort_by(f64::total_cmp);
        let fixed = if residuals.is_empty() { 1e-6 } else { residuals[residuals.len() / 2] };
        CommModel { alpha_s_per_byte: a, fixed_s: fixed.max(1e-6) }
    }

    /// Default: fit to the paper's all_gather profile (ring-CP traffic).
    pub fn paper_default() -> Self {
        Self::fit(TABLE3_ALL_GATHER)
    }

    /// The paper's testbed network *between* nodes: InfiniBand instead of
    /// NVLink.  Table 3 profiles intra-node collectives only, so this is a
    /// modeled degradation of the fit: the bandwidth-bound slope scales by
    /// the NVLink-to-IB bandwidth ratio (900 GB/s NVLink vs ≈100 GB/s
    /// effective HDR IB per direction → 8×, [`INTER_NODE_BW_RATIO`]), and
    /// the fixed overhead doubles for the extra NIC/switch hop.  Used for
    /// CP groups that `Topology::cp_group_crosses_nodes` says span node
    /// boundaries.
    pub fn paper_inter_node() -> Self {
        let intra = Self::paper_default();
        CommModel {
            alpha_s_per_byte: intra.alpha_s_per_byte * INTER_NODE_BW_RATIO,
            fixed_s: intra.fixed_s * 2.0,
        }
    }

    /// T_comm(V) of Eq. 16, V in bytes.  V=0 costs nothing (no collective
    /// is launched when a micro-batch has no distributed sequences).
    pub fn latency(&self, volume_bytes: f64) -> f64 {
        if volume_bytes <= 0.0 {
            return 0.0;
        }
        self.alpha_s_per_byte * volume_bytes + self.fixed_s
    }

    /// Effective bus bandwidth implied by the fit (for reports).
    pub fn bandwidth_gbps(&self) -> f64 {
        1.0 / self.alpha_s_per_byte / 1e9
    }
}

/// Eq. 15 extended to the whole model: the K/V activations each CP rank
/// must exchange per layer, both tensors, bf16.
pub fn kv_comm_bytes(total_dist_tokens: u64, kv_hidden: u64, layers: u64) -> f64 {
    const BYTES: f64 = 2.0; // bf16
    const KV_TENSORS: f64 = 2.0;
    total_dist_tokens as f64 * kv_hidden as f64 * BYTES * KV_TENSORS * layers as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_reproduces_table3_points() {
        let m = CommModel::fit(TABLE3_ALL_GATHER);
        // every Table-3 point within 35% (fixed-overhead region is noisy,
        // exactly as App. A.3 describes)
        for &(mib, us) in TABLE3_ALL_GATHER {
            let pred = m.latency(mib * MIB) * 1e6;
            let rel = (pred - us).abs() / us;
            assert!(rel < 0.35, "{mib} MiB: pred {pred:.1}us vs {us}us");
        }
    }

    #[test]
    fn fit_bandwidth_is_physical() {
        // 8-GPU NVLink all-gather: effective busbw well over PCIe, below
        // the 900 GB/s peak.
        let m = CommModel::paper_default();
        let bw = m.bandwidth_gbps();
        assert!((50.0..900.0).contains(&bw), "bandwidth {bw} GB/s");
    }

    #[test]
    fn latency_monotone_and_fixed_dominated_at_small_v() {
        let m = CommModel::paper_default();
        assert_eq!(m.latency(0.0), 0.0);
        let small = m.latency(1024.0);
        let big = m.latency(1024.0 * MIB);
        assert!(small < big);
        // App. A.3: below a threshold the fixed overhead dominates
        assert!(m.fixed_s / small > 0.9);
    }

    #[test]
    fn kv_volume_scales_with_tokens_and_layers() {
        let v1 = kv_comm_bytes(1000, 128, 24);
        assert_eq!(v1, 1000.0 * 128.0 * 2.0 * 2.0 * 24.0);
        assert_eq!(kv_comm_bytes(2000, 128, 24), 2.0 * v1);
    }

    #[test]
    fn inter_node_is_strictly_slower() {
        let intra = CommModel::paper_default();
        let inter = CommModel::paper_inter_node();
        assert!(inter.alpha_s_per_byte > intra.alpha_s_per_byte);
        assert!(inter.fixed_s > intra.fixed_s);
        assert!(inter.bandwidth_gbps() < intra.bandwidth_gbps());
        for v in [1024.0, MIB, 256.0 * MIB] {
            assert!(inter.latency(v) > intra.latency(v), "volume {v}");
        }
    }

    #[test]
    fn all_columns_fit_cleanly() {
        for table in [TABLE3_ALL_GATHER, TABLE3_ALL_TO_ALL, TABLE3_REDUCE_SCATTER] {
            let m = CommModel::fit(table);
            assert!(m.alpha_s_per_byte > 0.0);
            assert!(m.fixed_s > 0.0);
        }
    }
}
