//! Eq. 13 — computational cost as a function of sequence length:
//!
//!   FLOPs(S) = 20·b·h²·S + 4·b·h·h_kv·S + 4·b·h·S²        (per layer, b=1)
//!
//! The linear terms cover the projections + SwiGLU MLP; the quadratic term
//! is FlashAttention.  The hybrid linear/quadratic dependence — and where
//! the quadratic term starts to dominate (Fig. 5) — is what makes balancing
//! computation and memory simultaneously impossible (Section 4.3.1).

use crate::model::ModelSpec;

/// FLOPs estimation for one model configuration.  `PartialEq` is exact
/// (bitwise field equality) — the scheduler's incremental caches use it to
/// gate solution reuse on the model being unchanged.
#[derive(Clone, Debug, PartialEq)]
pub struct FlopsModel {
    pub hidden: f64,
    pub kv_hidden: f64,
    pub layers: f64,
}

impl FlopsModel {
    pub fn new(spec: &ModelSpec) -> Self {
        FlopsModel {
            hidden: spec.hidden as f64,
            kv_hidden: spec.kv_hidden() as f64,
            layers: spec.layers as f64,
        }
    }

    /// Linear (projection + MLP) component per layer, Eq. 13 terms 1–2.
    pub fn linear_per_layer(&self, s: u32) -> f64 {
        let s = s as f64;
        20.0 * self.hidden * self.hidden * s + 4.0 * self.hidden * self.kv_hidden * s
    }

    /// Quadratic (attention) component per layer, Eq. 13 term 3.
    pub fn attn_per_layer(&self, s: u32) -> f64 {
        let s = s as f64;
        4.0 * self.hidden * s * s
    }

    /// Whole-model FLOPs for one sequence of `s` tokens (Eq. 13 × layers).
    pub fn seq(&self, s: u32) -> f64 {
        self.layers * (self.linear_per_layer(s) + self.attn_per_layer(s))
    }

    /// Per-rank FLOPs of a CP-sharded sequence (Eq. 4: FLOPs(S)/N).
    pub fn shard(&self, s: u32, n: usize) -> f64 {
        self.seq(s) / n as f64
    }

    /// Whole-model attention FLOPs (for Fig. 1b's attention-only view).
    pub fn attn(&self, s: u32) -> f64 {
        self.layers * self.attn_per_layer(s)
    }

    /// Sequence length at which the quadratic term overtakes the linear
    /// terms (Fig. 5's crossover): 4hS² = 20h²S + 4h·h_kv·S.
    pub fn quadratic_crossover(&self) -> f64 {
        (20.0 * self.hidden + 4.0 * self.kv_hidden) / 4.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;

    #[test]
    fn eq13_hand_computed() {
        // h=2, h_kv=1, 1 layer, S=3:
        // 20*4*3 + 4*2*1*3 + 4*2*9 = 240 + 24 + 72 = 336
        let f = FlopsModel { hidden: 2.0, kv_hidden: 1.0, layers: 1.0 };
        assert_eq!(f.seq(3), 336.0);
        assert_eq!(f.linear_per_layer(3), 264.0);
        assert_eq!(f.attn_per_layer(3), 72.0);
    }

    #[test]
    fn shard_divides_by_n() {
        let f = FlopsModel::new(&ModelSpec::qwen2_5_0_5b());
        let s = 32_768;
        assert!((f.shard(s, 8) - f.seq(s) / 8.0).abs() < 1.0);
    }

    #[test]
    fn crossover_near_4k_for_0_5b() {
        // Appendix A.2: "the quadratic term begins to dominate only when the
        // sequence length S exceeds approximately 4K" for Qwen2.5-0.5B.
        let f = FlopsModel::new(&ModelSpec::qwen2_5_0_5b());
        let x = f.quadratic_crossover();
        assert!((3_000.0..6_000.0).contains(&x), "crossover {x}");
    }

    #[test]
    fn crossover_larger_for_7b() {
        // Fig. 5: 7B has larger h => faster FLOPs growth, crossover moves up.
        let c05 = FlopsModel::new(&ModelSpec::qwen2_5_0_5b()).quadratic_crossover();
        let c7 = FlopsModel::new(&ModelSpec::qwen2_5_7b()).quadratic_crossover();
        assert!(c7 > c05);
    }

    #[test]
    fn appendix_a2_32k_vs_4k_ratio() {
        // "when S=32K, the total computational workload is 30 times greater
        // than when S=4K" (Qwen2.5-0.5B) — accept 20–40x.
        let f = FlopsModel::new(&ModelSpec::qwen2_5_0_5b());
        let ratio = f.seq(32 * 1024) / f.seq(4 * 1024);
        assert!((20.0..40.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn monotone_in_length() {
        let f = FlopsModel::new(&ModelSpec::qwen2_5_7b());
        let mut prev = 0.0;
        for s in [1u32, 128, 1024, 8192, 65536] {
            let x = f.seq(s);
            assert!(x > prev);
            prev = x;
        }
    }
}
