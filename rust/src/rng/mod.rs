//! Deterministic PRNG + sampling substrate.
//!
//! The vendored crate set has no `rand`; everything downstream (dataset
//! synthesis, scheduler property tests, corpus generation) needs seeded,
//! reproducible randomness, so we implement xoshiro256++ (Blackman/Vigna)
//! seeded through SplitMix64, plus the distributions the data generators
//! use (uniform, normal, lognormal, mixtures).

/// SplitMix64: used to expand a 64-bit seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (for per-worker rngs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seed_from_u64(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    pub fn next_u64(&mut self) -> u64 {
        let res = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        res
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire's method, bias-free for our use).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // rejection sampling on the multiply-shift reduction
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as u32
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_with(mu, sigma).exp()
    }

    /// Bernoulli(p).
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct_streams() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_eq!(xs, ys);
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(xs, (0..16).map(|_| c.next_u64()).collect::<Vec<_>>());
    }

    #[test]
    fn fork_produces_independent_streams() {
        let mut root = Rng::seed_from_u64(7);
        let mut w0 = root.fork(0);
        let mut w1 = root.fork(1);
        assert_ne!(w0.next_u64(), w1.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::seed_from_u64(2);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_500..11_500).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::seed_from_u64(4);
        let n = 100_000;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(6.0, 1.0)).collect();
        xs.sort_by(f64::total_cmp);
        let median = xs[n / 2];
        assert!((median.ln() - 6.0).abs() < 0.03, "median={median}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::seed_from_u64(6);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.6..3.4).contains(&ratio), "{counts:?}");
    }
}
