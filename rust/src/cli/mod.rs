//! Hand-rolled CLI argument parser (no clap in the vendored crate set).
//!
//! Supports `skrull <subcommand> [--key value|--key=value|--flag] ...`,
//! typed accessors with defaults, required-argument errors, and generated
//! usage text.

use std::collections::BTreeMap;

#[derive(Debug)]
pub enum CliError {
    Missing(String),
    Invalid(String, String),
    Unknown(String),
    MissingValue(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Missing(name) => write!(f, "missing required argument --{name}"),
            CliError::Invalid(name, value) => write!(f, "invalid value for --{name}: {value:?}"),
            CliError::Unknown(arg) => write!(f, "unknown argument {arg:?}"),
            CliError::MissingValue(name) => write!(f, "missing value for --{name}"),
        }
    }
}

impl std::error::Error for CliError {}

/// Parsed arguments: positionals + `--key value` options + `--flag`s.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    known: Vec<String>,
}

impl Args {
    /// Parse raw tokens.  `known_flags` lists value-less options; everything
    /// else starting with `--` consumes the next token as its value.
    pub fn parse(raw: &[String], known_flags: &[&str]) -> Result<Args, CliError> {
        let mut args = Args::default();
        args.known = known_flags.iter().map(|s| s.to_string()).collect();
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&stripped) {
                    args.flags.push(stripped.to_string());
                } else {
                    i += 1;
                    let v = raw.get(i).ok_or_else(|| CliError::MissingValue(stripped.into()))?;
                    args.options.insert(stripped.to_string(), v.clone());
                }
            } else if tok.starts_with('-') && tok.len() > 1 {
                return Err(CliError::Unknown(tok.clone()));
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn from_env(known_flags: &[&str]) -> Result<Args, CliError> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw, known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn required(&self, name: &str) -> Result<&str, CliError> {
        self.get(name).ok_or_else(|| CliError::Missing(name.into()))
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| CliError::Invalid(name.into(), v.into())),
        }
    }

    /// Comma-separated list of T.
    pub fn list_or<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Result<Vec<T>, CliError>
    where
        T: Clone,
    {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| x.trim().parse::<T>().map_err(|_| CliError::Invalid(name.into(), x.into())))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(&s(&["train", "--steps", "100", "--verbose", "--lr=0.1"]), &["verbose"]).unwrap();
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.parse_or::<u32>("steps", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert_eq!(a.parse_or::<f64>("lr", 0.0).unwrap(), 0.1);
    }

    #[test]
    fn missing_required_errors() {
        let a = Args::parse(&s(&["bench"]), &[]).unwrap();
        assert!(matches!(a.required("dataset"), Err(CliError::Missing(_))));
    }

    #[test]
    fn invalid_typed_value_errors() {
        let a = Args::parse(&s(&["--steps", "abc"]), &[]).unwrap();
        assert!(matches!(a.parse_or::<u32>("steps", 1), Err(CliError::Invalid(..))));
    }

    #[test]
    fn missing_value_errors() {
        assert!(matches!(
            Args::parse(&s(&["--steps"]), &[]),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(&s(&["--buckets", "256,512, 1024"]), &[]).unwrap();
        assert_eq!(a.list_or::<u32>("buckets", &[]).unwrap(), vec![256, 512, 1024]);
        assert_eq!(a.list_or::<u32>("other", &[7]).unwrap(), vec![7]);
    }

    #[test]
    fn defaults_pass_through() {
        let a = Args::parse(&s(&[]), &[]).unwrap();
        assert_eq!(a.str_or("mode", "sim"), "sim");
        assert_eq!(a.parse_or::<u64>("seed", 42).unwrap(), 42);
        assert!(!a.flag("verbose"));
    }
}
