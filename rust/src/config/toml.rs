//! TOML-subset parser (no serde/toml offline).  Supports the config
//! surface the launcher needs: `[section.sub]` tables, string/int/float/
//! bool scalars, homogeneous arrays, and `#` comments.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Flat table: keys are `section.sub.key`.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub entries: BTreeMap<String, Value>,
}

impl Table {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn err(line: usize, msg: impl Into<String>) -> ParseError {
    ParseError { line, msg: msg.into() }
}

fn parse_scalar(raw: &str, line: usize) -> Result<Value, ParseError> {
    let t = raw.trim();
    if t.starts_with('"') {
        if !t.ends_with('"') || t.len() < 2 {
            return Err(err(line, format!("unterminated string: {t}")));
        }
        return Ok(Value::Str(t[1..t.len() - 1].to_string()));
    }
    if t.starts_with('[') {
        if !t.ends_with(']') {
            return Err(err(line, format!("unterminated array: {t}")));
        }
        let inner = &t[1..t.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_scalar(part, line)?);
            }
        }
        return Ok(Value::Array(items));
    }
    match t {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = t.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = t.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(line, format!("cannot parse value: {t:?}")))
}

/// Strip a trailing comment, respecting string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

pub fn parse(text: &str) -> Result<Table, ParseError> {
    let mut table = Table::default();
    let mut section = String::new();
    for (idx, raw_line) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = strip_comment(raw_line).trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(err(line_no, "unterminated section header"));
            }
            section = line[1..line.len() - 1].trim().to_string();
            if section.is_empty() {
                return Err(err(line_no, "empty section name"));
            }
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| err(line_no, format!("expected key = value, got {line:?}")))?;
        let key = k.trim();
        if key.is_empty() {
            return Err(err(line_no, "empty key"));
        }
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        table.entries.insert(full_key, parse_scalar(v, line_no)?);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let t = parse(
            r#"
# experiment config
name = "fig3"
[cluster]
dp = 4
cp = 8           # per-node GPUs
[model]
peak_tflops = 989.0
enabled = true
buckets = [256, 512, 1024]
"#,
        )
        .unwrap();
        assert_eq!(t.str_or("name", ""), "fig3");
        assert_eq!(t.i64_or("cluster.dp", 0), 4);
        assert_eq!(t.i64_or("cluster.cp", 0), 8);
        assert!((t.f64_or("model.peak_tflops", 0.0) - 989.0).abs() < 1e-9);
        assert!(t.bool_or("model.enabled", false));
        match t.get("model.buckets").unwrap() {
            Value::Array(a) => assert_eq!(a.len(), 3),
            _ => panic!("expected array"),
        }
    }

    #[test]
    fn int_promotes_to_f64() {
        let t = parse("x = 3").unwrap();
        assert_eq!(t.f64_or("x", 0.0), 3.0);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let t = parse(r##"s = "a#b" # real comment"##).unwrap();
        assert_eq!(t.str_or("s", ""), "a#b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse("x = @?!\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn defaults_apply_when_missing() {
        let t = parse("").unwrap();
        assert_eq!(t.i64_or("nope", 7), 7);
        assert_eq!(t.str_or("nope", "d"), "d");
    }
}
