//! Typed experiment configuration, loadable from a TOML-subset file or
//! assembled from CLI flags.  This is the launcher's single source of truth
//! (paper's evaluation setup: `<DP=4, CP=8, BatchSize=64>` etc.).

pub mod toml;

use crate::cluster::topology::{Topology, TopologyError};
use crate::memplan::{CapacitySource, MemPlan, MemoryConfig};
use crate::model::ModelSpec;
use crate::scheduler::SchedError;

/// Parallelism + batch settings of one training job.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Data-parallel world size (ws in the paper).
    pub dp: usize,
    /// Context-parallel degree (N in the paper).
    pub cp: usize,
    /// Global batch size in sequences (K per iteration).
    pub batch_size: usize,
    /// Physical layout (paper testbed: 4 nodes × 8 GPUs).  Decides which
    /// CP groups cross node boundaries and pay IB instead of NVLink.
    pub nodes: usize,
    pub gpus_per_node: usize,
}

impl ClusterConfig {
    pub fn gpus(&self) -> usize {
        self.dp * self.cp
    }

    /// The physical topology this layout maps onto.
    pub fn topology(&self) -> Result<Topology, TopologyError> {
        Topology::new(self.nodes, self.gpus_per_node, self.dp, self.cp)
    }
}

/// Scheduling policy selector — Fig. 3's step-by-step lanes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// DeepSpeed-like: fixed micro-batching, every sequence CP-sharded.
    Baseline,
    /// DACP within baseline micro-batches (step-by-step lane 2).
    DacpOnly,
    /// Full Skrull: GDS batching + DACP placement.
    Skrull,
    /// Skrull + cost-aware placement refinement (our extension; see
    /// scheduler::dacp::refine and the `ablations` bench).
    SkrullRefined,
    /// LongAlign-style sorted batching (related-work comparator).
    SortedBatching,
}

impl Policy {
    pub fn by_name(s: &str) -> Option<Policy> {
        match s {
            "baseline" | "deepspeed" => Some(Policy::Baseline),
            "dacp" | "dacp-only" => Some(Policy::DacpOnly),
            "skrull" | "full" => Some(Policy::Skrull),
            "skrull-refined" | "refined" => Some(Policy::SkrullRefined),
            "sorted" | "longalign" => Some(Policy::SortedBatching),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Baseline => "baseline",
            Policy::DacpOnly => "dacp-only",
            Policy::Skrull => "skrull",
            Policy::SkrullRefined => "skrull-refined",
            Policy::SortedBatching => "sorted",
        }
    }
}

/// Full experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub model: ModelSpec,
    pub cluster: ClusterConfig,
    /// BucketSize C in tokens per rank (paper: 26K for 0.5B, 13K for 7B).
    pub bucket_size: u32,
    pub dataset: String,
    pub policy: Policy,
    pub iterations: usize,
    pub seed: u64,
    /// Run-engine loader mode: overlap scheduling of batch i+1 with the
    /// execution of batch i (Section 4.3's DataLoader integration).
    pub pipelined: bool,
    /// Run-engine batch source: play one full shuffled epoch
    /// (`Dataset::epoch_batches`) instead of `iterations` i.i.d. batches.
    pub epoch: bool,
    /// Memory subsystem: where capacity C comes from, HBM budget,
    /// recomputation policy (see `memplan`).
    pub memory: MemoryConfig,
}

impl ExperimentConfig {
    /// The paper's default evaluation setting for a given model + dataset.
    pub fn paper_default(model: ModelSpec, dataset: &str) -> Self {
        // <DP=4, CP=8, B=64> except Qwen-7B + ChatQA2 which uses
        // <DP=2, CP=16, B=40> (Section 5).
        let (dp, cp, batch) = if model.name == "qwen2.5-7b" && dataset == "chatqa2" {
            (2, 16, 40)
        } else {
            (4, 8, 64)
        };
        let bucket = if model.name == "qwen2.5-7b" { 13 * 1024 } else { 26 * 1024 };
        ExperimentConfig {
            model,
            cluster: ClusterConfig { dp, cp, batch_size: batch, nodes: 4, gpus_per_node: 8 },
            bucket_size: bucket,
            dataset: dataset.to_string(),
            policy: Policy::Skrull,
            iterations: 30,
            seed: 42,
            pipelined: true,
            epoch: false,
            memory: MemoryConfig::default(),
        }
    }

    /// The memory plan for this experiment's model + parallel layout.
    pub fn mem_plan(&self) -> MemPlan {
        MemPlan::for_experiment(self)
    }

    /// The token capacity C the schedulers must use: the hand-set
    /// `bucket_size` under `CapacitySource::Fixed`, the memplan-derived
    /// one under `HbmDerived`.
    pub fn resolved_bucket_size(&self) -> Result<u32, SchedError> {
        match self.memory.source {
            CapacitySource::Fixed => Ok(self.bucket_size),
            CapacitySource::HbmDerived => {
                let plan = self.mem_plan();
                plan.derive_capacity().ok_or(SchedError::NoCapacity {
                    hbm_bytes: plan.hbm_bytes as u64,
                    static_bytes: plan.static_bytes as u64,
                })
            }
        }
    }

    /// A copy of this config with `bucket_size` replaced by the resolved
    /// capacity.  Idempotent (the derivation does not read `bucket_size`);
    /// `memory.source` is kept so reports can show where C came from.
    pub fn resolve_capacity(&self) -> Result<Self, SchedError> {
        let mut cfg = self.clone();
        cfg.bucket_size = self.resolved_bucket_size()?;
        Ok(cfg)
    }

    /// Load from a TOML-subset file; missing keys fall back to the paper
    /// defaults for the named model/dataset.
    pub fn from_table(t: &toml::Table) -> crate::util::error::Result<Self> {
        let model_name = t.str_or("model.name", "qwen2.5-0.5b");
        let model = ModelSpec::by_name(&model_name)
            .ok_or_else(|| crate::anyhow!("unknown model {model_name:?}"))?;
        let dataset = t.str_or("dataset.name", "wikipedia");
        let mut cfg = ExperimentConfig::paper_default(model, &dataset);
        cfg.cluster.dp = t.i64_or("cluster.dp", cfg.cluster.dp as i64) as usize;
        cfg.cluster.cp = t.i64_or("cluster.cp", cfg.cluster.cp as i64) as usize;
        cfg.cluster.batch_size =
            t.i64_or("cluster.batch_size", cfg.cluster.batch_size as i64) as usize;
        cfg.cluster.nodes = t.i64_or("cluster.nodes", cfg.cluster.nodes as i64) as usize;
        cfg.cluster.gpus_per_node =
            t.i64_or("cluster.gpus_per_node", cfg.cluster.gpus_per_node as i64) as usize;
        cfg.bucket_size = t.i64_or("scheduler.bucket_size", cfg.bucket_size as i64) as u32;
        let policy = t.str_or("scheduler.policy", cfg.policy.name());
        cfg.policy = Policy::by_name(&policy)
            .ok_or_else(|| crate::anyhow!("unknown policy {policy:?}"))?;
        cfg.iterations = t.i64_or("run.iterations", cfg.iterations as i64) as usize;
        cfg.seed = t.i64_or("run.seed", cfg.seed as i64) as u64;
        cfg.pipelined = t.bool_or("run.pipelined", cfg.pipelined);
        cfg.epoch = t.bool_or("run.epoch", cfg.epoch);
        let source = t.str_or("memory.capacity_source", cfg.memory.source.name());
        cfg.memory.source = CapacitySource::by_name(&source)
            .ok_or_else(|| crate::anyhow!("unknown capacity source {source:?}"))?;
        cfg.memory.hbm_gb = t.f64_or("memory.hbm_gb", cfg.memory.hbm_gb);
        let recompute = t.str_or("memory.recompute", cfg.memory.recompute.name());
        cfg.memory.recompute = crate::memplan::RecomputePolicy::by_name(&recompute)
            .ok_or_else(|| crate::anyhow!("unknown recompute policy {recompute:?}"))?;
        cfg.memory.peft_frac =
            t.get("memory.peft_frac").and_then(|v| v.as_f64()).or(cfg.memory.peft_frac);
        cfg.memory.headroom_frac = t.f64_or("memory.headroom_frac", cfg.memory.headroom_frac);
        Ok(cfg)
    }

    pub fn load(path: &str) -> crate::util::error::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let table = toml::parse(&text).map_err(|e| crate::anyhow!("{path}: {e}"))?;
        Self::from_table(&table)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_match_section5() {
        let c = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
        assert_eq!((c.cluster.dp, c.cluster.cp, c.cluster.batch_size), (4, 8, 64));
        assert_eq!(c.bucket_size, 26 * 1024);
        let c7 = ExperimentConfig::paper_default(ModelSpec::qwen2_5_7b(), "chatqa2");
        assert_eq!((c7.cluster.dp, c7.cluster.cp, c7.cluster.batch_size), (2, 16, 40));
        assert_eq!(c7.bucket_size, 13 * 1024);
        assert_eq!(c7.cluster.gpus(), 32);
    }

    #[test]
    fn from_table_overrides() {
        let t = toml::parse(
            r#"
[model]
name = "7b"
[dataset]
name = "lmsys"
[cluster]
dp = 8
[scheduler]
policy = "dacp"
bucket_size = 4096
[run]
iterations = 5
seed = 7
pipelined = false
"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(c.model.name, "qwen2.5-7b");
        assert_eq!(c.cluster.dp, 8);
        assert_eq!(c.cluster.cp, 8); // default retained
        assert_eq!(c.policy, Policy::DacpOnly);
        assert_eq!(c.bucket_size, 4096);
        assert_eq!(c.iterations, 5);
        assert_eq!(c.seed, 7);
        assert!(!c.pipelined);
        // defaults to pipelined when the key is absent
        let d = ExperimentConfig::from_table(&toml::parse("").unwrap()).unwrap();
        assert!(d.pipelined);
    }

    #[test]
    fn memory_and_layout_keys_parse() {
        use crate::memplan::RecomputePolicy;
        let t = toml::parse(
            r#"
[cluster]
nodes = 2
gpus_per_node = 16
[memory]
capacity_source = "hbm-derived"
hbm_gb = 40.0
recompute = "full"
peft_frac = 0.01
[run]
epoch = true
"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!((c.cluster.nodes, c.cluster.gpus_per_node), (2, 16));
        assert_eq!(c.memory.source, CapacitySource::HbmDerived);
        assert_eq!(c.memory.hbm_gb, 40.0);
        assert_eq!(c.memory.recompute, RecomputePolicy::Full);
        assert_eq!(c.memory.peft_frac, Some(0.01));
        assert!(c.epoch);
        // defaults: fixed capacity, 80 GB, selective recompute, no epoch
        let d = ExperimentConfig::from_table(&toml::parse("").unwrap()).unwrap();
        assert_eq!(d.memory, crate::memplan::MemoryConfig::default());
        assert!(!d.epoch);
        // bad values are rejected, not silently defaulted
        let t = toml::parse("[memory]\ncapacity_source = \"psychic\"\n").unwrap();
        assert!(ExperimentConfig::from_table(&t).is_err());
        let t = toml::parse("[memory]\nrecompute = \"sometimes\"\n").unwrap();
        assert!(ExperimentConfig::from_table(&t).is_err());
    }

    #[test]
    fn fixed_capacity_resolution_is_identity() {
        let c = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
        let r = c.resolve_capacity().unwrap();
        assert_eq!(r.bucket_size, c.bucket_size);
        assert_eq!(r.resolved_bucket_size().unwrap(), c.bucket_size);
    }

    #[test]
    fn hbm_derived_resolution_replaces_bucket_and_is_idempotent() {
        let mut c = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
        c.memory.source = CapacitySource::HbmDerived;
        let r = c.resolve_capacity().unwrap();
        assert_ne!(r.bucket_size, c.bucket_size);
        assert_eq!(r.bucket_size, c.mem_plan().derive_capacity().unwrap());
        // idempotent: resolving again changes nothing
        assert_eq!(r.resolve_capacity().unwrap().bucket_size, r.bucket_size);
        // infeasible budget is a clean error
        c.memory.hbm_gb = 0.5;
        assert!(matches!(
            c.resolve_capacity(),
            Err(crate::scheduler::SchedError::NoCapacity { .. })
        ));
    }

    #[test]
    fn cluster_topology_maps_paper_testbed() {
        let c = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
        let t = c.cluster.topology().unwrap();
        assert_eq!(t.total_gpus(), 32);
        assert!(!t.cp_group_crosses_nodes(0));
        let c7 = ExperimentConfig::paper_default(ModelSpec::qwen2_5_7b(), "chatqa2");
        assert!(c7.cluster.topology().unwrap().cp_group_crosses_nodes(0));
    }

    #[test]
    fn bad_model_name_errors() {
        let t = toml::parse("[model]\nname = \"gpt9\"\n").unwrap();
        assert!(ExperimentConfig::from_table(&t).is_err());
    }

    #[test]
    fn policy_round_trips() {
        for p in [
            Policy::Baseline,
            Policy::DacpOnly,
            Policy::Skrull,
            Policy::SkrullRefined,
            Policy::SortedBatching,
        ] {
            assert_eq!(Policy::by_name(p.name()), Some(p));
        }
    }
}
