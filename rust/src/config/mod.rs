//! Typed experiment configuration, loadable from a TOML-subset file or
//! assembled from CLI flags.  This is the launcher's single source of truth
//! (paper's evaluation setup: `<DP=4, CP=8, BatchSize=64>` etc.).

pub mod toml;

use crate::calib::CalibratedProfile;
use crate::cluster::topology::{Topology, TopologyError};
use crate::memplan::{CapacitySource, MemPlan, MemoryConfig};
use crate::model::ModelSpec;
use crate::perfmodel::CostModel;
use crate::scheduler::SchedError;

/// Range-checked integer lookup: `default` when the key is absent, an
/// error when it is present but non-integer or out of range for the
/// target type.  Replaces the old `i64_or(..) as u32/usize` pattern,
/// where an out-of-range TOML value (say `bucket_size = 4294967297`)
/// silently wrapped instead of erroring.
fn checked_int<T: TryFrom<i64>>(
    t: &toml::Table,
    key: &str,
    default: T,
) -> crate::util::error::Result<T> {
    let Some(v) = t.get(key) else {
        return Ok(default);
    };
    let raw = v
        .as_i64()
        .ok_or_else(|| crate::anyhow!("config key {key} must be an integer, got {v:?}"))?;
    T::try_from(raw).map_err(|_| {
        crate::anyhow!(
            "config key {key} = {raw} is out of range for {}",
            std::any::type_name::<T>()
        )
    })
}

/// Where the cost/memory model coefficients come from.
///
/// `Analytic` is the first-principles `Hardware::h100()` stack (the
/// pre-calibration behaviour, byte-identical schedules).  `Calibrated`
/// carries a fitted [`CalibratedProfile`], loaded and validated once at
/// config-resolution time, that the loader, run engine, trainer and e2e
/// sweep all consume.
#[derive(Clone, Debug)]
pub enum CostSource {
    Analytic,
    Calibrated {
        /// Where the profile was loaded from (for reports).
        path: String,
        profile: CalibratedProfile,
    },
}

impl CostSource {
    /// Load and sanity-check a fitted profile from disk.
    pub fn calibrated(path: &str) -> crate::util::error::Result<Self> {
        use crate::util::error::Context;
        let profile = crate::calib::load_profile(path)?;
        profile
            .validate(0.0)
            .with_context(|| format!("profile {path} has unusable coefficients"))?;
        Ok(CostSource::Calibrated { path: path.to_string(), profile })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CostSource::Analytic => "analytic",
            CostSource::Calibrated { .. } => "calibrated",
        }
    }

    pub fn profile(&self) -> Option<&CalibratedProfile> {
        match self {
            CostSource::Analytic => None,
            CostSource::Calibrated { profile, .. } => Some(profile),
        }
    }

    /// Coefficients are per-(model, hardware): a profile fitted on one
    /// model must not silently steer another model's memory plan (its
    /// measured static bytes and activation slope would be wrong).
    pub fn ensure_model(&self, model_name: &str) -> crate::util::error::Result<()> {
        if let CostSource::Calibrated { path, profile } = self {
            crate::ensure!(
                profile.model == model_name,
                "profile {path} was calibrated on {:?} but the experiment runs {model_name:?}; \
                 re-run `skrull calibrate --emit` with --model {model_name}",
                profile.model
            );
        }
        Ok(())
    }
}

/// Parallelism + batch settings of one training job.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Data-parallel world size (ws in the paper).
    pub dp: usize,
    /// Context-parallel degree (N in the paper).
    pub cp: usize,
    /// Global batch size in sequences (K per iteration).
    pub batch_size: usize,
    /// Physical layout (paper testbed: 4 nodes × 8 GPUs).  Decides which
    /// CP groups cross node boundaries and pay IB instead of NVLink.
    pub nodes: usize,
    pub gpus_per_node: usize,
}

impl ClusterConfig {
    pub fn gpus(&self) -> usize {
        self.dp * self.cp
    }

    /// The physical topology this layout maps onto.
    pub fn topology(&self) -> Result<Topology, TopologyError> {
        Topology::new(self.nodes, self.gpus_per_node, self.dp, self.cp)
    }
}

/// Scheduling policy selector — Fig. 3's step-by-step lanes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// DeepSpeed-like: fixed micro-batching, every sequence CP-sharded.
    Baseline,
    /// DACP within baseline micro-batches (step-by-step lane 2).
    DacpOnly,
    /// Full Skrull: GDS batching + DACP placement.
    Skrull,
    /// Skrull + cost-aware placement refinement (our extension; see
    /// scheduler::dacp::refine and the `ablations` bench).
    SkrullRefined,
    /// LongAlign-style sorted batching (related-work comparator).
    SortedBatching,
}

impl Policy {
    pub fn by_name(s: &str) -> Option<Policy> {
        match s {
            "baseline" | "deepspeed" => Some(Policy::Baseline),
            "dacp" | "dacp-only" => Some(Policy::DacpOnly),
            "skrull" | "full" => Some(Policy::Skrull),
            "skrull-refined" | "refined" => Some(Policy::SkrullRefined),
            "sorted" | "longalign" => Some(Policy::SortedBatching),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Baseline => "baseline",
            Policy::DacpOnly => "dacp-only",
            Policy::Skrull => "skrull",
            Policy::SkrullRefined => "skrull-refined",
            Policy::SortedBatching => "sorted",
        }
    }
}

/// Full experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub model: ModelSpec,
    pub cluster: ClusterConfig,
    /// BucketSize C in tokens per rank (paper: 26K for 0.5B, 13K for 7B).
    pub bucket_size: u32,
    pub dataset: String,
    pub policy: Policy,
    pub iterations: usize,
    pub seed: u64,
    /// Run-engine loader mode: overlap scheduling of batch i+1 with the
    /// execution of batch i (Section 4.3's DataLoader integration).
    pub pipelined: bool,
    /// Run-engine batch source: play one full shuffled epoch
    /// (`Dataset::epoch_batches`) instead of `iterations` i.i.d. batches.
    pub epoch: bool,
    /// Memory subsystem: where capacity C comes from, HBM budget,
    /// recomputation policy (see `memplan`).
    pub memory: MemoryConfig,
    /// Cost/memory coefficient source: analytic first-principles models or
    /// a calibrated profile fitted from a measured trace (see `calib`).
    pub cost: CostSource,
    /// Worker threads for sweep-level fan-out (`run.jobs`, consumed by
    /// `skrull e2e --config` into `bench::e2e::E2eOptions::jobs`; `--jobs`
    /// overrides).  Defaults to the machine's available parallelism,
    /// clamped ≥ 1; 1 = fully serial.  A job count never changes results,
    /// only wall-clock.
    pub jobs: usize,
    /// Shared-nothing scheduler shards (`scheduler.shards`, `--shards`):
    /// persistent worker threads the GDS hot path partitions DP ranks
    /// across (see `scheduler::shard`).  1 = the in-thread fast path;
    /// 0 (or negative in TOML) = auto, one shard per available core.
    /// Byte-identical output at every shard count (oracle-tested).
    pub shards: usize,
    /// Incremental re-scheduling (`scheduler.incremental`,
    /// `--incremental`): reuse the previous iteration's rank partition and
    /// per-rank solutions when the batch composition is unchanged.
    /// Byte-identical to fresh scheduling — reuse is gated on exact
    /// equality of lengths, model and knobs.
    pub incremental: bool,
    /// Streaming out-of-core data plane (`[stream]` table, `--spill-dir` /
    /// `--stream-ram-mb`): disk-spilled sequence store with a bounded-RAM
    /// page cache, reservoir length-sketching and drift-triggered
    /// recalibration (see `stream`).  Disabled unless `spill_dir` is set;
    /// schedules are byte-identical spilled or in-memory.
    pub stream: crate::stream::StreamConfig,
}

impl ExperimentConfig {
    /// The paper's default evaluation setting for a given model + dataset.
    pub fn paper_default(model: ModelSpec, dataset: &str) -> Self {
        // <DP=4, CP=8, B=64> except Qwen-7B + ChatQA2 which uses
        // <DP=2, CP=16, B=40> (Section 5).
        let (dp, cp, batch) = if model.name == "qwen2.5-7b" && dataset == "chatqa2" {
            (2, 16, 40)
        } else {
            (4, 8, 64)
        };
        let bucket = if model.name == "qwen2.5-7b" { 13 * 1024 } else { 26 * 1024 };
        ExperimentConfig {
            model,
            cluster: ClusterConfig { dp, cp, batch_size: batch, nodes: 4, gpus_per_node: 8 },
            bucket_size: bucket,
            dataset: dataset.to_string(),
            policy: Policy::Skrull,
            iterations: 30,
            seed: 42,
            pipelined: true,
            epoch: false,
            memory: MemoryConfig::default(),
            cost: CostSource::Analytic,
            jobs: crate::util::par::max_threads().max(1),
            shards: 1,
            incremental: false,
            stream: crate::stream::StreamConfig::default(),
        }
    }

    /// The cost model simulations and cost-aware scheduling run against:
    /// the analytic paper default, or the calibrated profile's drop-in
    /// reconstruction.
    pub fn cost_model(&self) -> CostModel {
        match self.cost.profile() {
            Some(p) => p.cost_model(&self.model),
            None => CostModel::paper_default(&self.model),
        }
    }

    /// The memory plan for this experiment's model + parallel layout.
    /// Under a calibrated cost source whose trace supported a memory fit,
    /// the analytic activation curve and static bytes are replaced by the
    /// measured ones.
    pub fn mem_plan(&self) -> MemPlan {
        let base = MemPlan::for_experiment(self);
        match self.cost.profile().and_then(|p| p.mem.as_ref()) {
            Some(m) => base.with_calibrated(m.slope, m.intercept),
            None => base,
        }
    }

    /// The token capacity C the schedulers must use: the hand-set
    /// `bucket_size` under `CapacitySource::Fixed`, the memplan-derived
    /// one under `HbmDerived`.
    pub fn resolved_bucket_size(&self) -> Result<u32, SchedError> {
        match self.memory.source {
            CapacitySource::Fixed => Ok(self.bucket_size),
            CapacitySource::HbmDerived => {
                let plan = self.mem_plan();
                plan.derive_capacity().ok_or(SchedError::NoCapacity {
                    hbm_bytes: plan.hbm_bytes as u64,
                    static_bytes: plan.static_bytes as u64,
                })
            }
        }
    }

    /// A copy of this config with `bucket_size` replaced by the resolved
    /// capacity.  Idempotent (the derivation does not read `bucket_size`);
    /// `memory.source` is kept so reports can show where C came from.
    pub fn resolve_capacity(&self) -> Result<Self, SchedError> {
        let mut cfg = self.clone();
        cfg.bucket_size = self.resolved_bucket_size()?;
        Ok(cfg)
    }

    /// Load from a TOML-subset file; missing keys fall back to the paper
    /// defaults for the named model/dataset.
    pub fn from_table(t: &toml::Table) -> crate::util::error::Result<Self> {
        let model_name = t.str_or("model.name", "qwen2.5-0.5b");
        let model = ModelSpec::by_name(&model_name)
            .ok_or_else(|| crate::anyhow!("unknown model {model_name:?}"))?;
        let dataset = t.str_or("dataset.name", "wikipedia");
        let mut cfg = ExperimentConfig::paper_default(model, &dataset);
        cfg.cluster.dp = checked_int(t, "cluster.dp", cfg.cluster.dp)?;
        cfg.cluster.cp = checked_int(t, "cluster.cp", cfg.cluster.cp)?;
        cfg.cluster.batch_size = checked_int(t, "cluster.batch_size", cfg.cluster.batch_size)?;
        cfg.cluster.nodes = checked_int(t, "cluster.nodes", cfg.cluster.nodes)?;
        cfg.cluster.gpus_per_node =
            checked_int(t, "cluster.gpus_per_node", cfg.cluster.gpus_per_node)?;
        cfg.bucket_size = checked_int(t, "scheduler.bucket_size", cfg.bucket_size)?;
        let policy = t.str_or("scheduler.policy", cfg.policy.name());
        cfg.policy = Policy::by_name(&policy)
            .ok_or_else(|| crate::anyhow!("unknown policy {policy:?}"))?;
        cfg.iterations = checked_int(t, "run.iterations", cfg.iterations)?;
        cfg.seed = checked_int(t, "run.seed", cfg.seed)?;
        cfg.pipelined = t.bool_or("run.pipelined", cfg.pipelined);
        cfg.epoch = t.bool_or("run.epoch", cfg.epoch);
        // 0 (or negative) means "auto": the machine's available
        // parallelism — same semantics as `--jobs 0`
        let jobs: i64 = checked_int(t, "run.jobs", cfg.jobs as i64)?;
        if jobs > 0 {
            cfg.jobs = jobs as usize;
        }
        // same auto convention as run.jobs: 0 / negative = one shard per core
        let shards: i64 = checked_int(t, "scheduler.shards", cfg.shards as i64)?;
        cfg.shards = if shards > 0 {
            shards as usize
        } else {
            crate::util::par::max_threads().max(1)
        };
        cfg.incremental = t.bool_or("scheduler.incremental", cfg.incremental);
        let source = t.str_or("memory.capacity_source", cfg.memory.source.name());
        cfg.memory.source = CapacitySource::by_name(&source)
            .ok_or_else(|| crate::anyhow!("unknown capacity source {source:?}"))?;
        // `hbm_gb` accepts a scalar (homogeneous cluster) or a per-node
        // list (`hbm_gb = [80, 40, 80, 80]`) whose minimum governs the
        // derived capacity and the OOM line
        match t.get("memory.hbm_gb") {
            None => {}
            Some(toml::Value::Array(items)) => {
                let nodes: Vec<f64> = items
                    .iter()
                    .map(|v| v.as_f64().ok_or_else(|| crate::anyhow!("non-numeric hbm_gb entry")))
                    .collect::<crate::util::error::Result<_>>()?;
                crate::ensure!(!nodes.is_empty(), "memory.hbm_gb list is empty");
                crate::ensure!(
                    nodes.len() == cfg.cluster.nodes,
                    "memory.hbm_gb lists {} nodes but the cluster has {}",
                    nodes.len(),
                    cfg.cluster.nodes
                );
                crate::ensure!(
                    nodes.iter().all(|&g| g.is_finite() && g > 0.0),
                    "memory.hbm_gb entries must be positive"
                );
                // the scalar is left alone: `effective_hbm_gb()` is the
                // single authority for folding the list into a budget
                cfg.memory.hbm_gb_nodes = Some(nodes);
            }
            Some(v) => {
                cfg.memory.hbm_gb = v
                    .as_f64()
                    .ok_or_else(|| crate::anyhow!("memory.hbm_gb must be a number or list"))?;
                cfg.memory.hbm_gb_nodes = None;
            }
        }
        let recompute = t.str_or("memory.recompute", cfg.memory.recompute.name());
        cfg.memory.recompute = crate::memplan::RecomputePolicy::by_name(&recompute)
            .ok_or_else(|| crate::anyhow!("unknown recompute policy {recompute:?}"))?;
        cfg.memory.peft_frac =
            t.get("memory.peft_frac").and_then(|v| v.as_f64()).or(cfg.memory.peft_frac);
        cfg.memory.headroom_frac = t.f64_or("memory.headroom_frac", cfg.memory.headroom_frac);
        if let Some(v) = t.get("scheduler.cost_profile") {
            let path = v
                .as_str()
                .ok_or_else(|| crate::anyhow!("scheduler.cost_profile must be a string path"))?;
            cfg.cost = CostSource::calibrated(path)?;
            cfg.cost.ensure_model(cfg.model.name)?;
        }
        // [stream]: the out-of-core data plane is off unless a spill
        // directory is named (same convention as the CLI's --spill-dir)
        if let Some(v) = t.get("stream.spill_dir") {
            let dir = v
                .as_str()
                .ok_or_else(|| crate::anyhow!("stream.spill_dir must be a string path"))?;
            cfg.stream.spill_dir = Some(dir.to_string());
        }
        cfg.stream.ram_mb = checked_int(t, "stream.ram_mb", cfg.stream.ram_mb)?;
        crate::ensure!(cfg.stream.ram_mb > 0, "stream.ram_mb must be positive");
        cfg.stream.page_len = checked_int(t, "stream.page_len", cfg.stream.page_len)?;
        crate::ensure!(cfg.stream.page_len > 0, "stream.page_len must be positive");
        cfg.stream.reservoir_shards =
            checked_int(t, "stream.reservoir_shards", cfg.stream.reservoir_shards)?;
        cfg.stream.reservoir_per_shard =
            checked_int(t, "stream.reservoir_per_shard", cfg.stream.reservoir_per_shard)?;
        cfg.stream.drift_window = checked_int(t, "stream.drift_window", cfg.stream.drift_window)?;
        cfg.stream.drift_threshold = t.f64_or("stream.drift_threshold", cfg.stream.drift_threshold);
        crate::ensure!(
            cfg.stream.drift_threshold > 0.0 && cfg.stream.drift_threshold.is_finite(),
            "stream.drift_threshold must be a positive number"
        );
        Ok(cfg)
    }

    pub fn load(path: &str) -> crate::util::error::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let table = toml::parse(&text).map_err(|e| crate::anyhow!("{path}: {e}"))?;
        Self::from_table(&table)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_match_section5() {
        let c = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
        assert_eq!((c.cluster.dp, c.cluster.cp, c.cluster.batch_size), (4, 8, 64));
        assert_eq!(c.bucket_size, 26 * 1024);
        let c7 = ExperimentConfig::paper_default(ModelSpec::qwen2_5_7b(), "chatqa2");
        assert_eq!((c7.cluster.dp, c7.cluster.cp, c7.cluster.batch_size), (2, 16, 40));
        assert_eq!(c7.bucket_size, 13 * 1024);
        assert_eq!(c7.cluster.gpus(), 32);
    }

    #[test]
    fn from_table_overrides() {
        let t = toml::parse(
            r#"
[model]
name = "7b"
[dataset]
name = "lmsys"
[cluster]
dp = 8
[scheduler]
policy = "dacp"
bucket_size = 4096
[run]
iterations = 5
seed = 7
pipelined = false
"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(c.model.name, "qwen2.5-7b");
        assert_eq!(c.cluster.dp, 8);
        assert_eq!(c.cluster.cp, 8); // default retained
        assert_eq!(c.policy, Policy::DacpOnly);
        assert_eq!(c.bucket_size, 4096);
        assert_eq!(c.iterations, 5);
        assert_eq!(c.seed, 7);
        assert!(!c.pipelined);
        // defaults to pipelined when the key is absent
        let d = ExperimentConfig::from_table(&toml::parse("").unwrap()).unwrap();
        assert!(d.pipelined);
    }

    #[test]
    fn out_of_range_integer_keys_error_instead_of_wrapping() {
        // u32::MAX + 2: the old `i64_or(..) as u32` parse wrapped this
        // to bucket_size = 1 silently
        let t = toml::parse("[scheduler]\nbucket_size = 4294967297\n").unwrap();
        let err = ExperimentConfig::from_table(&t).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
        // negative values must not wrap into huge unsigned ones
        for bad in ["[scheduler]\nbucket_size = -1\n", "[cluster]\ndp = -2\n", "[run]\nseed = -7\n"]
        {
            let t = toml::parse(bad).unwrap();
            assert!(ExperimentConfig::from_table(&t).is_err(), "accepted {bad:?}");
        }
        // wrong type used to fall back to the default silently; now it errors
        let t = toml::parse("[scheduler]\nbucket_size = \"big\"\n").unwrap();
        let err = ExperimentConfig::from_table(&t).unwrap_err();
        assert!(format!("{err:#}").contains("must be an integer"), "{err:#}");
        // in-range values still parse exactly
        let t = toml::parse("[scheduler]\nbucket_size = 4294967295\n").unwrap();
        assert_eq!(ExperimentConfig::from_table(&t).unwrap().bucket_size, u32::MAX);
    }

    #[test]
    fn run_jobs_key_parses_and_zero_means_auto() {
        let auto = crate::util::par::max_threads().max(1);
        let t = toml::parse("[run]\njobs = 3\n").unwrap();
        assert_eq!(ExperimentConfig::from_table(&t).unwrap().jobs, 3);
        // 0 (and negatives) mean "auto", same as --jobs 0 — never 0 workers
        let t = toml::parse("[run]\njobs = 0\n").unwrap();
        assert_eq!(ExperimentConfig::from_table(&t).unwrap().jobs, auto);
        let t = toml::parse("[run]\njobs = -4\n").unwrap();
        assert_eq!(ExperimentConfig::from_table(&t).unwrap().jobs, auto);
        // absent: the machine's available parallelism, at least 1
        let d = ExperimentConfig::from_table(&toml::parse("").unwrap()).unwrap();
        assert!(d.jobs >= 1);
        assert_eq!(d.jobs, auto);
    }

    #[test]
    fn scheduler_shards_and_incremental_keys_parse() {
        let auto = crate::util::par::max_threads().max(1);
        let t = toml::parse("[scheduler]\nshards = 4\nincremental = true\n").unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(c.shards, 4);
        assert!(c.incremental);
        // 0 / negative = auto (one shard per core), same as run.jobs
        let t = toml::parse("[scheduler]\nshards = 0\n").unwrap();
        assert_eq!(ExperimentConfig::from_table(&t).unwrap().shards, auto);
        let t = toml::parse("[scheduler]\nshards = -2\n").unwrap();
        assert_eq!(ExperimentConfig::from_table(&t).unwrap().shards, auto);
        // absent: single shard, incremental off — the PR-5 behaviour
        let d = ExperimentConfig::from_table(&toml::parse("").unwrap()).unwrap();
        assert_eq!(d.shards, 1);
        assert!(!d.incremental);
    }

    #[test]
    fn memory_and_layout_keys_parse() {
        use crate::memplan::RecomputePolicy;
        let t = toml::parse(
            r#"
[cluster]
nodes = 2
gpus_per_node = 16
[memory]
capacity_source = "hbm-derived"
hbm_gb = 40.0
recompute = "full"
peft_frac = 0.01
[run]
epoch = true
"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!((c.cluster.nodes, c.cluster.gpus_per_node), (2, 16));
        assert_eq!(c.memory.source, CapacitySource::HbmDerived);
        assert_eq!(c.memory.hbm_gb, 40.0);
        assert_eq!(c.memory.recompute, RecomputePolicy::Full);
        assert_eq!(c.memory.peft_frac, Some(0.01));
        assert!(c.epoch);
        // defaults: fixed capacity, 80 GB, selective recompute, no epoch
        let d = ExperimentConfig::from_table(&toml::parse("").unwrap()).unwrap();
        assert_eq!(d.memory, crate::memplan::MemoryConfig::default());
        assert!(!d.epoch);
        // bad values are rejected, not silently defaulted
        let t = toml::parse("[memory]\ncapacity_source = \"psychic\"\n").unwrap();
        assert!(ExperimentConfig::from_table(&t).is_err());
        let t = toml::parse("[memory]\nrecompute = \"sometimes\"\n").unwrap();
        assert!(ExperimentConfig::from_table(&t).is_err());
    }

    #[test]
    fn heterogeneous_hbm_list_parses_and_min_governs() {
        let t = toml::parse("[memory]\nhbm_gb = [80.0, 40, 80.0, 80.0]\n").unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(c.memory.hbm_gb_nodes, Some(vec![80.0, 40.0, 80.0, 80.0]));
        // the list, not the scalar, is authoritative: the fold lives in
        // effective_hbm_gb() alone
        assert_eq!(c.memory.effective_hbm_gb(), 40.0);
        // scalar form keeps the homogeneous path
        let t = toml::parse("[memory]\nhbm_gb = 64.0\n").unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(c.memory.hbm_gb, 64.0);
        assert_eq!(c.memory.hbm_gb_nodes, None);
        // wrong node count, empty list and bad entries are rejected
        for bad in [
            "[memory]\nhbm_gb = [80.0, 40.0]\n",
            "[memory]\nhbm_gb = []\n",
            "[memory]\nhbm_gb = [80.0, \"x\", 80.0, 80.0]\n",
            "[memory]\nhbm_gb = [80.0, -1.0, 80.0, 80.0]\n",
        ] {
            let t = toml::parse(bad).unwrap();
            assert!(ExperimentConfig::from_table(&t).is_err(), "{bad}");
        }
        // ... unless the cluster really has that many nodes
        let t = toml::parse("[cluster]\nnodes = 2\n[memory]\nhbm_gb = [80.0, 40.0]\n").unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(c.memory.effective_hbm_gb(), 40.0);
    }

    #[test]
    fn stream_table_parses_and_defaults_to_disabled() {
        let t = toml::parse(
            r#"
[stream]
spill_dir = "/tmp/skrull-spill"
ram_mb = 8
page_len = 512
reservoir_shards = 4
reservoir_per_shard = 128
drift_window = 256
drift_threshold = 0.5
"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert!(c.stream.enabled());
        assert_eq!(c.stream.spill_dir.as_deref(), Some("/tmp/skrull-spill"));
        assert_eq!(c.stream.ram_mb, 8);
        assert_eq!(c.stream.budget_bytes(), 8 * 1024 * 1024);
        assert_eq!(c.stream.page_len, 512);
        assert_eq!(c.stream.reservoir_shards, 4);
        assert_eq!(c.stream.reservoir_per_shard, 128);
        assert_eq!(c.stream.drift_window, 256);
        assert_eq!(c.stream.drift_threshold, 0.5);
        // absent: disabled, defaults intact
        let d = ExperimentConfig::from_table(&toml::parse("").unwrap()).unwrap();
        assert!(!d.stream.enabled());
        assert_eq!(d.stream, crate::stream::StreamConfig::default());
        // bad values are rejected, not silently defaulted
        for bad in [
            "[stream]\nram_mb = 0\n",
            "[stream]\npage_len = 0\n",
            "[stream]\ndrift_threshold = -0.1\n",
            "[stream]\nspill_dir = 7\n",
        ] {
            let t = toml::parse(bad).unwrap();
            assert!(ExperimentConfig::from_table(&t).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn cost_source_defaults_to_analytic() {
        let c = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
        assert_eq!(c.cost.name(), "analytic");
        assert!(c.cost.profile().is_none());
        // the analytic cost model is exactly the paper default
        let m = c.cost_model();
        let reference = crate::perfmodel::CostModel::paper_default(&c.model);
        assert_eq!(m.hw.peak_flops, reference.hw.peak_flops);
        assert_eq!(m.comm.alpha_s_per_byte, reference.comm.alpha_s_per_byte);
        // a missing profile file is a clean error
        let t = toml::parse("[scheduler]\ncost_profile = \"/no/such/profile.json\"\n").unwrap();
        assert!(ExperimentConfig::from_table(&t).is_err());
        assert!(CostSource::calibrated("/no/such/profile.json").is_err());
    }

    #[test]
    fn calibrated_profile_must_match_the_experiment_model() {
        use crate::calib::{CalibratedProfile, Fit};
        let fit = Fit {
            slope: 1.0,
            intercept: 0.1,
            r2: 1.0,
            slope_stderr: 0.0,
            intercept_stderr: 0.0,
            n: 4,
            outliers_dropped: 0,
        };
        let profile = CalibratedProfile {
            version: crate::calib::fit::PROFILE_SCHEMA_VERSION,
            model: "qwen2.5-0.5b".into(),
            comp: fit.clone(),
            comm: fit.clone(),
            comm_inter: fit.clone(),
            inter_extrapolated: false,
            step_overhead_s: 1e-3,
            mem: Some(fit),
            records: 4,
        };
        let src = CostSource::Calibrated { path: "p.json".into(), profile };
        src.ensure_model("qwen2.5-0.5b").unwrap();
        let err = src.ensure_model("qwen2.5-7b").unwrap_err().to_string();
        assert!(err.contains("calibrated on"), "{err}");
        // analytic never cares
        CostSource::Analytic.ensure_model("anything").unwrap();
    }

    #[test]
    fn fixed_capacity_resolution_is_identity() {
        let c = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
        let r = c.resolve_capacity().unwrap();
        assert_eq!(r.bucket_size, c.bucket_size);
        assert_eq!(r.resolved_bucket_size().unwrap(), c.bucket_size);
    }

    #[test]
    fn hbm_derived_resolution_replaces_bucket_and_is_idempotent() {
        let mut c = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
        c.memory.source = CapacitySource::HbmDerived;
        let r = c.resolve_capacity().unwrap();
        assert_ne!(r.bucket_size, c.bucket_size);
        assert_eq!(r.bucket_size, c.mem_plan().derive_capacity().unwrap());
        // idempotent: resolving again changes nothing
        assert_eq!(r.resolve_capacity().unwrap().bucket_size, r.bucket_size);
        // infeasible budget is a clean error
        c.memory.hbm_gb = 0.5;
        assert!(matches!(
            c.resolve_capacity(),
            Err(crate::scheduler::SchedError::NoCapacity { .. })
        ));
    }

    #[test]
    fn cluster_topology_maps_paper_testbed() {
        let c = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
        let t = c.cluster.topology().unwrap();
        assert_eq!(t.total_gpus(), 32);
        assert!(!t.cp_group_crosses_nodes(0));
        let c7 = ExperimentConfig::paper_default(ModelSpec::qwen2_5_7b(), "chatqa2");
        assert!(c7.cluster.topology().unwrap().cp_group_crosses_nodes(0));
    }

    #[test]
    fn bad_model_name_errors() {
        let t = toml::parse("[model]\nname = \"gpt9\"\n").unwrap();
        assert!(ExperimentConfig::from_table(&t).is_err());
    }

    #[test]
    fn policy_round_trips() {
        for p in [
            Policy::Baseline,
            Policy::DacpOnly,
            Policy::Skrull,
            Policy::SkrullRefined,
            Policy::SortedBatching,
        ] {
            assert_eq!(Policy::by_name(p.name()), Some(p));
        }
    }
}
