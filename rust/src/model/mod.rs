//! Model specifications.  The scheduler and the performance model depend on
//! the model only through these shapes (Eq. 13/15 of the paper), so the
//! same code drives both the paper's Qwen2.5 configs (analytic/simulated)
//! and the tiny config actually trained end-to-end on CPU.

/// Transformer shape parameters, Qwen2.5-style (GQA + SwiGLU + tied head).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    pub vocab: u64,
    pub hidden: u64,
    pub layers: u64,
    pub heads: u64,
    pub kv_heads: u64,
    pub ffn: u64,
}

impl ModelSpec {
    pub fn head_dim(&self) -> u64 {
        self.hidden / self.heads
    }

    /// h_kv of Eq. 13/15: the key/value hidden dimension.
    pub fn kv_hidden(&self) -> u64 {
        self.kv_heads * self.head_dim()
    }

    /// Parameter count (tied embedding, no biases) — used for the gradient
    /// synchronization cost and the ZeRO-2 state estimate.
    pub fn num_params(&self) -> u64 {
        let h = self.hidden;
        let hkv = self.kv_hidden();
        let per_layer = h // ln1
            + h * h // wq
            + h * hkv * 2 // wk, wv
            + h * h // wo
            + h // ln2
            + 3 * h * self.ffn; // gate, up, down
        self.vocab * h + self.layers * per_layer + h
    }

    /// Qwen2.5-0.5B (paper's small evaluation model).
    pub fn qwen2_5_0_5b() -> Self {
        ModelSpec {
            name: "qwen2.5-0.5b",
            vocab: 151_936,
            hidden: 896,
            layers: 24,
            heads: 14,
            kv_heads: 2,
            ffn: 4864,
        }
    }

    /// Qwen2.5-7B (paper's large evaluation model).
    pub fn qwen2_5_7b() -> Self {
        ModelSpec {
            name: "qwen2.5-7b",
            vocab: 152_064,
            hidden: 3584,
            layers: 28,
            heads: 28,
            kv_heads: 4,
            ffn: 18_944,
        }
    }

    /// The tiny model compiled by python/compile/aot.py and trained for real
    /// in examples/long_sft_train.rs.  MUST stay in sync with model.TINY.
    pub fn tiny() -> Self {
        ModelSpec {
            name: "tiny",
            vocab: 512,
            hidden: 256,
            layers: 4,
            heads: 8,
            kv_heads: 2,
            ffn: 768,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "qwen2.5-0.5b" | "0.5b" => Some(Self::qwen2_5_0_5b()),
            "qwen2.5-7b" | "7b" => Some(Self::qwen2_5_7b()),
            "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen_param_counts_are_plausible() {
        // ~0.49B and ~7.6B with tied/untied caveats; we accept +-20%.
        let p05 = ModelSpec::qwen2_5_0_5b().num_params() as f64;
        assert!((0.35e9..0.65e9).contains(&p05), "{p05}");
        let p7 = ModelSpec::qwen2_5_7b().num_params() as f64;
        assert!((6.0e9..9.0e9).contains(&p7), "{p7}");
    }

    #[test]
    fn tiny_matches_python_manifest_count() {
        // python/compile/model.py reported 3_148_032 params for TINY.
        assert_eq!(ModelSpec::tiny().num_params(), 3_148_032);
    }

    #[test]
    fn kv_hidden() {
        let m = ModelSpec::qwen2_5_0_5b();
        assert_eq!(m.head_dim(), 64);
        assert_eq!(m.kv_hidden(), 128);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(ModelSpec::by_name("7b").unwrap().name, "qwen2.5-7b");
        assert!(ModelSpec::by_name("nope").is_none());
    }
}
