//! The fleet's job model: tenants with weights and admission quotas,
//! fine-tuning jobs (dataset distribution, sequence count, scheduling
//! policy, priority, dp×cp shape), and deterministic workload synthesis
//! under three arrival patterns (steady, bursty, heavy-tailed tenant
//! sizes).  Everything is a pure function of the seed — the fleet
//! simulator's inputs carry no wall-clock anywhere.

use crate::config::Policy;
use crate::rng::Rng;

/// One tenant sharing the cluster.
#[derive(Clone, Debug)]
pub struct Tenant {
    pub id: usize,
    /// Fair-share weight: the fairness metric divides each tenant's
    /// delivered service by this.
    pub weight: f64,
    /// Admission quota: maximum jobs this tenant may have in the system
    /// (queued + running) at once; arrivals beyond it are rejected.
    pub quota: usize,
}

/// One submitted fine-tuning job.
#[derive(Clone, Debug)]
pub struct FleetJob {
    pub id: u64,
    pub tenant: usize,
    /// Length-distribution name (`data::LengthDistribution::by_name`).
    pub dataset: &'static str,
    /// Data-parallel × context-parallel shape the job is built for; the
    /// placement engine decides which pool's nodes host it.
    pub dp: usize,
    pub cp: usize,
    pub batch_size: usize,
    pub iterations: usize,
    /// Synthesized dataset size (the tenant's corpus).
    pub seq_count: usize,
    /// Intra-job scheduling policy (the paper's axis).
    pub policy: Policy,
    /// Larger = more urgent; drives the priority queue discipline and
    /// iteration-boundary preemption.
    pub priority: u32,
    /// Simulated submit time, seconds from sweep start.
    pub submit_time: f64,
    pub seed: u64,
}

impl FleetJob {
    pub fn gpus(&self) -> usize {
        self.dp * self.cp
    }
}

/// How job arrivals are spread over simulated time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalPattern {
    /// Near-uniform inter-arrival gaps.
    Steady,
    /// Clustered bursts of 3–6 jobs separated by quiet spells.
    Bursty,
    /// Exponential-ish gaps with lognormal corpus sizes and one dominant
    /// tenant (heavy-tailed tenant sizes).
    HeavyTailed,
}

impl ArrivalPattern {
    pub const ALL: [ArrivalPattern; 3] =
        [ArrivalPattern::Steady, ArrivalPattern::Bursty, ArrivalPattern::HeavyTailed];

    pub fn by_name(s: &str) -> Option<ArrivalPattern> {
        match s {
            "steady" => Some(ArrivalPattern::Steady),
            "bursty" => Some(ArrivalPattern::Bursty),
            "heavy-tailed" => Some(ArrivalPattern::HeavyTailed),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalPattern::Steady => "steady",
            ArrivalPattern::Bursty => "bursty",
            ArrivalPattern::HeavyTailed => "heavy-tailed",
        }
    }
}

/// One synthesized fleet workload: tenants plus their submitted jobs,
/// sorted by (submit_time, id).
#[derive(Clone, Debug)]
pub struct Workload {
    pub pattern: ArrivalPattern,
    pub tenants: Vec<Tenant>,
    pub jobs: Vec<FleetJob>,
}

const DATASETS: [&str; 3] = ["wikipedia", "lmsys", "chatqa2"];
/// Job shapes on the 32-GPU build canvas, small jobs most common.
const SHAPES: [(usize, usize); 3] = [(1, 8), (2, 8), (4, 8)];
const SHAPE_WEIGHTS: [f64; 3] = [0.5, 0.3, 0.2];
const POLICIES: [Policy; 4] =
    [Policy::Baseline, Policy::DacpOnly, Policy::Skrull, Policy::SkrullRefined];

/// Synthesize a deterministic workload: `n_jobs` jobs from four tenants
/// under `pattern`.  Same (pattern, n_jobs, seed) → byte-identical
/// workload, so every placement policy and pool set of a sweep sees the
/// same arrivals.
pub fn synthesize(pattern: ArrivalPattern, n_jobs: usize, seed: u64) -> Workload {
    let mut rng = Rng::seed_from_u64(seed ^ 0xF1EE7);
    let tenants = vec![
        Tenant { id: 0, weight: 4.0, quota: 4 },
        Tenant { id: 1, weight: 2.0, quota: 3 },
        Tenant { id: 2, weight: 1.0, quota: 3 },
        Tenant { id: 3, weight: 1.0, quota: 2 },
    ];
    // the dominant tenant submits most heavy-tailed traffic; the other
    // patterns spread jobs more evenly
    let tenant_weights: [f64; 4] = match pattern {
        ArrivalPattern::HeavyTailed => [8.0, 2.0, 1.0, 1.0],
        _ => [3.0, 3.0, 2.0, 2.0],
    };
    let mut jobs = Vec::with_capacity(n_jobs);
    let mut t = 0.0f64;
    let mut burst_left = 0usize;
    for id in 0..n_jobs {
        match pattern {
            ArrivalPattern::Steady => t += 4.0 * (0.5 + rng.f64()),
            ArrivalPattern::Bursty => {
                if burst_left == 0 {
                    burst_left = 3 + rng.usize_below(4);
                    t += 14.0 + 8.0 * rng.f64();
                } else {
                    // burst members arrive back to back, a hair apart so
                    // event ordering stays unambiguous
                    t += 1e-3;
                }
                burst_left -= 1;
            }
            ArrivalPattern::HeavyTailed => {
                // inverse-CDF exponential gaps, mean 4s
                t += -(1.0 - rng.f64()).ln() * 4.0;
            }
        }
        let seq_count = match pattern {
            ArrivalPattern::HeavyTailed => rng.lognormal(7.2, 0.6).clamp(500.0, 6000.0) as usize,
            _ => 800 + rng.usize_below(1600),
        };
        let (dp, cp) = SHAPES[rng.weighted_index(&SHAPE_WEIGHTS)];
        jobs.push(FleetJob {
            id: id as u64,
            tenant: rng.weighted_index(&tenant_weights),
            dataset: DATASETS[rng.usize_below(DATASETS.len())],
            dp,
            cp,
            batch_size: if rng.bool_with(0.3) { 16 } else { 8 },
            iterations: 2 + rng.usize_below(3),
            seq_count,
            policy: POLICIES[rng.usize_below(POLICIES.len())],
            priority: rng.range_u32(0, 4),
            submit_time: t,
            seed: rng.next_u64(),
        });
    }
    Workload { pattern, tenants, jobs }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthesis_is_deterministic_and_sorted() {
        for pattern in ArrivalPattern::ALL {
            let a = synthesize(pattern, 40, 7);
            let b = synthesize(pattern, 40, 7);
            assert_eq!(a.jobs.len(), 40);
            assert_eq!(a.tenants.len(), 4);
            for (x, y) in a.jobs.iter().zip(&b.jobs) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.submit_time.to_bits(), y.submit_time.to_bits());
                assert_eq!(x.seed, y.seed);
            }
            // arrivals are already in submit order
            assert!(a.jobs.windows(2).all(|w| w[0].submit_time <= w[1].submit_time));
            assert!(a.jobs.iter().all(|j| j.gpus() <= 32 && j.iterations >= 2));
        }
    }

    #[test]
    fn seeds_change_the_workload() {
        let a = synthesize(ArrivalPattern::Steady, 20, 1);
        let b = synthesize(ArrivalPattern::Steady, 20, 2);
        assert!(a.jobs.iter().zip(&b.jobs).any(|(x, y)| x.seed != y.seed));
    }

    #[test]
    fn heavy_tail_concentrates_on_the_big_tenant() {
        let w = synthesize(ArrivalPattern::HeavyTailed, 200, 3);
        let big = w.jobs.iter().filter(|j| j.tenant == 0).count();
        assert!(big > 200 / 3, "dominant tenant got only {big}/200 jobs");
        assert!(w.jobs.iter().any(|j| j.seq_count > 3000), "no heavy corpus in the tail");
    }

    #[test]
    fn pattern_names_round_trip() {
        for p in ArrivalPattern::ALL {
            assert_eq!(ArrivalPattern::by_name(p.name()), Some(p));
        }
        assert_eq!(ArrivalPattern::by_name("poisson"), None);
    }
}
