//! Multi-tenant fleet scheduling over build-once/price-many placement.
//!
//! The Skrull run engine separates *building* a run (all GDS/DACP
//! scheduling work, `cluster::run::build_run`) from *pricing* it on a
//! topology (`price_run`).  This subsystem lifts that split to cluster
//! scale: tenants submit fine-tuning jobs ([`job`]), a queue discipline
//! decides what starts next ([`queue`]), a placement engine prices each
//! job's single `BuiltRun` against every pool that could host it
//! ([`placement`]), and a deterministic discrete-event loop advances
//! starts, iteration-boundary preemptions and finishes in simulated time
//! ([`sim`]).  The `bench::fleet` sweep drives it across arrival
//! patterns × queue policies × pool topologies.

pub mod job;
pub mod placement;
pub mod queue;
pub mod sim;

pub use job::{synthesize, ArrivalPattern, FleetJob, Tenant, Workload};
pub use placement::{Candidate, ClusterSpec, PlacementEngine, PoolSpec};
pub use queue::{pick_next, FleetPolicy, QueueEntry};
pub use sim::{
    simulate, FleetCore, FleetEvent, FleetReport, ResumeError, ResumePoint, SimOptions,
    TenantStats, RESUME_POINT_LEN,
};
