//! The deterministic discrete-event fleet core.  Tenants submit jobs
//! (admission-controlled by per-tenant quota), the queue discipline
//! picks what runs next, the placement engine prices each job's single
//! `BuiltRun` against every pool that could host it, and the event loop
//! advances start / iteration-boundary-preemption / finish events in
//! purely simulated time.  Preempted jobs carry their progress through a
//! checksummed `ResumePoint` codec (the `coordinator::state` checkpoint
//! idiom), so a resumed job re-prices only its remaining iterations.
//!
//! [`FleetCore`] is the incremental engine shared by the batch
//! [`simulate`] wrapper and the `serve` daemon: `submit` one job at a
//! time, `step_until` a deadline, `drain`, then `finish_report`.  The
//! daemon additionally records every decision as a [`FleetEvent`] for
//! its write-ahead journal — the batch path and the daemon run the
//! *same* code, which is what makes their outputs byte-identical.
//!
//! Nothing here reads a wall clock: the same workload, policy and pool
//! set produce bit-identical reports on any machine at any parallelism.

use std::fmt;

use crate::cluster::run::{build_run, BuiltRun, RunConfig};
use crate::config::ExperimentConfig;
use crate::coordinator::state::fnv1a;
use crate::data::{Dataset, LengthDistribution};
use crate::fleet::job::{FleetJob, Tenant, Workload};
use crate::fleet::placement::{Candidate, ClusterSpec, PlacementEngine};
use crate::fleet::queue::{pick_next, FleetPolicy, QueueEntry};
use crate::model::ModelSpec;
use crate::perfmodel::CostModel;
use crate::util::error::{Context, Result};
use crate::util::stats::Summary;

/// Pinned per-invocation scheduler cost, so simulated durations never
/// depend on the host machine (same convention as `bench::e2e`).
pub const DETERMINISTIC_SCHED_SECONDS: f64 = 1e-6;

const RESUME_MAGIC: [u8; 8] = *b"SKRLFLT\0";
const RESUME_VERSION: u32 = 1;

/// Exact encoded size of a [`ResumePoint`]: magic + version + job_id +
/// done_iters + service + wait + CRC.  `decode` rejects any other length.
pub const RESUME_POINT_LEN: usize = 8 + 4 + 8 + 4 + 8 + 8 + 8;

/// Progress a preempted job carries back into the queue: iterations
/// done plus the service/wait it accrued, guarded by magic, version and
/// an FNV-1a checksum exactly like the trainer's checkpoints.
#[derive(Clone, Debug, PartialEq)]
pub struct ResumePoint {
    pub job_id: u64,
    pub done_iters: u32,
    pub service_seconds: f64,
    pub wait_seconds: f64,
}

/// Structured decode failure — never a panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ResumeError {
    Truncated { need: usize, have: usize },
    BadMagic,
    BadVersion(u32),
    BadChecksum { expected: u64, found: u64 },
    /// Trailing bytes after a checksum-valid encoding (or any length
    /// mismatch the field reads did not already catch).
    BadLength { expected: usize, got: usize },
}

impl fmt::Display for ResumeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ResumeError::Truncated { need, have } => {
                write!(f, "resume point truncated: need {need} bytes, have {have}")
            }
            ResumeError::BadMagic => write!(f, "resume point has wrong magic"),
            ResumeError::BadVersion(v) => write!(f, "unsupported resume point version {v}"),
            ResumeError::BadChecksum { expected, found } => {
                write!(
                    f,
                    "resume point checksum mismatch: expected {expected:#x}, found {found:#x}"
                )
            }
            ResumeError::BadLength { expected, got } => {
                write!(f, "resume point length {got} != {expected}")
            }
        }
    }
}

impl std::error::Error for ResumeError {}

fn take<const N: usize>(bytes: &[u8], off: usize) -> Result<[u8; N], ResumeError> {
    match bytes.get(off..off + N) {
        Some(s) => {
            let mut out = [0u8; N];
            out.copy_from_slice(s);
            Ok(out)
        }
        None => Err(ResumeError::Truncated { need: off + N, have: bytes.len() }),
    }
}

impl ResumePoint {
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(RESUME_POINT_LEN);
        buf.extend_from_slice(&RESUME_MAGIC);
        buf.extend_from_slice(&RESUME_VERSION.to_le_bytes());
        buf.extend_from_slice(&self.job_id.to_le_bytes());
        buf.extend_from_slice(&self.done_iters.to_le_bytes());
        buf.extend_from_slice(&self.service_seconds.to_le_bytes());
        buf.extend_from_slice(&self.wait_seconds.to_le_bytes());
        let crc = fnv1a(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf
    }

    pub fn decode(bytes: &[u8]) -> Result<ResumePoint, ResumeError> {
        let magic: [u8; 8] = take(bytes, 0)?;
        if magic != RESUME_MAGIC {
            return Err(ResumeError::BadMagic);
        }
        let version = u32::from_le_bytes(take(bytes, 8)?);
        if version != RESUME_VERSION {
            return Err(ResumeError::BadVersion(version));
        }
        let job_id = u64::from_le_bytes(take(bytes, 12)?);
        let done_iters = u32::from_le_bytes(take(bytes, 20)?);
        let service_seconds = f64::from_le_bytes(take(bytes, 24)?);
        let wait_seconds = f64::from_le_bytes(take(bytes, 32)?);
        let found = u64::from_le_bytes(take(bytes, 40)?);
        let expected = fnv1a(&bytes[..40]);
        if found != expected {
            return Err(ResumeError::BadChecksum { expected, found });
        }
        // reject trailing garbage after an otherwise valid encoding (the
        // old decode silently accepted it, so a mis-framed journal record
        // could smuggle extra bytes through)
        if bytes.len() != RESUME_POINT_LEN {
            return Err(ResumeError::BadLength { expected: RESUME_POINT_LEN, got: bytes.len() });
        }
        Ok(ResumePoint { job_id, done_iters, service_seconds, wait_seconds })
    }
}

/// Simulator knobs (the workload supplies everything else).
#[derive(Clone, Debug)]
pub struct SimOptions {
    pub policy: FleetPolicy,
    pub cluster: ClusterSpec,
    /// Forwarded to `RunConfig::serial_scheduler` when fleet cells fan
    /// out across worker threads (same rule as the e2e sweep).
    pub serial_scheduler: bool,
}

/// Per-tenant accounting for the fairness and quota gates.
#[derive(Clone, Debug, Default)]
pub struct TenantStats {
    pub submitted: usize,
    pub admitted: usize,
    pub rejected: usize,
    pub finished: usize,
    pub service_seconds: f64,
    /// High-water mark of this tenant's queued + running jobs; the quota
    /// property test asserts it never exceeds the tenant's quota.
    pub peak_in_flight: usize,
}

/// What one simulated fleet run produced.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub policy: FleetPolicy,
    pub cluster: &'static str,
    pub submitted: usize,
    pub admitted: usize,
    pub rejected: usize,
    pub finished: usize,
    pub preemptions: usize,
    /// Jobs dropped because node loss left no pool that could ever host
    /// their shape (zero unless the daemon injected node loss).
    pub evicted: usize,
    /// `build_run` invocations — exactly one per admitted job.
    pub builds: usize,
    /// `price_run` invocations — many per build.
    pub pricings: usize,
    pub max_builds_per_job: usize,
    /// Dispatches under `Priority` that passed over a strictly
    /// higher-priority placeable entry (must stay zero).
    pub priority_inversions: usize,
    pub makespan: f64,
    /// Busy GPU-seconds over total GPU-seconds to makespan.
    pub utilization: f64,
    /// Max over min weighted tenant service (1.0 if fewer than two
    /// tenants finished anything).
    pub fairness_ratio: f64,
    /// Total queue wait per finished job.
    pub queue_wait: Summary,
    pub tenants: Vec<TenantStats>,
}

/// One scheduling decision, in the order the core made it.  The serve
/// daemon journals the canonical encoding of every event and recovery
/// replay byte-compares recomputed events against the journal — "the
/// daemon must never out-decide the simulator" is checked per event.
#[derive(Clone, Debug, PartialEq)]
pub enum FleetEvent {
    Arrival { job_id: u64, at: f64 },
    Admit { job_id: u64 },
    Reject { job_id: u64 },
    Dispatch { job_id: u64, pool: u64, nodes: u64, finish: f64 },
    Preempt { job_id: u64, done_iters: u32, at: f64 },
    Complete { job_id: u64, at: f64, wait: f64 },
    Evict { job_id: u64, at: f64 },
}

impl FleetEvent {
    /// Append the canonical binary form (tag byte + little-endian fields,
    /// f64 as raw bits) to `buf`.  This layout is part of the journal
    /// format: recovery compares these bytes, so bit-exact f64 encoding
    /// matters.
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        match self {
            FleetEvent::Arrival { job_id, at } => {
                buf.push(1);
                buf.extend_from_slice(&job_id.to_le_bytes());
                buf.extend_from_slice(&at.to_le_bytes());
            }
            FleetEvent::Admit { job_id } => {
                buf.push(2);
                buf.extend_from_slice(&job_id.to_le_bytes());
            }
            FleetEvent::Reject { job_id } => {
                buf.push(3);
                buf.extend_from_slice(&job_id.to_le_bytes());
            }
            FleetEvent::Dispatch { job_id, pool, nodes, finish } => {
                buf.push(4);
                buf.extend_from_slice(&job_id.to_le_bytes());
                buf.extend_from_slice(&pool.to_le_bytes());
                buf.extend_from_slice(&nodes.to_le_bytes());
                buf.extend_from_slice(&finish.to_le_bytes());
            }
            FleetEvent::Preempt { job_id, done_iters, at } => {
                buf.push(5);
                buf.extend_from_slice(&job_id.to_le_bytes());
                buf.extend_from_slice(&done_iters.to_le_bytes());
                buf.extend_from_slice(&at.to_le_bytes());
            }
            FleetEvent::Complete { job_id, at, wait } => {
                buf.push(6);
                buf.extend_from_slice(&job_id.to_le_bytes());
                buf.extend_from_slice(&at.to_le_bytes());
                buf.extend_from_slice(&wait.to_le_bytes());
            }
            FleetEvent::Evict { job_id, at } => {
                buf.push(7);
                buf.extend_from_slice(&job_id.to_le_bytes());
                buf.extend_from_slice(&at.to_le_bytes());
            }
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        self.encode_into(&mut buf);
        buf
    }
}

/// One placed job occupying nodes.
pub(crate) struct Running {
    pub(crate) job: usize,
    pub(crate) pool: usize,
    pub(crate) nodes: usize,
    pub(crate) gpus: usize,
    pub(crate) start: f64,
    /// Iterations completed before this placement.
    pub(crate) done_before: usize,
    /// Absolute completion time of each remaining iteration.
    pub(crate) iter_ends: Vec<f64>,
    pub(crate) finish: f64,
    /// Next event for this machine: the finish, or an earlier preemption
    /// boundary once a preemption is pending.
    pub(crate) event_time: f64,
    /// Index into `iter_ends` where a pending preemption takes effect.
    pub(crate) preempt_at: Option<usize>,
    pub(crate) wait_so_far: f64,
    pub(crate) service_so_far: f64,
}

enum Event {
    Arrival,
    Machine(usize),
    Idle,
}

/// Pick the earliest pending event: machine events (finish/preempt) by
/// time, lowest job id on ties, and at equal times machines fire before
/// the next arrival.  `next_arrival` is `f64::INFINITY` once the
/// workload is exhausted.
///
/// Hot path: called once per simulated event; index scan, no allocation.
fn next_event(running: &[Running], next_arrival: f64) -> Event {
    let mut best: Option<usize> = None;
    let mut i = 0;
    while i < running.len() {
        match best {
            Some(b) => {
                let ord = running[i].event_time.total_cmp(&running[b].event_time);
                if ord == core::cmp::Ordering::Less
                    || (ord == core::cmp::Ordering::Equal && running[i].job < running[b].job)
                {
                    best = Some(i);
                }
            }
            None => best = Some(i),
        }
        i += 1;
    }
    match best {
        Some(b) => {
            if running[b].event_time.total_cmp(&next_arrival) == core::cmp::Ordering::Greater {
                Event::Arrival
            } else {
                Event::Machine(b)
            }
        }
        None if next_arrival.is_finite() => Event::Arrival,
        None => Event::Idle,
    }
}

/// The incremental fleet engine.  Owns its jobs and tenants so the serve
/// daemon can feed it submissions one control-plane record at a time;
/// [`simulate`] is a thin batch wrapper over the same methods, which is
/// what makes daemon replay and batch simulation byte-identical.
pub struct FleetCore {
    pub(crate) opts: SimOptions,
    pub(crate) tenant_specs: Vec<Tenant>,
    pub(crate) jobs: Vec<FleetJob>,
    cost: CostModel,
    pub(crate) engine: PlacementEngine,
    pub(crate) builts: Vec<Option<BuiltRun>>,
    pub(crate) build_counts: Vec<usize>,
    /// Set per job on snapshot restore: the next `ensure_built` is a
    /// cache refill of an already-counted build, not a new scheduling
    /// pass (keeps the build-once gate honest across restarts).
    pub(crate) refill: Vec<bool>,
    pub(crate) queue: Vec<QueueEntry>,
    pub(crate) running: Vec<Running>,
    pub(crate) in_system: Vec<usize>,
    pub(crate) tenants: Vec<TenantStats>,
    pub(crate) queue_wait: Summary,
    pub(crate) busy_gpu_seconds: f64,
    pub(crate) pricings: usize,
    pub(crate) preemptions: usize,
    pub(crate) priority_inversions: usize,
    pub(crate) finished: usize,
    pub(crate) admitted: usize,
    pub(crate) rejected: usize,
    pub(crate) evicted: usize,
    pub(crate) last_finish: f64,
    /// The core's simulated clock: the latest submit / machine-event /
    /// node-loss time processed.  Inputs must be non-decreasing in time.
    pub(crate) now: f64,
    record_events: bool,
    events: Vec<FleetEvent>,
}

impl FleetCore {
    pub fn new(tenants: Vec<Tenant>, opts: SimOptions) -> FleetCore {
        let engine = PlacementEngine::new(&opts.cluster);
        let cost =
            ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia").cost_model();
        let n_tenants = tenants.len();
        FleetCore {
            opts,
            tenant_specs: tenants,
            jobs: Vec::new(),
            cost,
            engine,
            builts: Vec::new(),
            build_counts: Vec::new(),
            refill: Vec::new(),
            queue: Vec::new(),
            running: Vec::new(),
            in_system: vec![0; n_tenants],
            tenants: vec![TenantStats::default(); n_tenants],
            queue_wait: Summary::new(),
            busy_gpu_seconds: 0.0,
            pricings: 0,
            preemptions: 0,
            priority_inversions: 0,
            finished: 0,
            admitted: 0,
            rejected: 0,
            evicted: 0,
            last_finish: 0.0,
            now: 0.0,
            record_events: false,
            events: Vec::new(),
        }
    }

    /// Record every decision as a [`FleetEvent`] (drained via
    /// [`FleetCore::take_events`]).  Off by default — the batch simulator
    /// has no journal to feed.
    pub fn set_record_events(&mut self, on: bool) {
        self.record_events = on;
    }

    /// Drain the recorded events in decision order.
    pub fn take_events(&mut self) -> Vec<FleetEvent> {
        std::mem::take(&mut self.events)
    }

    pub fn queued_jobs(&self) -> usize {
        self.queue.len()
    }

    pub fn running_jobs(&self) -> usize {
        self.running.len()
    }

    fn emit(&mut self, ev: FleetEvent) {
        if self.record_events {
            self.events.push(ev);
        }
    }

    /// Advance machine events (finishes, preemption boundaries) up to and
    /// including simulated time `t`.  At `t` itself machines fire before
    /// any arrival, matching the batch event loop's tie rule.
    pub fn step_until(&mut self, t: f64) -> Result<()> {
        loop {
            match next_event(&self.running, t) {
                Event::Machine(mi) => self.machine_event(mi)?,
                Event::Arrival | Event::Idle => return Ok(()),
            }
        }
    }

    /// Run every pending machine event to quiescence.
    pub fn drain(&mut self) -> Result<()> {
        self.step_until(f64::INFINITY)
    }

    /// Submit one job at simulated time `now` (non-decreasing across
    /// calls).  A job whose shape fits no pool — possible after node
    /// loss — is rejected like a quota violation, never an error: the
    /// daemon degrades gracefully.
    pub fn submit(&mut self, job: FleetJob, now: f64) -> Result<()> {
        crate::ensure!(
            job.tenant < self.tenant_specs.len(),
            "job {} names tenant {} of {}",
            job.id,
            job.tenant,
            self.tenant_specs.len()
        );
        crate::ensure!(
            now >= self.now,
            "job {} arrives at {now}, before the core's clock {}",
            job.id,
            self.now
        );
        self.now = now;
        let job_idx = self.jobs.len();
        self.jobs.push(job);
        self.builts.push(None);
        self.build_counts.push(0);
        self.refill.push(false);
        self.arrive(job_idx, now)
    }

    /// Schedule (GDS/DACP) the job exactly once; every later placement
    /// decision reprices this artifact.
    fn ensure_built(&mut self, job_idx: usize) -> Result<()> {
        if self.builts[job_idx].is_some() {
            return Ok(());
        }
        let job = self.jobs[job_idx].clone();
        let mut cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), job.dataset);
        cfg.cluster.dp = job.dp;
        cfg.cluster.cp = job.cp;
        cfg.cluster.batch_size = job.batch_size;
        cfg.policy = job.policy;
        cfg.seed = job.seed;
        cfg.pipelined = true;
        let cfg = cfg
            .resolve_capacity()
            .with_context(|| format!("job {}: capacity resolution failed", job.id))?;
        let dist = LengthDistribution::by_name(job.dataset)
            .ok_or_else(|| crate::anyhow!("job {}: unknown dataset {}", job.id, job.dataset))?;
        let ds = Dataset::synthesize(&dist, job.seq_count, job.seed)
            .truncated(cfg.bucket_size * job.cp as u32);
        let mut run = RunConfig::new(job.iterations, true);
        run.serial_scheduler = self.opts.serial_scheduler;
        let mut built = build_run(&ds, &cfg, &run)
            .with_context(|| format!("job {}: schedule build failed", job.id))?;
        built.pin_sched_seconds(DETERMINISTIC_SCHED_SECONDS);
        self.builts[job_idx] = Some(built);
        if self.refill[job_idx] {
            // rebuilding a schedule the pre-restart process already built
            // and counted — a cache refill, not a second scheduling pass
            self.refill[job_idx] = false;
        } else {
            self.build_counts[job_idx] += 1;
        }
        Ok(())
    }

    /// Price entry `queue[qi]`'s remaining iterations on every pool and
    /// keep the policy-preferred candidate.
    fn best_candidate(&mut self, qi: usize) -> Result<Option<Candidate>> {
        let job_idx = self.queue[qi].job;
        self.ensure_built(job_idx)?;
        let done = self.queue[qi].done_iters;
        let built = self.builts[job_idx]
            .as_ref()
            .ok_or_else(|| crate::anyhow!("job {job_idx} vanished from the build cache"))?;
        let mut cands = Vec::new();
        self.pricings += self.engine.candidates(built, &self.cost, done, &mut cands)?;
        let best_fit = self.opts.policy == FleetPolicy::BestFitPrice;
        let mut best: Option<Candidate> = None;
        for c in cands {
            let better = match &best {
                None => true,
                Some(b) => {
                    if best_fit && c.waste_gpus != b.waste_gpus {
                        c.waste_gpus < b.waste_gpus
                    } else {
                        c.seconds.total_cmp(&b.seconds) == core::cmp::Ordering::Less
                    }
                }
            };
            if better {
                best = Some(c);
            }
        }
        Ok(best)
    }

    /// Start queued jobs while the policy and free nodes allow.
    fn dispatch(&mut self, now: f64) -> Result<()> {
        loop {
            if self.queue.is_empty() {
                return Ok(());
            }
            let n = self.queue.len();
            let mut feasible = Vec::with_capacity(n);
            let mut secs = Vec::with_capacity(n);
            let mut prios = Vec::with_capacity(n);
            let mut chosen: Vec<Option<Candidate>> = Vec::with_capacity(n);
            for qi in 0..n {
                let cand = self.best_candidate(qi)?;
                feasible.push(cand.is_some());
                secs.push(cand.as_ref().map_or(f64::INFINITY, |c| c.seconds));
                prios.push(self.jobs[self.queue[qi].job].priority);
                chosen.push(cand);
            }
            let Some(qi) = pick_next(self.opts.policy, &feasible, &secs, &prios) else {
                return Ok(());
            };
            if self.opts.policy == FleetPolicy::Priority {
                self.priority_inversions += (0..n)
                    .filter(|&i| feasible[i] && prios[i] > prios[qi])
                    .count();
            }
            let cand = chosen
                .swap_remove(qi)
                .ok_or_else(|| crate::anyhow!("policy picked an infeasible entry"))?;
            self.start(qi, cand, now)?;
        }
    }

    fn start(&mut self, qi: usize, cand: Candidate, now: f64) -> Result<()> {
        let mut entry = self.queue.remove(qi);
        let (job_id, gpus) = {
            let j = &self.jobs[entry.job];
            (j.id, j.gpus())
        };
        // a preempted job's progress must round-trip the resume codec
        // intact before it re-enters service
        if let Some(bytes) = entry.resume.take() {
            let point = ResumePoint::decode(&bytes)
                .with_context(|| format!("job {job_id}: corrupt resume point"))?;
            crate::ensure!(
                point.job_id == job_id
                    && point.done_iters as usize == entry.done_iters
                    && point.service_seconds.to_bits() == entry.service_so_far.to_bits()
                    && point.wait_seconds.to_bits() == entry.wait_so_far.to_bits(),
                "job {job_id}: resume point disagrees with queue entry"
            );
        }
        crate::ensure!(!cand.per_iter.is_empty(), "job {job_id} has no remaining iterations");
        entry.wait_so_far += now - entry.enqueued_at;
        self.engine.allocate(&cand)?;
        let mut iter_ends = Vec::with_capacity(cand.per_iter.len());
        let mut t = now;
        for d in &cand.per_iter {
            t += d;
            iter_ends.push(t);
        }
        let finish = t;
        self.emit(FleetEvent::Dispatch {
            job_id,
            pool: cand.pool as u64,
            nodes: cand.nodes as u64,
            finish,
        });
        self.running.push(Running {
            job: entry.job,
            pool: cand.pool,
            nodes: cand.nodes,
            gpus,
            start: now,
            done_before: entry.done_iters,
            iter_ends,
            finish,
            event_time: finish,
            preempt_at: None,
            wait_so_far: entry.wait_so_far,
            service_so_far: entry.service_so_far,
        });
        Ok(())
    }

    /// Under `Priority`, make room for a placeable-nowhere arrival by
    /// preempting the weakest strictly-lower-priority running job at its
    /// next iteration boundary (one victim per arrival, no cascades).
    fn preempt_for(&mut self, arriving_priority: u32, now: f64) {
        let mut victim: Option<usize> = None;
        for (i, r) in self.running.iter().enumerate() {
            if r.preempt_at.is_some() {
                continue;
            }
            let prio = self.jobs[r.job].priority;
            if prio >= arriving_priority {
                continue;
            }
            // first boundary strictly after now that is not the finish
            let has_boundary = r
                .iter_ends
                .iter()
                .take(r.iter_ends.len().saturating_sub(1))
                .any(|&b| b > now);
            if !has_boundary {
                continue;
            }
            let better = match victim {
                None => true,
                Some(v) => {
                    let vp = self.jobs[self.running[v].job].priority;
                    prio < vp || (prio == vp && r.job < self.running[v].job)
                }
            };
            if better {
                victim = Some(i);
            }
        }
        if let Some(v) = victim {
            let r = &mut self.running[v];
            let last = r.iter_ends.len() - 1;
            for (j, &b) in r.iter_ends.iter().enumerate() {
                if b > now && j < last {
                    r.preempt_at = Some(j);
                    r.event_time = b;
                    break;
                }
            }
        }
    }

    fn arrive(&mut self, job_idx: usize, now: f64) -> Result<()> {
        let (job_id, tenant, priority, dp, cp) = {
            let j = &self.jobs[job_idx];
            (j.id, j.tenant, j.priority, j.dp, j.cp)
        };
        self.emit(FleetEvent::Arrival { job_id, at: now });
        self.tenants[tenant].submitted += 1;
        let quota = self.tenant_specs[tenant].quota;
        if self.in_system[tenant] >= quota || !self.engine.placeable(dp, cp) {
            self.rejected += 1;
            self.tenants[tenant].rejected += 1;
            self.emit(FleetEvent::Reject { job_id });
            return Ok(());
        }
        self.admitted += 1;
        self.tenants[tenant].admitted += 1;
        self.in_system[tenant] += 1;
        self.tenants[tenant].peak_in_flight =
            self.tenants[tenant].peak_in_flight.max(self.in_system[tenant]);
        self.emit(FleetEvent::Admit { job_id });
        self.queue.push(QueueEntry {
            job: job_idx,
            enqueued_at: now,
            done_iters: 0,
            resume: None,
            wait_so_far: 0.0,
            service_so_far: 0.0,
        });
        self.dispatch(now)?;
        if self.opts.policy == FleetPolicy::Priority {
            if let Some(qi) = self.queue.iter().position(|e| e.job == job_idx) {
                if self.best_candidate(qi)?.is_none() {
                    self.preempt_for(priority, now);
                }
            }
        }
        Ok(())
    }

    fn machine_event(&mut self, mi: usize) -> Result<()> {
        let r = self.running.swap_remove(mi);
        let now = r.event_time;
        self.now = now;
        let (job_id, tenant, iterations) = {
            let j = &self.jobs[r.job];
            (j.id, j.tenant, j.iterations)
        };
        let segment = now - r.start;
        self.busy_gpu_seconds += r.gpus as f64 * segment;
        self.tenants[tenant].service_seconds += segment;
        self.engine.release(r.pool, r.nodes)?;
        match r.preempt_at {
            Some(j) => {
                self.preemptions += 1;
                let done_iters = r.done_before + j + 1;
                crate::ensure!(
                    done_iters < iterations,
                    "job {job_id} preempted past its final iteration"
                );
                let service = r.service_so_far + segment;
                let point = ResumePoint {
                    job_id,
                    done_iters: done_iters as u32,
                    service_seconds: service,
                    wait_seconds: r.wait_so_far,
                };
                self.emit(FleetEvent::Preempt {
                    job_id,
                    done_iters: done_iters as u32,
                    at: now,
                });
                self.queue.push(QueueEntry {
                    job: r.job,
                    enqueued_at: now,
                    done_iters,
                    resume: Some(point.encode()),
                    wait_so_far: r.wait_so_far,
                    service_so_far: service,
                });
            }
            None => {
                self.finished += 1;
                self.tenants[tenant].finished += 1;
                self.in_system[tenant] -= 1;
                self.queue_wait.push(r.wait_so_far);
                self.last_finish = self.last_finish.max(r.finish);
                self.emit(FleetEvent::Complete { job_id, at: now, wait: r.wait_so_far });
            }
        }
        self.dispatch(now)
    }

    /// Forcibly preempt `running[mi]` at time `now` (a node-loss victim,
    /// not an iteration boundary): account the elapsed segment, keep only
    /// fully completed iterations, and re-queue the remainder behind a
    /// checksummed resume point.
    fn preempt_now(&mut self, mi: usize, now: f64) -> Result<()> {
        let r = self.running.swap_remove(mi);
        let (job_id, tenant, iterations) = {
            let j = &self.jobs[r.job];
            (j.id, j.tenant, j.iterations)
        };
        let segment = now - r.start;
        self.busy_gpu_seconds += r.gpus as f64 * segment;
        self.tenants[tenant].service_seconds += segment;
        self.engine.release(r.pool, r.nodes)?;
        self.preemptions += 1;
        // a partially executed iteration is lost; boundaries at exactly
        // `now` count as completed (the finish itself cannot be ≤ now —
        // step_until fired those machine events already)
        let completed = r.iter_ends.iter().filter(|&&b| b <= now).count();
        let done_iters = r.done_before + completed;
        crate::ensure!(
            done_iters < iterations,
            "job {job_id} lost its node after its final iteration"
        );
        let service = r.service_so_far + segment;
        let point = ResumePoint {
            job_id,
            done_iters: done_iters as u32,
            service_seconds: service,
            wait_seconds: r.wait_so_far,
        };
        self.emit(FleetEvent::Preempt { job_id, done_iters: done_iters as u32, at: now });
        self.queue.push(QueueEntry {
            job: r.job,
            enqueued_at: now,
            done_iters,
            resume: Some(point.encode()),
            wait_so_far: r.wait_so_far,
            service_so_far: service,
        });
        Ok(())
    }

    /// Permanently lose `n` nodes of pool `pool` at simulated time `now`.
    /// Running victims (lowest job id first) are preempted mid-iteration
    /// and re-queued behind their resume points for placement on the
    /// surviving pools; queued jobs whose shape no longer fits any pool
    /// are evicted (counted, evented, never an error).
    pub fn lose_nodes(&mut self, pool: usize, n: usize, now: f64) -> Result<()> {
        crate::ensure!(
            pool < self.engine.pools.len(),
            "node loss names pool {pool} of {}",
            self.engine.pools.len()
        );
        crate::ensure!(
            now >= self.now,
            "node loss at {now}, before the core's clock {}",
            self.now
        );
        self.now = now;
        let lose = n.min(self.engine.pools[pool].nodes);
        if lose == 0 {
            return Ok(());
        }
        // vacate busy nodes until the loss can be taken from free ones:
        // victims in lowest-job-id order for determinism
        while self.engine.free_nodes(pool) < lose {
            let mut victim: Option<usize> = None;
            for (i, r) in self.running.iter().enumerate() {
                if r.pool != pool {
                    continue;
                }
                let better = match victim {
                    None => true,
                    Some(v) => r.job < self.running[v].job,
                };
                if better {
                    victim = Some(i);
                }
            }
            let Some(vi) = victim else { break };
            self.preempt_now(vi, now)?;
        }
        crate::ensure!(
            self.engine.free_nodes(pool) >= lose,
            "pool {pool} still has only {} free nodes after vacating all jobs",
            self.engine.free_nodes(pool)
        );
        self.engine.remove_nodes(pool, lose)?;
        // evict queued jobs (including just-vacated victims) whose shape
        // no longer fits anywhere
        let mut qi = 0;
        while qi < self.queue.len() {
            let (job_id, tenant, dp, cp) = {
                let j = &self.jobs[self.queue[qi].job];
                (j.id, j.tenant, j.dp, j.cp)
            };
            if self.engine.placeable(dp, cp) {
                qi += 1;
            } else {
                self.queue.remove(qi);
                self.in_system[tenant] -= 1;
                self.evicted += 1;
                self.emit(FleetEvent::Evict { job_id, at: now });
            }
        }
        self.dispatch(now)
    }

    /// Close the books: every conservation / build-once / utilization
    /// gate of the batch simulator, then the report.
    pub fn finish_report(&self) -> Result<FleetReport> {
        let n_jobs = self.jobs.len();
        crate::ensure!(n_jobs > 0, "empty workload");
        crate::ensure!(
            self.queue.is_empty(),
            "fleet went idle with {} queued jobs",
            self.queue.len()
        );
        crate::ensure!(self.running.is_empty(), "{} jobs still running", self.running.len());
        crate::ensure!(
            self.admitted + self.rejected == n_jobs
                && self.finished + self.evicted == self.admitted,
            "conservation violated: {} submitted, {} admitted, {} rejected, {} finished, {} evicted",
            n_jobs,
            self.admitted,
            self.rejected,
            self.finished,
            self.evicted
        );
        let builds: usize = self.build_counts.iter().sum();
        let max_builds_per_job = self.build_counts.iter().copied().max().unwrap_or(0);
        crate::ensure!(
            max_builds_per_job <= 1 && builds == self.admitted,
            "build-once violated: {builds} builds for {} admitted jobs (max {max_builds_per_job})",
            self.admitted
        );
        crate::ensure!(self.finished > 0, "no job finished");
        let makespan = self.last_finish;
        let total_gpus = self.opts.cluster.total_gpus();
        let utilization = self.busy_gpu_seconds / (total_gpus as f64 * makespan);
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        let mut served = 0usize;
        for (t, stats) in self.tenant_specs.iter().zip(&self.tenants) {
            if stats.finished == 0 {
                continue;
            }
            served += 1;
            let weighted = stats.service_seconds / t.weight;
            lo = lo.min(weighted);
            hi = hi.max(weighted);
        }
        let fairness_ratio = if served >= 2 { hi / lo } else { 1.0 };
        Ok(FleetReport {
            policy: self.opts.policy,
            cluster: self.opts.cluster.name,
            submitted: n_jobs,
            admitted: self.admitted,
            rejected: self.rejected,
            finished: self.finished,
            preemptions: self.preemptions,
            evicted: self.evicted,
            builds,
            pricings: self.pricings,
            max_builds_per_job,
            priority_inversions: self.priority_inversions,
            makespan,
            utilization,
            fairness_ratio,
            queue_wait: self.queue_wait.clone(),
            tenants: self.tenants.clone(),
        })
    }
}

/// Run the fleet to completion and account for every job.
pub fn simulate(workload: &Workload, opts: &SimOptions) -> Result<FleetReport> {
    crate::ensure!(!workload.jobs.is_empty(), "empty workload");
    let probe = PlacementEngine::new(&opts.cluster);
    for job in &workload.jobs {
        crate::ensure!(
            probe.placeable(job.dp, job.cp),
            "job {} shape {}x{} fits no pool of {}",
            job.id,
            job.dp,
            job.cp,
            opts.cluster.name
        );
    }
    let mut core = FleetCore::new(workload.tenants.clone(), opts.clone());
    for job in &workload.jobs {
        let t = job.submit_time;
        core.step_until(t)?;
        core.submit(job.clone(), t)?;
    }
    core.drain()?;
    core.finish_report()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::job::{synthesize, ArrivalPattern};

    fn run(pattern: ArrivalPattern, policy: FleetPolicy, cluster: &str, n: usize) -> FleetReport {
        let workload = synthesize(pattern, n, 11);
        let opts = SimOptions {
            policy,
            cluster: ClusterSpec::by_name(cluster).unwrap(),
            serial_scheduler: false,
        };
        simulate(&workload, &opts).unwrap()
    }

    #[test]
    fn resume_points_round_trip_and_reject_corruption() {
        let p = ResumePoint {
            job_id: 42,
            done_iters: 3,
            service_seconds: 12.5,
            wait_seconds: 0.75,
        };
        let bytes = p.encode();
        assert_eq!(bytes.len(), RESUME_POINT_LEN);
        assert_eq!(ResumePoint::decode(&bytes).unwrap(), p);
        let mut flipped = bytes.clone();
        flipped[15] ^= 1;
        assert!(matches!(
            ResumePoint::decode(&flipped),
            Err(ResumeError::BadChecksum { .. })
        ));
        assert!(matches!(
            ResumePoint::decode(&bytes[..20]),
            Err(ResumeError::Truncated { .. })
        ));
        let mut wrong_magic = bytes.clone();
        wrong_magic[0] = b'X';
        assert_eq!(ResumePoint::decode(&wrong_magic), Err(ResumeError::BadMagic));
        // trailing garbage after a checksum-valid body must not decode
        let mut padded = bytes.clone();
        padded.push(0xAB);
        assert_eq!(
            ResumePoint::decode(&padded),
            Err(ResumeError::BadLength { expected: RESUME_POINT_LEN, got: 49 })
        );
        let mut wrong_version = bytes;
        wrong_version[8] = 9;
        // version is checked before the checksum
        assert_eq!(ResumePoint::decode(&wrong_version), Err(ResumeError::BadVersion(9)));
    }

    #[test]
    fn resume_codec_survives_exhaustive_mutation() {
        // every single-bit flip, every truncation, trailing garbage and
        // seeded random buffers: all structured errors, no panics, no
        // false accepts
        let p = ResumePoint {
            job_id: u64::MAX - 3,
            done_iters: 7,
            service_seconds: 1.5e-3,
            wait_seconds: 0.0,
        };
        crate::util::proptest::assert_codec_rejects_mutants(
            &p.encode(),
            256,
            99,
            ResumePoint::decode,
        );
    }

    #[test]
    fn fleet_accounts_for_every_job() {
        for policy in FleetPolicy::ALL {
            let r = run(ArrivalPattern::Steady, policy, "paper", 20);
            assert_eq!(r.submitted, 20);
            assert_eq!(r.admitted + r.rejected, 20);
            assert_eq!(r.finished, r.admitted);
            assert_eq!(r.evicted, 0);
            assert_eq!(r.builds, r.admitted);
            assert_eq!(r.max_builds_per_job, 1);
            assert!(r.pricings >= r.builds);
            assert!(r.makespan > 0.0);
            assert!(r.utilization > 0.0 && r.utilization <= 1.0);
            assert!(r.fairness_ratio >= 1.0);
            assert_eq!(r.queue_wait.len(), r.finished);
        }
    }

    #[test]
    fn bursty_arrivals_reject_over_quota_and_queue_waits_grow() {
        let r = run(ArrivalPattern::Bursty, FleetPolicy::Fifo, "paper", 40);
        assert!(r.rejected > 0, "bursts of 3-6 against quota 2-4 must reject");
        for (t, stats) in r.tenants.iter().enumerate() {
            let quota = synthesize(ArrivalPattern::Bursty, 40, 11).tenants[t].quota;
            assert!(stats.peak_in_flight <= quota, "tenant {t} exceeded quota {quota}");
        }
        assert!(r.queue_wait.max() > 0.0, "a one-pool bursty fleet must make someone wait");
    }

    #[test]
    fn priority_policy_preempts_and_never_inverts() {
        let mut preempted = 0usize;
        for seed_pattern in [ArrivalPattern::Bursty, ArrivalPattern::HeavyTailed] {
            let r = run(seed_pattern, FleetPolicy::Priority, "paper", 60);
            assert_eq!(r.priority_inversions, 0);
            preempted += r.preemptions;
        }
        assert!(preempted > 0, "priority fleets under load should preempt at least once");
    }

    #[test]
    fn identical_inputs_are_bit_identical_and_policies_differ() {
        let a = run(ArrivalPattern::HeavyTailed, FleetPolicy::ShortestPricedFirst, "hetero", 30);
        let b = run(ArrivalPattern::HeavyTailed, FleetPolicy::ShortestPricedFirst, "hetero", 30);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
        assert_eq!(a.fairness_ratio.to_bits(), b.fairness_ratio.to_bits());
        assert_eq!(a.pricings, b.pricings);
        let fifo = run(ArrivalPattern::HeavyTailed, FleetPolicy::Fifo, "hetero", 30);
        assert!(
            fifo.makespan.to_bits() != a.makespan.to_bits()
                || fifo.queue_wait.mean().to_bits() != a.queue_wait.mean().to_bits(),
            "policies should not be observationally identical"
        );
    }

    #[test]
    fn serial_scheduler_flag_does_not_change_the_simulation() {
        let workload = synthesize(ArrivalPattern::Steady, 15, 4);
        let mk = |serial| SimOptions {
            policy: FleetPolicy::BestFitPrice,
            cluster: ClusterSpec::by_name("hetero").unwrap(),
            serial_scheduler: serial,
        };
        let a = simulate(&workload, &mk(false)).unwrap();
        let b = simulate(&workload, &mk(true)).unwrap();
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.queue_wait.mean().to_bits(), b.queue_wait.mean().to_bits());
    }

    fn mini_job(id: u64, dp: usize, cp: usize) -> FleetJob {
        FleetJob {
            id,
            tenant: 0,
            dataset: "wikipedia",
            dp,
            cp,
            batch_size: 8,
            iterations: 2,
            seq_count: 200,
            policy: crate::config::Policy::Skrull,
            priority: 1,
            submit_time: 0.0,
            seed: 5 + id,
        }
    }

    #[test]
    fn node_loss_preempts_victims_and_evicts_unplaceable_jobs() {
        // one big job holding all 4 testbed nodes + one small queued job;
        // losing 3 nodes must preempt the big job, evict it (4-node shape
        // no longer fits), and let the small job finish on the survivor
        let tenants = vec![Tenant { id: 0, weight: 1.0, quota: 10 }];
        let opts = SimOptions {
            policy: FleetPolicy::Fifo,
            cluster: ClusterSpec::by_name("paper").unwrap(),
            serial_scheduler: false,
        };
        let mut core = FleetCore::new(tenants, opts);
        core.set_record_events(true);
        core.submit(mini_job(0, 4, 8), 0.0).unwrap();
        core.submit(mini_job(1, 1, 8), 0.0).unwrap();
        assert_eq!(core.running_jobs(), 1);
        assert_eq!(core.queued_jobs(), 1);
        core.lose_nodes(0, 3, 0.0).unwrap();
        core.drain().unwrap();
        let r = core.finish_report().unwrap();
        assert_eq!(r.submitted, 2);
        assert_eq!(r.admitted, 2);
        assert_eq!(r.finished, 1);
        assert_eq!(r.evicted, 1);
        assert_eq!(r.preemptions, 1);
        assert_eq!(r.builds, 2, "both admitted jobs were built exactly once");
        let kinds: Vec<u8> = core
            .take_events()
            .iter()
            .map(|e| e.encode()[0])
            .collect();
        // arrival/admit/dispatch(j0), arrival/admit(j1 queued),
        // preempt(j0), evict(j0), dispatch(j1), complete(j1)
        assert_eq!(kinds, vec![1, 2, 4, 1, 2, 5, 7, 4, 6]);
    }

    #[test]
    fn submit_after_node_loss_rejects_unplaceable_shapes_gracefully() {
        let tenants = vec![Tenant { id: 0, weight: 1.0, quota: 10 }];
        let opts = SimOptions {
            policy: FleetPolicy::Fifo,
            cluster: ClusterSpec::by_name("paper").unwrap(),
            serial_scheduler: false,
        };
        let mut core = FleetCore::new(tenants, opts);
        core.lose_nodes(0, 3, 0.0).unwrap();
        core.submit(mini_job(0, 4, 8), 0.0).unwrap();
        core.submit(mini_job(1, 1, 8), 0.0).unwrap();
        core.drain().unwrap();
        let r = core.finish_report().unwrap();
        assert_eq!(r.rejected, 1, "the 4-node shape must be rejected, not an error");
        assert_eq!(r.finished, 1);
    }

    #[test]
    fn incremental_core_matches_batch_simulate_bit_for_bit() {
        let workload = synthesize(ArrivalPattern::Bursty, 18, 9);
        let opts = SimOptions {
            policy: FleetPolicy::Priority,
            cluster: ClusterSpec::by_name("hetero").unwrap(),
            serial_scheduler: false,
        };
        let batch = simulate(&workload, &opts).unwrap();
        let mut core = FleetCore::new(workload.tenants.clone(), opts);
        for job in &workload.jobs {
            core.step_until(job.submit_time).unwrap();
            core.submit(job.clone(), job.submit_time).unwrap();
        }
        core.drain().unwrap();
        let inc = core.finish_report().unwrap();
        assert_eq!(batch.makespan.to_bits(), inc.makespan.to_bits());
        assert_eq!(batch.utilization.to_bits(), inc.utilization.to_bits());
        assert_eq!(batch.fairness_ratio.to_bits(), inc.fairness_ratio.to_bits());
        assert_eq!(batch.pricings, inc.pricings);
        assert_eq!(batch.preemptions, inc.preemptions);
        assert_eq!(batch.finished, inc.finished);
    }

    #[test]
    fn event_recording_is_off_by_default_and_drains() {
        let workload = synthesize(ArrivalPattern::Steady, 6, 3);
        let opts = SimOptions {
            policy: FleetPolicy::Fifo,
            cluster: ClusterSpec::by_name("paper").unwrap(),
            serial_scheduler: false,
        };
        let mut core = FleetCore::new(workload.tenants.clone(), opts.clone());
        for job in &workload.jobs {
            core.step_until(job.submit_time).unwrap();
            core.submit(job.clone(), job.submit_time).unwrap();
        }
        core.drain().unwrap();
        assert!(core.take_events().is_empty(), "recording must be opt-in");

        let mut rec = FleetCore::new(workload.tenants.clone(), opts);
        rec.set_record_events(true);
        for job in &workload.jobs {
            rec.step_until(job.submit_time).unwrap();
            rec.submit(job.clone(), job.submit_time).unwrap();
        }
        rec.drain().unwrap();
        let events = rec.take_events();
        let arrivals = events
            .iter()
            .filter(|e| matches!(e, FleetEvent::Arrival { .. }))
            .count();
        assert_eq!(arrivals, 6);
        let completes = events
            .iter()
            .filter(|e| matches!(e, FleetEvent::Complete { .. }))
            .count();
        let report = rec.finish_report().unwrap();
        assert_eq!(completes, report.finished);
        assert!(rec.take_events().is_empty(), "take_events must drain");
        // encodings are self-describing: distinct events encode distinctly
        let a = events[0].encode();
        let b = events[1].encode();
        assert_ne!(a, b);
    }
}
