//! The deterministic discrete-event fleet simulator.  Tenants submit
//! jobs (admission-controlled by per-tenant quota), the queue discipline
//! picks what runs next, the placement engine prices each job's single
//! `BuiltRun` against every pool that could host it, and the event loop
//! advances start / iteration-boundary-preemption / finish events in
//! purely simulated time.  Preempted jobs carry their progress through a
//! checksummed `ResumePoint` codec (the `coordinator::state` checkpoint
//! idiom), so a resumed job re-prices only its remaining iterations.
//!
//! Nothing here reads a wall clock: the same workload, policy and pool
//! set produce bit-identical reports on any machine at any parallelism.

use std::fmt;

use crate::cluster::run::{build_run, BuiltRun, RunConfig};
use crate::config::ExperimentConfig;
use crate::coordinator::state::fnv1a;
use crate::data::{Dataset, LengthDistribution};
use crate::fleet::job::Workload;
use crate::fleet::placement::{Candidate, ClusterSpec, PlacementEngine};
use crate::fleet::queue::{pick_next, FleetPolicy, QueueEntry};
use crate::model::ModelSpec;
use crate::perfmodel::CostModel;
use crate::util::error::{Context, Result};
use crate::util::stats::Summary;

/// Pinned per-invocation scheduler cost, so simulated durations never
/// depend on the host machine (same convention as `bench::e2e`).
pub const DETERMINISTIC_SCHED_SECONDS: f64 = 1e-6;

const RESUME_MAGIC: [u8; 8] = *b"SKRLFLT\0";
const RESUME_VERSION: u32 = 1;

/// Progress a preempted job carries back into the queue: iterations
/// done plus the service/wait it accrued, guarded by magic, version and
/// an FNV-1a checksum exactly like the trainer's checkpoints.
#[derive(Clone, Debug, PartialEq)]
pub struct ResumePoint {
    pub job_id: u64,
    pub done_iters: u32,
    pub service_seconds: f64,
    pub wait_seconds: f64,
}

/// Structured decode failure — never a panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ResumeError {
    Truncated { need: usize, have: usize },
    BadMagic,
    BadVersion(u32),
    BadChecksum { expected: u64, found: u64 },
}

impl fmt::Display for ResumeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ResumeError::Truncated { need, have } => {
                write!(f, "resume point truncated: need {need} bytes, have {have}")
            }
            ResumeError::BadMagic => write!(f, "resume point has wrong magic"),
            ResumeError::BadVersion(v) => write!(f, "unsupported resume point version {v}"),
            ResumeError::BadChecksum { expected, found } => {
                write!(
                    f,
                    "resume point checksum mismatch: expected {expected:#x}, found {found:#x}"
                )
            }
        }
    }
}

impl std::error::Error for ResumeError {}

fn take<const N: usize>(bytes: &[u8], off: usize) -> Result<[u8; N], ResumeError> {
    match bytes.get(off..off + N) {
        Some(s) => {
            let mut out = [0u8; N];
            out.copy_from_slice(s);
            Ok(out)
        }
        None => Err(ResumeError::Truncated { need: off + N, have: bytes.len() }),
    }
}

impl ResumePoint {
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(8 + 4 + 8 + 4 + 8 + 8 + 8);
        buf.extend_from_slice(&RESUME_MAGIC);
        buf.extend_from_slice(&RESUME_VERSION.to_le_bytes());
        buf.extend_from_slice(&self.job_id.to_le_bytes());
        buf.extend_from_slice(&self.done_iters.to_le_bytes());
        buf.extend_from_slice(&self.service_seconds.to_le_bytes());
        buf.extend_from_slice(&self.wait_seconds.to_le_bytes());
        let crc = fnv1a(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf
    }

    pub fn decode(bytes: &[u8]) -> Result<ResumePoint, ResumeError> {
        let magic: [u8; 8] = take(bytes, 0)?;
        if magic != RESUME_MAGIC {
            return Err(ResumeError::BadMagic);
        }
        let version = u32::from_le_bytes(take(bytes, 8)?);
        if version != RESUME_VERSION {
            return Err(ResumeError::BadVersion(version));
        }
        let job_id = u64::from_le_bytes(take(bytes, 12)?);
        let done_iters = u32::from_le_bytes(take(bytes, 20)?);
        let service_seconds = f64::from_le_bytes(take(bytes, 24)?);
        let wait_seconds = f64::from_le_bytes(take(bytes, 32)?);
        let found = u64::from_le_bytes(take(bytes, 40)?);
        let expected = fnv1a(&bytes[..40]);
        if found != expected {
            return Err(ResumeError::BadChecksum { expected, found });
        }
        Ok(ResumePoint { job_id, done_iters, service_seconds, wait_seconds })
    }
}

/// Simulator knobs (the workload supplies everything else).
#[derive(Clone, Debug)]
pub struct SimOptions {
    pub policy: FleetPolicy,
    pub cluster: ClusterSpec,
    /// Forwarded to `RunConfig::serial_scheduler` when fleet cells fan
    /// out across worker threads (same rule as the e2e sweep).
    pub serial_scheduler: bool,
}

/// Per-tenant accounting for the fairness and quota gates.
#[derive(Clone, Debug, Default)]
pub struct TenantStats {
    pub submitted: usize,
    pub admitted: usize,
    pub rejected: usize,
    pub finished: usize,
    pub service_seconds: f64,
    /// High-water mark of this tenant's queued + running jobs; the quota
    /// property test asserts it never exceeds the tenant's quota.
    pub peak_in_flight: usize,
}

/// What one simulated fleet run produced.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub policy: FleetPolicy,
    pub cluster: &'static str,
    pub submitted: usize,
    pub admitted: usize,
    pub rejected: usize,
    pub finished: usize,
    pub preemptions: usize,
    /// `build_run` invocations — exactly one per admitted job.
    pub builds: usize,
    /// `price_run` invocations — many per build.
    pub pricings: usize,
    pub max_builds_per_job: usize,
    /// Dispatches under `Priority` that passed over a strictly
    /// higher-priority placeable entry (must stay zero).
    pub priority_inversions: usize,
    pub makespan: f64,
    /// Busy GPU-seconds over total GPU-seconds to makespan.
    pub utilization: f64,
    /// Max over min weighted tenant service (1.0 if fewer than two
    /// tenants finished anything).
    pub fairness_ratio: f64,
    /// Total queue wait per finished job.
    pub queue_wait: Summary,
    pub tenants: Vec<TenantStats>,
}

/// One placed job occupying nodes.
struct Running {
    job: usize,
    pool: usize,
    nodes: usize,
    gpus: usize,
    start: f64,
    /// Iterations completed before this placement.
    done_before: usize,
    /// Absolute completion time of each remaining iteration.
    iter_ends: Vec<f64>,
    finish: f64,
    /// Next event for this machine: the finish, or an earlier preemption
    /// boundary once a preemption is pending.
    event_time: f64,
    /// Index into `iter_ends` where a pending preemption takes effect.
    preempt_at: Option<usize>,
    wait_so_far: f64,
    service_so_far: f64,
}

enum Event {
    Arrival,
    Machine(usize),
    Idle,
}

/// Pick the earliest pending event: machine events (finish/preempt) by
/// time, lowest job id on ties, and at equal times machines fire before
/// the next arrival.  `next_arrival` is `f64::INFINITY` once the
/// workload is exhausted.
///
/// Hot path: called once per simulated event; index scan, no allocation.
fn next_event(running: &[Running], next_arrival: f64) -> Event {
    let mut best: Option<usize> = None;
    let mut i = 0;
    while i < running.len() {
        match best {
            Some(b) => {
                let ord = running[i].event_time.total_cmp(&running[b].event_time);
                if ord == core::cmp::Ordering::Less
                    || (ord == core::cmp::Ordering::Equal && running[i].job < running[b].job)
                {
                    best = Some(i);
                }
            }
            None => best = Some(i),
        }
        i += 1;
    }
    match best {
        Some(b) => {
            if running[b].event_time.total_cmp(&next_arrival) == core::cmp::Ordering::Greater {
                Event::Arrival
            } else {
                Event::Machine(b)
            }
        }
        None if next_arrival.is_finite() => Event::Arrival,
        None => Event::Idle,
    }
}

struct Sim<'a> {
    workload: &'a Workload,
    opts: &'a SimOptions,
    cost: CostModel,
    engine: PlacementEngine,
    builts: Vec<Option<BuiltRun>>,
    build_counts: Vec<usize>,
    queue: Vec<QueueEntry>,
    running: Vec<Running>,
    in_system: Vec<usize>,
    tenants: Vec<TenantStats>,
    queue_wait: Summary,
    busy_gpu_seconds: f64,
    pricings: usize,
    preemptions: usize,
    priority_inversions: usize,
    finished: usize,
    admitted: usize,
    rejected: usize,
    last_finish: f64,
}

impl Sim<'_> {
    /// Schedule (GDS/DACP) the job exactly once; every later placement
    /// decision reprices this artifact.
    fn ensure_built(&mut self, job_idx: usize) -> Result<()> {
        if self.builts[job_idx].is_some() {
            return Ok(());
        }
        let job = &self.workload.jobs[job_idx];
        let mut cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), job.dataset);
        cfg.cluster.dp = job.dp;
        cfg.cluster.cp = job.cp;
        cfg.cluster.batch_size = job.batch_size;
        cfg.policy = job.policy;
        cfg.seed = job.seed;
        cfg.pipelined = true;
        let cfg = cfg
            .resolve_capacity()
            .with_context(|| format!("job {}: capacity resolution failed", job.id))?;
        let dist = LengthDistribution::by_name(job.dataset)
            .ok_or_else(|| crate::anyhow!("job {}: unknown dataset {}", job.id, job.dataset))?;
        let ds = Dataset::synthesize(&dist, job.seq_count, job.seed)
            .truncated(cfg.bucket_size * job.cp as u32);
        let mut run = RunConfig::new(job.iterations, true);
        run.serial_scheduler = self.opts.serial_scheduler;
        let mut built = build_run(&ds, &cfg, &run)
            .with_context(|| format!("job {}: schedule build failed", job.id))?;
        built.pin_sched_seconds(DETERMINISTIC_SCHED_SECONDS);
        self.builts[job_idx] = Some(built);
        self.build_counts[job_idx] += 1;
        Ok(())
    }

    /// Price entry `queue[qi]`'s remaining iterations on every pool and
    /// keep the policy-preferred candidate.
    fn best_candidate(&mut self, qi: usize) -> Result<Option<Candidate>> {
        let job_idx = self.queue[qi].job;
        self.ensure_built(job_idx)?;
        let done = self.queue[qi].done_iters;
        let built = self.builts[job_idx]
            .as_ref()
            .ok_or_else(|| crate::anyhow!("job {job_idx} vanished from the build cache"))?;
        let mut cands = Vec::new();
        self.pricings += self.engine.candidates(built, &self.cost, done, &mut cands)?;
        let best_fit = self.opts.policy == FleetPolicy::BestFitPrice;
        let mut best: Option<Candidate> = None;
        for c in cands {
            let better = match &best {
                None => true,
                Some(b) => {
                    if best_fit && c.waste_gpus != b.waste_gpus {
                        c.waste_gpus < b.waste_gpus
                    } else {
                        c.seconds.total_cmp(&b.seconds) == core::cmp::Ordering::Less
                    }
                }
            };
            if better {
                best = Some(c);
            }
        }
        Ok(best)
    }

    /// Start queued jobs while the policy and free nodes allow.
    fn dispatch(&mut self, now: f64) -> Result<()> {
        loop {
            if self.queue.is_empty() {
                return Ok(());
            }
            let n = self.queue.len();
            let mut feasible = Vec::with_capacity(n);
            let mut secs = Vec::with_capacity(n);
            let mut prios = Vec::with_capacity(n);
            let mut chosen: Vec<Option<Candidate>> = Vec::with_capacity(n);
            for qi in 0..n {
                let cand = self.best_candidate(qi)?;
                feasible.push(cand.is_some());
                secs.push(cand.as_ref().map_or(f64::INFINITY, |c| c.seconds));
                prios.push(self.workload.jobs[self.queue[qi].job].priority);
                chosen.push(cand);
            }
            let Some(qi) = pick_next(self.opts.policy, &feasible, &secs, &prios) else {
                return Ok(());
            };
            if self.opts.policy == FleetPolicy::Priority {
                self.priority_inversions += (0..n)
                    .filter(|&i| feasible[i] && prios[i] > prios[qi])
                    .count();
            }
            let cand = chosen
                .swap_remove(qi)
                .ok_or_else(|| crate::anyhow!("policy picked an infeasible entry"))?;
            self.start(qi, cand, now)?;
        }
    }

    fn start(&mut self, qi: usize, cand: Candidate, now: f64) -> Result<()> {
        let mut entry = self.queue.remove(qi);
        let job = &self.workload.jobs[entry.job];
        // a preempted job's progress must round-trip the resume codec
        // intact before it re-enters service
        if let Some(bytes) = entry.resume.take() {
            let point = ResumePoint::decode(&bytes)
                .with_context(|| format!("job {}: corrupt resume point", job.id))?;
            crate::ensure!(
                point.job_id == job.id
                    && point.done_iters as usize == entry.done_iters
                    && point.service_seconds.to_bits() == entry.service_so_far.to_bits()
                    && point.wait_seconds.to_bits() == entry.wait_so_far.to_bits(),
                "job {}: resume point disagrees with queue entry",
                job.id
            );
        }
        crate::ensure!(!cand.per_iter.is_empty(), "job {} has no remaining iterations", job.id);
        entry.wait_so_far += now - entry.enqueued_at;
        self.engine.allocate(&cand)?;
        let mut iter_ends = Vec::with_capacity(cand.per_iter.len());
        let mut t = now;
        for d in &cand.per_iter {
            t += d;
            iter_ends.push(t);
        }
        let finish = t;
        self.running.push(Running {
            job: entry.job,
            pool: cand.pool,
            nodes: cand.nodes,
            gpus: job.gpus(),
            start: now,
            done_before: entry.done_iters,
            iter_ends,
            finish,
            event_time: finish,
            preempt_at: None,
            wait_so_far: entry.wait_so_far,
            service_so_far: entry.service_so_far,
        });
        Ok(())
    }

    /// Under `Priority`, make room for a placeable-nowhere arrival by
    /// preempting the weakest strictly-lower-priority running job at its
    /// next iteration boundary (one victim per arrival, no cascades).
    fn preempt_for(&mut self, arriving_priority: u32, now: f64) {
        let mut victim: Option<usize> = None;
        for (i, r) in self.running.iter().enumerate() {
            if r.preempt_at.is_some() {
                continue;
            }
            let prio = self.workload.jobs[r.job].priority;
            if prio >= arriving_priority {
                continue;
            }
            // first boundary strictly after now that is not the finish
            let has_boundary = r
                .iter_ends
                .iter()
                .take(r.iter_ends.len().saturating_sub(1))
                .any(|&b| b > now);
            if !has_boundary {
                continue;
            }
            let better = match victim {
                None => true,
                Some(v) => {
                    let vp = self.workload.jobs[self.running[v].job].priority;
                    prio < vp || (prio == vp && r.job < self.running[v].job)
                }
            };
            if better {
                victim = Some(i);
            }
        }
        if let Some(v) = victim {
            let r = &mut self.running[v];
            let last = r.iter_ends.len() - 1;
            for (j, &b) in r.iter_ends.iter().enumerate() {
                if b > now && j < last {
                    r.preempt_at = Some(j);
                    r.event_time = b;
                    break;
                }
            }
        }
    }

    fn arrive(&mut self, job_idx: usize, now: f64) -> Result<()> {
        let job = &self.workload.jobs[job_idx];
        let tenant = job.tenant;
        self.tenants[tenant].submitted += 1;
        let quota = self.workload.tenants[tenant].quota;
        if self.in_system[tenant] >= quota {
            self.rejected += 1;
            self.tenants[tenant].rejected += 1;
            return Ok(());
        }
        self.admitted += 1;
        self.tenants[tenant].admitted += 1;
        self.in_system[tenant] += 1;
        self.tenants[tenant].peak_in_flight =
            self.tenants[tenant].peak_in_flight.max(self.in_system[tenant]);
        self.queue.push(QueueEntry {
            job: job_idx,
            enqueued_at: now,
            done_iters: 0,
            resume: None,
            wait_so_far: 0.0,
            service_so_far: 0.0,
        });
        self.dispatch(now)?;
        if self.opts.policy == FleetPolicy::Priority {
            if let Some(qi) = self.queue.iter().position(|e| e.job == job_idx) {
                if self.best_candidate(qi)?.is_none() {
                    self.preempt_for(self.workload.jobs[job_idx].priority, now);
                }
            }
        }
        Ok(())
    }

    fn machine_event(&mut self, mi: usize) -> Result<()> {
        let r = self.running.swap_remove(mi);
        let now = r.event_time;
        let job = &self.workload.jobs[r.job];
        let segment = now - r.start;
        self.busy_gpu_seconds += r.gpus as f64 * segment;
        self.tenants[job.tenant].service_seconds += segment;
        self.engine.release(r.pool, r.nodes)?;
        match r.preempt_at {
            Some(j) => {
                self.preemptions += 1;
                let done_iters = r.done_before + j + 1;
                crate::ensure!(
                    done_iters < job.iterations,
                    "job {} preempted past its final iteration",
                    job.id
                );
                let service = r.service_so_far + segment;
                let point = ResumePoint {
                    job_id: job.id,
                    done_iters: done_iters as u32,
                    service_seconds: service,
                    wait_seconds: r.wait_so_far,
                };
                self.queue.push(QueueEntry {
                    job: r.job,
                    enqueued_at: now,
                    done_iters,
                    resume: Some(point.encode()),
                    wait_so_far: r.wait_so_far,
                    service_so_far: service,
                });
            }
            None => {
                self.finished += 1;
                self.tenants[job.tenant].finished += 1;
                self.in_system[job.tenant] -= 1;
                self.queue_wait.push(r.wait_so_far);
                self.last_finish = self.last_finish.max(r.finish);
            }
        }
        self.dispatch(now)
    }
}

/// Run the fleet to completion and account for every job.
pub fn simulate(workload: &Workload, opts: &SimOptions) -> Result<FleetReport> {
    let n_jobs = workload.jobs.len();
    crate::ensure!(n_jobs > 0, "empty workload");
    let engine = PlacementEngine::new(&opts.cluster);
    for job in &workload.jobs {
        crate::ensure!(
            engine.placeable(job.dp, job.cp),
            "job {} shape {}x{} fits no pool of {}",
            job.id,
            job.dp,
            job.cp,
            opts.cluster.name
        );
    }
    let cost = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia").cost_model();
    let mut sim = Sim {
        workload,
        opts,
        cost,
        engine,
        builts: vec![None; n_jobs],
        build_counts: vec![0; n_jobs],
        queue: Vec::new(),
        running: Vec::new(),
        in_system: vec![0; workload.tenants.len()],
        tenants: vec![TenantStats::default(); workload.tenants.len()],
        queue_wait: Summary::new(),
        busy_gpu_seconds: 0.0,
        pricings: 0,
        preemptions: 0,
        priority_inversions: 0,
        finished: 0,
        admitted: 0,
        rejected: 0,
        last_finish: 0.0,
    };
    let mut next_job = 0usize;
    loop {
        let next_arrival = if next_job < n_jobs {
            workload.jobs[next_job].submit_time
        } else {
            f64::INFINITY
        };
        match next_event(&sim.running, next_arrival) {
            Event::Arrival => {
                sim.arrive(next_job, next_arrival)?;
                next_job += 1;
            }
            Event::Machine(mi) => sim.machine_event(mi)?,
            Event::Idle => break,
        }
    }
    crate::ensure!(sim.queue.is_empty(), "fleet went idle with {} queued jobs", sim.queue.len());
    crate::ensure!(
        sim.admitted + sim.rejected == n_jobs && sim.finished == sim.admitted,
        "conservation violated: {} submitted, {} admitted, {} rejected, {} finished",
        n_jobs,
        sim.admitted,
        sim.rejected,
        sim.finished
    );
    let builds: usize = sim.build_counts.iter().sum();
    let max_builds_per_job = sim.build_counts.iter().copied().max().unwrap_or(0);
    crate::ensure!(
        max_builds_per_job <= 1 && builds == sim.admitted,
        "build-once violated: {builds} builds for {} admitted jobs (max {max_builds_per_job})",
        sim.admitted
    );
    crate::ensure!(sim.finished > 0, "no job finished");
    let makespan = sim.last_finish;
    let total_gpus = opts.cluster.total_gpus();
    let utilization = sim.busy_gpu_seconds / (total_gpus as f64 * makespan);
    let mut lo = f64::INFINITY;
    let mut hi = 0.0f64;
    let mut served = 0usize;
    for (t, stats) in workload.tenants.iter().zip(&sim.tenants) {
        if stats.finished == 0 {
            continue;
        }
        served += 1;
        let weighted = stats.service_seconds / t.weight;
        lo = lo.min(weighted);
        hi = hi.max(weighted);
    }
    let fairness_ratio = if served >= 2 { hi / lo } else { 1.0 };
    Ok(FleetReport {
        policy: opts.policy,
        cluster: opts.cluster.name,
        submitted: n_jobs,
        admitted: sim.admitted,
        rejected: sim.rejected,
        finished: sim.finished,
        preemptions: sim.preemptions,
        builds,
        pricings: sim.pricings,
        max_builds_per_job,
        priority_inversions: sim.priority_inversions,
        makespan,
        utilization,
        fairness_ratio,
        queue_wait: sim.queue_wait,
        tenants: sim.tenants,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::job::{synthesize, ArrivalPattern};

    fn run(pattern: ArrivalPattern, policy: FleetPolicy, cluster: &str, n: usize) -> FleetReport {
        let workload = synthesize(pattern, n, 11);
        let opts = SimOptions {
            policy,
            cluster: ClusterSpec::by_name(cluster).unwrap(),
            serial_scheduler: false,
        };
        simulate(&workload, &opts).unwrap()
    }

    #[test]
    fn resume_points_round_trip_and_reject_corruption() {
        let p = ResumePoint {
            job_id: 42,
            done_iters: 3,
            service_seconds: 12.5,
            wait_seconds: 0.75,
        };
        let bytes = p.encode();
        assert_eq!(ResumePoint::decode(&bytes).unwrap(), p);
        let mut flipped = bytes.clone();
        flipped[15] ^= 1;
        assert!(matches!(
            ResumePoint::decode(&flipped),
            Err(ResumeError::BadChecksum { .. })
        ));
        assert!(matches!(
            ResumePoint::decode(&bytes[..20]),
            Err(ResumeError::Truncated { .. })
        ));
        let mut wrong_magic = bytes.clone();
        wrong_magic[0] = b'X';
        assert_eq!(ResumePoint::decode(&wrong_magic), Err(ResumeError::BadMagic));
        let mut wrong_version = bytes;
        wrong_version[8] = 9;
        // version is checked before the checksum
        assert_eq!(ResumePoint::decode(&wrong_version), Err(ResumeError::BadVersion(9)));
    }

    #[test]
    fn fleet_accounts_for_every_job() {
        for policy in FleetPolicy::ALL {
            let r = run(ArrivalPattern::Steady, policy, "paper", 20);
            assert_eq!(r.submitted, 20);
            assert_eq!(r.admitted + r.rejected, 20);
            assert_eq!(r.finished, r.admitted);
            assert_eq!(r.builds, r.admitted);
            assert_eq!(r.max_builds_per_job, 1);
            assert!(r.pricings >= r.builds);
            assert!(r.makespan > 0.0);
            assert!(r.utilization > 0.0 && r.utilization <= 1.0);
            assert!(r.fairness_ratio >= 1.0);
            assert_eq!(r.queue_wait.len(), r.finished);
        }
    }

    #[test]
    fn bursty_arrivals_reject_over_quota_and_queue_waits_grow() {
        let r = run(ArrivalPattern::Bursty, FleetPolicy::Fifo, "paper", 40);
        assert!(r.rejected > 0, "bursts of 3-6 against quota 2-4 must reject");
        for (t, stats) in r.tenants.iter().enumerate() {
            let quota = synthesize(ArrivalPattern::Bursty, 40, 11).tenants[t].quota;
            assert!(stats.peak_in_flight <= quota, "tenant {t} exceeded quota {quota}");
        }
        assert!(r.queue_wait.max() > 0.0, "a one-pool bursty fleet must make someone wait");
    }

    #[test]
    fn priority_policy_preempts_and_never_inverts() {
        let mut preempted = 0usize;
        for seed_pattern in [ArrivalPattern::Bursty, ArrivalPattern::HeavyTailed] {
            let r = run(seed_pattern, FleetPolicy::Priority, "paper", 60);
            assert_eq!(r.priority_inversions, 0);
            preempted += r.preemptions;
        }
        assert!(preempted > 0, "priority fleets under load should preempt at least once");
    }

    #[test]
    fn identical_inputs_are_bit_identical_and_policies_differ() {
        let a = run(ArrivalPattern::HeavyTailed, FleetPolicy::ShortestPricedFirst, "hetero", 30);
        let b = run(ArrivalPattern::HeavyTailed, FleetPolicy::ShortestPricedFirst, "hetero", 30);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
        assert_eq!(a.fairness_ratio.to_bits(), b.fairness_ratio.to_bits());
        assert_eq!(a.pricings, b.pricings);
        let fifo = run(ArrivalPattern::HeavyTailed, FleetPolicy::Fifo, "hetero", 30);
        assert!(
            fifo.makespan.to_bits() != a.makespan.to_bits()
                || fifo.queue_wait.mean().to_bits() != a.queue_wait.mean().to_bits(),
            "policies should not be observationally identical"
        );
    }

    #[test]
    fn serial_scheduler_flag_does_not_change_the_simulation() {
        let workload = synthesize(ArrivalPattern::Steady, 15, 4);
        let mk = |serial| SimOptions {
            policy: FleetPolicy::BestFitPrice,
            cluster: ClusterSpec::by_name("hetero").unwrap(),
            serial_scheduler: serial,
        };
        let a = simulate(&workload, &mk(false)).unwrap();
        let b = simulate(&workload, &mk(true)).unwrap();
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.queue_wait.mean().to_bits(), b.queue_wait.mean().to_bits());
    }
}
