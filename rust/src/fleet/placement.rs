//! The placement engine: carve (possibly heterogeneous-HBM) node pools
//! into candidate dp×cp slices and price one already-built run against
//! every candidate with `cluster::run::price_run` — the build-once/
//! price-many engine lifted one level up.  A job is scheduled (GDS/DACP)
//! exactly once; *where* it lands is decided by repricing that
//! `BuiltRun` on each pool's slice layout (fat NVLink nodes vs thin
//! IB-crossing ones price very differently for the same schedule).

use crate::cluster::run::{price_run, BuiltRun};
use crate::cluster::Topology;
use crate::perfmodel::CostModel;
use crate::util::error::Result;

/// One homogeneous node pool.
#[derive(Clone, Debug)]
pub struct PoolSpec {
    pub name: &'static str,
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// Per-GPU HBM of this pool's node class (the heterogeneity axis;
    /// reported per placement, smallest class governs nothing because
    /// jobs never span pools).
    pub hbm_gb: f64,
}

impl PoolSpec {
    pub fn gpus(&self) -> usize {
        self.nodes * self.gpus_per_node
    }
}

/// A named set of pools — the sweep's pool-topology axis.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub name: &'static str,
    pub pools: Vec<PoolSpec>,
}

impl ClusterSpec {
    pub const ALL_NAMES: [&'static str; 2] = ["paper", "hetero"];

    /// `"paper"` is the testbed alone (4 nodes × 8 GPUs); `"hetero"` adds
    /// a fat-NVLink pod (2 × 16) and a thin pod (8 × 4) of different HBM
    /// classes, so the same built run prices differently per pool.
    pub fn by_name(s: &str) -> Option<ClusterSpec> {
        match s {
            "paper" => Some(ClusterSpec {
                name: "paper",
                pools: vec![PoolSpec { name: "testbed", nodes: 4, gpus_per_node: 8, hbm_gb: 80.0 }],
            }),
            "hetero" => Some(ClusterSpec {
                name: "hetero",
                pools: vec![
                    PoolSpec { name: "testbed", nodes: 4, gpus_per_node: 8, hbm_gb: 80.0 },
                    PoolSpec { name: "fat", nodes: 2, gpus_per_node: 16, hbm_gb: 96.0 },
                    PoolSpec { name: "thin", nodes: 8, gpus_per_node: 4, hbm_gb: 40.0 },
                ],
            }),
            _ => None,
        }
    }

    pub fn total_gpus(&self) -> usize {
        self.pools.iter().map(PoolSpec::gpus).sum()
    }
}

/// One priced placement option: `nodes` whole nodes of `pool`, with the
/// run's remaining execution time under that slice's layout.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub pool: usize,
    pub nodes: usize,
    /// GPUs allocated (whole nodes) minus GPUs the dp×cp shape uses.
    pub waste_gpus: usize,
    /// Priced time to play iterations `done..` on this slice.
    pub seconds: f64,
    /// Per-iteration durations for iterations `done..` — absolute
    /// preemption boundaries come from their prefix sums.
    pub per_iter: Vec<f64>,
}

/// Free-node accounting over a pool set (whole-node allocation; no
/// fragmentation model — pools are flat NVLink/IB domains here).
#[derive(Clone, Debug)]
pub struct PlacementEngine {
    pub pools: Vec<PoolSpec>,
    free: Vec<usize>,
}

impl PlacementEngine {
    pub fn new(spec: &ClusterSpec) -> Self {
        let free = spec.pools.iter().map(|p| p.nodes).collect();
        PlacementEngine { pools: spec.pools.clone(), free }
    }

    pub fn free_nodes(&self, pool: usize) -> usize {
        self.free[pool]
    }

    /// The node count a dp×cp shape needs in `pool`, if the pool can host
    /// it at all (enough GPUs and a layout `Topology::new` accepts).
    fn fit(&self, pool: &PoolSpec, dp: usize, cp: usize) -> Option<usize> {
        let need = (dp * cp).div_ceil(pool.gpus_per_node);
        if need > pool.nodes {
            return None;
        }
        Topology::new(need, pool.gpus_per_node, dp, cp).ok().map(|_| need)
    }

    /// Could this shape *ever* run here (ignoring current occupancy)?
    pub fn placeable(&self, dp: usize, cp: usize) -> bool {
        self.pools.iter().any(|p| self.fit(p, dp, cp).is_some())
    }

    /// Price `built` (from iteration `done` on) against every pool with
    /// enough free nodes right now.  Clears and fills `out`; returns the
    /// number of pricings performed.  Build-once/price-many: this is pure
    /// `price_run` arithmetic, no GDS/DACP work.
    pub fn candidates(
        &self,
        built: &BuiltRun,
        cost: &CostModel,
        done: usize,
        out: &mut Vec<Candidate>,
    ) -> Result<usize> {
        out.clear();
        let mut priced = 0usize;
        for (pi, pool) in self.pools.iter().enumerate() {
            let Some(need) = self.fit(pool, built.dp, built.cp) else { continue };
            if need > self.free[pi] {
                continue;
            }
            // the candidate slice: `need` whole nodes of this pool's class
            let topo = Topology::new(need, pool.gpus_per_node, built.dp, built.cp)
                .map_err(|e| crate::anyhow!("candidate layout vanished: {e}"))?;
            let report = price_run(built, cost, &topo);
            priced += 1;
            crate::ensure!(
                done <= report.iterations.len(),
                "resume point {done} past the built run's {} iterations",
                report.iterations.len()
            );
            let per_iter: Vec<f64> = report.iterations[done..]
                .iter()
                .map(|it| it.exec_seconds + it.exposed_sched_seconds)
                .collect();
            let seconds = per_iter.iter().sum();
            out.push(Candidate {
                pool: pi,
                nodes: need,
                waste_gpus: need * pool.gpus_per_node - built.dp * built.cp,
                seconds,
                per_iter,
            });
        }
        Ok(priced)
    }

    pub fn allocate(&mut self, c: &Candidate) -> Result<()> {
        crate::ensure!(
            self.free[c.pool] >= c.nodes,
            "allocating {} nodes from pool {} with only {} free",
            c.nodes,
            c.pool,
            self.free[c.pool]
        );
        self.free[c.pool] -= c.nodes;
        Ok(())
    }

    pub fn release(&mut self, pool: usize, nodes: usize) -> Result<()> {
        self.free[pool] += nodes;
        crate::ensure!(
            self.free[pool] <= self.pools[pool].nodes,
            "pool {pool} over-released to {} of {} nodes",
            self.free[pool],
            self.pools[pool].nodes
        );
        Ok(())
    }

    /// Permanently shrink `pool` by `n` nodes (a node-loss fault).  The
    /// lost nodes must currently be free — the fleet core vacates running
    /// victims first, so a busy node is never yanked silently.
    pub fn remove_nodes(&mut self, pool: usize, n: usize) -> Result<()> {
        crate::ensure!(pool < self.pools.len(), "pool {pool} of {}", self.pools.len());
        crate::ensure!(
            self.free[pool] >= n,
            "removing {n} nodes from pool {pool} with only {} free",
            self.free[pool]
        );
        self.free[pool] -= n;
        self.pools[pool].nodes -= n;
        Ok(())
    }

    /// Current free-node vector, indexed like `pools` (snapshot codec).
    pub fn free_state(&self) -> &[usize] {
        &self.free
    }

    /// Restore pool sizes and free counts from a snapshot.  Lengths must
    /// match this engine's pool count and `free[i] <= nodes[i]`.
    pub fn restore_state(&mut self, nodes: &[usize], free: &[usize]) -> Result<()> {
        crate::ensure!(
            nodes.len() == self.pools.len() && free.len() == self.pools.len(),
            "snapshot has {}/{} pools, engine has {}",
            nodes.len(),
            free.len(),
            self.pools.len()
        );
        for i in 0..self.pools.len() {
            crate::ensure!(
                free[i] <= nodes[i],
                "snapshot pool {i} has {} free of {} nodes",
                free[i],
                nodes[i]
            );
            self.pools[i].nodes = nodes[i];
            self.free[i] = free[i];
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::run::{build_run, RunConfig};
    use crate::config::ExperimentConfig;
    use crate::data::{Dataset, LengthDistribution};
    use crate::model::ModelSpec;

    fn tiny_built(dp: usize, cp: usize) -> (BuiltRun, CostModel) {
        let cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "chatqa2");
        let mut cfg = cfg;
        cfg.cluster.dp = dp;
        cfg.cluster.cp = cp;
        cfg.cluster.batch_size = 8;
        let cfg = cfg.resolve_capacity().unwrap();
        let dist = LengthDistribution::by_name("chatqa2").unwrap();
        let ds = Dataset::synthesize(&dist, 500, 5).truncated(cfg.bucket_size * cp as u32);
        let cost = cfg.cost_model();
        let mut built = build_run(&ds, &cfg, &RunConfig::new(2, true)).unwrap();
        built.pin_sched_seconds(1e-6);
        (built, cost)
    }

    #[test]
    fn cluster_specs_resolve_by_name() {
        for name in ClusterSpec::ALL_NAMES {
            let spec = ClusterSpec::by_name(name).unwrap();
            assert_eq!(spec.name, name);
            assert!(spec.total_gpus() >= 32);
        }
        assert!(ClusterSpec::by_name("mystery").is_none());
    }

    #[test]
    fn hetero_pools_price_the_same_built_run_differently() {
        let spec = ClusterSpec::by_name("hetero").unwrap();
        let engine = PlacementEngine::new(&spec);
        let (built, cost) = tiny_built(4, 8);
        let mut out = Vec::new();
        let priced = engine.candidates(&built, &cost, 0, &mut out).unwrap();
        // all three pools can host a 32-GPU job when empty
        assert_eq!(priced, 3);
        assert_eq!(out.len(), 3);
        // the fat-NVLink pod (everything node-contained) must beat the
        // thin pod (CP rings cross IB): same schedule, different price
        let fat = out.iter().find(|c| c.pool == 1).unwrap();
        let thin = out.iter().find(|c| c.pool == 2).unwrap();
        assert!(
            fat.seconds < thin.seconds,
            "fat {} should underprice thin {}",
            fat.seconds,
            thin.seconds
        );
        assert!(out.iter().all(|c| c.per_iter.len() == 2 && c.seconds > 0.0));
    }

    #[test]
    fn occupancy_and_resume_points_narrow_candidates() {
        let spec = ClusterSpec::by_name("hetero").unwrap();
        let mut engine = PlacementEngine::new(&spec);
        let (built, cost) = tiny_built(4, 8);
        let mut out = Vec::new();
        engine.candidates(&built, &cost, 0, &mut out).unwrap();
        let first = out[0].clone();
        engine.allocate(&first).unwrap();
        engine.candidates(&built, &cost, 0, &mut out).unwrap();
        assert!(out.iter().all(|c| c.pool != first.pool), "occupied pool still offered");
        engine.release(first.pool, first.nodes).unwrap();
        // a resumed job (1 of 2 iterations done) prices only the tail
        engine.candidates(&built, &cost, 0, &mut out).unwrap();
        let full = out[0].seconds;
        engine.candidates(&built, &cost, 1, &mut out).unwrap();
        assert!(out[0].seconds < full);
        assert_eq!(out[0].per_iter.len(), 1);
        // a resume point past the run is a structured error, not a panic
        assert!(engine.candidates(&built, &cost, 3, &mut out).is_err());
    }

    #[test]
    fn release_guards_against_double_free() {
        let spec = ClusterSpec::by_name("paper").unwrap();
        let mut engine = PlacementEngine::new(&spec);
        assert!(engine.release(0, 1).is_err());
    }

    #[test]
    fn remove_nodes_shrinks_the_pool_and_refuses_busy_nodes() {
        let spec = ClusterSpec::by_name("paper").unwrap();
        let mut engine = PlacementEngine::new(&spec);
        engine.remove_nodes(0, 3).unwrap();
        assert_eq!(engine.pools[0].nodes, 1);
        assert_eq!(engine.free_nodes(0), 1);
        // a 4-node shape no longer fits anywhere
        assert!(!engine.placeable(4, 8));
        assert!(engine.placeable(1, 8));
        // more than the pool holds is an error, as is a bad pool index
        assert!(engine.remove_nodes(0, 2).is_err());
        assert!(engine.remove_nodes(7, 1).is_err());
    }

    #[test]
    fn engine_state_round_trips_through_restore() {
        let spec = ClusterSpec::by_name("hetero").unwrap();
        let mut engine = PlacementEngine::new(&spec);
        let (built, cost) = tiny_built(4, 8);
        let mut out = Vec::new();
        engine.candidates(&built, &cost, 0, &mut out).unwrap();
        engine.allocate(&out[0]).unwrap();
        engine.remove_nodes(2, 5).unwrap();
        let nodes: Vec<usize> = engine.pools.iter().map(|p| p.nodes).collect();
        let free = engine.free_state().to_vec();
        let mut fresh = PlacementEngine::new(&spec);
        fresh.restore_state(&nodes, &free).unwrap();
        assert_eq!(fresh.free_state(), engine.free_state());
        assert_eq!(fresh.pools[2].nodes, 3);
        // malformed snapshots are structured errors
        assert!(fresh.restore_state(&nodes[..1], &free).is_err());
        assert!(fresh.restore_state(&[4, 2, 3], &[5, 0, 0]).is_err());
    }
}
