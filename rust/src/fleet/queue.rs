//! The fleet job queue: four placement-queue disciplines over pending
//! entries, with the selection scan (`pick_next`) kept alloc-free — it
//! runs once per dispatch attempt, which under bursty arrivals means
//! once per queued job per event, squarely on the simulator's hot path.

/// Which pending job runs next, and on which priced candidate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FleetPolicy {
    /// Strict arrival order with head-of-line blocking: if the oldest
    /// job does not fit right now, nothing starts.
    Fifo,
    /// Highest priority among currently-placeable jobs; preempts lower
    /// priority running jobs at iteration boundaries.
    Priority,
    /// Cheapest remaining priced time among placeable jobs first.
    ShortestPricedFirst,
    /// First placeable job in arrival order (backfill), landing on the
    /// least-waste candidate slice.
    BestFitPrice,
}

impl FleetPolicy {
    pub const ALL: [FleetPolicy; 4] = [
        FleetPolicy::Fifo,
        FleetPolicy::Priority,
        FleetPolicy::ShortestPricedFirst,
        FleetPolicy::BestFitPrice,
    ];

    pub fn by_name(s: &str) -> Option<FleetPolicy> {
        match s {
            "fifo" => Some(FleetPolicy::Fifo),
            "priority" => Some(FleetPolicy::Priority),
            "shortest-priced" => Some(FleetPolicy::ShortestPricedFirst),
            "best-fit-price" => Some(FleetPolicy::BestFitPrice),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FleetPolicy::Fifo => "fifo",
            FleetPolicy::Priority => "priority",
            FleetPolicy::ShortestPricedFirst => "shortest-priced",
            FleetPolicy::BestFitPrice => "best-fit-price",
        }
    }
}

/// One queued job, in arrival order.
#[derive(Clone, Debug)]
pub struct QueueEntry {
    /// Index into the workload's job array.
    pub job: usize,
    /// When this entry (re-)entered the queue.
    pub enqueued_at: f64,
    /// Iterations already completed (non-zero after a preemption).
    pub done_iters: usize,
    /// Checkpoint bytes carried across a preemption (`sim::ResumePoint`).
    pub resume: Option<Vec<u8>>,
    /// Queue wait accumulated over earlier residencies.
    pub wait_so_far: f64,
    /// Service delivered before the last preemption.
    pub service_so_far: f64,
}

/// Select the queue position to dispatch next, or `None` if the policy
/// starts nothing.  `feasible[i]` / `best_seconds[i]` / `priorities[i]`
/// describe entry `i`'s current best candidate (`best_seconds` is only
/// read where `feasible` holds).  Entries are in arrival order, so "first
/// wins" ties preserve FIFO fairness within a class.
///
/// Hot path: index scan only — no allocation, no `partial_cmp`.
pub fn pick_next(
    policy: FleetPolicy,
    feasible: &[bool],
    best_seconds: &[f64],
    priorities: &[u32],
) -> Option<usize> {
    debug_assert_eq!(feasible.len(), best_seconds.len());
    debug_assert_eq!(feasible.len(), priorities.len());
    match policy {
        FleetPolicy::Fifo => {
            if feasible.first().copied().unwrap_or(false) {
                Some(0)
            } else {
                None
            }
        }
        FleetPolicy::BestFitPrice => feasible.iter().position(|&f| f),
        FleetPolicy::Priority => {
            let mut best: Option<usize> = None;
            let mut i = 0;
            while i < feasible.len() {
                if feasible[i] {
                    match best {
                        Some(b) if priorities[i] <= priorities[b] => {}
                        _ => best = Some(i),
                    }
                }
                i += 1;
            }
            best
        }
        FleetPolicy::ShortestPricedFirst => {
            let mut best: Option<usize> = None;
            let mut i = 0;
            while i < feasible.len() {
                if feasible[i] {
                    match best {
                        Some(b)
                            if best_seconds[i].total_cmp(&best_seconds[b])
                                != core::cmp::Ordering::Less => {}
                        _ => best = Some(i),
                    }
                }
                i += 1;
            }
            best
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FEAS: [bool; 4] = [false, true, true, true];
    const SECS: [f64; 4] = [9.0, 5.0, 2.0, 2.0];
    const PRIO: [u32; 4] = [3, 1, 2, 2];

    #[test]
    fn fifo_blocks_at_the_head() {
        assert_eq!(pick_next(FleetPolicy::Fifo, &FEAS, &SECS, &PRIO), None);
        assert_eq!(pick_next(FleetPolicy::Fifo, &[true, false], &[1.0, 1.0], &[0, 0]), Some(0));
    }

    #[test]
    fn backfill_takes_the_first_placeable() {
        assert_eq!(pick_next(FleetPolicy::BestFitPrice, &FEAS, &SECS, &PRIO), Some(1));
    }

    #[test]
    fn priority_takes_the_strongest_feasible_and_breaks_ties_by_arrival() {
        // entry 0 has the top priority but is infeasible; 2 and 3 tie at
        // priority 2 and the earlier arrival wins
        assert_eq!(pick_next(FleetPolicy::Priority, &FEAS, &SECS, &PRIO), Some(2));
    }

    #[test]
    fn shortest_priced_first_breaks_ties_by_arrival() {
        assert_eq!(pick_next(FleetPolicy::ShortestPricedFirst, &FEAS, &SECS, &PRIO), Some(2));
    }

    #[test]
    fn empty_and_infeasible_queues_dispatch_nothing() {
        for policy in FleetPolicy::ALL {
            assert_eq!(pick_next(policy, &[], &[], &[]), None);
            assert_eq!(pick_next(policy, &[false; 3], &[1.0; 3], &[0; 3]), None);
        }
    }

    #[test]
    fn policy_names_round_trip() {
        for p in FleetPolicy::ALL {
            assert_eq!(FleetPolicy::by_name(p.name()), Some(p));
        }
        assert_eq!(FleetPolicy::by_name("lifo"), None);
    }
}
