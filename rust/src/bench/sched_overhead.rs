//! Scheduler-overhead benchmark behind `skrull sched-bench` and
//! `benches/sched_overhead.rs`.
//!
//! Two sweeps share one report:
//!
//! * **Overhead rows** — Section 4.3's "near-zero cost online scheduling"
//!   claim: wall-clock of the full GDS+DACP pass per iteration vs the
//!   simulated iteration time it schedules, across paper-scale batch
//!   sizes, with the pre-fast-path reference as the speedup baseline.
//! * **Scaling rows** — the million-sequence curve: scheduling time at
//!   K = 2^12 … 2^20 through the sharded hot path (no reference timing
//!   there — the reference is deliberately quadratic-ish and exists for
//!   oracle tests, not for stress scale), plus the incremental-mode
//!   steady-state time on a repeated batch.
//!
//! `render_json` emits `BENCH_sched_overhead.json` (schema v2) and
//! `validate_json` is the CI gate: required keys, finite values, strictly
//! increasing K, a near-linear K-scaling bound, and the <1% overhead
//! claim itself.

use std::fmt::Write as _;

use crate::bench::harness::{finite_values, json_str, require_count, require_top_keys, values_after};
use crate::bench::{measure, Measurement, TableBuilder};
use crate::cluster::simulate_iteration;
use crate::config::ExperimentConfig;
use crate::data::{Dataset, LengthDistribution};
use crate::model::ModelSpec;
use crate::perfmodel::{CostModel, FlopsModel};
use crate::rng::Rng;
use crate::scheduler::gds::{self, GdsConfig, SchedCtx};
use crate::util::error::Result;

/// What one bench run measures.
#[derive(Clone, Debug)]
pub struct SchedBenchOptions {
    pub model: ModelSpec,
    pub dataset: String,
    /// Batch sizes for the overhead sweep (fast vs refined vs reference,
    /// overhead ratio against the simulated iteration).
    pub overhead_ks: Vec<usize>,
    /// Batch sizes for the K-scaling curve (sharded fast path only).
    pub scaling_ks: Vec<usize>,
    /// Shard count for the scaling sweep; 0 = auto (one per core).
    pub shards: usize,
    /// (warmup, samples) for the scaling sweep — kept small, the larger
    /// K's already take O(seconds) per call.
    pub scaling_reps: (usize, usize),
}

impl SchedBenchOptions {
    /// The paper-scale run: overhead at K ≤ 4096, scaling to K = 2^20.
    pub fn paper_default() -> Self {
        SchedBenchOptions {
            model: ModelSpec::qwen2_5_0_5b(),
            dataset: "wikipedia".to_string(),
            overhead_ks: vec![16, 64, 256, 1024, 4096],
            // 2^12 … 2^20 in 4x steps — the near-linear claim's x-axis
            scaling_ks: vec![1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20],
            shards: 0,
            scaling_reps: (1, 3),
        }
    }

    /// CI smoke: same shape, reduced K so the gate runs in seconds.
    pub fn smoke() -> Self {
        SchedBenchOptions {
            overhead_ks: vec![16, 64, 256],
            scaling_ks: vec![1 << 12, 1 << 14, 1 << 16],
            scaling_reps: (1, 2),
            ..Self::paper_default()
        }
    }
}

/// One overhead-sweep batch size.
#[derive(Clone, Debug)]
pub struct OverheadRow {
    pub k: usize,
    pub fast: Measurement,
    pub refined: Measurement,
    pub reference: Measurement,
    pub iter_time_s: f64,
    pub overhead_ratio: f64,
}

/// One K-scaling batch size (sharded fast path; no reference timing).
#[derive(Clone, Debug)]
pub struct ScalingRow {
    pub k: usize,
    pub shards: usize,
    pub sched_mean_s: f64,
    pub per_seq_us: f64,
    /// steady-state time on a repeated batch with `incremental = true`
    /// (partition replay + per-rank cache hits)
    pub incremental_mean_s: f64,
}

#[derive(Clone, Debug)]
pub struct SchedBenchReport {
    pub cfg: ExperimentConfig,
    pub rows: Vec<OverheadRow>,
    pub scaling: Vec<ScalingRow>,
    /// worst sched/iter ratio across paper-scale batches (K ≤ 64)
    pub worst_paper_scale_ratio: f64,
}

/// Slack factor for the near-linear gate: end-to-end time may grow at
/// most `slack × (k_max / k_min)` across the scaling curve.  Generous on
/// purpose — it forbids quadratic blow-up, not cache effects or timer
/// noise.
pub const NEAR_LINEAR_SLACK: f64 = 8.0;

/// Run both sweeps.  Everything is deterministic except the wall-clock
/// readings themselves.
pub fn run(opts: &SchedBenchOptions) -> Result<SchedBenchReport> {
    let cfg = ExperimentConfig::paper_default(opts.model.clone(), &opts.dataset);
    let dist = LengthDistribution::by_name(&opts.dataset)
        .ok_or_else(|| crate::anyhow!("unknown dataset {:?}", opts.dataset))?;
    let ds = Dataset::synthesize(&dist, 100_000, 7).truncated(cfg.bucket_size * cfg.cluster.cp as u32);
    let cost = CostModel::paper_default(&cfg.model);
    let flops = FlopsModel::new(&cfg.model);
    let gcfg = GdsConfig::new(cfg.bucket_size, cfg.cluster.cp, cfg.cluster.dp);

    let mut rng = Rng::seed_from_u64(99);
    let mut worst_ratio: f64 = 0.0;
    let mut rows: Vec<OverheadRow> = Vec::new();
    let mut ctx = SchedCtx::default();
    for &k in &opts.overhead_ks {
        let batch = ds.sample_batch(&mut rng, k);
        // fewer samples at stress scale — the reference path is the
        // pre-fast-path scheduler and is deliberately slow there
        let (warmup, samples) = if k <= 256 { (3, 20) } else { (1, 5) };
        let fast = measure(&format!("gds k={k}"), warmup, samples, || {
            // skrull-lint: allow(panic-in-lib) -- measure() closures can't propagate Result; a failed schedule invalidates the whole benchmark
            let _ = gds::schedule_with_ctx(&batch, &gcfg, &flops, &mut ctx).expect("schedule");
        });
        let refined = measure(&format!("gds+refine k={k}"), warmup, samples, || {
            // skrull-lint: allow(panic-in-lib) -- measure() closures can't propagate Result; a failed schedule invalidates the whole benchmark
            gds::schedule_refined_with_ctx(&batch, &gcfg, &cost, &mut ctx).expect("schedule");
        });
        let reference =
            measure(&format!("gds reference k={k}"), warmup.min(1), samples.min(5), || {
                // skrull-lint: allow(panic-in-lib) -- measure() closures can't propagate Result; a failed schedule invalidates the whole benchmark
                let _ = gds::schedule_reference(&batch, &gcfg, &flops).expect("schedule");
            });
        let sched = gds::schedule(&batch, &gcfg, &flops)?;
        let iter_time = simulate_iteration(&sched, &cost, cfg.cluster.cp).total_time;
        let overhead_ratio = fast.mean_s() / iter_time;
        if k <= 64 {
            worst_ratio = worst_ratio.max(overhead_ratio);
        }
        rows.push(OverheadRow { k, fast, refined, reference, iter_time_s: iter_time, overhead_ratio });
    }

    let shards = if opts.shards == 0 {
        crate::util::par::max_threads().max(1)
    } else {
        opts.shards
    };
    let (warmup, samples) = opts.scaling_reps;
    let mut scaling: Vec<ScalingRow> = Vec::new();
    // fresh arenas per mode so the plain sweep can't warm the incremental
    // one (or vice versa)
    let mut sctx = SchedCtx::default();
    let mut ictx = SchedCtx::default();
    let mut sharded_cfg = gcfg.clone();
    sharded_cfg.shards = shards;
    let mut inc_cfg = sharded_cfg.clone();
    inc_cfg.incremental = true;
    for &k in &opts.scaling_ks {
        let batch = ds.sample_batch(&mut rng, k);
        let m = measure(&format!("gds sharded k={k}"), warmup, samples, || {
            // skrull-lint: allow(panic-in-lib) -- measure() closures can't propagate Result; a failed schedule invalidates the whole benchmark
            gds::schedule_with_ctx(&batch, &sharded_cfg, &flops, &mut sctx).expect("schedule");
        });
        // warmup ≥ 1 means the measured calls all replay the cached
        // solution — this is the steady-state repeated-batch number
        let m_inc = measure(&format!("gds incremental k={k}"), warmup.max(1), samples, || {
            // skrull-lint: allow(panic-in-lib) -- measure() closures can't propagate Result; a failed schedule invalidates the whole benchmark
            gds::schedule_with_ctx(&batch, &inc_cfg, &flops, &mut ictx).expect("schedule");
        });
        scaling.push(ScalingRow {
            k,
            shards,
            sched_mean_s: m.mean_s(),
            per_seq_us: m.mean_s() * 1e6 / k as f64,
            incremental_mean_s: m_inc.mean_s(),
        });
    }

    Ok(SchedBenchReport { cfg, rows, scaling, worst_paper_scale_ratio: worst_ratio })
}

/// Print both sweeps as human-readable tables.
pub fn print_report(r: &SchedBenchReport) {
    let fmt = crate::util::fmt_secs;
    let mut table = TableBuilder::new("Scheduler overhead (GDS+DACP)").header(&[
        "BatchSize K",
        "sched time",
        "+refine",
        "reference",
        "speedup",
        "iter time (sim)",
        "overhead",
    ]);
    for row in &r.rows {
        table.row(&[
            row.k.to_string(),
            fmt(row.fast.mean_s()),
            fmt(row.refined.mean_s()),
            fmt(row.reference.mean_s()),
            format!("{:.1}x", row.reference.mean_s() / row.fast.mean_s().max(1e-12)),
            fmt(row.iter_time_s),
            format!("{:.3}%", 100.0 * row.overhead_ratio),
        ]);
    }
    table.print();
    println!(
        "worst overhead at paper-scale batches (K≤64): {:.3}%",
        100.0 * r.worst_paper_scale_ratio
    );
    println!();
    let mut table = TableBuilder::new(&format!(
        "K-scaling, sharded fast path ({} shard{})",
        r.scaling.first().map_or(0, |s| s.shards),
        if r.scaling.first().map_or(0, |s| s.shards) == 1 { "" } else { "s" }
    ))
    .header(&["BatchSize K", "sched time", "per-seq", "incremental (repeat)"]);
    for row in &r.scaling {
        table.row(&[
            row.k.to_string(),
            fmt(row.sched_mean_s),
            format!("{:.2}us", row.per_seq_us),
            fmt(row.incremental_mean_s),
        ]);
    }
    table.print();
}

/// Render the machine-trackable `BENCH_sched_overhead.json` (schema v2:
/// v1's overhead rows plus the `scaling_rows` curve).
pub fn render_json(r: &SchedBenchReport) -> String {
    let cfg = &r.cfg;
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"bench\": \"sched_overhead\",");
    let _ = writeln!(out, "  \"schema_version\": 2,");
    let _ = writeln!(
        out,
        "  \"config\": {{\"model\": \"{}\", \"dataset\": \"{}\", \"dp\": {}, \"cp\": {}, \"bucket_size\": {}}},",
        json_str(&cfg.model.name),
        json_str(&cfg.dataset),
        cfg.cluster.dp,
        cfg.cluster.cp,
        cfg.bucket_size
    );
    out.push_str("  \"rows\": [\n");
    for (i, row) in r.rows.iter().enumerate() {
        let _ = writeln!(
            out,
            "    {{\"k\": {}, \"sched_mean_s\": {:e}, \"sched_p50_s\": {:e}, \"refine_mean_s\": {:e}, \
             \"reference_mean_s\": {:e}, \"speedup_vs_reference\": {:.3}, \"iter_time_s\": {:e}, \
             \"overhead_ratio\": {:e}}}{}",
            row.k,
            row.fast.mean_s(),
            row.fast.samples.quantile(0.5),
            row.refined.mean_s(),
            row.reference.mean_s(),
            row.reference.mean_s() / row.fast.mean_s().max(1e-12),
            row.iter_time_s,
            row.overhead_ratio,
            if i + 1 == r.rows.len() { "" } else { "," }
        );
    }
    out.push_str("  ],\n");
    // scaling keys are all "scaling_"-prefixed so the key-occurrence
    // scans below never mix the two row kinds
    out.push_str("  \"scaling_rows\": [\n");
    for (i, row) in r.scaling.iter().enumerate() {
        let _ = writeln!(
            out,
            "    {{\"scaling_k\": {}, \"scaling_shards\": {}, \"scaling_sched_mean_s\": {:e}, \
             \"scaling_per_seq_us\": {:e}, \"scaling_incremental_mean_s\": {:e}}}{}",
            row.k,
            row.shards,
            row.sched_mean_s,
            row.per_seq_us,
            row.incremental_mean_s,
            if i + 1 == r.scaling.len() { "" } else { "," }
        );
    }
    out.push_str("  ],\n");
    let _ = writeln!(out, "  \"worst_paper_scale_ratio\": {:e},", r.worst_paper_scale_ratio);
    let _ =
        writeln!(out, "  \"near_zero_overhead_pass\": {}", r.worst_paper_scale_ratio < 0.01);
    out.push_str("}\n");
    out
}

const REQUIRED_TOP_KEYS: [&str; 7] = [
    "\"bench\"",
    "\"schema_version\"",
    "\"config\"",
    "\"rows\"",
    "\"scaling_rows\"",
    "\"worst_paper_scale_ratio\"",
    "\"near_zero_overhead_pass\"",
];

const REQUIRED_ROW_KEYS: [&str; 8] = [
    "k",
    "sched_mean_s",
    "sched_p50_s",
    "refine_mean_s",
    "reference_mean_s",
    "speedup_vs_reference",
    "iter_time_s",
    "overhead_ratio",
];

const REQUIRED_SCALING_KEYS: [&str; 5] = [
    "scaling_k",
    "scaling_shards",
    "scaling_sched_mean_s",
    "scaling_per_seq_us",
    "scaling_incremental_mean_s",
];

/// CI gate: does `text` look like a complete, sane
/// `BENCH_sched_overhead.json`?  Checks required top-level / per-row
/// keys, finiteness everywhere, strictly increasing K in both sweeps, the
/// near-linear K-scaling bound (`NEAR_LINEAR_SLACK`), and the near-zero-
/// overhead claim (`worst_paper_scale_ratio < 1%`, `near_zero_overhead_pass`
/// true).
pub fn validate_json(text: &str) -> Result<()> {
    require_top_keys(text, &REQUIRED_TOP_KEYS)?;
    let version: u64 = values_after(text, "schema_version")
        .first()
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| crate::anyhow!("unparsable schema_version"))?;
    crate::ensure!(version >= 2, "schema_version {version} predates v2");

    // overhead rows
    let n_rows = values_after(text, "k").len();
    crate::ensure!(n_rows > 0, "no overhead rows");
    for key in REQUIRED_ROW_KEYS {
        require_count(text, key, n_rows, "row")?;
    }
    for key in ["sched_mean_s", "refine_mean_s", "reference_mean_s", "iter_time_s", "overhead_ratio"]
    {
        for x in finite_values(text, key)? {
            crate::ensure!(x >= 0.0, "\"{key}\" = {x} is negative");
        }
    }
    let ks = finite_values(text, "k")?;
    crate::ensure!(ks.windows(2).all(|w| w[0] < w[1]), "overhead K values not increasing");

    // scaling rows
    let n_scaling = values_after(text, "scaling_k").len();
    crate::ensure!(n_scaling >= 2, "need at least 2 scaling rows, got {n_scaling}");
    for key in REQUIRED_SCALING_KEYS {
        require_count(text, key, n_scaling, "scaling")?;
    }
    let sks = finite_values(text, "scaling_k")?;
    crate::ensure!(sks.windows(2).all(|w| w[0] < w[1]), "scaling K values not increasing");
    let times = finite_values(text, "scaling_sched_mean_s")?;
    finite_values(text, "scaling_per_seq_us")?;
    finite_values(text, "scaling_incremental_mean_s")?;
    crate::ensure!(times.iter().all(|&t| t > 0.0), "non-positive scaling time");
    let (k_lo, k_hi) = (sks[0], sks[sks.len() - 1]);
    crate::ensure!(k_hi / k_lo >= 4.0, "scaling curve spans < 4x in K — no linearity signal");
    // near-linear gate: growth bounded by slack × the K ratio, end to end
    // and between consecutive points (the latter catches a superlinear
    // knee that end-to-end slack would forgive)
    let grow = times[times.len() - 1] / times[0];
    crate::ensure!(
        grow <= NEAR_LINEAR_SLACK * (k_hi / k_lo),
        "scheduling time grew {grow:.1}x over a {:.0}x K range — not near-linear",
        k_hi / k_lo
    );
    for i in 1..times.len() {
        let g = times[i] / times[i - 1];
        crate::ensure!(
            g <= NEAR_LINEAR_SLACK * (sks[i] / sks[i - 1]),
            "scheduling time jumped {g:.1}x from K={} to K={}",
            sks[i - 1],
            sks[i]
        );
    }

    // the claim itself
    let worst: f64 = values_after(text, "worst_paper_scale_ratio")
        .first()
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| crate::anyhow!("unparsable worst_paper_scale_ratio"))?;
    crate::ensure!(
        worst.is_finite() && (0.0..0.01).contains(&worst),
        "worst_paper_scale_ratio {worst} violates the <1% overhead claim"
    );
    crate::ensure!(
        values_after(text, "near_zero_overhead_pass").first() == Some(&"true"),
        "near_zero_overhead_pass is not true"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A structurally complete report with hand-set timings — the
    /// validator is pure text, so golden JSON keeps these tests free of
    /// wall-clock noise (debug-build timings would trip the <1% gate).
    fn golden() -> String {
        let mut r = SchedBenchReport {
            cfg: ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia"),
            rows: Vec::new(),
            scaling: Vec::new(),
            worst_paper_scale_ratio: 0.002,
        };
        for (i, k) in [16usize, 64].into_iter().enumerate() {
            let m = |name: &str, s: f64| {
                let mut sum = crate::util::stats::Summary::new();
                sum.push(s);
                Measurement { name: name.to_string(), samples: sum }
            };
            r.rows.push(OverheadRow {
                k,
                fast: m("fast", 1e-4 * (i + 1) as f64),
                refined: m("refined", 2e-4),
                reference: m("reference", 5e-3),
                iter_time_s: 2.0,
                overhead_ratio: 0.002,
            });
        }
        for (i, k) in [4096usize, 16384, 65536].into_iter().enumerate() {
            let t = 1e-3 * 4f64.powi(i as i32); // exactly linear in K
            r.scaling.push(ScalingRow {
                k,
                shards: 4,
                sched_mean_s: t,
                per_seq_us: t * 1e6 / k as f64,
                incremental_mean_s: t / 10.0,
            });
        }
        render_json(&r)
    }

    #[test]
    fn golden_report_renders_and_validates() {
        let json = golden();
        assert!(json.contains("\"schema_version\": 2"));
        assert!(json.contains("\"scaling_k\": 65536"));
        validate_json(&json).unwrap();
    }

    #[test]
    fn validator_rejects_missing_keys_and_bad_values() {
        let json = golden();
        // dropped top-level key
        let broken = json.replace("\"scaling_rows\"", "\"scaling_rowz\"");
        assert!(validate_json(&broken).is_err());
        // a scaling row loses a field
        let broken = json.replacen("\"scaling_shards\"", "\"scaling_shardz\"", 1);
        assert!(validate_json(&broken).is_err());
        // non-finite timing
        let sample = values_after(&json, "scaling_sched_mean_s")[0].to_string();
        let broken = json.replacen(&sample, "NaN", 1);
        assert!(validate_json(&broken).is_err());
        // overhead claim violated
        let broken = json
            .replace("\"near_zero_overhead_pass\": true", "\"near_zero_overhead_pass\": false");
        assert!(validate_json(&broken).is_err());
    }

    #[test]
    fn validator_rejects_superlinear_scaling() {
        let json = golden();
        // blow up the largest-K time far past slack × K-ratio
        let last = values_after(&json, "scaling_sched_mean_s")[2].to_string();
        let broken = json.replacen(&last, "1e3", 1);
        assert!(validate_json(&broken).is_err());
    }

    #[test]
    fn tiny_live_run_produces_structurally_valid_rows() {
        // real measurements at toy K — checks run()'s plumbing without
        // gating on debug-build wall-clock ratios
        let opts = SchedBenchOptions {
            overhead_ks: vec![8, 16],
            scaling_ks: vec![32, 128],
            shards: 2,
            scaling_reps: (0, 1),
            ..SchedBenchOptions::smoke()
        };
        let r = run(&opts).unwrap();
        assert_eq!(r.rows.len(), 2);
        assert_eq!(r.scaling.len(), 2);
        assert!(r.scaling.iter().all(|s| s.shards == 2));
        assert!(r.scaling.iter().all(|s| {
            s.sched_mean_s > 0.0
                && s.per_seq_us.is_finite()
                && s.incremental_mean_s > 0.0
        }));
        assert!(r.rows.iter().all(|row| row.overhead_ratio.is_finite()));
        // the rendered text carries both row kinds
        let json = render_json(&r);
        assert_eq!(values_after(&json, "k").len(), 2);
        assert_eq!(values_after(&json, "scaling_k").len(), 2);
    }
}
