//! Bench harness shared by every `BENCH_*.json` producer: wall-clock
//! measurement (warmup + N samples, summary statistics) and the
//! hand-rolled JSON validator plumbing (`values_after` token scanning,
//! finiteness checks, key-count assertions) that `bench::e2e`,
//! `bench::sched_overhead` and `bench::fleet` all gate CI with.

use std::time::Instant;

use crate::util::error::Result;
use crate::util::stats::Summary;

#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Summary,
}

impl Measurement {
    pub fn mean_s(&self) -> f64 {
        self.samples.mean()
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} mean {:>10}  p50 {:>10}  min {:>10}  max {:>10}  (n={})",
            self.name,
            crate::util::fmt_secs(self.samples.mean()),
            crate::util::fmt_secs(self.samples.quantile(0.5)),
            crate::util::fmt_secs(self.samples.min()),
            crate::util::fmt_secs(self.samples.max()),
            self.samples.len(),
        )
    }
}

/// Run `f` `warmup` times unmeasured, then `samples` measured repetitions.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut summary = Summary::new();
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        summary.push(t0.elapsed().as_secs_f64());
    }
    Measurement { name: name.to_string(), samples: summary }
}

/// Keep the hand-rolled JSON writers honest: every string we emit is
/// identifier-ish, so anything that would need escaping is a bug in the
/// caller, not a rendering case to support.
pub fn json_str(s: &str) -> &str {
    assert!(!s.contains(['"', '\\', '\n']), "unescapable: {s}");
    s
}

/// Every value token following `"key":` occurrences, in file order — the
/// substrate of all `BENCH_*.json` validators (no serde in the image, so
/// validation is text scanning over the renderer's known output shape).
pub fn values_after<'a>(text: &'a str, key: &str) -> Vec<&'a str> {
    let needle = format!("\"{key}\":");
    let mut out = Vec::new();
    let mut rest = text;
    while let Some(pos) = rest.find(&needle) {
        rest = &rest[pos + needle.len()..];
        let tail = rest.trim_start();
        let end = tail.find([',', '}', '\n']).unwrap_or(tail.len());
        out.push(tail[..end].trim());
    }
    out
}

/// All of `key`'s values parsed as finite `f64`s, or a structured error
/// naming the first offender.
pub fn finite_values(text: &str, key: &str) -> Result<Vec<f64>> {
    values_after(text, key)
        .iter()
        .enumerate()
        .map(|(i, v)| {
            let x: f64 = v.parse().map_err(|_| {
                crate::anyhow!("entry {i}: \"{key}\" value {v:?} is not a number")
            })?;
            crate::ensure!(x.is_finite(), "entry {i}: \"{key}\" = {v} is not finite");
            Ok(x)
        })
        .collect()
}

/// Require every listed top-level key (pre-quoted, e.g. `"\"bench\""`) to
/// appear as `key:` at least once.
pub fn require_top_keys(text: &str, keys: &[&str]) -> Result<()> {
    for key in keys {
        crate::ensure!(text.contains(&format!("{key}:")), "missing top-level key {key}");
    }
    Ok(())
}

/// Require `key` to appear exactly `expected` times (`what` names the row
/// kind in the error, e.g. "cell").
pub fn require_count(text: &str, key: &str, expected: usize, what: &str) -> Result<()> {
    let n = values_after(text, key).len();
    crate::ensure!(n == expected, "{what} key \"{key}\" appears {n} times, expected {expected}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_collects_samples() {
        let mut count = 0usize;
        let m = measure("noop", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(m.samples.len(), 5);
        assert!(m.mean_s() >= 0.0);
        assert!(m.report().contains("noop"));
    }

    #[test]
    fn measure_orders_timings_sanely() {
        let slow = measure("slow", 0, 3, || std::thread::sleep(std::time::Duration::from_millis(2)));
        let fast = measure("fast", 0, 3, || {});
        assert!(slow.mean_s() > fast.mean_s());
    }

    #[test]
    fn values_after_extracts_tokens() {
        let text = r#"{"a": 1, "b": "x", "a": 2.5}"#;
        assert_eq!(values_after(text, "a"), vec!["1", "2.5"]);
        assert_eq!(values_after(text, "b"), vec!["\"x\""]);
        assert!(values_after(text, "c").is_empty());
    }

    #[test]
    fn finite_values_parses_and_rejects() {
        let text = r#"{"t": 1.5, "t": 2e-3, "bad": NaN, "word": "x"}"#;
        assert_eq!(finite_values(text, "t").unwrap(), vec![1.5, 2e-3]);
        assert!(finite_values(text, "bad").is_err());
        assert!(finite_values(text, "word").is_err());
        assert!(finite_values(text, "absent").unwrap().is_empty());
    }

    #[test]
    fn key_requirements_gate_presence_and_counts() {
        let text = r#"{"bench": "x", "rows": [{"k": 1}, {"k": 2}]}"#;
        require_top_keys(text, &["\"bench\"", "\"rows\""]).unwrap();
        assert!(require_top_keys(text, &["\"missing\""]).is_err());
        require_count(text, "k", 2, "row").unwrap();
        let err = require_count(text, "k", 3, "row").unwrap_err().to_string();
        assert!(err.contains("appears 2 times, expected 3"), "{err}");
    }

    #[test]
    fn json_str_passes_identifier_ish_strings() {
        assert_eq!(json_str("best-fit-price"), "best-fit-price");
    }
}
