//! Timing harness: warmup + N samples, summary statistics.

use std::time::Instant;

use crate::util::stats::Summary;

#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Summary,
}

impl Measurement {
    pub fn mean_s(&self) -> f64 {
        self.samples.mean()
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} mean {:>10}  p50 {:>10}  min {:>10}  max {:>10}  (n={})",
            self.name,
            crate::util::fmt_secs(self.samples.mean()),
            crate::util::fmt_secs(self.samples.quantile(0.5)),
            crate::util::fmt_secs(self.samples.min()),
            crate::util::fmt_secs(self.samples.max()),
            self.samples.len(),
        )
    }
}

/// Run `f` `warmup` times unmeasured, then `samples` measured repetitions.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut summary = Summary::new();
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        summary.push(t0.elapsed().as_secs_f64());
    }
    Measurement { name: name.to_string(), samples: summary }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_collects_samples() {
        let mut count = 0usize;
        let m = measure("noop", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(m.samples.len(), 5);
        assert!(m.mean_s() >= 0.0);
        assert!(m.report().contains("noop"));
    }

    #[test]
    fn measure_orders_timings_sanely() {
        let slow = measure("slow", 0, 3, || std::thread::sleep(std::time::Duration::from_millis(2)));
        let fast = measure("fast", 0, 3, || {});
        assert!(slow.mean_s() > fast.mean_s());
    }
}
