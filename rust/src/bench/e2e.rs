//! End-to-end experiment engine (the paper's Section 5 evaluation, as a
//! sweep): every scheduling policy × length distribution × cluster
//! topology, played for N iterations (or one full epoch) through the run
//! engine (`cluster::run`), with per-cell total wall-clock, speedup vs the
//! DeepSpeed-like baseline, utilization, exposed-scheduling-overhead
//! fraction and — since the memplan subsystem — peak-memory fraction and
//! modeled OOM count.  A seed list turns every cell into a mean/stddev
//! pair so trajectory comparisons are noise-aware.
//!
//! The sweep is **build-once/price-many** (`cluster::run::{build_run,
//! price_run}`): each cell drives the scheduler exactly once — per-cell
//! `sched_invocations` makes that machine-visible — and a calibrated sweep
//! computes `estimator_error` by *repricing* the already-built schedules
//! under the analytic model instead of re-running GDS/DACP.  Cells fan out
//! over `opts.jobs` scoped worker threads (`util::par::map_up_to`,
//! `--jobs`); results are reduced serially in grid order, so the emitted
//! JSON is byte-identical regardless of job count (measured wall-clock
//! aside — pin it with `deterministic_timing` for exact comparisons).
//! Emits the machine-readable `BENCH_e2e.json` that tracks the repo's
//! headline number across PRs (`skrull e2e`), and validates it for CI
//! (`skrull e2e --validate`).

use std::fmt::Write as _;
use std::path::Path;
use std::time::Instant;

use crate::bench::harness::{finite_values, json_str, require_count, require_top_keys, values_after};
use crate::cluster::run::{
    build_run, build_run_streamed, price_run, schedule_digest, RunConfig, RunReport,
};
use crate::cluster::Topology;
use crate::config::{CostSource, ExperimentConfig, Policy};
use crate::data::{Dataset, LengthDistribution};
use crate::memplan::MemoryConfig;
use crate::model::ModelSpec;
use crate::perfmodel::CostModel;
use crate::stream::{ingest_dataset, IngestReport, StreamConfig, StreamSource};
use crate::util::error::{Context, Result};
use crate::util::par;
use crate::util::stats::Summary;

/// Sweep order: the baseline must come first so every other cell of the
/// same (dataset, topology) can report speedup against it.
pub const ALL_POLICIES: [Policy; 5] = [
    Policy::Baseline,
    Policy::SortedBatching,
    Policy::DacpOnly,
    Policy::Skrull,
    Policy::SkrullRefined,
];

/// Per-iteration scheduling wall-clock substituted under
/// `E2eOptions::deterministic_timing` (1 µs — small enough to keep the
/// near-zero-overhead picture, nonzero so the exposure math still runs).
pub const DETERMINISTIC_SCHED_SECONDS: f64 = 1e-6;

/// Sweep parameters.
#[derive(Clone, Debug)]
pub struct E2eOptions {
    pub model: ModelSpec,
    pub datasets: Vec<String>,
    /// (dp, cp) pairs; validated against the paper's 4×8-GPU testbed.
    pub topologies: Vec<(usize, usize)>,
    pub iterations: usize,
    /// None = the paper default for each (model, dataset) cell.
    pub batch_size: Option<usize>,
    /// synthesized dataset size per distribution
    pub dataset_samples: usize,
    /// One full run per seed (workload synthesis + batch sampling); the
    /// first seed is the primary run every legacy field reports, the rest
    /// feed the per-cell mean/stddev.
    pub seeds: Vec<u64>,
    pub pipelined: bool,
    /// Play one full shuffled epoch per cell instead of `iterations`
    /// i.i.d. batches (`Dataset::epoch_batches`).
    pub epoch: bool,
    /// Memory subsystem settings applied to every cell (capacity source,
    /// HBM budget, recompute policy — see `memplan`).
    pub memory: MemoryConfig,
    /// Cost/memory coefficient source applied to every cell.  Under
    /// `CostSource::Calibrated` each cell additionally reports
    /// `estimator_error` — the mean per-iteration relative deviation of
    /// the calibrated model's predictions from the analytic ground truth
    /// on the same schedules (the round-trip quality metric), computed by
    /// repricing the cell's built schedules, not by re-running them.
    pub cost: CostSource,
    /// Worker threads for the cell fan-out (`--jobs` / `run.jobs`);
    /// clamped ≥ 1, where 1 is the serial path.  Every cell is an
    /// independent (topology, dataset, seed, policy) unit, so the job
    /// count changes wall-clock only, never results.  With jobs > 1 each
    /// cell's scheduler runs single-threaded (`RunConfig::
    /// serial_scheduler`) so nested fan-outs don't oversubscribe the
    /// cores or inflate the measured `sched_seconds`; jobs == 1 keeps
    /// the scheduler's own per-rank fan-out, the pre-split behaviour.
    pub jobs: usize,
    /// Replace each cell's *measured* scheduling wall-clock with
    /// [`DETERMINISTIC_SCHED_SECONDS`] and report `sweep_seconds` as 0 —
    /// the only nondeterministic inputs pinned, so two sweeps (any job
    /// counts) emit byte-identical `BENCH_e2e.json`.  For determinism
    /// tests/CI; production sweeps keep real measurements.
    pub deterministic_timing: bool,
    /// Streaming out-of-core data plane (`--spill-dir`/`--stream-ram-mb`):
    /// when `stream.enabled()` the sweep spills every truncated workload
    /// to disk once, then builds each cell through the bounded-RAM page
    /// cache instead of the in-memory dataset.  Schedules are
    /// byte-identical either way — the CI gate `cmp`s the two modes'
    /// `--sched-digest` files.
    pub stream: StreamConfig,
}

impl E2eOptions {
    /// The paper's evaluation grid: 3 length distributions × 2 topologies.
    pub fn paper_default() -> Self {
        E2eOptions {
            model: ModelSpec::qwen2_5_0_5b(),
            datasets: vec![
                "wikipedia".into(),
                "lmsys".into(),
                "chatqa2".into(),
                "bursty-long".into(),
            ],
            topologies: vec![(4, 8), (2, 16)],
            iterations: 10,
            batch_size: None,
            dataset_samples: 20_000,
            seeds: vec![42],
            pipelined: true,
            epoch: false,
            memory: MemoryConfig::default(),
            cost: CostSource::Analytic,
            jobs: par::max_threads().max(1),
            deterministic_timing: false,
            stream: StreamConfig::default(),
        }
    }

    /// Tiny grid for CI smoke runs (still all 5 policies; two seeds so the
    /// variance fields are exercised).
    pub fn smoke() -> Self {
        let mut o = Self::paper_default();
        o.iterations = 2;
        o.batch_size = Some(8);
        o.dataset_samples = 2_000;
        o.seeds = vec![42, 43];
        o
    }
}

/// One sweep cell: simulated runs of one policy on one workload — the
/// primary seed's full report plus cross-seed statistics.
#[derive(Clone, Debug)]
pub struct E2eCell {
    pub policy: Policy,
    pub dataset: String,
    pub dp: usize,
    pub cp: usize,
    pub batch_size: usize,
    /// the first seed's run (the primary every scalar field reports)
    pub report: RunReport,
    pub speedup_vs_baseline: f64,
    /// mean per-iteration |calibrated − analytic| / analytic over this
    /// cell's primary run; 0.0 under `CostSource::Analytic` (the ground
    /// truth deviates from itself by nothing)
    pub estimator_error: f64,
    /// cross-seed statistics (single-seed sweeps have stddev 0)
    pub wall_mean: f64,
    pub wall_std: f64,
    pub speedup_mean: f64,
    pub speedup_std: f64,
    pub runs: usize,
    /// FNV-1a digest over the primary run's schedule bytes
    /// (`cluster::run::schedule_digest`) — identical for streamed and
    /// in-memory builds of the same cell
    pub sched_digest: u64,
}

/// The whole sweep.
#[derive(Clone, Debug)]
pub struct E2eSweep {
    pub model: String,
    pub iterations: usize,
    pub pipelined: bool,
    pub epoch: bool,
    pub seeds: Vec<u64>,
    /// `"analytic"` or `"calibrated"` — decides the validator's
    /// `estimator_error` gate.
    pub cost_source: String,
    /// measured wall-clock of the whole sweep (0.0 under
    /// `deterministic_timing`) — the harness's own speed, tracked across
    /// PRs alongside the numbers it produces
    pub sweep_seconds: f64,
    /// whether cells were built through the out-of-core data plane
    pub streamed: bool,
    /// the page-cache byte budget streamed cells ran under (0 in-memory)
    pub stream_ram_bytes: u64,
    pub cells: Vec<E2eCell>,
}

impl E2eSweep {
    pub fn cell(&self, policy: Policy, dataset: &str, dp: usize, cp: usize) -> Option<&E2eCell> {
        self.cells.iter().find(|c| {
            c.policy == policy && c.dataset == dataset && c.dp == dp && c.cp == cp
        })
    }
}

/// One fanned-out unit of sweep work: a (topology, dataset, seed, policy)
/// cell-run, independent of every other unit.
#[derive(Clone, Copy)]
struct CellJob {
    ti: usize,
    di: usize,
    si: usize,
    pi: usize,
}

/// What one cell-run produced (moved out of the fan-out by the reducer).
struct CellRun {
    report: RunReport,
    wall: f64,
    batch_size: usize,
    estimator_error: f64,
    digest: u64,
}

/// One cell group's shared experiment config (everything but the policy);
/// capacity resolution and workload truncation derive from it and are
/// policy-independent, so they are hoisted out of the per-policy cells.
fn cell_config(
    opts: &E2eOptions,
    name: &str,
    (dp, cp): (usize, usize),
    seed: u64,
) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_default(opts.model.clone(), name);
    cfg.cluster.dp = dp;
    cfg.cluster.cp = cp;
    if let Some(b) = opts.batch_size {
        cfg.cluster.batch_size = b;
    }
    cfg.seed = seed;
    cfg.pipelined = opts.pipelined;
    cfg.memory = opts.memory.clone();
    cfg.cost = opts.cost.clone();
    cfg
}

/// Build + price one cell: exactly one scheduling pass, however many
/// pricings the cost source needs.  `ds` arrives already truncated to the
/// group's resolved capacity.  When `stream` names a spill file and its
/// ingest report, the build goes through the out-of-core data plane
/// instead of `ds` — byte-identical schedules, bounded RAM.
fn run_cell(
    opts: &E2eOptions,
    ds: &Dataset,
    name: &str,
    (dp, cp): (usize, usize),
    seed: u64,
    policy: Policy,
    primary: bool,
    stream: Option<(&str, &IngestReport)>,
) -> Result<CellRun> {
    let mut cfg = cell_config(opts, name, (dp, cp), seed);
    cfg.policy = policy;
    let cost = cfg.cost_model();
    let mut run = if opts.epoch {
        RunConfig::epoch(opts.pipelined)
    } else {
        RunConfig::new(opts.iterations, opts.pipelined)
    };
    // the sweep already parallelizes across cells: keep each cell's
    // scheduler single-threaded so jobs × per-rank fan-outs don't
    // oversubscribe the cores and inflate the measured sched_seconds.
    // --jobs 1 keeps the scheduler's own fan-out, i.e. today's serial
    // sweep behaves exactly as before the cell fan-out existed.
    run.serial_scheduler = opts.jobs > 1;
    let mut built = match stream {
        Some((path, ingest)) => {
            let mut src = StreamSource::open(Path::new(path), &opts.stream)
                .map_err(|e| crate::anyhow!("opening spill {path}: {e}"))?;
            build_run_streamed(&mut src, ingest, &cfg, &run).with_context(|| {
                format!(
                    "{} on {name} <DP={dp},CP={cp}> seed {seed} (streamed)",
                    policy.name()
                )
            })?
        }
        None => build_run(ds, &cfg, &run).with_context(|| {
            format!("{} on {name} <DP={dp},CP={cp}> seed {seed}", policy.name())
        })?,
    };
    let digest = schedule_digest(&built);
    if opts.deterministic_timing {
        built.pin_sched_seconds(DETERMINISTIC_SCHED_SECONDS);
    }
    let report = price_run(&built, &cost, &built.topology);
    // calibration quality: *reprice* the same built schedules under the
    // analytic ground truth and compare per-iteration execution
    // predictions — zero additional GDS/DACP work (the pre-split engine
    // re-ran the whole scheduler here, ~2x scheduling per calibrated cell)
    let estimator_err = if primary && opts.cost.profile().is_some() {
        let analytic = CostModel::paper_default(&cfg.model);
        let truth = price_run(&built, &analytic, &built.topology);
        estimator_error(&report, &truth)
    } else {
        0.0
    };
    Ok(CellRun {
        wall: report.wall_seconds(),
        batch_size: cfg.cluster.batch_size,
        report,
        estimator_error: estimator_err,
        digest,
    })
}

/// Run the full sweep: for each (topology, dataset, seed), all policies
/// over the *same* synthesized workload, baseline first.  Cell-runs fan
/// out over `opts.jobs` workers; the reduction is serial and in grid
/// order, so output does not depend on the job count.
pub fn run_sweep(opts: &E2eOptions) -> Result<E2eSweep> {
    let t_sweep = Instant::now();
    crate::ensure!(
        opts.epoch || opts.iterations > 0,
        "e2e sweep needs at least 1 iteration (or --epoch)"
    );
    crate::ensure!(!opts.datasets.is_empty(), "e2e sweep needs at least one dataset");
    crate::ensure!(!opts.topologies.is_empty(), "e2e sweep needs at least one topology");
    crate::ensure!(!opts.seeds.is_empty(), "e2e sweep needs at least one seed");
    // a profile fitted on another model must not steer this sweep
    opts.cost.ensure_model(opts.model.name)?;
    for &(dp, cp) in &opts.topologies {
        // the paper's testbed bounds + power-of-two CP check
        Topology::paper_testbed(dp, cp)
            .with_context(|| format!("invalid topology dp={dp} cp={cp}"))?;
    }
    let dists: Vec<LengthDistribution> = opts
        .datasets
        .iter()
        .map(|name| {
            LengthDistribution::by_name(name)
                .with_context(|| format!("unknown dataset {name:?}"))
        })
        .collect::<Result<_>>()?;

    let np = ALL_POLICIES.len();
    let ns = opts.seeds.len();
    let jobs = opts.jobs.max(1);

    // hoisted per-(dataset, seed) dataset construction: the same untruncated
    // workload feeds every topology and policy (the per-topology loop used
    // to re-synthesize it); indexed di * ns + si
    let ds_keys: Vec<(usize, usize)> = (0..opts.datasets.len())
        .flat_map(|di| (0..ns).map(move |si| (di, si)))
        .collect();
    let base_datasets: Vec<Dataset> = par::map_up_to(jobs, &ds_keys, |_, &(di, si)| {
        Dataset::synthesize(&dists[di], opts.dataset_samples, opts.seeds[si] ^ 0xD5)
    });

    // hoisted per-(topology, dataset, seed) capacity resolution +
    // truncation: both are policy-independent, so one truncated workload
    // serves a group's five policy cells; indexed (ti * nd + di) * ns + si
    let nd = opts.datasets.len();
    let trunc_keys: Vec<(usize, usize, usize)> = (0..opts.topologies.len())
        .flat_map(|ti| (0..nd).flat_map(move |di| (0..ns).map(move |si| (ti, di, si))))
        .collect();
    let truncated: Vec<Dataset> = par::map_up_to(jobs, &trunc_keys, |_, &(ti, di, si)| {
        let (dp, cp) = opts.topologies[ti];
        let name = &opts.datasets[di];
        let cfg = cell_config(opts, name, (dp, cp), opts.seeds[si])
            .resolve_capacity()
            .with_context(|| format!("resolving capacity for {name} <DP={dp},CP={cp}>"))?;
        Ok(base_datasets[di * ns + si].truncated(cfg.bucket_size * cp as u32))
    })
    .into_iter()
    .collect::<Result<_>>()?;

    // streaming pre-pass: spill every truncated workload to disk exactly
    // once, *before* the parallel cell grid — cells then open the store
    // read-only, so the fan-out stays race-free.  One ingest pass per
    // (topology, dataset, seed) group carries the reservoir length sketch
    // and any drift events into every policy cell of that group.
    let streamed = opts.stream.enabled();
    let spill_paths: Vec<String> = trunc_keys
        .iter()
        .map(|&(ti, di, si)| match &opts.stream.spill_dir {
            Some(dir) => format!("{dir}/cell-{ti}-{di}-{si}.spill"),
            None => String::new(),
        })
        .collect();
    let ingests: Vec<Option<IngestReport>> = if streamed {
        let dir = opts.stream.spill_dir.as_deref().unwrap_or(".");
        std::fs::create_dir_all(dir).with_context(|| format!("creating spill dir {dir}"))?;
        par::map_up_to(jobs, &trunc_keys, |_, &(ti, di, si)| {
            let idx = (ti * nd + di) * ns + si;
            ingest_dataset(
                &truncated[idx],
                Path::new(&spill_paths[idx]),
                &opts.stream,
                opts.seeds[si],
            )
            .map(Some)
            .map_err(|e| crate::anyhow!("spilling {}: {e}", spill_paths[idx]))
        })
        .into_iter()
        .collect::<Result<_>>()?
    } else {
        (0..trunc_keys.len()).map(|_| None).collect()
    };

    // one job per (topology, dataset, seed, policy), in grid order — the
    // same order the serial reduction below consumes them in
    let cell_jobs: Vec<CellJob> = (0..opts.topologies.len())
        .flat_map(|ti| {
            (0..opts.datasets.len()).flat_map(move |di| {
                (0..ns).flat_map(move |si| (0..np).map(move |pi| CellJob { ti, di, si, pi }))
            })
        })
        .collect();
    // round-robin permutation before the contiguous-chunking fan-out:
    // each worker's chunk takes a *strided* slice of the grid, so
    // heterogeneous cell costs (a slow topology or dataset clustered
    // together in grid order) spread evenly instead of serializing on one
    // worker.  Results are scattered back to grid order, so the output is
    // independent of both the permutation and the job count.
    let n_cells = cell_jobs.len();
    let stride = jobs.min(n_cells).max(1);
    let order: Vec<usize> = (0..stride)
        .flat_map(|c| (c..n_cells).step_by(stride))
        .collect();
    let permuted: Vec<CellJob> = order.iter().map(|&gi| cell_jobs[gi]).collect();
    let permuted_results = par::map_up_to(jobs, &permuted, |_, job| {
        let &CellJob { ti, di, si, pi } = job;
        let idx = (ti * nd + di) * ns + si;
        let stream = ingests[idx].as_ref().map(|ing| (spill_paths[idx].as_str(), ing));
        Some(run_cell(
            opts,
            &truncated[idx],
            &opts.datasets[di],
            opts.topologies[ti],
            opts.seeds[si],
            ALL_POLICIES[pi],
            si == 0,
            stream,
        ))
    });
    let mut results: Vec<Option<Result<CellRun>>> = (0..n_cells).map(|_| None).collect();
    for (&gi, r) in order.iter().zip(permuted_results) {
        results[gi] = r;
    }

    // serial reduction in grid order: baselines, speedups, cross-seed
    // statistics, cells
    let mut cells = Vec::new();
    let mut idx = 0usize;
    for &(dp, cp) in &opts.topologies {
        for name in &opts.datasets {
            let mut walls: Vec<Summary> = (0..np).map(|_| Summary::new()).collect();
            let mut speedups: Vec<Summary> = (0..np).map(|_| Summary::new()).collect();
            let mut primaries: Vec<Option<(RunReport, f64, usize, f64, u64)>> =
                (0..np).map(|_| None).collect();
            for si in 0..ns {
                let mut baseline_wall = None;
                for pi in 0..np {
                    // skrull-lint: allow(panic-in-lib) -- reduce loop visits each grid slot exactly once; a double-take is a bench-harness bug, not an input error
                    let r = results[idx].take().expect("each job reduced once")?;
                    idx += 1;
                    let base = *baseline_wall.get_or_insert(r.wall);
                    let speedup = if r.wall > 0.0 { base / r.wall } else { f64::INFINITY };
                    walls[pi].push(r.wall);
                    speedups[pi].push(speedup);
                    if si == 0 {
                        primaries[pi] =
                            Some((r.report, speedup, r.batch_size, r.estimator_error, r.digest));
                    }
                }
            }
            for (pi, policy) in ALL_POLICIES.into_iter().enumerate() {
                // skrull-lint: allow(panic-in-lib) -- si == 0 always populates primaries[pi] above; absence is a bench-harness bug
                let primary = primaries[pi].take().expect("primary seed ran");
                let (report, speedup, batch_size, estimator_error, sched_digest) = primary;
                cells.push(E2eCell {
                    policy,
                    dataset: name.clone(),
                    dp,
                    cp,
                    batch_size,
                    report,
                    speedup_vs_baseline: speedup,
                    estimator_error,
                    wall_mean: walls[pi].mean(),
                    wall_std: walls[pi].std(),
                    speedup_mean: speedups[pi].mean(),
                    speedup_std: speedups[pi].std(),
                    runs: ns,
                    sched_digest,
                });
            }
        }
    }
    Ok(E2eSweep {
        model: opts.model.name.to_string(),
        iterations: opts.iterations,
        pipelined: opts.pipelined,
        epoch: opts.epoch,
        seeds: opts.seeds.clone(),
        cost_source: opts.cost.name().to_string(),
        sweep_seconds: if opts.deterministic_timing {
            0.0
        } else {
            t_sweep.elapsed().as_secs_f64()
        },
        streamed,
        stream_ram_bytes: if streamed { opts.stream.budget_bytes() } else { 0 },
        cells,
    })
}

/// Mean per-iteration relative deviation of a run's execution predictions
/// from a reference run of the same schedules.
fn estimator_error(run: &RunReport, reference: &RunReport) -> f64 {
    let n = run.iterations.len().min(reference.iterations.len());
    if n == 0 {
        return 0.0;
    }
    let total: f64 = run
        .iterations
        .iter()
        .zip(&reference.iterations)
        .map(|(a, b)| (a.exec_seconds - b.exec_seconds).abs() / b.exec_seconds.max(1e-30))
        .sum();
    total / n as f64
}

/// Render the sweep as `BENCH_e2e.json` (hand-rolled JSON; no serde in the
/// image).  Schema: see README "End-to-end benchmark".
pub fn render_json(sweep: &E2eSweep) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"bench\": \"e2e\",");
    let _ = writeln!(out, "  \"schema_version\": 5,");
    let _ = writeln!(out, "  \"model\": \"{}\",", json_str(&sweep.model));
    let _ = writeln!(out, "  \"iterations\": {},", sweep.iterations);
    let _ = writeln!(out, "  \"pipelined\": {},", sweep.pipelined);
    let _ = writeln!(out, "  \"epoch\": {},", sweep.epoch);
    let _ = writeln!(out, "  \"cost_source\": \"{}\",", json_str(&sweep.cost_source));
    let _ = writeln!(out, "  \"sweep_seconds\": {:e},", sweep.sweep_seconds);
    let _ = writeln!(out, "  \"streamed\": {},", sweep.streamed);
    let _ = writeln!(out, "  \"stream_ram_bytes\": {},", sweep.stream_ram_bytes);
    let seeds: Vec<String> = sweep.seeds.iter().map(|s| s.to_string()).collect();
    let _ = writeln!(out, "  \"seeds\": [{}],", seeds.join(", "));
    out.push_str("  \"cells\": [\n");
    for (i, c) in sweep.cells.iter().enumerate() {
        let r = &c.report;
        let _ = writeln!(
            out,
            "    {{\"policy\": \"{}\", \"dataset\": \"{}\", \"dp\": {}, \"cp\": {}, \
             \"batch_size\": {}, \"bucket_size\": {}, \"capacity_source\": \"{}\", \
             \"total_seconds\": {:e}, \"exec_seconds\": {:e}, \
             \"sched_seconds\": {:e}, \"exposed_sched_seconds\": {:e}, \
             \"speedup_vs_baseline\": {:.4}, \"estimator_error\": {:e}, \
             \"total_seconds_mean\": {:e}, \
             \"total_seconds_std\": {:e}, \"speedup_mean\": {:.4}, \
             \"speedup_std\": {:.4}, \"runs\": {}, \"utilization\": {:.4}, \
             \"effective_utilization\": {:.4}, \"sched_overhead_fraction\": {:e}, \
             \"padding_fraction\": {:.4}, \"peak_mem_fraction\": {:.6}, \
             \"oom_count\": {}, \"dp_imbalance\": {:.4}, \"micro_batches\": {}, \
             \"sched_invocations\": {}, \"drift_events\": {}, \
             \"peak_stream_rss_bytes\": {}, \"sched_digest\": \"{:016x}\"}}{}",
            json_str(c.policy.name()),
            json_str(&c.dataset),
            c.dp,
            c.cp,
            c.batch_size,
            r.bucket_size,
            json_str(r.capacity_source.name()),
            r.wall_seconds(),
            r.exec_seconds,
            r.sched_seconds,
            r.exposed_sched_seconds,
            c.speedup_vs_baseline,
            c.estimator_error,
            c.wall_mean,
            c.wall_std,
            c.speedup_mean,
            c.speedup_std,
            c.runs,
            r.utilization(),
            r.effective_utilization(),
            r.sched_overhead_fraction(),
            r.padding_fraction(),
            r.peak_mem_fraction(),
            r.oom_count(),
            r.mean_dp_imbalance(),
            r.total_micro_batches(),
            r.sched_invocations,
            r.drift_events,
            r.peak_stream_rss_bytes,
            c.sched_digest,
            if i + 1 == sweep.cells.len() { "" } else { "," }
        );
    }
    out.push_str("  ]\n}\n");
    out
}

/// Render the per-cell schedule digests as a stable text file, one line
/// per cell in grid order.  A streamed sweep and an in-memory sweep of
/// the same grid produce *identical* files — the CI byte-identity gate
/// `cmp`s these rather than the full JSONs, which legitimately differ in
/// the stream-only accounting fields (`drift_events`,
/// `peak_stream_rss_bytes`, `streamed`).
pub fn render_digests(sweep: &E2eSweep) -> String {
    let mut out = String::new();
    out.push_str("# e2e schedule digests v1\n");
    for c in &sweep.cells {
        let _ = writeln!(
            out,
            "{} {} dp{} cp{} {:016x}",
            c.policy.name(),
            c.dataset,
            c.dp,
            c.cp,
            c.sched_digest
        );
    }
    out
}

/// Top-level keys every `BENCH_e2e.json` must carry.
const REQUIRED_TOP_KEYS: [&str; 11] = [
    "\"bench\"",
    "\"schema_version\"",
    "\"model\"",
    "\"iterations\"",
    "\"seeds\"",
    "\"epoch\"",
    "\"cost_source\"",
    "\"sweep_seconds\"",
    "\"streamed\"",
    "\"stream_ram_bytes\"",
    "\"cells\"",
];

/// Per-cell keys; the numeric ones are additionally checked for finiteness.
const REQUIRED_CELL_KEYS: [&str; 19] = [
    "policy",
    "dataset",
    "dp",
    "cp",
    "bucket_size",
    "total_seconds",
    "speedup_vs_baseline",
    "estimator_error",
    "utilization",
    "sched_overhead_fraction",
    "total_seconds_mean",
    "total_seconds_std",
    "speedup_mean",
    "speedup_std",
    "peak_mem_fraction",
    "sched_invocations",
    "drift_events",
    "peak_stream_rss_bytes",
    "sched_digest",
];

const FINITE_CELL_KEYS: [&str; 10] = [
    "total_seconds",
    "speedup_vs_baseline",
    "estimator_error",
    "utilization",
    "sched_overhead_fraction",
    "total_seconds_mean",
    "total_seconds_std",
    "speedup_mean",
    "speedup_std",
    "peak_mem_fraction",
];

/// Ceiling on per-cell `estimator_error` when the sweep ran calibrated —
/// the acceptance bar for the calibration round trip.
pub const CALIBRATED_ESTIMATOR_ERROR_MAX: f64 = 0.05;

/// CI gate: does `text` look like a complete, sane `BENCH_e2e.json`?
/// Checks required top-level and per-cell keys (schema v5: top-level
/// `streamed`/`stream_ram_bytes`, per-cell `drift_events`/
/// `peak_stream_rss_bytes`/`sched_digest`), rejects non-finite (or
/// unparsable) values for every speedup/time/utilization/memory field,
/// and enforces the consistency rules: an OOM-free cell must report
/// `peak_mem_fraction` in (0, 1]; the build-once guarantee — every
/// non-epoch cell's `sched_invocations` must equal the sweep's iteration
/// count exactly (one GDS/DACP pass per played iteration, no 2x work);
/// and the bounded-RAM guarantee — a streamed sweep's per-cell page-cache
/// peak must be positive and within the declared byte budget, while an
/// in-memory sweep must report it as exactly 0.
pub fn validate_json(text: &str) -> Result<()> {
    require_top_keys(text, &REQUIRED_TOP_KEYS)?;
    // schema v5 or later
    let version: u64 = values_after(text, "schema_version")
        .first()
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| crate::anyhow!("unparsable schema_version"))?;
    crate::ensure!(version >= 5, "schema_version {version} predates v5");
    let sweep_s: f64 = values_after(text, "sweep_seconds")
        .first()
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| crate::anyhow!("unparsable sweep_seconds"))?;
    crate::ensure!(
        sweep_s.is_finite() && sweep_s >= 0.0,
        "sweep_seconds {sweep_s} is not a finite non-negative number"
    );
    let n_cells = values_after(text, "policy").len();
    crate::ensure!(n_cells > 0, "no cells in BENCH_e2e.json");
    for key in REQUIRED_CELL_KEYS {
        require_count(text, key, n_cells, "cell")?;
    }
    for key in FINITE_CELL_KEYS {
        finite_values(text, key)?;
    }
    // memory-model consistency: oom_count is a per-cell integer, and an
    // OOM-free cell's peak fraction must land in (0, 1]
    require_count(text, "oom_count", n_cells, "cell")?;
    let ooms = values_after(text, "oom_count");
    let peaks = values_after(text, "peak_mem_fraction");
    for (i, (o, p)) in ooms.iter().zip(&peaks).enumerate() {
        let oom: u64 = o
            .parse()
            .map_err(|_| crate::anyhow!("cell {i}: \"oom_count\" value {o:?} is not an integer"))?;
        let frac: f64 = p.parse().map_err(|_| {
            crate::anyhow!("cell {i}: \"peak_mem_fraction\" value {p:?} is not a number")
        })?;
        if oom == 0 {
            crate::ensure!(
                frac > 0.0 && frac <= 1.0,
                "cell {i}: peak_mem_fraction {frac} outside (0, 1] with no OOM flagged"
            );
        }
    }
    // streaming consistency: drift/RSS accounting is a u64 per cell; a
    // streamed sweep's page cache must actually have resident frames
    // (peak > 0) and stay within the declared byte budget — the
    // bounded-RAM acceptance criterion as a validator rule — while an
    // in-memory sweep must report exactly 0
    let streamed = values_after(text, "streamed")
        .first()
        .map(|v| *v == "true")
        .unwrap_or(false);
    let ram_bytes: u64 = values_after(text, "stream_ram_bytes")
        .first()
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| crate::anyhow!("unparsable stream_ram_bytes"))?;
    for (i, v) in values_after(text, "drift_events").iter().enumerate() {
        let _: u64 = v.parse().map_err(|_| {
            crate::anyhow!("cell {i}: \"drift_events\" value {v:?} is not an integer")
        })?;
    }
    for (i, v) in values_after(text, "peak_stream_rss_bytes").iter().enumerate() {
        let peak: u64 = v.parse().map_err(|_| {
            crate::anyhow!("cell {i}: \"peak_stream_rss_bytes\" value {v:?} is not an integer")
        })?;
        if streamed {
            crate::ensure!(peak > 0, "cell {i}: streamed sweep with peak_stream_rss_bytes = 0");
            crate::ensure!(
                peak <= ram_bytes,
                "cell {i}: peak_stream_rss_bytes {peak} exceeds stream_ram_bytes {ram_bytes}"
            );
        } else {
            crate::ensure!(
                peak == 0,
                "cell {i}: in-memory sweep reports peak_stream_rss_bytes {peak}"
            );
        }
    }
    // the build-once gate: every cell scheduled exactly once per played
    // iteration.  Outside epoch mode the iteration count is the top-level
    // `iterations`; in epoch mode it is per-cell (the epoch length), so
    // only positivity can be checked from the file alone.
    let epoch = values_after(text, "epoch")
        .first()
        .map(|v| *v == "true")
        .unwrap_or(false);
    let iterations: u64 = values_after(text, "iterations")
        .first()
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| crate::anyhow!("unparsable top-level iterations"))?;
    for (i, v) in values_after(text, "sched_invocations").iter().enumerate() {
        let n: u64 = v.parse().map_err(|_| {
            crate::anyhow!("cell {i}: \"sched_invocations\" value {v:?} is not an integer")
        })?;
        if epoch {
            crate::ensure!(n >= 1, "cell {i}: sched_invocations {n} < 1");
        } else {
            crate::ensure!(
                n == iterations,
                "cell {i}: sched_invocations {n} != iterations {iterations} — \
                 the one-pass-per-iteration guarantee is broken"
            );
        }
    }
    // calibration gate: estimator_error is non-negative everywhere, and a
    // calibrated sweep must track the analytic ground truth within the
    // acceptance tolerance in every cell
    let calibrated = values_after(text, "cost_source")
        .first()
        .map(|v| *v == "\"calibrated\"")
        .unwrap_or(false);
    for (i, v) in values_after(text, "estimator_error").iter().enumerate() {
        let err: f64 = v.parse().map_err(|_| {
            crate::anyhow!("cell {i}: \"estimator_error\" value {v:?} is not a number")
        })?;
        crate::ensure!(err >= 0.0, "cell {i}: negative estimator_error {err}");
        if calibrated {
            crate::ensure!(
                err <= CALIBRATED_ESTIMATOR_ERROR_MAX,
                "cell {i}: calibrated estimator_error {err} exceeds {CALIBRATED_ESTIMATOR_ERROR_MAX}"
            );
        }
    }
    // every known policy must be present at least once
    for p in ALL_POLICIES {
        crate::ensure!(
            text.contains(&format!("\"policy\": \"{}\"", p.name())),
            "policy {} missing from sweep",
            p.name()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memplan::CapacitySource;

    fn tiny_opts() -> E2eOptions {
        E2eOptions {
            model: ModelSpec::qwen2_5_0_5b(),
            datasets: vec!["chatqa2".into()],
            topologies: vec![(4, 8)],
            iterations: 2,
            batch_size: Some(16),
            dataset_samples: 2_000,
            seeds: vec![11],
            pipelined: true,
            epoch: false,
            memory: MemoryConfig::default(),
            cost: CostSource::Analytic,
            jobs: 1,
            deterministic_timing: false,
            stream: StreamConfig::default(),
        }
    }

    fn temp_spill_dir(tag: &str) -> String {
        let dir = std::env::temp_dir().join(format!("skrull-e2e-{tag}-{}", std::process::id()));
        dir.to_string_lossy().into_owned()
    }

    #[test]
    fn sweep_covers_grid_and_baseline_is_unit_speedup() {
        let sweep = run_sweep(&tiny_opts()).unwrap();
        assert_eq!(sweep.cells.len(), ALL_POLICIES.len());
        assert_eq!(sweep.cost_source, "analytic");
        assert!(sweep.sweep_seconds > 0.0);
        let base = sweep.cell(Policy::Baseline, "chatqa2", 4, 8).unwrap();
        assert!((base.speedup_vs_baseline - 1.0).abs() < 1e-12);
        for c in &sweep.cells {
            assert!(c.speedup_vs_baseline.is_finite());
            assert!(c.report.wall_seconds() > 0.0);
            // analytic ground truth deviates from itself by nothing
            assert_eq!(c.estimator_error, 0.0);
            // build-once: one scheduling pass per played iteration
            assert_eq!(c.report.sched_invocations, 2);
            // single-seed sweep: means collapse onto the primary run
            assert_eq!(c.runs, 1);
            assert_eq!(c.wall_mean, c.report.wall_seconds());
            assert_eq!(c.wall_std, 0.0);
            assert_eq!(c.speedup_mean, c.speedup_vs_baseline);
            assert_eq!(c.speedup_std, 0.0);
        }
    }

    #[test]
    fn skrull_speeds_up_mixed_workload_end_to_end() {
        // acceptance criterion: >1.0x simulated speedup vs Baseline on a
        // mixed long/short distribution
        let sweep = run_sweep(&tiny_opts()).unwrap();
        let sk = sweep.cell(Policy::Skrull, "chatqa2", 4, 8).unwrap();
        assert!(
            sk.speedup_vs_baseline > 1.0,
            "skrull speedup {} ≤ 1.0",
            sk.speedup_vs_baseline
        );
    }

    #[test]
    fn parallel_sweep_emits_byte_identical_json() {
        // the --jobs knob is a wall-clock lever only: with measured timing
        // pinned, any worker count produces the same file byte for byte
        let mut o = tiny_opts();
        o.deterministic_timing = true;
        o.seeds = vec![11, 12];
        o.jobs = 1;
        let serial = render_json(&run_sweep(&o).unwrap());
        for jobs in [2, 4, 16] {
            o.jobs = jobs;
            let parallel = render_json(&run_sweep(&o).unwrap());
            assert_eq!(serial, parallel, "jobs={jobs} diverged from serial");
        }
        validate_json(&serial).unwrap();
        assert!(serial.contains("\"sweep_seconds\": 0e0"));
    }

    #[test]
    fn memory_fields_are_emitted_and_sane_on_defaults() {
        // acceptance criterion: `skrull e2e` emits peak_mem_fraction and
        // oom_count per cell; the paper defaults (80 GB, fixed 26K bucket)
        // are OOM-free
        let sweep = run_sweep(&tiny_opts()).unwrap();
        for c in &sweep.cells {
            let f = c.report.peak_mem_fraction();
            assert!(f > 0.0 && f <= 1.0, "{}: {f}", c.policy.name());
            assert_eq!(c.report.oom_count(), 0, "{}", c.policy.name());
        }
        let json = render_json(&sweep);
        assert!(json.contains("\"peak_mem_fraction\""));
        assert!(json.contains("\"oom_count\""));
        validate_json(&json).unwrap();
    }

    #[test]
    fn hbm_derived_capacity_sweep_is_oom_free() {
        // acceptance criterion: with CapacitySource::HbmDerived no cell
        // reports an OOM, for any policy
        let mut o = tiny_opts();
        o.memory.source = CapacitySource::HbmDerived;
        let sweep = run_sweep(&o).unwrap();
        for c in &sweep.cells {
            assert_eq!(c.report.oom_count(), 0, "{}", c.policy.name());
            let f = c.report.peak_mem_fraction();
            assert!(f > 0.0 && f <= 1.0, "{}: {f}", c.policy.name());
            // the derived 0.5B capacity on 80 GB beats the hand-set 26K
            assert!(c.report.bucket_size > 26 * 1024);
            assert_eq!(c.report.capacity_source, CapacitySource::HbmDerived);
        }
        validate_json(&render_json(&sweep)).unwrap();
    }

    #[test]
    fn undersized_hbm_flags_ooms_and_still_validates() {
        let mut o = tiny_opts();
        o.memory.hbm_gb = 4.0; // fixed 26K bucket cannot fit
        let sweep = run_sweep(&o).unwrap();
        assert!(sweep.cells.iter().any(|c| c.report.oom_count() > 0));
        for c in &sweep.cells {
            if c.report.oom_count() > 0 {
                assert!(c.report.peak_mem_fraction() > 1.0);
            }
        }
        // OOM-flagged cells are exempt from the (0,1] rule
        validate_json(&render_json(&sweep)).unwrap();
    }

    #[test]
    fn seed_list_emits_noise_aware_statistics() {
        let mut o = tiny_opts();
        o.seeds = vec![11, 12, 13];
        let sweep = run_sweep(&o).unwrap();
        assert_eq!(sweep.seeds, vec![11, 12, 13]);
        for c in &sweep.cells {
            assert_eq!(c.runs, 3);
            assert!(c.wall_mean > 0.0 && c.wall_mean.is_finite());
            assert!(c.wall_std >= 0.0 && c.wall_std.is_finite());
            assert!(c.speedup_std >= 0.0 && c.speedup_std.is_finite());
            if c.policy == Policy::Baseline {
                // every seed's baseline is 1.0 by construction
                assert!((c.speedup_mean - 1.0).abs() < 1e-12);
                assert!(c.speedup_std < 1e-12);
            }
        }
        validate_json(&render_json(&sweep)).unwrap();
    }

    #[test]
    fn epoch_mode_plays_one_full_epoch_per_cell() {
        let mut o = tiny_opts();
        o.epoch = true;
        o.dataset_samples = 100;
        o.batch_size = Some(16);
        let sweep = run_sweep(&o).unwrap();
        assert!(sweep.epoch);
        let dist = LengthDistribution::by_name("chatqa2").unwrap();
        let ds = Dataset::synthesize(&dist, 100, o.seeds[0] ^ 0xD5).truncated(26 * 1024 * 8);
        for c in &sweep.cells {
            assert_eq!(c.report.iterations.len(), 100usize.div_ceil(16));
            assert_eq!(c.report.data_tokens, ds.total_tokens(), "{}", c.policy.name());
            // epoch cells schedule once per epoch batch
            assert_eq!(c.report.sched_invocations, 100usize.div_ceil(16));
        }
        let json = render_json(&sweep);
        assert!(json.contains("\"epoch\": true"));
        validate_json(&json).unwrap();
    }

    #[test]
    fn rendered_json_validates_and_mutations_fail() {
        let sweep = run_sweep(&tiny_opts()).unwrap();
        let json = render_json(&sweep);
        validate_json(&json).unwrap();

        // missing top-level key
        let broken = json.replace("\"schema_version\"", "\"schema_ver\"");
        assert!(validate_json(&broken).is_err());
        // missing cell key in one cell
        let broken = json.replacen("\"speedup_vs_baseline\"", "\"speedup\"", 1);
        assert!(validate_json(&broken).is_err());
        // non-finite speedup
        let sample = values_after(&json, "speedup_vs_baseline")[0].to_string();
        let broken = json.replacen(
            &format!("\"speedup_vs_baseline\": {sample}"),
            "\"speedup_vs_baseline\": NaN",
            1,
        );
        assert_ne!(broken, json, "mutation must apply");
        assert!(validate_json(&broken).is_err());
        // truncated file
        assert!(validate_json(&json[..json.len() / 2]).is_err());
        // memory rule: an OOM-free cell with a zero or >1 peak fraction
        let sample = values_after(&json, "peak_mem_fraction")[0].to_string();
        for bad in ["0.000000", "1.500000"] {
            let broken = json.replacen(
                &format!("\"peak_mem_fraction\": {sample}"),
                &format!("\"peak_mem_fraction\": {bad}"),
                1,
            );
            assert_ne!(broken, json, "mutation must apply");
            assert!(validate_json(&broken).is_err(), "peak {bad} should fail");
        }
        // non-integer oom_count
        let broken = json.replacen("\"oom_count\": 0", "\"oom_count\": 0.5", 1);
        assert_ne!(broken, json, "mutation must apply");
        assert!(validate_json(&broken).is_err());
        // schema v5: cost_source, sweep_seconds, sched_invocations and the
        // streaming fields are mandatory, and the version itself is gated
        assert!(json.contains("\"schema_version\": 5"));
        assert!(json.contains("\"cost_source\": \"analytic\""));
        assert!(json.contains("\"sweep_seconds\""));
        assert!(json.contains("\"streamed\": false"));
        assert!(json.contains("\"stream_ram_bytes\": 0"));
        let broken = json.replace("\"estimator_error\"", "\"est_err\"");
        assert!(validate_json(&broken).is_err());
        let broken = json.replace("\"cost_source\"", "\"cost_src\"");
        assert!(validate_json(&broken).is_err());
        let broken = json.replace("\"schema_version\": 5", "\"schema_version\": 4");
        assert!(validate_json(&broken).is_err());
        // streaming consistency rules: the fields are mandatory, an
        // in-memory sweep must report zero peaks, and a streamed flag with
        // zero peaks is inconsistent
        let broken = json.replace("\"drift_events\"", "\"drift_evs\"");
        assert!(validate_json(&broken).is_err());
        let broken = json.replace("\"sched_digest\"", "\"digest\"");
        assert!(validate_json(&broken).is_err());
        let broken = json.replacen(
            "\"peak_stream_rss_bytes\": 0",
            "\"peak_stream_rss_bytes\": 17",
            1,
        );
        assert_ne!(broken, json, "mutation must apply");
        assert!(validate_json(&broken).is_err());
        let broken = json.replace("\"streamed\": false", "\"streamed\": true");
        assert!(validate_json(&broken).is_err());
        let broken = json.replace("\"sweep_seconds\"", "\"sweep_secs\"");
        assert!(validate_json(&broken).is_err());
        let sweep_sample = values_after(&json, "sweep_seconds")[0].to_string();
        let broken = json.replacen(
            &format!("\"sweep_seconds\": {sweep_sample}"),
            "\"sweep_seconds\": -1.0",
            1,
        );
        assert_ne!(broken, json, "mutation must apply");
        assert!(validate_json(&broken).is_err());
        // the one-pass gate: sched_invocations must equal iterations (2)
        let broken = json.replace("\"sched_invocations\"", "\"sched_invoc\"");
        assert!(validate_json(&broken).is_err());
        let broken = json.replacen("\"sched_invocations\": 2", "\"sched_invocations\": 4", 1);
        assert_ne!(broken, json, "mutation must apply");
        let err = validate_json(&broken).unwrap_err().to_string();
        assert!(err.contains("one-pass-per-iteration"), "{err}");
        // a calibrated sweep is gated on estimator_error ≤ 5%; an analytic
        // one carries the same field ungated
        let sample = values_after(&json, "estimator_error")[0].to_string();
        let drifted = json.replacen(
            &format!("\"estimator_error\": {sample}"),
            "\"estimator_error\": 2e-1",
            1,
        );
        assert_ne!(drifted, json, "mutation must apply");
        validate_json(&drifted).unwrap();
        let calibrated = drifted.replace("\"cost_source\": \"analytic\"", "\"cost_source\": \"calibrated\"");
        let err = validate_json(&calibrated).unwrap_err().to_string();
        assert!(err.contains("estimator_error"), "{err}");
        // negative estimator_error never validates
        let negative = json.replacen(
            &format!("\"estimator_error\": {sample}"),
            "\"estimator_error\": -1e-3",
            1,
        );
        assert!(validate_json(&negative).is_err());
    }

    #[test]
    fn streamed_sweep_is_byte_identical_to_in_memory_and_bounded() {
        // the headline acceptance criterion, cell-grid edition: a sweep
        // built through the disk-spilled page cache emits the exact same
        // schedule digests as the in-memory sweep, at bounded RAM
        let mut o = tiny_opts();
        o.deterministic_timing = true;
        let in_memory = run_sweep(&o).unwrap();
        let mut s = o.clone();
        s.stream.spill_dir = Some(temp_spill_dir("digest"));
        s.stream.ram_mb = 1;
        let streamed = run_sweep(&s).unwrap();
        assert!(streamed.streamed && !in_memory.streamed);
        // identical digest files — what the CI gate cmp's
        assert_eq!(render_digests(&in_memory), render_digests(&streamed));
        // per-cell digests and full run accounting agree
        for (a, b) in in_memory.cells.iter().zip(&streamed.cells) {
            assert_eq!(a.sched_digest, b.sched_digest, "{}", a.policy.name());
            assert_eq!(a.report.data_tokens, b.report.data_tokens);
            assert_eq!(a.report.exec_seconds, b.report.exec_seconds);
            assert_eq!(a.report.sched_invocations, b.report.sched_invocations);
            // bounded RAM: the page cache stayed within its byte budget
            assert_eq!(a.report.peak_stream_rss_bytes, 0);
            assert!(b.report.peak_stream_rss_bytes > 0);
            assert!(b.report.peak_stream_rss_bytes <= s.stream.budget_bytes());
        }
        let json = render_json(&streamed);
        assert!(json.contains("\"streamed\": true"));
        validate_json(&json).unwrap();
        // ... and the streamed file still validates in-memory too
        validate_json(&render_json(&in_memory)).unwrap();
        if let Some(dir) = &s.stream.spill_dir {
            let _ = std::fs::remove_dir_all(dir);
        }
    }

    #[test]
    fn bad_options_are_rejected() {
        let mut o = tiny_opts();
        o.topologies = vec![(8, 8)]; // 64 GPUs > 32-GPU testbed
        assert!(run_sweep(&o).is_err());
        let mut o = tiny_opts();
        o.datasets = vec!["imagenet".into()];
        assert!(run_sweep(&o).is_err());
        let mut o = tiny_opts();
        o.iterations = 0;
        assert!(run_sweep(&o).is_err());
        // ... but 0 iterations is fine in epoch mode
        o.epoch = true;
        o.dataset_samples = 50;
        assert!(run_sweep(&o).is_ok());
        let mut o = tiny_opts();
        o.seeds = vec![];
        assert!(run_sweep(&o).is_err());
        // an infeasible HBM budget surfaces as a clean error
        let mut o = tiny_opts();
        o.memory.source = CapacitySource::HbmDerived;
        o.memory.hbm_gb = 0.25;
        assert!(run_sweep(&o).is_err());
        // ... also when the cells run on worker threads
        o.jobs = 4;
        assert!(run_sweep(&o).is_err());
    }
}
