//! End-to-end experiment engine (the paper's Section 5 evaluation, as a
//! sweep): every scheduling policy × length distribution × cluster
//! topology, played for N iterations through the run engine
//! (`cluster::run`), with per-cell total wall-clock, speedup vs the
//! DeepSpeed-like baseline, utilization and exposed-scheduling-overhead
//! fraction.  Emits the machine-readable `BENCH_e2e.json` that tracks the
//! repo's headline number across PRs (`skrull e2e`), and validates it for
//! CI (`skrull e2e --validate`).

use std::fmt::Write as _;

use crate::cluster::run::{simulate_run, RunConfig, RunReport};
use crate::cluster::Topology;
use crate::config::{ExperimentConfig, Policy};
use crate::data::{Dataset, LengthDistribution};
use crate::model::ModelSpec;
use crate::perfmodel::CostModel;
use crate::util::error::{Context, Result};

/// Sweep order: the baseline must come first so every other cell of the
/// same (dataset, topology) can report speedup against it.
pub const ALL_POLICIES: [Policy; 5] = [
    Policy::Baseline,
    Policy::SortedBatching,
    Policy::DacpOnly,
    Policy::Skrull,
    Policy::SkrullRefined,
];

/// Sweep parameters.
#[derive(Clone, Debug)]
pub struct E2eOptions {
    pub model: ModelSpec,
    pub datasets: Vec<String>,
    /// (dp, cp) pairs; validated against the paper's 4×8-GPU testbed.
    pub topologies: Vec<(usize, usize)>,
    pub iterations: usize,
    /// None = the paper default for each (model, dataset) cell.
    pub batch_size: Option<usize>,
    /// synthesized dataset size per distribution
    pub dataset_samples: usize,
    pub seed: u64,
    pub pipelined: bool,
}

impl E2eOptions {
    /// The paper's evaluation grid: 3 length distributions × 2 topologies.
    pub fn paper_default() -> Self {
        E2eOptions {
            model: ModelSpec::qwen2_5_0_5b(),
            datasets: vec!["wikipedia".into(), "lmsys".into(), "chatqa2".into()],
            topologies: vec![(4, 8), (2, 16)],
            iterations: 10,
            batch_size: None,
            dataset_samples: 20_000,
            seed: 42,
            pipelined: true,
        }
    }

    /// Tiny grid for CI smoke runs (still all 5 policies).
    pub fn smoke() -> Self {
        let mut o = Self::paper_default();
        o.iterations = 2;
        o.batch_size = Some(8);
        o.dataset_samples = 2_000;
        o
    }
}

/// One sweep cell: a full simulated run of one policy on one workload.
#[derive(Clone, Debug)]
pub struct E2eCell {
    pub policy: Policy,
    pub dataset: String,
    pub dp: usize,
    pub cp: usize,
    pub batch_size: usize,
    pub report: RunReport,
    pub speedup_vs_baseline: f64,
}

/// The whole sweep.
#[derive(Clone, Debug)]
pub struct E2eSweep {
    pub model: String,
    pub iterations: usize,
    pub pipelined: bool,
    pub cells: Vec<E2eCell>,
}

impl E2eSweep {
    pub fn cell(&self, policy: Policy, dataset: &str, dp: usize, cp: usize) -> Option<&E2eCell> {
        self.cells.iter().find(|c| {
            c.policy == policy && c.dataset == dataset && c.dp == dp && c.cp == cp
        })
    }
}

/// Run the full sweep: for each (topology, dataset), all policies over the
/// *same* synthesized workload, baseline first.
pub fn run_sweep(opts: &E2eOptions) -> Result<E2eSweep> {
    crate::ensure!(opts.iterations > 0, "e2e sweep needs at least 1 iteration");
    crate::ensure!(!opts.datasets.is_empty(), "e2e sweep needs at least one dataset");
    crate::ensure!(!opts.topologies.is_empty(), "e2e sweep needs at least one topology");
    let mut cells = Vec::new();
    for &(dp, cp) in &opts.topologies {
        // the paper's testbed bounds + power-of-two CP check
        Topology::paper_testbed(dp, cp)
            .with_context(|| format!("invalid topology dp={dp} cp={cp}"))?;
        for name in &opts.datasets {
            let dist = LengthDistribution::by_name(name)
                .with_context(|| format!("unknown dataset {name:?}"))?;
            let mut cfg = ExperimentConfig::paper_default(opts.model.clone(), name);
            cfg.cluster.dp = dp;
            cfg.cluster.cp = cp;
            if let Some(b) = opts.batch_size {
                cfg.cluster.batch_size = b;
            }
            cfg.seed = opts.seed;
            cfg.pipelined = opts.pipelined;
            let ds = Dataset::synthesize(&dist, opts.dataset_samples, opts.seed ^ 0xD5)
                .truncated(cfg.bucket_size * cp as u32);
            let cost = CostModel::paper_default(&cfg.model);
            let run = RunConfig::new(opts.iterations, opts.pipelined);

            let mut baseline_wall = None;
            for policy in ALL_POLICIES {
                let mut pcfg = cfg.clone();
                pcfg.policy = policy;
                let report = simulate_run(&ds, &pcfg, &cost, &run)
                    .with_context(|| format!("{} on {name} <DP={dp},CP={cp}>", policy.name()))?;
                let wall = report.wall_seconds();
                let base = *baseline_wall.get_or_insert(wall);
                cells.push(E2eCell {
                    policy,
                    dataset: name.clone(),
                    dp,
                    cp,
                    batch_size: pcfg.cluster.batch_size,
                    speedup_vs_baseline: if wall > 0.0 { base / wall } else { f64::INFINITY },
                    report,
                });
            }
        }
    }
    Ok(E2eSweep {
        model: opts.model.name.to_string(),
        iterations: opts.iterations,
        pipelined: opts.pipelined,
        cells,
    })
}

fn json_str(s: &str) -> &str {
    assert!(!s.contains(['"', '\\', '\n']), "unescapable: {s}");
    s
}

/// Render the sweep as `BENCH_e2e.json` (hand-rolled JSON; no serde in the
/// image).  Schema: see README "End-to-end benchmark".
pub fn render_json(sweep: &E2eSweep) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"bench\": \"e2e\",");
    let _ = writeln!(out, "  \"schema_version\": 1,");
    let _ = writeln!(out, "  \"model\": \"{}\",", json_str(&sweep.model));
    let _ = writeln!(out, "  \"iterations\": {},", sweep.iterations);
    let _ = writeln!(out, "  \"pipelined\": {},", sweep.pipelined);
    out.push_str("  \"cells\": [\n");
    for (i, c) in sweep.cells.iter().enumerate() {
        let r = &c.report;
        let _ = writeln!(
            out,
            "    {{\"policy\": \"{}\", \"dataset\": \"{}\", \"dp\": {}, \"cp\": {}, \
             \"batch_size\": {}, \"total_seconds\": {:e}, \"exec_seconds\": {:e}, \
             \"sched_seconds\": {:e}, \"exposed_sched_seconds\": {:e}, \
             \"speedup_vs_baseline\": {:.4}, \"utilization\": {:.4}, \
             \"effective_utilization\": {:.4}, \"sched_overhead_fraction\": {:e}, \
             \"padding_fraction\": {:.4}, \"dp_imbalance\": {:.4}, \"micro_batches\": {}}}{}",
            json_str(c.policy.name()),
            json_str(&c.dataset),
            c.dp,
            c.cp,
            c.batch_size,
            r.wall_seconds(),
            r.exec_seconds,
            r.sched_seconds,
            r.exposed_sched_seconds,
            c.speedup_vs_baseline,
            r.utilization(),
            r.effective_utilization(),
            r.sched_overhead_fraction(),
            r.padding_fraction(),
            r.mean_dp_imbalance(),
            r.total_micro_batches(),
            if i + 1 == sweep.cells.len() { "" } else { "," }
        );
    }
    out.push_str("  ]\n}\n");
    out
}

/// Top-level keys every `BENCH_e2e.json` must carry.
const REQUIRED_TOP_KEYS: [&str; 5] =
    ["\"bench\"", "\"schema_version\"", "\"model\"", "\"iterations\"", "\"cells\""];

/// Per-cell keys; the numeric ones are additionally checked for finiteness.
const REQUIRED_CELL_KEYS: [&str; 8] = [
    "policy",
    "dataset",
    "dp",
    "cp",
    "total_seconds",
    "speedup_vs_baseline",
    "utilization",
    "sched_overhead_fraction",
];

const FINITE_CELL_KEYS: [&str; 4] =
    ["total_seconds", "speedup_vs_baseline", "utilization", "sched_overhead_fraction"];

/// Every value token following `"key":` occurrences, in file order.
fn values_after<'a>(text: &'a str, key: &str) -> Vec<&'a str> {
    let needle = format!("\"{key}\":");
    let mut out = Vec::new();
    let mut rest = text;
    while let Some(pos) = rest.find(&needle) {
        rest = &rest[pos + needle.len()..];
        let tail = rest.trim_start();
        let end = tail.find([',', '}', '\n']).unwrap_or(tail.len());
        out.push(tail[..end].trim());
    }
    out
}

/// CI gate: does `text` look like a complete, sane `BENCH_e2e.json`?
/// Checks required top-level and per-cell keys and rejects non-finite (or
/// unparsable) values for every speedup/time/utilization field.
pub fn validate_json(text: &str) -> Result<()> {
    for key in REQUIRED_TOP_KEYS {
        crate::ensure!(text.contains(&format!("{key}:")), "missing top-level key {key}");
    }
    let n_cells = values_after(text, "policy").len();
    crate::ensure!(n_cells > 0, "no cells in BENCH_e2e.json");
    for key in REQUIRED_CELL_KEYS {
        let n = values_after(text, key).len();
        crate::ensure!(
            n == n_cells,
            "cell key \"{key}\" appears {n} times, expected {n_cells}"
        );
    }
    for key in FINITE_CELL_KEYS {
        for (i, v) in values_after(text, key).iter().enumerate() {
            let x: f64 = v
                .parse()
                .map_err(|_| crate::anyhow!("cell {i}: \"{key}\" value {v:?} is not a number"))?;
            crate::ensure!(x.is_finite(), "cell {i}: \"{key}\" = {v} is not finite");
        }
    }
    // every known policy must be present at least once
    for p in ALL_POLICIES {
        crate::ensure!(
            text.contains(&format!("\"policy\": \"{}\"", p.name())),
            "policy {} missing from sweep",
            p.name()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> E2eOptions {
        E2eOptions {
            model: ModelSpec::qwen2_5_0_5b(),
            datasets: vec!["chatqa2".into()],
            topologies: vec![(4, 8)],
            iterations: 2,
            batch_size: Some(16),
            dataset_samples: 2_000,
            seed: 11,
            pipelined: true,
        }
    }

    #[test]
    fn sweep_covers_grid_and_baseline_is_unit_speedup() {
        let sweep = run_sweep(&tiny_opts()).unwrap();
        assert_eq!(sweep.cells.len(), ALL_POLICIES.len());
        let base = sweep.cell(Policy::Baseline, "chatqa2", 4, 8).unwrap();
        assert!((base.speedup_vs_baseline - 1.0).abs() < 1e-12);
        for c in &sweep.cells {
            assert!(c.speedup_vs_baseline.is_finite());
            assert!(c.report.wall_seconds() > 0.0);
        }
    }

    #[test]
    fn skrull_speeds_up_mixed_workload_end_to_end() {
        // acceptance criterion: >1.0x simulated speedup vs Baseline on a
        // mixed long/short distribution
        let sweep = run_sweep(&tiny_opts()).unwrap();
        let sk = sweep.cell(Policy::Skrull, "chatqa2", 4, 8).unwrap();
        assert!(
            sk.speedup_vs_baseline > 1.0,
            "skrull speedup {} ≤ 1.0",
            sk.speedup_vs_baseline
        );
    }

    #[test]
    fn rendered_json_validates_and_mutations_fail() {
        let sweep = run_sweep(&tiny_opts()).unwrap();
        let json = render_json(&sweep);
        validate_json(&json).unwrap();

        // missing top-level key
        let broken = json.replace("\"schema_version\"", "\"schema_ver\"");
        assert!(validate_json(&broken).is_err());
        // missing cell key in one cell
        let broken = json.replacen("\"speedup_vs_baseline\"", "\"speedup\"", 1);
        assert!(validate_json(&broken).is_err());
        // non-finite speedup
        let sample = values_after(&json, "speedup_vs_baseline")[0].to_string();
        let broken = json.replacen(
            &format!("\"speedup_vs_baseline\": {sample}"),
            "\"speedup_vs_baseline\": NaN",
            1,
        );
        assert_ne!(broken, json, "mutation must apply");
        assert!(validate_json(&broken).is_err());
        // truncated file
        assert!(validate_json(&json[..json.len() / 2]).is_err());
    }

    #[test]
    fn values_after_extracts_tokens() {
        let text = r#"{"a": 1, "b": "x", "a": 2.5}"#;
        assert_eq!(values_after(text, "a"), vec!["1", "2.5"]);
        assert_eq!(values_after(text, "b"), vec!["\"x\""]);
        assert!(values_after(text, "c").is_empty());
    }

    #[test]
    fn bad_options_are_rejected() {
        let mut o = tiny_opts();
        o.topologies = vec![(8, 8)]; // 64 GPUs > 32-GPU testbed
        assert!(run_sweep(&o).is_err());
        let mut o = tiny_opts();
        o.datasets = vec!["imagenet".into()];
        assert!(run_sweep(&o).is_err());
        let mut o = tiny_opts();
        o.iterations = 0;
        assert!(run_sweep(&o).is_err());
    }
}
