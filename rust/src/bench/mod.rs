//! Benchmark harness substrate (criterion is not in the vendored set):
//! wall-clock measurement with warmup + repetitions, plain-text table
//! rendering shared by all `benches/*.rs` targets, and the end-to-end
//! policy × distribution × topology sweep behind `skrull e2e`.

pub mod e2e;
pub mod harness;
pub mod sched_overhead;
pub mod table;

pub use harness::{measure, Measurement};
pub use table::TableBuilder;
