//! Benchmark harness substrate (criterion is not in the vendored set):
//! wall-clock measurement with warmup + repetitions, and plain-text table
//! rendering shared by all `benches/*.rs` targets.

pub mod harness;
pub mod table;

pub use harness::{measure, Measurement};
pub use table::TableBuilder;
