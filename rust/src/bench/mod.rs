//! Benchmark harness substrate (criterion is not in the vendored set):
//! wall-clock measurement with warmup + repetitions, plain-text table
//! rendering shared by all `benches/*.rs` targets, the end-to-end
//! policy × distribution × topology sweep behind `skrull e2e`, and the
//! multi-tenant fleet-scheduling sweep behind `skrull fleet`.

pub mod e2e;
pub mod fleet;
pub mod harness;
pub mod sched_overhead;
pub mod table;

pub use harness::{measure, Measurement};
pub use table::TableBuilder;
