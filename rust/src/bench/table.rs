//! Plain-text table rendering for bench reports (the "same rows the paper
//! reports" requirement — every bench prints paper-shaped tables).

#[derive(Default)]
pub struct TableBuilder {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TableBuilder {
    pub fn new(title: &str) -> Self {
        TableBuilder { title: title.to_string(), ..Default::default() }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        self.rows.push(cells.iter().map(|s| s.to_string()).collect());
        self
    }

    pub fn render(&self) -> String {
        let ncols = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("| ");
            for i in 0..ncols {
                let cell = cells.get(i).map(|c| c.as_str()).unwrap_or("");
                s.push_str(&format!("{:<width$} | ", cell, width = widths[i]));
            }
            s.trim_end().to_string()
        };
        let mut out = format!("== {} ==\n", self.title);
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&format!(
                "|{}|\n",
                widths
                    .iter()
                    .map(|w| "-".repeat(w + 2))
                    .collect::<Vec<_>>()
                    .join("|")
            ));
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = TableBuilder::new("Table 1").header(&["Dataset", "<1K", "Longest"]);
        t.row_strs(&["Wikipedia", "87.88%", "78K"]);
        t.row_strs(&["ChatQA2", "21.92%", "99K"]);
        let s = t.render();
        assert!(s.contains("== Table 1 =="));
        assert!(s.contains("| Wikipedia"));
        // all data rows share the same width
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with('|')).collect();
        let lens: Vec<usize> = lines.iter().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    fn handles_ragged_rows() {
        let mut t = TableBuilder::new("x").header(&["a"]);
        t.row_strs(&["1", "2", "3"]);
        let s = t.render();
        assert!(s.contains('1') && s.contains('3'));
    }
}
