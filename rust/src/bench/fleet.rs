//! Fleet-scheduling sweep (`skrull fleet`): the discrete-event fleet
//! simulator (`fleet::sim`) played over every arrival pattern × queue
//! policy × pool topology, emitting the machine-readable
//! `BENCH_fleet.json` (schema v1) and validating it for CI
//! (`skrull fleet --validate`).
//!
//! Each arrival pattern synthesizes ONE workload per sweep, so every
//! (policy, pool set) cell of that pattern replays identical arrivals —
//! the cells differ only in what the fleet does with them.  Cells fan
//! out over `--jobs` worker threads with the e2e sweep's round-robin/
//! scatter-back discipline, and the simulator runs in pure simulated
//! time, so the JSON is byte-identical for any job count with no timing
//! pin needed (the sweep's own wall-clock goes to stdout, never into the
//! file).

use std::fmt::Write as _;
use std::time::Instant;

use crate::bench::harness::{finite_values, json_str, require_count, require_top_keys, values_after};
use crate::bench::TableBuilder;
use crate::fleet::job::{synthesize, ArrivalPattern, Workload};
use crate::fleet::placement::ClusterSpec;
use crate::fleet::queue::FleetPolicy;
use crate::fleet::sim::{simulate, FleetReport, SimOptions};
use crate::util::error::{Context, Result};
use crate::util::par;

/// Sweep parameters.
#[derive(Clone, Debug)]
pub struct FleetBenchOptions {
    /// Jobs synthesized per arrival pattern; every (policy, pool) cell of
    /// a pattern replays the same workload.
    pub jobs_per_cell: usize,
    pub seed: u64,
    pub arrivals: Vec<ArrivalPattern>,
    pub policies: Vec<FleetPolicy>,
    /// Pool-set names (`ClusterSpec::by_name`).
    pub pool_sets: Vec<String>,
    /// Worker threads for the cell fan-out (`--jobs`); wall-clock lever
    /// only, never results.
    pub jobs: usize,
}

impl FleetBenchOptions {
    /// The full grid: 3 arrivals × 4 policies × 2 pool sets, 12 jobs per
    /// cell → 288 simulated jobs per sweep.
    pub fn paper_default() -> Self {
        FleetBenchOptions {
            jobs_per_cell: 12,
            seed: 42,
            arrivals: ArrivalPattern::ALL.to_vec(),
            policies: FleetPolicy::ALL.to_vec(),
            pool_sets: ClusterSpec::ALL_NAMES.iter().map(|s| s.to_string()).collect(),
            jobs: par::max_threads().max(1),
        }
    }

    /// Same grid, fewer jobs per cell, for CI smoke runs.
    pub fn smoke() -> Self {
        let mut o = Self::paper_default();
        o.jobs_per_cell = 6;
        o
    }
}

/// One sweep cell: one simulated fleet.
#[derive(Clone, Debug)]
pub struct FleetCell {
    pub arrival: ArrivalPattern,
    pub pool_set: &'static str,
    pub pool_gpus: usize,
    pub report: FleetReport,
}

/// The whole sweep.
#[derive(Clone, Debug)]
pub struct FleetSweep {
    pub seed: u64,
    pub jobs_per_cell: usize,
    /// Sum of submitted jobs over all cells.
    pub total_jobs: usize,
    /// Measured sweep wall-clock — printed, never rendered into the JSON
    /// (the file must not depend on the host machine).
    pub sweep_seconds: f64,
    pub cells: Vec<FleetCell>,
}

/// One fanned-out unit: (arrival, policy, pool set) indices.
#[derive(Clone, Copy)]
struct CellJob {
    ai: usize,
    pi: usize,
    ci: usize,
}

/// Run the sweep: every (arrival × policy × pool set) cell in grid order,
/// fanned out round-robin over `opts.jobs` workers and scattered back, so
/// the result is independent of the job count.
pub fn run_sweep(opts: &FleetBenchOptions) -> Result<FleetSweep> {
    let t_sweep = Instant::now();
    crate::ensure!(opts.jobs_per_cell > 0, "fleet sweep needs at least 1 job per cell");
    crate::ensure!(!opts.arrivals.is_empty(), "fleet sweep needs at least one arrival pattern");
    crate::ensure!(!opts.policies.is_empty(), "fleet sweep needs at least one policy");
    crate::ensure!(!opts.pool_sets.is_empty(), "fleet sweep needs at least one pool set");
    let clusters: Vec<ClusterSpec> = opts
        .pool_sets
        .iter()
        .map(|name| {
            ClusterSpec::by_name(name).with_context(|| format!("unknown pool set {name:?}"))
        })
        .collect::<Result<_>>()?;
    let jobs = opts.jobs.max(1);

    // one workload per arrival pattern, shared by that pattern's cells
    let workloads: Vec<Workload> = opts
        .arrivals
        .iter()
        .map(|&p| synthesize(p, opts.jobs_per_cell, opts.seed))
        .collect();

    let (na, np) = (opts.arrivals.len(), opts.policies.len());
    let cell_jobs: Vec<CellJob> = (0..na)
        .flat_map(|ai| {
            (0..np).flat_map(move |pi| (0..clusters.len()).map(move |ci| CellJob { ai, pi, ci }))
        })
        .collect();
    // round-robin permutation + scatter-back, as in the e2e sweep: strided
    // chunks spread slow cells across workers, grid-order reduction keeps
    // the output independent of both
    let n_cells = cell_jobs.len();
    let stride = jobs.min(n_cells).max(1);
    let order: Vec<usize> = (0..stride).flat_map(|c| (c..n_cells).step_by(stride)).collect();
    let permuted: Vec<CellJob> = order.iter().map(|&gi| cell_jobs[gi]).collect();
    let permuted_results = par::map_up_to(jobs, &permuted, |_, job| {
        let &CellJob { ai, pi, ci } = job;
        let sim_opts = SimOptions {
            policy: opts.policies[pi],
            cluster: clusters[ci].clone(),
            // same rule as e2e: with cells on worker threads, keep each
            // cell's scheduler single-threaded
            serial_scheduler: jobs > 1,
        };
        Some(simulate(&workloads[ai], &sim_opts))
    });
    let mut results: Vec<Option<Result<FleetReport>>> = (0..n_cells).map(|_| None).collect();
    for (&gi, r) in order.iter().zip(permuted_results) {
        results[gi] = r;
    }

    let mut cells = Vec::with_capacity(n_cells);
    let mut total_jobs = 0usize;
    let mut idx = 0usize;
    for (ai, &arrival) in opts.arrivals.iter().enumerate() {
        for _pi in 0..np {
            for cluster in &clusters {
                // skrull-lint: allow(panic-in-lib) -- reduce loop visits each grid slot exactly once; a double-take is a bench-harness bug, not an input error
                let report = results[idx].take().expect("each cell reduced once").with_context(
                    || {
                        format!(
                            "fleet cell {} × {} failed",
                            arrival.name(),
                            cluster.name
                        )
                    },
                )?;
                idx += 1;
                crate::ensure!(
                    report.submitted == workloads[ai].jobs.len(),
                    "cell lost jobs: {} submitted of {}",
                    report.submitted,
                    workloads[ai].jobs.len()
                );
                total_jobs += report.submitted;
                cells.push(FleetCell {
                    arrival,
                    pool_set: cluster.name,
                    pool_gpus: cluster.total_gpus(),
                    report,
                });
            }
        }
    }
    Ok(FleetSweep {
        seed: opts.seed,
        jobs_per_cell: opts.jobs_per_cell,
        total_jobs,
        sweep_seconds: t_sweep.elapsed().as_secs_f64(),
        cells,
    })
}

/// Render one cell's JSON payload, byte-for-byte as `render_json` embeds
/// it.  `skrull serve --replay` emits this exact string for its single
/// cell and CI `cmp`s it against the simulator's — the daemon must never
/// out-decide the simulator, and this shared renderer is where the two
/// paths converge.
pub fn render_cell_json(
    arrival: &str,
    pool_set: &str,
    pool_gpus: usize,
    r: &FleetReport,
) -> String {
    let w = &r.queue_wait;
    format!(
        "{{\"arrival\": \"{}\", \"fleet_policy\": \"{}\", \"pool_set\": \"{}\", \
         \"pool_gpus\": {}, \"submitted\": {}, \"admitted\": {}, \"rejected\": {}, \
         \"finished\": {}, \"preemptions\": {}, \"builds\": {}, \"pricings\": {}, \
         \"max_builds_per_job\": {}, \"priority_inversions\": {}, \
         \"makespan\": {:e}, \"utilization\": {:.4}, \"fairness_ratio\": {:.4}, \
         \"queue_wait_mean\": {:e}, \"queue_wait_p50\": {:e}, \
         \"queue_wait_p95\": {:e}, \"queue_wait_max\": {:e}}}",
        json_str(arrival),
        json_str(r.policy.name()),
        json_str(pool_set),
        pool_gpus,
        r.submitted,
        r.admitted,
        r.rejected,
        r.finished,
        r.preemptions,
        r.builds,
        r.pricings,
        r.max_builds_per_job,
        r.priority_inversions,
        r.makespan,
        r.utilization,
        r.fairness_ratio,
        w.mean(),
        w.quantile(0.5),
        w.quantile(0.95),
        w.max(),
    )
}

/// Render the sweep as `BENCH_fleet.json` (schema v1, hand-rolled JSON; no
/// serde in the image).  Deliberately excludes `sweep_seconds`: nothing in
/// the file depends on the host, so byte-identity across `--jobs` holds
/// unconditionally.
pub fn render_json(sweep: &FleetSweep) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"bench\": \"fleet\",");
    let _ = writeln!(out, "  \"schema_version\": 1,");
    let _ = writeln!(out, "  \"seed\": {},", sweep.seed);
    let _ = writeln!(out, "  \"jobs_per_cell\": {},", sweep.jobs_per_cell);
    let _ = writeln!(out, "  \"total_jobs\": {},", sweep.total_jobs);
    out.push_str("  \"cells\": [\n");
    for (i, c) in sweep.cells.iter().enumerate() {
        let _ = writeln!(
            out,
            "    {}{}",
            render_cell_json(c.arrival.name(), c.pool_set, c.pool_gpus, &c.report),
            if i + 1 == sweep.cells.len() { "" } else { "," }
        );
    }
    out.push_str("  ]\n}\n");
    out
}

const REQUIRED_TOP_KEYS: [&str; 6] = [
    "\"bench\"",
    "\"schema_version\"",
    "\"seed\"",
    "\"jobs_per_cell\"",
    "\"total_jobs\"",
    "\"cells\"",
];

const REQUIRED_CELL_KEYS: [&str; 20] = [
    "arrival",
    "fleet_policy",
    "pool_set",
    "pool_gpus",
    "submitted",
    "admitted",
    "rejected",
    "finished",
    "preemptions",
    "builds",
    "pricings",
    "max_builds_per_job",
    "priority_inversions",
    "makespan",
    "utilization",
    "fairness_ratio",
    "queue_wait_mean",
    "queue_wait_p50",
    "queue_wait_p95",
    "queue_wait_max",
];

const FINITE_CELL_KEYS: [&str; 7] = [
    "makespan",
    "utilization",
    "fairness_ratio",
    "queue_wait_mean",
    "queue_wait_p50",
    "queue_wait_p95",
    "queue_wait_max",
];

fn cell_ints(text: &str, key: &str) -> Result<Vec<u64>> {
    values_after(text, key)
        .iter()
        .enumerate()
        .map(|(i, v)| {
            v.parse()
                .map_err(|_| crate::anyhow!("cell {i}: \"{key}\" value {v:?} is not an integer"))
        })
        .collect()
}

/// CI gate: does `text` look like a complete, sane `BENCH_fleet.json`?
/// Schema v1 checks: required top-level and per-cell keys, finite metric
/// values, and the fleet invariants — per-cell conservation
/// (`submitted == finished + rejected`, `admitted == finished`), the
/// build-once guarantee (`builds == finished`, `max_builds_per_job == 1`,
/// `pricings ≥ builds`), zero priority inversions, `utilization` in
/// (0, 1], `fairness_ratio ≥ 1`, ordered queue-wait quantiles, the
/// total-jobs sum, and full grid coverage (every arrival pattern, queue
/// policy and pool set present).
pub fn validate_json(text: &str) -> Result<()> {
    require_top_keys(text, &REQUIRED_TOP_KEYS)?;
    let version: u64 = values_after(text, "schema_version")
        .first()
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| crate::anyhow!("unparsable schema_version"))?;
    crate::ensure!(version >= 1, "schema_version {version} predates v1");
    let n_cells = values_after(text, "arrival").len();
    crate::ensure!(n_cells > 0, "no cells in BENCH_fleet.json");
    for key in REQUIRED_CELL_KEYS {
        require_count(text, key, n_cells, "cell")?;
    }
    for key in FINITE_CELL_KEYS {
        finite_values(text, key)?;
    }
    let submitted = cell_ints(text, "submitted")?;
    let admitted = cell_ints(text, "admitted")?;
    let rejected = cell_ints(text, "rejected")?;
    let finished = cell_ints(text, "finished")?;
    let builds = cell_ints(text, "builds")?;
    let pricings = cell_ints(text, "pricings")?;
    let max_builds = cell_ints(text, "max_builds_per_job")?;
    let inversions = cell_ints(text, "priority_inversions")?;
    for i in 0..n_cells {
        crate::ensure!(
            submitted[i] == finished[i] + rejected[i] && admitted[i] == finished[i],
            "cell {i}: conservation violated ({} submitted, {} admitted, {} rejected, {} finished)",
            submitted[i],
            admitted[i],
            rejected[i],
            finished[i]
        );
        crate::ensure!(
            builds[i] == finished[i] && max_builds[i] == 1 && pricings[i] >= builds[i],
            "cell {i}: build-once violated ({} builds, max {} per job, {} pricings, {} finished)",
            builds[i],
            max_builds[i],
            pricings[i],
            finished[i]
        );
        crate::ensure!(
            inversions[i] == 0,
            "cell {i}: {} priority inversions — the priority discipline is broken",
            inversions[i]
        );
    }
    let makespans = finite_values(text, "makespan")?;
    let utils = finite_values(text, "utilization")?;
    let fairness = finite_values(text, "fairness_ratio")?;
    let p50 = finite_values(text, "queue_wait_p50")?;
    let p95 = finite_values(text, "queue_wait_p95")?;
    let wmax = finite_values(text, "queue_wait_max")?;
    for i in 0..n_cells {
        crate::ensure!(makespans[i] > 0.0, "cell {i}: makespan {} not positive", makespans[i]);
        crate::ensure!(
            utils[i] > 0.0 && utils[i] <= 1.0,
            "cell {i}: utilization {} outside (0, 1]",
            utils[i]
        );
        crate::ensure!(fairness[i] >= 1.0, "cell {i}: fairness_ratio {} < 1", fairness[i]);
        crate::ensure!(
            p50[i] <= p95[i] && p95[i] <= wmax[i] && p50[i] >= 0.0,
            "cell {i}: queue-wait quantiles out of order ({} / {} / {})",
            p50[i],
            p95[i],
            wmax[i]
        );
    }
    let total: u64 = values_after(text, "total_jobs")
        .first()
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| crate::anyhow!("unparsable total_jobs"))?;
    let sum: u64 = submitted.iter().sum();
    crate::ensure!(total == sum, "total_jobs {total} != sum of submitted {sum}");
    for p in ArrivalPattern::ALL {
        crate::ensure!(
            text.contains(&format!("\"arrival\": \"{}\"", p.name())),
            "arrival pattern {} missing from sweep",
            p.name()
        );
    }
    for p in FleetPolicy::ALL {
        crate::ensure!(
            text.contains(&format!("\"fleet_policy\": \"{}\"", p.name())),
            "fleet policy {} missing from sweep",
            p.name()
        );
    }
    for name in ClusterSpec::ALL_NAMES {
        crate::ensure!(
            text.contains(&format!("\"pool_set\": \"{name}\"")),
            "pool set {name} missing from sweep"
        );
    }
    Ok(())
}

/// Paper-shaped summary table: one row per cell.
pub fn print_summary(sweep: &FleetSweep) {
    let mut t = TableBuilder::new("Fleet scheduling sweep").header(&[
        "Arrival",
        "Policy",
        "Pools",
        "Jobs",
        "Rej",
        "Preempt",
        "Makespan",
        "Util",
        "Fairness",
        "Wait p50",
        "Wait p95",
    ]);
    for c in &sweep.cells {
        let r = &c.report;
        t.row(&[
            c.arrival.name().to_string(),
            r.policy.name().to_string(),
            c.pool_set.to_string(),
            r.submitted.to_string(),
            r.rejected.to_string(),
            r.preemptions.to_string(),
            crate::util::fmt_secs(r.makespan),
            format!("{:.1}%", r.utilization * 100.0),
            format!("{:.2}", r.fairness_ratio),
            crate::util::fmt_secs(r.queue_wait.quantile(0.5)),
            crate::util::fmt_secs(r.queue_wait.quantile(0.95)),
        ]);
    }
    t.print();
    println!(
        "{} jobs over {} cells (seed {}), swept in {:.2}s",
        sweep.total_jobs,
        sweep.cells.len(),
        sweep.seed,
        sweep.sweep_seconds
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> FleetBenchOptions {
        let mut o = FleetBenchOptions::smoke();
        o.jobs_per_cell = 4;
        o.jobs = 1;
        o
    }

    #[test]
    fn sweep_covers_the_grid_and_counts_jobs() {
        let sweep = run_sweep(&tiny_opts()).unwrap();
        assert_eq!(sweep.cells.len(), 3 * 4 * 2);
        assert_eq!(sweep.total_jobs, 3 * 4 * 2 * 4);
        assert!(sweep.sweep_seconds > 0.0);
        for c in &sweep.cells {
            assert_eq!(c.report.submitted, 4);
            assert_eq!(c.report.max_builds_per_job, 1);
        }
    }

    #[test]
    fn parallel_sweep_emits_byte_identical_json() {
        let mut o = tiny_opts();
        let serial = render_json(&run_sweep(&o).unwrap());
        for jobs in [2, 4, 16] {
            o.jobs = jobs;
            let parallel = render_json(&run_sweep(&o).unwrap());
            assert_eq!(serial, parallel, "jobs={jobs} diverged from serial");
        }
        validate_json(&serial).unwrap();
    }

    #[test]
    fn rendered_json_validates_and_mutations_fail() {
        let sweep = run_sweep(&tiny_opts()).unwrap();
        let json = render_json(&sweep);
        validate_json(&json).unwrap();
        // the sweep's wall-clock never reaches the file
        assert!(!json.contains("sweep_seconds"));

        let broken = json.replace("\"schema_version\"", "\"schema_ver\"");
        assert!(validate_json(&broken).is_err());
        let broken = json.replacen("\"fairness_ratio\"", "\"fairness\"", 1);
        assert!(validate_json(&broken).is_err());
        assert!(validate_json(&json[..json.len() / 2]).is_err());
        // conservation: drop a finished job
        let sample = format!("\"finished\": {}", sweep.cells[0].report.finished);
        let broken = json.replacen(&sample, "\"finished\": 0", 1);
        assert_ne!(broken, json, "mutation must apply");
        assert!(validate_json(&broken).is_err());
        // build-once: a job built twice
        let broken = json.replacen("\"max_builds_per_job\": 1", "\"max_builds_per_job\": 2", 1);
        assert_ne!(broken, json, "mutation must apply");
        let err = validate_json(&broken).unwrap_err().to_string();
        assert!(err.contains("build-once"), "{err}");
        // a priority inversion
        let broken = json.replacen("\"priority_inversions\": 0", "\"priority_inversions\": 3", 1);
        assert_ne!(broken, json, "mutation must apply");
        assert!(validate_json(&broken).is_err());
        // a non-finite metric
        let sample = values_after(&json, "makespan")[0].to_string();
        let broken = json.replacen(
            &format!("\"makespan\": {sample}"),
            "\"makespan\": NaN",
            1,
        );
        assert_ne!(broken, json, "mutation must apply");
        assert!(validate_json(&broken).is_err());
        // utilization above 1
        let sample = values_after(&json, "utilization")[0].to_string();
        let broken = json.replacen(
            &format!("\"utilization\": {sample}"),
            "\"utilization\": 1.5000",
            1,
        );
        assert_ne!(broken, json, "mutation must apply");
        assert!(validate_json(&broken).is_err());
        // total_jobs disagreeing with the cells
        let broken = json.replacen(
            &format!("\"total_jobs\": {}", sweep.total_jobs),
            "\"total_jobs\": 1",
            1,
        );
        assert_ne!(broken, json, "mutation must apply");
        assert!(validate_json(&broken).is_err());
        // a missing policy
        let broken = json.replace("\"fleet_policy\": \"fifo\"", "\"fleet_policy\": \"lifo\"");
        assert!(validate_json(&broken).is_err());
    }

    #[test]
    fn summary_table_renders_every_cell() {
        let sweep = run_sweep(&tiny_opts()).unwrap();
        // print_summary goes to stdout; exercise the row construction path
        // via the same table builder
        let mut t = TableBuilder::new("t").header(&["Arrival"]);
        for c in &sweep.cells {
            t.row_strs(&[c.arrival.name()]);
        }
        let rendered = t.render();
        assert_eq!(rendered.matches("steady").count(), 8);
        print_summary(&sweep);
    }

    #[test]
    fn bad_options_are_rejected() {
        let mut o = tiny_opts();
        o.pool_sets = vec!["mystery".into()];
        assert!(run_sweep(&o).is_err());
        let mut o = tiny_opts();
        o.jobs_per_cell = 0;
        assert!(run_sweep(&o).is_err());
        let mut o = tiny_opts();
        o.arrivals = vec![];
        assert!(run_sweep(&o).is_err());
    }
}
