//! Training metrics: loss curve, iteration timings, token throughput.

use crate::util::stats::Summary;

#[derive(Clone, Debug, Default)]
pub struct TrainMetrics {
    /// (step, loss) pairs
    pub loss_curve: Vec<(usize, f32)>,
    pub step_seconds: Summary,
    pub tokens_processed: u64,
    /// tokens that carried loss (non-padding, non-final)
    pub loss_tokens: u64,
    pub micro_batches_executed: usize,
    pub sched_seconds: f64,
    /// GDS/DACP passes performed — one per optimizer step (the trainer
    /// schedules each sampled batch exactly once, mirroring the run
    /// engine's `BuiltRun::sched_invocations` accounting)
    pub sched_invocations: usize,
}

impl TrainMetrics {
    pub fn record_step(&mut self, step: usize, loss: f32, seconds: f64, tokens: u64, loss_tokens: u64, mbs: usize) {
        self.loss_curve.push((step, loss));
        self.step_seconds.push(seconds);
        self.tokens_processed += tokens;
        self.loss_tokens += loss_tokens;
        self.micro_batches_executed += mbs;
    }

    pub fn tokens_per_second(&self) -> f64 {
        let total: f64 = self.step_seconds.len() as f64 * self.step_seconds.mean();
        if total > 0.0 {
            self.tokens_processed as f64 / total
        } else {
            0.0
        }
    }

    pub fn first_loss(&self) -> Option<f32> {
        self.loss_curve.first().map(|&(_, l)| l)
    }

    /// Mean loss over the final `n` recorded steps.
    pub fn final_loss(&self, n: usize) -> Option<f32> {
        if self.loss_curve.is_empty() {
            return None;
        }
        let tail = &self.loss_curve[self.loss_curve.len().saturating_sub(n)..];
        Some(tail.iter().map(|&(_, l)| l).sum::<f32>() / tail.len() as f32)
    }

    /// Render the loss curve as sparse text rows (for EXPERIMENTS.md).
    pub fn render_curve(&self, every: usize) -> String {
        let mut out = String::from("step,loss\n");
        for (i, &(step, loss)) in self.loss_curve.iter().enumerate() {
            if i % every == 0 || i + 1 == self.loss_curve.len() {
                out.push_str(&format!("{step},{loss:.4}\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = TrainMetrics::default();
        m.record_step(0, 6.0, 0.5, 1000, 900, 4);
        m.record_step(1, 5.0, 0.5, 1000, 900, 4);
        assert_eq!(m.first_loss(), Some(6.0));
        assert_eq!(m.final_loss(1), Some(5.0));
        assert_eq!(m.final_loss(10), Some(5.5));
        assert_eq!(m.tokens_processed, 2000);
        assert!((m.tokens_per_second() - 2000.0).abs() < 1.0);
        assert_eq!(m.micro_batches_executed, 8);
    }

    #[test]
    fn curve_rendering_includes_last_point() {
        let mut m = TrainMetrics::default();
        for i in 0..10 {
            m.record_step(i, 6.0 - i as f32 * 0.1, 0.1, 10, 9, 1);
        }
        let s = m.render_curve(4);
        assert!(s.starts_with("step,loss"));
        assert!(s.contains("0,6.0000"));
        assert!(s.contains("9,5.1000"));
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = TrainMetrics::default();
        assert_eq!(m.first_loss(), None);
        assert_eq!(m.final_loss(3), None);
        assert_eq!(m.tokens_per_second(), 0.0);
    }
}
