//! Training-state checkpointing: params + AdamW moments + step counter in
//! a self-describing little-endian binary format (no serde offline).
//!
//! Layout:
//!   magic  "SKRULLCK"            8 bytes
//!   version u32                  (= 1)
//!   step    u32
//!   lr      f32
//!   n       u64  (param count)
//!   params  n × f32
//!   m       n × f32
//!   v       n × f32
//!   crc     u64  (FNV-1a over everything above)

use std::io::Read;
use std::path::Path;

const MAGIC: &[u8; 8] = b"SKRULLCK";
const VERSION: u32 = 1;

#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    BadMagic,
    BadVersion(u32),
    BadChecksum,
    Truncated { need: usize, got: usize },
    SizeMismatch { got: usize, want: usize },
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "io: {e}"),
            CheckpointError::BadMagic => write!(f, "bad magic — not a skrull checkpoint"),
            CheckpointError::BadVersion(v) => write!(f, "unsupported checkpoint version {v}"),
            CheckpointError::BadChecksum => write!(f, "checksum mismatch (file corrupt)"),
            CheckpointError::Truncated { need, got } => {
                write!(f, "checkpoint truncated: need {need} bytes, got {got}")
            }
            CheckpointError::SizeMismatch { got, want } => {
                write!(f, "parameter count mismatch: checkpoint {got}, model {want}")
            }
        }
    }
}

impl std::error::Error for CheckpointError {}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

/// A complete resumable training state.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainState {
    pub step: u32,
    pub lr: f32,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

/// FNV-1a over a byte payload — the checkpoint CRC shared with the fleet
/// simulator's preemption resume codec (`fleet::sim::ResumePoint`).
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn push_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    buf.reserve(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Read a fixed-width little-endian field, propagating a structured
/// error (never panicking) on short input.
fn le_bytes<const N: usize>(bytes: &[u8], off: usize) -> Result<[u8; N], CheckpointError> {
    bytes
        .get(off..off + N)
        .and_then(|s| <[u8; N]>::try_from(s).ok())
        .ok_or(CheckpointError::Truncated { need: off + N, got: bytes.len() })
}

fn read_f32s(bytes: &[u8], n: usize, off: &mut usize) -> Result<Vec<f32>, CheckpointError> {
    // saturating: `n` comes straight from the (possibly corrupt) file
    let need = n.saturating_mul(4);
    if off.saturating_add(need) > bytes.len() {
        return Err(CheckpointError::Truncated {
            need: off.saturating_add(need),
            got: bytes.len(),
        });
    }
    let mut out = vec![0f32; n];
    for (i, ch) in bytes[*off..*off + need].chunks_exact(4).enumerate() {
        out[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
    }
    *off += need;
    Ok(out)
}

impl TrainState {
    pub fn encode(&self) -> Vec<u8> {
        assert_eq!(self.params.len(), self.m.len());
        assert_eq!(self.params.len(), self.v.len());
        let mut buf = Vec::with_capacity(32 + self.params.len() * 12);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&self.step.to_le_bytes());
        buf.extend_from_slice(&self.lr.to_le_bytes());
        buf.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        push_f32s(&mut buf, &self.params);
        push_f32s(&mut buf, &self.m);
        push_f32s(&mut buf, &self.v);
        let crc = fnv1a(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf
    }

    pub fn decode(bytes: &[u8]) -> Result<TrainState, CheckpointError> {
        if bytes.get(..8) != Some(&MAGIC[..]) {
            return Err(CheckpointError::BadMagic);
        }
        // magic + version/step/lr/n header + trailing crc
        let min = 8 + 20 + 8;
        if bytes.len() < min {
            return Err(CheckpointError::Truncated { need: min, got: bytes.len() });
        }
        let body = &bytes[..bytes.len() - 8];
        let crc_stored = u64::from_le_bytes(le_bytes(bytes, bytes.len() - 8)?);
        if fnv1a(body) != crc_stored {
            return Err(CheckpointError::BadChecksum);
        }
        let ver = u32::from_le_bytes(le_bytes(bytes, 8)?);
        if ver != VERSION {
            return Err(CheckpointError::BadVersion(ver));
        }
        let step = u32::from_le_bytes(le_bytes(bytes, 12)?);
        let lr = f32::from_le_bytes(le_bytes(bytes, 16)?);
        let n = u64::from_le_bytes(le_bytes(bytes, 20)?) as usize;
        let mut off = 28;
        let params = read_f32s(body, n, &mut off)?;
        let m = read_f32s(body, n, &mut off)?;
        let v = read_f32s(body, n, &mut off)?;
        Ok(TrainState { step, lr, params, m, v })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), CheckpointError> {
        // write-tmp → fsync → rename → fsync(dir): without the final
        // directory sync the rename itself may not survive a crash
        crate::util::fsio::write_atomic(path.as_ref(), &self.encode(), "tmp")?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>, expect_params: usize) -> Result<TrainState, CheckpointError> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut bytes)?;
        let st = Self::decode(&bytes)?;
        if st.params.len() != expect_params {
            return Err(CheckpointError::SizeMismatch {
                got: st.params.len(),
                want: expect_params,
            });
        }
        Ok(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TrainState {
        TrainState {
            step: 42,
            lr: 3e-3,
            params: vec![1.0, -2.5, 3.25],
            m: vec![0.1, 0.2, 0.3],
            v: vec![0.01, 0.02, 0.03],
        }
    }

    #[test]
    fn encode_decode_round_trip() {
        let st = sample();
        let back = TrainState::decode(&st.encode()).unwrap();
        assert_eq!(st, back);
    }

    #[test]
    fn save_load_round_trip() {
        let dir = std::env::temp_dir().join(format!("skrull_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.ckpt");
        let st = sample();
        st.save(&path).unwrap();
        let back = TrainState::load(&path, 3).unwrap();
        assert_eq!(st, back);
        assert!(!path.with_extension("tmp").exists(), "tmp file must be renamed away");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corruption_is_detected() {
        let mut bytes = sample().encode();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(matches!(TrainState::decode(&bytes), Err(CheckpointError::BadChecksum)));
    }

    #[test]
    fn wrong_magic_and_version() {
        let mut bytes = sample().encode();
        bytes[0] = b'X';
        assert!(matches!(TrainState::decode(&bytes), Err(CheckpointError::BadMagic)));
        let mut bytes = sample().encode();
        bytes[8] = 9;
        // checksum covers the version field, so flipping it must first
        // trip the checksum — rebuild a valid-but-wrong-version blob:
        let body_len = bytes.len() - 8;
        let crc = super::fnv1a(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(TrainState::decode(&bytes), Err(CheckpointError::BadVersion(9))));
    }

    #[test]
    fn size_mismatch_on_load() {
        let dir = std::env::temp_dir().join(format!("skrull_ckpt_sz_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.ckpt");
        sample().save(&path).unwrap();
        assert!(matches!(
            TrainState::load(&path, 99),
            Err(CheckpointError::SizeMismatch { got: 3, want: 99 })
        ));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn truncated_file_errors() {
        let bytes = sample().encode();
        assert!(TrainState::decode(&bytes[..10]).is_err());
    }

    #[test]
    fn truncation_is_a_structured_error_not_a_panic() {
        // valid magic but nothing else: the old decode length check
        // reported this as BadMagic; it is a truncation
        let short = &sample().encode()[..20];
        assert!(matches!(
            TrainState::decode(short),
            Err(CheckpointError::Truncated { got: 20, .. })
        ));
        // header intact but the f32 payload cut off: caught by the
        // checksum first (the crc is no longer where the length says)
        let bytes = sample().encode();
        assert!(TrainState::decode(&bytes[..bytes.len() - 4]).is_err());
        // a corrupt param count must not panic or overflow, even with a
        // crc recomputed to match the corrupted header
        let mut bytes = sample().encode();
        bytes[20..28].copy_from_slice(&u64::MAX.to_le_bytes());
        let body_len = bytes.len() - 8;
        let crc = super::fnv1a(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(
            TrainState::decode(&bytes),
            Err(CheckpointError::Truncated { .. })
        ));
    }
}
