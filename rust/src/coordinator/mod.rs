//! The training coordinator: ties the scheduling DataLoader, the PJRT
//! runtime and the host-side optimizer into the end-to-end Long-SFT loop
//! (examples/long_sft_train.rs), and collects the metrics the benches and
//! EXPERIMENTS.md report.

pub mod corpus;
pub mod metrics;
pub mod optimizer;
pub mod state;
pub mod trainer;

pub use metrics::TrainMetrics;
pub use optimizer::{Adam, LrSchedule};
pub use state::TrainState;
pub use trainer::{
    bucket_capacity_for, buckets_for_iteration, TrainReport, Trainer, TrainerOptions,
};
