//! Synthetic tiny-corpus generator for the end-to-end training example.
//!
//! Sequences are drawn from a learnable order-1 Markov process: with
//! probability 1-ε the next token is a fixed affine function of the
//! current one, else uniform noise.  Cross-entropy of the optimal
//! predictor is  H = -(1-ε+ε/V)·ln(1-ε+ε/V) - ... ≈ well below ln(V),
//! so a training run that learns must show the loss dropping from ~ln(V)
//! toward the entropy floor — the e2e validation signal.

use crate::data::packing::TokenSeq;
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub vocab: i32,
    /// affine transition: next = (a*cur + b) mod vocab
    pub a: i32,
    pub b: i32,
    /// noise probability ε
    pub noise: f64,
}

impl CorpusConfig {
    pub fn tiny(vocab: i32) -> Self {
        CorpusConfig { vocab, a: 7, b: 3, noise: 0.10 }
    }

    /// Entropy floor (nats/token) of the process — the best achievable loss.
    pub fn entropy_floor(&self) -> f64 {
        let v = self.vocab as f64;
        let p_hit = (1.0 - self.noise) + self.noise / v;
        let p_other = self.noise / v;
        -(p_hit * p_hit.ln() + (v - 1.0) * p_other * p_other.ln())
    }

    /// Generate one sequence of `len` tokens.
    pub fn generate(&self, rng: &mut Rng, id: u64, len: u32) -> TokenSeq {
        let mut tokens = Vec::with_capacity(len as usize);
        let mut cur = rng.below(self.vocab as u64) as i32;
        tokens.push(cur);
        for _ in 1..len {
            cur = if rng.bool_with(self.noise) {
                rng.below(self.vocab as u64) as i32
            } else {
                (self.a * cur + self.b).rem_euclid(self.vocab)
            };
            tokens.push(cur);
        }
        TokenSeq { id, tokens }
    }

    /// Generate a corpus with the given sequence lengths.
    pub fn corpus(&self, seed: u64, lens: &[u32]) -> Vec<TokenSeq> {
        let mut rng = Rng::seed_from_u64(seed);
        lens.iter()
            .enumerate()
            .map(|(i, &l)| self.generate(&mut rng, i as u64, l))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab_and_lengths_respected() {
        let cfg = CorpusConfig::tiny(512);
        let corpus = cfg.corpus(1, &[5, 100, 37]);
        assert_eq!(corpus.len(), 3);
        assert_eq!(corpus[1].tokens.len(), 100);
        assert_eq!(corpus[2].id, 2);
        for s in &corpus {
            assert!(s.tokens.iter().all(|&t| (0..512).contains(&t)));
        }
    }

    #[test]
    fn transitions_mostly_follow_the_rule() {
        let cfg = CorpusConfig::tiny(512);
        let mut rng = Rng::seed_from_u64(2);
        let s = cfg.generate(&mut rng, 0, 10_000);
        let hits = s
            .tokens
            .windows(2)
            .filter(|w| w[1] == (cfg.a * w[0] + cfg.b).rem_euclid(cfg.vocab))
            .count();
        let rate = hits as f64 / 9_999.0;
        assert!((0.85..0.95).contains(&rate), "rate {rate}");
    }

    #[test]
    fn entropy_floor_is_below_uniform() {
        let cfg = CorpusConfig::tiny(512);
        let floor = cfg.entropy_floor();
        let uniform = (512f64).ln();
        assert!(floor < uniform / 2.0, "floor {floor} vs uniform {uniform}");
        assert!(floor > 0.0);
    }

    #[test]
    fn corpus_is_deterministic() {
        let cfg = CorpusConfig::tiny(128);
        let a = cfg.corpus(9, &[50, 60]);
        let b = cfg.corpus(9, &[50, 60]);
        assert_eq!(a[0].tokens, b[0].tokens);
        assert_eq!(a[1].tokens, b[1].tokens);
    }
}
