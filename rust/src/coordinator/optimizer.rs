//! Host-side AdamW over the flat parameter buffer (Kingma & Ba; Loshchilov
//! & Hutter).  The paper's GDS keeps scheduling within the global batch
//! precisely so these optimizers stay mathematically equivalent — the
//! trainer's gradient accumulation preserves that (token-weighted mean
//! across micro-batches before a single step).

/// AdamW with bias correction.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u32,
}

impl Adam {
    pub fn new(n: usize, lr: f32) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }

    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= self.lr * (mhat / (vhat.sqrt() + self.eps) + self.weight_decay * params[i]);
        }
    }

    pub fn steps_taken(&self) -> u32 {
        self.t
    }

    /// Expose the moment buffers + step for checkpointing.
    pub fn state(&self) -> (&[f32], &[f32], u32) {
        (&self.m, &self.v, self.t)
    }

    /// Rebuild from a checkpoint.
    pub fn from_state(lr: f32, m: Vec<f32>, v: Vec<f32>, t: u32) -> Self {
        assert_eq!(m.len(), v.len());
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0, m, v, t }
    }
}

/// Learning-rate schedules (linear warmup + cosine decay is the Long-SFT
/// staple).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LrSchedule {
    Constant(f32),
    WarmupCosine { peak: f32, warmup: u32, total: u32, floor: f32 },
}

impl LrSchedule {
    pub fn at(&self, step: u32) -> f32 {
        match *self {
            LrSchedule::Constant(lr) => lr,
            LrSchedule::WarmupCosine { peak, warmup, total, floor } => {
                if warmup > 0 && step < warmup {
                    peak * (step + 1) as f32 / warmup as f32
                } else if step >= total {
                    floor
                } else {
                    let t = (step - warmup) as f32 / (total - warmup).max(1) as f32;
                    floor + 0.5 * (peak - floor) * (1.0 + (std::f32::consts::PI * t).cos())
                }
            }
        }
    }
}

/// Clip a gradient buffer to a global L2 norm; returns the pre-clip norm.
pub fn clip_global_norm(grads: &mut [f32], max_norm: f32) -> f32 {
    let norm = grads.iter().map(|&g| (g as f64) * (g as f64)).sum::<f64>().sqrt() as f32;
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            *g *= scale;
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    /// f(x) = Σ (x_i - c_i)²: Adam must converge to c.
    #[test]
    fn converges_on_quadratic() {
        let target = [3.0f32, -2.0, 0.5];
        let mut x = vec![0.0f32; 3];
        let mut opt = Adam::new(3, 0.05);
        for _ in 0..2000 {
            let g: Vec<f32> = x.iter().zip(&target).map(|(xi, ci)| 2.0 * (xi - ci)).collect();
            opt.step(&mut x, &g);
        }
        for (xi, ci) in x.iter().zip(&target) {
            assert!((xi - ci).abs() < 1e-2, "{x:?}");
        }
        assert_eq!(opt.steps_taken(), 2000);
    }

    #[test]
    fn first_step_magnitude_is_lr() {
        // with bias correction, |Δx| of step 1 ≈ lr regardless of grad scale
        for scale in [1e-3f32, 1.0, 1e3] {
            let mut x = vec![0.0f32];
            let mut opt = Adam::new(1, 0.01);
            opt.step(&mut x, &[scale]);
            assert!((x[0].abs() - 0.01).abs() < 1e-4, "scale {scale} -> {}", x[0]);
        }
    }

    #[test]
    fn weight_decay_pulls_toward_zero() {
        let mut x = vec![1.0f32];
        let mut opt = Adam::new(1, 0.01);
        opt.weight_decay = 0.1;
        for _ in 0..100 {
            opt.step(&mut x, &[0.0]);
        }
        assert!(x[0] < 1.0);
    }

    #[test]
    #[should_panic]
    fn mismatched_sizes_panic() {
        let mut opt = Adam::new(2, 0.1);
        let mut x = vec![0.0f32; 2];
        opt.step(&mut x, &[1.0]);
    }

    #[test]
    fn state_round_trip_resumes_identically() {
        // train 5 steps; checkpoint; train 5 more vs resume-and-train 5:
        // identical trajectories.
        let grad = |x: &[f32]| vec![2.0 * (x[0] - 1.0), 2.0 * (x[1] + 1.0)];
        let mut x1 = vec![0.0f32; 2];
        let mut o1 = Adam::new(2, 0.05);
        for _ in 0..5 {
            let g = grad(&x1);
            o1.step(&mut x1, &g);
        }
        let (m, v, t) = o1.state();
        let mut o2 = Adam::from_state(0.05, m.to_vec(), v.to_vec(), t);
        let mut x2 = x1.clone();
        for _ in 0..5 {
            let g1 = grad(&x1);
            o1.step(&mut x1, &g1);
            let g2 = grad(&x2);
            o2.step(&mut x2, &g2);
        }
        assert_eq!(x1, x2);
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = LrSchedule::WarmupCosine { peak: 1.0, warmup: 10, total: 110, floor: 0.1 };
        assert!(s.at(0) < s.at(5));
        assert!((s.at(9) - 1.0).abs() < 0.11); // near peak at warmup end
        assert!((s.at(10) - 1.0).abs() < 1e-6);
        let mid = s.at(60);
        assert!(mid < 1.0 && mid > 0.1);
        assert!((s.at(109) - 0.1).abs() < 0.01);
        assert_eq!(s.at(500), 0.1);
        assert_eq!(LrSchedule::Constant(0.3).at(77), 0.3);
    }

    #[test]
    fn clip_global_norm_behaviour() {
        let mut g = vec![3.0f32, 4.0]; // norm 5
        let norm = clip_global_norm(&mut g, 1.0);
        assert!((norm - 5.0).abs() < 1e-6);
        let new_norm = (g[0] * g[0] + g[1] * g[1]).sqrt();
        assert!((new_norm - 1.0).abs() < 1e-6);
        // under the cap: untouched
        let mut g2 = vec![0.3f32, 0.4];
        let n2 = clip_global_norm(&mut g2, 1.0);
        assert!((n2 - 0.5).abs() < 1e-6);
        assert_eq!(g2, vec![0.3, 0.4]);
    }
}
