//! End-to-end trainer: schedule → pack → execute (PJRT) → accumulate →
//! Adam.  This is the real-workload validation path (examples/
//! long_sft_train.rs): the tiny Qwen-style model is actually trained on a
//! synthetic corpus with the same scheduler that drives the simulator.
//!
//! Emulation note (DESIGN.md §2): the CP "ranks" here are time-sliced onto
//! one CPU PJRT device, so a *sharded* sequence is executed whole in its
//! own bucket — gradient-identical to ring-attention sharding (attention
//! is exact), differing only in wall-clock semantics that the cluster
//! simulator, not this trainer, is responsible for.  What the trainer
//! demonstrates for real: packing density and micro-batch count (= PJRT
//! launches) drop under Skrull scheduling, with identical learning curves.

use crate::util::error::{Context, Result};

use crate::config::Policy;
use crate::coordinator::metrics::TrainMetrics;
use crate::memplan::{CapacitySource, MemPlan, MemoryConfig};
use crate::coordinator::optimizer::{clip_global_norm, Adam, LrSchedule};
use crate::coordinator::state::TrainState;
use crate::data::packing::{pack, PackedBucket, TokenSeq};
use crate::data::Sequence;
use crate::model::ModelSpec;
use crate::perfmodel::{CostModel, FlopsModel};
use crate::rng::Rng;
use crate::runtime::{Manifest, Runtime};
use crate::scheduler::{dispatch, gds};

#[derive(Clone, Debug)]
pub struct TrainerOptions {
    /// emulated worker count (DP×CP footprint of the schedule)
    pub workers: usize,
    /// BucketSize C in tokens; must not exceed the largest artifact bucket
    pub bucket_capacity: u32,
    pub policy: Policy,
    pub lr: f32,
    pub seed: u64,
    pub batch_size: usize,
    /// optional warmup+cosine schedule (overrides the constant lr)
    pub lr_schedule: Option<LrSchedule>,
    /// global gradient-norm clip (None = off)
    pub clip_norm: Option<f32>,
    /// where `bucket_capacity` comes from: hand-set (`Fixed`) or derived
    /// from `hbm_gb` via memplan (then clamped to the largest compiled
    /// artifact bucket, since HLO shapes are static)
    pub capacity: CapacitySource,
    /// HBM budget for `CapacitySource::HbmDerived`, in GiB
    pub hbm_gb: f64,
    /// calibrated coefficients (`skrull calibrate`): when present and the
    /// profile carries a memory fit, the HBM-derived capacity uses the
    /// measured activation curve instead of the analytic one
    pub profile: Option<crate::calib::CalibratedProfile>,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            workers: 4,
            bucket_capacity: 1024,
            policy: Policy::Skrull,
            lr: 3e-3,
            seed: 42,
            batch_size: 16,
            lr_schedule: None,
            clip_norm: None,
            capacity: CapacitySource::Fixed,
            hbm_gb: 80.0,
            profile: None,
        }
    }
}

#[derive(Debug)]
pub struct TrainReport {
    pub metrics: TrainMetrics,
    pub buckets_executed: usize,
    pub padded_tokens: u64,
    pub executed_tokens: u64,
    pub wall_seconds: f64,
    pub compile_seconds: f64,
}

impl TrainReport {
    /// Padding waste: fraction of executed tokens that were padding.
    pub fn padding_fraction(&self) -> f64 {
        if self.executed_tokens == 0 {
            0.0
        } else {
            self.padded_tokens as f64 / self.executed_tokens as f64
        }
    }
}

pub struct Trainer {
    pub runtime: Runtime,
    pub params: crate::runtime::FlatParams,
    opt: Adam,
    opts: TrainerOptions,
    flops: FlopsModel,
    /// analytic tiny-model cost model, built once — only the cost-aware
    /// refinement (SkrullRefined) consults it
    cost: CostModel,
    /// scheduler scratch arena, reused across steps like the run engine's
    /// DataLoader (the per-step throwaway arena was a hidden allocation)
    ctx: gds::SchedCtx,
    rng: Rng,
}

impl Trainer {
    pub fn new(artifacts_dir: &str, opts: TrainerOptions) -> Result<Self> {
        let runtime = Runtime::load(artifacts_dir)?;
        let largest = runtime
            .manifest
            .largest_bucket()
            .context("no buckets in manifest")?;
        let mut opts = opts;
        if opts.capacity == CapacitySource::HbmDerived {
            opts.bucket_capacity = derived_bucket_capacity(
                &ModelSpec::tiny(),
                opts.workers,
                opts.hbm_gb,
                largest,
                opts.profile.as_ref(),
            )?;
        }
        crate::ensure!(
            opts.bucket_capacity <= largest,
            "bucket_capacity {} exceeds largest artifact bucket {largest}",
            opts.bucket_capacity
        );
        let params = runtime.initial_params()?;
        let opt = Adam::new(params.data.len(), opts.lr);
        let flops = FlopsModel::new(&ModelSpec::tiny());
        let cost = CostModel::paper_default(&ModelSpec::tiny());
        let rng = Rng::seed_from_u64(opts.seed);
        Ok(Trainer {
            runtime,
            params,
            opt,
            opts,
            flops,
            cost,
            ctx: gds::SchedCtx::default(),
            rng,
        })
    }

    /// Build the iteration's packed buckets from a schedule: each CP rank's
    /// local sequences pack together; each distributed sequence gets its
    /// own bucket (see the emulation note above).  Errs (instead of killing
    /// the run) when a sequence exceeds every compiled artifact bucket.
    fn buckets_for_iteration(
        &self,
        corpus: &[TokenSeq],
        sched: &crate::scheduler::IterationSchedule,
    ) -> Result<Vec<PackedBucket>> {
        buckets_for_iteration(&self.runtime.manifest, corpus, sched, self.opts.workers)
    }

    fn schedule(
        &mut self,
        batch: &[Sequence],
    ) -> Result<crate::scheduler::IterationSchedule> {
        // one dispatch shared with the scheduling DataLoader: dp=1, the
        // emulated workers as the CP footprint
        let gcfg = gds::GdsConfig::new(self.opts.bucket_capacity, self.opts.workers, 1);
        let sched = dispatch::schedule_policy(
            self.opts.policy,
            batch,
            &gcfg,
            &self.flops,
            &self.cost,
            &mut self.ctx,
        )?;
        Ok(sched)
    }

    /// Run `steps` optimizer steps over the corpus; each step samples
    /// `batch_size` sequences, schedules them, executes every bucket and
    /// applies one token-weighted AdamW update (global-batch equivalence).
    pub fn train(&mut self, corpus: &[TokenSeq], steps: usize) -> Result<TrainReport> {
        let t_start = std::time::Instant::now();
        let mut metrics = TrainMetrics::default();
        let mut buckets_executed = 0usize;
        let mut padded_tokens = 0u64;
        let mut executed_tokens = 0u64;

        for step in 0..steps {
            // sample a global batch (ids index into corpus)
            let batch: Vec<Sequence> = (0..self.opts.batch_size)
                .map(|_| {
                    let id = self.rng.below(corpus.len() as u64);
                    Sequence { id, len: corpus[id as usize].tokens.len() as u32 }
                })
                .collect();

            let t_sched = std::time::Instant::now();
            let sched = self.schedule(&batch)?;
            metrics.sched_seconds += t_sched.elapsed().as_secs_f64();
            metrics.sched_invocations += 1;

            let buckets = self.buckets_for_iteration(corpus, &sched)?;
            let t0 = std::time::Instant::now();
            let mut grad_acc = vec![0f64; self.params.data.len()];
            let mut loss_acc = 0f64;
            let mut weight_acc = 0f64;
            let mut step_tokens = 0u64;
            let mut step_loss_tokens = 0u64;
            // params are constant within a step: upload once, reuse for
            // every micro-batch (EXPERIMENTS.md §Perf)
            let dev_params = self.runtime.upload_params(&self.params)?;
            for b in &buckets {
                let out = self.runtime.train_step_on(&dev_params, b)?;
                let w = b.loss_tokens();
                if w > 0.0 {
                    loss_acc += out.loss as f64 * w;
                    weight_acc += w;
                    for (acc, g) in grad_acc.iter_mut().zip(&out.grads) {
                        *acc += *g as f64 * w;
                    }
                }
                buckets_executed += 1;
                padded_tokens += b.pad_tokens() as u64;
                executed_tokens += b.capacity as u64;
                step_tokens += b.used_tokens() as u64;
                step_loss_tokens += w as u64;
            }
            crate::ensure!(weight_acc > 0.0, "step {step}: no loss-bearing tokens");
            let mut grads: Vec<f32> = grad_acc.iter().map(|&g| (g / weight_acc) as f32).collect();
            if let Some(max_norm) = self.opts.clip_norm {
                clip_global_norm(&mut grads, max_norm);
            }
            if let Some(sched) = self.opts.lr_schedule {
                self.opt.lr = sched.at(self.opt.steps_taken());
            }
            self.opt.step(&mut self.params.data, &grads);
            let loss = (loss_acc / weight_acc) as f32;
            metrics.record_step(
                step,
                loss,
                t0.elapsed().as_secs_f64(),
                step_tokens,
                step_loss_tokens,
                buckets.len(),
            );
        }

        Ok(TrainReport {
            metrics,
            buckets_executed,
            padded_tokens,
            executed_tokens,
            wall_seconds: t_start.elapsed().as_secs_f64(),
            compile_seconds: self.runtime.compile_seconds,
        })
    }

    /// Snapshot the resumable training state (params + AdamW moments).
    pub fn checkpoint(&self) -> TrainState {
        let (m, v, t) = self.opt.state();
        TrainState {
            step: t,
            lr: self.opt.lr,
            params: self.params.data.clone(),
            m: m.to_vec(),
            v: v.to_vec(),
        }
    }

    /// Restore a snapshot (param count must match the loaded artifacts).
    pub fn restore(&mut self, st: TrainState) -> Result<()> {
        crate::ensure!(
            st.params.len() == self.params.data.len(),
            "checkpoint has {} params, artifacts expect {}",
            st.params.len(),
            self.params.data.len()
        );
        self.params.data = st.params;
        self.opt = Adam::from_state(st.lr, st.m, st.v, st.step);
        Ok(())
    }
}

/// Derive the trainer's bucket capacity from an HBM budget (memplan with
/// dp=1 and the emulated workers as the CP footprint), clamped to the
/// largest compiled artifact bucket — HLO shapes are static, so memory
/// headroom beyond the biggest artifact cannot be used.  A calibrated
/// profile with a memory fit replaces the analytic activation curve and
/// static bytes with the measured ones.
pub fn derived_bucket_capacity(
    spec: &ModelSpec,
    workers: usize,
    hbm_gb: f64,
    largest_bucket: u32,
    profile: Option<&crate::calib::CalibratedProfile>,
) -> Result<u32> {
    let mem = MemoryConfig {
        source: CapacitySource::HbmDerived,
        hbm_gb,
        ..Default::default()
    };
    let mut plan = MemPlan::new(spec, 1, workers.max(1), &mem);
    if let Some(m) = profile.and_then(|p| p.mem.as_ref()) {
        plan = plan.with_calibrated(m.slope, m.intercept);
    }
    let c = plan.derive_capacity().with_context(|| {
        format!("HBM budget of {hbm_gb} GiB cannot hold the {} static state", spec.name)
    })?;
    Ok(c.min(largest_bucket))
}

/// Smallest compiled bucket that holds `tokens` (HLO shapes are static).
/// A sequence no artifact can hold is a clean, reportable configuration
/// error — not a reason to panic mid-run.
pub fn bucket_capacity_for(manifest: &Manifest, tokens: usize) -> Result<usize> {
    manifest
        .bucket_for(tokens as u32)
        .map(|b| b as usize)
        .with_context(|| {
            format!(
                "no artifact bucket holds {tokens} tokens (largest compiled bucket: {})",
                manifest.largest_bucket().unwrap_or(0)
            )
        })
}

/// Manifest-level bucket construction backing [`Trainer::train`]: each CP
/// rank's local sequences pack together; each distributed sequence gets its
/// own bucket (time-sliced CP emulation, see the module note).
pub fn buckets_for_iteration(
    manifest: &Manifest,
    corpus: &[TokenSeq],
    sched: &crate::scheduler::IterationSchedule,
    cp: usize,
) -> Result<Vec<PackedBucket>> {
    let mut buckets = Vec::new();
    for rank in &sched.ranks {
        for mb in &rank.micro_batches {
            for j in 0..cp {
                let locals: Vec<&TokenSeq> = mb
                    .plan
                    .locals_of(j)
                    .map(|i| &corpus[mb.seqs[i].id as usize])
                    .collect();
                if locals.is_empty() {
                    continue;
                }
                let used: usize = locals.iter().map(|s| s.tokens.len()).sum();
                let cap = bucket_capacity_for(manifest, used)?;
                buckets.push(pack(&locals, cap));
            }
            for i in mb.plan.distributed() {
                let seq = &corpus[mb.seqs[i].id as usize];
                let cap = bucket_capacity_for(manifest, seq.tokens.len())?;
                buckets.push(pack(&[seq], cap));
            }
        }
    }
    Ok(buckets)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Sequence;
    use crate::scheduler::plan::{DacpPlan, IterationSchedule, MicroBatch, RankSchedule};
    use std::path::PathBuf;

    const MANIFEST: &str = "\
version 1
model vocab=512 hidden=256 layers=4 seed=0
param tok_embed 512x256
bucket 8 train_step_t8.hlo.txt
bucket 16 train_step_t16.hlo.txt
params params.bin
";

    fn corpus(lens: &[usize]) -> Vec<TokenSeq> {
        lens.iter()
            .enumerate()
            .map(|(id, &n)| TokenSeq { id: id as u64, tokens: vec![1; n] })
            .collect()
    }

    fn sched_of(corpus: &[TokenSeq], assign: Vec<i32>) -> IterationSchedule {
        IterationSchedule {
            ranks: vec![RankSchedule {
                micro_batches: vec![MicroBatch {
                    seqs: corpus
                        .iter()
                        .map(|s| Sequence { id: s.id, len: s.tokens.len() as u32 })
                        .collect(),
                    plan: DacpPlan { assign },
                }],
            }],
        }
    }

    #[test]
    fn oversized_sequence_is_an_error_not_a_panic() {
        // Regression: capacity_for used to panic ("no artifact bucket holds
        // ..."), killing the training run.
        let m = Manifest::parse(MANIFEST, PathBuf::from("/a")).unwrap();
        let corpus = corpus(&[100]); // > largest bucket (16)
        let sched = sched_of(&corpus, vec![0]);
        let err = buckets_for_iteration(&m, &corpus, &sched, 2).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("no artifact bucket holds 100 tokens"), "{msg}");
        assert!(msg.contains("largest compiled bucket: 16"), "{msg}");
    }

    #[test]
    fn fitting_sequences_pack_into_smallest_buckets() {
        let m = Manifest::parse(MANIFEST, PathBuf::from("/a")).unwrap();
        let corpus = corpus(&[5, 3, 12]);
        // seqs 0+1 local on rank 0 (5+3=8 → bucket 8); seq 2 local on
        // rank 1 (12 → bucket 16)
        let sched = sched_of(&corpus, vec![0, 0, 1]);
        let buckets = buckets_for_iteration(&m, &corpus, &sched, 2).unwrap();
        assert_eq!(buckets.len(), 2);
        assert_eq!(buckets[0].capacity, 8);
        assert_eq!(buckets[0].seq_ids, vec![0, 1]);
        assert_eq!(buckets[1].capacity, 16);
        assert_eq!(buckets[1].seq_ids, vec![2]);
    }

    #[test]
    fn derived_bucket_capacity_clamps_to_artifacts() {
        let spec = crate::model::ModelSpec::tiny();
        // a generous budget derives far more than any compiled bucket →
        // clamped to the artifact ceiling
        assert_eq!(derived_bucket_capacity(&spec, 4, 1.0, 1024, None).unwrap(), 1024);
        // a tight budget derives a real (smaller) capacity: tiny statics
        // are ~19 MB, so 32 MB leaves room for a few hundred tokens
        let c = derived_bucket_capacity(&spec, 4, 0.03125, 1024, None).unwrap();
        assert!(c >= 1 && c < 1024, "derived {c}");
        // and a budget below the static state is a clean error
        assert!(derived_bucket_capacity(&spec, 4, 0.01, 1024, None).is_err());
    }

    #[test]
    fn calibrated_profile_steers_derived_capacity() {
        use crate::calib::{CalibratedProfile, Fit};
        let spec = crate::model::ModelSpec::tiny();
        let fit = |slope: f64, intercept: f64| Fit {
            slope,
            intercept,
            r2: 1.0,
            slope_stderr: 0.0,
            intercept_stderr: 0.0,
            n: 10,
            outliers_dropped: 0,
        };
        // measured: 1 KB/token of activations over 16 MB of static state
        let profile = CalibratedProfile {
            version: crate::calib::fit::PROFILE_SCHEMA_VERSION,
            model: "tiny".into(),
            comp: fit(1e-15, 1e-6),
            comm: fit(1e-11, 1e-5),
            comm_inter: fit(8e-11, 2e-5),
            inter_extrapolated: true,
            step_overhead_s: 1e-3,
            mem: Some(fit(1024.0, 16.0 * 1024.0 * 1024.0)),
            records: 12,
        };
        // 0.0625 GiB = 64 MiB: usable 57.6 MiB − 16 MiB static = 41.6 MiB
        // over 1 KiB/token ⇒ ~42K tokens, clamped to the artifact ceiling
        let c = derived_bucket_capacity(&spec, 4, 0.0625, 1 << 20, Some(&profile)).unwrap();
        let expect_tokens = (0.0625 * (1u64 << 30) as f64 * 0.9 - 16.0 * 1024.0 * 1024.0) / 1024.0;
        assert_eq!(c, expect_tokens as u32);
        // a memory-less profile falls back to the analytic curve
        let mut no_mem = profile.clone();
        no_mem.mem = None;
        assert_eq!(
            derived_bucket_capacity(&spec, 4, 1.0, 1024, Some(&no_mem)).unwrap(),
            derived_bucket_capacity(&spec, 4, 1.0, 1024, None).unwrap()
        );
    }

    #[test]
    fn bucket_capacity_for_reports_result() {
        let m = Manifest::parse(MANIFEST, PathBuf::from("/a")).unwrap();
        assert_eq!(bucket_capacity_for(&m, 7).unwrap(), 8);
        assert_eq!(bucket_capacity_for(&m, 16).unwrap(), 16);
        assert!(bucket_capacity_for(&m, 17).is_err());
    }
}
