//! Minimal leveled logger backing the `log` facade (no env_logger offline).
//! Level comes from `SKRULL_LOG` (error|warn|info|debug|trace), default info.

use log::{Level, LevelFilter, Metadata, Record};
use std::time::Instant;

static START: once_cell::sync::Lazy<Instant> = once_cell::sync::Lazy::new(Instant::now);

struct SimpleLogger {
    level: LevelFilter,
}

impl log::Log for SimpleLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger; safe to call more than once (later calls are no-ops).
pub fn init() {
    let level = match std::env::var("SKRULL_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    let logger = Box::new(SimpleLogger { level });
    if log::set_boxed_logger(logger).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
