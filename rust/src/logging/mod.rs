//! Minimal leveled logger (the offline build has no log/env_logger).
//! Level comes from `SKRULL_LOG` (error|warn|info|debug|trace), default
//! info.  Use through the crate-root macros `log_error!` … `log_trace!`;
//! `init()` stamps the epoch and applies the env level and is safe to call
//! more than once.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

/// Install the logger; safe to call more than once (later calls only
/// re-read the env level).
pub fn init() {
    START.get_or_init(Instant::now);
    let level = match std::env::var("SKRULL_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one record (used by the `log_*!` macros).
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {} {target}] {args}", level.tag());
}

// The level gate runs BEFORE the format arguments are evaluated, so a
// disabled `log_debug!("{}", expensive())` costs one atomic load — the
// zero-cost-when-disabled property of the `log` facade this replaces.
#[macro_export]
macro_rules! log_at {
    ($level:expr, $($arg:tt)*) => {
        if $crate::logging::enabled($level) {
            $crate::logging::log($level, module_path!(), format_args!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::log_at!($crate::logging::Level::Error, $($arg)*) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::log_at!($crate::logging::Level::Warn, $($arg)*) };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::log_at!($crate::logging::Level::Info, $($arg)*) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::log_at!($crate::logging::Level::Debug, $($arg)*) };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => { $crate::log_at!($crate::logging::Level::Trace, $($arg)*) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        crate::log_info!("logger smoke");
    }

    #[test]
    fn level_order_is_sane() {
        assert!(Level::Error < Level::Trace);
        init();
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Trace) || std::env::var("SKRULL_LOG").as_deref() == Ok("trace"));
    }
}
