//! Long-SFT data substrate: synthetic sequence-length distributions fit to
//! the paper's Table 1, dataset sampling, sequence packing, and the
//! scheduling DataLoader that hosts GDS+DACP (Section 4.3: "our scheduling
//! algorithm is integrated into the DataLoader").

pub mod dataset;
pub mod distribution;
pub mod loader;
pub mod packing;

pub use dataset::{Dataset, Sequence};
pub use distribution::LengthDistribution;
