//! Sequence packing (Appendix A.1: "we employ sequence packing to eliminate
//! padding").  A `PackedBucket` is the unit the runtime executes: a fixed-
//! capacity token buffer holding whole sequences back-to-back with segment
//! ids, intra-segment positions, next-token targets and a loss mask; the
//! unfilled remainder is a padding segment with loss weight zero.

/// A sequence's tokens, ready for packing.
#[derive(Clone, Debug)]
pub struct TokenSeq {
    pub id: u64,
    pub tokens: Vec<i32>,
}

/// A packed fixed-size training buffer, matching the L2 train_step inputs.
#[derive(Clone, Debug)]
pub struct PackedBucket {
    pub capacity: usize,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub loss_mask: Vec<f32>,
    pub segment_ids: Vec<i32>,
    pub positions: Vec<i32>,
    /// ids of the sequences packed here (for bookkeeping/tests).
    pub seq_ids: Vec<u64>,
}

impl PackedBucket {
    /// Number of loss-bearing tokens.
    pub fn loss_tokens(&self) -> f64 {
        self.loss_mask.iter().map(|&m| m as f64).sum()
    }

    /// Number of non-padding tokens.
    pub fn used_tokens(&self) -> usize {
        self.tokens.len() - self.pad_tokens()
    }

    pub fn pad_tokens(&self) -> usize {
        // padding is the trailing run with segment id == pad id (= #segments)
        let pad_id = self.seq_ids.len() as i32;
        self.segment_ids.iter().filter(|&&s| s == pad_id).count()
    }
}

pub const PAD_TOKEN: i32 = 0;

/// Pack the given sequences (all must fit) into one bucket of `capacity`
/// tokens.  Targets are next-token within each segment; the final token of
/// each segment and all padding are loss-masked.
///
/// Panics if the sequences exceed capacity — callers (the scheduler) are
/// responsible for respecting BucketSize C; this is asserted, not patched,
/// so memory-constraint violations surface in tests.
pub fn pack(seqs: &[&TokenSeq], capacity: usize) -> PackedBucket {
    let used: usize = seqs.iter().map(|s| s.tokens.len()).sum();
    assert!(
        used <= capacity,
        "packing overflow: {used} tokens into capacity {capacity}"
    );
    let mut b = PackedBucket {
        capacity,
        tokens: Vec::with_capacity(capacity),
        targets: Vec::with_capacity(capacity),
        loss_mask: Vec::with_capacity(capacity),
        segment_ids: Vec::with_capacity(capacity),
        positions: Vec::with_capacity(capacity),
        seq_ids: seqs.iter().map(|s| s.id).collect(),
    };
    for (seg, s) in seqs.iter().enumerate() {
        let n = s.tokens.len();
        for (i, &tok) in s.tokens.iter().enumerate() {
            b.tokens.push(tok);
            b.targets.push(if i + 1 < n { s.tokens[i + 1] } else { PAD_TOKEN });
            b.loss_mask.push(if i + 1 < n { 1.0 } else { 0.0 });
            b.segment_ids.push(seg as i32);
            b.positions.push(i as i32);
        }
    }
    // padding segment: distinct id so it only attends to itself, zero loss
    let pad_seg = seqs.len() as i32;
    let mut pos = 0;
    while b.tokens.len() < capacity {
        b.tokens.push(PAD_TOKEN);
        b.targets.push(PAD_TOKEN);
        b.loss_mask.push(0.0);
        b.segment_ids.push(pad_seg);
        b.positions.push(pos);
        pos += 1;
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(id: u64, toks: &[i32]) -> TokenSeq {
        TokenSeq { id, tokens: toks.to_vec() }
    }

    #[test]
    fn packs_two_sequences_with_padding() {
        let (s1, s2) = (seq(7, &[1, 2, 3]), seq(9, &[4, 5]));
        let b = pack(&[&s1, &s2], 8);
        assert_eq!(b.tokens, vec![1, 2, 3, 4, 5, 0, 0, 0]);
        assert_eq!(b.targets, vec![2, 3, 0, 5, 0, 0, 0, 0]);
        assert_eq!(b.loss_mask, vec![1.0, 1.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(b.segment_ids, vec![0, 0, 0, 1, 1, 2, 2, 2]);
        assert_eq!(b.positions, vec![0, 1, 2, 0, 1, 0, 1, 2]);
        assert_eq!(b.seq_ids, vec![7, 9]);
        assert_eq!(b.used_tokens(), 5);
        assert_eq!(b.pad_tokens(), 3);
        assert_eq!(b.loss_tokens(), 3.0);
    }

    #[test]
    fn exact_fit_has_no_padding() {
        let (s1, s2) = (seq(0, &[1, 2]), seq(1, &[3, 4]));
        let b = pack(&[&s1, &s2], 4);
        assert_eq!(b.pad_tokens(), 0);
        assert_eq!(b.used_tokens(), 4);
    }

    #[test]
    fn empty_pack_is_all_padding() {
        let b = pack(&[] as &[&TokenSeq], 4);
        assert_eq!(b.used_tokens(), 0);
        assert_eq!(b.loss_tokens(), 0.0);
        assert_eq!(b.segment_ids, vec![0, 0, 0, 0]);
    }

    #[test]
    #[should_panic(expected = "packing overflow")]
    fn overflow_panics() {
        let s = seq(0, &[1, 2, 3, 4, 5]);
        pack(&[&s], 4);
    }

    #[test]
    fn single_token_sequence_is_fully_masked() {
        let s = seq(0, &[42]);
        let b = pack(&[&s], 2);
        assert_eq!(b.loss_mask[0], 0.0); // no next token to predict
        assert_eq!(b.loss_tokens(), 0.0);
    }
}
