//! The scheduling DataLoader (Section 4.3: "our scheduling algorithm is
//! integrated into the DataLoader and introduces near-zero overhead").
//!
//! Wraps a Dataset + Policy and yields per-iteration `IterationSchedule`s,
//! recording the wall-clock the scheduler itself consumed so the
//! near-zero-overhead claim is measurable (bench `sched_overhead`).

use std::time::Instant;

use crate::config::ExperimentConfig;
use crate::data::{Dataset, Sequence};
use crate::perfmodel::{CostModel, FlopsModel};
use crate::rng::Rng;
use crate::scheduler::{dispatch, gds, IterationSchedule, SchedError};

/// One produced iteration: the global batch plus its schedule.
type LoaderItem = (Vec<Sequence>, IterationSchedule);

pub struct ScheduledLoader<'a> {
    dataset: &'a Dataset,
    /// borrowed, not cloned: a loader is created per run and the config
    /// (with its possibly multi-KB calibrated profile) stays the caller's
    cfg: &'a ExperimentConfig,
    flops: FlopsModel,
    cost: CostModel,
    rng: Rng,
    /// scheduler scratch arena, reused every iteration (the fast path's
    /// buffers survive across `next_iteration` calls)
    ctx: gds::SchedCtx,
    /// resolved token capacity C: the hand-set bucket under
    /// `CapacitySource::Fixed`, the memplan-derived one under
    /// `HbmDerived`.  An infeasible HBM budget is held here and surfaced
    /// by the first scheduling call.
    capacity: Result<u32, SchedError>,
    /// cumulative seconds spent inside *successful* scheduling calls
    pub sched_seconds: f64,
    /// iterations that yielded a schedule (failed calls are not served)
    pub iterations_served: usize,
    /// every GDS/DACP pass this loader performed, Ok or Err — the
    /// scheduling-work counter behind the run engine's one-pass-per-
    /// iteration guarantee (`BuiltRun::sched_invocations`)
    pub sched_invocations: usize,
    /// whether the scheduler may use its internal thread fan-out (GDS
    /// per-rank / refinement threads).  Callers that already parallelize
    /// at a coarser grain (the e2e sweep's per-cell workers) turn this
    /// off so nested fan-outs don't oversubscribe the cores; schedules
    /// are byte-identical either way (gds oracle tests).
    pub sched_parallel: bool,
    /// wall-clock of the most recent `schedule_batch` call, Ok or Err
    last_sched_seconds: f64,
}

impl<'a> ScheduledLoader<'a> {
    pub fn new(dataset: &'a Dataset, cfg: &'a ExperimentConfig) -> Self {
        let flops = FlopsModel::new(&cfg.model);
        // the cost-aware refinement (SkrullRefined) estimates with the
        // configured cost source: analytic, or the calibrated profile
        let cost = cfg.cost_model();
        let rng = Rng::seed_from_u64(cfg.seed);
        let capacity = cfg.resolved_bucket_size();
        ScheduledLoader {
            dataset,
            cfg,
            flops,
            cost,
            rng,
            ctx: gds::SchedCtx::default(),
            capacity,
            sched_seconds: 0.0,
            iterations_served: 0,
            sched_invocations: 0,
            sched_parallel: true,
            last_sched_seconds: 0.0,
        }
    }

    /// The token capacity C this loader schedules against (see `memplan`).
    pub fn capacity(&self) -> &Result<u32, SchedError> {
        &self.capacity
    }

    /// Schedule an explicit global batch under the configured policy.
    pub fn schedule_batch(&mut self, batch: &[Sequence]) -> Result<IterationSchedule, SchedError> {
        let bucket = match &self.capacity {
            Ok(c) => *c,
            Err(e) => return Err(e.clone()),
        };
        let t0 = Instant::now();
        let c = &self.cfg.cluster;
        let mut gcfg = gds::GdsConfig::new(bucket, c.cp, c.dp);
        gcfg.parallel = gcfg.parallel && self.sched_parallel;
        gcfg.shards = self.cfg.shards.max(1);
        gcfg.incremental = self.cfg.incremental;
        self.sched_invocations += 1;
        let out = dispatch::schedule_policy(
            self.cfg.policy,
            batch,
            &gcfg,
            &self.flops,
            &self.cost,
            &mut self.ctx,
        );
        self.last_sched_seconds = t0.elapsed().as_secs_f64();
        // only successfully served iterations count toward the overhead
        // metrics — an Err yields no schedule, so folding its wall-clock
        // into `mean_sched_seconds` would skew the per-served-iteration
        // number backing the near-zero-overhead claim
        if out.is_ok() {
            self.sched_seconds += self.last_sched_seconds;
            self.iterations_served += 1;
        }
        out
    }

    /// Sample a fresh global batch (with replacement) and schedule it.
    pub fn next_iteration(&mut self) -> Result<(Vec<Sequence>, IterationSchedule), SchedError> {
        let batch = self
            .dataset
            .sample_batch(&mut self.rng, self.cfg.cluster.batch_size);
        let sched = self.schedule_batch(&batch)?;
        Ok((batch, sched))
    }

    /// Mean scheduling time per served iteration.
    pub fn mean_sched_seconds(&self) -> f64 {
        if self.iterations_served == 0 {
            0.0
        } else {
            self.sched_seconds / self.iterations_served as f64
        }
    }

    /// Wall-clock of the most recent scheduling call (Ok or Err).
    pub fn last_sched_seconds(&self) -> f64 {
        self.last_sched_seconds
    }

    /// Iterations where incremental mode replayed the previous rank
    /// partition outright (see `gds::SchedCtx::partition_reuses`).
    pub fn sched_partition_reuses(&self) -> u64 {
        self.ctx.partition_reuses()
    }

    /// Per-rank incremental cache hits (see `gds::SchedCtx::rank_cache_hits`;
    /// shard workers keep thread-local caches, so observe this with
    /// `shards = 1`).
    pub fn sched_rank_cache_hits(&self) -> u64 {
        self.ctx.rank_cache_hits()
    }

    /// Drive `iterations` iterations synchronously: schedule, then hand the
    /// batch to `consume`.  Counterpart of [`run_pipelined`] with identical
    /// callback semantics (the last argument is that iteration's scheduling
    /// wall-clock), for apples-to-apples overhead accounting.
    ///
    /// [`run_pipelined`]: ScheduledLoader::run_pipelined
    pub fn run_synchronous<F>(
        &mut self,
        iterations: usize,
        mut consume: F,
    ) -> Result<(), SchedError>
    where
        F: FnMut(usize, &[Sequence], &IterationSchedule, f64),
    {
        // one scratch batch reused across iterations (the draws are
        // byte-identical to `next_iteration`'s owned batches)
        let mut batch: Vec<Sequence> = Vec::with_capacity(self.cfg.cluster.batch_size);
        for i in 0..iterations {
            self.dataset
                .sample_batch_into(&mut self.rng, self.cfg.cluster.batch_size, &mut batch);
            let sched = self.schedule_batch(&batch)?;
            consume(i, &batch, &sched, self.last_sched_seconds);
        }
        Ok(())
    }

    /// Lazy epoch driver: chunk a shuffled [`Dataset::epoch_order`] and
    /// fill one batch at a time into a reused scratch buffer — O(batch)
    /// extra memory instead of `epoch_batches`' O(dataset) batch
    /// materialization, with byte-identical schedules (same shuffle, same
    /// chunking; regression-pinned in `rust/tests/stream.rs`).
    pub fn run_synchronous_order<F>(
        &mut self,
        order: &[u64],
        batch_size: usize,
        mut consume: F,
    ) -> Result<(), SchedError>
    where
        F: FnMut(usize, &[Sequence], &IterationSchedule, f64),
    {
        let bs = batch_size.max(1);
        let mut batch: Vec<Sequence> = Vec::with_capacity(bs.min(order.len()));
        for (i, chunk) in order.chunks(bs).enumerate() {
            self.dataset.fill_batch(chunk, &mut batch);
            let sched = self.schedule_batch(&batch)?;
            consume(i, &batch, &sched, self.last_sched_seconds);
        }
        Ok(())
    }

    /// Synchronous driver over an explicit batch list (epoch-mode runs:
    /// the caller owns the batches, typically `Dataset::epoch_batches`).
    pub fn run_synchronous_batches<F>(
        &mut self,
        batches: &[Vec<Sequence>],
        mut consume: F,
    ) -> Result<(), SchedError>
    where
        F: FnMut(usize, &[Sequence], &IterationSchedule, f64),
    {
        for (i, batch) in batches.iter().enumerate() {
            let sched = self.schedule_batch(batch)?;
            consume(i, batch, &sched, self.last_sched_seconds);
        }
        Ok(())
    }

    /// Double-buffered pipelined driver (Section 4.3: scheduling lives in
    /// the DataLoader and hides behind execution).  While `consume`
    /// processes batch *i* on the calling thread, batch *i+1* is being
    /// sampled and scheduled on a scoped background thread — so the exposed
    /// scheduling cost per iteration is `max(0, sched − exec)`, not
    /// additive.  The loader is threaded through the prefetch thread by
    /// ownership, so batches and schedules are byte-identical to the
    /// synchronous path (same RNG draw order, same scratch arena reuse).
    ///
    /// Returns the loader so cumulative stats remain inspectable.
    pub fn run_pipelined<F>(self, iterations: usize, consume: F) -> Result<Self, SchedError>
    where
        F: FnMut(usize, &[Sequence], &IterationSchedule, f64),
    {
        self.run_pipelined_with(iterations, |l, _| l.next_iteration(), consume)
    }

    /// Pipelined driver over an explicit batch list — identical overlap
    /// semantics to [`run_pipelined`], with the caller's batches
    /// (epoch-mode runs) instead of fresh samples.
    ///
    /// [`run_pipelined`]: ScheduledLoader::run_pipelined
    pub fn run_pipelined_batches<F>(
        self,
        batches: &[Vec<Sequence>],
        consume: F,
    ) -> Result<Self, SchedError>
    where
        F: FnMut(usize, &[Sequence], &IterationSchedule, f64),
    {
        self.run_pipelined_with(
            batches.len(),
            |l, i| {
                let batch = batches[i].clone();
                let sched = l.schedule_batch(&batch)?;
                Ok((batch, sched))
            },
            consume,
        )
    }

    /// Pipelined counterpart of [`run_synchronous_order`]: the epoch order
    /// is chunked lazily on the prefetch thread, so an epoch run holds one
    /// in-flight batch instead of the whole epoch's batch list.
    ///
    /// [`run_synchronous_order`]: ScheduledLoader::run_synchronous_order
    pub fn run_pipelined_order<F>(
        self,
        order: &[u64],
        batch_size: usize,
        consume: F,
    ) -> Result<Self, SchedError>
    where
        F: FnMut(usize, &[Sequence], &IterationSchedule, f64),
    {
        let bs = batch_size.max(1);
        let iterations = order.len().div_ceil(bs);
        self.run_pipelined_with(
            iterations,
            |l, i| {
                let lo = i * bs;
                let hi = (lo + bs).min(order.len());
                let mut batch = Vec::with_capacity(hi - lo);
                l.dataset.fill_batch(&order[lo..hi], &mut batch);
                let sched = l.schedule_batch(&batch)?;
                Ok((batch, sched))
            },
            consume,
        )
    }

    /// The double-buffered engine behind both pipelined drivers: while
    /// `consume` processes batch *i* on the calling thread, `next`
    /// produces batch *i+1* on a scoped background thread.  The loader
    /// (and the producer closure) are threaded through the prefetch
    /// thread by ownership, so schedules are byte-identical to the
    /// synchronous path (same RNG draw order, same scratch arena reuse).
    fn run_pipelined_with<N, F>(
        mut self,
        iterations: usize,
        mut next: N,
        mut consume: F,
    ) -> Result<Self, SchedError>
    where
        N: FnMut(&mut ScheduledLoader<'a>, usize) -> Result<LoaderItem, SchedError> + Send,
        F: FnMut(usize, &[Sequence], &IterationSchedule, f64),
    {
        if iterations == 0 {
            return Ok(self);
        }
        std::thread::scope(|scope| {
            // prefetch iteration 0 (pipeline fill: this one is exposed)
            let mut pending = Some(scope.spawn(move || {
                let r = next(&mut self, 0);
                (self, next, r)
            }));
            let mut done = None;
            for i in 0..iterations {
                let (mut loader, mut next, r) = pending
                    .take()
                    // skrull-lint: allow(panic-in-lib) -- pending is refilled every iteration below; an empty slot is a pipeline bug
                    .expect("prefetch handle present")
                    .join()
                    // skrull-lint: allow(panic-in-lib) -- re-raises a panic from the prefetch thread on the caller's thread
                    .expect("prefetch thread panicked");
                let sched_s = loader.last_sched_seconds;
                let (batch, sched) = r?;
                if i + 1 < iterations {
                    // launch the next prefetch *before* consuming — this is
                    // the overlap window
                    pending = Some(scope.spawn(move || {
                        let r = next(&mut loader, i + 1);
                        (loader, next, r)
                    }));
                } else {
                    done = Some(loader);
                }
                consume(i, &batch, &sched, sched_s);
            }
            // skrull-lint: allow(panic-in-lib) -- the iterations == 0 early-return above guarantees the loop's last pass stored the loader
            Ok(done.expect("loop ran at least once"))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Policy;
    use crate::data::LengthDistribution;
    use crate::model::ModelSpec;

    fn setup(policy: Policy) -> (Dataset, ExperimentConfig) {
        let ds = Dataset::synthesize(&LengthDistribution::wikipedia(), 2_000, 1);
        let mut cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
        cfg.policy = policy;
        (ds, cfg)
    }

    #[test]
    fn loader_yields_complete_schedules_for_all_policies() {
        for policy in [Policy::Baseline, Policy::DacpOnly, Policy::Skrull, Policy::SkrullRefined, Policy::SortedBatching] {
            let (ds, cfg) = setup(policy);
            let bs = cfg.cluster.batch_size;
            let mut loader = ScheduledLoader::new(&ds, &cfg);
            let (batch, sched) = loader.next_iteration().unwrap();
            assert_eq!(batch.len(), bs);
            let mut expect: Vec<u64> = batch.iter().map(|s| s.id).collect();
            expect.sort_unstable();
            assert_eq!(sched.assigned_ids(), expect, "{policy:?}");
        }
    }

    #[test]
    fn loader_is_deterministic_per_seed() {
        let (ds, cfg) = setup(Policy::Skrull);
        let mut l1 = ScheduledLoader::new(&ds, &cfg);
        let mut l2 = ScheduledLoader::new(&ds, &cfg);
        for _ in 0..3 {
            let (b1, s1) = l1.next_iteration().unwrap();
            let (b2, s2) = l2.next_iteration().unwrap();
            assert_eq!(b1, b2);
            // not just the sampled batches: the *schedules* (micro-batch
            // splits + DACP placements) must be identical too
            assert_eq!(s1, s2);
        }
    }

    #[test]
    fn failed_scheduling_is_not_counted_as_served() {
        // Regression: a scheduling Err used to bump iterations_served and
        // sched_seconds, skewing mean_sched_seconds — the metric behind the
        // near-zero-overhead claim.
        let (_, mut cfg) = setup(Policy::Skrull);
        // one sequence longer than C·N can never be scheduled → TooLong
        let cap = cfg.bucket_size as u64 * cfg.cluster.cp as u64;
        let ds = Dataset { name: "oversized".into(), lengths: vec![cap as u32 + 1] };
        cfg.cluster.batch_size = 1;
        let mut loader = ScheduledLoader::new(&ds, &cfg);
        assert!(loader.next_iteration().is_err());
        assert_eq!(loader.iterations_served, 0);
        assert_eq!(loader.sched_seconds, 0.0);
        assert_eq!(loader.mean_sched_seconds(), 0.0);
        // the attempt itself is still observable for run-engine accounting:
        // the invocation counter tracks work *performed*, Ok or Err
        assert!(loader.last_sched_seconds() >= 0.0);
        assert_eq!(loader.sched_invocations, 1);
    }

    #[test]
    fn pipelined_loader_matches_synchronous_schedules_exactly() {
        // The double-buffered prefetch path must be a pure latency
        // optimization: same batches, same schedules, byte for byte.
        for policy in [Policy::Baseline, Policy::Skrull, Policy::SkrullRefined] {
            let (ds, cfg) = setup(policy);
            let iters = 4;

            let mut sync_out: Vec<(Vec<Sequence>, IterationSchedule)> = Vec::new();
            let mut sync_loader = ScheduledLoader::new(&ds, &cfg);
            sync_loader
                .run_synchronous(iters, |_, batch, sched, _| {
                    sync_out.push((batch.to_vec(), sched.clone()));
                })
                .unwrap();

            let mut pipe_out: Vec<(Vec<Sequence>, IterationSchedule)> = Vec::new();
            let pipe_loader = ScheduledLoader::new(&ds, &cfg)
                .run_pipelined(iters, |i, batch, sched, sched_s| {
                    assert!(sched_s >= 0.0);
                    assert_eq!(i, pipe_out.len());
                    pipe_out.push((batch.to_vec(), sched.clone()));
                })
                .unwrap();

            assert_eq!(sync_out, pipe_out, "{policy:?}");
            assert_eq!(pipe_loader.iterations_served, iters);
            assert_eq!(sync_loader.iterations_served, iters);
        }
    }

    #[test]
    fn pipelined_loader_surfaces_scheduling_errors() {
        let (_, mut cfg) = setup(Policy::Skrull);
        let cap = cfg.bucket_size as u64 * cfg.cluster.cp as u64;
        let ds = Dataset { name: "oversized".into(), lengths: vec![cap as u32 + 1] };
        cfg.cluster.batch_size = 1;
        let r = ScheduledLoader::new(&ds, &cfg).run_pipelined(3, |_, _, _, _| {
            panic!("no iteration should be consumable");
        });
        assert!(r.is_err());
    }

    #[test]
    fn batch_list_drivers_match_each_other_and_the_sampled_path() {
        // epoch-mode plumbing: feeding the *same* batches through the
        // synchronous and pipelined batch-list drivers must yield
        // byte-identical schedules.
        let (ds, cfg) = setup(Policy::Skrull);
        let batches = ds.epoch_batches(16, 9);
        let n = batches.len().min(5);
        let batches = &batches[..n];

        let mut sync_out: Vec<IterationSchedule> = Vec::new();
        let mut sync_loader = ScheduledLoader::new(&ds, &cfg);
        sync_loader
            .run_synchronous_batches(batches, |i, batch, sched, _| {
                assert_eq!(batch, &batches[i][..]);
                sync_out.push(sched.clone());
            })
            .unwrap();

        let mut pipe_out: Vec<IterationSchedule> = Vec::new();
        let pipe_loader = ScheduledLoader::new(&ds, &cfg)
            .run_pipelined_batches(batches, |i, batch, sched, sched_s| {
                assert!(sched_s >= 0.0);
                assert_eq!(batch, &batches[i][..]);
                pipe_out.push(sched.clone());
            })
            .unwrap();

        assert_eq!(sync_out, pipe_out);
        assert_eq!(sync_loader.iterations_served, n);
        assert_eq!(pipe_loader.iterations_served, n);
    }

    #[test]
    fn hbm_derived_capacity_drives_the_scheduler() {
        use crate::memplan::CapacitySource;
        let (ds, mut cfg) = setup(Policy::Skrull);
        cfg.memory.source = CapacitySource::HbmDerived;
        let mut loader = ScheduledLoader::new(&ds, &cfg);
        let derived = *loader.capacity().as_ref().unwrap();
        // 80 GB admits far more than the hand-set 26K bucket on the 0.5B
        assert!(derived > cfg.bucket_size, "derived {derived}");
        assert_eq!(derived, cfg.mem_plan().derive_capacity().unwrap());
        let (_, sched) = loader.next_iteration().unwrap();
        for rank in &sched.ranks {
            for mb in &rank.micro_batches {
                mb.plan.validate(&mb.lens(), derived, cfg.cluster.cp).unwrap();
            }
        }
    }

    #[test]
    fn infeasible_hbm_budget_surfaces_as_scheduling_error() {
        use crate::memplan::CapacitySource;
        let (ds, mut cfg) = setup(Policy::Skrull);
        cfg.memory.source = CapacitySource::HbmDerived;
        cfg.memory.hbm_gb = 0.5; // cannot hold the 0.5B static state
        let mut loader = ScheduledLoader::new(&ds, &cfg);
        assert!(loader.capacity().is_err());
        assert!(matches!(
            loader.next_iteration(),
            Err(SchedError::NoCapacity { .. })
        ));
        assert_eq!(loader.iterations_served, 0);
    }

    #[test]
    fn scheduler_overhead_is_tracked() {
        let (ds, cfg) = setup(Policy::Skrull);
        let mut loader = ScheduledLoader::new(&ds, &cfg);
        for _ in 0..3 {
            loader.next_iteration().unwrap();
        }
        assert_eq!(loader.iterations_served, 3);
        // exactly one GDS/DACP pass per served iteration
        assert_eq!(loader.sched_invocations, 3);
        assert!(loader.sched_seconds > 0.0);
        assert!(loader.mean_sched_seconds() > 0.0);
    }
}
