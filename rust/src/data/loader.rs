//! The scheduling DataLoader (Section 4.3: "our scheduling algorithm is
//! integrated into the DataLoader and introduces near-zero overhead").
//!
//! Wraps a Dataset + Policy and yields per-iteration `IterationSchedule`s,
//! recording the wall-clock the scheduler itself consumed so the
//! near-zero-overhead claim is measurable (bench `sched_overhead`).

use std::time::Instant;

use crate::config::{ExperimentConfig, Policy};
use crate::data::{Dataset, Sequence};
use crate::perfmodel::{CostModel, FlopsModel};
use crate::rng::Rng;
use crate::scheduler::{baseline, gds, IterationSchedule, SchedError};

pub struct ScheduledLoader<'a> {
    dataset: &'a Dataset,
    cfg: ExperimentConfig,
    flops: FlopsModel,
    cost: CostModel,
    rng: Rng,
    /// scheduler scratch arena, reused every iteration (the fast path's
    /// buffers survive across `next_iteration` calls)
    ctx: gds::SchedCtx,
    /// cumulative seconds spent inside scheduling
    pub sched_seconds: f64,
    pub iterations_served: usize,
}

impl<'a> ScheduledLoader<'a> {
    pub fn new(dataset: &'a Dataset, cfg: ExperimentConfig) -> Self {
        let flops = FlopsModel::new(&cfg.model);
        let cost = CostModel::paper_default(&cfg.model);
        let rng = Rng::seed_from_u64(cfg.seed);
        ScheduledLoader {
            dataset,
            cfg,
            flops,
            cost,
            rng,
            ctx: gds::SchedCtx::default(),
            sched_seconds: 0.0,
            iterations_served: 0,
        }
    }

    /// Schedule an explicit global batch under the configured policy.
    pub fn schedule_batch(&mut self, batch: &[Sequence]) -> Result<IterationSchedule, SchedError> {
        let t0 = Instant::now();
        let c = &self.cfg.cluster;
        let out = match self.cfg.policy {
            Policy::Baseline => Ok(baseline::deepspeed(batch, c.dp, c.cp)),
            Policy::DacpOnly => {
                baseline::dacp_only(batch, c.dp, c.cp, self.cfg.bucket_size, &self.flops)
            }
            Policy::Skrull => {
                let gcfg = gds::GdsConfig::new(self.cfg.bucket_size, c.cp, c.dp);
                gds::schedule_with_ctx(batch, &gcfg, &self.flops, &mut self.ctx)
            }
            Policy::SkrullRefined => {
                let gcfg = gds::GdsConfig::new(self.cfg.bucket_size, c.cp, c.dp);
                gds::schedule_refined_with_ctx(batch, &gcfg, &self.cost, &mut self.ctx)
            }
            Policy::SortedBatching => {
                Ok(baseline::sorted_batching(batch, c.dp, c.cp, self.cfg.bucket_size))
            }
        };
        self.sched_seconds += t0.elapsed().as_secs_f64();
        self.iterations_served += 1;
        out
    }

    /// Sample a fresh global batch (with replacement) and schedule it.
    pub fn next_iteration(&mut self) -> Result<(Vec<Sequence>, IterationSchedule), SchedError> {
        let batch = self
            .dataset
            .sample_batch(&mut self.rng, self.cfg.cluster.batch_size);
        let sched = self.schedule_batch(&batch)?;
        Ok((batch, sched))
    }

    /// Mean scheduling time per served iteration.
    pub fn mean_sched_seconds(&self) -> f64 {
        if self.iterations_served == 0 {
            0.0
        } else {
            self.sched_seconds / self.iterations_served as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LengthDistribution;
    use crate::model::ModelSpec;

    fn setup(policy: Policy) -> (Dataset, ExperimentConfig) {
        let ds = Dataset::synthesize(&LengthDistribution::wikipedia(), 2_000, 1);
        let mut cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
        cfg.policy = policy;
        (ds, cfg)
    }

    #[test]
    fn loader_yields_complete_schedules_for_all_policies() {
        for policy in [Policy::Baseline, Policy::DacpOnly, Policy::Skrull, Policy::SkrullRefined, Policy::SortedBatching] {
            let (ds, cfg) = setup(policy);
            let bs = cfg.cluster.batch_size;
            let mut loader = ScheduledLoader::new(&ds, cfg);
            let (batch, sched) = loader.next_iteration().unwrap();
            assert_eq!(batch.len(), bs);
            let mut expect: Vec<u64> = batch.iter().map(|s| s.id).collect();
            expect.sort_unstable();
            assert_eq!(sched.assigned_ids(), expect, "{policy:?}");
        }
    }

    #[test]
    fn loader_is_deterministic_per_seed() {
        let (ds, cfg) = setup(Policy::Skrull);
        let mut l1 = ScheduledLoader::new(&ds, cfg.clone());
        let mut l2 = ScheduledLoader::new(&ds, cfg);
        let (b1, _) = l1.next_iteration().unwrap();
        let (b2, _) = l2.next_iteration().unwrap();
        assert_eq!(b1, b2);
    }

    #[test]
    fn scheduler_overhead_is_tracked() {
        let (ds, cfg) = setup(Policy::Skrull);
        let mut loader = ScheduledLoader::new(&ds, cfg);
        for _ in 0..3 {
            loader.next_iteration().unwrap();
        }
        assert_eq!(loader.iterations_served, 3);
        assert!(loader.sched_seconds > 0.0);
        assert!(loader.mean_sched_seconds() > 0.0);
    }
}
