//! Dataset abstraction: a bag of sequences (identified by id + token
//! length) plus global-batch sampling.  Token *contents* are only
//! materialized by the end-to-end trainer (coordinator/corpus.rs); the
//! scheduler and the simulator operate on lengths alone, exactly like the
//! paper's DataLoader-level scheduler.

use crate::data::distribution::LengthDistribution;
use crate::rng::Rng;

/// One training sample: opaque id + token count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Sequence {
    pub id: u64,
    pub len: u32,
}

/// A materialized dataset of sequence lengths.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub lengths: Vec<u32>,
}

impl Dataset {
    /// Synthesize `n` samples from a named distribution.
    pub fn synthesize(dist: &LengthDistribution, n: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        Dataset {
            name: dist.name().to_string(),
            lengths: dist.sample_many(&mut rng, n),
        }
    }

    pub fn len(&self) -> usize {
        self.lengths.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lengths.is_empty()
    }

    pub fn total_tokens(&self) -> u64 {
        self.lengths.iter().map(|&l| l as u64).sum()
    }

    pub fn max_len(&self) -> u32 {
        self.lengths.iter().copied().max().unwrap_or(0)
    }

    /// The seeded shuffled visit order for one epoch.  O(dataset) ids, not
    /// O(dataset) materialized batches: the lazy epoch drivers
    /// (`ScheduledLoader::run_synchronous_order` / `run_pipelined_order`
    /// and the streaming `StreamSource`) chunk this and fill one batch at
    /// a time into a reused scratch buffer.
    pub fn epoch_order(&self, seed: u64) -> Vec<u64> {
        shuffled_order(self.lengths.len() as u64, seed)
    }

    /// Iterate the dataset in shuffled order as global batches of
    /// `batch_size` sequences — one epoch.  The tail short batch is kept.
    /// Materializes every batch up front; the run engine uses the lazy
    /// [`Dataset::epoch_order`] + [`Dataset::fill_batch`] pair instead,
    /// which is byte-identical (same shuffle, same chunking).
    pub fn epoch_batches(&self, batch_size: usize, seed: u64) -> Vec<Vec<Sequence>> {
        self.epoch_order(seed)
            .chunks(batch_size)
            .map(|chunk| {
                chunk
                    .iter()
                    .map(|&id| Sequence { id, len: self.lengths[id as usize] })
                    .collect()
            })
            .collect()
    }

    /// Resolve an id slice (one epoch-order chunk) into `out`.  Hot path:
    /// `out` is a scratch buffer reused across iterations.
    pub fn fill_batch(&self, ids: &[u64], out: &mut Vec<Sequence>) {
        out.clear();
        for &id in ids {
            out.push(Sequence { id, len: self.lengths[id as usize] });
        }
    }

    /// Sample one global batch with replacement (for benchmarking runs that
    /// draw i.i.d. batches like the paper's iteration-time measurements).
    pub fn sample_batch(&self, rng: &mut Rng, batch_size: usize) -> Vec<Sequence> {
        let mut out = Vec::with_capacity(batch_size);
        self.sample_batch_into(rng, batch_size, &mut out);
        out
    }

    /// [`Dataset::sample_batch`] into a reused scratch buffer — the
    /// loader's per-iteration hot path draws through this to avoid a fresh
    /// allocation every iteration.  One `rng.below(n)` per slot; the
    /// streaming `StreamSource::fill_sampled_batch` replays the identical
    /// draw sequence.
    pub fn sample_batch_into(&self, rng: &mut Rng, batch_size: usize, out: &mut Vec<Sequence>) {
        out.clear();
        let n = self.lengths.len() as u64;
        for _ in 0..batch_size {
            let id = rng.below(n);
            out.push(Sequence { id, len: self.lengths[id as usize] });
        }
    }

    /// Clamp all lengths (used when a bucket/CP config cannot hold the
    /// longest sample — mirrors SFT-time truncation to the context window).
    pub fn truncated(&self, max_len: u32) -> Dataset {
        Dataset {
            name: format!("{}-trunc{}", self.name, max_len),
            lengths: self.lengths.iter().map(|&l| l.min(max_len)).collect(),
        }
    }
}

/// The seeded Fisher-Yates permutation of `0..n` shared by every epoch
/// driver — in-memory and streamed epochs must shuffle identically for
/// the byte-identity invariant to hold.
pub fn shuffled_order(n: u64, seed: u64) -> Vec<u64> {
    let mut order: Vec<u64> = (0..n).collect();
    let mut rng = Rng::seed_from_u64(seed);
    rng.shuffle(&mut order);
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset { name: "toy".into(), lengths: vec![10, 20, 30, 40, 50, 60, 70] }
    }

    #[test]
    fn epoch_covers_every_sequence_exactly_once() {
        let ds = toy();
        let batches = ds.epoch_batches(3, 7);
        assert_eq!(batches.len(), 3); // 3 + 3 + 1
        let mut ids: Vec<u64> = batches.iter().flatten().map(|s| s.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..7).collect::<Vec<_>>());
        for b in &batches {
            for s in b {
                assert_eq!(s.len, ds.lengths[s.id as usize]);
            }
        }
    }

    #[test]
    fn epoch_shuffle_is_seeded() {
        let ds = toy();
        let a = ds.epoch_batches(3, 7);
        let b = ds.epoch_batches(3, 7);
        let c = ds.epoch_batches(3, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn sample_batch_draws_valid_ids() {
        let ds = toy();
        let mut rng = Rng::seed_from_u64(1);
        let batch = ds.sample_batch(&mut rng, 64);
        assert_eq!(batch.len(), 64);
        for s in batch {
            assert!(s.id < 7);
            assert_eq!(s.len, ds.lengths[s.id as usize]);
        }
    }

    #[test]
    fn truncation_clamps() {
        let ds = toy().truncated(35);
        assert_eq!(ds.lengths, vec![10, 20, 30, 35, 35, 35, 35]);
        assert_eq!(ds.max_len(), 35);
    }

    #[test]
    fn lazy_epoch_order_reproduces_materialized_batches() {
        let ds = Dataset::synthesize(&LengthDistribution::wikipedia(), 257, 42);
        let old = ds.epoch_batches(16, 9);
        let order = ds.epoch_order(9);
        assert_eq!(order.len(), 257);
        let mut scratch = Vec::new();
        let lazy: Vec<Vec<Sequence>> = order
            .chunks(16)
            .map(|chunk| {
                ds.fill_batch(chunk, &mut scratch);
                scratch.clone()
            })
            .collect();
        assert_eq!(lazy, old);
    }

    #[test]
    fn sample_batch_into_replays_sample_batch() {
        let ds = toy();
        let mut a = Rng::seed_from_u64(5);
        let mut b = Rng::seed_from_u64(5);
        let mut scratch = Vec::new();
        for _ in 0..10 {
            let owned = ds.sample_batch(&mut a, 32);
            ds.sample_batch_into(&mut b, 32, &mut scratch);
            assert_eq!(scratch, owned);
        }
    }

    #[test]
    fn synthesize_is_deterministic() {
        let d = LengthDistribution::wikipedia();
        let a = Dataset::synthesize(&d, 100, 3);
        let b = Dataset::synthesize(&d, 100, 3);
        assert_eq!(a.lengths, b.lengths);
        assert_eq!(a.total_tokens(), b.total_tokens());
    }
}
