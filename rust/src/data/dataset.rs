//! Dataset abstraction: a bag of sequences (identified by id + token
//! length) plus global-batch sampling.  Token *contents* are only
//! materialized by the end-to-end trainer (coordinator/corpus.rs); the
//! scheduler and the simulator operate on lengths alone, exactly like the
//! paper's DataLoader-level scheduler.

use crate::data::distribution::LengthDistribution;
use crate::rng::Rng;

/// One training sample: opaque id + token count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Sequence {
    pub id: u64,
    pub len: u32,
}

/// A materialized dataset of sequence lengths.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub lengths: Vec<u32>,
}

impl Dataset {
    /// Synthesize `n` samples from a named distribution.
    pub fn synthesize(dist: &LengthDistribution, n: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        Dataset {
            name: dist.name().to_string(),
            lengths: dist.sample_many(&mut rng, n),
        }
    }

    pub fn len(&self) -> usize {
        self.lengths.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lengths.is_empty()
    }

    pub fn total_tokens(&self) -> u64 {
        self.lengths.iter().map(|&l| l as u64).sum()
    }

    pub fn max_len(&self) -> u32 {
        self.lengths.iter().copied().max().unwrap_or(0)
    }

    /// Iterate the dataset in shuffled order as global batches of
    /// `batch_size` sequences — one epoch.  The tail short batch is kept.
    pub fn epoch_batches(&self, batch_size: usize, seed: u64) -> Vec<Vec<Sequence>> {
        let mut order: Vec<u64> = (0..self.lengths.len() as u64).collect();
        let mut rng = Rng::seed_from_u64(seed);
        rng.shuffle(&mut order);
        order
            .chunks(batch_size)
            .map(|chunk| {
                chunk
                    .iter()
                    .map(|&id| Sequence { id, len: self.lengths[id as usize] })
                    .collect()
            })
            .collect()
    }

    /// Sample one global batch with replacement (for benchmarking runs that
    /// draw i.i.d. batches like the paper's iteration-time measurements).
    pub fn sample_batch(&self, rng: &mut Rng, batch_size: usize) -> Vec<Sequence> {
        (0..batch_size)
            .map(|_| {
                let id = rng.below(self.lengths.len() as u64);
                Sequence { id, len: self.lengths[id as usize] }
            })
            .collect()
    }

    /// Clamp all lengths (used when a bucket/CP config cannot hold the
    /// longest sample — mirrors SFT-time truncation to the context window).
    pub fn truncated(&self, max_len: u32) -> Dataset {
        Dataset {
            name: format!("{}-trunc{}", self.name, max_len),
            lengths: self.lengths.iter().map(|&l| l.min(max_len)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset { name: "toy".into(), lengths: vec![10, 20, 30, 40, 50, 60, 70] }
    }

    #[test]
    fn epoch_covers_every_sequence_exactly_once() {
        let ds = toy();
        let batches = ds.epoch_batches(3, 7);
        assert_eq!(batches.len(), 3); // 3 + 3 + 1
        let mut ids: Vec<u64> = batches.iter().flatten().map(|s| s.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..7).collect::<Vec<_>>());
        for b in &batches {
            for s in b {
                assert_eq!(s.len, ds.lengths[s.id as usize]);
            }
        }
    }

    #[test]
    fn epoch_shuffle_is_seeded() {
        let ds = toy();
        let a = ds.epoch_batches(3, 7);
        let b = ds.epoch_batches(3, 7);
        let c = ds.epoch_batches(3, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn sample_batch_draws_valid_ids() {
        let ds = toy();
        let mut rng = Rng::seed_from_u64(1);
        let batch = ds.sample_batch(&mut rng, 64);
        assert_eq!(batch.len(), 64);
        for s in batch {
            assert!(s.id < 7);
            assert_eq!(s.len, ds.lengths[s.id as usize]);
        }
    }

    #[test]
    fn truncation_clamps() {
        let ds = toy().truncated(35);
        assert_eq!(ds.lengths, vec![10, 20, 30, 35, 35, 35, 35]);
        assert_eq!(ds.max_len(), 35);
    }

    #[test]
    fn synthesize_is_deterministic() {
        let d = LengthDistribution::wikipedia();
        let a = Dataset::synthesize(&d, 100, 3);
        let b = Dataset::synthesize(&d, 100, 3);
        assert_eq!(a.lengths, b.lengths);
        assert_eq!(a.total_tokens(), b.total_tokens());
    }
}
