//! Synthetic sequence-length distributions matching the paper's datasets.
//!
//! The scheduler consumes only sequence lengths, so Table 1 + Figure 1a
//! fully characterize what matters about the real datasets (DESIGN.md §2).
//! Parameters below were fit so the generated percentiles land on Table 1:
//!
//! | Dataset          | <1K    | <4K    | <8K    | <32K   | Longest |
//! | Wikipedia        | 87.88% | 99.34% | 99.92% | 99.99% | 78K     |
//! | LMsysChat1M      | 87.12% | 99.35% | 99.87% | 99.98% | 1643K   |
//! | ChatQA2-Long-SFT | 21.92% | 31.48% | 40.43% | 99.86% | 99K     |

use crate::rng::Rng;

/// A sequence-length distribution (token counts).
#[derive(Clone, Debug)]
pub enum LengthDistribution {
    /// Mixture of lognormals with weights; sample is clamped to [1, max_len].
    LognormalMixture {
        name: &'static str,
        components: Vec<(f64, f64, f64)>, // (weight, mu, sigma)
        max_len: u32,
    },
    /// Uniform in [lo, hi] — for tests and toy runs.
    Uniform { lo: u32, hi: u32 },
    /// Non-stationary: the corpus alternates between phases of
    /// `phase_len` sequences, each drawn from its own lognormal.
    /// Position-dependent by construction — `sample_many` is the
    /// authoritative corpus-order generator (sample index *i* belongs to
    /// phase `(i / phase_len) % phases.len()`), while a bare `sample`
    /// draws the stationary marginal (uniform over phases).  This is the
    /// bursty long-doc traffic axis the streaming drift detector exists
    /// for.
    Phased {
        name: &'static str,
        phase_len: usize,
        phases: Vec<(f64, f64)>, // (mu, sigma) per phase
        max_len: u32,
    },
}

impl LengthDistribution {
    /// Wikipedia-cn-20230720-filtered: extreme long-tail (Llama3-like).
    pub fn wikipedia() -> Self {
        LengthDistribution::LognormalMixture {
            name: "wikipedia",
            // bulk of short docs + thin tail reaching ~78K
            components: vec![(0.995, 5.66, 1.06), (0.005, 8.9, 0.95)],
            max_len: 78 * 1024,
        }
    }

    /// LMsysChat1M: same long-tail shape, longer extreme tail.  The raw
    /// dataset's longest entry is 1643K tokens; Long-SFT truncates to the
    /// model context window (we use 128K, Qwen2.5's window) — documented
    /// substitution, since <DP=4,CP=8,C=26K> cannot hold 1.6M tokens either.
    pub fn lmsys_chat() -> Self {
        LengthDistribution::LognormalMixture {
            name: "lmsys",
            components: vec![(0.994, 5.60, 1.08), (0.006, 9.1, 1.1)],
            max_len: 128 * 1024,
        }
    }

    /// ChatQA2-Long-SFT: bimodal — ~40% short chat turns, ~60% long
    /// retrieval contexts centered around 14K tokens.
    pub fn chatqa2() -> Self {
        LengthDistribution::LognormalMixture {
            name: "chatqa2",
            components: vec![(0.345, 6.28, 1.32), (0.655, 9.57, 0.40)],
            max_len: 99 * 1024,
        }
    }

    /// Llama3's internal Long-SFT mix (Section 1 / 3.1): 99.89% short
    /// sequences averaging under 1K tokens, 0.11% long averaging ~37K.
    pub fn llama3_mix() -> Self {
        LengthDistribution::LognormalMixture {
            name: "llama3-mix",
            // short mode: mean < 1K  (exp(μ+σ²/2) ≈ 740);
            // long mode: mean ≈ 37K (exp(μ+σ²/2) ≈ 36.9K)
            components: vec![(0.9989, 6.3, 0.9), (0.0011, 10.4, 0.5)],
            max_len: 128 * 1024,
        }
    }

    /// Qwen2.5-Turbo's staged context-extension mix (Section 1): 40% long
    /// sequences, 60% short.
    pub fn qwen_turbo_mix() -> Self {
        LengthDistribution::LognormalMixture {
            name: "qwen-turbo-mix",
            components: vec![(0.60, 6.0, 1.0), (0.40, 10.0, 0.6)],
            max_len: 256 * 1024,
        }
    }

    /// Bursty long-doc traffic: stretches of short chat-style sequences
    /// (median ≈ 270 tokens) interleaved with long retrieval-context
    /// bursts (median ≈ 15K) every 2048 samples — the non-stationary mix
    /// that LongAlign-style Long-SFT corpora exhibit and that the
    /// streaming drift detector is built to catch.
    pub fn bursty_long() -> Self {
        LengthDistribution::Phased {
            name: "bursty-long",
            phase_len: 2048,
            phases: vec![(5.6, 1.0), (9.6, 0.5)],
            max_len: 99 * 1024,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "wikipedia" | "wiki" => Some(Self::wikipedia()),
            "lmsys" | "lmsyschat1m" => Some(Self::lmsys_chat()),
            "chatqa2" | "chatqa2-long-sft" => Some(Self::chatqa2()),
            "llama3-mix" | "llama3" => Some(Self::llama3_mix()),
            "qwen-turbo-mix" | "qwen-turbo" => Some(Self::qwen_turbo_mix()),
            "bursty-long" | "bursty" => Some(Self::bursty_long()),
            _ => None,
        }
    }

    pub fn name(&self) -> &str {
        match self {
            LengthDistribution::LognormalMixture { name, .. } => name,
            LengthDistribution::Uniform { .. } => "uniform",
            LengthDistribution::Phased { name, .. } => name,
        }
    }

    pub fn max_len(&self) -> u32 {
        match self {
            LengthDistribution::LognormalMixture { max_len, .. } => *max_len,
            LengthDistribution::Uniform { hi, .. } => *hi,
            LengthDistribution::Phased { max_len, .. } => *max_len,
        }
    }

    /// Draw one sequence length.  For [`LengthDistribution::Phased`] this
    /// is the stationary marginal (uniform over phases); corpus-order
    /// generation goes through [`LengthDistribution::sample_many`].
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        match self {
            LengthDistribution::LognormalMixture { components, max_len, .. } => {
                let weights: Vec<f64> = components.iter().map(|c| c.0).collect();
                let (_, mu, sigma) = components[rng.weighted_index(&weights)];
                let x = rng.lognormal(mu, sigma);
                (x.round() as u64).clamp(1, *max_len as u64) as u32
            }
            LengthDistribution::Uniform { lo, hi } => rng.range_u32(*lo, *hi + 1),
            LengthDistribution::Phased { phases, max_len, .. } => {
                let (mu, sigma) = phases[rng.usize_below(phases.len())];
                let x = rng.lognormal(mu, sigma);
                (x.round() as u64).clamp(1, *max_len as u64) as u32
            }
        }
    }

    /// Draw `n` lengths in corpus order.  Phased distributions are
    /// position-dependent here: sample *i* comes from phase
    /// `(i / phase_len) % phases.len()`.
    pub fn sample_many(&self, rng: &mut Rng, n: usize) -> Vec<u32> {
        match self {
            LengthDistribution::Phased { phase_len, phases, max_len, .. } => {
                let pl = (*phase_len).max(1);
                (0..n)
                    .map(|i| {
                        let (mu, sigma) = phases[(i / pl) % phases.len()];
                        let x = rng.lognormal(mu, sigma);
                        (x.round() as u64).clamp(1, *max_len as u64) as u32
                    })
                    .collect()
            }
            _ => (0..n).map(|_| self.sample(rng)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::fraction_below;

    const N: usize = 200_000;

    fn check(dist: &LengthDistribution, expected: &[(u32, f64)], tol: f64) {
        let mut rng = Rng::seed_from_u64(1234);
        let xs = dist.sample_many(&mut rng, N);
        for &(thr, frac) in expected {
            let got = fraction_below(&xs, thr);
            assert!(
                (got - frac).abs() < tol,
                "{}: P(<{}) = {:.4}, expected {:.4}",
                dist.name(),
                thr,
                got,
                frac
            );
        }
    }

    #[test]
    fn wikipedia_matches_table1() {
        check(
            &LengthDistribution::wikipedia(),
            &[(1_024, 0.8788), (4_096, 0.9934), (8_192, 0.9992), (32_768, 0.9999)],
            0.02,
        );
    }

    #[test]
    fn lmsys_matches_table1() {
        check(
            &LengthDistribution::lmsys_chat(),
            &[(1_024, 0.8712), (4_096, 0.9935), (8_192, 0.9987), (32_768, 0.9998)],
            0.02,
        );
    }

    #[test]
    fn chatqa2_matches_table1() {
        check(
            &LengthDistribution::chatqa2(),
            &[(1_024, 0.2192), (4_096, 0.3148), (8_192, 0.4043), (32_768, 0.9986)],
            0.025,
        );
    }

    #[test]
    fn samples_respect_bounds() {
        for dist in [
            LengthDistribution::wikipedia(),
            LengthDistribution::lmsys_chat(),
            LengthDistribution::chatqa2(),
        ] {
            let mut rng = Rng::seed_from_u64(9);
            for _ in 0..10_000 {
                let x = dist.sample(&mut rng);
                assert!(x >= 1 && x <= dist.max_len());
            }
        }
    }

    #[test]
    fn uniform_spans_range() {
        let d = LengthDistribution::Uniform { lo: 10, hi: 20 };
        let mut rng = Rng::seed_from_u64(2);
        let xs = d.sample_many(&mut rng, 5000);
        assert!(xs.iter().all(|&x| (10..=20).contains(&x)));
        assert!(xs.contains(&10) && xs.contains(&20));
    }

    #[test]
    fn by_name_resolves_all_datasets() {
        for n in ["wikipedia", "lmsys", "chatqa2", "llama3-mix", "qwen-turbo-mix", "bursty-long"] {
            assert_eq!(LengthDistribution::by_name(n).unwrap().name(), n);
        }
        assert!(LengthDistribution::by_name("imagenet").is_none());
    }

    #[test]
    fn bursty_long_is_position_dependent() {
        let d = LengthDistribution::bursty_long();
        let mut rng = Rng::seed_from_u64(7);
        let xs = d.sample_many(&mut rng, 4096);
        let short_phase = &xs[..2048];
        let long_phase = &xs[2048..];
        let mean = |v: &[u32]| v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        assert!(
            mean(long_phase) > 10.0 * mean(short_phase),
            "phases not distinct: {} vs {}",
            mean(short_phase),
            mean(long_phase)
        );
        assert!(xs.iter().all(|&x| x >= 1 && x <= d.max_len()));
        // the stationary marginal still respects the bounds
        for _ in 0..5_000 {
            let x = d.sample(&mut rng);
            assert!(x >= 1 && x <= d.max_len());
        }
    }

    #[test]
    fn llama3_mix_matches_section1() {
        // "99.89% of sequences are under 1K tokens on average, while the
        // remaining 0.11% are approximately 37K" — check the short-mode
        // fraction and both modes' means.
        let d = LengthDistribution::llama3_mix();
        let mut rng = Rng::seed_from_u64(5);
        let xs = d.sample_many(&mut rng, N);
        let short: Vec<u32> = xs.iter().copied().filter(|&x| x < 8192).collect();
        let long: Vec<u32> = xs.iter().copied().filter(|&x| x >= 8192).collect();
        let frac_short = short.len() as f64 / xs.len() as f64;
        assert!((0.995..1.0).contains(&frac_short), "{frac_short}");
        let mean_short = short.iter().map(|&x| x as f64).sum::<f64>() / short.len() as f64;
        assert!(mean_short < 1024.0, "short mean {mean_short}");
        let mean_long = long.iter().map(|&x| x as f64).sum::<f64>() / long.len().max(1) as f64;
        assert!((20_000.0..60_000.0).contains(&mean_long), "long mean {mean_long}");
    }

    #[test]
    fn qwen_turbo_mix_is_40_60() {
        // "training on 40% long sequences and 60% short sequences"
        let d = LengthDistribution::qwen_turbo_mix();
        let mut rng = Rng::seed_from_u64(6);
        let xs = d.sample_many(&mut rng, N);
        let frac_long = xs.iter().filter(|&&x| x >= 8192).count() as f64 / xs.len() as f64;
        assert!((0.33..0.45).contains(&frac_long), "{frac_long}");
    }
}
