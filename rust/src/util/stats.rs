//! Summary statistics + least-squares fitting used by the offline profiler
//! (Appendix A: fit alpha/beta of Eq. 12/14/16) and the bench reports.

/// Running summary of a sample set.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    xs: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (self.xs.len() - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Quantile via linear interpolation on the sorted sample.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut sorted = self.xs.clone();
        sorted.sort_by(f64::total_cmp);
        let pos = q * (sorted.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = pos - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }
}

/// Fraction of samples strictly below `threshold` (Table 1's "<1K" columns).
pub fn fraction_below(xs: &[u32], threshold: u32) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|&&x| x < threshold).count() as f64 / xs.len() as f64
}

/// Ordinary least squares for y = a*x + b.  Returns (a, b, r2).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let a = sxy / sxx.max(1e-300);
    let b = my - a * mx;
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a * x + b);
            e * e
        })
        .sum();
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.std() - 1.5811).abs() < 1e-3);
        assert_eq!(s.quantile(0.5), 3.0);
        assert_eq!(s.quantile(0.0), 1.0);
        assert_eq!(s.quantile(1.0), 5.0);
    }

    #[test]
    fn fraction_below_counts_strictly() {
        let xs = [100, 1000, 2000];
        assert!((fraction_below(&xs, 1000) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(fraction_below(&xs, 5000), 1.0);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.5 * x + 7.0).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 2.5).abs() < 1e-9);
        assert!((b - 7.0).abs() < 1e-9);
        assert!(r2 > 0.999999);
    }

    #[test]
    fn linear_fit_noisy_r2() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| 3.0 * x + if i % 2 == 0 { 5.0 } else { -5.0 })
            .collect();
        let (a, _, r2) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 0.01);
        assert!(r2 > 0.99);
    }
}
