//! Summary statistics + least-squares fitting used by the offline profiler
//! (Appendix A: fit alpha/beta of Eq. 12/14/16), the calibration subsystem
//! (`calib::fit` builds its robust fits on [`linear_fit`]) and the bench
//! reports.

use std::cell::OnceCell;

/// Running summary of a sample set.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    xs: Vec<f64>,
    /// Sorted view, computed lazily on first quantile request and reused
    /// until the next `push` (the bench reports ask for several quantiles
    /// of the same sample; re-sorting per call was O(n log n) each).
    sorted: OnceCell<Vec<f64>>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild a summary from raw samples, in insertion order.  Used by the
    /// serve snapshot codec to round-trip fleet state exactly: together
    /// with [`Summary::samples`] this is a lossless (bit-exact) round trip.
    pub fn from_samples(xs: Vec<f64>) -> Self {
        Summary { xs, sorted: OnceCell::new() }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        // invalidate the cached sorted view
        self.sorted.take();
    }

    /// The raw samples in insertion order (see [`Summary::from_samples`]).
    pub fn samples(&self) -> &[f64] {
        &self.xs
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (self.xs.len() - 1) as f64)
            .sqrt()
    }

    /// Smallest sample; 0.0 on an empty summary (consistent with `mean` /
    /// `std` — a bare fold used to return +∞, which leaked non-finite
    /// values into JSON reports the validator rejects).
    pub fn min(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample; 0.0 on an empty summary (see [`Summary::min`]).
    pub fn max(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Sorted view of the sample, computed once and cached until the next
    /// `push`.
    pub fn sorted(&self) -> &[f64] {
        self.sorted.get_or_init(|| {
            let mut v = self.xs.clone();
            v.sort_by(f64::total_cmp);
            v
        })
    }

    /// Quantile via linear interpolation on the sorted sample.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.xs.is_empty() {
            return 0.0;
        }
        let sorted = self.sorted();
        let pos = q * (sorted.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = pos - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }
}

/// Fraction of samples strictly below `threshold` (Table 1's "<1K" columns).
pub fn fraction_below(xs: &[u32], threshold: u32) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|&&x| x < threshold).count() as f64 / xs.len() as f64
}

/// Median of a sample (by value); 0.0 on an empty slice.
pub fn median_of(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let mid = v.len() / 2;
    if v.len() % 2 == 1 {
        v[mid]
    } else {
        0.5 * (v[mid - 1] + v[mid])
    }
}

/// Ordinary least squares for y = a*x + b.  Returns (a, b, r2).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let a = sxy / sxx.max(1e-300);
    let b = my - a * mx;
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a * x + b);
            e * e
        })
        .sum();
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.std() - 1.5811).abs() < 1e-3);
        assert_eq!(s.quantile(0.5), 3.0);
        assert_eq!(s.quantile(0.0), 1.0);
        assert_eq!(s.quantile(1.0), 5.0);
    }

    #[test]
    fn empty_summary_is_all_zeros() {
        // Regression: min/max used to return ±∞ on an empty sample,
        // inconsistent with mean/std and non-finite in JSON reports.
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.quantile(0.5), 0.0);
        assert!(s.min().is_finite() && s.max().is_finite());
    }

    #[test]
    fn quantile_cache_invalidates_on_push() {
        let mut s = Summary::new();
        s.push(1.0);
        s.push(3.0);
        assert_eq!(s.quantile(1.0), 3.0);
        assert_eq!(s.sorted(), &[1.0, 3.0]);
        // a later push must not serve the stale sorted view
        s.push(2.0);
        assert_eq!(s.quantile(0.5), 2.0);
        assert_eq!(s.quantile(1.0), 3.0);
        assert_eq!(s.sorted(), &[1.0, 2.0, 3.0]);
        // repeated quantile calls agree (served from the cache)
        assert_eq!(s.quantile(0.5), 2.0);
    }

    #[test]
    fn samples_round_trip_bit_exact() {
        let mut s = Summary::new();
        for x in [0.1, -3.5e-9, 7.0, f64::MIN_POSITIVE] {
            s.push(x);
        }
        let back = Summary::from_samples(s.samples().to_vec());
        assert_eq!(back.samples().len(), 4);
        for (a, b) in s.samples().iter().zip(back.samples()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(s.quantile(0.5), back.quantile(0.5));
    }

    #[test]
    fn median_of_odd_even_and_empty() {
        assert_eq!(median_of(&[]), 0.0);
        assert_eq!(median_of(&[5.0]), 5.0);
        assert_eq!(median_of(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median_of(&[4.0, 1.0, 3.0, 2.0]), 2.5);
    }

    #[test]
    fn fraction_below_counts_strictly() {
        let xs = [100, 1000, 2000];
        assert!((fraction_below(&xs, 1000) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(fraction_below(&xs, 5000), 1.0);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.5 * x + 7.0).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 2.5).abs() < 1e-9);
        assert!((b - 7.0).abs() < 1e-9);
        assert!(r2 > 0.999999);
    }

    #[test]
    fn linear_fit_noisy_r2() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| 3.0 * x + if i % 2 == 0 { 5.0 } else { -5.0 })
            .collect();
        let (a, _, r2) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 0.01);
        assert!(r2 > 0.99);
    }
}
