//! Minimal error plumbing (the offline build has no anyhow/thiserror).
//!
//! `Error` is an opaque message-carrying error, `Result` defaults its
//! error type to it, `Context` adds context the way anyhow's trait does,
//! and the crate-root macros `anyhow!` / `bail!` / `ensure!` mirror their
//! namesakes.  A blanket `From<E: std::error::Error>` makes `?` work on
//! io/parse/scheduler errors; `Error` itself deliberately does NOT
//! implement `std::error::Error` so that blanket stays coherent.

use std::fmt;

/// Opaque application error: a human-readable message chain.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line (used by [`Context`]).
    fn wrap(self, ctx: impl fmt::Display) -> Self {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Debug doubles as the `fn main() -> Result<()>` exit rendering.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// anyhow-style context attachment for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Bail unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        Ok(std::fs::read_to_string("/definitely/not/a/path")?)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_wraps_results_and_options() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("while formatting").unwrap_err();
        assert!(e.to_string().starts_with("while formatting: "));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
        assert_eq!(Some(7u32).context("fine").unwrap(), 7);
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                crate::bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(crate::anyhow!("n={}", 4).to_string(), "n=4");
    }
}
