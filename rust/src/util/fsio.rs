//! Crash-safe file write helpers shared by the spill store, the
//! coordinator checkpoint and the serve journal/snapshot.
//!
//! The durability recipe is write-tmp → fsync(file) → rename → **fsync
//! (parent dir)**.  The last step is the one everybody forgets: POSIX
//! only guarantees the rename itself is durable once the directory
//! entry has been synced, so a crash after `rename` but before the
//! directory flush can resurrect the old file — or lose the new one —
//! despite the data blocks being on disk.

use std::fs::File;
use std::io;
use std::path::Path;

/// Fsync the directory containing `path` (or `path` itself if it is a
/// directory), making a preceding `rename` into it durable.
pub fn fsync_dir(path: &Path) -> io::Result<()> {
    let dir = if path.is_dir() {
        path
    } else {
        match path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p,
            _ => Path::new("."),
        }
    };
    File::open(dir)?.sync_all()
}

/// Atomically replace `path` with `bytes`: write to a sibling tmp file
/// (`path.with_extension(tmp_ext)`), fsync it, rename over `path`, then
/// fsync the parent directory so the rename survives a crash.
pub fn write_atomic(path: &Path, bytes: &[u8], tmp_ext: &str) -> io::Result<()> {
    use std::io::Write;
    let tmp = path.with_extension(tmp_ext);
    let mut f = File::create(&tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)?;
    fsync_dir(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("skrull_fsio_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn write_atomic_replaces_and_cleans_tmp() {
        let dir = tmpdir("replace");
        let path = dir.join("state.bin");
        write_atomic(&path, b"first", "tmp").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        write_atomic(&path, b"second", "tmp").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        assert!(!path.with_extension("tmp").exists(), "tmp must be renamed away");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fsync_dir_handles_bare_and_nested_paths() {
        let dir = tmpdir("dirsync");
        let nested = dir.join("file.bin");
        std::fs::write(&nested, b"x").unwrap();
        fsync_dir(&nested).unwrap();
        // a bare filename has no parent component: falls back to "."
        fsync_dir(Path::new("Cargo.toml")).unwrap();
        // a directory path syncs itself
        fsync_dir(&dir).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
