//! Scoped-thread fan-out helpers (the offline build has no rayon).
//!
//! The scheduler's unit of parallelism is coarse — one DP rank, one
//! micro-batch refinement — so plain `std::thread::scope` with contiguous
//! chunking is enough: no work stealing, deterministic output order, and
//! results identical to the serial loop byte for byte.  Threads are
//! spawned per call; at the scheduler's call rates (once per iteration)
//! spawn cost is noise next to the work each chunk carries.

use std::num::NonZeroUsize;

/// Worker budget: `SKRULL_THREADS` override, else available parallelism.
pub fn max_threads() -> usize {
    if let Ok(v) = std::env::var("SKRULL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(NonZeroUsize::get)
        .unwrap_or(1)
}

/// `out[i] = f(i, &items[i], &mut scratch[i])`, fanned out over up to
/// `max_threads()` scoped threads (serial when 0/1 items or 1 thread).
/// `items` and `scratch` must have equal length; output order matches
/// input order regardless of thread count.
pub fn map_with_scratch<A, B, R, F>(items: &[A], scratch: &mut [B], f: F) -> Vec<R>
where
    A: Sync,
    B: Send,
    R: Send,
    F: Fn(usize, &A, &mut B) -> R + Sync,
{
    map_with_scratch_up_to(max_threads(), items, scratch, f)
}

/// [`map_with_scratch`] with an explicit worker cap — for nested fan-outs,
/// where each outer worker should only claim its share of the core budget
/// instead of a full `max_threads()` each.
pub fn map_with_scratch_up_to<A, B, R, F>(
    limit: usize,
    items: &[A],
    scratch: &mut [B],
    f: F,
) -> Vec<R>
where
    A: Sync,
    B: Send,
    R: Send,
    F: Fn(usize, &A, &mut B) -> R + Sync,
{
    assert_eq!(items.len(), scratch.len());
    let n = items.len();
    let threads = limit.max(1).min(n);
    if threads <= 1 {
        return items
            .iter()
            .zip(scratch.iter_mut())
            .enumerate()
            .map(|(i, (a, b))| f(i, a, b))
            .collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out: Vec<R> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for (ci, (ichunk, schunk)) in items.chunks(chunk).zip(scratch.chunks_mut(chunk)).enumerate()
        {
            let f = &f;
            handles.push(s.spawn(move || {
                ichunk
                    .iter()
                    .zip(schunk.iter_mut())
                    .enumerate()
                    .map(|(j, (a, b))| f(ci * chunk + j, a, b))
                    .collect::<Vec<R>>()
            }));
        }
        for h in handles {
            out.extend(h.join().expect("par worker panicked"));
        }
    });
    out
}

/// `out[i] = f(i, &items[i])` with an explicit worker cap — the
/// scratch-free sibling of [`map_with_scratch_up_to`] for fan-outs whose
/// work items carry no per-item state (the e2e sweep's `--jobs` knob).
/// Contiguous chunking, deterministic output order: results are identical
/// to the serial loop byte for byte regardless of `limit`.
pub fn map_up_to<A, R, F>(limit: usize, items: &[A], f: F) -> Vec<R>
where
    A: Sync,
    R: Send,
    F: Fn(usize, &A) -> R + Sync,
{
    let mut scratch = vec![(); items.len()];
    map_with_scratch_up_to(limit, items, &mut scratch, |i, a, _| f(i, a))
}

/// In-place parallel `for`: `f(i, &mut items[i])` over contiguous chunks.
pub fn for_each_mut<T, F>(items: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    let threads = max_threads().min(n);
    if threads <= 1 {
        for (i, t) in items.iter_mut().enumerate() {
            f(i, t);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (ci, tchunk) in items.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, t) in tchunk.iter_mut().enumerate() {
                    f(ci * chunk + j, t);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order_and_uses_scratch() {
        let items: Vec<u64> = (0..137).collect();
        let mut scratch = vec![0u64; items.len()];
        let out = map_with_scratch(&items, &mut scratch, |i, &x, s| {
            *s += x;
            (i as u64) * 1000 + x
        });
        for (i, &r) in out.iter().enumerate() {
            assert_eq!(r, (i as u64) * 1000 + i as u64);
        }
        assert_eq!(scratch, items);
    }

    #[test]
    fn map_handles_empty_and_single() {
        let mut empty_scratch: Vec<u8> = Vec::new();
        let out: Vec<u8> = map_with_scratch(&[], &mut empty_scratch, |_, _: &u8, _| 0u8);
        assert!(out.is_empty());
        let mut s = [0u8];
        assert_eq!(map_with_scratch(&[5u8], &mut s, |_, &x, _| x + 1), vec![6]);
    }

    #[test]
    fn map_up_to_is_limit_invariant() {
        let items: Vec<u64> = (0..97).collect();
        let serial = map_up_to(1, &items, |i, &x| i as u64 * 31 + x * x);
        for limit in [2, 3, 4, 8, 128] {
            assert_eq!(map_up_to(limit, &items, |i, &x| i as u64 * 31 + x * x), serial);
        }
        assert!(map_up_to(4, &[] as &[u8], |_, _| 0u8).is_empty());
    }

    #[test]
    fn for_each_mut_touches_every_item_once() {
        let mut items: Vec<u32> = vec![1; 301];
        for_each_mut(&mut items, |i, t| *t += i as u32);
        for (i, &t) in items.iter().enumerate() {
            assert_eq!(t, 1 + i as u32);
        }
    }

    #[test]
    fn matches_serial_result_exactly() {
        let items: Vec<f64> = (0..64).map(|i| i as f64 * 0.37).collect();
        let mut s1 = vec![0.0f64; items.len()];
        let par: Vec<f64> = map_with_scratch(&items, &mut s1, |_, &x, _| x.sin() * x.cos());
        let ser: Vec<f64> = items.iter().map(|&x| x.sin() * x.cos()).collect();
        assert_eq!(par, ser);
    }
}
