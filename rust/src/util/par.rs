//! Scoped-thread fan-out helpers (the offline build has no rayon), plus
//! the bounded SPSC channel the sharded scheduler's shard workers use.
//!
//! The scheduler's unit of parallelism is coarse — one DP rank, one
//! micro-batch refinement — so plain `std::thread::scope` with contiguous
//! chunking is enough: no work stealing, deterministic output order, and
//! results identical to the serial loop byte for byte.  Threads are
//! spawned per call; at the scheduler's call rates (once per iteration)
//! spawn cost is noise next to the work each chunk carries.
//!
//! The channel ([`bounded`]) backs the shared-nothing shard pool
//! (scheduler::shard): each shard worker owns its arenas outright and
//! talks to the dispatcher only through one job queue and one result
//! queue, so no scheduling state is ever shared mutably across shards.

use std::collections::VecDeque;
use std::num::NonZeroUsize;
use std::sync::{Arc, Condvar, Mutex};

/// Worker budget: `SKRULL_THREADS` override, else available parallelism.
pub fn max_threads() -> usize {
    if let Ok(v) = std::env::var("SKRULL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(NonZeroUsize::get)
        .unwrap_or(1)
}

/// `out[i] = f(i, &items[i], &mut scratch[i])`, fanned out over up to
/// `max_threads()` scoped threads (serial when 0/1 items or 1 thread).
/// `items` and `scratch` must have equal length; output order matches
/// input order regardless of thread count.
pub fn map_with_scratch<A, B, R, F>(items: &[A], scratch: &mut [B], f: F) -> Vec<R>
where
    A: Sync,
    B: Send,
    R: Send,
    F: Fn(usize, &A, &mut B) -> R + Sync,
{
    map_with_scratch_up_to(max_threads(), items, scratch, f)
}

/// [`map_with_scratch`] with an explicit worker cap — for nested fan-outs,
/// where each outer worker should only claim its share of the core budget
/// instead of a full `max_threads()` each.
pub fn map_with_scratch_up_to<A, B, R, F>(
    limit: usize,
    items: &[A],
    scratch: &mut [B],
    f: F,
) -> Vec<R>
where
    A: Sync,
    B: Send,
    R: Send,
    F: Fn(usize, &A, &mut B) -> R + Sync,
{
    assert_eq!(items.len(), scratch.len());
    let n = items.len();
    let threads = limit.max(1).min(n);
    if threads <= 1 {
        return items
            .iter()
            .zip(scratch.iter_mut())
            .enumerate()
            .map(|(i, (a, b))| f(i, a, b))
            .collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out: Vec<R> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for (ci, (ichunk, schunk)) in items.chunks(chunk).zip(scratch.chunks_mut(chunk)).enumerate()
        {
            let f = &f;
            handles.push(s.spawn(move || {
                ichunk
                    .iter()
                    .zip(schunk.iter_mut())
                    .enumerate()
                    .map(|(j, (a, b))| f(ci * chunk + j, a, b))
                    .collect::<Vec<R>>()
            }));
        }
        for h in handles {
            out.extend(h.join().expect("par worker panicked"));
        }
    });
    out
}

/// `out[i] = f(i, &items[i])` with an explicit worker cap — the
/// scratch-free sibling of [`map_with_scratch_up_to`] for fan-outs whose
/// work items carry no per-item state (the e2e sweep's `--jobs` knob).
/// Contiguous chunking, deterministic output order: results are identical
/// to the serial loop byte for byte regardless of `limit`.
pub fn map_up_to<A, R, F>(limit: usize, items: &[A], f: F) -> Vec<R>
where
    A: Sync,
    R: Send,
    F: Fn(usize, &A) -> R + Sync,
{
    let mut scratch = vec![(); items.len()];
    map_with_scratch_up_to(limit, items, &mut scratch, |i, a, _| f(i, a))
}

/// In-place parallel `for`: `f(i, &mut items[i])` over contiguous chunks.
pub fn for_each_mut<T, F>(items: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    let threads = max_threads().min(n);
    if threads <= 1 {
        for (i, t) in items.iter_mut().enumerate() {
            f(i, t);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (ci, tchunk) in items.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, t) in tchunk.iter_mut().enumerate() {
                    f(ci * chunk + j, t);
                }
            });
        }
    });
}

// ---------------------------------------------------------------------------
// Bounded SPSC channel.
//
// A deliberately small blocking queue: one producer, one consumer, fixed
// capacity chosen at creation.  The buffer is allocated once up front
// (`VecDeque::with_capacity`) and never grows past `cap`, so steady-state
// sends and receives perform zero heap allocations.  Backpressure is
// blocking: `send` waits while the queue is full, `recv` waits while it is
// empty.  Dropping the `Sender` wakes the receiver with end-of-stream;
// dropping the `Receiver` makes further sends fail fast.

struct ChannelState<T> {
    buf: VecDeque<T>,
    sender_alive: bool,
    receiver_alive: bool,
}

struct Channel<T> {
    state: Mutex<ChannelState<T>>,
    cap: usize,
    not_empty: Condvar,
    not_full: Condvar,
}

/// Producing half of a [`bounded`] channel.
pub struct Sender<T> {
    ch: Arc<Channel<T>>,
}

/// Consuming half of a [`bounded`] channel.
pub struct Receiver<T> {
    ch: Arc<Channel<T>>,
}

/// Create a bounded single-producer/single-consumer channel holding at
/// most `cap` in-flight items (`cap` is clamped to ≥ 1).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let cap = cap.max(1);
    let ch = Arc::new(Channel {
        state: Mutex::new(ChannelState {
            buf: VecDeque::with_capacity(cap),
            sender_alive: true,
            receiver_alive: true,
        }),
        cap,
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
    });
    (Sender { ch: Arc::clone(&ch) }, Receiver { ch })
}

impl<T> Sender<T> {
    /// Block until there is room, then enqueue.  Returns the item back as
    /// `Err` if the receiver is gone.
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut st = self.ch.state.lock().expect("channel poisoned");
        loop {
            if !st.receiver_alive {
                return Err(item);
            }
            if st.buf.len() < self.ch.cap {
                st.buf.push_back(item);
                self.ch.not_empty.notify_one();
                return Ok(());
            }
            st = self.ch.not_full.wait(st).expect("channel poisoned");
        }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.ch.state.lock().expect("channel poisoned");
        st.sender_alive = false;
        self.ch.not_empty.notify_all();
    }
}

impl<T> Receiver<T> {
    /// Block until an item arrives; `None` once the sender is gone and the
    /// queue has drained (end of stream).
    pub fn recv(&self) -> Option<T> {
        let mut st = self.ch.state.lock().expect("channel poisoned");
        loop {
            if let Some(item) = st.buf.pop_front() {
                self.ch.not_full.notify_one();
                return Some(item);
            }
            if !st.sender_alive {
                return None;
            }
            st = self.ch.not_empty.wait(st).expect("channel poisoned");
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.ch.state.lock().expect("channel poisoned");
        st.receiver_alive = false;
        self.ch.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order_and_uses_scratch() {
        let items: Vec<u64> = (0..137).collect();
        let mut scratch = vec![0u64; items.len()];
        let out = map_with_scratch(&items, &mut scratch, |i, &x, s| {
            *s += x;
            (i as u64) * 1000 + x
        });
        for (i, &r) in out.iter().enumerate() {
            assert_eq!(r, (i as u64) * 1000 + i as u64);
        }
        assert_eq!(scratch, items);
    }

    #[test]
    fn map_handles_empty_and_single() {
        let mut empty_scratch: Vec<u8> = Vec::new();
        let out: Vec<u8> = map_with_scratch(&[], &mut empty_scratch, |_, _: &u8, _| 0u8);
        assert!(out.is_empty());
        let mut s = [0u8];
        assert_eq!(map_with_scratch(&[5u8], &mut s, |_, &x, _| x + 1), vec![6]);
    }

    #[test]
    fn map_up_to_is_limit_invariant() {
        let items: Vec<u64> = (0..97).collect();
        let serial = map_up_to(1, &items, |i, &x| i as u64 * 31 + x * x);
        for limit in [2, 3, 4, 8, 128] {
            assert_eq!(map_up_to(limit, &items, |i, &x| i as u64 * 31 + x * x), serial);
        }
        assert!(map_up_to(4, &[] as &[u8], |_, _| 0u8).is_empty());
    }

    #[test]
    fn for_each_mut_touches_every_item_once() {
        let mut items: Vec<u32> = vec![1; 301];
        for_each_mut(&mut items, |i, t| *t += i as u32);
        for (i, &t) in items.iter().enumerate() {
            assert_eq!(t, 1 + i as u32);
        }
    }

    #[test]
    fn matches_serial_result_exactly() {
        let items: Vec<f64> = (0..64).map(|i| i as f64 * 0.37).collect();
        let mut s1 = vec![0.0f64; items.len()];
        let par: Vec<f64> = map_with_scratch(&items, &mut s1, |_, &x, _| x.sin() * x.cos());
        let ser: Vec<f64> = items.iter().map(|&x| x.sin() * x.cos()).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn channel_is_fifo_within_capacity() {
        let (tx, rx) = bounded::<u32>(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv(), Some(i));
        }
    }

    #[test]
    fn channel_end_of_stream_after_sender_drop() {
        let (tx, rx) = bounded::<u32>(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn channel_send_fails_after_receiver_drop() {
        let (tx, rx) = bounded::<u32>(2);
        drop(rx);
        assert_eq!(tx.send(7), Err(7));
    }

    #[test]
    fn channel_backpressure_blocks_then_drains_across_threads() {
        // capacity 1: the producer must block on the second send until the
        // consumer drains — all 100 items still arrive in order
        let (tx, rx) = bounded::<u64>(1);
        let producer = std::thread::spawn(move || {
            for i in 0..100u64 {
                tx.send(i).unwrap();
            }
        });
        let mut got = Vec::new();
        while let Some(v) = rx.recv() {
            got.push(v);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }
}
