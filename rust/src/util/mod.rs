//! Small shared utilities: summary statistics, histograms, formatting,
//! a micro property-testing harness (no proptest in the vendored set),
//! anyhow-style error plumbing (util::error), and scoped-thread fan-out
//! (util::par) — the offline build vendors its own substitutes.

pub mod error;
pub mod fsio;
pub mod par;
pub mod proptest;
pub mod stats;

/// Format a token count the way the paper's tables do ("26K", "1643K").
pub fn fmt_tokens(n: u64) -> String {
    if n >= 1024 * 1024 {
        format!("{:.1}M", n as f64 / (1024.0 * 1024.0))
    } else if n >= 1024 {
        format!("{}K", n / 1024)
    } else {
        n.to_string()
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_formatting() {
        assert_eq!(fmt_tokens(512), "512");
        assert_eq!(fmt_tokens(26 * 1024), "26K");
        assert_eq!(fmt_tokens(2 * 1024 * 1024), "2.0M");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(1.5), "1.50s");
        assert_eq!(fmt_secs(0.0123), "12.30ms");
        assert_eq!(fmt_secs(42e-6), "42.0us");
    }
}
