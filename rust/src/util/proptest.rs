//! Minimal property-testing harness (the vendored crate set lacks proptest).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` generated inputs;
//! on failure it greedily shrinks via the generator's `shrink` and panics
//! with the minimal failing case.  Used by the scheduler invariant tests.

use crate::rng::Rng;

/// A generator produces a case from randomness and can propose smaller cases.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate shrinks, largest reduction first.  Default: no shrinking.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run a property over `cases` random inputs, shrinking on failure.
/// `FnMut` so properties can thread mutable state (e.g. a scheduler
/// scratch arena) through the cases.
pub fn forall<G, F>(seed: u64, cases: usize, gen: &G, mut prop: F)
where
    G: Gen,
    F: FnMut(&G::Value) -> Result<(), String>,
{
    let mut rng = Rng::seed_from_u64(seed);
    for case_idx in 0..cases {
        let v = gen.generate(&mut rng);
        if let Err(first_msg) = prop(&v) {
            // shrink loop: repeatedly take the first failing shrink candidate
            let mut cur = v;
            let mut msg = first_msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in gen.shrink(&cur) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case_idx}, seed {seed}):\n  {msg}\n  minimal input: {cur:?}"
            );
        }
    }
}

/// Generator for a vector of sequence lengths in [1, max_len], a staple for
/// scheduler tests.  Shrinks by halving the vector and by shrinking lengths.
pub struct SeqLensGen {
    pub min_k: usize,
    pub max_k: usize,
    pub max_len: u32,
}

impl Gen for SeqLensGen {
    type Value = Vec<u32>;

    fn generate(&self, rng: &mut Rng) -> Vec<u32> {
        let k = self.min_k + rng.usize_below(self.max_k - self.min_k + 1);
        (0..k)
            .map(|_| {
                // log-uniform lengths: scheduler stress lives in the skew
                let lo = 1f64.ln();
                let hi = (self.max_len as f64).ln();
                (lo + rng.f64() * (hi - lo)).exp().round().max(1.0) as u32
            })
            .collect()
    }

    fn shrink(&self, v: &Vec<u32>) -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        if v.len() > self.min_k {
            let half = v.len().max(2) / 2;
            if half >= self.min_k {
                out.push(v[..half].to_vec());
                out.push(v[half..].to_vec());
            }
            let mut drop_first = v.clone();
            drop_first.remove(0);
            if drop_first.len() >= self.min_k {
                out.push(drop_first);
            }
        }
        // halve each length
        let halved: Vec<u32> = v.iter().map(|&x| (x / 2).max(1)).collect();
        if &halved != v {
            out.push(halved);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let gen = SeqLensGen { min_k: 1, max_k: 16, max_len: 1000 };
        forall(1, 100, &gen, |v| {
            if v.iter().all(|&x| x >= 1) {
                Ok(())
            } else {
                Err("zero length".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_shrunk_case() {
        let gen = SeqLensGen { min_k: 1, max_k: 32, max_len: 4096 };
        forall(2, 100, &gen, |v| {
            if v.iter().sum::<u32>() < 100 {
                Ok(())
            } else {
                Err(format!("sum too big: {}", v.iter().sum::<u32>()))
            }
        });
    }

    #[test]
    fn shrink_reduces_size() {
        let gen = SeqLensGen { min_k: 1, max_k: 8, max_len: 100 };
        let v = vec![50u32, 60, 70, 80];
        for s in gen.shrink(&v) {
            let smaller_len = s.len() < v.len();
            let smaller_vals = s.iter().sum::<u32>() < v.iter().sum::<u32>();
            assert!(smaller_len || smaller_vals, "{s:?} is not smaller than {v:?}");
        }
    }
}
