//! Minimal property-testing harness (the vendored crate set lacks proptest).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` generated inputs;
//! on failure it greedily shrinks via the generator's `shrink` and panics
//! with the minimal failing case.  Used by the scheduler invariant tests.

use crate::rng::Rng;

/// A generator produces a case from randomness and can propose smaller cases.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate shrinks, largest reduction first.  Default: no shrinking.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run a property over `cases` random inputs, shrinking on failure.
/// `FnMut` so properties can thread mutable state (e.g. a scheduler
/// scratch arena) through the cases.
pub fn forall<G, F>(seed: u64, cases: usize, gen: &G, mut prop: F)
where
    G: Gen,
    F: FnMut(&G::Value) -> Result<(), String>,
{
    let mut rng = Rng::seed_from_u64(seed);
    for case_idx in 0..cases {
        let v = gen.generate(&mut rng);
        if let Err(first_msg) = prop(&v) {
            // shrink loop: repeatedly take the first failing shrink candidate
            let mut cur = v;
            let mut msg = first_msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in gen.shrink(&cur) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case_idx}, seed {seed}):\n  {msg}\n  minimal input: {cur:?}"
            );
        }
    }
}

/// Generator for a vector of sequence lengths in [1, max_len], a staple for
/// scheduler tests.  Shrinks by halving the vector and by shrinking lengths.
pub struct SeqLensGen {
    pub min_k: usize,
    pub max_k: usize,
    pub max_len: u32,
}

impl Gen for SeqLensGen {
    type Value = Vec<u32>;

    fn generate(&self, rng: &mut Rng) -> Vec<u32> {
        let k = self.min_k + rng.usize_below(self.max_k - self.min_k + 1);
        (0..k)
            .map(|_| {
                // log-uniform lengths: scheduler stress lives in the skew
                let lo = 1f64.ln();
                let hi = (self.max_len as f64).ln();
                (lo + rng.f64() * (hi - lo)).exp().round().max(1.0) as u32
            })
            .collect()
    }

    fn shrink(&self, v: &Vec<u32>) -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        if v.len() > self.min_k {
            let half = v.len().max(2) / 2;
            if half >= self.min_k {
                out.push(v[..half].to_vec());
                out.push(v[half..].to_vec());
            }
            let mut drop_first = v.clone();
            drop_first.remove(0);
            if drop_first.len() >= self.min_k {
                out.push(drop_first);
            }
        }
        // halve each length
        let halved: Vec<u32> = v.iter().map(|&x| (x / 2).max(1)).collect();
        if &halved != v {
            out.push(halved);
        }
        out
    }
}

/// Exhaustive mutation sweep for binary codecs: feeds `decode` every
/// single-bit flip of `valid`, every truncation length, and `garbage_cases`
/// seeded random buffers, asserting each one is *rejected* (returns `Err`)
/// without panicking.  `decode` returning `Ok` for any mutant fails with a
/// message naming the mutant.  Shared by the `fleet::ResumePoint` and serve
/// journal-record hardening tests.
pub fn assert_codec_rejects_mutants<T, E, F>(valid: &[u8], garbage_cases: usize, seed: u64, decode: F)
where
    F: Fn(&[u8]) -> Result<T, E>,
{
    // every single-bit flip of the valid encoding
    let mut buf = valid.to_vec();
    for byte in 0..valid.len() {
        for bit in 0..8 {
            buf[byte] ^= 1 << bit;
            assert!(
                decode(&buf).is_err(),
                "decode accepted a corrupt encoding (bit {bit} of byte {byte} flipped)"
            );
            buf[byte] ^= 1 << bit;
        }
    }
    // every strict truncation (the full-length prefix is the valid input)
    for cut in 0..valid.len() {
        assert!(
            decode(&valid[..cut]).is_err(),
            "decode accepted a truncation to {cut} of {} bytes",
            valid.len()
        );
    }
    // trailing garbage appended to a valid encoding
    let mut extended = valid.to_vec();
    extended.push(0);
    assert!(decode(&extended).is_err(), "decode accepted trailing garbage");
    // seeded random garbage of assorted lengths
    let mut rng = Rng::seed_from_u64(seed);
    for case in 0..garbage_cases {
        let len = rng.usize_below(valid.len() * 2 + 1);
        let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        if bytes == valid {
            continue; // astronomically unlikely, but be precise
        }
        assert!(
            decode(&bytes).is_err(),
            "decode accepted random garbage (case {case}, len {len})"
        );
    }
    // and the valid input itself still decodes
    assert!(decode(valid).is_ok(), "decode rejected the valid encoding");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutation_sweep_accepts_a_sound_codec() {
        // toy codec: 4-byte payload + 8-byte FNV-ish checksum, fixed length
        fn crc(bytes: &[u8]) -> u64 {
            let mut h: u64 = 0x9E37_79B9_7F4A_7C15;
            for &b in bytes {
                h = h.rotate_left(7) ^ b as u64;
                h = h.wrapping_mul(0x2545_F491_4F6C_DD1D);
            }
            h
        }
        fn decode(bytes: &[u8]) -> Result<u32, String> {
            if bytes.len() != 12 {
                return Err("bad length".into());
            }
            let (body, tail) = bytes.split_at(4);
            let mut c = [0u8; 8];
            c.copy_from_slice(tail);
            if crc(body) != u64::from_le_bytes(c) {
                return Err("bad crc".into());
            }
            Ok(u32::from_le_bytes([body[0], body[1], body[2], body[3]]))
        }
        let mut valid = 0xDEAD_BEEFu32.to_le_bytes().to_vec();
        valid.extend_from_slice(&crc(&valid).to_le_bytes());
        assert_codec_rejects_mutants(&valid, 64, 11, decode);
    }

    #[test]
    #[should_panic(expected = "decode accepted")]
    fn mutation_sweep_catches_a_lax_codec() {
        // a codec that ignores its checksum: the bit-flip sweep must object
        fn decode(bytes: &[u8]) -> Result<u32, String> {
            if bytes.len() < 4 {
                return Err("too short".into());
            }
            Ok(u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]))
        }
        assert_codec_rejects_mutants(&[1, 2, 3, 4, 5, 6], 8, 3, decode);
    }

    #[test]
    fn passing_property_runs_all_cases() {
        let gen = SeqLensGen { min_k: 1, max_k: 16, max_len: 1000 };
        forall(1, 100, &gen, |v| {
            if v.iter().all(|&x| x >= 1) {
                Ok(())
            } else {
                Err("zero length".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_shrunk_case() {
        let gen = SeqLensGen { min_k: 1, max_k: 32, max_len: 4096 };
        forall(2, 100, &gen, |v| {
            if v.iter().sum::<u32>() < 100 {
                Ok(())
            } else {
                Err(format!("sum too big: {}", v.iter().sum::<u32>()))
            }
        });
    }

    #[test]
    fn shrink_reduces_size() {
        let gen = SeqLensGen { min_k: 1, max_k: 8, max_len: 100 };
        let v = vec![50u32, 60, 70, 80];
        for s in gen.shrink(&v) {
            let smaller_len = s.len() < v.len();
            let smaller_vals = s.iter().sum::<u32>() < v.iter().sum::<u32>();
            assert!(smaller_len || smaller_vals, "{s:?} is not smaller than {v:?}");
        }
    }
}
