//! Schedule plan types shared by the heuristics, the exact solver, the cost
//! model and the simulator.  These are the D/P/B variables of the paper's
//! formulation in concrete form.

use crate::data::Sequence;

/// Sentinel for "distributed": the sequence is CP-sharded over all N ranks
/// (paper: ret[i] = -1, i.e. D_k = 1).
pub const DISTRIBUTED: i32 = -1;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SchedError {
    Infeasible { seq_idx: usize, len: u32, shard: u32, remain: i64 },
    RollbackFailed { rank: usize },
    TooLong { len: u32, cap: u64 },
    /// `CapacitySource::HbmDerived` found no positive token capacity: the
    /// HBM budget cannot hold the static state plus a single token.
    NoCapacity { hbm_bytes: u64, static_bytes: u64 },
    /// The physical cluster layout cannot host the requested dp×cp ranks
    /// (the run engine refuses to price an impossible topology).
    BadTopology { reason: String },
    /// The streaming data plane failed to produce a batch (spill I/O or
    /// checksum failure surfaced through `build_run_streamed`).
    Stream { reason: String },
}

impl std::fmt::Display for SchedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SchedError::Infeasible { seq_idx, len, shard, remain } => write!(
                f,
                "sequence {seq_idx} (len {len}) cannot fit: shard {shard} > min remaining bucket {remain}"
            ),
            SchedError::RollbackFailed { rank } => {
                write!(f, "roll-back failed: no local sequence left in bucket {rank}")
            }
            SchedError::TooLong { len, cap } => {
                write!(f, "sequence of length {len} exceeds total capacity C*N = {cap}")
            }
            SchedError::NoCapacity { hbm_bytes, static_bytes } => write!(
                f,
                "HBM budget of {hbm_bytes} bytes cannot hold the {static_bytes}-byte static state plus any activations"
            ),
            SchedError::BadTopology { reason } => {
                write!(f, "invalid cluster layout: {reason}")
            }
            SchedError::Stream { reason } => {
                write!(f, "streaming data plane error: {reason}")
            }
        }
    }
}

impl std::error::Error for SchedError {}

/// DACP result for one micro-batch: per-sequence assignment, in the
/// *original* order of the micro-batch's sequence list.
/// `assign[k] == DISTRIBUTED` ⇔ D_k = 1; otherwise P_{k, assign[k]} = 1.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DacpPlan {
    pub assign: Vec<i32>,
}

impl DacpPlan {
    pub fn all_distributed(k: usize) -> Self {
        DacpPlan { assign: vec![DISTRIBUTED; k] }
    }

    pub fn num_distributed(&self) -> usize {
        self.assign.iter().filter(|&&a| a == DISTRIBUTED).count()
    }

    /// Indices of local sequences on CP rank `j`.
    pub fn locals_of(&self, j: usize) -> impl Iterator<Item = usize> + '_ {
        // skrull-lint: allow(truncating-cast) -- a CP rank index, a GPU count nowhere near i32::MAX
        let j = j as i32;
        self.assign
            .iter()
            .enumerate()
            .filter(move |(_, &a)| a == j)
            .map(|(i, _)| i)
    }

    /// Indices of distributed sequences.
    pub fn distributed(&self) -> impl Iterator<Item = usize> + '_ {
        self.assign
            .iter()
            .enumerate()
            .filter(|(_, &a)| a == DISTRIBUTED)
            .map(|(i, _)| i)
    }

    /// Check Eq. 6 (completeness is structural) + Eq. 7 (memory): for every
    /// CP rank j:  Σ_local S_k + Σ_dist S_k/N  ≤  C.
    /// Shard sizes use ceiling division (a real CP implementation pads the
    /// sequence to a multiple of N).
    pub fn validate(&self, lens: &[u32], bucket_size: u32, n: usize) -> Result<(), SchedError> {
        assert_eq!(self.assign.len(), lens.len());
        let dist_tokens: u64 = self
            .distributed()
            .map(|i| (lens[i] as u64).div_ceil(n as u64))
            .sum();
        for j in 0..n {
            let local: u64 = self.locals_of(j).map(|i| lens[i] as u64).sum();
            if local + dist_tokens > bucket_size as u64 {
                return Err(SchedError::Infeasible {
                    seq_idx: j,
                    // skrull-lint: allow(truncating-cast) -- diagnostic error-report field; token counts are bounded by the capacity clamp
                    len: (local + dist_tokens) as u32,
                    // skrull-lint: allow(truncating-cast) -- diagnostic error-report field; token counts are bounded by the capacity clamp
                    shard: dist_tokens as u32,
                    remain: bucket_size as i64 - local as i64,
                });
            }
        }
        for (k, &a) in self.assign.iter().enumerate() {
            if a != DISTRIBUTED && (a < 0 || a as usize >= n) {
                return Err(SchedError::Infeasible {
                    seq_idx: k,
                    len: lens[k],
                    shard: 0,
                    remain: -1,
                });
            }
        }
        Ok(())
    }
}

/// One scheduled micro-batch: its sequences + the DACP placement.
/// `PartialEq` backs the fast-path-vs-reference oracle tests.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MicroBatch {
    pub seqs: Vec<Sequence>,
    pub plan: DacpPlan,
}

impl MicroBatch {
    pub fn lens(&self) -> Vec<u32> {
        self.seqs.iter().map(|s| s.len).collect()
    }

    pub fn total_tokens(&self) -> u64 {
        self.seqs.iter().map(|s| s.len as u64).sum()
    }

    /// Tokens each CP rank must actually execute for this micro-batch: its
    /// local sequences plus its ceil(1/N) share of every distributed
    /// sequence.  The single source of the static-bucket fill rule — both
    /// the run engine's padding accounting and memplan's peak-memory
    /// simulation build on it, so they cannot drift apart.  Allocation-free
    /// (the run engine walks it once per micro-batch per iteration); use
    /// [`rank_used_tokens`] when a `Vec` is more convenient.
    ///
    /// [`rank_used_tokens`]: MicroBatch::rank_used_tokens
    pub fn rank_used_tokens_iter(&self, cp: usize) -> impl Iterator<Item = u64> + '_ {
        let cp = cp.max(1);
        let dist_share: u64 = self
            .plan
            .distributed()
            .map(|i| (self.seqs[i].len as u64).div_ceil(cp as u64))
            .sum();
        (0..cp).map(move |j| {
            let local: u64 = self.plan.locals_of(j).map(|i| self.seqs[i].len as u64).sum();
            local + dist_share
        })
    }

    /// [`rank_used_tokens_iter`] collected into a `Vec`.
    ///
    /// [`rank_used_tokens_iter`]: MicroBatch::rank_used_tokens_iter
    pub fn rank_used_tokens(&self, cp: usize) -> Vec<u64> {
        self.rank_used_tokens_iter(cp).collect()
    }
}

/// All micro-batches of one DP rank for one iteration (inner Vec = the
/// gradient-accumulation steps), i.e. one row of the B_{kij} matrix.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RankSchedule {
    pub micro_batches: Vec<MicroBatch>,
}

/// The full iteration schedule across DP ranks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IterationSchedule {
    pub ranks: Vec<RankSchedule>,
}

impl IterationSchedule {
    /// Every sequence id must appear exactly once (Eq. 9).
    pub fn assigned_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .ranks
            .iter()
            .flat_map(|r| r.micro_batches.iter())
            .flat_map(|mb| mb.seqs.iter().map(|s| s.id))
            .collect();
        ids.sort_unstable();
        ids
    }

    pub fn num_micro_batches(&self) -> usize {
        self.ranks.iter().map(|r| r.micro_batches.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_accepts_feasible_plan() {
        // lens [10, 20, 100], C=60, N=2; distribute the 100, split the rest
        let plan = DacpPlan { assign: vec![0, 1, DISTRIBUTED] };
        plan.validate(&[10, 20, 100], 70, 2).unwrap();
    }

    #[test]
    fn validate_rejects_memory_violation() {
        // rank 0 holds 10 local + 50 shard = 60 > C=55
        let plan = DacpPlan { assign: vec![0, 1, DISTRIBUTED] };
        assert!(plan.validate(&[10, 20, 100], 55, 2).is_err());
    }

    #[test]
    fn validate_uses_ceiling_shards() {
        // len 101 over N=2 → 51 per rank, not 50
        let plan = DacpPlan { assign: vec![DISTRIBUTED] };
        assert!(plan.validate(&[101], 50, 2).is_err());
        plan.validate(&[101], 51, 2).unwrap();
    }

    #[test]
    fn validate_rejects_out_of_range_rank() {
        let plan = DacpPlan { assign: vec![5] };
        assert!(plan.validate(&[10], 100, 2).is_err());
    }

    #[test]
    fn rank_used_tokens_splits_locals_and_ceil_shares() {
        // lens [100, 50, 64], rank0 local 100, rank1 local 50, 64 sharded
        // over cp=2 → ceil(64/2)=32 per rank
        let mb = MicroBatch {
            seqs: vec![
                Sequence { id: 0, len: 100 },
                Sequence { id: 1, len: 50 },
                Sequence { id: 2, len: 64 },
            ],
            plan: DacpPlan { assign: vec![0, 1, DISTRIBUTED] },
        };
        assert_eq!(mb.rank_used_tokens(2), vec![132, 82]);
        // odd shard rounds up on every rank
        let mb = MicroBatch {
            seqs: vec![Sequence { id: 0, len: 101 }],
            plan: DacpPlan { assign: vec![DISTRIBUTED] },
        };
        assert_eq!(mb.rank_used_tokens(2), vec![51, 51]);
        // the allocation-free iterator is the same rule, element for element
        assert_eq!(mb.rank_used_tokens_iter(2).collect::<Vec<_>>(), mb.rank_used_tokens(2));
        assert_eq!(mb.rank_used_tokens_iter(3).collect::<Vec<_>>(), mb.rank_used_tokens(3));
    }

    #[test]
    fn locals_and_distributed_partition() {
        let plan = DacpPlan { assign: vec![0, DISTRIBUTED, 1, 0] };
        assert_eq!(plan.locals_of(0).collect::<Vec<_>>(), vec![0, 3]);
        assert_eq!(plan.locals_of(1).collect::<Vec<_>>(), vec![2]);
        assert_eq!(plan.distributed().collect::<Vec<_>>(), vec![1]);
        assert_eq!(plan.num_distributed(), 1);
    }
}
