//! The paper's contribution: dynamic data scheduling for Long-SFT.
//!
//! * `dacp` — Distributed-Aware Context Parallelism (Algorithm 1 + 3):
//!   fine-grained, within a micro-batch.
//! * `gds` — Global Data Scheduling (Algorithm 2): coarse-grained, from the
//!   global batch to per-DP-rank micro-batches.
//! * `binpack` — FLOPs-balancing bin packing used by GDS step (i).
//! * `baseline` — the comparators of Fig. 3 (DeepSpeed-like, DACP-only,
//!   LongAlign sorted batching).
//! * `solver` — exact branch-and-bound DACP for heuristic-gap ablations.
//! * `shard` — shared-nothing shard pool behind `GdsConfig::shards`:
//!   persistent per-core workers owning their rank arenas, fed over
//!   bounded SPSC queues, byte-identical to the single-shard path.

pub mod baseline;
pub mod binpack;
pub mod dacp;
pub mod dispatch;
pub mod gds;
pub mod plan;
pub mod shard;
pub mod solver;

pub use dispatch::schedule_policy;
pub use plan::{DacpPlan, IterationSchedule, MicroBatch, RankSchedule, SchedError};
