//! Comparator schedulers for Fig. 3 and the related-work discussion.
//!
//! * `deepspeed` — the paper's baseline: DeepSpeed + ZeRO-2 with CP sized
//!   for the longest sequence.  No data scheduling: sequences go to DP
//!   ranks round-robin in arrival order, one sequence per micro-batch
//!   (Long-SFT practice when the length spread is extreme), and *every*
//!   sequence is CP-sharded across all N ranks.
//! * `deepspeed_packed` — a stronger baseline that greedily packs arrival-
//!   order sequences under the token cap (still all-sharded, no balance).
//! * `dacp_only` — Fig. 3's step-by-step lane: baseline batching, but DACP
//!   placement inside each micro-batch.
//! * `sorted_batching` — LongAlign-style: sort the global batch, pack
//!   contiguous chunks (efficient but equivalence-breaking; Section 6).

use crate::data::Sequence;
use crate::perfmodel::FlopsModel;
use crate::scheduler::dacp::{self, DacpConfig};
use crate::scheduler::plan::{DacpPlan, IterationSchedule, MicroBatch, RankSchedule, SchedError};

/// Round-robin sequences over DP ranks in arrival order.
fn round_robin(batch: &[Sequence], dp: usize) -> Vec<Vec<Sequence>> {
    let mut bins: Vec<Vec<Sequence>> = vec![Vec::new(); dp];
    for (i, &s) in batch.iter().enumerate() {
        bins[i % dp].push(s);
    }
    bins
}

/// DeepSpeed-like baseline: 1 sequence per micro-batch, everything sharded.
pub fn deepspeed(batch: &[Sequence], dp: usize, _cp: usize) -> IterationSchedule {
    let ranks = round_robin(batch, dp)
        .into_iter()
        .map(|subset| RankSchedule {
            micro_batches: subset
                .into_iter()
                .map(|s| MicroBatch { seqs: vec![s], plan: DacpPlan::all_distributed(1) })
                .collect(),
        })
        .collect();
    IterationSchedule { ranks }
}

/// DeepSpeed + naive packing: fill micro-batches in arrival order up to the
/// C·N token cap; still no placement decisions (all sharded).
pub fn deepspeed_packed(
    batch: &[Sequence],
    dp: usize,
    cp: usize,
    bucket_size: u32,
) -> IterationSchedule {
    let cap = bucket_size as u64 * cp as u64;
    let ranks = round_robin(batch, dp)
        .into_iter()
        .map(|subset| {
            let mut mbs: Vec<Vec<Sequence>> = Vec::new();
            let mut cur: Vec<Sequence> = Vec::new();
            let mut cur_tokens = 0u64;
            for s in subset {
                if !cur.is_empty() && cur_tokens + s.len as u64 > cap {
                    mbs.push(std::mem::take(&mut cur));
                    cur_tokens = 0;
                }
                cur_tokens += s.len as u64;
                cur.push(s);
            }
            if !cur.is_empty() {
                mbs.push(cur);
            }
            RankSchedule {
                micro_batches: mbs
                    .into_iter()
                    .map(|seqs| {
                        let k = seqs.len();
                        MicroBatch { seqs, plan: DacpPlan::all_distributed(k) }
                    })
                    .collect(),
            }
        })
        .collect();
    IterationSchedule { ranks }
}

/// Step-by-step lane 2: baseline (packed) batching, DACP placement inside.
pub fn dacp_only(
    batch: &[Sequence],
    dp: usize,
    cp: usize,
    bucket_size: u32,
    flops: &FlopsModel,
) -> Result<IterationSchedule, SchedError> {
    let base = deepspeed_packed(batch, dp, cp, bucket_size);
    let cfg = DacpConfig::new(bucket_size, cp);
    let ranks = base
        .ranks
        .into_iter()
        .map(|r| {
            let micro_batches = r
                .micro_batches
                .into_iter()
                .map(|mb| {
                    let lens = mb.lens();
                    dacp::schedule(&lens, &cfg, flops).map(|plan| MicroBatch { seqs: mb.seqs, plan })
                })
                .collect::<Result<Vec<_>, _>>()?;
            Ok(RankSchedule { micro_batches })
        })
        .collect::<Result<Vec<_>, SchedError>>()?;
    Ok(IterationSchedule { ranks })
}

/// LongAlign-style sorted batching: sort the whole batch, pack contiguous
/// runs under the cap, deal micro-batches round-robin over DP ranks.
pub fn sorted_batching(
    batch: &[Sequence],
    dp: usize,
    cp: usize,
    bucket_size: u32,
) -> IterationSchedule {
    let cap = bucket_size as u64 * cp as u64;
    let mut sorted: Vec<Sequence> = batch.to_vec();
    sorted.sort_by_key(|s| s.len);
    let mut mbs: Vec<Vec<Sequence>> = Vec::new();
    let mut cur: Vec<Sequence> = Vec::new();
    let mut cur_tokens = 0u64;
    for s in sorted {
        if !cur.is_empty() && cur_tokens + s.len as u64 > cap {
            mbs.push(std::mem::take(&mut cur));
            cur_tokens = 0;
        }
        cur_tokens += s.len as u64;
        cur.push(s);
    }
    if !cur.is_empty() {
        mbs.push(cur);
    }
    let mut ranks: Vec<RankSchedule> = (0..dp).map(|_| RankSchedule::default()).collect();
    for (i, seqs) in mbs.into_iter().enumerate() {
        let k = seqs.len();
        ranks[i % dp]
            .micro_batches
            .push(MicroBatch { seqs, plan: DacpPlan::all_distributed(k) });
    }
    IterationSchedule { ranks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;

    fn fm() -> FlopsModel {
        FlopsModel::new(&ModelSpec::qwen2_5_0_5b())
    }

    fn seqs(lens: &[u32]) -> Vec<Sequence> {
        lens.iter()
            .enumerate()
            .map(|(i, &len)| Sequence { id: i as u64, len })
            .collect()
    }

    #[test]
    fn deepspeed_one_seq_per_microbatch_all_sharded() {
        let batch = seqs(&[100, 200, 300, 400, 500]);
        let sched = deepspeed(&batch, 2, 8);
        assert_eq!(sched.ranks[0].micro_batches.len(), 3);
        assert_eq!(sched.ranks[1].micro_batches.len(), 2);
        for r in &sched.ranks {
            for mb in &r.micro_batches {
                assert_eq!(mb.seqs.len(), 1);
                assert_eq!(mb.plan.num_distributed(), 1);
            }
        }
        assert_eq!(sched.assigned_ids(), (0..5).collect::<Vec<u64>>());
    }

    #[test]
    fn packed_baseline_respects_cap_and_order() {
        let batch = seqs(&[600, 600, 600, 600]);
        // dp=1, cap = 1000*1 → pairs of 600 overflow: 1 per mb
        let sched = deepspeed_packed(&batch, 1, 1, 1000);
        assert_eq!(sched.ranks[0].micro_batches.len(), 4);
        // cap 1300 → 600+600=1200 fits, 2 per mb
        let sched = deepspeed_packed(&batch, 1, 1, 1300);
        assert_eq!(sched.ranks[0].micro_batches.len(), 2);
        let mb0 = &sched.ranks[0].micro_batches[0];
        assert!(mb0.total_tokens() <= 1300);
    }

    #[test]
    fn dacp_only_localizes_short_sequences() {
        let batch = seqs(&[100, 200, 300, 400]);
        let sched = dacp_only(&batch, 1, 8, 26 * 1024, &fm()).unwrap();
        for r in &sched.ranks {
            for mb in &r.micro_batches {
                assert_eq!(mb.plan.num_distributed(), 0, "shorts must stay local");
            }
        }
        assert_eq!(sched.assigned_ids(), (0..4).collect::<Vec<u64>>());
    }

    #[test]
    fn sorted_batching_groups_similar_lengths() {
        let batch = seqs(&[10_000, 50, 9_000, 60, 8_000, 70]);
        let sched = sorted_batching(&batch, 2, 8, 26 * 1024);
        assert_eq!(sched.assigned_ids(), (0..6).collect::<Vec<u64>>());
        // first micro-batch (shortest-first) holds the short ones
        let first = &sched.ranks[0].micro_batches[0];
        assert!(first.seqs.iter().any(|s| s.len <= 70));
    }

    #[test]
    fn all_baselines_cover_every_sequence() {
        let batch = seqs(&[5, 10, 2000, 40_000, 17, 900, 33_000, 120]);
        for sched in [
            deepspeed(&batch, 4, 8),
            deepspeed_packed(&batch, 4, 8, 26 * 1024),
            dacp_only(&batch, 4, 8, 26 * 1024, &fm()).unwrap(),
            sorted_batching(&batch, 4, 8, 26 * 1024),
        ] {
            assert_eq!(sched.assigned_ids(), (0..8).collect::<Vec<u64>>());
        }
    }
}
