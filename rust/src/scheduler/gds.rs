//! Algorithm 2: Global Data Scheduling.
//!
//! Principles (Section 4.3.2): (i) balance FLOPs across DP ranks via
//! bin-packing, (ii) pair long and short sequences by sorting then slicing
//! with a stride ("Subset[j::init]"), (iii) use as few micro-batches as
//! memory allows, growing the count when the token cap or DACP scheduling
//! fails (the GDS-level roll-back).
//!
//! Scope is the global batch — the largest scheduling scope that keeps
//! Adam/AdamW mathematically equivalent (Section 4.2).

use crate::data::Sequence;
use crate::perfmodel::FlopsModel;
use crate::scheduler::binpack;
use crate::scheduler::dacp::{self, DacpConfig};
use crate::scheduler::plan::{IterationSchedule, MicroBatch, RankSchedule, SchedError};

#[derive(Clone, Debug)]
pub struct GdsConfig {
    pub bucket_size: u32,
    pub cp: usize,
    pub dp: usize,
    pub rollback_largest: bool,
    /// Disable the long/short interleaving (ablation): contiguous chunks
    /// of the sorted subset instead of strided slices.
    pub interleave: bool,
}

impl GdsConfig {
    pub fn new(bucket_size: u32, cp: usize, dp: usize) -> Self {
        GdsConfig { bucket_size, cp, dp, rollback_largest: true, interleave: true }
    }

    pub fn dacp(&self) -> DacpConfig {
        let mut c = DacpConfig::new(self.bucket_size, self.cp);
        c.rollback_largest = self.rollback_largest;
        c
    }
}

/// GDS + DACP + the cost-aware refinement pass (our extension — see
/// scheduler::dacp::refine and the `ablations` bench).  Guarantees the
/// plan is never worse than Algorithm 1's under the cost model, and in
/// particular restores bigger-bucket monotonicity that the avoid-sharding
/// principle alone violates.
pub fn schedule_refined(
    global_batch: &[Sequence],
    cfg: &GdsConfig,
    cost: &crate::perfmodel::CostModel,
) -> Result<IterationSchedule, SchedError> {
    let mut sched = schedule(global_batch, cfg, &cost.flops)?;
    let dcfg = cfg.dacp();
    for rank in &mut sched.ranks {
        for mb in &mut rank.micro_batches {
            let lens = mb.lens();
            mb.plan = crate::scheduler::dacp::refine_multistart(&mb.plan, &lens, &dcfg, cost);
        }
    }
    Ok(sched)
}

/// Schedule one DP rank's subset (Algorithm 2 body).  `subset` is that
/// rank's sequences in any order.
pub fn schedule_rank(
    subset: &[Sequence],
    cfg: &GdsConfig,
    flops: &FlopsModel,
) -> Result<RankSchedule, SchedError> {
    if subset.is_empty() {
        return Ok(RankSchedule::default());
    }
    let cap = cfg.bucket_size as u64 * cfg.cp as u64;
    let total: u64 = subset.iter().map(|s| s.len as u64).sum();
    for s in subset {
        if s.len as u64 > cap {
            return Err(SchedError::TooLong { len: s.len, cap });
        }
    }

    // line 3: ascending sort
    let mut sorted: Vec<Sequence> = subset.to_vec();
    sorted.sort_by_key(|s| s.len);

    // line 2: start from the memory lower bound on micro-batch count
    let min_mbs = (total.div_ceil(cap) as usize).max(1);
    let dacp_cfg = cfg.dacp();

    'outer: for n_mb in min_mbs..=sorted.len() {
        let mut mbs: Vec<MicroBatch> = Vec::with_capacity(n_mb);
        for j in 0..n_mb {
            // line 7: Subset[j::n_mb] pairs long and short sequences
            let seqs: Vec<Sequence> = if cfg.interleave {
                sorted.iter().skip(j).step_by(n_mb).copied().collect()
            } else {
                let chunk = sorted.len().div_ceil(n_mb);
                sorted.iter().skip(j * chunk).take(chunk).copied().collect()
            };
            if seqs.is_empty() {
                continue;
            }
            let tokens: u64 = seqs.iter().map(|s| s.len as u64).sum();
            // line 8: token cap or DACP failure → retry with more MBs
            if tokens > cap {
                continue 'outer;
            }
            let lens: Vec<u32> = seqs.iter().map(|s| s.len).collect();
            match dacp::schedule(&lens, &dacp_cfg, flops) {
                Ok(plan) => mbs.push(MicroBatch { seqs, plan }),
                Err(_) => continue 'outer,
            }
        }
        return Ok(RankSchedule { micro_batches: mbs });
    }

    // n_mb == len means one sequence per micro-batch; with S ≤ C·N that
    // must be schedulable, so reaching here is a genuine capacity error.
    Err(SchedError::TooLong {
        len: sorted.last().map(|s| s.len).unwrap_or(0),
        cap,
    })
}

/// Full GDS: bin-pack the global batch over DP ranks by FLOPs
/// (Algorithm 2, line 1), then schedule each rank.
pub fn schedule(
    global_batch: &[Sequence],
    cfg: &GdsConfig,
    flops: &FlopsModel,
) -> Result<IterationSchedule, SchedError> {
    let weighted: Vec<(Sequence, f64)> = global_batch
        .iter()
        .map(|&s| (s, flops.seq(s.len)))
        .collect();
    let bins = binpack::balance(&weighted, cfg.dp);
    let ranks = bins
        .iter()
        .map(|subset| schedule_rank(subset, cfg, flops))
        .collect::<Result<Vec<_>, _>>()?;
    Ok(IterationSchedule { ranks })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;
    use crate::util::proptest::{forall, SeqLensGen};

    fn fm() -> FlopsModel {
        FlopsModel::new(&ModelSpec::qwen2_5_0_5b())
    }

    fn seqs(lens: &[u32]) -> Vec<Sequence> {
        lens.iter()
            .enumerate()
            .map(|(i, &len)| Sequence { id: i as u64, len })
            .collect()
    }

    #[test]
    fn every_sequence_assigned_exactly_once() {
        let batch = seqs(&[100, 5000, 250, 30_000, 90, 800, 12_000, 400]);
        let cfg = GdsConfig::new(26 * 1024, 8, 4);
        let sched = schedule(&batch, &cfg, &fm()).unwrap();
        assert_eq!(sched.assigned_ids(), (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn micro_batches_respect_token_cap() {
        let batch = seqs(&[40_000; 12]);
        let cfg = GdsConfig::new(26 * 1024, 8, 4);
        let sched = schedule(&batch, &cfg, &fm()).unwrap();
        let cap = cfg.bucket_size as u64 * cfg.cp as u64;
        for r in &sched.ranks {
            for mb in &r.micro_batches {
                assert!(mb.total_tokens() <= cap);
                mb.plan
                    .validate(&mb.lens(), cfg.bucket_size, cfg.cp)
                    .unwrap();
            }
        }
    }

    #[test]
    fn pairing_spreads_long_sequences() {
        // 2 long + 6 short on one rank, 2 micro-batches: interleaving must
        // not put both longs in the same micro-batch.
        let subset = seqs(&[30_000, 30_000, 100, 100, 100, 100, 100, 100]);
        let mut cfg = GdsConfig::new(26 * 1024, 8, 1);
        cfg.interleave = true;
        let rs = schedule_rank(&subset, &cfg, &fm()).unwrap();
        if rs.micro_batches.len() >= 2 {
            let longs_per_mb: Vec<usize> = rs
                .micro_batches
                .iter()
                .map(|mb| mb.seqs.iter().filter(|s| s.len >= 30_000).count())
                .collect();
            assert!(longs_per_mb.iter().all(|&c| c <= 1), "{longs_per_mb:?}");
        }
    }

    #[test]
    fn grows_micro_batch_count_under_memory_pressure() {
        // total 100K tokens, cap C·N = 16K → at least 7 micro-batches
        let subset = seqs(&[10_000; 10]);
        let cfg = GdsConfig::new(2 * 1024, 8, 1);
        let rs = schedule_rank(&subset, &cfg, &fm()).unwrap();
        assert!(rs.micro_batches.len() >= 7, "{}", rs.micro_batches.len());
        let cap = cfg.bucket_size as u64 * cfg.cp as u64;
        for mb in &rs.micro_batches {
            assert!(mb.total_tokens() <= cap);
        }
    }

    #[test]
    fn too_long_sequence_errors() {
        let batch = seqs(&[300_000]);
        let cfg = GdsConfig::new(26 * 1024, 8, 4);
        assert!(matches!(
            schedule(&batch, &cfg, &fm()),
            Err(SchedError::TooLong { .. })
        ));
    }

    #[test]
    fn empty_batch_is_fine() {
        let cfg = GdsConfig::new(1024, 8, 4);
        let sched = schedule(&[], &cfg, &fm()).unwrap();
        assert_eq!(sched.ranks.len(), 4);
        assert_eq!(sched.num_micro_batches(), 0);
    }

    #[test]
    fn schedule_refined_keeps_invariants_and_improves() {
        use crate::perfmodel::CostModel;
        let cost = CostModel::paper_default(&ModelSpec::qwen2_5_0_5b());
        let batch = seqs(&[25_000, 300, 400, 500, 14_000, 100, 18_000, 900]);
        let cfg = GdsConfig::new(26 * 1024, 4, 2);
        let plain = schedule(&batch, &cfg, &cost.flops).unwrap();
        let refined = schedule_refined(&batch, &cfg, &cost).unwrap();
        assert_eq!(refined.assigned_ids(), plain.assigned_ids());
        let total = |s: &IterationSchedule| -> f64 {
            s.ranks
                .iter()
                .map(|r| {
                    r.micro_batches
                        .iter()
                        .map(|mb| cost.tdacp(&mb.lens(), &mb.plan, cfg.cp))
                        .sum::<f64>()
                })
                .fold(0.0, f64::max)
        };
        assert!(total(&refined) <= total(&plain) * (1.0 + 1e-9));
        for r in &refined.ranks {
            for mb in &r.micro_batches {
                mb.plan.validate(&mb.lens(), cfg.bucket_size, cfg.cp).unwrap();
            }
        }
    }

    #[test]
    fn property_completeness_and_memory() {
        // Eq. 9 (exactly once) + Eq. 7/10 (memory) on random workloads.
        let gen = SeqLensGen { min_k: 1, max_k: 64, max_len: 100_000 };
        let flops = fm();
        forall(0x6D5, 200, &gen, |lens| {
            let batch = seqs(lens);
            let cfg = GdsConfig::new(26 * 1024, 8, 4);
            match schedule(&batch, &cfg, &flops) {
                Err(SchedError::TooLong { .. }) => Ok(()), // only when a seq > C·N
                Err(e) => Err(format!("unexpected: {e}")),
                Ok(sched) => {
                    let mut ids = sched.assigned_ids();
                    ids.dedup();
                    if ids.len() != lens.len() {
                        return Err(format!("{} ids for {} seqs", ids.len(), lens.len()));
                    }
                    for r in &sched.ranks {
                        for mb in &r.micro_batches {
                            mb.plan
                                .validate(&mb.lens(), cfg.bucket_size, cfg.cp)
                                .map_err(|e| e.to_string())?;
                        }
                    }
                    Ok(())
                }
            }
        });
    }
}
