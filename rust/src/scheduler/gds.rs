//! Algorithm 2: Global Data Scheduling.
//!
//! Principles (Section 4.3.2): (i) balance FLOPs across DP ranks via
//! bin-packing, (ii) pair long and short sequences by sorting then slicing
//! with a stride ("Subset[j::init]"), (iii) use as few micro-batches as
//! memory allows, growing the count when the token cap or DACP scheduling
//! fails (the GDS-level roll-back).
//!
//! Scope is the global batch — the largest scheduling scope that keeps
//! Adam/AdamW mathematically equivalent (Section 4.2).
//!
//! Two implementations live here:
//!
//! * the **fast path** ([`schedule`] / [`schedule_with_ctx`]) — an
//!   allocation-lean, incremental, parallel engine: a reusable [`SchedCtx`]
//!   scratch arena recycles the sort/stride/DACP buffers across the
//!   micro-batch-count retry loop, an O(K) strided token-sum precheck
//!   rejects infeasible counts before any DACP call, a galloping search
//!   (see [`MbSearch`]) skips over the token-infeasible prefix of counts,
//!   and the work fans out over scoped threads (util::par) twice — across
//!   DP ranks, and across a large candidate's independent per-subset DACP
//!   runs;
//! * the **reference path** ([`schedule_reference`]) — the direct
//!   transcription of Algorithm 2 that the fast path is oracle-tested
//!   against (`fast path ≡ reference`, byte for byte, on random
//!   workloads; see the property tests below and
//!   rust/tests/scheduler_integration.rs).

use crate::data::Sequence;
use crate::perfmodel::FlopsModel;
use crate::scheduler::binpack;
use crate::scheduler::dacp::{self, DacpConfig, DacpScratch};
use crate::scheduler::plan::{DacpPlan, IterationSchedule, MicroBatch, RankSchedule, SchedError};
use crate::util::par;

/// How `schedule_rank` searches for the smallest feasible micro-batch
/// count.  Both strategies run the same O(K) token precheck per candidate;
/// they differ only in which candidates they visit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MbSearch {
    /// Exponential (1, 2, 4, …) advance to bracket the first
    /// token-feasible count, then binary search inside the bracket.
    /// Assumes the max strided-subset token sum is non-increasing in the
    /// count — which holds exactly on doubling chains (a stride-2b subset
    /// is a sub-multiset of a stride-b subset) and empirically on every
    /// random workload the oracle tests throw at it.  The assumption is
    /// provably FALSE for the chunked ablation mode (ceil(K/n) steps make
    /// the max chunk sum non-monotone, e.g. sorted lens
    /// [0,5,5,9,12,15,16,32,41,49] with cap 89: feasible at n=4,
    /// infeasible at n=5..9), so `interleave = false` always takes the
    /// linear scan regardless of this setting.  After the first feasible
    /// count, DACP failures advance linearly, exactly like the reference.
    Gallop,
    /// Plain linear scan from the memory lower bound — reference-exact by
    /// construction, kept as the fallback for pathological length
    /// profiles.
    Linear,
}

#[derive(Clone, Debug)]
pub struct GdsConfig {
    pub bucket_size: u32,
    pub cp: usize,
    pub dp: usize,
    pub rollback_largest: bool,
    /// Disable the long/short interleaving (ablation): contiguous chunks
    /// of the sorted subset instead of strided slices.
    pub interleave: bool,
    /// Fan DP ranks (and refinement micro-batches) out over scoped
    /// threads.  Output is byte-identical either way.
    pub parallel: bool,
    /// Micro-batch-count search strategy (fast path only).
    pub search: MbSearch,
    /// Shared-nothing scheduler shards (≥ 2 routes [`schedule_with_ctx`]
    /// through the persistent shard pool in scheduler::shard; 1 keeps the
    /// in-process path).  Output is byte-identical for every value.
    pub shards: usize,
    /// Incremental re-scheduling: when batch composition repeats between
    /// iterations, replay the previous LPT partition and per-rank
    /// micro-batch structure instead of re-deriving them.  Reuse is gated
    /// on *exact* length equality, so the output is byte-identical to a
    /// fresh schedule by construction.
    pub incremental: bool,
}

impl GdsConfig {
    pub fn new(bucket_size: u32, cp: usize, dp: usize) -> Self {
        GdsConfig {
            bucket_size,
            cp,
            dp,
            rollback_largest: true,
            interleave: true,
            parallel: true,
            search: MbSearch::Gallop,
            shards: 1,
            incremental: false,
        }
    }

    pub fn dacp(&self) -> DacpConfig {
        let mut c = DacpConfig::new(self.bucket_size, self.cp);
        c.rollback_largest = self.rollback_largest;
        c
    }
}

/// Per-rank scratch arena: every buffer the micro-batch-count retry loop
/// needs, reused across candidates, ranks (when serial) and iterations.
/// Struct-of-arrays throughout — sequence metadata lives in flat `u32`/
/// `u64`/`i32` arrays (lens, packed sort keys, concatenated assignments)
/// so the steady state performs zero heap allocations beyond the returned
/// schedule itself.
#[derive(Debug, Default)]
pub struct RankCtx {
    /// the rank's subset, ascending by length
    sorted: Vec<Sequence>,
    /// packed `(len << 32) | original_index` sort keys: strictly distinct,
    /// so the allocation-free unstable sort reproduces the reference's
    /// stable sort-by-length byte for byte
    keys: Vec<u64>,
    /// lengths of `sorted` (contiguous, cache-friendly for the prechecks)
    lens: Vec<u32>,
    /// prefix token sums of `lens` (chunked precheck)
    prefix: Vec<u64>,
    /// per-subset token sums for one candidate count (strided precheck)
    subset_tokens: Vec<u64>,
    /// lengths of the subset currently handed to DACP
    lens_buf: Vec<u32>,
    /// flat plan arena: accepted per-subset assignments for the candidate
    /// under trial, concatenated in subset order …
    plan_assign: Vec<i32>,
    /// … with `plan_offsets[j]..plan_offsets[j+1]` delimiting subset j
    plan_offsets: Vec<usize>,
    /// DACP's own working buffers
    dacp: DacpScratch,
    /// per-subset length buffers for the parallel inner DACP fan-out
    lens_pool: Vec<Vec<u32>>,
    /// per-subset DACP scratches for the parallel inner fan-out
    dacp_pool: Vec<DacpScratch>,
    /// previous successful solution, for incremental re-scheduling
    cache: RankCache,
    /// how many times the incremental cache short-circuited the search
    cache_hits: u64,
}

/// A rank's previous solution, cached for incremental re-scheduling.  A
/// hit requires the *exact* sorted length multiset plus every config knob
/// that can influence the solution to match; the post-sort schedule is a
/// pure function of those, so replaying the cached micro-batch structure
/// over the freshly sorted sequences is byte-identical to a fresh solve.
#[derive(Debug, Default)]
struct RankCache {
    valid: bool,
    bucket_size: u32,
    cp: usize,
    interleave: bool,
    rollback_largest: bool,
    flops: Option<FlopsModel>,
    /// sorted lengths the cached solution was derived from
    lens: Vec<u32>,
    /// accepted micro-batch count
    n_mb: usize,
    /// concatenated per-subset assignments (same layout as the plan arena)
    assign: Vec<i32>,
    offsets: Vec<usize>,
}

impl RankCache {
    fn matches(&self, cfg: &GdsConfig, flops: &FlopsModel, sorted_lens: &[u32]) -> bool {
        self.valid
            && self.bucket_size == cfg.bucket_size
            && self.cp == cfg.cp
            && self.interleave == cfg.interleave
            && self.rollback_largest == cfg.rollback_largest
            && self.flops.as_ref() == Some(flops)
            && self.lens == sorted_lens
    }
}

/// Below this many sequences on a rank, the inner per-subset DACP fan-out
/// is not worth the thread spawns; the candidate runs serially.
const PAR_SUBSET_MIN_SEQS: usize = 512;

/// Scratch arena for a full [`schedule_with_ctx`] call: per-rank contexts,
/// the weighted-sequence and bin arenas the bin-packer consumes, the
/// incremental-partition cache, and (lazily) the shard worker pool.  Hold
/// one per loader/caller and reuse it every iteration.
#[derive(Debug, Default)]
pub struct SchedCtx {
    ranks: Vec<RankCtx>,
    weighted: Vec<(Sequence, f64)>,
    /// per-DP-rank subset arena (recycled bin `Vec`s)
    bins: Vec<Vec<Sequence>>,
    /// batch positions routed to each bin, in LPT placement order — the
    /// incremental mode replays this to reproduce the exact partition
    placed: Vec<Vec<usize>>,
    binpack: binpack::BinpackScratch,
    /// batch lengths the cached partition was derived from
    prev_lens: Vec<u32>,
    prev_dp: usize,
    prev_flops: Option<FlopsModel>,
    prev_valid: bool,
    partition_reuses: u64,
    /// persistent shared-nothing worker pool (created on first sharded
    /// call, recreated when the shard count or rank capacity changes)
    pool: Option<crate::scheduler::shard::ShardPool>,
}

impl SchedCtx {
    fn ensure_ranks(&mut self, dp: usize) {
        if self.ranks.len() < dp {
            self.ranks.resize_with(dp, RankCtx::default);
        }
    }

    /// How many calls replayed the previous LPT partition instead of
    /// re-running the bin-packer (incremental mode only).
    pub fn partition_reuses(&self) -> u64 {
        self.partition_reuses
    }

    /// How many per-rank solves were short-circuited by the incremental
    /// cache.  Counts the in-process paths; shard workers keep their
    /// caches (and counters) thread-local, so run shard-count 1 when a
    /// test needs to observe this.
    pub fn rank_cache_hits(&self) -> u64 {
        self.ranks.iter().map(|r| r.cache_hits).sum()
    }
}

/// Max strided-subset token total `max_j Σ Subset[j::n_mb]` ≤ cap, in one
/// pass over the sorted lengths (element i belongs to subset i mod n_mb).
fn interleaved_feasible(lens: &[u32], n_mb: usize, cap: u64, sums: &mut Vec<u64>) -> bool {
    sums.clear();
    sums.resize(n_mb, 0);
    for (i, &l) in lens.iter().enumerate() {
        sums[i % n_mb] += l as u64;
    }
    sums.iter().all(|&s| s <= cap)
}

/// Chunked (ablation mode) counterpart over precomputed prefix sums.
fn chunked_feasible(prefix: &[u64], n_mb: usize, cap: u64) -> bool {
    let len = prefix.len() - 1;
    let chunk = len.div_ceil(n_mb);
    let mut start = 0;
    while start < len {
        let end = (start + chunk).min(len);
        if prefix[end] - prefix[start] > cap {
            return false;
        }
        start = end;
    }
    true
}

fn token_feasible(rctx: &mut RankCtx, interleave: bool, n_mb: usize, cap: u64) -> bool {
    if interleave {
        interleaved_feasible(&rctx.lens, n_mb, cap, &mut rctx.subset_tokens)
    } else {
        chunked_feasible(&rctx.prefix, n_mb, cap)
    }
}

/// Smallest token-feasible micro-batch count in `[lo, hi]`, or None.
/// `hi` = the subset size, where singleton micro-batches are always
/// token-feasible (each sequence was length-checked against the cap), so
/// the gallop always brackets.
fn first_token_feasible(
    rctx: &mut RankCtx,
    interleave: bool,
    cap: u64,
    lo: usize,
    hi: usize,
    search: MbSearch,
) -> Option<usize> {
    match search {
        MbSearch::Linear => (lo..=hi).find(|&n| token_feasible(rctx, interleave, n, cap)),
        MbSearch::Gallop => {
            if lo > hi {
                return None;
            }
            if token_feasible(rctx, interleave, lo, cap) {
                return Some(lo);
            }
            let mut bad = lo;
            let mut step = 1usize;
            loop {
                let cand = bad.saturating_add(step).min(hi);
                if token_feasible(rctx, interleave, cand, cap) {
                    // binary search the bracket (bad, cand]
                    let (mut l, mut r) = (bad, cand);
                    while r - l > 1 {
                        let m = l + (r - l) / 2;
                        if token_feasible(rctx, interleave, m, cap) {
                            r = m;
                        } else {
                            l = m;
                        }
                    }
                    return Some(r);
                }
                if cand == hi {
                    return None;
                }
                bad = cand;
                step *= 2;
            }
        }
    }
}

/// Number of non-empty micro-batches a candidate count produces.  With
/// interleaving every stride j < n_mb ≤ K is populated; in chunked mode
/// the trailing chunks can be empty (the reference skips them too).
fn active_mbs(len: usize, n_mb: usize, interleave: bool) -> usize {
    if interleave {
        n_mb
    } else {
        let chunk = len.div_ceil(n_mb);
        len.div_ceil(chunk)
    }
}

/// Schedule one DP rank's subset (Algorithm 2 body) — fast path.
/// Byte-identical plans to [`schedule_rank_reference`].
pub fn schedule_rank_with_ctx(
    subset: &[Sequence],
    cfg: &GdsConfig,
    flops: &FlopsModel,
    rctx: &mut RankCtx,
) -> Result<RankSchedule, SchedError> {
    schedule_rank_inner(subset, cfg, flops, rctx, 1)
}

/// Materialize one micro-batch's sequence list from the sorted arena —
/// the strided (or chunked) slice `Subset[j::n_mb]`.  These `Vec`s are
/// part of the returned schedule; they are the only allocations the
/// steady-state serial path performs.
fn subset_seqs(sorted: &[Sequence], j: usize, n_mb: usize, chunk: usize, interleave: bool) -> Vec<Sequence> {
    if interleave {
        sorted.iter().skip(j).step_by(n_mb).copied().collect()
    } else {
        sorted.iter().skip(j * chunk).take(chunk).copied().collect()
    }
}

/// The rank scheduler body.  `outer_fanout` is how many sibling rank
/// schedulers are running concurrently (1 when standalone): the inner
/// per-subset DACP fan-out claims only its `1/outer_fanout` share of the
/// core budget so the nested parallelism cannot oversubscribe.  Shard
/// workers (scheduler::shard) call this directly with their own arenas.
pub(crate) fn schedule_rank_inner(
    subset: &[Sequence],
    cfg: &GdsConfig,
    flops: &FlopsModel,
    rctx: &mut RankCtx,
    outer_fanout: usize,
) -> Result<RankSchedule, SchedError> {
    if subset.is_empty() {
        return Ok(RankSchedule::default());
    }
    let cap = cfg.bucket_size as u64 * cfg.cp as u64;
    let total: u64 = subset.iter().map(|s| s.len as u64).sum();
    for s in subset {
        if s.len as u64 > cap {
            return Err(SchedError::TooLong { len: s.len, cap });
        }
    }

    // line 3: ascending sort (into the reusable arena).  Packed
    // (len, original index) keys are strictly distinct, so the in-place
    // unstable sort reproduces the reference's stable sort exactly while
    // allocating nothing.
    rctx.keys.clear();
    rctx.keys
        .extend(subset.iter().enumerate().map(|(i, s)| ((s.len as u64) << 32) | i as u64));
    rctx.keys.sort_unstable();
    rctx.sorted.clear();
    rctx.sorted
        .extend(rctx.keys.iter().map(|&key| subset[(key & u32::MAX as u64) as usize]));
    let k = rctx.sorted.len();
    rctx.lens.clear();
    // skrull-lint: allow(truncating-cast) -- exact by construction: the high 32 bits of `key` are the packed u32 length
    rctx.lens.extend(rctx.keys.iter().map(|&key| (key >> 32) as u32));

    // incremental re-scheduling: an exact match on the sorted lengths (and
    // every knob the solution depends on) means the fresh solve below
    // would reproduce the cached structure verbatim — replay it over the
    // freshly sorted sequences and skip the search + DACP entirely.
    if cfg.incremental && rctx.cache.matches(cfg, flops, &rctx.lens) {
        rctx.cache_hits += 1;
        let n_mb = rctx.cache.n_mb;
        let chunk = k.div_ceil(n_mb);
        let active = rctx.cache.offsets.len() - 1;
        let mut mbs = Vec::with_capacity(active);
        for j in 0..active {
            let (a, b) = (rctx.cache.offsets[j], rctx.cache.offsets[j + 1]);
            mbs.push(MicroBatch {
                seqs: subset_seqs(&rctx.sorted, j, n_mb, chunk, cfg.interleave),
                // skrull-lint: allow(hot-path-alloc) -- builds the returned RankSchedule; within the audited per-call allocation budget
                plan: DacpPlan { assign: rctx.cache.assign[a..b].to_vec() },
            });
        }
        return Ok(RankSchedule { micro_batches: mbs });
    }

    if !cfg.interleave {
        rctx.prefix.clear();
        rctx.prefix.reserve(k + 1);
        rctx.prefix.push(0);
        let mut acc = 0u64;
        for &l in &rctx.lens {
            acc += l as u64;
            rctx.prefix.push(acc);
        }
    }

    // line 2: start from the memory lower bound on micro-batch count
    let min_mbs = (total.div_ceil(cap) as usize).max(1);
    let dacp_cfg = cfg.dacp();
    let capacity_error = |rctx: &RankCtx| SchedError::TooLong {
        len: rctx.sorted.last().map(|s| s.len).unwrap_or(0),
        cap,
    };

    // the retry loop of Algorithm 2, with the token precheck hoisted in
    // front of every DACP call and the first candidate found by `search`.
    // The gallop's monotonicity assumption only holds for strided subsets
    // (see MbSearch::Gallop), so the chunked ablation mode is pinned to
    // the exact linear scan.
    let search = if cfg.interleave { cfg.search } else { MbSearch::Linear };
    let Some(mut n_mb) = first_token_feasible(rctx, cfg.interleave, cap, min_mbs, k, search)
    else {
        return Err(capacity_error(rctx));
    };
    'outer: loop {
        let active = active_mbs(k, n_mb, cfg.interleave);
        let chunk = k.div_ceil(n_mb);
        rctx.plan_assign.clear();
        rctx.plan_offsets.clear();
        rctx.plan_offsets.push(0);
        let mut dacp_failed = false;
        let inner_limit = (par::max_threads() / outer_fanout.max(1)).max(1);
        if cfg.parallel && active >= 2 && inner_limit >= 2 && k >= PAR_SUBSET_MIN_SEQS {
            // inner fan-out: the candidate's subsets are independent, so
            // their DACP runs can proceed concurrently; the accept/reject
            // decision ("did any subset fail?") and the accepted plans are
            // identical to the serial j-order walk
            if rctx.lens_pool.len() < active {
                // skrull-lint: allow(hot-path-alloc) -- lazy pool growth: reached only when the pool is too small, then recycled
                rctx.lens_pool.resize_with(active, Vec::new);
            }
            if rctx.dacp_pool.len() < active {
                rctx.dacp_pool.resize_with(active, DacpScratch::default);
            }
            for j in 0..active {
                let buf = &mut rctx.lens_pool[j];
                buf.clear();
                if cfg.interleave {
                    buf.extend(rctx.lens.iter().skip(j).step_by(n_mb));
                } else {
                    buf.extend(rctx.lens.iter().skip(j * chunk).take(chunk));
                }
            }
            let results = par::map_with_scratch_up_to(
                inner_limit,
                &rctx.lens_pool[..active],
                &mut rctx.dacp_pool[..active],
                |_, lens, scratch| dacp::schedule_into(lens, &dacp_cfg, flops, scratch),
            );
            dacp_failed = results.iter().any(|r| r.is_err());
            if !dacp_failed {
                for scratch in &rctx.dacp_pool[..active] {
                    rctx.plan_assign.extend_from_slice(scratch.assign());
                    rctx.plan_offsets.push(rctx.plan_assign.len());
                }
            }
        } else {
            for j in 0..active {
                // line 7: Subset[j::n_mb] pairs long and short sequences
                rctx.lens_buf.clear();
                if cfg.interleave {
                    rctx.lens_buf.extend(rctx.lens.iter().skip(j).step_by(n_mb));
                } else {
                    rctx.lens_buf.extend(rctx.lens.iter().skip(j * chunk).take(chunk));
                }
                match dacp::schedule_into(&rctx.lens_buf, &dacp_cfg, flops, &mut rctx.dacp) {
                    Ok(()) => {
                        rctx.plan_assign.extend_from_slice(rctx.dacp.assign());
                        rctx.plan_offsets.push(rctx.plan_assign.len());
                    }
                    Err(_) => {
                        dacp_failed = true;
                        break;
                    }
                }
            }
        }
        if dacp_failed {
            // line 8: DACP failure → retry with more micro-batches (token
            // failures were already excluded by the precheck); linear
            // advance over token-feasible counts — exactly the reference's
            // behaviour from this point on
            loop {
                n_mb += 1;
                if n_mb > k {
                    return Err(capacity_error(rctx));
                }
                if token_feasible(rctx, cfg.interleave, n_mb, cap) {
                    continue 'outer;
                }
            }
        }
        // all subsets scheduled: remember the structure for incremental
        // replay, then materialize the rank plan (the only allocations
        // that escape the arena are the returned micro-batches)
        if cfg.incremental {
            let cache = &mut rctx.cache;
            cache.valid = true;
            cache.bucket_size = cfg.bucket_size;
            cache.cp = cfg.cp;
            cache.interleave = cfg.interleave;
            cache.rollback_largest = cfg.rollback_largest;
            // skrull-lint: allow(hot-path-alloc) -- fresh-solve bookkeeping, off the cached steady-state path
            cache.flops = Some(flops.clone());
            cache.lens.clear();
            cache.lens.extend_from_slice(&rctx.lens);
            cache.n_mb = n_mb;
            cache.assign.clear();
            cache.assign.extend_from_slice(&rctx.plan_assign);
            cache.offsets.clear();
            cache.offsets.extend_from_slice(&rctx.plan_offsets);
        }
        let mut mbs = Vec::with_capacity(active);
        for j in 0..active {
            let (a, b) = (rctx.plan_offsets[j], rctx.plan_offsets[j + 1]);
            mbs.push(MicroBatch {
                seqs: subset_seqs(&rctx.sorted, j, n_mb, chunk, cfg.interleave),
                // skrull-lint: allow(hot-path-alloc) -- builds the returned RankSchedule; within the audited per-call allocation budget
                plan: DacpPlan { assign: rctx.plan_assign[a..b].to_vec() },
            });
        }
        return Ok(RankSchedule { micro_batches: mbs });
    }
}

/// Schedule one DP rank's subset with a throwaway scratch arena.
pub fn schedule_rank(
    subset: &[Sequence],
    cfg: &GdsConfig,
    flops: &FlopsModel,
) -> Result<RankSchedule, SchedError> {
    schedule_rank_with_ctx(subset, cfg, flops, &mut RankCtx::default())
}

/// Full GDS fast path: bin-pack the global batch over DP ranks by FLOPs
/// (Algorithm 2, line 1), then schedule each rank — in parallel when
/// `cfg.parallel`, across the shared-nothing shard pool when
/// `cfg.shards > 1` — reusing the caller's scratch arena.  All routes are
/// byte-identical to [`schedule_reference`].
pub fn schedule_with_ctx(
    global_batch: &[Sequence],
    cfg: &GdsConfig,
    flops: &FlopsModel,
    ctx: &mut SchedCtx,
) -> Result<IterationSchedule, SchedError> {
    assert!(cfg.dp > 0, "dp must be positive");
    ctx.ensure_ranks(cfg.dp);
    // step (i): FLOPs-balancing LPT partition — replayed from the cached
    // placement when incremental mode sees the exact same batch lengths
    // (equal lens + equal FLOPs model ⇒ equal weights ⇒ LPT would make
    // identical decisions, so the replay is byte-identical by construction)
    let reuse = cfg.incremental
        && ctx.prev_valid
        && ctx.prev_dp == cfg.dp
        && ctx.prev_flops.as_ref() == Some(flops)
        && ctx.prev_lens.len() == global_batch.len()
        && ctx.prev_lens.iter().zip(global_batch).all(|(&l, s)| l == s.len);
    if reuse {
        for (bin, placed) in ctx.bins.iter_mut().zip(&ctx.placed) {
            bin.clear();
            bin.extend(placed.iter().map(|&i| global_batch[i]));
        }
        ctx.partition_reuses += 1;
    } else {
        ctx.weighted.clear();
        ctx.weighted
            .extend(global_batch.iter().map(|&s| (s, flops.seq(s.len))));
        binpack::balance_into(
            &ctx.weighted,
            cfg.dp,
            &mut ctx.binpack,
            &mut ctx.bins,
            &mut ctx.placed,
        );
        if cfg.incremental {
            ctx.prev_valid = true;
            ctx.prev_dp = cfg.dp;
            ctx.prev_flops = Some(flops.clone());
            ctx.prev_lens.clear();
            ctx.prev_lens.extend(global_batch.iter().map(|s| s.len));
        } else {
            ctx.prev_valid = false;
        }
    }
    // step (ii)+(iii): schedule each rank's subset
    let shards = cfg.shards.max(1).min(cfg.dp);
    let SchedCtx { ranks, bins, pool, .. } = ctx;
    if shards > 1 {
        let pool = crate::scheduler::shard::ensure_pool(pool, shards, cfg.dp);
        return pool.run(bins, cfg, flops);
    }
    if cfg.parallel && cfg.dp > 1 {
        let outer = cfg.dp.min(par::max_threads());
        let results: Vec<Result<RankSchedule, SchedError>> =
            par::map_with_scratch(&bins[..cfg.dp], &mut ranks[..cfg.dp], move |_, subset, rctx| {
                schedule_rank_inner(subset, cfg, flops, rctx, outer)
            });
        let ranks = results.into_iter().collect::<Result<Vec<_>, _>>()?;
        return Ok(IterationSchedule { ranks });
    }
    // serial path: build the output directly — together with the arenas
    // above this keeps the steady state at zero allocations beyond the
    // returned schedule (asserted by tests/alloc_audit.rs)
    let mut out = Vec::with_capacity(cfg.dp);
    for (subset, rctx) in bins[..cfg.dp].iter().zip(ranks.iter_mut()) {
        out.push(schedule_rank_inner(subset, cfg, flops, rctx, 1)?);
    }
    Ok(IterationSchedule { ranks: out })
}

/// Full GDS fast path with a throwaway scratch arena.
pub fn schedule(
    global_batch: &[Sequence],
    cfg: &GdsConfig,
    flops: &FlopsModel,
) -> Result<IterationSchedule, SchedError> {
    schedule_with_ctx(global_batch, cfg, flops, &mut SchedCtx::default())
}

/// GDS + DACP + the cost-aware refinement pass (our extension — see
/// scheduler::dacp::refine and the `ablations` bench).  Guarantees the
/// plan is never worse than Algorithm 1's under the cost model, and in
/// particular restores bigger-bucket monotonicity that the avoid-sharding
/// principle alone violates.  Refinement of independent micro-batches fans
/// out over scoped threads when `cfg.parallel`.
pub fn schedule_refined_with_ctx(
    global_batch: &[Sequence],
    cfg: &GdsConfig,
    cost: &crate::perfmodel::CostModel,
    ctx: &mut SchedCtx,
) -> Result<IterationSchedule, SchedError> {
    let mut sched = schedule_with_ctx(global_batch, cfg, &cost.flops, ctx)?;
    let dcfg = cfg.dacp();
    let refine_one = |mb: &mut MicroBatch| {
        let lens = mb.lens();
        mb.plan = dacp::refine_multistart(&mb.plan, &lens, &dcfg, cost);
    };
    let mut mbs: Vec<&mut MicroBatch> = sched
        .ranks
        .iter_mut()
        .flat_map(|r| r.micro_batches.iter_mut())
        .collect();
    if cfg.parallel && mbs.len() > 1 {
        par::for_each_mut(&mut mbs, |_, mb| refine_one(mb));
    } else {
        for mb in mbs {
            refine_one(mb);
        }
    }
    Ok(sched)
}

/// GDS + refinement with a throwaway scratch arena.
pub fn schedule_refined(
    global_batch: &[Sequence],
    cfg: &GdsConfig,
    cost: &crate::perfmodel::CostModel,
) -> Result<IterationSchedule, SchedError> {
    schedule_refined_with_ctx(global_batch, cfg, cost, &mut SchedCtx::default())
}

// ---------------------------------------------------------------------------
// Reference path: the direct transcription of Algorithm 2 the fast path is
// oracle-tested against.  Serial, allocates per candidate, linear search —
// semantics, not speed.

/// Schedule one DP rank's subset — reference implementation.
pub fn schedule_rank_reference(
    subset: &[Sequence],
    cfg: &GdsConfig,
    flops: &FlopsModel,
) -> Result<RankSchedule, SchedError> {
    if subset.is_empty() {
        return Ok(RankSchedule::default());
    }
    let cap = cfg.bucket_size as u64 * cfg.cp as u64;
    let total: u64 = subset.iter().map(|s| s.len as u64).sum();
    for s in subset {
        if s.len as u64 > cap {
            return Err(SchedError::TooLong { len: s.len, cap });
        }
    }

    // line 3: ascending sort
    let mut sorted: Vec<Sequence> = subset.to_vec();
    sorted.sort_by_key(|s| s.len);

    // line 2: start from the memory lower bound on micro-batch count
    let min_mbs = (total.div_ceil(cap) as usize).max(1);
    let dacp_cfg = cfg.dacp();

    'outer: for n_mb in min_mbs..=sorted.len() {
        let mut mbs: Vec<MicroBatch> = Vec::with_capacity(n_mb);
        for j in 0..n_mb {
            // line 7: Subset[j::n_mb] pairs long and short sequences
            let seqs: Vec<Sequence> = if cfg.interleave {
                sorted.iter().skip(j).step_by(n_mb).copied().collect()
            } else {
                let chunk = sorted.len().div_ceil(n_mb);
                sorted.iter().skip(j * chunk).take(chunk).copied().collect()
            };
            if seqs.is_empty() {
                continue;
            }
            let tokens: u64 = seqs.iter().map(|s| s.len as u64).sum();
            // line 8: token cap or DACP failure → retry with more MBs
            if tokens > cap {
                continue 'outer;
            }
            let lens: Vec<u32> = seqs.iter().map(|s| s.len).collect();
            match dacp::schedule(&lens, &dacp_cfg, flops) {
                Ok(plan) => mbs.push(MicroBatch { seqs, plan }),
                Err(_) => continue 'outer,
            }
        }
        return Ok(RankSchedule { micro_batches: mbs });
    }

    // n_mb == len means one sequence per micro-batch; with S ≤ C·N that
    // must be schedulable, so reaching here is a genuine capacity error.
    Err(SchedError::TooLong {
        len: sorted.last().map(|s| s.len).unwrap_or(0),
        cap,
    })
}

/// Full GDS — reference implementation (reference bin-packer included).
pub fn schedule_reference(
    global_batch: &[Sequence],
    cfg: &GdsConfig,
    flops: &FlopsModel,
) -> Result<IterationSchedule, SchedError> {
    let weighted: Vec<(Sequence, f64)> = global_batch
        .iter()
        .map(|&s| (s, flops.seq(s.len)))
        .collect();
    let bins = binpack::balance_reference(&weighted, cfg.dp);
    let ranks = bins
        .iter()
        .map(|subset| schedule_rank_reference(subset, cfg, flops))
        .collect::<Result<Vec<_>, _>>()?;
    Ok(IterationSchedule { ranks })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;
    use crate::util::proptest::{forall, SeqLensGen};

    fn fm() -> FlopsModel {
        FlopsModel::new(&ModelSpec::qwen2_5_0_5b())
    }

    fn seqs(lens: &[u32]) -> Vec<Sequence> {
        lens.iter()
            .enumerate()
            .map(|(i, &len)| Sequence { id: i as u64, len })
            .collect()
    }

    #[test]
    fn every_sequence_assigned_exactly_once() {
        let batch = seqs(&[100, 5000, 250, 30_000, 90, 800, 12_000, 400]);
        let cfg = GdsConfig::new(26 * 1024, 8, 4);
        let sched = schedule(&batch, &cfg, &fm()).unwrap();
        assert_eq!(sched.assigned_ids(), (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn micro_batches_respect_token_cap() {
        let batch = seqs(&[40_000; 12]);
        let cfg = GdsConfig::new(26 * 1024, 8, 4);
        let sched = schedule(&batch, &cfg, &fm()).unwrap();
        let cap = cfg.bucket_size as u64 * cfg.cp as u64;
        for r in &sched.ranks {
            for mb in &r.micro_batches {
                assert!(mb.total_tokens() <= cap);
                mb.plan
                    .validate(&mb.lens(), cfg.bucket_size, cfg.cp)
                    .unwrap();
            }
        }
    }

    #[test]
    fn pairing_spreads_long_sequences() {
        // 2 long + 6 short on one rank, 2 micro-batches: interleaving must
        // not put both longs in the same micro-batch.
        let subset = seqs(&[30_000, 30_000, 100, 100, 100, 100, 100, 100]);
        let mut cfg = GdsConfig::new(26 * 1024, 8, 1);
        cfg.interleave = true;
        let rs = schedule_rank(&subset, &cfg, &fm()).unwrap();
        if rs.micro_batches.len() >= 2 {
            let longs_per_mb: Vec<usize> = rs
                .micro_batches
                .iter()
                .map(|mb| mb.seqs.iter().filter(|s| s.len >= 30_000).count())
                .collect();
            assert!(longs_per_mb.iter().all(|&c| c <= 1), "{longs_per_mb:?}");
        }
    }

    #[test]
    fn grows_micro_batch_count_under_memory_pressure() {
        // total 100K tokens, cap C·N = 16K → at least 7 micro-batches
        let subset = seqs(&[10_000; 10]);
        let cfg = GdsConfig::new(2 * 1024, 8, 1);
        let rs = schedule_rank(&subset, &cfg, &fm()).unwrap();
        assert!(rs.micro_batches.len() >= 7, "{}", rs.micro_batches.len());
        let cap = cfg.bucket_size as u64 * cfg.cp as u64;
        for mb in &rs.micro_batches {
            assert!(mb.total_tokens() <= cap);
        }
    }

    #[test]
    fn too_long_sequence_errors() {
        let batch = seqs(&[300_000]);
        let cfg = GdsConfig::new(26 * 1024, 8, 4);
        assert!(matches!(
            schedule(&batch, &cfg, &fm()),
            Err(SchedError::TooLong { .. })
        ));
    }

    #[test]
    fn empty_batch_is_fine() {
        let cfg = GdsConfig::new(1024, 8, 4);
        let sched = schedule(&[], &cfg, &fm()).unwrap();
        assert_eq!(sched.ranks.len(), 4);
        assert_eq!(sched.num_micro_batches(), 0);
    }

    #[test]
    fn schedule_refined_keeps_invariants_and_improves() {
        use crate::perfmodel::CostModel;
        let cost = CostModel::paper_default(&ModelSpec::qwen2_5_0_5b());
        let batch = seqs(&[25_000, 300, 400, 500, 14_000, 100, 18_000, 900]);
        let cfg = GdsConfig::new(26 * 1024, 4, 2);
        let plain = schedule(&batch, &cfg, &cost.flops).unwrap();
        let refined = schedule_refined(&batch, &cfg, &cost).unwrap();
        assert_eq!(refined.assigned_ids(), plain.assigned_ids());
        let total = |s: &IterationSchedule| -> f64 {
            s.ranks
                .iter()
                .map(|r| {
                    r.micro_batches
                        .iter()
                        .map(|mb| cost.tdacp(&mb.lens(), &mb.plan, cfg.cp))
                        .sum::<f64>()
                })
                .fold(0.0, f64::max)
        };
        assert!(total(&refined) <= total(&plain) * (1.0 + 1e-9));
        for r in &refined.ranks {
            for mb in &r.micro_batches {
                mb.plan.validate(&mb.lens(), cfg.bucket_size, cfg.cp).unwrap();
            }
        }
    }

    #[test]
    fn property_completeness_and_memory() {
        // Eq. 9 (exactly once) + Eq. 7/10 (memory) on random workloads.
        let gen = SeqLensGen { min_k: 1, max_k: 64, max_len: 100_000 };
        let flops = fm();
        forall(0x6D5, 200, &gen, |lens| {
            let batch = seqs(lens);
            let cfg = GdsConfig::new(26 * 1024, 8, 4);
            match schedule(&batch, &cfg, &flops) {
                Err(SchedError::TooLong { .. }) => Ok(()), // only when a seq > C·N
                Err(e) => Err(format!("unexpected: {e}")),
                Ok(sched) => {
                    let mut ids = sched.assigned_ids();
                    ids.dedup();
                    if ids.len() != lens.len() {
                        return Err(format!("{} ids for {} seqs", ids.len(), lens.len()));
                    }
                    for r in &sched.ranks {
                        for mb in &r.micro_batches {
                            mb.plan
                                .validate(&mb.lens(), cfg.bucket_size, cfg.cp)
                                .map_err(|e| e.to_string())?;
                        }
                    }
                    Ok(())
                }
            }
        });
    }

    /// The tentpole's safety net: the fast path (all four combinations of
    /// search strategy × parallelism, with a *reused* arena) produces
    /// byte-identical schedules — or the identical error — to the
    /// reference transcription of Algorithm 2, across random workloads and
    /// both interleave modes.
    #[test]
    fn property_fast_path_matches_reference() {
        let flops = fm();
        let gen = SeqLensGen { min_k: 1, max_k: 96, max_len: 120_000 };
        let mut ctx = SchedCtx::default();
        let configs = [
            (26 * 1024u32, 8usize, 4usize, true),
            (26 * 1024, 8, 4, false),
            (4 * 1024, 4, 2, true),
            (1024, 2, 3, true),
        ];
        forall(0xFA57, 220, &gen, |lens| {
            let batch = seqs(lens);
            for &(c, cp, dp, interleave) in &configs {
                let mut cfg = GdsConfig::new(c, cp, dp);
                cfg.interleave = interleave;
                let reference = schedule_reference(&batch, &cfg, &flops);
                for search in [MbSearch::Gallop, MbSearch::Linear] {
                    for parallel in [false, true] {
                        cfg.search = search;
                        cfg.parallel = parallel;
                        let fast = schedule_with_ctx(&batch, &cfg, &flops, &mut ctx);
                        match (&reference, &fast) {
                            (Ok(a), Ok(b)) => {
                                if a != b {
                                    return Err(format!(
                                        "plan mismatch (C={c} cp={cp} dp={dp} il={interleave} {search:?} par={parallel})"
                                    ));
                                }
                            }
                            (Err(a), Err(b)) => {
                                if a != b {
                                    return Err(format!("error mismatch: {a} vs {b}"));
                                }
                            }
                            _ => {
                                return Err(format!(
                                    "feasibility mismatch: ref {:?} fast {:?}",
                                    reference.is_ok(),
                                    fast.is_ok()
                                ))
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fast_path_matches_reference_at_large_k() {
        use crate::data::{Dataset, LengthDistribution};
        use crate::rng::Rng;
        let flops = fm();
        let cfg = GdsConfig::new(26 * 1024, 8, 4);
        let ds = Dataset::synthesize(&LengthDistribution::wikipedia(), 50_000, 11)
            .truncated(26 * 1024 * 8);
        let mut rng = Rng::seed_from_u64(0xB16);
        let mut ctx = SchedCtx::default();
        for k in [1024usize, 4096] {
            let batch = ds.sample_batch(&mut rng, k);
            let fast = schedule_with_ctx(&batch, &cfg, &flops, &mut ctx).unwrap();
            let reference = schedule_reference(&batch, &cfg, &flops).unwrap();
            assert_eq!(fast, reference, "K={k}");
        }
    }

    #[test]
    fn reused_ctx_is_stateless_across_calls() {
        // scheduling A, then B, then A again through one arena must give
        // the same answer for A both times
        let flops = fm();
        let cfg = GdsConfig::new(8 * 1024, 4, 2);
        let a = seqs(&[100, 9_000, 250, 30_000, 90, 800, 12_000, 400]);
        let b = seqs(&[5_000; 40]);
        let mut ctx = SchedCtx::default();
        let first = schedule_with_ctx(&a, &cfg, &flops, &mut ctx).unwrap();
        let _ = schedule_with_ctx(&b, &cfg, &flops, &mut ctx).unwrap();
        let again = schedule_with_ctx(&a, &cfg, &flops, &mut ctx).unwrap();
        assert_eq!(first, again);
    }

    #[test]
    fn refined_parallel_matches_serial() {
        use crate::perfmodel::CostModel;
        let cost = CostModel::paper_default(&ModelSpec::qwen2_5_0_5b());
        let batch = seqs(&[25_000, 300, 400, 500, 14_000, 100, 18_000, 900, 22_000, 60]);
        let mut cfg = GdsConfig::new(13 * 1024, 4, 2);
        cfg.parallel = false;
        let serial = schedule_refined(&batch, &cfg, &cost).unwrap();
        cfg.parallel = true;
        let parallel = schedule_refined(&batch, &cfg, &cost).unwrap();
        assert_eq!(serial, parallel);
    }

    #[test]
    fn chunked_mode_pins_gallop_to_linear_scan() {
        // max chunk sums for these lens: 90@n=3, 89@n=4, 90@n=5..9 — a
        // non-monotone profile where binary search could overshoot the
        // first feasible count.  Chunked mode must ignore Gallop and match
        // the reference's linear scan exactly.
        let flops = fm();
        let subset = seqs(&[1, 5, 5, 9, 12, 15, 16, 32, 41, 49]);
        let mut cfg = GdsConfig::new(89, 1, 1);
        cfg.interleave = false;
        cfg.search = MbSearch::Gallop;
        let fast = schedule_rank(&subset, &cfg, &flops).unwrap();
        let reference = schedule_rank_reference(&subset, &cfg, &flops).unwrap();
        assert_eq!(fast, reference);
        assert_eq!(fast.micro_batches.len(), 4);
    }

    /// The sharded and incremental routes are the same function: every
    /// combination of shard count × incremental mode must match the
    /// reference byte for byte, with the arenas (and shard pool) reused
    /// across all cases.
    #[test]
    fn property_sharded_and_incremental_match_reference() {
        let flops = fm();
        let gen = SeqLensGen { min_k: 1, max_k: 48, max_len: 120_000 };
        let mut ctx = SchedCtx::default();
        forall(0x5AAD, 60, &gen, |lens| {
            let batch = seqs(lens);
            for &(c, cp, dp) in &[(26 * 1024u32, 8usize, 4usize), (2 * 1024, 2, 3)] {
                let mut cfg = GdsConfig::new(c, cp, dp);
                let reference = schedule_reference(&batch, &cfg, &flops);
                for shards in [2usize, 3] {
                    for incremental in [false, true] {
                        cfg.shards = shards;
                        cfg.incremental = incremental;
                        // twice per case: the second call exercises the
                        // warm arenas — and, when incremental, the cached
                        // partition + per-rank replay path
                        for round in 0..2 {
                            let fast = schedule_with_ctx(&batch, &cfg, &flops, &mut ctx);
                            let agree = match (&reference, &fast) {
                                (Ok(a), Ok(b)) => a == b,
                                (Err(a), Err(b)) => a == b,
                                _ => false,
                            };
                            if !agree {
                                return Err(format!(
                                    "mismatch (C={c} cp={cp} dp={dp} shards={shards} inc={incremental} round={round})"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn incremental_repeat_batch_hits_both_caches() {
        let flops = fm();
        let mut cfg = GdsConfig::new(8 * 1024, 4, 2);
        cfg.parallel = false; // in-process rank path so the counters are visible
        cfg.incremental = true;
        let a = seqs(&[100, 9_000, 250, 30_000, 90, 800, 12_000, 400]);
        let mut ctx = SchedCtx::default();
        let first = schedule_with_ctx(&a, &cfg, &flops, &mut ctx).unwrap();
        assert_eq!(ctx.partition_reuses(), 0);
        assert_eq!(ctx.rank_cache_hits(), 0);
        let again = schedule_with_ctx(&a, &cfg, &flops, &mut ctx).unwrap();
        assert_eq!(first, again);
        assert_eq!(ctx.partition_reuses(), 1);
        assert_eq!(ctx.rank_cache_hits(), cfg.dp as u64);
        // a changed batch must invalidate both caches, not replay stale state
        let b = seqs(&[100, 9_000, 250, 30_000, 90, 800, 12_000, 500]);
        let fresh = schedule_with_ctx(&b, &cfg, &flops, &mut ctx).unwrap();
        assert_eq!(ctx.partition_reuses(), 1);
        assert_eq!(fresh, schedule_reference(&b, &cfg, &flops).unwrap());
    }

    #[test]
    fn incremental_cache_respects_knob_and_model_changes() {
        let flops = fm();
        let mut cfg = GdsConfig::new(8 * 1024, 4, 1);
        cfg.parallel = false;
        cfg.incremental = true;
        let a = seqs(&[100, 9_000, 250, 30_000, 90, 800, 12_000, 400]);
        let mut ctx = SchedCtx::default();
        let _ = schedule_with_ctx(&a, &cfg, &flops, &mut ctx).unwrap();
        // same batch, different bucket size: the rank cache must miss and
        // the answer must equal a fresh reference under the new knob (the
        // LPT partition legitimately replays — it never reads the bucket)
        cfg.bucket_size = 4 * 1024;
        let shrunk = schedule_with_ctx(&a, &cfg, &flops, &mut ctx).unwrap();
        assert_eq!(ctx.rank_cache_hits(), 0);
        assert_eq!(ctx.partition_reuses(), 1);
        assert_eq!(shrunk, schedule_reference(&a, &cfg, &flops).unwrap());
        // different FLOPs model: LPT weights change, so the partition
        // cache must miss too
        let other = FlopsModel::new(&ModelSpec::qwen2_5_7b());
        let under_other = schedule_with_ctx(&a, &cfg, &other, &mut ctx).unwrap();
        assert_eq!(ctx.partition_reuses(), 1);
        assert_eq!(ctx.rank_cache_hits(), 0);
        assert_eq!(under_other, schedule_reference(&a, &cfg, &other).unwrap());
    }

    /// Overflow hardening at million-sequence scale: the strided precheck
    /// accumulates `K × max_len` tokens — 2^20 sequences of 128K tokens is
    /// ~2^37, far past u32 — and must stay exact in u64.
    #[test]
    fn strided_precheck_is_exact_at_extreme_k() {
        let k: usize = 1 << 20;
        let len: u32 = 128 * 1024;
        let lens = vec![len; k];
        let mut sums = Vec::new();
        // one subset: the sum is K·len = 2^37 exactly
        assert!(interleaved_feasible(&lens, 1, (k as u64) * len as u64, &mut sums));
        assert_eq!(sums, vec![(k as u64) * len as u64]);
        assert!(!interleaved_feasible(&lens, 1, (k as u64) * len as u64 - 1, &mut sums));
        // 2^10 subsets of 2^10 sequences each: per-subset sum 2^27
        let per = (k as u64 / 1024) * len as u64;
        assert!(interleaved_feasible(&lens, 1024, per, &mut sums));
        assert!(!interleaved_feasible(&lens, 1024, per - 1, &mut sums));
        // chunked counterpart over prefix sums (u64 end to end)
        let mut prefix = Vec::with_capacity(k + 1);
        prefix.push(0u64);
        let mut acc = 0u64;
        for &l in &lens {
            acc += l as u64;
            prefix.push(acc);
        }
        assert_eq!(acc, (k as u64) * len as u64);
        assert!(chunked_feasible(&prefix, 1024, per));
        assert!(!chunked_feasible(&prefix, 1024, per - 1));
    }

    #[test]
    fn gallop_handles_tight_caps() {
        // total >> cap forces a large first feasible count; gallop and
        // linear must agree on it exactly
        let flops = fm();
        for lens in [vec![1_000u32; 257], vec![2_000; 64], vec![1, 1, 1, 4_000]] {
            let subset = seqs(&lens);
            let mut cfg = GdsConfig::new(512, 8, 1);
            let linear = {
                cfg.search = MbSearch::Linear;
                schedule_rank(&subset, &cfg, &flops)
            };
            let gallop = {
                cfg.search = MbSearch::Gallop;
                schedule_rank(&subset, &cfg, &flops)
            };
            match (linear, gallop) {
                (Ok(a), Ok(b)) => assert_eq!(a.micro_batches.len(), b.micro_batches.len()),
                (Err(_), Err(_)) => {}
                (a, b) => panic!("search disagreement: {:?} vs {:?}", a.is_ok(), b.is_ok()),
            }
        }
    }
}
