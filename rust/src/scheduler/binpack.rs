//! Greedy makespan-balancing "bin packing" (LPT — longest processing time
//! first), used by GDS step (i) to balance FLOPs across DP ranks
//! (Algorithm 2, line 1).  LPT has a 4/3 makespan guarantee, plenty for a
//! near-zero-cost online scheduler.

/// Distribute weighted items over `bins` bins, minimizing the max bin
/// weight.  Returns per-bin item lists; items keep their payloads.
pub fn balance<T: Copy>(items: &[(T, f64)], bins: usize) -> Vec<Vec<T>> {
    assert!(bins > 0);
    let mut order: Vec<usize> = (0..items.len()).collect();
    order.sort_by(|&a, &b| items[b].1.partial_cmp(&items[a].1).unwrap());
    let mut out: Vec<Vec<T>> = vec![Vec::new(); bins];
    let mut load = vec![0.0f64; bins];
    for idx in order {
        let j = (0..bins)
            .min_by(|&a, &b| load[a].partial_cmp(&load[b]).unwrap())
            .unwrap();
        out[j].push(items[idx].0);
        load[j] += items[idx].1;
    }
    out
}

/// Max/mean load ratio of a partition under a weight function — the
/// imbalance metric reported by the benches.
pub fn imbalance<T, F: Fn(&T) -> f64>(bins: &[Vec<T>], weight: F) -> f64 {
    let loads: Vec<f64> = bins
        .iter()
        .map(|b| b.iter().map(&weight).sum::<f64>())
        .collect();
    let max = loads.iter().cloned().fold(0.0, f64::max);
    let mean = loads.iter().sum::<f64>() / loads.len().max(1) as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn covers_all_items() {
        let items: Vec<(usize, f64)> = (0..17).map(|i| (i, (i + 1) as f64)).collect();
        let bins = balance(&items, 4);
        let mut got: Vec<usize> = bins.iter().flatten().copied().collect();
        got.sort_unstable();
        assert_eq!(got, (0..17).collect::<Vec<_>>());
    }

    #[test]
    fn equal_items_spread_evenly() {
        let items: Vec<(u32, f64)> = (0..8).map(|i| (i, 10.0)).collect();
        let bins = balance(&items, 4);
        assert!(bins.iter().all(|b| b.len() == 2));
    }

    #[test]
    fn lpt_beats_naive_on_skewed_weights() {
        let mut rng = Rng::seed_from_u64(11);
        let items: Vec<(usize, f64)> = (0..64)
            .map(|i| (i, rng.lognormal(3.0, 1.5)))
            .collect();
        let bins = balance(&items, 4);
        let lpt_imb = imbalance(&bins, |&i| items[i].1);
        // naive round-robin for comparison
        let mut naive: Vec<Vec<usize>> = vec![Vec::new(); 4];
        for (i, _) in &items {
            naive[i % 4].push(*i);
        }
        let naive_imb = imbalance(&naive, |&i| items[i].1);
        assert!(lpt_imb <= naive_imb, "lpt {lpt_imb} vs naive {naive_imb}");
        // LPT guarantee: makespan ≤ 4/3 · OPT, and OPT ≥ max(total/bins,
        // largest item) — with one dominant item that bound, not 1.0, is
        // the floor.
        let total: f64 = items.iter().map(|it| it.1).sum();
        let largest = items.iter().map(|it| it.1).fold(0.0, f64::max);
        let opt_lb = (total / 4.0).max(largest);
        let makespan = bins
            .iter()
            .map(|b| b.iter().map(|&i| items[i].1).sum::<f64>())
            .fold(0.0, f64::max);
        assert!(makespan <= 4.0 / 3.0 * opt_lb + 1e-9, "makespan {makespan} vs lb {opt_lb}");
    }

    #[test]
    fn single_bin_takes_everything() {
        let items = [(0u32, 1.0), (1, 2.0)];
        let bins = balance(&items, 1);
        assert_eq!(bins.len(), 1);
        assert_eq!(bins[0].len(), 2);
    }

    #[test]
    fn empty_items_yield_empty_bins() {
        let bins = balance::<u32>(&[], 3);
        assert_eq!(bins.len(), 3);
        assert!(bins.iter().all(|b| b.is_empty()));
        assert_eq!(imbalance(&bins, |_| 1.0), 1.0);
    }
}
