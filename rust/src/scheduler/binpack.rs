//! Greedy makespan-balancing "bin packing" (LPT — longest processing time
//! first), used by GDS step (i) to balance FLOPs across DP ranks
//! (Algorithm 2, line 1).  LPT has a 4/3 makespan guarantee, plenty for a
//! near-zero-cost online scheduler.
//!
//! The fast path keeps the bins in a min-heap keyed by (load, index), so
//! each placement is O(log dp) instead of an O(dp) min-scan — identical
//! output to [`balance_reference`] (ties resolve to the lowest bin index
//! in both), which stays around as the oracle.  All comparisons use
//! `f64::total_cmp`: a NaN weight degrades placement quality instead of
//! panicking the scheduler.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// `f64` with the IEEE 754 total order, for heap keys.
#[derive(Clone, Copy, Debug, PartialEq)]
struct TotalF64(f64);

impl Eq for TotalF64 {}

impl PartialOrd for TotalF64 {
    // skrull-lint: allow(nan-unsafe-ord) -- delegates to Ord::cmp, which is total_cmp; this is the documented NaN-safe exception
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for TotalF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Reusable working buffers for [`balance_into`]: the LPT order and the
/// (load, bin) min-heap.  Thread one through repeated calls and the
/// steady state allocates nothing.
#[derive(Debug, Default)]
pub struct BinpackScratch {
    order: Vec<usize>,
    heap: BinaryHeap<Reverse<(TotalF64, usize)>>,
}

/// Distribute weighted items over `bins` bins, minimizing the max bin
/// weight.  Returns per-bin item lists; items keep their payloads.
pub fn balance<T: Copy>(items: &[(T, f64)], bins: usize) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let mut placed = Vec::new();
    balance_into(items, bins, &mut BinpackScratch::default(), &mut out, &mut placed);
    out
}

/// [`balance`] into caller-owned bins: identical placements, but the bin
/// `Vec`s, the LPT order and the heap are all recycled across calls.
/// `placed[j]` records the item *indices* routed to bin `j`, in placement
/// (descending-weight) order — the incremental scheduler replays it to
/// reproduce this exact partition without re-running LPT.
pub fn balance_into<T: Copy>(
    items: &[(T, f64)],
    bins: usize,
    scratch: &mut BinpackScratch,
    out: &mut Vec<Vec<T>>,
    placed: &mut Vec<Vec<usize>>,
) {
    assert!(bins > 0);
    out.resize_with(bins, Vec::new); // skrull-lint: allow(hot-path-alloc) -- bin arenas grow once to `bins` and are recycled (cleared, not freed) across calls
    placed.resize_with(bins, Vec::new);
    for b in out.iter_mut() {
        b.clear();
    }
    for p in placed.iter_mut() {
        p.clear();
    }
    scratch.order.clear();
    scratch.order.extend(0..items.len());
    // descending weight with an ascending-index tiebreak: a strict total
    // order, so the allocation-free unstable sort reproduces the stable
    // `sort_by` ordering the reference uses
    scratch
        .order
        .sort_unstable_by(|&a, &b| items[b].1.total_cmp(&items[a].1).then(a.cmp(&b)));
    // min-heap over (load, bin index): equal loads pop the lowest index,
    // matching the reference min-scan's first-minimum rule
    scratch.heap.clear();
    for j in 0..bins {
        scratch.heap.push(Reverse((TotalF64(0.0), j)));
    }
    for &idx in &scratch.order {
        // skrull-lint: allow(panic-in-lib) -- heap is seeded with `bins` entries and bins > 0 is asserted at entry
        let Reverse((TotalF64(load), j)) = scratch.heap.pop().expect("bins > 0");
        out[j].push(items[idx].0);
        placed[j].push(idx);
        scratch.heap.push(Reverse((TotalF64(load + items[idx].1), j)));
    }
}

/// The original O(items × bins) min-scan LPT — oracle for [`balance`].
pub fn balance_reference<T: Copy>(items: &[(T, f64)], bins: usize) -> Vec<Vec<T>> {
    assert!(bins > 0);
    let mut order: Vec<usize> = (0..items.len()).collect();
    order.sort_by(|&a, &b| items[b].1.total_cmp(&items[a].1));
    let mut out: Vec<Vec<T>> = vec![Vec::new(); bins];
    let mut load = vec![0.0f64; bins];
    for idx in order {
        let j = (0..bins)
            .min_by(|&a, &b| load[a].total_cmp(&load[b]))
            // skrull-lint: allow(panic-in-lib) -- min over 0..bins with bins > 0 asserted; never empty
            .unwrap();
        out[j].push(items[idx].0);
        load[j] += items[idx].1;
    }
    out
}

/// Max/mean load ratio of a partition under a weight function — the
/// imbalance metric reported by the benches.
pub fn imbalance<T, F: Fn(&T) -> f64>(bins: &[Vec<T>], weight: F) -> f64 {
    let loads: Vec<f64> = bins
        .iter()
        .map(|b| b.iter().map(&weight).sum::<f64>())
        .collect();
    let max = loads.iter().cloned().fold(0.0, f64::max);
    let mean = loads.iter().sum::<f64>() / loads.len().max(1) as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn covers_all_items() {
        let items: Vec<(usize, f64)> = (0..17).map(|i| (i, (i + 1) as f64)).collect();
        let bins = balance(&items, 4);
        let mut got: Vec<usize> = bins.iter().flatten().copied().collect();
        got.sort_unstable();
        assert_eq!(got, (0..17).collect::<Vec<_>>());
    }

    #[test]
    fn equal_items_spread_evenly() {
        let items: Vec<(u32, f64)> = (0..8).map(|i| (i, 10.0)).collect();
        let bins = balance(&items, 4);
        assert!(bins.iter().all(|b| b.len() == 2));
    }

    #[test]
    fn lpt_beats_naive_on_skewed_weights() {
        let mut rng = Rng::seed_from_u64(11);
        let items: Vec<(usize, f64)> = (0..64)
            .map(|i| (i, rng.lognormal(3.0, 1.5)))
            .collect();
        let bins = balance(&items, 4);
        let lpt_imb = imbalance(&bins, |&i| items[i].1);
        // naive round-robin for comparison
        let mut naive: Vec<Vec<usize>> = vec![Vec::new(); 4];
        for (i, _) in &items {
            naive[i % 4].push(*i);
        }
        let naive_imb = imbalance(&naive, |&i| items[i].1);
        assert!(lpt_imb <= naive_imb, "lpt {lpt_imb} vs naive {naive_imb}");
        // LPT guarantee: makespan ≤ 4/3 · OPT, and OPT ≥ max(total/bins,
        // largest item) — with one dominant item that bound, not 1.0, is
        // the floor.
        let total: f64 = items.iter().map(|it| it.1).sum();
        let largest = items.iter().map(|it| it.1).fold(0.0, f64::max);
        let opt_lb = (total / 4.0).max(largest);
        let makespan = bins
            .iter()
            .map(|b| b.iter().map(|&i| items[i].1).sum::<f64>())
            .fold(0.0, f64::max);
        assert!(makespan <= 4.0 / 3.0 * opt_lb + 1e-9, "makespan {makespan} vs lb {opt_lb}");
    }

    #[test]
    fn single_bin_takes_everything() {
        let items = [(0u32, 1.0), (1, 2.0)];
        let bins = balance(&items, 1);
        assert_eq!(bins.len(), 1);
        assert_eq!(bins[0].len(), 2);
    }

    #[test]
    fn empty_items_yield_empty_bins() {
        let bins = balance::<u32>(&[], 3);
        assert_eq!(bins.len(), 3);
        assert!(bins.iter().all(|b| b.is_empty()));
        assert_eq!(imbalance(&bins, |_| 1.0), 1.0);
    }

    #[test]
    fn heap_matches_reference_min_scan() {
        // the fast heap LPT must place every item in exactly the bin the
        // reference min-scan picks, including tie-heavy inputs
        let mut rng = Rng::seed_from_u64(0x1B);
        for bins in [1usize, 2, 3, 7, 16] {
            for trial in 0..20 {
                let n = 1 + (trial * 13) % 97;
                let items: Vec<(usize, f64)> = (0..n)
                    .map(|i| {
                        // mix of ties (quantized) and spread weights
                        let w = if i % 3 == 0 {
                            (rng.below(5) + 1) as f64
                        } else {
                            rng.lognormal(2.0, 1.2)
                        };
                        (i, w)
                    })
                    .collect();
                assert_eq!(
                    balance(&items, bins),
                    balance_reference(&items, bins),
                    "bins={bins} n={n}"
                );
            }
        }
    }

    #[test]
    fn balance_into_reuse_matches_reference_and_replays() {
        // one scratch + bin arena threaded through many differently-sized
        // calls must keep matching the reference, and the recorded
        // placements must replay to the identical partition
        let mut rng = Rng::seed_from_u64(0x51);
        let mut scratch = BinpackScratch::default();
        let mut out: Vec<Vec<usize>> = Vec::new();
        let mut placed: Vec<Vec<usize>> = Vec::new();
        for trial in 0..30 {
            let n = 1 + (trial * 17) % 83;
            let bins = 1 + trial % 6;
            let items: Vec<(usize, f64)> = (0..n)
                .map(|i| (i, if i % 4 == 0 { 8.0 } else { rng.lognormal(2.0, 1.0) }))
                .collect();
            balance_into(&items, bins, &mut scratch, &mut out, &mut placed);
            assert_eq!(out, balance_reference(&items, bins), "trial {trial}");
            let replayed: Vec<Vec<usize>> = placed
                .iter()
                .map(|p| p.iter().map(|&idx| items[idx].0).collect())
                .collect();
            assert_eq!(replayed, out, "trial {trial}");
        }
    }

    #[test]
    fn nan_weight_does_not_panic() {
        // regression: the seed's partial_cmp().unwrap() sorts panicked on
        // NaN; total_cmp must keep every item assigned instead
        let items = [(0u32, 2.0), (1, f64::NAN), (2, 1.0), (3, f64::NAN), (4, 3.0)];
        for bins in [1usize, 2, 4] {
            let out = balance(&items, bins);
            let mut got: Vec<u32> = out.iter().flatten().copied().collect();
            got.sort_unstable();
            assert_eq!(got, vec![0, 1, 2, 3, 4], "bins={bins}");
            assert_eq!(out, balance_reference(&items, bins), "bins={bins}");
        }
    }
}
