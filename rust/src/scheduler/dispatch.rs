//! Single policy → scheduler dispatch.
//!
//! Every entry point that turns a global batch into an
//! [`IterationSchedule`] under a [`Policy`] — the scheduling DataLoader
//! (`data::loader`) and the real-workload trainer (`coordinator::trainer`)
//! — routes through this one match, so the policy set cannot drift between
//! the simulation and training paths and both reuse the fast path's
//! scratch arena across calls.

use crate::config::Policy;
use crate::data::Sequence;
use crate::perfmodel::{CostModel, FlopsModel};
use crate::scheduler::{baseline, gds, IterationSchedule, SchedError};

/// Schedule `batch` under `policy` onto the `dp × cp` layout carried by
/// `gcfg` (which also holds the per-rank token capacity C).  `flops`
/// drives the FLOPs-balancing policies, `cost` only the cost-aware
/// refinement (`Policy::SkrullRefined`), and `ctx` is the reusable GDS
/// scratch arena (byte-identical results to the throwaway-arena paths,
/// enforced by the gds oracle tests).
pub fn schedule_policy(
    policy: Policy,
    batch: &[Sequence],
    gcfg: &gds::GdsConfig,
    flops: &FlopsModel,
    cost: &CostModel,
    ctx: &mut gds::SchedCtx,
) -> Result<IterationSchedule, SchedError> {
    let (dp, cp, bucket) = (gcfg.dp, gcfg.cp, gcfg.bucket_size);
    match policy {
        Policy::Baseline => Ok(baseline::deepspeed(batch, dp, cp)),
        Policy::DacpOnly => baseline::dacp_only(batch, dp, cp, bucket, flops),
        Policy::Skrull => gds::schedule_with_ctx(batch, gcfg, flops, ctx),
        Policy::SkrullRefined => gds::schedule_refined_with_ctx(batch, gcfg, cost, ctx),
        Policy::SortedBatching => Ok(baseline::sorted_batching(batch, dp, cp, bucket)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;

    #[test]
    fn dispatch_matches_direct_scheduler_calls_for_every_policy() {
        let spec = ModelSpec::qwen2_5_0_5b();
        let flops = FlopsModel::new(&spec);
        let cost = CostModel::paper_default(&spec);
        let (dp, cp, bucket) = (2usize, 4usize, 8_192u32);
        let gcfg = gds::GdsConfig::new(bucket, cp, dp);
        let batch: Vec<Sequence> = [3_000u32, 500, 7_000, 1_200, 9_000, 64]
            .iter()
            .enumerate()
            .map(|(i, &len)| Sequence { id: i as u64, len })
            .collect();
        let mut ctx = gds::SchedCtx::default();
        for policy in [
            Policy::Baseline,
            Policy::DacpOnly,
            Policy::Skrull,
            Policy::SkrullRefined,
            Policy::SortedBatching,
        ] {
            let via_dispatch =
                schedule_policy(policy, &batch, &gcfg, &flops, &cost, &mut ctx).unwrap();
            let direct = match policy {
                Policy::Baseline => baseline::deepspeed(&batch, dp, cp),
                Policy::DacpOnly => baseline::dacp_only(&batch, dp, cp, bucket, &flops).unwrap(),
                Policy::Skrull => gds::schedule(&batch, &gcfg, &flops).unwrap(),
                Policy::SkrullRefined => gds::schedule_refined(&batch, &gcfg, &cost).unwrap(),
                Policy::SortedBatching => baseline::sorted_batching(&batch, dp, cp, bucket),
            };
            assert_eq!(via_dispatch, direct, "{policy:?}");
        }
    }
}
