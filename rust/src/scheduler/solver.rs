//! Exact DACP solver (branch-and-bound) for small micro-batches.
//!
//! Section 4.3 notes that ILP solvers (SCIP) find the optimum but are far
//! too slow for online use.  This module plays that role for the ablation
//! study: it enumerates sequence classifications/assignments (D, P of the
//! formulation) with feasibility + bound pruning and returns the true
//! optimum of Eq. 1–7 under the same cost model the simulator uses, so the
//! heuristic's optimality gap can be measured (bench `ablations`).

use crate::perfmodel::CostModel;
use crate::scheduler::plan::{DacpPlan, DISTRIBUTED};

pub struct Solved {
    pub plan: DacpPlan,
    pub cost: f64,
    /// Number of explored branch nodes (reported by the ablation bench).
    pub nodes: u64,
}

struct Search<'a> {
    lens: &'a [u32],
    cost: &'a CostModel,
    bucket: i64,
    n: usize,
    // state
    assign: Vec<i32>,
    rb: Vec<i64>,
    // incremental bound state, pushed/popped along the DFS so that
    // `lower_bound` is O(N) instead of the seed's O(K·N) full rescans.
    // Each push adds onto the exact previous partial sum and each pop
    // restores the saved value bit-for-bit, so every bound equals what the
    // rescan would have computed and the search explores identical nodes.
    /// per-sequence layer FLOPs, in search (longest-first) order
    seq_flops: Vec<f64>,
    /// per-sequence ceil(S/N) shard tokens, in search order
    shard_tok: Vec<i64>,
    /// Σ seq_flops of the locals on each rank
    local_flops: Vec<f64>,
    /// number of locals on each rank (for the symmetric-empty-rank dedupe)
    local_count: Vec<u32>,
    /// Σ seq_flops of the distributed sequences
    dist_flops: f64,
    /// Σ tokens of the distributed sequences (drives T_comm)
    dist_tokens: u64,
    best_cost: f64,
    best: Option<Vec<i32>>,
    nodes: u64,
    node_limit: u64,
}

impl<'a> Search<'a> {
    /// Lower bound on the final TDACP given a partial assignment: the
    /// distributed compute so far is paid by everyone; local compute per
    /// rank is a lower bound on that rank's Eq. 2 term.  O(N) from the
    /// maintained sums.
    fn lower_bound(&self) -> f64 {
        let t_dist = self.cost.t_comp_per_layer(self.dist_flops / self.n as f64);
        let t_comm = self.cost.t_comm_dist(self.dist_tokens);
        // adding sequences to a rank only grows its aggregate kernel, so
        // the partial assignment's per-rank local time lower-bounds the
        // final one
        let max_local: f64 = self
            .local_flops
            .iter()
            .map(|&w| self.cost.t_comp_per_layer(w))
            .fold(0.0, f64::max);
        max_local.max(t_comm) + t_dist
    }

    fn evaluate(&mut self) {
        let plan = DacpPlan { assign: self.assign.clone() };
        let c = self.cost.tdacp(self.lens, &plan, self.n);
        if c < self.best_cost {
            self.best_cost = c;
            self.best = Some(self.assign.clone());
        }
    }

    fn dfs(&mut self, k: usize) {
        self.nodes += 1;
        if self.nodes > self.node_limit {
            return;
        }
        if self.lower_bound() >= self.best_cost {
            return; // bound prune
        }
        if k == self.lens.len() {
            self.evaluate();
            return;
        }
        let s = self.lens[k] as i64;
        let w = self.seq_flops[k];
        let shard = self.shard_tok[k];

        // branch: local on each rank (dedupe symmetric empty ranks)
        let mut seen_empty = false;
        for j in 0..self.n {
            let empty = self.rb[j] == self.bucket && self.local_count[j] == 0;
            if empty {
                if seen_empty {
                    continue; // identical to the previous empty rank
                }
                seen_empty = true;
            }
            if self.rb[j] >= s {
                // save/restore instead of add/subtract: bit-exact pops
                let saved = self.local_flops[j];
                self.rb[j] -= s;
                self.local_flops[j] = saved + w;
                self.local_count[j] += 1;
                // skrull-lint: allow(truncating-cast) -- a CP rank index, a GPU count nowhere near i32::MAX
                self.assign[k] = j as i32;
                self.dfs(k + 1);
                self.rb[j] += s;
                self.local_flops[j] = saved;
                self.local_count[j] -= 1;
            }
        }
        // branch: distributed
        if (0..self.n).all(|j| self.rb[j] >= shard) {
            let saved = self.dist_flops;
            for j in 0..self.n {
                self.rb[j] -= shard;
            }
            self.dist_flops = saved + w;
            self.dist_tokens += self.lens[k] as u64;
            self.assign[k] = DISTRIBUTED;
            self.dfs(k + 1);
            for j in 0..self.n {
                self.rb[j] += shard;
            }
            self.dist_flops = saved;
            self.dist_tokens -= self.lens[k] as u64;
        }
        self.assign[k] = i32::MIN;
    }
}

/// Find the optimal DACP plan, or None if no feasible assignment exists
/// (or the node limit was exhausted without finding one).
pub fn solve(
    lens: &[u32],
    bucket_size: u32,
    n: usize,
    cost: &CostModel,
    node_limit: u64,
) -> Option<Solved> {
    solve_warm(lens, bucket_size, n, cost, node_limit, None)
}

/// [`solve`] with an incumbent warm start: a previous iteration's (or the
/// heuristic's) feasible plan seeds `best`/`best_cost`, so the bound
/// pruning bites from the first node instead of only after the DFS finds
/// its own incumbent.  The returned cost is still the true optimum — a
/// valid incumbent only tightens the strict `<` pruning, never excludes a
/// better assignment — but on repeat batch compositions the search
/// explores a fraction of the nodes.  An infeasible or mismatched warm
/// plan is ignored.
pub fn solve_warm(
    lens: &[u32],
    bucket_size: u32,
    n: usize,
    cost: &CostModel,
    node_limit: u64,
    warm: Option<&DacpPlan>,
) -> Option<Solved> {
    // order longest-first: decisions about big sequences prune hardest
    let mut order: Vec<usize> = (0..lens.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(lens[i]));
    let ordered: Vec<u32> = order.iter().map(|&i| lens[i]).collect();
    // per-sequence costs are fixed by the lengths: compute them once here
    // rather than once per explored node
    let seq_flops: Vec<f64> = ordered.iter().map(|&s| cost.seq_layer_flops(s)).collect();
    let shard_tok: Vec<i64> = ordered
        .iter()
        .map(|&s| (s as i64 + n as i64 - 1) / n as i64)
        .collect();
    let mut s2 = Search {
        lens: &ordered,
        cost,
        bucket: bucket_size as i64,
        n,
        assign: vec![i32::MIN; lens.len()],
        rb: vec![bucket_size as i64; n],
        seq_flops,
        shard_tok,
        local_flops: vec![0.0; n],
        local_count: vec![0; n],
        dist_flops: 0.0,
        dist_tokens: 0,
        best_cost: f64::INFINITY,
        best: None,
        nodes: 0,
        node_limit,
    };
    if let Some(w) = warm {
        if w.assign.len() == lens.len() && w.validate(lens, bucket_size, n).is_ok() {
            // permute the incumbent into search (longest-first) order so a
            // DFS improvement overwrites it shape-compatibly
            s2.best_cost = cost.tdacp(lens, w, n);
            s2.best = Some(order.iter().map(|&i| w.assign[i]).collect());
        }
    }
    s2.dfs(0);
    let best = s2.best?;
    // un-permute the assignment back to the original order
    let mut assign = vec![0i32; lens.len()];
    for (pos, &orig) in order.iter().enumerate() {
        assign[orig] = best[pos];
    }
    Some(Solved { plan: DacpPlan { assign }, cost: s2.best_cost, nodes: s2.nodes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;
    use crate::perfmodel::CostModel;
    use crate::scheduler::dacp::{self, DacpConfig};
    use crate::util::proptest::{forall, SeqLensGen};

    fn cm() -> CostModel {
        CostModel::paper_default(&ModelSpec::qwen2_5_0_5b())
    }

    #[test]
    fn optimal_keeps_shorts_local() {
        let cost = cm();
        let lens = [500, 600, 700, 800];
        let sol = solve(&lens, 10_000, 2, &cost, 1_000_000).unwrap();
        assert_eq!(sol.plan.num_distributed(), 0);
        sol.plan.validate(&lens, 10_000, 2).unwrap();
    }

    #[test]
    fn optimal_never_beaten_by_heuristic() {
        let cost = cm();
        let gen = SeqLensGen { min_k: 1, max_k: 8, max_len: 30_000 };
        let cfg = DacpConfig::new(16 * 1024, 4);
        forall(0x501E, 60, &gen, |lens| {
            let Some(sol) = solve(lens, cfg.bucket_size, cfg.cp_degree, &cost, 2_000_000) else {
                return Ok(()); // infeasible for both
            };
            sol.plan
                .validate(lens, cfg.bucket_size, cfg.cp_degree)
                .map_err(|e| e.to_string())?;
            if let Ok(hplan) = dacp::schedule(lens, &cfg, &cost.flops) {
                let hcost = cost.tdacp(lens, &hplan, cfg.cp_degree);
                if sol.cost > hcost * (1.0 + 1e-9) {
                    return Err(format!("solver {0} worse than heuristic {hcost}", sol.cost));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn matches_exhaustive_enumeration_on_tiny_instances() {
        // the incremental push/pop bound state must not change what the
        // search finds: on instances small enough to enumerate every
        // assignment, the solver's optimum equals the brute-force optimum
        let cost = cm();
        let gen = SeqLensGen { min_k: 1, max_k: 5, max_len: 6_000 };
        let (c, n) = (4_000u32, 2usize);
        forall(0xE14, 40, &gen, |lens| {
            let k = lens.len();
            let mut best: Option<f64> = None;
            let mut digits = vec![0i32; k]; // base n+1; digit n means DISTRIBUTED
            'enumerate: loop {
                let plan = DacpPlan {
                    assign: digits
                        .iter()
                        .map(|&d| if d == n as i32 { DISTRIBUTED } else { d })
                        .collect(),
                };
                if plan.validate(lens, c, n).is_ok() {
                    let t = cost.tdacp(lens, &plan, n);
                    best = Some(best.map_or(t, |b: f64| b.min(t)));
                }
                for i in 0..k {
                    if digits[i] < n as i32 {
                        digits[i] += 1;
                        for d in digits.iter_mut().take(i) {
                            *d = 0;
                        }
                        continue 'enumerate;
                    }
                }
                break;
            }
            let sol = solve(lens, c, n, &cost, 10_000_000);
            match (best, sol) {
                (None, None) => Ok(()),
                (Some(b), Some(s)) => {
                    if (s.cost - b).abs() <= 1e-9 * b.max(1.0) {
                        Ok(())
                    } else {
                        Err(format!("solver {} vs brute force {b}", s.cost))
                    }
                }
                (b, s) => Err(format!(
                    "feasibility mismatch: brute {:?} solver {:?}",
                    b.is_some(),
                    s.is_some()
                )),
            }
        });
    }

    #[test]
    fn warm_start_preserves_optimum_and_never_explores_more() {
        let cost = cm();
        let gen = SeqLensGen { min_k: 1, max_k: 8, max_len: 30_000 };
        let cfg = DacpConfig::new(16 * 1024, 4);
        forall(0x3A12, 60, &gen, |lens| {
            let cold = solve(lens, cfg.bucket_size, cfg.cp_degree, &cost, 2_000_000);
            let warm_plan = dacp::schedule(lens, &cfg, &cost.flops).ok();
            let warm = solve_warm(
                lens,
                cfg.bucket_size,
                cfg.cp_degree,
                &cost,
                2_000_000,
                warm_plan.as_ref(),
            );
            match (&cold, &warm) {
                (None, None) => Ok(()),
                (Some(a), Some(b)) => {
                    if (a.cost - b.cost).abs() > 1e-9 * a.cost.max(1.0) {
                        return Err(format!("warm cost {} vs cold {}", b.cost, a.cost));
                    }
                    // a valid incumbent can only tighten the pruning
                    if warm_plan.is_some() && b.nodes > a.nodes {
                        return Err(format!("warm explored {} > cold {}", b.nodes, a.nodes));
                    }
                    b.plan
                        .validate(lens, cfg.bucket_size, cfg.cp_degree)
                        .map_err(|e| e.to_string())
                }
                _ => Err(format!(
                    "feasibility mismatch: cold {:?} warm {:?}",
                    cold.is_some(),
                    warm.is_some()
                )),
            }
        });
    }

    #[test]
    fn warm_start_ignores_bogus_plans() {
        let cost = cm();
        let lens = [500, 600, 700, 800];
        // wrong length and an infeasible assignment must both be ignored
        let wrong_len = DacpPlan { assign: vec![0] };
        let sol = solve_warm(&lens, 10_000, 2, &cost, 1_000_000, Some(&wrong_len)).unwrap();
        let cold = solve(&lens, 10_000, 2, &cost, 1_000_000).unwrap();
        assert!((sol.cost - cold.cost).abs() <= 1e-12);
    }

    #[test]
    fn infeasible_returns_none() {
        // 3 sequences of 100 with C=40, N=2: shard=50 > 40 → nothing fits
        assert!(solve(&[100, 100, 100], 40, 2, &cm(), 100_000).is_none());
    }

    #[test]
    fn distributes_when_optimal() {
        // one huge sequence + tiny bucket: must be distributed
        let cost = cm();
        let lens = [7_000];
        let sol = solve(&lens, 4_000, 4, &cost, 100_000).unwrap();
        assert_eq!(sol.plan.assign[0], DISTRIBUTED);
    }
}
