//! Algorithm 1 + Algorithm 3: the DACP heuristic.
//!
//! Principles (Section 4.3.2): (i) avoid sharding, (ii) prioritize
//! computation balance, (iii) roll back on memory pressure.
//!
//! Bookkeeping per CP rank: RemainBucket RB (token budget left, Eq. 7) and
//! Loads L (FLOPs assigned).  Sequences are visited in ascending length;
//! each tries (a) the min-load rank, (b) the max-remaining rank, then
//! (c) distribution, and if even distribution cannot fit, a local sequence
//! in the tightest bucket is rolled back to distributed and the sequence is
//! retried.
//!
//! Two deliberate deviations from the paper's pseudocode (documented in
//! DESIGN.md):
//!  * Alg. 3's ROLLBACK updates `RB[rank] ← RB[rank] - S[i] + S[i]/N`; the
//!    signs are inverted there (rolling a local sequence *out* frees its
//!    tokens and charges the shard), and only the chosen rank is updated
//!    even though a distributed sequence occupies S/N on *every* rank
//!    (Eq. 7).  We apply the sign-corrected, all-rank update — otherwise
//!    the memory constraint the roll-back exists to protect is violated.
//!  * We roll back the *largest* local sequence in the bucket rather than
//!    the first in iteration order: it frees the most memory per roll-back,
//!    so the retry loop converges in fewer steps (ablated in benches).

use crate::perfmodel::FlopsModel;
use crate::scheduler::plan::{DacpPlan, SchedError, DISTRIBUTED};

/// Tuning knobs, mostly for ablation benches.
#[derive(Clone, Debug)]
pub struct DacpConfig {
    pub bucket_size: u32,
    pub cp_degree: usize,
    /// Roll back the largest local (true, our default) or the first-found
    /// (paper's literal Alg. 3).
    pub rollback_largest: bool,
}

impl DacpConfig {
    pub fn new(bucket_size: u32, cp_degree: usize) -> Self {
        DacpConfig { bucket_size, cp_degree, rollback_largest: true }
    }
}

/// Reusable working buffers for [`schedule_with_scratch`].  GDS calls DACP
/// once per candidate micro-batch inside its retry loop; threading one
/// scratch through those calls removes all per-call allocations except the
/// returned plan itself (which the caller owns).
#[derive(Debug, Default)]
pub struct DacpScratch {
    rb: Vec<i64>,
    load: Vec<f64>,
    assign: Vec<i32>,
    order: Vec<usize>,
}

impl DacpScratch {
    /// The assignment produced by the last successful [`schedule_into`]
    /// call, in the original index order of its `lens`.
    pub fn assign(&self) -> &[i32] {
        &self.assign
    }
}

/// Internal mutable state: RB, L and the assignment under construction
/// (views into a `DacpScratch`).
struct State<'a> {
    cfg: &'a DacpConfig,
    flops: &'a FlopsModel,
    lens: &'a [u32],
    /// remaining bucket tokens per rank (can go fractional via shards —
    /// tracked in tokens, shards use ceiling division)
    rb: &'a mut [i64],
    /// FLOPs load per rank
    load: &'a mut [f64],
    assign: &'a mut [i32],
}

impl<'a> State<'a> {
    fn shard_tokens(&self, len: u32) -> i64 {
        let n = self.cfg.cp_degree as i64;
        (len as i64 + n - 1) / n
    }

    /// UPDATELOCAL (Alg. 3): place sequence `idx` whole on `rank`.
    fn update_local(&mut self, idx: usize, rank: usize) {
        // skrull-lint: allow(truncating-cast) -- a CP rank index < cp_degree, a GPU count nowhere near i32::MAX
        self.assign[idx] = rank as i32;
        self.rb[rank] -= self.lens[idx] as i64;
        self.load[rank] += self.flops.seq(self.lens[idx]);
    }

    /// UPDATEALL (Alg. 3): distribute sequence `idx` over all ranks.
    fn update_all(&mut self, idx: usize) {
        self.assign[idx] = DISTRIBUTED;
        let shard = self.shard_tokens(self.lens[idx]);
        let w = self.flops.shard(self.lens[idx], self.cfg.cp_degree);
        for j in 0..self.cfg.cp_degree {
            self.rb[j] -= shard;
            self.load[j] += w;
        }
    }

    /// ROLLBACK (Alg. 3, sign-corrected): demote one local sequence of
    /// `rank` to distributed.  Returns false if the bucket has no locals.
    fn rollback(&mut self, rank: usize) -> bool {
        let candidate = self
            .assign
            .iter()
            .enumerate()
            // skrull-lint: allow(truncating-cast) -- a CP rank index < cp_degree, a GPU count nowhere near i32::MAX
            .filter(|(_, &a)| a == rank as i32)
            .map(|(i, _)| i)
            .reduce(|best, i| {
                if self.cfg.rollback_largest {
                    if self.lens[i] > self.lens[best] {
                        i
                    } else {
                        best
                    }
                } else {
                    best.min(i)
                }
            });
        let Some(i) = candidate else { return false };
        // undo the local placement...
        self.rb[rank] += self.lens[i] as i64;
        self.load[rank] -= self.flops.seq(self.lens[i]);
        // ...and re-account it as distributed on every rank
        self.update_all(i);
        true
    }

    fn argmin_load(&self) -> usize {
        // total_cmp: NaN-safe (a poisoned FLOPs model must not panic the
        // scheduler) and identical to partial_cmp on the finite loads the
        // algorithm actually produces.
        (0..self.cfg.cp_degree)
            .min_by(|&a, &b| self.load[a].total_cmp(&self.load[b]))
            // skrull-lint: allow(panic-in-lib) -- total_cmp reduction over cp_degree >= 1 ranks; never empty
            .unwrap()
    }

    fn argmax_rb(&self) -> usize {
        // skrull-lint: allow(panic-in-lib) -- reduction over cp_degree >= 1 ranks; never empty
        (0..self.cfg.cp_degree).max_by_key(|&j| self.rb[j]).unwrap()
    }

    fn argmin_rb(&self) -> usize {
        // skrull-lint: allow(panic-in-lib) -- reduction over cp_degree >= 1 ranks; never empty
        (0..self.cfg.cp_degree).min_by_key(|&j| self.rb[j]).unwrap()
    }
}

/// Algorithm 1.  Returns the assignment in the original index order of
/// `lens` (the paper sorts in place; we schedule through a sorted index
/// view so callers keep stable sequence identity).
pub fn schedule(lens: &[u32], cfg: &DacpConfig, flops: &FlopsModel) -> Result<DacpPlan, SchedError> {
    schedule_with_scratch(lens, cfg, flops, &mut DacpScratch::default())
}

/// Algorithm 1 with caller-owned working buffers.  Produces exactly the
/// plan [`schedule`] does; the scratch only recycles allocations.
pub fn schedule_with_scratch(
    lens: &[u32],
    cfg: &DacpConfig,
    flops: &FlopsModel,
    scratch: &mut DacpScratch,
) -> Result<DacpPlan, SchedError> {
    schedule_into(lens, cfg, flops, scratch)?;
    Ok(DacpPlan { assign: scratch.assign.clone() })
}

/// Algorithm 1 with zero output allocation: on success the assignment is
/// left in `scratch.assign()` (original index order) instead of being
/// materialized into a fresh [`DacpPlan`].  This is the scheduler hot
/// path's entry point — GDS copies the slice into its flat plan arena.
pub fn schedule_into(
    lens: &[u32],
    cfg: &DacpConfig,
    flops: &FlopsModel,
    scratch: &mut DacpScratch,
) -> Result<(), SchedError> {
    let n = cfg.cp_degree;
    let cap = cfg.bucket_size as u64 * n as u64;
    for &l in lens {
        if l as u64 > cap {
            return Err(SchedError::TooLong { len: l, cap });
        }
    }
    let DacpScratch { rb, load, assign, order } = scratch;
    rb.clear();
    rb.resize(n, cfg.bucket_size as i64);
    load.clear();
    load.resize(n, 0.0);
    assign.clear();
    assign.resize(lens.len(), i32::MIN);
    let mut st = State {
        cfg,
        flops,
        lens,
        rb: rb.as_mut_slice(),
        load: load.as_mut_slice(),
        assign: assign.as_mut_slice(),
    };

    // ascending length order (line 1) — packed (len, index) keys make the
    // keys strictly distinct, so the allocation-free unstable sort yields
    // exactly the stable sort-by-length ordering
    order.clear();
    order.extend(0..lens.len());
    order.sort_unstable_by_key(|&i| ((lens[i] as u64) << 32) | i as u64);

    let mut qi = 0;
    // Roll-backs can only happen O(K) times total (each converts one local
    // to distributed, permanently), so this loop terminates.
    let mut rollback_budget = lens.len() + 1;
    while qi < order.len() {
        let i = order[qi];
        let s = lens[i] as i64;

        // (a) min-load rank, if it fits (lines 6-8)
        let t = st.argmin_load();
        if st.rb[t] >= s {
            st.update_local(i, t);
            qi += 1;
            continue;
        }
        // (b) max-remaining rank (lines 10-12)
        let t = st.argmax_rb();
        if st.rb[t] >= s {
            st.update_local(i, t);
            qi += 1;
            continue;
        }
        // (c) distribute if every rank can take a shard (lines 14-16);
        // feasibility is gated by the *tightest* bucket.
        let t = st.argmin_rb();
        let shard = st.shard_tokens(lens[i]);
        if st.rb[t] >= shard {
            st.update_all(i);
            qi += 1;
            continue;
        }
        // (d) roll back a local in the tightest bucket and retry (line 18)
        if rollback_budget == 0 || !st.rollback(t) {
            return Err(SchedError::RollbackFailed { rank: t });
        }
        rollback_budget -= 1;
        // retry the same sequence (line 19: i ← i-1; continue)
    }

    // no validation here, even in debug builds: this is the zero-alloc
    // hot path (tests/alloc_audit.rs counts its allocations), and the
    // property tests validate every plan the public entry points emit
    Ok(())
}

/// Cost-aware refinement (extension, not in the paper's Alg. 1; see the
/// `ablations` bench).  Algorithm 1's "avoid sharding" principle can leave
/// a single long local sequence dominating the micro-batch makespan even
/// when distributing it would be much faster.  This pass greedily applies
/// the best of two move types while TDACP improves:
///   * demote a local sequence to distributed (if every rank has room)
///   * migrate a local sequence to another rank (if it fits)
/// The plan stays feasible by construction (validated in debug builds).
pub fn refine(
    plan: &DacpPlan,
    lens: &[u32],
    cfg: &DacpConfig,
    cost: &crate::perfmodel::CostModel,
) -> DacpPlan {
    Refiner::new(lens, cfg, cost, plan.clone()).run()
}

/// Incremental refinement engine.  The naive formulation (clone the plan,
/// re-validate, recompute TDACP for every candidate move) is O(K²·N) per
/// round and dominated wall-clock at large K (EXPERIMENTS.md §Perf);
/// maintaining per-rank FLOPs/token sums makes each candidate O(N).
struct Refiner<'a> {
    lens: &'a [u32],
    cfg: &'a DacpConfig,
    cost: &'a crate::perfmodel::CostModel,
    plan: DacpPlan,
    /// per-rank Σ seq_layer_flops of locals
    local_flops: Vec<f64>,
    /// per-rank Σ tokens of locals
    local_tokens: Vec<i64>,
    /// Σ seq_layer_flops of distributed seqs
    dist_flops: f64,
    /// Σ tokens of distributed seqs (drives T_comm)
    dist_tokens: u64,
    /// Σ ceil(S/N) of distributed seqs (drives Eq. 7)
    dist_shard_tokens: i64,
    /// cached per-seq layer flops
    seq_flops: Vec<f64>,
    /// cached per-rank t_comp_per_layer(local_flops[j])
    t_local: Vec<f64>,
    /// top-3 (value, rank) of t_local — lets a move be costed in O(1)
    top_t_local: [(f64, usize); 3],
    /// top-3 (tokens, rank) of local_tokens — O(1) Eq. 7 check
    top_tokens: [(i64, usize); 3],
}

/// Top-3 (value, index) of a slice, descending; missing entries keep the
/// sentinel.  Excluding at most two indices always leaves a valid max.
macro_rules! top3_fn {
    ($name:ident, $t:ty, $sentinel:expr) => {
        fn $name(xs: &[$t]) -> [($t, usize); 3] {
            let mut top = [($sentinel, usize::MAX); 3];
            for (i, &x) in xs.iter().enumerate() {
                if x > top[0].0 {
                    top[2] = top[1];
                    top[1] = top[0];
                    top[0] = (x, i);
                } else if x > top[1].0 {
                    top[2] = top[1];
                    top[1] = (x, i);
                } else if x > top[2].0 {
                    top[2] = (x, i);
                }
            }
            top
        }
    };
}
top3_fn!(top3_f64, f64, f64::NEG_INFINITY);
top3_fn!(top3_i64, i64, i64::MIN);

/// Largest value among entries whose index is neither `a` nor `b`.
fn max_excluding<T: Copy>(top: &[(T, usize); 3], a: usize, b: usize, sentinel: T) -> T {
    for &(v, i) in top {
        if i != a && i != b && i != usize::MAX {
            return v;
        }
    }
    sentinel
}

impl<'a> Refiner<'a> {
    fn new(
        lens: &'a [u32],
        cfg: &'a DacpConfig,
        cost: &'a crate::perfmodel::CostModel,
        plan: DacpPlan,
    ) -> Self {
        let n = cfg.cp_degree;
        let seq_flops: Vec<f64> = lens.iter().map(|&s| cost.seq_layer_flops(s)).collect();
        let mut r = Refiner {
            lens,
            cfg,
            cost,
            plan,
            local_flops: vec![0.0; n],
            local_tokens: vec![0; n],
            dist_flops: 0.0,
            dist_tokens: 0,
            dist_shard_tokens: 0,
            seq_flops,
            t_local: vec![0.0; n],
            top_t_local: [(f64::NEG_INFINITY, usize::MAX); 3],
            top_tokens: [(i64::MIN, usize::MAX); 3],
        };
        r.rebuild_sums();
        r
    }

    fn shard_tokens(&self, s: u32) -> i64 {
        let n = self.cfg.cp_degree as i64;
        (s as i64 + n - 1) / n
    }

    /// Recompute the aggregates from the assignment (also re-run between
    /// rounds to kill f64 add/subtract drift).
    fn rebuild_sums(&mut self) {
        self.local_flops.iter_mut().for_each(|x| *x = 0.0);
        self.local_tokens.iter_mut().for_each(|x| *x = 0);
        self.dist_flops = 0.0;
        self.dist_tokens = 0;
        self.dist_shard_tokens = 0;
        for (k, &a) in self.plan.assign.iter().enumerate() {
            if a == DISTRIBUTED {
                self.dist_flops += self.seq_flops[k];
                self.dist_tokens += self.lens[k] as u64;
                self.dist_shard_tokens += self.shard_tokens(self.lens[k]);
            } else {
                self.local_flops[a as usize] += self.seq_flops[k];
                self.local_tokens[a as usize] += self.lens[k] as i64;
            }
        }
        for j in 0..self.t_local.len() {
            self.t_local[j] = self.cost.t_comp_per_layer(self.local_flops[j]);
        }
        self.top_t_local = top3_f64(&self.t_local);
        self.top_tokens = top3_i64(&self.local_tokens);
    }

    /// TDACP of the current aggregates, with sequence k hypothetically
    /// moved to `to` (DISTRIBUTED or a rank).  Returns None if the move
    /// violates Eq. 7.
    fn move_cost(&self, k: usize, to: i32) -> Option<f64> {
        let n = self.cfg.cp_degree;
        let from = self.plan.assign[k];
        let s = self.lens[k];
        let w = self.seq_flops[k];
        // aggregates after the move
        let mut dist_flops = self.dist_flops;
        let mut dist_tokens = self.dist_tokens;
        let mut dist_shard = self.dist_shard_tokens;
        if from == DISTRIBUTED {
            dist_flops -= w;
            dist_tokens -= s as u64;
            dist_shard -= self.shard_tokens(s);
        }
        if to == DISTRIBUTED {
            dist_flops += w;
            dist_tokens += s as u64;
            dist_shard += self.shard_tokens(s);
        }
        // at most two ranks change their local sums
        let ra = if from >= 0 { from as usize } else { usize::MAX };
        let rb = if to >= 0 { to as usize } else { usize::MAX };

        // Eq. 7 feasibility in O(1): the binding rank is either an
        // unchanged max-token rank or one of the two changed ranks.
        let cap = self.cfg.bucket_size as i64;
        let mut max_tokens = max_excluding(&self.top_tokens, ra, rb, i64::MIN);
        if ra != usize::MAX {
            max_tokens = max_tokens.max(self.local_tokens[ra] - s as i64);
        }
        if rb != usize::MAX {
            max_tokens = max_tokens.max(self.local_tokens[rb] + s as i64);
        }
        if max_tokens.max(0) + dist_shard > cap {
            return None;
        }

        // Eq. 1/2 cost in O(1): max_j max(t_local_j, t_comm) + t_dist.
        let t_comm = self.cost.t_comm_dist(dist_tokens);
        let t_dist = self.cost.t_comp_per_layer(dist_flops / n as f64);
        let overhead = if self.lens.is_empty() { 0.0 } else { self.cost.hw.step_overhead_s };
        let mut max_t_local = max_excluding(&self.top_t_local, ra, rb, 0.0).max(0.0);
        if ra != usize::MAX {
            max_t_local = max_t_local.max(self.cost.t_comp_per_layer(self.local_flops[ra] - w));
        }
        if rb != usize::MAX {
            max_t_local = max_t_local.max(self.cost.t_comp_per_layer(self.local_flops[rb] + w));
        }
        Some(max_t_local.max(t_comm) + t_dist + overhead)
    }

    fn apply(&mut self, k: usize, to: i32) {
        self.plan.assign[k] = to;
        self.rebuild_sums();
    }

    fn run(mut self) -> DacpPlan {
        let n = self.cfg.cp_degree;
        let mut best_cost = self
            .cost
            .tdacp(self.lens, &self.plan, n);
        let budget = 4 * self.lens.len().max(4);
        for _ in 0..budget {
            let mut improved: Option<(usize, i32, f64)> = None;
            for k in 0..self.lens.len() {
                let from = self.plan.assign[k];
                // skrull-lint: allow(truncating-cast) -- n is the CP rank count, a GPU count nowhere near i32::MAX
                let candidates = (0..n as i32).map(Some).chain(std::iter::once(None));
                for cand in candidates {
                    let to = cand.unwrap_or(DISTRIBUTED);
                    if to == from {
                        continue;
                    }
                    if let Some(c) = self.move_cost(k, to) {
                        if c < best_cost * (1.0 - 1e-9)
                            && improved.map(|(_, _, ic)| c < ic).unwrap_or(true)
                        {
                            improved = Some((k, to, c));
                        }
                    }
                }
            }
            match improved {
                Some((k, to, c)) => {
                    self.apply(k, to);
                    best_cost = c;
                }
                None => break,
            }
        }
        debug_assert!(self
            .plan
            .validate(self.lens, self.cfg.bucket_size, n)
            .is_ok());
        self.plan
    }
}

/// Multi-start refinement: greedy local search is vulnerable to the
/// demote-one-at-a-time valley (distributing a single sequence piles shard
/// work onto already-busy ranks even when distributing *all* long
/// sequences would win).  Starting a second descent from the
/// all-distributed plan covers that regime; the cheaper plan wins.
pub fn refine_multistart(
    plan: &DacpPlan,
    lens: &[u32],
    cfg: &DacpConfig,
    cost: &crate::perfmodel::CostModel,
) -> DacpPlan {
    let n = cfg.cp_degree;
    let a = refine(plan, lens, cfg, cost);
    // Lower bound on any plan: all compute spread perfectly with zero
    // communication.  If descent A is already within 10% of it, the
    // second (all-distributed) start cannot pay for itself — this gate is
    // what keeps the refined scheduler near-zero-overhead on short-heavy
    // batches (EXPERIMENTS.md §Perf).
    let total_layer_flops: f64 = lens.iter().map(|&s| cost.seq_layer_flops(s)).sum();
    let lb = cost.t_comp_per_layer(total_layer_flops / n as f64)
        + if lens.is_empty() { 0.0 } else { cost.hw.step_overhead_s };
    let cost_a = cost.tdacp(lens, &a, n);
    if cost_a <= 1.10 * lb {
        return a;
    }
    let all_dist = DacpPlan::all_distributed(lens.len());
    if all_dist.validate(lens, cfg.bucket_size, n).is_err() {
        return a;
    }
    let b = refine(&all_dist, lens, cfg, cost);
    if cost.tdacp(lens, &b, n) < cost_a {
        b
    } else {
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;
    use crate::util::proptest::{forall, SeqLensGen};

    fn fm() -> FlopsModel {
        FlopsModel::new(&ModelSpec::qwen2_5_0_5b())
    }

    fn sched(lens: &[u32], c: u32, n: usize) -> Result<DacpPlan, SchedError> {
        schedule(lens, &DacpConfig::new(c, n), &fm())
    }

    #[test]
    fn all_short_sequences_stay_local() {
        // plenty of room: nothing should be sharded (principle i)
        let lens = [100, 200, 300, 400, 500, 600, 700, 800];
        let plan = sched(&lens, 10_000, 4).unwrap();
        assert_eq!(plan.num_distributed(), 0);
        plan.validate(&lens, 10_000, 4).unwrap();
    }

    #[test]
    fn long_sequence_is_distributed() {
        // one sequence larger than C must be sharded
        let lens = [100, 200, 5_000];
        let plan = sched(&lens, 2_000, 4).unwrap();
        assert_eq!(plan.assign[2], DISTRIBUTED);
        assert_eq!(plan.num_distributed(), 1);
        plan.validate(&lens, 2_000, 4).unwrap();
    }

    #[test]
    fn load_balance_spreads_locals() {
        // 4 equal sequences over 4 ranks: one each (min-load rule)
        let lens = [1000, 1000, 1000, 1000];
        let plan = sched(&lens, 4_000, 4).unwrap();
        let mut ranks: Vec<i32> = plan.assign.clone();
        ranks.sort_unstable();
        assert_eq!(ranks, vec![0, 1, 2, 3]);
    }

    #[test]
    fn sequence_exceeding_total_capacity_errors() {
        let e = sched(&[100_000], 1_000, 8).unwrap_err();
        assert!(matches!(e, SchedError::TooLong { .. }));
    }

    #[test]
    fn rollback_rescues_tight_fit() {
        // C=1000, N=2, lens sorted [4, 998, 998] (total = C·N exactly).
        // Greedy places 4→r0, 998→r1, then 998 fits nowhere locally and
        // its shard (499) exceeds min RB — only rolling earlier locals
        // back to distributed makes the assignment feasible (all three
        // distributed: per-rank 2+499+499 = 1000 = C).
        let lens = [998, 998, 4];
        let plan = sched(&lens, 1000, 2).unwrap();
        plan.validate(&lens, 1000, 2).unwrap();
        assert_eq!(plan.num_distributed(), 3);
    }

    #[test]
    fn rollback_failure_reports_error() {
        // N=2, C=100: [90, 90, 90, 90, 200] — after filling both buckets
        // with 90+90... capacity 2*100=200 total vs 560 needed: infeasible.
        let e = sched(&[90, 90, 90, 90, 200], 100, 2);
        assert!(e.is_err());
    }

    #[test]
    fn paper_literal_rollback_variant_also_valid() {
        let mut cfg = DacpConfig::new(1000, 2);
        cfg.rollback_largest = false;
        let lens = [998, 998, 4];
        let plan = schedule(&lens, &cfg, &fm()).unwrap();
        plan.validate(&lens, 1000, 2).unwrap();
    }

    #[test]
    fn schedule_into_leaves_identical_assignment_in_scratch() {
        let gen = SeqLensGen { min_k: 1, max_k: 32, max_len: 60_000 };
        let flops = fm();
        let cfg = DacpConfig::new(13 * 1024, 8);
        let mut scratch = DacpScratch::default();
        forall(0x1A70, 150, &gen, |lens| {
            let fresh = schedule(lens, &cfg, &flops);
            let into = schedule_into(lens, &cfg, &flops, &mut scratch);
            match (&fresh, &into) {
                (Ok(plan), Ok(())) => {
                    if plan.assign != scratch.assign() {
                        return Err("assignments differ".into());
                    }
                    Ok(())
                }
                (Err(a), Err(b)) if a == b => Ok(()),
                _ => Err(format!("feasibility mismatch: {fresh:?} vs {into:?}")),
            }
        });
    }

    #[test]
    fn refine_never_worsens_and_shards_isolated_long_seq() {
        use crate::perfmodel::CostModel;
        let cost = CostModel::paper_default(&ModelSpec::qwen2_5_0_5b());
        let cfg = DacpConfig::new(26 * 1024, 4);
        // a lone 25K sequence fits locally, so Alg. 1 keeps it local — but
        // distributing it cuts the makespan ~Nx (one rank does all work
        // otherwise).
        let lens = [25_000u32, 300, 400, 500];
        let plan = schedule(&lens, &cfg, &cost.flops).unwrap();
        assert_eq!(plan.num_distributed(), 0); // paper behaviour
        let refined = refine(&plan, &lens, &cfg, &cost);
        refined.validate(&lens, cfg.bucket_size, 4).unwrap();
        let before = cost.tdacp(&lens, &plan, 4);
        let after = cost.tdacp(&lens, &refined, 4);
        assert!(after <= before);
        assert_eq!(refined.assign[0], DISTRIBUTED, "long seq should be sharded");
        assert!(after < 0.6 * before, "{after} vs {before}");
    }

    #[test]
    fn refine_property_monotone_and_valid() {
        use crate::perfmodel::CostModel;
        let cost = CostModel::paper_default(&ModelSpec::qwen2_5_0_5b());
        let gen = SeqLensGen { min_k: 1, max_k: 12, max_len: 50_000 };
        let cfg = DacpConfig::new(26 * 1024, 8);
        forall(0x0F13E, 100, &gen, |lens| {
            let Ok(plan) = schedule(lens, &cfg, &cost.flops) else { return Ok(()) };
            let refined = refine(&plan, lens, &cfg, &cost);
            refined
                .validate(lens, cfg.bucket_size, cfg.cp_degree)
                .map_err(|e| e.to_string())?;
            let before = cost.tdacp(lens, &plan, cfg.cp_degree);
            let after = cost.tdacp(lens, &refined, cfg.cp_degree);
            if after > before * (1.0 + 1e-9) {
                return Err(format!("refine worsened: {before} -> {after}"));
            }
            Ok(())
        });
    }

    #[test]
    fn property_valid_or_error_never_panics() {
        // On any workload, schedule() either returns a plan satisfying
        // Eq. 6/7 or a structured error.
        let gen = SeqLensGen { min_k: 1, max_k: 40, max_len: 60_000 };
        let flops = fm();
        for (c, n) in [(26 * 1024, 8), (13 * 1024, 16), (2_048, 4), (512, 2)] {
            forall(0xDAC9, 300, &gen, |lens| {
                match schedule(lens, &DacpConfig::new(c, n), &flops) {
                    Ok(plan) => {
                        if plan.assign.iter().any(|&a| a == i32::MIN) {
                            return Err("unassigned sequence".into());
                        }
                        plan.validate(lens, c, n).map_err(|e| e.to_string())
                    }
                    Err(_) => Ok(()),
                }
            });
        }
    }

    #[test]
    fn property_feasible_when_total_fits_halved() {
        // Sufficient condition: if ΣS ≤ C·N/2 the heuristic must succeed
        // (it has slack to place or shard everything).
        let gen = SeqLensGen { min_k: 1, max_k: 24, max_len: 8_000 };
        let flops = fm();
        forall(0xFEA5, 300, &gen, |lens| {
            let total: u64 = lens.iter().map(|&l| l as u64).sum();
            let n = 8usize;
            let c = ((2 * total / n as u64).max(*lens.iter().max().unwrap() as u64) + 1) as u32;
            match schedule(lens, &DacpConfig::new(c, n), &flops) {
                Ok(plan) => plan.validate(lens, c, n).map_err(|e| e.to_string()),
                Err(e) => Err(format!("unexpected failure: {e}")),
            }
        });
    }
}
