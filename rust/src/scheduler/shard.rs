//! Shared-nothing scheduler shards.
//!
//! [`ShardPool`] partitions the DP ranks of one [`gds::schedule_with_ctx`]
//! call across persistent worker threads.  Each worker owns its per-rank
//! [`gds::RankCtx`] arenas outright — no scheduling state is ever shared
//! mutably — and talks to the dispatcher through exactly two bounded SPSC
//! queues (util::par::bounded): a job queue in, a result queue out.  Job
//! payloads are owned (`Vec<Sequence>` bins travel out with the job and
//! come back with the result), so the bin allocations are recycled across
//! iterations just like the single-shard arenas.
//!
//! Determinism / byte-identity: shard `s` owns the contiguous rank range
//! `[s·chunk, (s+1)·chunk)`, workers process their queue FIFO, and the
//! dispatcher gathers results shard by shard in that same order — so the
//! ranks come back in global rank order and the assembled schedule (and
//! its first-error-in-rank-order failure behaviour) is byte-identical to
//! the serial walk, which the property tests pin against
//! [`gds::schedule_reference`].  The only knob the shard route changes is
//! `outer_fanout`, which bounds the *inner* DACP fan-out's thread budget
//! and never affects output.
//!
//! Unlike the scoped-thread fan-out in util::par, the workers persist
//! across iterations: their arenas stay warm, thread spawns are paid once
//! per pool, and per-worker incremental caches survive from one batch to
//! the next.

use std::thread::JoinHandle;

use crate::data::Sequence;
use crate::perfmodel::FlopsModel;
use crate::scheduler::gds::{self, GdsConfig};
use crate::scheduler::plan::{IterationSchedule, RankSchedule, SchedError};
use crate::util::par::{bounded, Receiver, Sender};

/// One rank's worth of work, owned outright by the receiving shard.
struct Job {
    rank: usize,
    /// index into the worker's private arena vector (stable across
    /// iterations while dp and shard count are unchanged, which keeps the
    /// arenas and incremental caches warm)
    slot: usize,
    bin: Vec<Sequence>,
    cfg: GdsConfig,
    flops: FlopsModel,
    outer: usize,
}

/// A finished rank: the result plus the bin buffer, returned for reuse.
struct Done {
    rank: usize,
    bin: Vec<Sequence>,
    result: Result<RankSchedule, SchedError>,
}

struct Shard {
    /// `None` once the pool is shutting down (closing the queue is what
    /// tells the worker to exit)
    jobs: Option<Sender<Job>>,
    done: Receiver<Done>,
    handle: Option<JoinHandle<()>>,
}

/// Persistent pool of shared-nothing scheduler shards.  Created lazily by
/// [`gds::SchedCtx`] on the first sharded call and kept for the arena (and
/// thread) reuse; recreated only when the shard count or the per-shard
/// rank capacity changes.
pub struct ShardPool {
    shards: Vec<Shard>,
    queue_cap: usize,
}

impl std::fmt::Debug for ShardPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardPool")
            .field("shards", &self.shards.len())
            .field("queue_cap", &self.queue_cap)
            .finish()
    }
}

fn worker(jobs: Receiver<Job>, done: Sender<Done>) {
    // the worker's private arenas, one per rank slot it owns
    // skrull-lint: allow(hot-path-alloc) -- per-worker arena allocated once at thread startup, before the job loop
    let mut ctxs: Vec<gds::RankCtx> = Vec::new();
    while let Some(job) = jobs.recv() {
        if ctxs.len() <= job.slot {
            ctxs.resize_with(job.slot + 1, gds::RankCtx::default);
        }
        let result =
            gds::schedule_rank_inner(&job.bin, &job.cfg, &job.flops, &mut ctxs[job.slot], job.outer);
        if done.send(Done { rank: job.rank, bin: job.bin, result }).is_err() {
            break; // pool dropped mid-flight
        }
    }
}

impl ShardPool {
    pub(crate) fn new(shards: usize, queue_cap: usize) -> Self {
        let shards = shards.max(1);
        let queue_cap = queue_cap.max(1);
        let mut v = Vec::with_capacity(shards);
        for i in 0..shards {
            let (jtx, jrx) = bounded::<Job>(queue_cap);
            let (dtx, drx) = bounded::<Done>(queue_cap);
            let handle = std::thread::Builder::new()
                .name(format!("skrull-shard-{i}"))
                .spawn(move || worker(jrx, dtx))
                // skrull-lint: allow(panic-in-lib) -- thread-spawn failure (OS resource exhaustion) is unrecoverable here
                .expect("failed to spawn scheduler shard");
            v.push(Shard { jobs: Some(jtx), done: drx, handle: Some(handle) });
        }
        ShardPool { shards: v, queue_cap }
    }

    pub(crate) fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub(crate) fn queue_cap(&self) -> usize {
        self.queue_cap
    }

    /// Dispatch one iteration's rank subsets across the shards and gather
    /// the per-rank schedules in global rank order.  Each queue can hold a
    /// full shard's worth of jobs (`queue_cap ≥ chunk`), so the scatter
    /// phase never blocks and the scatter→gather cycle cannot deadlock.
    pub(crate) fn run(
        &mut self,
        bins: &mut [Vec<Sequence>],
        cfg: &GdsConfig,
        flops: &FlopsModel,
    ) -> Result<IterationSchedule, SchedError> {
        let dp = cfg.dp;
        let shards_used = self.shards.len().min(dp).max(1);
        let chunk = dp.div_ceil(shards_used);
        assert!(chunk <= self.queue_cap, "shard queues undersized for dp={dp}");
        for s in 0..shards_used {
            let lo = s * chunk;
            let hi = ((s + 1) * chunk).min(dp);
            for rank in lo..hi {
                let job = Job {
                    rank,
                    slot: rank - lo,
                    bin: std::mem::take(&mut bins[rank]),
                    cfg: cfg.clone(),
                    flops: flops.clone(),
                    outer: shards_used,
                };
                // skrull-lint: allow(panic-in-lib) -- jobs is Some for the pool's whole life; None only inside Drop
                let sent = self.shards[s].jobs.as_ref().expect("pool closed").send(job);
                assert!(sent.is_ok(), "scheduler shard worker died");
            }
        }
        let mut results: Vec<Result<RankSchedule, SchedError>> = Vec::with_capacity(dp);
        for s in 0..shards_used {
            let lo = s * chunk;
            let hi = ((s + 1) * chunk).min(dp);
            for _ in lo..hi {
                // skrull-lint: allow(panic-in-lib) -- recv fails only if the worker died; re-raises the worker's panic on the caller
                let d = self.shards[s].done.recv().expect("scheduler shard worker died");
                bins[d.rank] = d.bin;
                results.push(d.result);
            }
        }
        let ranks = results.into_iter().collect::<Result<Vec<_>, _>>()?;
        Ok(IterationSchedule { ranks })
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        for s in &mut self.shards {
            s.jobs = None; // close the job queue → worker sees end-of-stream
        }
        for s in &mut self.shards {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// Get (or lazily create / recreate) the pool for `shards` shards able to
/// carry `dp` ranks.  Over-provisions the queue capacity a little so
/// small dp fluctuations don't churn worker threads and their warm arenas.
pub(crate) fn ensure_pool<'a>(
    slot: &'a mut Option<ShardPool>,
    shards: usize,
    dp: usize,
) -> &'a mut ShardPool {
    let need = dp.div_ceil(shards.max(1)).max(1);
    let stale = match slot.as_ref() {
        Some(p) => p.shard_count() != shards || p.queue_cap() < need,
        None => true,
    };
    if stale {
        *slot = Some(ShardPool::new(shards, need.max(16)));
    }
    // skrull-lint: allow(panic-in-lib) -- the stale branch above just stored Some; None is impossible
    slot.as_mut().expect("just ensured")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;

    fn seqs(lens: &[u32]) -> Vec<Sequence> {
        lens.iter()
            .enumerate()
            .map(|(i, &len)| Sequence { id: i as u64, len })
            .collect()
    }

    #[test]
    fn pool_matches_reference_and_recycles_bins() {
        let flops = FlopsModel::new(&ModelSpec::qwen2_5_0_5b());
        let mut cfg = GdsConfig::new(8 * 1024, 4, 4);
        cfg.shards = 3;
        let batch = seqs(&[100, 9_000, 250, 30_000, 90, 800, 12_000, 400, 7_000, 50]);
        let reference = gds::schedule_reference(&batch, &cfg, &flops).unwrap();
        let mut ctx = gds::SchedCtx::default();
        // two calls through the same pool: identical both times, and the
        // second proves the bins/arenas survive the round trip
        for _ in 0..2 {
            let sharded = gds::schedule_with_ctx(&batch, &cfg, &flops, &mut ctx).unwrap();
            assert_eq!(sharded, reference);
        }
    }

    #[test]
    fn pool_survives_dp_and_shard_changes() {
        let flops = FlopsModel::new(&ModelSpec::qwen2_5_0_5b());
        let batch = seqs(&[5_000; 24]);
        let mut ctx = gds::SchedCtx::default();
        for (shards, dp) in [(2usize, 2usize), (2, 6), (4, 6), (4, 3), (7, 5)] {
            let mut cfg = GdsConfig::new(8 * 1024, 4, dp);
            cfg.shards = shards;
            let sharded = gds::schedule_with_ctx(&batch, &cfg, &flops, &mut ctx).unwrap();
            let reference = gds::schedule_reference(&batch, &cfg, &flops).unwrap();
            assert_eq!(sharded, reference, "shards={shards} dp={dp}");
        }
    }

    #[test]
    fn pool_reports_errors_like_the_serial_path() {
        let flops = FlopsModel::new(&ModelSpec::qwen2_5_0_5b());
        let mut cfg = GdsConfig::new(1024, 2, 4);
        cfg.shards = 2;
        // one sequence above the C·N cap → the same TooLong error the
        // reference produces, from whichever rank sees it first
        let batch = seqs(&[100, 300_000, 200, 400]);
        let mut ctx = gds::SchedCtx::default();
        let sharded = gds::schedule_with_ctx(&batch, &cfg, &flops, &mut ctx);
        let reference = gds::schedule_reference(&batch, &cfg, &flops);
        assert_eq!(sharded.unwrap_err(), reference.unwrap_err());
    }
}
