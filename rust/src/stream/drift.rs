//! Online distribution-drift detection over the ingestion stream.
//!
//! Long-SFT corpora are non-stationary: bursty long-document phases change
//! the length mix that the capacity plan and the cost estimator were
//! calibrated against.  The detector compares a tumbling window's quantile
//! sketch against the calibration-time baseline sketch and emits a
//! structured [`DriftEvent`] when any probe quantile moves by more than the
//! configured relative threshold.  Events feed `calib::recal` (fresh
//! capacity/padded-token accounting) and surface per cell as
//! `drift_events` in `BENCH_e2e.json` — they never perturb schedules,
//! which by the byte-identity invariant depend only on the data and the
//! seed.

use super::reservoir::LengthSketch;

/// Probe quantiles compared between the window and the baseline.  The far
/// tail (p99+) of a few-thousand-sample window is too noisy to gate on;
/// the body and shoulder move decisively under a real mix shift.
pub const DRIFT_PROBES: [f64; 3] = [0.25, 0.5, 0.9];

#[derive(Debug, Clone, PartialEq)]
pub struct DriftConfig {
    /// Tumbling-window size in sequences; the first full window becomes
    /// the calibration baseline.
    pub window: usize,
    /// Relative quantile displacement that fires an event.
    pub threshold: f64,
    /// Windows to stay silent after firing (hysteresis).
    pub cooldown_windows: u32,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig { window: 1024, threshold: 0.30, cooldown_windows: 1 }
    }
}

/// One detected mix shift, in ingestion order.
#[derive(Debug, Clone, PartialEq)]
pub struct DriftEvent {
    /// Sequences ingested when the window closed.
    pub at: u64,
    /// Largest relative probe displacement vs the baseline.
    pub rel_change: f64,
    /// Median of the offending window vs the baseline's.
    pub window_p50: u32,
    pub baseline_p50: u32,
    /// Shoulder (p90) of the offending window vs the baseline's.
    pub window_p90: u32,
    pub baseline_p90: u32,
}

#[derive(Debug, Clone)]
pub struct DriftDetector {
    cfg: DriftConfig,
    baseline: Option<LengthSketch>,
    window: Vec<u32>,
    last_window: Option<LengthSketch>,
    seen: u64,
    cooldown: u32,
    events: u64,
}

impl DriftDetector {
    pub fn new(cfg: DriftConfig) -> Self {
        let cap = cfg.window.max(1);
        DriftDetector {
            cfg,
            baseline: None,
            window: Vec::with_capacity(cap),
            last_window: None,
            seen: 0,
            cooldown: 0,
            events: 0,
        }
    }

    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Events fired so far.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// The calibration-time (or last rebased) reference sketch.
    pub fn baseline(&self) -> Option<&LengthSketch> {
        self.baseline.as_ref()
    }

    /// The most recent completed window's sketch.
    pub fn last_window(&self) -> Option<&LengthSketch> {
        self.last_window.as_ref()
    }

    /// Feed one length from the ingestion stream; returns an event when a
    /// window closes beyond the threshold.
    pub fn observe(&mut self, len: u32) -> Option<DriftEvent> {
        self.seen += 1;
        self.window.push(len);
        if self.window.len() < self.cfg.window.max(1) {
            return None;
        }
        let sketch = LengthSketch::from_lengths(&self.window);
        self.window.clear();
        let Some(base) = self.baseline.as_ref() else {
            // first full window: calibration baseline
            self.baseline = Some(sketch);
            return None;
        };
        let d = sketch.rel_distance(base, &DRIFT_PROBES);
        let fired = d > self.cfg.threshold && self.cooldown == 0;
        if self.cooldown > 0 {
            self.cooldown -= 1;
        }
        let ev = if fired {
            self.cooldown = self.cfg.cooldown_windows;
            self.events += 1;
            Some(DriftEvent {
                at: self.seen,
                rel_change: d,
                window_p50: sketch.quantile(0.5),
                baseline_p50: base.quantile(0.5),
                window_p90: sketch.quantile(0.9),
                baseline_p90: base.quantile(0.9),
            })
        } else {
            None
        };
        self.last_window = Some(sketch);
        ev
    }

    /// Re-baseline after recalibration: the most recent full window becomes
    /// the new reference mix and the hysteresis resets.
    pub fn rebase(&mut self) {
        if let Some(s) = self.last_window.take() {
            self.baseline = Some(s);
        }
        self.cooldown = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn detector(window: usize) -> DriftDetector {
        DriftDetector::new(DriftConfig { window, threshold: 0.30, cooldown_windows: 1 })
    }

    #[test]
    fn fires_on_injected_mix_shift_and_rebase_silences() {
        let mut d = detector(100);
        let mut events = Vec::new();
        // calibration + one stationary window of short docs
        for _ in 0..200 {
            if let Some(e) = d.observe(100) {
                events.push(e);
            }
        }
        assert!(events.is_empty());
        // shift to long docs: the next full window must fire
        for _ in 0..100 {
            if let Some(e) = d.observe(5000) {
                events.push(e);
            }
        }
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].at, 300);
        assert!(events[0].rel_change > 0.9);
        assert_eq!(events[0].baseline_p50, 100);
        assert_eq!(events[0].window_p50, 5000);
        // after rebasing onto the shifted window, the new mix is quiet
        d.rebase();
        for _ in 0..300 {
            assert!(d.observe(5000).is_none());
        }
    }

    #[test]
    fn cooldown_suppresses_back_to_back_windows() {
        let mut d = detector(50);
        for _ in 0..50 {
            d.observe(10);
        }
        let mut fired = 0;
        // three shifted windows without rebase: fire, cool down, fire again
        for _ in 0..150 {
            if d.observe(9000).is_some() {
                fired += 1;
            }
        }
        assert_eq!(fired, 2);
    }

    #[test]
    fn stays_silent_on_stationary_mix_across_seeds() {
        use crate::data::LengthDistribution;
        use crate::rng::Rng;
        for dist in [LengthDistribution::wikipedia(), LengthDistribution::chatqa2()] {
            for seed in [1u64, 2, 3] {
                let mut rng = Rng::seed_from_u64(seed);
                let lens = dist.sample_many(&mut rng, 8192);
                let mut d = detector(1024);
                for &l in &lens {
                    assert!(
                        d.observe(l).is_none(),
                        "{} seed {seed} fired spuriously",
                        dist.name()
                    );
                }
            }
        }
    }
}
