//! Streaming batch source: the `cluster::run` / `ScheduledLoader` facing
//! side of the spill store.
//!
//! The byte-identity invariant lives here: the source replays *exactly*
//! the RNG draw sequence of the in-memory path (`Dataset::sample_batch`'s
//! one `rng.below(n)` per slot; `Dataset::epoch_order`'s seeded
//! Fisher-Yates shuffle) and resolves each drawn id through the page
//! cache.  Same seed, same ids, same lengths ⇒ the scheduler sees the
//! same batches and emits byte-identical schedules — the page cache can
//! only change how many disk reads happen, never what the scheduler sees.

use std::path::Path;

use super::spill::{SpillError, SpillStore};
use super::StreamConfig;
use crate::data::dataset::shuffled_order;
use crate::data::Sequence;
use crate::rng::Rng;

/// A spilled corpus opened for scheduling: bounded-RAM random access plus
/// the two batch-filling modes (`Sampled` replay and epoch order).
pub struct StreamSource {
    store: SpillStore,
    name: String,
}

impl StreamSource {
    /// Open under the `[stream]` config's RAM budget (leader role).
    pub fn open(path: &Path, cfg: &StreamConfig) -> Result<StreamSource, SpillError> {
        StreamSource::open_with_budget(path, cfg.budget_bytes())
    }

    /// Open with an explicit cache budget in bytes (tests use tiny budgets
    /// to force eviction).
    pub fn open_with_budget(path: &Path, budget_bytes: u64) -> Result<StreamSource, SpillError> {
        let store = SpillStore::open(path, budget_bytes)?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        Ok(StreamSource { store, name })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn len(&self) -> u64 {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    /// See [`SpillStore::peak_resident_bytes`].
    pub fn peak_resident_bytes(&self) -> u64 {
        self.store.peak_resident_bytes()
    }

    pub fn budget_bytes(&self) -> u64 {
        self.store.budget_bytes()
    }

    /// Fill one i.i.d. global batch, drawing ids exactly like
    /// `Dataset::sample_batch` (one `rng.below(n)` per slot) so a loader
    /// seeded identically sees identical batches.  Hot path.
    pub fn fill_sampled_batch(
        &mut self,
        rng: &mut Rng,
        batch_size: usize,
        out: &mut Vec<Sequence>,
    ) -> Result<(), SpillError> {
        out.clear();
        let n = self.store.len();
        for _ in 0..batch_size {
            let id = rng.below(n);
            let len = self.store.get(id)?;
            out.push(Sequence { id, len });
        }
        Ok(())
    }

    /// Resolve an explicit id slice (one epoch-order chunk) into `out`.
    pub fn fill_batch_from_ids(
        &mut self,
        ids: &[u64],
        out: &mut Vec<Sequence>,
    ) -> Result<(), SpillError> {
        out.clear();
        for &id in ids {
            let len = self.store.get(id)?;
            out.push(Sequence { id, len });
        }
        Ok(())
    }

    /// The epoch visit order — same seeded shuffle as
    /// `Dataset::epoch_order`, so epoch runs match the in-memory path.
    pub fn epoch_order(&self, seed: u64) -> Vec<u64> {
        shuffled_order(self.store.len(), seed)
    }
}

#[cfg(test)]
mod tests {
    use super::super::spill::spill_lengths;
    use super::*;
    use crate::data::{Dataset, LengthDistribution};

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("skrull-source-{}-{tag}.spill", std::process::id()));
        p
    }

    #[test]
    fn sampled_batches_replay_the_in_memory_draws() {
        let ds = Dataset::synthesize(&LengthDistribution::wikipedia(), 3_000, 17);
        let path = tmp_path("sampled");
        spill_lengths(&ds.lengths, &path, 128).unwrap();
        let mut src = StreamSource::open_with_budget(&path, 4096).unwrap();

        let mut rng_mem = Rng::seed_from_u64(42);
        let mut rng_spill = Rng::seed_from_u64(42);
        let mut batch = Vec::new();
        for _ in 0..20 {
            let expect = ds.sample_batch(&mut rng_mem, 64);
            src.fill_sampled_batch(&mut rng_spill, 64, &mut batch).unwrap();
            assert_eq!(batch, expect);
        }
        assert!(src.peak_resident_bytes() <= 4096);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn epoch_order_matches_dataset() {
        let ds = Dataset::synthesize(&LengthDistribution::chatqa2(), 500, 3);
        let path = tmp_path("epoch");
        spill_lengths(&ds.lengths, &path, 64).unwrap();
        let mut src = StreamSource::open_with_budget(&path, 2048).unwrap();
        let order = src.epoch_order(42);
        assert_eq!(order, ds.epoch_order(42));
        let mut batch = Vec::new();
        for (chunk, expect) in order.chunks(16).zip(ds.epoch_batches(16, 42)) {
            src.fill_batch_from_ids(chunk, &mut batch).unwrap();
            assert_eq!(batch, expect);
        }
        std::fs::remove_file(&path).unwrap();
    }
}
