//! Disk-spilled sequence store: the out-of-core half of the streaming data
//! plane.
//!
//! A corpus is spilled once to a versioned, checksummed on-disk file (the
//! same magic/version/FNV-1a layering as the trainer's `ResumePoint`
//! checkpoint codec in `coordinator::state` and the fleet's preemption
//! codec), then read back through a bounded-RAM page cache.  Schedules
//! built from the store are byte-identical to the in-memory path because
//! the store returns exactly the lengths that were spilled — the cache is
//! purely a capacity lever, never a semantic one.
//!
//! On-disk layout (all integers little-endian):
//!
//! ```text
//! magic    8 bytes   "SKRLSPL\0"
//! version  u32
//! n_seqs   u64
//! page_len u32       sequences per page
//! hdr_crc  u64       FNV-1a over the 24 bytes above
//! page 0   page_len × u32 lengths, then u64 FNV-1a over those bytes
//! page 1   …
//! page P-1 the tail page holds n_seqs − (P−1)·page_len entries
//! ```
//!
//! Every full page occupies `page_len·4 + 8` bytes, so page *i* starts at
//! `HEADER_LEN + i·(page_len·4 + 8)` without an index structure.
//!
//! The cache budget follows a leader/follower dial in the spirit of
//! SNIPPETS.md's Dynamic RAM Policy: the leader fills up to 85% of the
//! configured byte budget, followers stop at 70% to leave headroom.  The
//! dial is a *pure function of the configured budget* — no wall-clock and
//! no `/proc` reads anywhere near the schedule-affecting path, so runs
//! stay deterministic.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;

use crate::coordinator::state::fnv1a;

pub const SPILL_MAGIC: &[u8; 8] = b"SKRLSPL\0";
pub const SPILL_VERSION: u32 = 1;

/// magic + version + n_seqs + page_len + header CRC.
pub const HEADER_LEN: usize = 8 + 4 + 8 + 4 + 8;
const PAGE_CRC_LEN: usize = 8;
/// Sentinel in `page_frame` / `frame_page` for "not resident".
const NO_FRAME: u32 = u32::MAX;
const NO_PAGE: u64 = u64::MAX;

#[derive(Debug)]
pub enum SpillError {
    Io(std::io::Error),
    BadMagic,
    BadVersion(u32),
    BadHeaderChecksum,
    BadPageChecksum { page: u64 },
    Truncated { need: u64, got: u64 },
    BadPageLen,
    OutOfRange { id: u64, n_seqs: u64 },
    /// The configured cache budget cannot hold even a single page.
    BudgetTooSmall { budget_bytes: u64, page_bytes: u64 },
}

impl std::fmt::Display for SpillError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpillError::Io(e) => write!(f, "spill i/o error: {e}"),
            SpillError::BadMagic => write!(f, "not a skrull spill file (bad magic)"),
            SpillError::BadVersion(v) => {
                write!(f, "unsupported spill version {v} (expected {SPILL_VERSION})")
            }
            SpillError::BadHeaderChecksum => write!(f, "spill header checksum mismatch"),
            SpillError::BadPageChecksum { page } => {
                write!(f, "spill page {page} checksum mismatch")
            }
            SpillError::Truncated { need, got } => {
                write!(f, "spill file truncated: need {need} bytes, got {got}")
            }
            SpillError::BadPageLen => write!(f, "spill page_len must be positive"),
            SpillError::OutOfRange { id, n_seqs } => {
                write!(f, "sequence id {id} out of range (spill holds {n_seqs})")
            }
            SpillError::BudgetTooSmall { budget_bytes, page_bytes } => write!(
                f,
                "stream RAM budget of {budget_bytes} bytes cannot hold one {page_bytes}-byte page"
            ),
        }
    }
}

impl std::error::Error for SpillError {}

impl From<std::io::Error> for SpillError {
    fn from(e: std::io::Error) -> Self {
        SpillError::Io(e)
    }
}

/// Role in the leader/follower RAM dial (SNIPPETS.md "Dynamic RAM
/// Policy"): the leader may fill a larger share of the configured budget
/// than followers, which keep headroom for the leader's bursts.  The
/// single-process CLI always runs as `Leader`; `Follower` exists for
/// multi-store deployments (e.g. one store per fleet tenant).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RamRole {
    Leader,
    Follower,
}

impl RamRole {
    /// Upper edge of the policy band, percent of the configured budget
    /// (leader 65–85%, follower 50–70%; the cache sizes against the top).
    pub fn target_percent(self) -> u64 {
        match self {
            RamRole::Leader => 85,
            RamRole::Follower => 70,
        }
    }

    /// Lower edge of the band (reported for observability; the page cache
    /// never shrinks below one frame).
    pub fn low_percent(self) -> u64 {
        match self {
            RamRole::Leader => 65,
            RamRole::Follower => 50,
        }
    }
}

/// Pure dial: how many page frames a role may hold under `budget_bytes`.
/// Always at least one frame; the caller rejects budgets below one page.
pub fn frames_for_budget(role: RamRole, budget_bytes: u64, page_bytes: u64) -> u64 {
    if page_bytes == 0 {
        return 1;
    }
    (budget_bytes / 100 * role.target_percent() / page_bytes)
        .max(budget_bytes * role.target_percent() / 100 / page_bytes)
        .max(1)
}

/// Spill a length corpus to `path` (write-to-temp then rename, with both
/// the file *and its parent directory* fsynced — a rename is only durable
/// once the directory entry is on disk).
pub fn spill_lengths(lengths: &[u32], path: &Path, page_len: u32) -> Result<(), SpillError> {
    if page_len == 0 {
        return Err(SpillError::BadPageLen);
    }
    let mut buf: Vec<u8> = Vec::with_capacity(HEADER_LEN + lengths.len() * 4);
    buf.extend_from_slice(SPILL_MAGIC);
    buf.extend_from_slice(&SPILL_VERSION.to_le_bytes());
    buf.extend_from_slice(&(lengths.len() as u64).to_le_bytes());
    buf.extend_from_slice(&page_len.to_le_bytes());
    let hdr_crc = fnv1a(&buf);
    buf.extend_from_slice(&hdr_crc.to_le_bytes());
    let mut page: Vec<u8> = Vec::with_capacity(page_len as usize * 4);
    for chunk in lengths.chunks(page_len as usize) {
        page.clear();
        for &len in chunk {
            page.extend_from_slice(&len.to_le_bytes());
        }
        let crc = fnv1a(&page);
        buf.extend_from_slice(&page);
        buf.extend_from_slice(&crc.to_le_bytes());
    }
    crate::util::fsio::write_atomic(path, &buf, "spill.tmp")?;
    Ok(())
}

/// Read-side handle: validated header + bounded page cache.  `get` is the
/// hot path — alloc-free in steady state (frames and the read scratch
/// reach their high-water capacity on first touch and are reused after).
pub struct SpillStore {
    file: File,
    n_seqs: u64,
    page_len: u32,
    n_pages: u64,
    budget_bytes: u64,
    /// Decoded lengths per frame (capacity grows once, on first load).
    frames: Vec<Vec<u32>>,
    /// Which page each frame holds (`NO_PAGE` = empty).
    frame_page: Vec<u64>,
    /// Last-access tick per frame (deterministic LRU).
    frame_tick: Vec<u64>,
    /// Which frame each page lives in (`NO_FRAME` = not resident).
    page_frame: Vec<u32>,
    tick: u64,
    /// Frames that have ever held a page — the RSS high-water mark.
    loaded_frames: usize,
    /// Read scratch, reused across page loads.
    page_buf: Vec<u8>,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl SpillStore {
    /// Open as `RamRole::Leader` under `budget_bytes` of cache RAM.
    pub fn open(path: &Path, budget_bytes: u64) -> Result<SpillStore, SpillError> {
        SpillStore::open_as(path, budget_bytes, RamRole::Leader)
    }

    pub fn open_as(path: &Path, budget_bytes: u64, role: RamRole) -> Result<SpillStore, SpillError> {
        // Sweep this store's own orphaned tmp file (a crash between
        // `write_all` and `rename` in `spill_lengths` leaks one).  Only the
        // sibling tmp is removed — never a directory-wide glob, which would
        // race parallel workers spilling into a shared --spill-dir.
        let stale = path.with_extension("spill.tmp");
        match std::fs::remove_file(&stale) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => return Err(SpillError::Io(e)),
        }
        let mut file = File::open(path)?;
        let file_len = file.metadata()?.len();
        let mut header = [0u8; HEADER_LEN];
        if let Err(e) = file.read_exact(&mut header) {
            return Err(if e.kind() == std::io::ErrorKind::UnexpectedEof {
                SpillError::Truncated { need: HEADER_LEN as u64, got: file_len }
            } else {
                SpillError::Io(e)
            });
        }
        if &header[..8] != SPILL_MAGIC {
            return Err(SpillError::BadMagic);
        }
        let mut crc = [0u8; 8];
        crc.copy_from_slice(&header[HEADER_LEN - 8..]);
        if fnv1a(&header[..HEADER_LEN - 8]) != u64::from_le_bytes(crc) {
            return Err(SpillError::BadHeaderChecksum);
        }
        let mut v4 = [0u8; 4];
        v4.copy_from_slice(&header[8..12]);
        let version = u32::from_le_bytes(v4);
        if version != SPILL_VERSION {
            return Err(SpillError::BadVersion(version));
        }
        let mut n8 = [0u8; 8];
        n8.copy_from_slice(&header[12..20]);
        let n_seqs = u64::from_le_bytes(n8);
        let mut p4 = [0u8; 4];
        p4.copy_from_slice(&header[20..24]);
        let page_len = u32::from_le_bytes(p4);
        if page_len == 0 {
            return Err(SpillError::BadPageLen);
        }
        let n_pages = n_seqs.div_ceil(page_len as u64);
        let full_page_bytes = page_len as u64 * 4 + PAGE_CRC_LEN as u64;
        let expected = if n_pages == 0 {
            HEADER_LEN as u64
        } else {
            let tail_entries = n_seqs - (n_pages - 1) * page_len as u64;
            HEADER_LEN as u64
                + (n_pages - 1) * full_page_bytes
                + tail_entries * 4
                + PAGE_CRC_LEN as u64
        };
        if file_len < expected {
            return Err(SpillError::Truncated { need: expected, got: file_len });
        }
        let page_bytes = page_len as u64 * 4;
        if budget_bytes < page_bytes {
            return Err(SpillError::BudgetTooSmall { budget_bytes, page_bytes });
        }
        let n_frames_u64 = frames_for_budget(role, budget_bytes, page_bytes).min(n_pages.max(1));
        let n_frames = usize::try_from(n_frames_u64).unwrap_or(usize::MAX);
        let mut frames = Vec::with_capacity(n_frames);
        frames.resize_with(n_frames, Vec::new);
        Ok(SpillStore {
            file,
            n_seqs,
            page_len,
            n_pages,
            budget_bytes,
            frames,
            frame_page: vec![NO_PAGE; n_frames],
            frame_tick: vec![0; n_frames],
            page_frame: vec![
                NO_FRAME;
                usize::try_from(n_pages).unwrap_or(usize::MAX)
            ],
            tick: 0,
            loaded_frames: 0,
            page_buf: Vec::with_capacity(page_len as usize * 4 + PAGE_CRC_LEN),
            cache_hits: 0,
            cache_misses: 0,
        })
    }

    pub fn len(&self) -> u64 {
        self.n_seqs
    }

    pub fn is_empty(&self) -> bool {
        self.n_seqs == 0
    }

    pub fn page_len(&self) -> u32 {
        self.page_len
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// High-water mark of cache RAM actually filled with page data, in
    /// bytes.  Deterministic accounting (frames × page bytes), never an OS
    /// RSS probe — so the bounded-memory invariant is testable exactly:
    /// `peak_resident_bytes() ≤ budget_bytes` always holds by construction.
    pub fn peak_resident_bytes(&self) -> u64 {
        self.loaded_frames as u64 * self.page_len as u64 * 4
    }

    /// Length of sequence `id`, via the page cache.  Hot path.
    pub fn get(&mut self, id: u64) -> Result<u32, SpillError> {
        if id >= self.n_seqs {
            return Err(SpillError::OutOfRange { id, n_seqs: self.n_seqs });
        }
        let page = id / self.page_len as u64;
        let slot = (id % self.page_len as u64) as usize;
        self.tick += 1;
        let f = self.page_frame[page as usize];
        if f != NO_FRAME {
            self.frame_tick[f as usize] = self.tick;
            self.cache_hits += 1;
            return Ok(self.frames[f as usize][slot]);
        }
        self.cache_misses += 1;
        let f = self.evict_lru();
        self.load_page(page, f)?;
        Ok(self.frames[f][slot])
    }

    /// Deterministic LRU: the frame with the oldest access tick wins;
    /// never-used frames (tick 0) win first, ties break to the lowest
    /// index.  No hashing, no clocks — eviction order is a pure function
    /// of the access sequence.
    fn evict_lru(&mut self) -> usize {
        let mut best = 0usize;
        let mut best_tick = self.frame_tick[0];
        for (i, &t) in self.frame_tick.iter().enumerate().skip(1) {
            if t < best_tick {
                best = i;
                best_tick = t;
            }
        }
        let old = self.frame_page[best];
        if old != NO_PAGE {
            self.page_frame[old as usize] = NO_FRAME;
        }
        best
    }

    fn load_page(&mut self, page: u64, frame: usize) -> Result<(), SpillError> {
        let pl = self.page_len as u64;
        let entries = if page + 1 == self.n_pages {
            (self.n_seqs - page * pl) as usize
        } else {
            pl as usize
        };
        let nbytes = entries * 4 + PAGE_CRC_LEN;
        let off = HEADER_LEN as u64 + page * (pl * 4 + PAGE_CRC_LEN as u64);
        self.file.seek(SeekFrom::Start(off))?;
        self.page_buf.resize(nbytes, 0);
        if let Err(e) = self.file.read_exact(&mut self.page_buf) {
            return Err(if e.kind() == std::io::ErrorKind::UnexpectedEof {
                SpillError::Truncated { need: off + nbytes as u64, got: off }
            } else {
                SpillError::Io(e)
            });
        }
        let (data, crc_bytes) = self.page_buf.split_at(entries * 4);
        let mut crc = [0u8; 8];
        crc.copy_from_slice(crc_bytes);
        if fnv1a(data) != u64::from_le_bytes(crc) {
            return Err(SpillError::BadPageChecksum { page });
        }
        let dst = &mut self.frames[frame];
        dst.clear();
        dst.reserve(entries);
        for c in data.chunks_exact(4) {
            let mut b = [0u8; 4];
            b.copy_from_slice(c);
            dst.push(u32::from_le_bytes(b));
        }
        if self.frame_page[frame] == NO_PAGE {
            self.loaded_frames += 1;
        }
        self.frame_page[frame] = page;
        // skrull-lint: allow(truncating-cast) -- frame indexes the bounded cache pool (≤ budget/page_bytes frames), far below u32::MAX
        self.page_frame[page as usize] = frame as u32;
        self.frame_tick[frame] = self.tick;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("skrull-spill-{}-{tag}.spill", std::process::id()));
        p
    }

    #[test]
    fn spill_round_trips_every_length() {
        let lens: Vec<u32> = (0..1000u32).map(|i| i * 7 + 1).collect();
        let path = tmp_path("roundtrip");
        spill_lengths(&lens, &path, 64).unwrap();
        let mut store = SpillStore::open(&path, 1 << 20).unwrap();
        assert_eq!(store.len(), 1000);
        for (i, &l) in lens.iter().enumerate() {
            assert_eq!(store.get(i as u64).unwrap(), l);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn tiny_budget_forces_eviction_but_stays_bounded() {
        let lens: Vec<u32> = (0..4096u32).collect();
        let path = tmp_path("evict");
        spill_lengths(&lens, &path, 64).unwrap();
        // 600 bytes ≥ one 256-byte page; the 85% dial yields exactly 1 frame
        let mut store = SpillStore::open(&path, 600).unwrap();
        // stride across pages to defeat the cache
        for i in (0..4096u64).step_by(97) {
            assert_eq!(store.get(i).unwrap(), i as u32);
        }
        assert!(store.cache_misses > 1, "eviction never happened");
        assert!(store.peak_resident_bytes() <= 600);
        assert!(store.peak_resident_bytes() > 0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corrupted_page_is_rejected() {
        let lens: Vec<u32> = (0..256u32).collect();
        let path = tmp_path("corrupt");
        spill_lengths(&lens, &path, 64).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // flip one bit inside page 1's data
        let off = HEADER_LEN + (64 * 4 + 8) + 10;
        bytes[off] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let mut store = SpillStore::open(&path, 1 << 20).unwrap();
        assert_eq!(store.get(3).unwrap(), 3); // page 0 intact
        assert!(matches!(store.get(70), Err(SpillError::BadPageChecksum { page: 1 })));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn bad_magic_version_truncation_and_budget_are_rejected() {
        let lens: Vec<u32> = (0..100u32).collect();
        let path = tmp_path("reject");
        spill_lengths(&lens, &path, 32).unwrap();
        let good = std::fs::read(&path).unwrap();

        let mut bad = good.clone();
        bad[0] = b'X';
        std::fs::write(&path, &bad).unwrap();
        assert!(matches!(SpillStore::open(&path, 1 << 20), Err(SpillError::BadMagic)));

        let mut bad = good.clone();
        bad[8] = 99; // version byte — caught by the header CRC first? no:
                     // the CRC covers the version too, so this is a checksum error
        std::fs::write(&path, &bad).unwrap();
        assert!(matches!(
            SpillStore::open(&path, 1 << 20),
            Err(SpillError::BadHeaderChecksum)
        ));

        std::fs::write(&path, &good[..good.len() - 4]).unwrap();
        assert!(matches!(SpillStore::open(&path, 1 << 20), Err(SpillError::Truncated { .. })));

        std::fs::write(&path, &good).unwrap();
        assert!(matches!(
            SpillStore::open(&path, 16),
            Err(SpillError::BudgetTooSmall { .. })
        ));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn open_sweeps_own_orphaned_tmp() {
        // Regression: a crash between write and rename leaks `X.spill.tmp`;
        // `open` must clean it up without touching unrelated files.
        let lens: Vec<u32> = (0..64u32).collect();
        let path = tmp_path("orphan");
        spill_lengths(&lens, &path, 32).unwrap();
        let orphan = path.with_extension("spill.tmp");
        std::fs::write(&orphan, b"half-written junk").unwrap();
        let unrelated = path.with_extension("other.spill.tmp");
        std::fs::write(&unrelated, b"someone else's in-flight tmp").unwrap();
        let mut store = SpillStore::open(&path, 1 << 20).unwrap();
        assert_eq!(store.get(5).unwrap(), 5);
        assert!(!orphan.exists(), "own orphan tmp must be swept on open");
        assert!(unrelated.exists(), "sweep must not touch other tmp files");
        std::fs::remove_file(&unrelated).unwrap();
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn dial_is_pure_and_ordered() {
        let pb = 4096u64;
        let leader = frames_for_budget(RamRole::Leader, 1 << 24, pb);
        let follower = frames_for_budget(RamRole::Follower, 1 << 24, pb);
        assert!(leader > follower);
        assert_eq!(leader, frames_for_budget(RamRole::Leader, 1 << 24, pb));
        assert!(leader * pb <= 1 << 24);
        assert_eq!(frames_for_budget(RamRole::Leader, 0, pb), 1);
    }
}
