//! Streaming out-of-core data plane.
//!
//! Production corpora do not fit in RAM and arrive continuously.  This
//! subsystem replaces the fully-materialized [`crate::data::Dataset`] on
//! demand with a three-stage streaming path:
//!
//! 1. **Ingest** ([`ingest_dataset`]): the corpus streams once, in corpus
//!    order, into a checksummed on-disk spill file ([`spill`]) while a
//!    seeded stratified reservoir ([`reservoir`]) sketches the length
//!    distribution and a windowed quantile detector ([`drift`]) watches
//!    for mix shifts, re-triggering capacity/estimator recalibration
//!    (`calib::recal`) on every event.
//! 2. **Schedule** ([`source::StreamSource`]): batches are filled through
//!    a bounded-RAM page cache, replaying the in-memory path's RNG draws
//!    exactly — schedules are byte-identical to a `Dataset`-backed run
//!    (`cluster::run::build_run_streamed`, enforced by test and the CI
//!    digest `cmp` gate).
//! 3. **Account**: `peak_stream_rss_bytes` (deterministic cache
//!    accounting, ≤ the configured budget by construction) and
//!    `drift_events` surface per cell in schema-v5 `BENCH_e2e.json`.

pub mod drift;
pub mod reservoir;
pub mod source;
pub mod spill;

pub use drift::{DriftConfig, DriftDetector, DriftEvent, DRIFT_PROBES};
pub use reservoir::{LengthSketch, Reservoir, StratifiedReservoir};
pub use source::StreamSource;
pub use spill::{spill_lengths, RamRole, SpillError, SpillStore};

use std::path::Path;

use crate::calib::recal::{recalibrate, Recalibration};
use crate::data::Dataset;

/// The `[stream]` config table: spill location, cache budget and the
/// sketching/drift knobs.  Everything is an explicit value — the RAM
/// budget is a byte count from config, never a `/proc` or wall-clock
/// reading, so cache sizing is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamConfig {
    /// Directory for spill files; `Some` switches the e2e sweep (and
    /// `build_run_streamed` callers) onto the out-of-core path.
    pub spill_dir: Option<String>,
    /// Page-cache budget in MiB (`--stream-ram-mb`).
    pub ram_mb: usize,
    /// Sequences per spill page.
    pub page_len: u32,
    /// Stratification shards for the reservoir sketch.
    pub reservoir_shards: usize,
    /// Reservoir capacity per shard.
    pub reservoir_per_shard: usize,
    /// Drift tumbling-window size in sequences.
    pub drift_window: usize,
    /// Relative quantile displacement that fires a drift event.
    pub drift_threshold: f64,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            spill_dir: None,
            ram_mb: 64,
            page_len: 1024,
            reservoir_shards: 16,
            reservoir_per_shard: 256,
            drift_window: 1024,
            drift_threshold: 0.30,
        }
    }
}

impl StreamConfig {
    pub fn enabled(&self) -> bool {
        self.spill_dir.is_some()
    }

    pub fn budget_bytes(&self) -> u64 {
        self.ram_mb as u64 * 1024 * 1024
    }

    pub fn drift_config(&self) -> DriftConfig {
        DriftConfig {
            window: self.drift_window,
            threshold: self.drift_threshold,
            ..DriftConfig::default()
        }
    }
}

/// Everything the single ingestion pass learned about the corpus.
#[derive(Debug, Clone)]
pub struct IngestReport {
    pub sequences: u64,
    pub total_tokens: u64,
    /// Stratified-reservoir length sketch (what GDS/memplan consumers see
    /// instead of a full scan).
    pub sketch: LengthSketch,
    /// Mix shifts detected in corpus order.
    pub drift_events: Vec<DriftEvent>,
    /// One recalibration per drift event (accounting only — schedules
    /// never depend on these).
    pub recalibrations: Vec<Recalibration>,
}

/// Spill `lengths` to `path` and stream them once through the reservoir
/// sketch and the drift detector.  `seed` drives the reservoir's RNG
/// streams; the detector is deterministic given the corpus order.
pub fn ingest_lengths(
    lengths: &[u32],
    path: &Path,
    cfg: &StreamConfig,
    seed: u64,
) -> Result<IngestReport, SpillError> {
    spill_lengths(lengths, path, cfg.page_len)?;
    let mut reservoir =
        StratifiedReservoir::new(cfg.reservoir_shards, cfg.reservoir_per_shard, seed);
    let mut detector = DriftDetector::new(cfg.drift_config());
    let mut drift_events = Vec::new();
    let mut recalibrations = Vec::new();
    let mut total_tokens = 0u64;
    for (i, &len) in lengths.iter().enumerate() {
        total_tokens += len as u64;
        reservoir.observe(i as u64, len);
        if let Some(ev) = detector.observe(len) {
            // drift → recalibration hook: derive fresh capacity accounting
            // from the shifted window, then adopt it as the new baseline
            if let Some(window) = detector.last_window() {
                recalibrations.push(recalibrate(ev.at, window));
            }
            detector.rebase();
            drift_events.push(ev);
        }
    }
    Ok(IngestReport {
        sequences: lengths.len() as u64,
        total_tokens,
        sketch: reservoir.sketch(),
        drift_events,
        recalibrations,
    })
}

/// [`ingest_lengths`] over a materialized dataset (the e2e sweep's entry
/// point: synthesize once, spill, then schedule out-of-core).
pub fn ingest_dataset(
    ds: &Dataset,
    path: &Path,
    cfg: &StreamConfig,
    seed: u64,
) -> Result<IngestReport, SpillError> {
    ingest_lengths(&ds.lengths, path, cfg, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LengthDistribution;

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("skrull-ingest-{}-{tag}.spill", std::process::id()));
        p
    }

    #[test]
    fn ingest_reports_drift_and_recalibrations_on_bursty_corpus() {
        let ds = Dataset::synthesize(&LengthDistribution::bursty_long(), 8192, 5);
        let path = tmp_path("bursty");
        let cfg = StreamConfig::default();
        let report = ingest_dataset(&ds, &path, &cfg, 11).unwrap();
        assert_eq!(report.sequences, 8192);
        assert_eq!(report.total_tokens, ds.total_tokens());
        assert!(!report.drift_events.is_empty(), "bursty phases must fire drift");
        assert_eq!(report.drift_events.len(), report.recalibrations.len());
        for (ev, rc) in report.drift_events.iter().zip(&report.recalibrations) {
            assert_eq!(ev.at, rc.at);
            assert!(rc.suggested_bucket > 0);
        }
        assert!(!report.sketch.is_empty());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn ingest_is_silent_on_stationary_corpus() {
        let ds = Dataset::synthesize(&LengthDistribution::wikipedia(), 8192, 5);
        let path = tmp_path("flat");
        let report = ingest_dataset(&ds, &path, &StreamConfig::default(), 11).unwrap();
        assert!(report.drift_events.is_empty());
        assert!(report.recalibrations.is_empty());
        std::fs::remove_file(&path).unwrap();
    }
}
