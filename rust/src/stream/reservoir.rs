//! Deterministic reservoir length-sketching.
//!
//! GDS and memplan want an accurate picture of the length distribution —
//! bucket feasibility, capacity planning, padded-token estimates — but an
//! out-of-core corpus cannot be scanned on demand.  A seeded reservoir
//! (Vitter's Algorithm R on our xoshiro256++ streams) keeps a bounded,
//! uniform sample per shard while the corpus streams through ingestion
//! once; stratifying by `id % shards` keeps every region of the corpus
//! represented even under adversarial orderings.  Same seed ⇒ same sketch,
//! bit-for-bit — the sketch is diagnostic/calibration state and never
//! feeds back into schedules (the byte-identity invariant).

use crate::rng::Rng;

/// Vitter Algorithm R over one stratum: a uniform sample of everything
/// observed, held in arrival order of the surviving items.
#[derive(Debug, Clone)]
pub struct Reservoir {
    cap: usize,
    items: Vec<u32>,
    seen: u64,
    rng: Rng,
}

impl Reservoir {
    pub fn new(cap: usize, rng: Rng) -> Self {
        Reservoir { cap, items: Vec::with_capacity(cap), seen: 0, rng }
    }

    pub fn observe(&mut self, len: u32) {
        self.seen += 1;
        if self.items.len() < self.cap {
            self.items.push(len);
            return;
        }
        if self.cap == 0 {
            return;
        }
        // t-th item replaces a slot with probability cap/t
        let j = self.rng.below(self.seen);
        if (j as usize) < self.cap {
            self.items[j as usize] = len;
        }
    }

    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn items(&self) -> &[u32] {
        &self.items
    }
}

/// Per-shard stratified reservoir: shard `id % n_shards` samples its own
/// stratum with an independent forked RNG stream.
#[derive(Debug, Clone)]
pub struct StratifiedReservoir {
    shards: Vec<Reservoir>,
}

impl StratifiedReservoir {
    pub fn new(n_shards: usize, per_shard: usize, seed: u64) -> Self {
        let mut base = Rng::seed_from_u64(seed);
        let shards = (0..n_shards.max(1))
            .map(|s| Reservoir::new(per_shard, base.fork(s as u64)))
            .collect();
        StratifiedReservoir { shards }
    }

    pub fn observe(&mut self, id: u64, len: u32) {
        let s = (id % self.shards.len() as u64) as usize;
        self.shards[s].observe(len);
    }

    pub fn seen(&self) -> u64 {
        self.shards.iter().map(Reservoir::seen).sum()
    }

    /// Merge every shard's sample into one sorted sketch.
    pub fn sketch(&self) -> LengthSketch {
        let mut all: Vec<u32> = Vec::new();
        for sh in &self.shards {
            all.extend_from_slice(sh.items());
        }
        LengthSketch::from_unsorted(all)
    }
}

/// A sorted sample of sequence lengths with quantile/mean accessors — the
/// unit both the drift detector and the recalibration hook consume.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LengthSketch {
    sorted: Vec<u32>,
}

impl LengthSketch {
    pub fn from_unsorted(mut lens: Vec<u32>) -> Self {
        lens.sort_unstable();
        LengthSketch { sorted: lens }
    }

    pub fn from_lengths(lens: &[u32]) -> Self {
        LengthSketch::from_unsorted(lens.to_vec())
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// Nearest-rank quantile; 0 on an empty sketch.
    pub fn quantile(&self, q: f64) -> u32 {
        if self.sorted.is_empty() {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let idx = ((self.sorted.len() - 1) as f64 * q).round() as usize;
        self.sorted[idx.min(self.sorted.len() - 1)]
    }

    pub fn mean(&self) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        let total: u64 = self.sorted.iter().map(|&l| l as u64).sum();
        total as f64 / self.sorted.len() as f64
    }

    pub fn max_len(&self) -> u32 {
        self.sorted.last().copied().unwrap_or(0)
    }

    /// Largest relative quantile displacement between two sketches over the
    /// given probe points — the drift detector's distance measure.
    pub fn rel_distance(&self, other: &LengthSketch, probes: &[f64]) -> f64 {
        let mut worst = 0.0f64;
        for &q in probes {
            let a = self.quantile(q) as f64;
            let b = other.quantile(q) as f64;
            let d = (a - b).abs() / a.max(b).max(1.0);
            if d > worst {
                worst = d;
            }
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LengthDistribution;

    #[test]
    fn same_seed_same_sketch() {
        let mut rng = Rng::seed_from_u64(9);
        let lens: Vec<u32> = (0..50_000).map(|_| rng.range_u32(1, 10_000)).collect();
        let mut a = StratifiedReservoir::new(16, 256, 7);
        let mut b = StratifiedReservoir::new(16, 256, 7);
        let mut c = StratifiedReservoir::new(16, 256, 8);
        for (i, &l) in lens.iter().enumerate() {
            a.observe(i as u64, l);
            b.observe(i as u64, l);
            c.observe(i as u64, l);
        }
        assert_eq!(a.sketch(), b.sketch());
        assert_ne!(a.sketch(), c.sketch());
    }

    #[test]
    fn sketch_quantiles_track_true_distribution() {
        let dist = LengthDistribution::wikipedia();
        let mut rng = Rng::seed_from_u64(3);
        let lens = dist.sample_many(&mut rng, 100_000);
        let truth = LengthSketch::from_lengths(&lens);
        let mut res = StratifiedReservoir::new(16, 512, 5);
        for (i, &l) in lens.iter().enumerate() {
            res.observe(i as u64, l);
        }
        let sketch = res.sketch();
        assert_eq!(sketch.len(), 16 * 512);
        for q in [0.25, 0.5, 0.75, 0.9] {
            let s = sketch.quantile(q) as f64;
            let t = truth.quantile(q) as f64;
            let rel = (s - t).abs() / t.max(1.0);
            assert!(rel < 0.10, "q{q}: sketch {s} vs truth {t} (rel {rel:.3})");
        }
    }

    #[test]
    fn small_corpus_is_kept_whole() {
        let mut res = StratifiedReservoir::new(4, 100, 1);
        for i in 0..50u64 {
            res.observe(i, (i + 1) as u32);
        }
        let sketch = res.sketch();
        assert_eq!(sketch.len(), 50);
        assert_eq!(sketch.quantile(0.0), 1);
        assert_eq!(sketch.quantile(1.0), 50);
        assert_eq!(sketch.max_len(), 50);
        assert!((sketch.mean() - 25.5).abs() < 1e-9);
    }

    #[test]
    fn rel_distance_is_zero_on_self_and_large_on_shift() {
        let a = LengthSketch::from_lengths(&[100, 200, 300, 400, 500]);
        let b = LengthSketch::from_lengths(&[1000, 2000, 3000, 4000, 5000]);
        assert_eq!(a.rel_distance(&a, &[0.25, 0.5, 0.9]), 0.0);
        assert!(a.rel_distance(&b, &[0.25, 0.5, 0.9]) > 0.8);
    }
}
