//! Robust coefficient fitting: from a calibration trace to a
//! [`CalibratedProfile`] the scheduler and simulator can consume.
//!
//! Every model in the trace schema is affine in its features, so each
//! coefficient pair reduces to a 1-D least-squares problem on per-launch
//! means (built on `util::stats::linear_fit`), hardened for real traces:
//! an outlier-trimmed refit (profilers hiccup; a 3σ trim absorbs stray
//! steps), per-coefficient standard errors, and R².  The recovered
//! coefficients are exactly the paper's:
//!
//! * Eq. 14 — `T_comp = α·FLOPs + β` per kernel (compute fit)
//! * Eq. 16 — `T_comm = α·V + T_fixed` per collective, NVLink and IB
//!   fitted separately (intra/inter comm fits)
//! * Eq. 12 — `Peak = Static + α_act·C` (memory fit: the memplan
//!   activation α, measured instead of first-principles)
//! * the per-dispatch framework overhead (median, maximally robust)

use crate::calib::trace::Trace;
use crate::memplan::{MemPlan, MemoryConfig};
use crate::model::ModelSpec;
use crate::perfmodel::comm::INTER_NODE_BW_RATIO;
use crate::perfmodel::{CommModel, CostModel, Hardware};
use crate::util::error::Result;
use crate::util::stats::{linear_fit, median_of};

/// Version stamp of the serialized profile format.
pub const PROFILE_SCHEMA_VERSION: u32 = 1;

/// One fitted line y = slope·x + intercept with quality diagnostics.
#[derive(Clone, Debug, PartialEq)]
pub struct Fit {
    pub slope: f64,
    pub intercept: f64,
    pub r2: f64,
    /// Standard error of the slope (per-coefficient confidence).
    pub slope_stderr: f64,
    pub intercept_stderr: f64,
    /// Samples the final fit used.
    pub n: usize,
    /// Samples the trimmed refit discarded.
    pub outliers_dropped: usize,
}

impl Fit {
    pub fn predict(&self, x: f64) -> f64 {
        self.slope * x + self.intercept
    }

    /// A fit carried over from another fit by a known physical ratio
    /// (e.g. the NVLink→IB bandwidth scaling) rather than from samples.
    pub fn scaled(&self, slope_factor: f64, intercept_factor: f64) -> Fit {
        Fit {
            slope: self.slope * slope_factor,
            intercept: self.intercept * intercept_factor,
            r2: self.r2,
            slope_stderr: self.slope_stderr * slope_factor,
            intercept_stderr: self.intercept_stderr * intercept_factor,
            n: 0,
            outliers_dropped: 0,
        }
    }
}

fn fit_once(xs: &[f64], ys: &[f64]) -> Fit {
    let (slope, intercept, r2) = linear_fit(xs, ys);
    let n = xs.len();
    let mx = xs.iter().sum::<f64>() / n as f64;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (slope * x + intercept);
            e * e
        })
        .sum();
    let (slope_stderr, intercept_stderr) = if n > 2 && sxx > 0.0 {
        let s2 = ss_res / (n - 2) as f64;
        (
            (s2 / sxx).sqrt(),
            (s2 * (1.0 / n as f64 + mx * mx / sxx)).sqrt(),
        )
    } else {
        (0.0, 0.0)
    };
    Fit { slope, intercept, r2, slope_stderr, intercept_stderr, n, outliers_dropped: 0 }
}

fn x_spread_ok(xs: &[f64]) -> bool {
    let lo = xs.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let scale = lo.abs().max(hi.abs()).max(1e-300);
    (hi - lo) / scale > 1e-9
}

/// Least squares with an iterated outlier-trimmed refit: fit, drop samples
/// whose residual exceeds 3× the robust (MAD-based) scale, refit, repeat
/// until stable.  The MAD scale keeps gross profiler hiccups from
/// inflating the cut the way an RMS σ would, and the trim never discards
/// more than half the samples.  Errors on fewer than 2 samples or a
/// degenerate abscissa (all x equal — slope and intercept cannot be
/// separated; vary the workload instead).
pub fn robust_fit(xs: &[f64], ys: &[f64]) -> Result<Fit> {
    const MAX_ROUNDS: usize = 8;
    // MAD → σ for a normal distribution
    const MAD_SCALE: f64 = 1.4826;
    crate::ensure!(xs.len() == ys.len(), "x/y length mismatch");
    crate::ensure!(xs.len() >= 2, "need at least 2 samples, got {}", xs.len());
    crate::ensure!(
        xs.iter().chain(ys).all(|v| v.is_finite()),
        "non-finite sample in fit input"
    );
    crate::ensure!(
        x_spread_ok(xs),
        "degenerate fit: all {} abscissae are (nearly) identical — the trace \
         must vary the workload to separate slope from intercept",
        xs.len()
    );
    let n = xs.len();
    let y_scale = ys.iter().map(|y| y.abs()).fold(0.0, f64::max).max(1e-300);
    let mut idx: Vec<usize> = (0..n).collect();
    let mut fit = fit_once(xs, ys);
    for _ in 0..MAX_ROUNDS {
        let abs_res: Vec<f64> =
            idx.iter().map(|&i| (ys[i] - fit.predict(xs[i])).abs()).collect();
        let sigma = MAD_SCALE * median_of(&abs_res);
        // numerically exact already: don't let fp dust evict valid samples
        if sigma <= 1e-12 * y_scale {
            break;
        }
        let keep: Vec<usize> = idx
            .iter()
            .copied()
            .zip(&abs_res)
            .filter(|(_, r)| **r <= 3.0 * sigma)
            .map(|(i, _)| i)
            .collect();
        if keep.len() == idx.len() || keep.len() < 2 || keep.len() < n.div_ceil(2) {
            break;
        }
        let kx: Vec<f64> = keep.iter().map(|&i| xs[i]).collect();
        if !x_spread_ok(&kx) {
            // trimming collapsed the abscissa; the current fit is safer
            break;
        }
        let ky: Vec<f64> = keep.iter().map(|&i| ys[i]).collect();
        fit = fit_once(&kx, &ky);
        fit.outliers_dropped = n - keep.len();
        idx = keep;
    }
    Ok(fit)
}

/// The calibrated coefficient set: everything the analytic
/// `CostModel`/`MemPlan` pair parameterizes, recovered from measurement.
#[derive(Clone, Debug, PartialEq)]
pub struct CalibratedProfile {
    pub version: u32,
    /// Model the trace was taken on (provenance; fits are per-hardware).
    pub model: String,
    /// Eq. 14: seconds = slope·FLOPs + intercept per kernel.
    pub comp: Fit,
    /// Eq. 16, intra-node (NVLink): seconds = slope·bytes + intercept per
    /// collective.
    pub comm: Fit,
    /// Eq. 16, inter-node (IB).
    pub comm_inter: Fit,
    /// The inter fit was extrapolated from the intra fit (or vice versa)
    /// by the NVLink→IB ratio because the trace had no samples of its own
    /// for that class.
    pub inter_extrapolated: bool,
    /// Per-dispatch framework overhead (median over the trace).
    pub step_overhead_s: f64,
    /// Eq. 12: peak_bytes = slope·bucket_tokens + intercept — the memplan
    /// activation α (slope) and the measured static bytes (intercept).
    /// `None` when the trace ran a single bucket size (degenerate).
    pub mem: Option<Fit>,
    /// Records the fits consumed.
    pub records: usize,
}

impl CalibratedProfile {
    /// The simulator/scheduler cost model implied by the fits.  The
    /// kernel-time curve `w/(peak·eff(w)) + launch` is affine in w, so a
    /// synthesized [`Hardware`] with `eff_max = 1`, `peak = 1/slope` and
    /// `w_half = intercept/slope` reproduces the fitted per-kernel line
    /// exactly; comm models carry the fitted α/T_fixed directly.
    pub fn cost_model(&self, spec: &ModelSpec) -> CostModel {
        let slope = self.comp.slope.max(1e-30);
        let intercept = self.comp.intercept.max(0.0);
        let hw = Hardware {
            peak_flops: 1.0 / slope,
            eff_max: 1.0,
            w_half: intercept / slope,
            launch_overhead_s: 0.0,
            step_overhead_s: self.step_overhead_s.max(0.0),
        };
        let comm = CommModel {
            alpha_s_per_byte: self.comm.slope.max(0.0),
            fixed_s: self.comm.intercept.max(1e-9),
        };
        let inter = CommModel {
            alpha_s_per_byte: self.comm_inter.slope.max(0.0),
            fixed_s: self.comm_inter.intercept.max(1e-9),
        };
        let mut cost = CostModel::new(spec, hw, comm);
        cost.inter_comm = inter;
        cost
    }

    /// The calibrated memory plan for a parallel layout, when the trace
    /// supported a memory fit: measured static bytes + measured activation
    /// slope against the configured HBM budget.
    pub fn mem_plan(&self, spec: &ModelSpec, dp: usize, cp: usize, mem: &MemoryConfig) -> Option<MemPlan> {
        let fit = self.mem.as_ref()?;
        Some(MemPlan::new(spec, dp, cp, mem).with_calibrated(fit.slope, fit.intercept))
    }

    /// Sanity gate on the fitted coefficients themselves (the residual
    /// gate lives in `calib::report::validate`).
    pub fn validate(&self, min_r2: f64) -> Result<()> {
        for (name, fit) in [("comp", &self.comp), ("comm", &self.comm), ("comm_inter", &self.comm_inter)] {
            crate::ensure!(
                fit.slope.is_finite() && fit.slope > 0.0,
                "{name} fit: non-positive slope {}",
                fit.slope
            );
            crate::ensure!(
                fit.intercept.is_finite() && fit.intercept >= 0.0,
                "{name} fit: negative intercept {}",
                fit.intercept
            );
            crate::ensure!(
                fit.r2.is_finite() && fit.r2 >= min_r2,
                "{name} fit: r² {} below {min_r2}",
                fit.r2
            );
        }
        crate::ensure!(
            self.step_overhead_s.is_finite() && self.step_overhead_s >= 0.0,
            "negative step overhead {}",
            self.step_overhead_s
        );
        if let Some(m) = &self.mem {
            crate::ensure!(
                m.slope.is_finite() && m.slope > 0.0,
                "memory fit: non-positive bytes/token {}",
                m.slope
            );
            crate::ensure!(
                m.intercept.is_finite() && m.intercept >= 0.0,
                "memory fit: negative static bytes {}",
                m.intercept
            );
            crate::ensure!(m.r2 >= min_r2, "memory fit: r² {} below {min_r2}", m.r2);
        }
        Ok(())
    }
}

/// Per-launch mean samples for one (seconds, bytes-or-flops, launches)
/// column group.
fn launch_means(
    records: &[crate::calib::trace::TraceRecord],
    select: impl Fn(&crate::calib::trace::TraceRecord) -> (f64, f64, f64),
) -> (Vec<f64>, Vec<f64>) {
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for r in records {
        let (feature, launches, seconds) = select(r);
        if launches > 0.0 {
            xs.push(feature / launches);
            ys.push(seconds / launches);
        }
    }
    (xs, ys)
}

/// Fit every coefficient the trace supports.
pub fn calibrate(trace: &Trace) -> Result<CalibratedProfile> {
    use crate::util::error::Context;
    let recs = &trace.records;
    crate::ensure!(!recs.is_empty(), "empty trace: nothing to calibrate");
    crate::ensure!(
        trace.header.version == crate::calib::trace::TRACE_SCHEMA_VERSION,
        "trace schema v{} but this build reads v{}",
        trace.header.version,
        crate::calib::trace::TRACE_SCHEMA_VERSION
    );

    let (cx, cy) = launch_means(recs, |r| (r.comp_flops, r.comp_kernels, r.comp_seconds));
    let comp = robust_fit(&cx, &cy).context("fitting T_comp = α·FLOPs + β (Eq. 14)")?;

    let (ix, iy) = launch_means(recs, |r| (r.comm_bytes, r.comm_launches, r.comm_seconds));
    let (xx, xy) = launch_means(recs, |r| (r.xcomm_bytes, r.xcomm_launches, r.xcomm_seconds));
    let intra = robust_fit(&ix, &iy);
    let inter = robust_fit(&xx, &xy);
    let (comm, comm_inter, inter_extrapolated) = match (intra, inter) {
        (Ok(a), Ok(b)) => (a, b, false),
        (Ok(a), Err(_)) => {
            let b = a.scaled(INTER_NODE_BW_RATIO, 2.0);
            (a, b, true)
        }
        (Err(_), Ok(b)) => {
            let a = b.scaled(1.0 / INTER_NODE_BW_RATIO, 0.5);
            (a, b, true)
        }
        (Err(e), Err(_)) => {
            return Err(e).context("fitting T_comm = α·V + T_fixed (Eq. 16): no usable samples in either bandwidth class")
        }
    };

    let overheads: Vec<f64> = recs
        .iter()
        .filter(|r| r.dispatches > 0.0)
        .map(|r| r.overhead_seconds / r.dispatches)
        .collect();
    crate::ensure!(
        !overheads.is_empty(),
        "no dispatched micro-batches in the trace: cannot fit the step overhead"
    );
    let step_overhead_s = median_of(&overheads);

    let mx: Vec<f64> = recs.iter().map(|r| r.bucket_tokens as f64).collect();
    let my: Vec<f64> = recs.iter().map(|r| r.peak_bytes).collect();
    // a single bucket size cannot separate static bytes from the slope —
    // that (and only that) degrades gracefully to a cost-only profile;
    // any other memory-fit failure (corrupt peaks, too few records) is a
    // real error the user must see, not a silent `None`
    let mem = if x_spread_ok(&mx) {
        Some(robust_fit(&mx, &my).context("fitting Peak = Static + α_act·C (Eq. 12)")?)
    } else {
        None
    };

    Ok(CalibratedProfile {
        version: PROFILE_SCHEMA_VERSION,
        model: trace.header.model.clone(),
        comp,
        comm,
        comm_inter,
        inter_extrapolated,
        step_overhead_s,
        mem,
        records: recs.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::util::proptest::forall;

    #[test]
    fn robust_fit_recovers_exact_line() {
        let xs: Vec<f64> = (1..40).map(|i| i as f64 * 3.0).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0e-12 * x + 5.0e-5).collect();
        let f = robust_fit(&xs, &ys).unwrap();
        assert!((f.slope - 2.0e-12).abs() / 2.0e-12 < 1e-9);
        assert!((f.intercept - 5.0e-5).abs() < 1e-12);
        assert!(f.r2 > 0.999999);
        assert_eq!(f.outliers_dropped, 0);
        assert_eq!(f.n, xs.len());
    }

    #[test]
    fn robust_fit_survives_injected_noise_and_outliers() {
        // Property (satellite): over random true coefficients, Gaussian-ish
        // noise and a few gross outliers, the trimmed refit recovers the
        // coefficients within a few percent.
        struct CoeffGen;
        impl crate::util::proptest::Gen for CoeffGen {
            type Value = (f64, f64, u64);
            fn generate(&self, rng: &mut Rng) -> Self::Value {
                let slope = 1e-12 * (0.2 + 5.0 * rng.f64());
                let intercept = 1e-5 * (0.5 + 10.0 * rng.f64());
                (slope, intercept, rng.next_u64())
            }
        }
        forall(0xF17, 40, &CoeffGen, |&(slope, intercept, seed)| {
            let mut rng = Rng::seed_from_u64(seed);
            let n = 60;
            let mut xs = Vec::with_capacity(n);
            let mut ys = Vec::with_capacity(n);
            for i in 0..n {
                let x = 1e6 * (1.0 + i as f64) * (0.8 + 0.4 * rng.f64());
                let y_true = slope * x + intercept;
                // ±0.5% multiplicative noise
                let noise = 1.0 + 0.005 * (2.0 * rng.f64() - 1.0);
                let mut y = y_true * noise;
                // ~5% gross outliers (a profiler hiccup: 20x the true value)
                if rng.f64() < 0.05 {
                    y = y_true * 20.0;
                }
                xs.push(x);
                ys.push(y);
            }
            let f = robust_fit(&xs, &ys).map_err(|e| e.to_string())?;
            let ds = (f.slope - slope).abs() / slope;
            if ds > 0.05 {
                return Err(format!("slope off by {ds:.3}: {} vs {slope}", f.slope));
            }
            let di = (f.intercept - intercept).abs() / intercept;
            if di > 0.25 {
                return Err(format!("intercept off by {di:.3}: {} vs {intercept}", f.intercept));
            }
            if f.r2 < 0.99 {
                return Err(format!("r² {} too low after trimming", f.r2));
            }
            Ok(())
        });
    }

    #[test]
    fn outlier_trim_beats_plain_least_squares() {
        // One gross outlier at the far end tilts plain OLS visibly; the
        // trimmed refit removes it.
        let xs: Vec<f64> = (1..=30).map(|i| i as f64).collect();
        let mut ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 1.0).collect();
        // tiny jitter so sigma is non-zero and trimming engages
        for (i, y) in ys.iter_mut().enumerate() {
            *y += if i % 2 == 0 { 1e-3 } else { -1e-3 };
        }
        ys[29] = 3.0 * 30.0 * 10.0; // 10x hiccup on the last sample
        let (plain_slope, _, _) = linear_fit(&xs, &ys);
        let f = robust_fit(&xs, &ys).unwrap();
        assert_eq!(f.outliers_dropped, 1);
        assert!((f.slope - 3.0).abs() < 1e-2, "trimmed slope {}", f.slope);
        assert!((plain_slope - 3.0).abs() > 0.5, "plain slope {plain_slope}");
        assert!(f.slope_stderr < 1e-2);
    }

    #[test]
    fn degenerate_and_tiny_inputs_error() {
        assert!(robust_fit(&[1.0], &[2.0]).is_err());
        // all abscissae identical: slope/intercept inseparable
        assert!(robust_fit(&[5.0, 5.0, 5.0], &[1.0, 2.0, 3.0]).is_err());
        assert!(robust_fit(&[1.0, f64::NAN], &[1.0, 2.0]).is_err());
        assert!(robust_fit(&[1.0, 2.0], &[1.0, f64::INFINITY]).is_err());
    }

    #[test]
    fn scaled_fit_carries_the_ratio() {
        let f = Fit {
            slope: 2.0,
            intercept: 3.0,
            r2: 0.99,
            slope_stderr: 0.1,
            intercept_stderr: 0.2,
            n: 10,
            outliers_dropped: 1,
        };
        let s = f.scaled(8.0, 2.0);
        assert_eq!(s.slope, 16.0);
        assert_eq!(s.intercept, 6.0);
        assert_eq!(s.n, 0);
        assert_eq!(s.predict(1.0), 22.0);
    }
}
