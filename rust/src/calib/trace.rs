//! The versioned calibration trace schema (JSONL) and the simulator-side
//! calibration sweep that emits it.
//!
//! A trace is the raw material calibration works from: one JSONL line per
//! training step, carrying the step's sequence-length composition, its
//! measured compute/communication/overhead seconds together with the
//! *features* those seconds are affine in (aggregate kernel FLOPs and
//! launch counts, collective bytes and launch counts), and the step's
//! peak-memory observation.  `calib::fit` regresses seconds on features to
//! recover the paper's Eq. 12/14/16 coefficients; because every field is a
//! plain per-step aggregate a profiler can produce (kernel time + kernel
//! count, collective time + collective count, allocator peak), externally
//! measured DeepSpeed/Megatron traces convert into the same schema and
//! flow through unchanged.
//!
//! The reference emitter lives in `cluster::run::simulate_run_traced`: it
//! plays a run through the analytic cost model and records what a real
//! cluster would have measured, which makes calibration self-validating —
//! fitting on an emitted trace must reproduce the analytic model
//! (`rust/tests/calibration.rs`).

use crate::cluster::run::{simulate_run_traced, RunConfig};
use crate::config::{ExperimentConfig, Policy};
use crate::data::{Dataset, LengthDistribution};
use crate::model::ModelSpec;
use crate::util::error::{Context, Result};

/// Version stamp of the JSONL trace schema (the header line's
/// `skrull_trace` value).  Bump on any field change.
pub const TRACE_SCHEMA_VERSION: u32 = 1;

/// The trace's header line: schema version + the model the trace was
/// taken on.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceHeader {
    pub version: u32,
    pub model: String,
}

/// One training step's measurements.  Seconds fields are *measured*
/// aggregates; the paired feature fields are what those seconds are
/// affine in under the Eq. 14/16 models:
///
/// * compute:  `comp_seconds  = α_comp·comp_flops + β_comp·comp_kernels`
/// * comm:     `comm_seconds  = α_comm·comm_bytes + T_fixed·comm_launches`
///   (split into intra-node `comm_*` and cross-node `xcomm_*` groups so
///   NVLink and IB fits stay separate; the ZeRO-2 gradient reduce-scatter
///   folds into whichever group its DP-group placement dictates)
/// * overhead: `overhead_seconds = step_overhead·dispatches`
/// * memory:   `peak_bytes    = static + α_mem·bucket_tokens`
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    pub iteration: usize,
    /// DP × CP layout the step ran under.
    pub dp: usize,
    pub cp: usize,
    /// Sequence-length composition of the global batch (provenance; lets
    /// an ingester recompute FLOPs features under its own model).
    pub seq_lens: Vec<u32>,
    /// Σ per-layer-kernel FLOPs over every compute kernel launched.
    pub comp_flops: f64,
    /// Compute kernel launches (counts are f64: schema-wide numeric type).
    pub comp_kernels: f64,
    pub comp_seconds: f64,
    /// Intra-node collectives: total bytes moved / launches / seconds.
    pub comm_bytes: f64,
    pub comm_launches: f64,
    pub comm_seconds: f64,
    /// Cross-node (IB) collectives.
    pub xcomm_bytes: f64,
    pub xcomm_launches: f64,
    pub xcomm_seconds: f64,
    /// Non-empty micro-batch dispatches and the framework overhead they
    /// paid.
    pub dispatches: f64,
    pub overhead_seconds: f64,
    /// Largest per-GPU executed bucket (tokens, padding included).
    pub bucket_tokens: u64,
    /// Largest per-GPU peak bytes observed this step.
    pub peak_bytes: f64,
    /// End-to-end step seconds (validation target, not a fit input).
    pub iteration_seconds: f64,
}

impl TraceRecord {
    /// An all-zero record for `iteration` under a dp×cp layout; the
    /// emitter accumulates into it.
    pub fn empty(iteration: usize, dp: usize, cp: usize) -> Self {
        TraceRecord {
            iteration,
            dp,
            cp,
            seq_lens: Vec::new(),
            comp_flops: 0.0,
            comp_kernels: 0.0,
            comp_seconds: 0.0,
            comm_bytes: 0.0,
            comm_launches: 0.0,
            comm_seconds: 0.0,
            xcomm_bytes: 0.0,
            xcomm_launches: 0.0,
            xcomm_seconds: 0.0,
            dispatches: 0.0,
            overhead_seconds: 0.0,
            bucket_tokens: 0,
            peak_bytes: 0.0,
            iteration_seconds: 0.0,
        }
    }
}

/// A parsed trace: header + per-step records.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    pub header: TraceHeader,
    pub records: Vec<TraceRecord>,
}

/// Parameters of the simulator-side calibration sweep.  Real offline
/// profiling varies the workload to condition the fits (App. A profiles a
/// ladder of sequence lengths); the sweep does the same by playing short
/// runs across datasets, topologies and *bucket-size scales* — the scales
/// are what give the memory fit distinct abscissae.
#[derive(Clone, Debug)]
pub struct EmitOptions {
    pub model: ModelSpec,
    pub datasets: Vec<String>,
    /// (dp, cp) pairs; include one whose CP groups cross nodes to feed the
    /// inter-node (IB) fit real samples.
    pub topologies: Vec<(usize, usize)>,
    /// Fractions of the paper bucket size to run at.
    pub bucket_scales: Vec<f64>,
    pub iterations: usize,
    pub batch_size: usize,
    pub dataset_samples: usize,
    pub seed: u64,
}

impl EmitOptions {
    /// The default sweep: 3 distributions × {node-contained, node-crossing}
    /// topologies × 3 bucket scales, a few iterations each.
    pub fn default_sweep(model: ModelSpec) -> Self {
        EmitOptions {
            model,
            datasets: vec!["wikipedia".into(), "lmsys".into(), "chatqa2".into()],
            topologies: vec![(4, 8), (2, 16)],
            bucket_scales: vec![0.25, 0.5, 1.0],
            iterations: 3,
            batch_size: 16,
            dataset_samples: 2_000,
            seed: 42,
        }
    }
}

/// Run the calibration sweep against the analytic simulator and collect
/// every step's record into one trace.
pub fn emit_calibration_sweep(opts: &EmitOptions) -> Result<Trace> {
    crate::ensure!(opts.iterations > 0, "calibration sweep needs at least 1 iteration");
    crate::ensure!(!opts.datasets.is_empty(), "calibration sweep needs at least one dataset");
    crate::ensure!(
        !opts.topologies.is_empty(),
        "calibration sweep needs at least one topology"
    );
    crate::ensure!(
        opts.bucket_scales.iter().all(|&s| s > 0.0 && s <= 1.0),
        "bucket scales must be in (0, 1]"
    );
    // hoisted per-dataset synthesis: the same untruncated workload feeds
    // every (topology, bucket-scale) combination
    let base_datasets: Vec<Dataset> = opts
        .datasets
        .iter()
        .map(|name| {
            let dist = LengthDistribution::by_name(name)
                .with_context(|| format!("unknown dataset {name:?}"))?;
            Ok(Dataset::synthesize(&dist, opts.dataset_samples, opts.seed ^ 0xD5))
        })
        .collect::<Result<_>>()?;
    let mut records = Vec::new();
    for &(dp, cp) in &opts.topologies {
        for (name, base) in opts.datasets.iter().zip(&base_datasets) {
            for &scale in &opts.bucket_scales {
                let mut cfg = ExperimentConfig::paper_default(opts.model.clone(), name);
                cfg.cluster.dp = dp;
                cfg.cluster.cp = cp;
                cfg.cluster.batch_size = opts.batch_size;
                cfg.policy = Policy::Skrull;
                cfg.seed = opts.seed;
                cfg.bucket_size = ((cfg.bucket_size as f64 * scale) as u32).max(1024);
                let ds = base.truncated(cfg.bucket_size * cp as u32);
                let cost = cfg.cost_model();
                let run = RunConfig::new(opts.iterations, false);
                let (_, recs) = simulate_run_traced(&ds, &cfg, &cost, &run).with_context(
                    || format!("calibration run on {name} <DP={dp},CP={cp}> scale {scale}"),
                )?;
                records.extend(recs);
            }
        }
    }
    Ok(Trace {
        header: TraceHeader {
            version: TRACE_SCHEMA_VERSION,
            model: opts.model.name.to_string(),
        },
        records,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_sweep_emits_varied_conditioned_records() {
        let mut opts = EmitOptions::default_sweep(ModelSpec::qwen2_5_0_5b());
        // keep the unit test fast: one dataset, both topologies
        opts.datasets = vec!["chatqa2".into()];
        opts.iterations = 2;
        opts.dataset_samples = 1_000;
        let trace = emit_calibration_sweep(&opts).unwrap();
        assert_eq!(trace.header.version, TRACE_SCHEMA_VERSION);
        assert_eq!(trace.header.model, "qwen2.5-0.5b");
        // 2 topologies × 3 scales × 2 iterations
        assert_eq!(trace.records.len(), 12);
        for r in &trace.records {
            assert!(!r.seq_lens.is_empty());
            assert!(r.comp_kernels > 0.0 && r.comp_seconds > 0.0);
            assert!(r.dispatches > 0.0 && r.overhead_seconds > 0.0);
            assert!(r.bucket_tokens > 0 && r.peak_bytes > 0.0);
            assert!(r.iteration_seconds > 0.0);
            // features and measurements are finite
            for v in [
                r.comp_flops,
                r.comm_bytes,
                r.comm_seconds,
                r.xcomm_bytes,
                r.xcomm_seconds,
            ] {
                assert!(v.is_finite() && v >= 0.0);
            }
        }
        // the memory fit needs distinct abscissae: the bucket scales
        // produce them
        let mut tokens: Vec<u64> = trace.records.iter().map(|r| r.bucket_tokens).collect();
        tokens.sort_unstable();
        tokens.dedup();
        assert!(tokens.len() >= 3, "bucket scales gave {} distinct sizes", tokens.len());
        // the node-crossing <2,16> topology feeds the inter-node fit
        assert!(trace.records.iter().any(|r| r.xcomm_launches > 0.0));
        // and the node-contained <4,8> topology feeds the intra-node fit
        assert!(trace.records.iter().any(|r| r.comm_launches > 0.0));
    }

    #[test]
    fn bad_sweep_options_are_rejected() {
        let base = EmitOptions::default_sweep(ModelSpec::qwen2_5_0_5b());
        let mut o = base.clone();
        o.iterations = 0;
        assert!(emit_calibration_sweep(&o).is_err());
        let mut o = base.clone();
        o.datasets = vec!["imagenet".into()];
        assert!(emit_calibration_sweep(&o).is_err());
        let mut o = base.clone();
        o.bucket_scales = vec![0.0];
        assert!(emit_calibration_sweep(&o).is_err());
        let mut o = base;
        o.topologies = vec![];
        assert!(emit_calibration_sweep(&o).is_err());
    }
}
