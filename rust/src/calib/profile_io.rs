//! Dependency-free (de)serialization for calibration artifacts: the JSONL
//! trace (`*.jsonl`, one object per line, header first) and the fitted
//! `CalibratedProfile` (a single flat JSON object).
//!
//! The parser is a small hand-rolled reader for the flat subset the
//! schemas use — string/number/bool scalars and arrays of numbers — with
//! line/byte-accurate errors.  No nesting, no serde, mirroring the repo's
//! offline-build rule.

use std::collections::BTreeMap;

use crate::calib::fit::{CalibratedProfile, Fit, PROFILE_SCHEMA_VERSION};
use crate::calib::trace::{Trace, TraceHeader, TraceRecord, TRACE_SCHEMA_VERSION};
use crate::util::error::{Context, Result};

/// A parsed flat JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Jval {
    Num(f64),
    Str(String),
    Bool(bool),
    Arr(Vec<f64>),
}

impl Jval {
    fn as_f64(&self) -> Option<f64> {
        match self {
            Jval::Num(x) => Some(*x),
            _ => None,
        }
    }
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(text: &'a str) -> Self {
        Cursor { bytes: text.as_bytes(), pos: 0 }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.bytes.get(self.pos).copied()
    }

    fn expect_byte(&mut self, c: u8) -> Result<()> {
        match self.peek() {
            Some(b) if b == c => {
                self.pos += 1;
                Ok(())
            }
            other => crate::bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.pos,
                other.map(|b| b as char)
            ),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect_byte(b'"')?;
        let start = self.pos;
        while self.pos < self.bytes.len() {
            match self.bytes[self.pos] {
                b'"' => {
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|e| crate::anyhow!("invalid utf-8 in string: {e}"))?;
                    // the writers never emit escapes; reject rather than
                    // silently mis-parse them
                    crate::ensure!(!s.contains('\\'), "escape sequences unsupported: {s:?}");
                    self.pos += 1;
                    return Ok(s.to_string());
                }
                _ => self.pos += 1,
            }
        }
        crate::bail!("unterminated string at byte {start}")
    }

    fn number(&mut self) -> Result<f64> {
        self.skip_ws();
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'+' | b'-' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let raw = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap_or("");
        raw.parse::<f64>()
            .map_err(|_| crate::anyhow!("invalid number {raw:?} at byte {start}"))
    }

    fn value(&mut self) -> Result<Jval> {
        match self.peek() {
            Some(b'"') => Ok(Jval::Str(self.string()?)),
            Some(b't') => {
                crate::ensure!(
                    self.bytes[self.pos..].starts_with(b"true"),
                    "bad literal at byte {}",
                    self.pos
                );
                self.pos += 4;
                Ok(Jval::Bool(true))
            }
            Some(b'f') => {
                crate::ensure!(
                    self.bytes[self.pos..].starts_with(b"false"),
                    "bad literal at byte {}",
                    self.pos
                );
                self.pos += 5;
                Ok(Jval::Bool(false))
            }
            Some(b'[') => {
                self.expect_byte(b'[')?;
                let mut items = Vec::new();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Jval::Arr(items));
                }
                loop {
                    items.push(self.number()?);
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            break;
                        }
                        other => crate::bail!(
                            "expected ',' or ']' in array at byte {}, found {:?}",
                            self.pos,
                            other.map(|b| b as char)
                        ),
                    }
                }
                Ok(Jval::Arr(items))
            }
            Some(_) => Ok(Jval::Num(self.number()?)),
            None => crate::bail!("unexpected end of input"),
        }
    }
}

/// Parse one flat JSON object (`{"k": v, ...}`) into a key → value map.
pub fn parse_object(text: &str) -> Result<BTreeMap<String, Jval>> {
    let mut c = Cursor::new(text);
    c.expect_byte(b'{')?;
    let mut map = BTreeMap::new();
    if c.peek() == Some(b'}') {
        c.pos += 1;
        return Ok(map);
    }
    loop {
        let key = c.string()?;
        c.expect_byte(b':')?;
        let val = c.value()?;
        map.insert(key, val);
        match c.peek() {
            Some(b',') => c.pos += 1,
            Some(b'}') => {
                c.pos += 1;
                break;
            }
            other => crate::bail!(
                "expected ',' or '}}' at byte {}, found {:?}",
                c.pos,
                other.map(|b| b as char)
            ),
        }
    }
    c.skip_ws();
    crate::ensure!(c.pos == c.bytes.len(), "trailing garbage after object at byte {}", c.pos);
    Ok(map)
}

fn need_f64(map: &BTreeMap<String, Jval>, key: &str) -> Result<f64> {
    let x = map
        .get(key)
        .and_then(Jval::as_f64)
        .with_context(|| format!("missing or non-numeric field {key:?}"))?;
    // an overflowing literal (1e999) parses to ±inf; reject it here with
    // the field name instead of letting it surface deep inside the fits
    crate::ensure!(x.is_finite(), "field {key:?} is not finite ({x})");
    Ok(x)
}

/// Count-like fields must be exact non-negative integers: a converter bug
/// emitting `-8320` or `1e300` must fail the parse, not saturate through
/// an `as` cast into the fits.
fn need_uint(map: &BTreeMap<String, Jval>, key: &str) -> Result<u64> {
    let x = need_f64(map, key)?;
    crate::ensure!(
        x.is_finite() && x >= 0.0 && x <= 2f64.powi(53) && x.fract() == 0.0,
        "field {key:?} must be a non-negative integer, got {x}"
    );
    Ok(x as u64)
}

fn f64_or(map: &BTreeMap<String, Jval>, key: &str, default: f64) -> f64 {
    map.get(key).and_then(Jval::as_f64).unwrap_or(default)
}

// ---------------------------------------------------------------------------
// trace JSONL
// ---------------------------------------------------------------------------

fn render_record(r: &TraceRecord) -> String {
    let lens: Vec<String> = r.seq_lens.iter().map(|l| l.to_string()).collect();
    format!(
        "{{\"iteration\": {}, \"dp\": {}, \"cp\": {}, \"seq_lens\": [{}], \
         \"comp_flops\": {:e}, \"comp_kernels\": {}, \"comp_seconds\": {:e}, \
         \"comm_bytes\": {:e}, \"comm_launches\": {}, \"comm_seconds\": {:e}, \
         \"xcomm_bytes\": {:e}, \"xcomm_launches\": {}, \"xcomm_seconds\": {:e}, \
         \"dispatches\": {}, \"overhead_seconds\": {:e}, \
         \"bucket_tokens\": {}, \"peak_bytes\": {:e}, \"iteration_seconds\": {:e}}}",
        r.iteration,
        r.dp,
        r.cp,
        lens.join(", "),
        r.comp_flops,
        r.comp_kernels,
        r.comp_seconds,
        r.comm_bytes,
        r.comm_launches,
        r.comm_seconds,
        r.xcomm_bytes,
        r.xcomm_launches,
        r.xcomm_seconds,
        r.dispatches,
        r.overhead_seconds,
        r.bucket_tokens,
        r.peak_bytes,
        r.iteration_seconds,
    )
}

/// Render a trace as JSONL text: header line, then one line per record.
pub fn render_trace(trace: &Trace) -> String {
    let mut out = format!(
        "{{\"skrull_trace\": {}, \"model\": \"{}\"}}\n",
        trace.header.version, trace.header.model
    );
    for r in &trace.records {
        out.push_str(&render_record(r));
        out.push('\n');
    }
    out
}

fn parse_record(map: &BTreeMap<String, Jval>) -> Result<TraceRecord> {
    let seq_lens = match map.get("seq_lens") {
        Some(Jval::Arr(xs)) => xs
            .iter()
            .map(|&x| {
                crate::ensure!(
                    x.is_finite() && (0.0..=u32::MAX as f64).contains(&x) && x.fract() == 0.0,
                    "seq_lens entry {x} is not a u32"
                );
                Ok(x as u32)
            })
            .collect::<Result<Vec<u32>>>()?,
        _ => crate::bail!("missing or non-array field \"seq_lens\""),
    };
    Ok(TraceRecord {
        iteration: need_uint(map, "iteration")? as usize,
        dp: need_uint(map, "dp")? as usize,
        cp: need_uint(map, "cp")? as usize,
        seq_lens,
        comp_flops: need_f64(map, "comp_flops")?,
        comp_kernels: need_f64(map, "comp_kernels")?,
        comp_seconds: need_f64(map, "comp_seconds")?,
        comm_bytes: need_f64(map, "comm_bytes")?,
        comm_launches: need_f64(map, "comm_launches")?,
        comm_seconds: need_f64(map, "comm_seconds")?,
        xcomm_bytes: need_f64(map, "xcomm_bytes")?,
        xcomm_launches: need_f64(map, "xcomm_launches")?,
        xcomm_seconds: need_f64(map, "xcomm_seconds")?,
        dispatches: need_f64(map, "dispatches")?,
        overhead_seconds: need_f64(map, "overhead_seconds")?,
        bucket_tokens: need_uint(map, "bucket_tokens")?,
        peak_bytes: need_f64(map, "peak_bytes")?,
        iteration_seconds: need_f64(map, "iteration_seconds")?,
    })
}

/// Parse JSONL trace text (header line + records).
pub fn parse_trace(text: &str) -> Result<Trace> {
    let mut lines = text
        .lines()
        .enumerate()
        .filter(|(_, l)| !l.trim().is_empty());
    let (_, first) = lines.next().context("empty trace file")?;
    let head = parse_object(first).context("parsing trace header")?;
    let version = need_f64(&head, "skrull_trace").context(
        "first line is not a trace header (expected {\"skrull_trace\": 1, ...})",
    )? as u32;
    crate::ensure!(
        version == TRACE_SCHEMA_VERSION,
        "trace schema v{version}, this build reads v{TRACE_SCHEMA_VERSION}"
    );
    let model = match head.get("model") {
        Some(Jval::Str(s)) => s.clone(),
        _ => String::new(),
    };
    let mut records = Vec::new();
    for (idx, line) in lines {
        let map = parse_object(line).with_context(|| format!("trace line {}", idx + 1))?;
        records.push(parse_record(&map).with_context(|| format!("trace line {}", idx + 1))?);
    }
    Ok(Trace { header: TraceHeader { version, model }, records })
}

/// Read a JSONL trace from disk.
pub fn read_trace(path: &str) -> Result<Trace> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    parse_trace(&text).with_context(|| format!("parsing trace {path}"))
}

/// Write a trace to disk as JSONL.
pub fn write_trace(path: &str, trace: &Trace) -> Result<()> {
    std::fs::write(path, render_trace(trace)).with_context(|| format!("writing {path}"))
}

// ---------------------------------------------------------------------------
// profile JSON
// ---------------------------------------------------------------------------

fn push_fit(out: &mut String, prefix: &str, fit: &Fit) {
    use std::fmt::Write as _;
    let _ = write!(
        out,
        "  \"{prefix}_slope\": {:e},\n  \"{prefix}_intercept\": {:e},\n  \
         \"{prefix}_r2\": {:e},\n  \"{prefix}_slope_stderr\": {:e},\n  \
         \"{prefix}_intercept_stderr\": {:e},\n  \"{prefix}_n\": {},\n  \
         \"{prefix}_outliers\": {},\n",
        fit.slope, fit.intercept, fit.r2, fit.slope_stderr, fit.intercept_stderr, fit.n,
        fit.outliers_dropped,
    );
}

fn pull_fit(map: &BTreeMap<String, Jval>, prefix: &str) -> Result<Fit> {
    Ok(Fit {
        slope: need_f64(map, &format!("{prefix}_slope"))?,
        intercept: need_f64(map, &format!("{prefix}_intercept"))?,
        r2: need_f64(map, &format!("{prefix}_r2"))?,
        slope_stderr: f64_or(map, &format!("{prefix}_slope_stderr"), 0.0),
        intercept_stderr: f64_or(map, &format!("{prefix}_intercept_stderr"), 0.0),
        n: f64_or(map, &format!("{prefix}_n"), 0.0) as usize,
        outliers_dropped: f64_or(map, &format!("{prefix}_outliers"), 0.0) as usize,
    })
}

/// Render a fitted profile as a flat JSON object.
pub fn render_profile(p: &CalibratedProfile) -> String {
    use std::fmt::Write as _;
    let mut out = String::from("{\n");
    let _ = writeln!(out, "  \"skrull_profile\": {},", p.version);
    let _ = writeln!(out, "  \"model\": \"{}\",", p.model);
    push_fit(&mut out, "comp", &p.comp);
    push_fit(&mut out, "comm", &p.comm);
    push_fit(&mut out, "xcomm", &p.comm_inter);
    let _ = writeln!(out, "  \"xcomm_extrapolated\": {},", p.inter_extrapolated);
    let _ = writeln!(out, "  \"step_overhead_s\": {:e},", p.step_overhead_s);
    if let Some(m) = &p.mem {
        push_fit(&mut out, "mem", m);
    }
    let _ = writeln!(out, "  \"records\": {}", p.records);
    out.push_str("}\n");
    out
}

/// Parse a profile from its JSON text.
pub fn parse_profile(text: &str) -> Result<CalibratedProfile> {
    let map = parse_object(text).context("parsing calibrated profile")?;
    let version = need_f64(&map, "skrull_profile")? as u32;
    crate::ensure!(
        version == PROFILE_SCHEMA_VERSION,
        "profile schema v{version}, this build reads v{PROFILE_SCHEMA_VERSION}"
    );
    let model = match map.get("model") {
        Some(Jval::Str(s)) => s.clone(),
        _ => String::new(),
    };
    let mem = if map.contains_key("mem_slope") { Some(pull_fit(&map, "mem")?) } else { None };
    Ok(CalibratedProfile {
        version,
        model,
        comp: pull_fit(&map, "comp")?,
        comm: pull_fit(&map, "comm")?,
        comm_inter: pull_fit(&map, "xcomm")?,
        inter_extrapolated: matches!(map.get("xcomm_extrapolated"), Some(Jval::Bool(true))),
        step_overhead_s: need_f64(&map, "step_overhead_s")?,
        mem,
        records: f64_or(&map, "records", 0.0) as usize,
    })
}

/// Load a fitted profile from disk.
pub fn load_profile(path: &str) -> Result<CalibratedProfile> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    parse_profile(&text).with_context(|| format!("parsing profile {path}"))
}

/// Save a fitted profile to disk.
pub fn save_profile(path: &str, p: &CalibratedProfile) -> Result<()> {
    std::fs::write(path, render_profile(p)).with_context(|| format!("writing {path}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_record(i: usize) -> TraceRecord {
        let mut r = TraceRecord::empty(i, 4, 8);
        r.seq_lens = vec![100 + i as u32, 2000, 30_000];
        r.comp_flops = 1.5e12 * (i + 1) as f64;
        r.comp_kernels = 96.0;
        r.comp_seconds = 2e-15 * r.comp_flops + 1e-5 * r.comp_kernels;
        r.comm_bytes = 5e8 * (i + 1) as f64;
        r.comm_launches = 48.0;
        r.comm_seconds = 1.25e-11 * r.comm_bytes + 2e-5 * r.comm_launches;
        r.xcomm_bytes = 1e8;
        r.xcomm_launches = 1.0;
        r.xcomm_seconds = 1e-10 * r.xcomm_bytes + 4e-5;
        r.dispatches = 4.0;
        r.overhead_seconds = 0.012;
        r.bucket_tokens = 26_624 + 1000 * i as u64;
        r.peak_bytes = 6e9 + 5e4 * r.bucket_tokens as f64;
        r.iteration_seconds = 0.8;
        r
    }

    #[test]
    fn trace_round_trips_exactly() {
        let trace = Trace {
            header: TraceHeader { version: TRACE_SCHEMA_VERSION, model: "qwen2.5-0.5b".into() },
            records: (0..5).map(sample_record).collect(),
        };
        let text = render_trace(&trace);
        let back = parse_trace(&text).unwrap();
        assert_eq!(back, trace);
        // empty record list still round-trips
        let empty = Trace { header: trace.header.clone(), records: vec![] };
        assert_eq!(parse_trace(&render_trace(&empty)).unwrap(), empty);
    }

    #[test]
    fn profile_round_trips_exactly() {
        let fit = |s: f64| Fit {
            slope: s,
            intercept: s * 0.5,
            r2: 0.999,
            slope_stderr: s * 1e-3,
            intercept_stderr: s * 2e-3,
            n: 42,
            outliers_dropped: 3,
        };
        let p = CalibratedProfile {
            version: PROFILE_SCHEMA_VERSION,
            model: "qwen2.5-0.5b".into(),
            comp: fit(2e-15),
            comm: fit(1.25e-11),
            comm_inter: fit(1e-10),
            inter_extrapolated: true,
            step_overhead_s: 3e-3,
            mem: Some(fit(5e4)),
            records: 54,
        };
        let text = render_profile(&p);
        assert_eq!(parse_profile(&text).unwrap(), p);
        // mem-less profiles round-trip to mem-less
        let mut q = p.clone();
        q.mem = None;
        assert_eq!(parse_profile(&render_profile(&q)).unwrap(), q);
    }

    #[test]
    fn parser_handles_the_flat_subset() {
        let m = parse_object(
            r#"{"a": 1.5, "b": "text", "c": true, "d": false, "e": [1, 2.5, 3e2], "f": -2e-3}"#,
        )
        .unwrap();
        assert_eq!(m["a"], Jval::Num(1.5));
        assert_eq!(m["b"], Jval::Str("text".into()));
        assert_eq!(m["c"], Jval::Bool(true));
        assert_eq!(m["d"], Jval::Bool(false));
        assert_eq!(m["e"], Jval::Arr(vec![1.0, 2.5, 300.0]));
        assert_eq!(m["f"], Jval::Num(-2e-3));
        assert!(parse_object("{}").unwrap().is_empty());
        // whitespace (including newlines) is insignificant
        let m = parse_object("{\n  \"x\": 1,\n  \"y\": [ ]\n}\n").unwrap();
        assert_eq!(m["x"], Jval::Num(1.0));
        assert_eq!(m["y"], Jval::Arr(vec![]));
    }

    #[test]
    fn parser_rejects_malformed_input() {
        for bad in [
            "",
            "{",
            "{\"a\" 1}",
            "{\"a\": }",
            "{\"a\": 1,}",
            "{\"a\": [1, ]}",
            "{\"a\": nope}",
            "{\"a\": \"unterminated}",
            "{\"a\": 1} trailing",
        ] {
            assert!(parse_object(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn count_fields_must_be_exact_non_negative_integers() {
        // external converters can be buggy: saturating `as` casts would
        // silently feed garbage abscissae into the fits
        let good = render_trace(&Trace {
            header: TraceHeader { version: TRACE_SCHEMA_VERSION, model: "m".into() },
            records: vec![sample_record(0)],
        });
        assert!(parse_trace(&good).is_ok());
        let tokens = format!("\"bucket_tokens\": {}", sample_record(0).bucket_tokens);
        for bad in ["\"bucket_tokens\": -8320", "\"bucket_tokens\": 1.5", "\"bucket_tokens\": 1e300"] {
            let broken = good.replace(&tokens, bad);
            assert_ne!(broken, good, "mutation must apply");
            assert!(parse_trace(&broken).is_err(), "accepted {bad}");
        }
        let broken = good.replace("\"dp\": 4", "\"dp\": -1");
        assert!(parse_trace(&broken).is_err());
        let broken = good.replace("\"dp\": 4", "\"dp\": 4.5");
        assert!(parse_trace(&broken).is_err());
        // an overflowing literal (→ inf) is rejected at the field, with
        // its name in the error, not deep inside the fits
        let secs = format!("\"comp_seconds\": {:e}", sample_record(0).comp_seconds);
        let broken = good.replace(&secs, "\"comp_seconds\": 1e999");
        assert_ne!(broken, good, "mutation must apply");
        let err = parse_trace(&broken).unwrap_err().to_string();
        assert!(err.contains("comp_seconds") && err.contains("finite"), "{err}");
        // a negative seq_lens entry is rejected too
        let lens = sample_record(0).seq_lens;
        let needle = format!("{}, {}", lens[0], lens[1]);
        let broken = good.replace(&needle, &format!("-{}, {}", lens[0], lens[1]));
        assert_ne!(broken, good, "mutation must apply");
        assert!(parse_trace(&broken).is_err());
    }

    #[test]
    fn trace_parse_errors_name_the_line() {
        let good = render_trace(&Trace {
            header: TraceHeader { version: TRACE_SCHEMA_VERSION, model: "m".into() },
            records: vec![sample_record(0)],
        });
        // break the record line
        let broken = good.replace("\"comp_flops\"", "\"nope\"");
        let err = parse_trace(&broken).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("comp_flops"), "{err}");
        // wrong schema version is rejected
        let v99 = good.replace("\"skrull_trace\": 1", "\"skrull_trace\": 99");
        assert!(parse_trace(&v99).is_err());
        // a non-header first line is rejected
        assert!(parse_trace("{\"iteration\": 0}\n").is_err());
    }
}
