//! Calibration — from measured traces to calibrated cost/memory models.
//!
//! The scheduler's quality rests on estimator coefficients (Eq. 12/14/16
//! α/β, memplan's activation α) that the rest of the repo derives from
//! first principles against `Hardware::h100()`.  This subsystem closes
//! the measurement loop:
//!
//! * [`trace`] — the versioned JSONL trace schema (per-step seq-len
//!   composition, measured compute/comm/overhead seconds plus the
//!   features they are affine in, peak bytes, dp/cp layout) and the
//!   simulator-side calibration sweep that emits it; the reference
//!   emitter itself lives in `cluster::run::simulate_run_traced`.
//! * [`fit`] — robust fitting (outlier-trimmed least squares on
//!   `util::stats::linear_fit`, per-coefficient stderr, R²) into a
//!   [`CalibratedProfile`], convertible to a drop-in `CostModel` /
//!   `MemPlan`.
//! * [`profile_io`] — dependency-free JSONL/JSON parsing and rendering
//!   for traces and profiles.
//! * [`report`] — residual report + the `skrull calibrate --validate`
//!   gate.
//! * [`recal`] — the streaming data plane's drift → recalibration hook:
//!   turns a `stream::DriftEvent`'s post-shift sketch into fresh capacity
//!   accounting (never into schedule changes).
//!
//! The loop is self-validating: calibrating on a trace emitted by the
//! analytic simulator reproduces the analytic model's per-iteration
//! predictions (`rust/tests/calibration.rs`); the same machinery ingests
//! externally measured DeepSpeed/Megatron traces unchanged.  Runs consume
//! a profile through `config::CostSource::Calibrated`.

pub mod fit;
pub mod profile_io;
pub mod recal;
pub mod report;
pub mod trace;

pub use fit::{calibrate, robust_fit, CalibratedProfile, Fit};
pub use profile_io::{load_profile, read_trace, save_profile, write_trace};
pub use trace::{
    emit_calibration_sweep, EmitOptions, Trace, TraceHeader, TraceRecord, TRACE_SCHEMA_VERSION,
};
