//! Residual reporting and the calibration validation gate.
//!
//! After fitting, every trace record is replayed through the fitted
//! coefficients and compared against its measured seconds/bytes — the
//! per-component relative residuals are what `skrull calibrate` prints
//! and what `--validate` gates CI on: a calibration that cannot
//! reproduce its own trace has no business steering the scheduler.

use crate::calib::fit::{CalibratedProfile, Fit};
use crate::calib::trace::Trace;
use crate::util::error::Result;
use crate::util::stats::median_of;

/// Relative-residual summary of one fitted component over the trace.
#[derive(Clone, Debug, Default)]
pub struct ResidualStats {
    /// Records that exercised this component.
    pub n: usize,
    pub mean_rel: f64,
    pub median_rel: f64,
    pub max_rel: f64,
}

impl ResidualStats {
    fn from_rels(rels: &[f64]) -> Self {
        if rels.is_empty() {
            return ResidualStats::default();
        }
        ResidualStats {
            n: rels.len(),
            mean_rel: rels.iter().sum::<f64>() / rels.len() as f64,
            median_rel: median_of(rels),
            max_rel: rels.iter().copied().fold(0.0, f64::max),
        }
    }
}

/// Residuals of every fitted component.
#[derive(Clone, Debug)]
pub struct ComponentResiduals {
    pub comp: ResidualStats,
    pub comm: ResidualStats,
    pub xcomm: ResidualStats,
    pub mem: ResidualStats,
}

fn rel_err(pred: f64, actual: f64) -> f64 {
    (pred - actual).abs() / actual.abs().max(1e-30)
}

/// Replay the trace through the profile and summarize per-component
/// relative residuals.
pub fn residuals(trace: &Trace, p: &CalibratedProfile) -> ComponentResiduals {
    let mut comp = Vec::new();
    let mut comm = Vec::new();
    let mut xcomm = Vec::new();
    let mut mem = Vec::new();
    for r in &trace.records {
        if r.comp_kernels > 0.0 && r.comp_seconds > 0.0 {
            let pred = p.comp.slope * r.comp_flops + p.comp.intercept * r.comp_kernels;
            comp.push(rel_err(pred, r.comp_seconds));
        }
        if r.comm_launches > 0.0 && r.comm_seconds > 0.0 {
            let pred = p.comm.slope * r.comm_bytes + p.comm.intercept * r.comm_launches;
            comm.push(rel_err(pred, r.comm_seconds));
        }
        if r.xcomm_launches > 0.0 && r.xcomm_seconds > 0.0 {
            let pred =
                p.comm_inter.slope * r.xcomm_bytes + p.comm_inter.intercept * r.xcomm_launches;
            xcomm.push(rel_err(pred, r.xcomm_seconds));
        }
        if let Some(m) = &p.mem {
            if r.peak_bytes > 0.0 {
                mem.push(rel_err(m.predict(r.bucket_tokens as f64), r.peak_bytes));
            }
        }
    }
    ComponentResiduals {
        comp: ResidualStats::from_rels(&comp),
        comm: ResidualStats::from_rels(&comm),
        xcomm: ResidualStats::from_rels(&xcomm),
        mem: ResidualStats::from_rels(&mem),
    }
}

fn fit_row(
    name: &str,
    slope_unit: &str,
    intercept_unit: &str,
    fit: &Fit,
    res: &ResidualStats,
) -> Vec<String> {
    vec![
        name.to_string(),
        format!("{:.4e} {slope_unit}", fit.slope),
        format!("{:.4e} {intercept_unit}", fit.intercept),
        format!("{:.6}", fit.r2),
        format!("{:.1e}", fit.slope_stderr),
        format!("{}", fit.n),
        format!("{}", fit.outliers_dropped),
        format!("{:.3}%", 100.0 * res.median_rel),
        format!("{:.3}%", 100.0 * res.max_rel),
    ]
}

/// Human-readable calibration report (coefficients + residuals).
pub fn render_report(p: &CalibratedProfile, res: &ComponentResiduals) -> String {
    use std::fmt::Write as _;
    let mut table = crate::bench::TableBuilder::new(&format!(
        "Calibration of {} ({} trace records)",
        p.model, p.records
    ))
    .header(&[
        "component",
        "slope",
        "intercept",
        "r²",
        "±slope",
        "n",
        "dropped",
        "median err",
        "max err",
    ]);
    table.row(&fit_row("comp (Eq.14)", "s/FLOP", "s", &p.comp, &res.comp));
    table.row(&fit_row("comm intra (Eq.16)", "s/B", "s", &p.comm, &res.comm));
    let inter_name = if p.inter_extrapolated {
        "comm inter (scaled)"
    } else {
        "comm inter (Eq.16)"
    };
    table.row(&fit_row(inter_name, "s/B", "s", &p.comm_inter, &res.xcomm));
    if let Some(m) = &p.mem {
        table.row(&fit_row("memory (Eq.12)", "B/token", "B", m, &res.mem));
    }
    let mut out = table.render();
    let _ = writeln!(out, "step overhead: {:.3e} s/dispatch", p.step_overhead_s);
    if p.mem.is_none() {
        let _ = writeln!(
            out,
            "memory fit: skipped (trace ran a single bucket size; sweep several \
             with `skrull calibrate --emit`)"
        );
    }
    out
}

/// The `--validate` gate: fitted coefficients must be sane (finite,
/// positive, r² ≥ `min_r2`) and the fits must reproduce the trace — the
/// median relative residual of every exercised component within
/// `tolerance`.
pub fn validate(
    p: &CalibratedProfile,
    res: &ComponentResiduals,
    min_r2: f64,
    tolerance: f64,
) -> Result<()> {
    p.validate(min_r2)?;
    for (name, stats) in [
        ("comp", &res.comp),
        ("comm", &res.comm),
        ("xcomm", &res.xcomm),
        ("mem", &res.mem),
    ] {
        if stats.n == 0 {
            continue;
        }
        crate::ensure!(
            stats.median_rel.is_finite() && stats.median_rel <= tolerance,
            "{name}: median relative residual {:.4} exceeds tolerance {tolerance}",
            stats.median_rel
        );
    }
    crate::ensure!(
        res.comp.n > 0,
        "trace exercised no compute kernels: nothing validated"
    );
    crate::ensure!(
        p.mem.is_some(),
        "no memory fit: the trace must sweep several bucket sizes to calibrate \
         the memplan activation α"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::fit::calibrate;
    use crate::calib::trace::{TraceHeader, TraceRecord, TRACE_SCHEMA_VERSION};

    /// A synthetic trace lying exactly on known coefficient lines.
    fn exact_trace(n: usize) -> Trace {
        let records = (0..n)
            .map(|i| {
                let mut r = TraceRecord::empty(i, 4, 8);
                r.seq_lens = vec![1000, 2000];
                r.comp_kernels = 96.0;
                r.comp_flops = 1e12 * (1 + i) as f64;
                r.comp_seconds = 2e-15 * r.comp_flops + 1e-5 * r.comp_kernels;
                r.comm_launches = 48.0;
                r.comm_bytes = 4e8 * (1 + i) as f64;
                r.comm_seconds = 1.25e-11 * r.comm_bytes + 2e-5 * r.comm_launches;
                r.xcomm_launches = 2.0;
                r.xcomm_bytes = 1e8 * (1 + i) as f64;
                r.xcomm_seconds = 1e-10 * r.xcomm_bytes + 4e-5 * r.xcomm_launches;
                r.dispatches = 4.0;
                r.overhead_seconds = 3e-3 * r.dispatches;
                r.bucket_tokens = 10_000 + 2_000 * i as u64;
                r.peak_bytes = 6e9 + 5e4 * r.bucket_tokens as f64;
                r.iteration_seconds = 1.0;
                r
            })
            .collect();
        Trace {
            header: TraceHeader { version: TRACE_SCHEMA_VERSION, model: "test".into() },
            records,
        }
    }

    #[test]
    fn exact_trace_calibrates_reports_and_validates() {
        let trace = exact_trace(8);
        let p = calibrate(&trace).unwrap();
        assert!((p.comp.slope - 2e-15).abs() / 2e-15 < 1e-9);
        assert!((p.comp.intercept - 1e-5).abs() < 1e-12);
        assert!((p.comm.slope - 1.25e-11).abs() / 1.25e-11 < 1e-9);
        assert!((p.comm_inter.slope - 1e-10).abs() / 1e-10 < 1e-9);
        assert!(!p.inter_extrapolated);
        assert!((p.step_overhead_s - 3e-3).abs() < 1e-15);
        let m = p.mem.as_ref().expect("memory fit present");
        assert!((m.slope - 5e4).abs() / 5e4 < 1e-9);
        assert!((m.intercept - 6e9).abs() / 6e9 < 1e-9);
        let res = residuals(&trace, &p);
        assert_eq!(res.comp.n, 8);
        assert!(res.comp.max_rel < 1e-9);
        assert!(res.mem.max_rel < 1e-9);
        validate(&p, &res, 0.99, 0.05).unwrap();
        let rendered = render_report(&p, &res);
        assert!(rendered.contains("comp (Eq.14)"));
        assert!(rendered.contains("memory (Eq.12)"));
        assert!(rendered.contains("step overhead"));
    }

    #[test]
    fn validation_rejects_bad_fits_and_residuals() {
        let trace = exact_trace(8);
        let good = calibrate(&trace).unwrap();
        let res = residuals(&trace, &good);

        // r² below the gate
        let mut p = good.clone();
        p.comp.r2 = 0.5;
        assert!(validate(&p, &res, 0.99, 0.05).is_err());
        // negative slope
        let mut p = good.clone();
        p.comm.slope = -1.0;
        assert!(validate(&p, &res, 0.0, 1.0).is_err());
        // missing memory fit
        let mut p = good.clone();
        p.mem = None;
        assert!(validate(&p, &res, 0.99, 0.05).is_err());
        // a profile that mis-predicts the trace fails the residual gate
        let mut p = good.clone();
        p.comp.slope *= 2.0;
        let bad_res = residuals(&trace, &p);
        assert!(bad_res.comp.median_rel > 0.05);
        assert!(validate(&p, &bad_res, 0.0, 0.05).is_err());
        // the honest profile still passes
        validate(&good, &res, 0.99, 0.05).unwrap();
    }

    #[test]
    fn corrupt_peak_bytes_is_a_real_error_not_a_skipped_memory_fit() {
        // Regression: every memory-fit failure used to collapse into
        // `mem: None`, telling the user to sweep bucket sizes when the
        // actual problem was bad data.
        let mut trace = exact_trace(8);
        trace.records[3].peak_bytes = f64::NAN;
        let err = calibrate(&trace).unwrap_err().to_string();
        assert!(err.contains("Eq. 12"), "{err}");
    }

    #[test]
    fn single_bucket_trace_loses_only_the_memory_fit() {
        let mut trace = exact_trace(8);
        for r in &mut trace.records {
            r.bucket_tokens = 26_624;
            r.peak_bytes = 6e9 + 5e4 * r.bucket_tokens as f64;
        }
        let p = calibrate(&trace).unwrap();
        assert!(p.mem.is_none());
        // cost fits are unaffected
        assert!((p.comp.slope - 2e-15).abs() / 2e-15 < 1e-9);
        let res = residuals(&trace, &p);
        assert_eq!(res.mem.n, 0);
        let rendered = render_report(&p, &res);
        assert!(rendered.contains("memory fit: skipped"));
        // and --validate demands the sweep
        let err = validate(&p, &res, 0.99, 0.05).unwrap_err().to_string();
        assert!(err.contains("bucket sizes"), "{err}");
    }
}
