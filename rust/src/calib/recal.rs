//! Drift → recalibration hook.
//!
//! A `stream::DriftEvent` means the length mix that the capacity plan and
//! the cost estimator were calibrated against no longer describes the
//! corpus: the `estimator_error` trajectory will start to climb.  This
//! module turns the detector's post-shift window sketch into fresh
//! *accounting* quantities — quantiles, mean length, a suggested bucket
//! size — that capacity/estimator consumers can adopt.  It never perturbs
//! schedules: by the streaming byte-identity invariant, schedules depend
//! only on the data and the seed, so recalibration is observable in
//! reports (and in a future re-fit of the calibrated profile) but not in
//! placement.

use crate::stream::reservoir::LengthSketch;

/// Granularity for `suggested_bucket` (matches the KiB-aligned bucket
/// sizes used throughout the configs).
const BUCKET_ALIGN: u64 = 1024;

/// Fresh capacity accounting derived from a post-drift sketch.
#[derive(Debug, Clone, PartialEq)]
pub struct Recalibration {
    /// Sequences ingested when the triggering window closed.
    pub at: u64,
    pub p50: u32,
    pub p90: u32,
    pub p99: u32,
    pub mean_len: f64,
    /// Smallest KiB-aligned bucket that holds the new mix's p99 — the
    /// quantity a capacity planner would re-derive after the shift.
    pub suggested_bucket: u32,
}

/// Derive recalibrated accounting from the shifted window's sketch.
pub fn recalibrate(at: u64, sketch: &LengthSketch) -> Recalibration {
    let p99 = sketch.quantile(0.99);
    let aligned = (p99 as u64).max(1).div_ceil(BUCKET_ALIGN) * BUCKET_ALIGN;
    Recalibration {
        at,
        p50: sketch.quantile(0.5),
        p90: sketch.quantile(0.9),
        p99,
        mean_len: sketch.mean(),
        // skrull-lint: allow(truncating-cast) -- p99 is a u32 length, so its KiB round-up fits u32 (lengths are capped well below u32::MAX)
        suggested_bucket: aligned as u32,
    }
}

impl Recalibration {
    /// Expected padded tokens for a batch of `batch_size` sequences under
    /// the new mix if every sequence were padded to `suggested_bucket` —
    /// the pessimistic bound the pre-Skrull baseline would pay, useful as
    /// a "how much does scheduling matter now" indicator after a shift.
    pub fn padded_tokens_per_batch(&self, batch_size: usize) -> u64 {
        self.suggested_bucket as u64 * batch_size as u64
    }

    /// Mean data tokens per batch under the new mix (the numerator of the
    /// post-shift padding-efficiency estimate).
    pub fn data_tokens_per_batch(&self, batch_size: usize) -> f64 {
        self.mean_len * batch_size as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recalibration_tracks_the_sketch() {
        let sketch = LengthSketch::from_lengths(&[100, 200, 300, 4000, 5000]);
        let rc = recalibrate(500, &sketch);
        assert_eq!(rc.at, 500);
        assert_eq!(rc.p50, 300);
        assert_eq!(rc.p99, 5000);
        assert_eq!(rc.suggested_bucket, 5 * 1024);
        assert!((rc.mean_len - 1920.0).abs() < 1e-9);
        assert_eq!(rc.padded_tokens_per_batch(8), 8 * 5 * 1024);
        assert!((rc.data_tokens_per_batch(8) - 15360.0).abs() < 1e-9);
    }

    #[test]
    fn suggested_bucket_is_kib_aligned_and_positive() {
        let sketch = LengthSketch::from_lengths(&[1]);
        let rc = recalibrate(1, &sketch);
        assert_eq!(rc.suggested_bucket, 1024);
        let sketch = LengthSketch::from_lengths(&[1025]);
        assert_eq!(recalibrate(1, &sketch).suggested_bucket, 2048);
    }
}
