//! The rule catalog: each rule encodes one invariant this repo has
//! already been bitten by (see the per-rule docs), expressed as token
//! patterns over [`crate::analysis::lexer`] output with per-rule module
//! scoping.  Paths are relative to the scan root (`rust/src`) with `/`
//! separators; a scope entry matches any path it prefixes.

use crate::analysis::lexer::{TokKind, Token};

/// Where a rule applies, as path prefixes relative to the scan root.
#[derive(Clone, Copy, Debug)]
pub enum Scope {
    /// Everywhere.
    All,
    /// Only under these prefixes.
    Within(&'static [&'static str]),
    /// Everywhere except under these prefixes (the sanctioned sites).
    Except(&'static [&'static str]),
}

impl Scope {
    pub fn contains(&self, rel: &str) -> bool {
        match self {
            Scope::All => true,
            Scope::Within(paths) => paths.iter().any(|p| rel.starts_with(p)),
            Scope::Except(paths) => !paths.iter().any(|p| rel.starts_with(p)),
        }
    }
}

/// One lint rule.
#[derive(Clone, Copy, Debug)]
pub struct Rule {
    pub id: &'static str,
    pub summary: &'static str,
    pub scope: Scope,
}

/// Modules whose iteration order can leak into schedules, reports or
/// benchmark artifacts — everywhere byte-identity is load-bearing.
const DETERMINISTIC_MODULES: &[&str] = &[
    "analysis/",
    "bench/",
    "calib/",
    "cluster/",
    "config/",
    "coordinator/",
    "data/",
    "fleet/",
    "memplan/",
    "scheduler/",
    "serve/",
    "stream/",
];

/// Library modules where `SchedError`/`Result` propagation is the
/// convention.  Deliberately absent: `util/` (the SPSC channel treats
/// lock poisoning as fatal by design — Miri covers it), `runtime/` and
/// `logging/` (fail-fast process boundaries), `cli/` and `main.rs` (the
/// launcher may abort on hard usage errors).
const ERROR_CONVENTION_MODULES: &[&str] = &[
    "analysis/",
    "bench/",
    "calib/",
    "cluster/",
    "config/",
    "coordinator/",
    "data/",
    "fleet/",
    "memplan/",
    "model/",
    "perfmodel/",
    "rng/",
    "scheduler/",
    "serve/",
    "stream/",
];

/// Accumulation-path modules where a narrowing cast can silently wrap
/// token/FLOP counts (the PR 6 overflow class at K = 2^20).
const ACCUMULATION_MODULES: &[&str] = &["config/", "memplan/", "perfmodel/", "scheduler/"];

/// The sanctioned wall-clock sites: measurement (bench), the pipelined
/// loader's overhead accounting, the trainer, logging, and the PJRT
/// boundary.  Everywhere else timing must flow through recorded values
/// so `--deterministic-timing` stays a pure wall-clock lever.
const TIMING_SANCTIONED: &[&str] =
    &["bench/", "coordinator/trainer.rs", "data/loader.rs", "logging/", "runtime/pjrt.rs"];

/// Modules carrying declared zero-alloc hot paths (`hot-path-alloc`
/// scans only the [`HOT_FUNCTIONS`] bodies within them).
const HOT_PATH_MODULES: &[&str] = &["data/", "fleet/", "scheduler/", "serve/", "stream/"];

/// The declared hot-path set for `hot-path-alloc`: the static complement
/// of `tests/alloc_audit.rs`.  `(file, fn)` pairs; the rule scans the
/// named fn's body only.
pub const HOT_FUNCTIONS: &[(&str, &str)] = &[
    ("scheduler/gds.rs", "schedule_rank_inner"),
    ("scheduler/dacp.rs", "schedule_into"),
    ("scheduler/binpack.rs", "balance_into"),
    ("scheduler/shard.rs", "worker"),
    ("fleet/queue.rs", "pick_next"),
    ("fleet/sim.rs", "next_event"),
    ("serve/journal.rs", "append"),
    ("data/dataset.rs", "fill_batch"),
    ("data/dataset.rs", "sample_batch_into"),
    ("stream/spill.rs", "get"),
    ("stream/source.rs", "fill_sampled_batch"),
];

pub const RULES: &[Rule] = &[
    Rule {
        id: "nan-unsafe-ord",
        summary: "partial_cmp-based ordering; NaN makes it panic or reorder (use f64::total_cmp)",
        scope: Scope::All,
    },
    Rule {
        id: "truncating-cast",
        summary: "narrowing `as` cast in an accumulation path can wrap silently",
        scope: Scope::Within(ACCUMULATION_MODULES),
    },
    Rule {
        id: "hot-path-alloc",
        summary: "allocation-capable construct inside a declared zero-alloc hot path",
        scope: Scope::Within(HOT_PATH_MODULES),
    },
    Rule {
        id: "nondet-iteration",
        summary: "HashMap/HashSet in schedule-output-affecting code breaks byte-identity",
        scope: Scope::Within(DETERMINISTIC_MODULES),
    },
    Rule {
        id: "wall-clock-in-pure-code",
        summary: "Instant/SystemTime outside the sanctioned timing sites",
        scope: Scope::Except(TIMING_SANCTIONED),
    },
    Rule {
        id: "panic-in-lib",
        summary: "unwrap/expect/panic! in library code where error propagation is the convention",
        scope: Scope::Within(ERROR_CONVENTION_MODULES),
    },
];

/// Meta rules emitted by the engine itself; they cannot be suppressed.
pub const META_RULES: &[&str] = &["malformed-suppression", "unused-suppression"];

pub fn is_known_rule(id: &str) -> bool {
    RULES.iter().any(|r| r.id == id)
}

/// A rule hit before suppression matching.
#[derive(Clone, Debug)]
pub struct RawFinding {
    pub rule: &'static str,
    pub line: u32,
    pub col: u32,
    pub message: String,
}

const NARROW_INTS: &[&str] = &["u8", "i8", "u16", "i16", "u32", "i32"];
const ALLOC_METHODS: &[&str] = &["collect", "clone", "to_vec", "to_owned"];
const ALLOC_MACROS: &[&str] = &["vec", "format"];
const ALLOC_PATHS: &[&str] = &["Vec", "Box", "String"];
const PANIC_METHODS: &[&str] = &["unwrap", "expect"];
const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

fn text_at(toks: &[Token<'_>], i: usize) -> &str {
    toks.get(i).map_or("", |t| t.text)
}

/// Run every rule over one file's token stream.  Findings in `#[cfg(test)]`
/// items are dropped at the source; scope filtering happens here too.
pub fn check_file(rel: &str, toks: &[Token<'_>]) -> Vec<RawFinding> {
    let mut out = Vec::new();
    let scoped =
        |id: &'static str| RULES.iter().any(|r| r.id == id && r.scope.contains(rel));
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || t.in_test {
            continue;
        }
        let next = text_at(toks, i + 1);
        let prev = if i > 0 { text_at(toks, i - 1) } else { "" };
        if t.text == "partial_cmp" && scoped("nan-unsafe-ord") {
            out.push(RawFinding {
                rule: "nan-unsafe-ord",
                line: t.line,
                col: t.col,
                message: "partial_cmp-based ordering (NaN-unsafe); use f64::total_cmp".into(),
            });
        }
        if t.text == "as" && NARROW_INTS.contains(&next) && scoped("truncating-cast") {
            out.push(RawFinding {
                rule: "truncating-cast",
                line: t.line,
                col: t.col,
                message: format!(
                    "narrowing `as {next}` can truncate silently; use try_from or a checked helper"
                ),
            });
        }
        if (t.text == "HashMap" || t.text == "HashSet") && scoped("nondet-iteration") {
            out.push(RawFinding {
                rule: "nondet-iteration",
                line: t.line,
                col: t.col,
                message: format!(
                    "{} iteration order is nondeterministic; use BTreeMap/BTreeSet here",
                    t.text
                ),
            });
        }
        if (t.text == "Instant" || t.text == "SystemTime") && scoped("wall-clock-in-pure-code") {
            out.push(RawFinding {
                rule: "wall-clock-in-pure-code",
                line: t.line,
                col: t.col,
                message: format!(
                    "{} outside the sanctioned timing sites breaks --deterministic-timing",
                    t.text
                ),
            });
        }
        if scoped("panic-in-lib") {
            if PANIC_METHODS.contains(&t.text) && prev == "." {
                out.push(RawFinding {
                    rule: "panic-in-lib",
                    line: t.line,
                    col: t.col,
                    message: format!(
                        ".{}() in library code; propagate a structured error instead",
                        t.text
                    ),
                });
            }
            if PANIC_MACROS.contains(&t.text) && next == "!" {
                out.push(RawFinding {
                    rule: "panic-in-lib",
                    line: t.line,
                    col: t.col,
                    message: format!(
                        "{}! in library code; propagate a structured error instead",
                        t.text
                    ),
                });
            }
        }
    }
    if scoped("hot-path-alloc") {
        check_hot_paths(rel, toks, &mut out);
    }
    out
}

/// Scan the bodies of the declared hot-path functions in `rel` for
/// allocation-capable constructs.
fn check_hot_paths(rel: &str, toks: &[Token<'_>], out: &mut Vec<RawFinding>) {
    let hot: Vec<&str> =
        HOT_FUNCTIONS.iter().filter(|(p, _)| *p == rel).map(|(_, f)| *f).collect();
    if hot.is_empty() {
        return;
    }
    let n = toks.len();
    let mut i = 0;
    while i < n {
        if !(toks[i].text == "fn" && hot.contains(&text_at(toks, i + 1))) {
            i += 1;
            continue;
        }
        let name = text_at(toks, i + 1);
        // find the body's `{`; a `;` first means a trait-method signature
        let mut j = i + 2;
        while j < n && toks[j].text != "{" && toks[j].text != ";" {
            j += 1;
        }
        if j >= n || toks[j].text == ";" {
            i = j + 1;
            continue;
        }
        let mut depth = 0usize;
        let mut k = j;
        while k < n {
            match toks[k].text {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        for m in j..=k.min(n - 1) {
            let t = &toks[m];
            if t.kind != TokKind::Ident || t.in_test {
                continue;
            }
            let next = text_at(toks, m + 1);
            let prev = if m > 0 { text_at(toks, m - 1) } else { "" };
            let what = if ALLOC_MACROS.contains(&t.text) && next == "!" {
                Some(format!("{}!", t.text))
            } else if ALLOC_METHODS.contains(&t.text) && prev == "." {
                Some(format!(".{}()", t.text))
            } else if ALLOC_PATHS.contains(&t.text)
                && next == ":"
                && text_at(toks, m + 2) == ":"
                && text_at(toks, m + 3) == "new"
            {
                Some(format!("{}::new", t.text))
            } else {
                None
            };
            if let Some(what) = what {
                out.push(RawFinding {
                    rule: "hot-path-alloc",
                    line: t.line,
                    col: t.col,
                    message: format!("{what} allocates inside declared hot path fn `{name}`"),
                });
            }
        }
        i = k + 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn rules_hit(rel: &str, src: &str) -> Vec<&'static str> {
        let lexed = lex(src);
        check_file(rel, &lexed.tokens).into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn scope_gates_rules_by_path() {
        let src = "fn f() { x.unwrap(); }";
        assert_eq!(rules_hit("scheduler/x.rs", src), ["panic-in-lib"]);
        assert!(rules_hit("util/x.rs", src).is_empty());
    }

    #[test]
    fn wall_clock_allows_sanctioned_sites() {
        let src = "fn f() { let t = Instant::now(); }";
        assert_eq!(rules_hit("cluster/x.rs", src), ["wall-clock-in-pure-code"]);
        assert!(rules_hit("bench/x.rs", src).is_empty());
        assert!(rules_hit("data/loader.rs", src).is_empty());
    }

    #[test]
    fn hot_path_alloc_only_in_declared_fns() {
        let src = "
            fn schedule_rank_inner() { let v = vec![1]; }
            fn helper() { let v = vec![1]; }
        ";
        assert_eq!(rules_hit("scheduler/gds.rs", src), ["hot-path-alloc"]);
        assert!(rules_hit("scheduler/other.rs", src).is_empty());
    }

    #[test]
    fn narrowing_casts_flagged_widening_ignored() {
        let src = "fn f(x: u64) { let a = x as u32; let b = x as u128; let c = 3u32 as u64; }";
        assert_eq!(rules_hit("scheduler/x.rs", src), ["truncating-cast"]);
        assert!(rules_hit("cluster/x.rs", src).is_empty(), "cluster is not an accumulation path");
    }

    #[test]
    fn unwrap_or_is_not_unwrap() {
        let src = "fn f() { x.unwrap_or(0); y.unwrap_or_else(Vec::new); }";
        assert!(rules_hit("scheduler/x.rs", src).is_empty());
    }

    #[test]
    fn test_items_are_exempt() {
        let src = "
            #[cfg(test)]
            mod tests {
                fn t() { x.unwrap(); let m = HashMap::new(); }
            }
        ";
        assert!(rules_hit("scheduler/x.rs", src).is_empty());
    }
}
