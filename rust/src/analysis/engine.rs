//! The lint engine: walk a source tree, lex each file, run the rule
//! catalog, then resolve `// skrull-lint: allow(<rule>) -- <reason>`
//! suppressions.  A suppression on line L covers findings on L (trailing
//! comment) and L+1 (standalone comment above the offending line), must
//! name a known rule, and must carry a `-- reason`; violations of those
//! requirements are themselves findings (`malformed-suppression`,
//! `unused-suppression`) so a typo can never silently disable a rule.

use std::path::{Path, PathBuf};

use crate::analysis::lexer::{self, Suppression};
use crate::analysis::rules;
use crate::util::error::{Context, Result};

/// One resolved finding.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub rule: String,
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub message: String,
    pub suppressed: bool,
    /// The justification, for suppressed findings.
    pub reason: Option<String>,
}

/// The result of linting a tree (or a single source text).
#[derive(Clone, Debug, Default)]
pub struct LintOutcome {
    /// All findings, sorted by (file, line, col, rule).
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl LintOutcome {
    pub fn unsuppressed(&self) -> usize {
        self.findings.iter().filter(|f| !f.suppressed).count()
    }

    pub fn suppressed(&self) -> usize {
        self.findings.len() - self.unsuppressed()
    }
}

/// Lint one file's source text.  `rel` is its path relative to the scan
/// root (`/`-separated) — rule scopes key off it.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let lexed = lexer::lex(src);
    let raw = rules::check_file(rel, &lexed.tokens);
    let mut used = vec![false; lexed.suppressions.len()];
    let mut out: Vec<Finding> = Vec::new();
    for f in raw {
        let sup = lexed.suppressions.iter().enumerate().find(|(_, s)| {
            s.rule.as_deref() == Some(f.rule) && (s.line == f.line || s.line + 1 == f.line)
        });
        let (suppressed, reason) = match sup {
            // a reason-less directive stays malformed; it must not
            // silence anything
            Some((si, s)) if s.reason.is_some() => {
                used[si] = true;
                (true, s.reason.clone())
            }
            _ => (false, None),
        };
        out.push(Finding {
            rule: f.rule.to_string(),
            file: rel.to_string(),
            line: f.line,
            col: f.col,
            message: f.message,
            suppressed,
            reason,
        });
    }
    for (si, s) in lexed.suppressions.iter().enumerate() {
        if let Some(meta) = audit_suppression(s, used[si]) {
            out.push(Finding {
                rule: meta.0.to_string(),
                file: rel.to_string(),
                line: s.line,
                col: 1,
                message: meta.1,
                suppressed: false,
                reason: None,
            });
        }
    }
    out.sort_by(|a, b| (a.line, a.col, &a.rule).cmp(&(b.line, b.col, &b.rule)));
    out
}

/// Decide whether a suppression directive is itself a finding.
fn audit_suppression(s: &Suppression, used: bool) -> Option<(&'static str, String)> {
    match &s.rule {
        None => Some((
            "malformed-suppression",
            "unparseable skrull-lint directive; want `skrull-lint: allow(<rule>) -- <reason>`"
                .to_string(),
        )),
        Some(rule) if !rules::is_known_rule(rule) => Some((
            "malformed-suppression",
            format!("suppression names unknown rule {rule:?}"),
        )),
        Some(rule) if s.reason.is_none() => Some((
            "malformed-suppression",
            format!("suppression of {rule} lacks the required `-- <reason>` justification"),
        )),
        Some(rule) if !used => {
            Some(("unused-suppression", format!("suppression of {rule} matches no finding")))
        }
        Some(_) => None,
    }
}

/// Recursively collect `*.rs` files under `root`, sorted by relative
/// path so output is deterministic on any filesystem.
fn collect_sources(root: &Path) -> Result<Vec<(String, PathBuf)>> {
    let mut stack = vec![root.to_path_buf()];
    let mut files = Vec::new();
    while let Some(dir) = stack.pop() {
        let entries = std::fs::read_dir(&dir)
            .with_context(|| format!("reading directory {}", dir.display()))?;
        for entry in entries {
            let entry = entry.with_context(|| format!("reading entry in {}", dir.display()))?;
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = path
                    .strip_prefix(root)
                    .unwrap_or(&path)
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy())
                    .collect::<Vec<_>>()
                    .join("/");
                files.push((rel, path));
            }
        }
    }
    files.sort();
    Ok(files)
}

/// Lint every `*.rs` file under `root`.
pub fn lint_tree(root: &Path) -> Result<LintOutcome> {
    let files = collect_sources(root)?;
    let mut outcome = LintOutcome { findings: Vec::new(), files_scanned: files.len() };
    for (rel, path) in files {
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        outcome.findings.extend(lint_source(&rel, &src));
    }
    Ok(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suppression_with_reason_covers_same_and_next_line() {
        let src = "
            // skrull-lint: allow(panic-in-lib) -- invariant: x is Some here
            fn f() { x.unwrap(); }
            fn g() { y.unwrap(); } // skrull-lint: allow(panic-in-lib) -- join propagates panics
        ";
        let fs = lint_source("scheduler/x.rs", src);
        assert_eq!(fs.len(), 2);
        assert!(fs.iter().all(|f| f.suppressed), "{fs:?}");
        assert!(fs.iter().all(|f| f.reason.is_some()));
    }

    #[test]
    fn reasonless_suppression_is_malformed_and_does_not_silence() {
        let src = "
            // skrull-lint: allow(panic-in-lib)
            fn f() { x.unwrap(); }
        ";
        let fs = lint_source("scheduler/x.rs", src);
        let rules: Vec<&str> = fs.iter().map(|f| f.rule.as_str()).collect();
        assert_eq!(rules, ["malformed-suppression", "panic-in-lib"]);
        assert!(fs.iter().all(|f| !f.suppressed));
    }

    #[test]
    fn unknown_rule_and_unused_suppressions_are_findings() {
        let src = "
            // skrull-lint: allow(no-such-rule) -- because
            // skrull-lint: allow(panic-in-lib) -- nothing to suppress here
            fn f() {}
        ";
        let rules: Vec<String> =
            lint_source("scheduler/x.rs", src).into_iter().map(|f| f.rule).collect();
        assert_eq!(rules, ["malformed-suppression", "unused-suppression"]);
    }

    #[test]
    fn wrong_rule_suppression_does_not_cover() {
        let src = "
            // skrull-lint: allow(truncating-cast) -- wrong rule named
            fn f() { x.unwrap(); }
        ";
        let fs = lint_source("scheduler/x.rs", src);
        assert!(fs.iter().any(|f| f.rule == "panic-in-lib" && !f.suppressed));
        assert!(fs.iter().any(|f| f.rule == "unused-suppression"));
    }

    #[test]
    fn one_suppression_covers_multiple_same_rule_findings_on_its_line() {
        let src = "
            // skrull-lint: allow(panic-in-lib) -- both guarded by the assert above
            fn f() { x.unwrap(); y.unwrap(); }
        ";
        let fs = lint_source("scheduler/x.rs", src);
        assert_eq!(fs.len(), 2);
        assert!(fs.iter().all(|f| f.suppressed));
    }
}
