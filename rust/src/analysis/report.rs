//! Rendering and validation of lint results: a human-readable listing
//! for the terminal and a JSON report (`LINT_REPORT.json`) for CI.  The
//! JSON reader here is a small nested-value parser in the
//! `calib::profile_io` cursor idiom (`profile_io` itself only parses the
//! flat subset its schemas need; the lint report nests findings inside
//! an array of objects).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::analysis::engine::{Finding, LintOutcome};
use crate::util::error::{Context, Result};

pub const SCHEMA_VERSION: u64 = 1;

/// Human-readable listing: one `file:line:col rule message` per finding,
/// suppressed ones annotated with their justification, then a summary.
pub fn render_human(outcome: &LintOutcome) -> String {
    let mut s = String::new();
    for f in &outcome.findings {
        let _ = write!(s, "{}:{}:{} [{}] {}", f.file, f.line, f.col, f.rule, f.message);
        match &f.reason {
            Some(reason) if f.suppressed => {
                let _ = writeln!(s, " (suppressed: {reason})");
            }
            _ => {
                let _ = writeln!(s);
            }
        }
    }
    let _ = writeln!(
        s,
        "{} files scanned: {} finding(s), {} unsuppressed, {} suppressed",
        outcome.files_scanned,
        outcome.findings.len(),
        outcome.unsuppressed(),
        outcome.suppressed(),
    );
    s
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Render the JSON report (schema v1).
pub fn render_json(outcome: &LintOutcome) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "{{");
    let _ = writeln!(s, "  \"schema_version\": {SCHEMA_VERSION},");
    let _ = writeln!(s, "  \"tool\": \"skrull-lint\",");
    let _ = writeln!(s, "  \"files_scanned\": {},", outcome.files_scanned);
    let _ = writeln!(s, "  \"total\": {},", outcome.findings.len());
    let _ = writeln!(s, "  \"unsuppressed\": {},", outcome.unsuppressed());
    let _ = writeln!(s, "  \"suppressed\": {},", outcome.suppressed());
    let _ = writeln!(s, "  \"findings\": [");
    for (i, f) in outcome.findings.iter().enumerate() {
        let reason = match &f.reason {
            Some(r) => format!("\"{}\"", esc(r)),
            None => "null".to_string(),
        };
        let _ = write!(
            s,
            "    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"col\": {}, \
             \"suppressed\": {}, \"reason\": {}, \"message\": \"{}\"}}",
            esc(&f.rule),
            esc(&f.file),
            f.line,
            f.col,
            f.suppressed,
            reason,
            esc(&f.message),
        );
        let _ = writeln!(s, "{}", if i + 1 < outcome.findings.len() { "," } else { "" });
    }
    let _ = writeln!(s, "  ]");
    let _ = writeln!(s, "}}");
    s
}

/// A parsed JSON value (nested, unlike `profile_io::Jval`).
#[derive(Clone, Debug, PartialEq)]
enum Val {
    Num(f64),
    Str(String),
    Bool(bool),
    Null,
    Arr(Vec<Val>),
    Obj(BTreeMap<String, Val>),
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(text: &'a str) -> Self {
        Cursor { bytes: text.as_bytes(), pos: 0 }
    }

    fn peek(&mut self) -> Option<u8> {
        while self.bytes.get(self.pos).is_some_and(|b| b.is_ascii_whitespace()) {
            self.pos += 1;
        }
        self.bytes.get(self.pos).copied()
    }

    fn expect_byte(&mut self, c: u8) -> Result<()> {
        match self.peek() {
            Some(b) if b == c => {
                self.pos += 1;
                Ok(())
            }
            other => crate::bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.pos,
                other.map(|b| b as char)
            ),
        }
    }

    fn eat_word(&mut self, word: &str) -> bool {
        self.peek();
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            true
        } else {
            false
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            let Some(b) = self.bytes.get(self.pos).copied() else {
                crate::bail!("unterminated string at byte {}", self.pos);
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.bytes.get(self.pos).copied() else {
                        crate::bail!("dangling escape at byte {}", self.pos);
                    };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .and_then(char::from_u32)
                                .with_context(|| format!("bad \\u escape at {}", self.pos))?;
                            self.pos += 4;
                            out.push(hex);
                        }
                        other => crate::bail!("unsupported escape \\{}", other as char),
                    }
                }
                other => out.push(other as char),
            }
        }
    }

    fn number(&mut self) -> Result<f64> {
        self.peek();
        let start = self.pos;
        while self
            .bytes
            .get(self.pos)
            .is_some_and(|b| b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse().ok())
            .with_context(|| format!("bad number at byte {start}"))
    }

    fn value(&mut self) -> Result<Val> {
        match self.peek() {
            Some(b'"') => Ok(Val::Str(self.string()?)),
            Some(b'{') => {
                self.pos += 1;
                let mut map = BTreeMap::new();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Val::Obj(map));
                }
                loop {
                    let key = self.string()?;
                    self.expect_byte(b':')?;
                    map.insert(key, self.value()?);
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Val::Obj(map));
                        }
                        other => crate::bail!("expected ',' or '}}' in object, found {other:?}"),
                    }
                }
            }
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Val::Arr(items));
                }
                loop {
                    items.push(self.value()?);
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Val::Arr(items));
                        }
                        other => crate::bail!("expected ',' or ']' in array, found {other:?}"),
                    }
                }
            }
            Some(b't') | Some(b'f') => {
                if self.eat_word("true") {
                    Ok(Val::Bool(true))
                } else if self.eat_word("false") {
                    Ok(Val::Bool(false))
                } else {
                    crate::bail!("bad literal at byte {}", self.pos)
                }
            }
            Some(b'n') => {
                if self.eat_word("null") {
                    Ok(Val::Null)
                } else {
                    crate::bail!("bad literal at byte {}", self.pos)
                }
            }
            Some(_) => Ok(Val::Num(self.number()?)),
            None => crate::bail!("unexpected end of input"),
        }
    }
}

/// A parsed `LINT_REPORT.json`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ParsedReport {
    pub files_scanned: u64,
    pub findings: Vec<Finding>,
}

fn need_u64(map: &BTreeMap<String, Val>, key: &str) -> Result<u64> {
    match map.get(key) {
        Some(Val::Num(x)) if *x >= 0.0 && x.fract() == 0.0 => Ok(*x as u64),
        other => crate::bail!("report key {key:?}: want a non-negative integer, got {other:?}"),
    }
}

fn need_str(map: &BTreeMap<String, Val>, key: &str) -> Result<String> {
    match map.get(key) {
        Some(Val::Str(s)) => Ok(s.clone()),
        other => crate::bail!("report key {key:?}: want a string, got {other:?}"),
    }
}

fn need_bool(map: &BTreeMap<String, Val>, key: &str) -> Result<bool> {
    match map.get(key) {
        Some(Val::Bool(b)) => Ok(*b),
        other => crate::bail!("report key {key:?}: want a bool, got {other:?}"),
    }
}

/// Parse a lint report, checking schema shape and internal consistency
/// (counts must match the findings array; suppressed findings must carry
/// a justification).
pub fn parse_report(text: &str) -> Result<ParsedReport> {
    let mut c = Cursor::new(text);
    let Val::Obj(top) = c.value()? else {
        crate::bail!("lint report must be a JSON object");
    };
    if c.peek().is_some() {
        crate::bail!("trailing garbage after the report object at byte {}", c.pos);
    }
    let version = need_u64(&top, "schema_version")?;
    crate::ensure!(
        version == SCHEMA_VERSION,
        "unsupported lint report schema_version {version} (want {SCHEMA_VERSION})"
    );
    let tool = need_str(&top, "tool")?;
    crate::ensure!(tool == "skrull-lint", "not a skrull-lint report (tool = {tool:?})");
    let files_scanned = need_u64(&top, "files_scanned")?;
    let Some(Val::Arr(items)) = top.get("findings") else {
        crate::bail!("report key \"findings\": want an array");
    };
    let mut findings = Vec::with_capacity(items.len());
    for (i, item) in items.iter().enumerate() {
        let Val::Obj(f) = item else {
            crate::bail!("finding {i}: want an object");
        };
        let reason = match f.get("reason") {
            Some(Val::Str(s)) => Some(s.clone()),
            Some(Val::Null) | None => None,
            other => crate::bail!("finding {i}: reason must be a string or null, got {other:?}"),
        };
        let finding = Finding {
            rule: need_str(f, "rule")?,
            file: need_str(f, "file")?,
            line: u32::try_from(need_u64(f, "line")?)
                .map_err(|_| crate::anyhow!("finding {i}: line out of range"))?,
            col: u32::try_from(need_u64(f, "col")?)
                .map_err(|_| crate::anyhow!("finding {i}: col out of range"))?,
            message: need_str(f, "message")?,
            suppressed: need_bool(f, "suppressed")?,
            reason,
        };
        crate::ensure!(
            !finding.suppressed || finding.reason.as_deref().is_some_and(|r| !r.is_empty()),
            "finding {i} ({}:{} {}) is suppressed without a written reason",
            finding.file,
            finding.line,
            finding.rule
        );
        findings.push(finding);
    }
    let total = need_u64(&top, "total")?;
    let unsuppressed = need_u64(&top, "unsuppressed")?;
    let suppressed = need_u64(&top, "suppressed")?;
    let actual_unsup = findings.iter().filter(|f| !f.suppressed).count() as u64;
    crate::ensure!(
        total == findings.len() as u64,
        "total {total} does not match the {} findings listed",
        findings.len()
    );
    crate::ensure!(
        unsuppressed == actual_unsup && suppressed == total - actual_unsup,
        "suppression counts ({unsuppressed}/{suppressed}) disagree with the findings array"
    );
    Ok(ParsedReport { files_scanned, findings })
}

/// The CI gate: a report is valid iff it parses, is internally
/// consistent, and lists zero unsuppressed findings.
pub fn validate_json(text: &str) -> Result<()> {
    let report = parse_report(text)?;
    let unsup: Vec<&Finding> = report.findings.iter().filter(|f| !f.suppressed).collect();
    crate::ensure!(
        unsup.is_empty(),
        "{} unsuppressed finding(s), first: {}:{}:{} [{}] {}",
        unsup.len(),
        unsup[0].file,
        unsup[0].line,
        unsup[0].col,
        unsup[0].rule,
        unsup[0].message
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::engine::lint_source;

    fn outcome_of(rel: &str, src: &str) -> LintOutcome {
        LintOutcome { findings: lint_source(rel, src), files_scanned: 1 }
    }

    #[test]
    fn json_round_trips_through_the_parser() {
        let out = outcome_of(
            "scheduler/x.rs",
            "
            fn f() { a.unwrap(); }
            // skrull-lint: allow(truncating-cast) -- bounded by \"cp\" \\ degree
            fn g(x: u64) -> u32 { x as u32 }
            ",
        );
        let json = render_json(&out);
        let parsed = parse_report(&json).unwrap();
        assert_eq!(parsed.files_scanned, 1);
        assert_eq!(parsed.findings, out.findings);
    }

    #[test]
    fn validate_fails_on_unsuppressed_findings() {
        let out = outcome_of("scheduler/x.rs", "fn f() { a.unwrap(); }");
        let err = validate_json(&render_json(&out)).unwrap_err();
        assert!(format!("{err:#}").contains("panic-in-lib"), "{err:#}");
    }

    #[test]
    fn validate_passes_on_clean_and_fully_suppressed_reports() {
        let clean = outcome_of("scheduler/x.rs", "fn f() {}");
        validate_json(&render_json(&clean)).unwrap();
        let suppressed = outcome_of(
            "scheduler/x.rs",
            "
            // skrull-lint: allow(panic-in-lib) -- test fixture
            fn f() { a.unwrap(); }
            ",
        );
        validate_json(&render_json(&suppressed)).unwrap();
    }

    #[test]
    fn tampered_counts_are_rejected() {
        let out = outcome_of("scheduler/x.rs", "fn f() { a.unwrap(); }");
        let json = render_json(&out).replace("\"unsuppressed\": 1", "\"unsuppressed\": 0");
        assert!(parse_report(&json).is_err());
    }

    #[test]
    fn suppressed_without_reason_is_rejected() {
        let json = r#"{
            "schema_version": 1, "tool": "skrull-lint", "files_scanned": 1,
            "total": 1, "unsuppressed": 0, "suppressed": 1,
            "findings": [
                {"rule": "panic-in-lib", "file": "x.rs", "line": 1, "col": 1,
                 "suppressed": true, "reason": null, "message": "m"}
            ]
        }"#;
        assert!(parse_report(json).is_err());
    }
}
