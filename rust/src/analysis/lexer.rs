//! A minimal Rust lexer for `skrull lint` — just enough token structure
//! for the rule engine: identifiers, numbers, string/char literals,
//! lifetimes and single-character punctuation, with comments consumed
//! (line comments are scanned for `skrull-lint:` suppression directives)
//! and `#[cfg(test)]` / `#[test]` items marked so rules can skip test
//! code.  Hand-rolled in the `calib::profile_io` byte-cursor idiom: `syn`
//! is unavailable offline, and the rules below only need token shapes,
//! not a parse tree.

/// What a [`Token`] is.  String/char literals carry no text — no rule
/// inspects literal contents, and dropping them keeps tokens cheap.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Number,
    Str,
    Char,
    Lifetime,
    Punct,
}

/// One lexed token.  `text` borrows from the source for `Ident`,
/// `Number`, `Lifetime` and `Punct`; literals get `""`.
#[derive(Clone, Copy, Debug)]
pub struct Token<'a> {
    pub kind: TokKind,
    pub text: &'a str,
    pub line: u32,
    pub col: u32,
    /// Inside a `#[cfg(test)]` / `#[test]` item — rules skip these.
    pub in_test: bool,
}

/// A `// skrull-lint: allow(<rule>) -- <reason>` directive, or a comment
/// that tried to be one.  `rule` is `None` when the directive failed to
/// parse at all (the engine reports that as `malformed-suppression`
/// rather than silently ignoring a typo).
#[derive(Clone, Debug)]
pub struct Suppression {
    pub line: u32,
    pub rule: Option<String>,
    pub reason: Option<String>,
}

/// Lexer output: the token stream plus every suppression directive seen.
#[derive(Debug, Default)]
pub struct Lexed<'a> {
    pub tokens: Vec<Token<'a>>,
    pub suppressions: Vec<Suppression>,
}

const DIRECTIVE: &str = "skrull-lint";

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

struct Scanner<'a> {
    src: &'a str,
    bytes: &'a [u8],
    pos: usize,
    line: u32,
    col: u32,
}

impl<'a> Scanner<'a> {
    fn new(src: &'a str) -> Self {
        Scanner { src, bytes: src.as_bytes(), pos: 0, line: 1, col: 1 }
    }

    fn peek(&self, ahead: usize) -> Option<u8> {
        self.bytes.get(self.pos + ahead).copied()
    }

    /// Advance one byte, tracking line/col.  Multi-byte UTF-8 sequences
    /// advance col once per byte — columns are byte offsets, which is
    /// what editors jumping to `file:line:col` expect for ASCII source.
    fn bump(&mut self) {
        if self.peek(0) == Some(b'\n') {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        self.pos += 1;
    }

    fn bump_n(&mut self, n: usize) {
        for _ in 0..n {
            self.bump();
        }
    }
}

/// Parse a line comment as a suppression directive.  Only a comment whose
/// body *starts* with the marker counts (prose merely mentioning
/// `skrull-lint` mid-sentence is not a directive); returns `None` for
/// everything else.  An attempted directive that fails to parse comes
/// back with `rule: None` so the engine can flag it.
fn parse_directive(comment: &str, line: u32) -> Option<Suppression> {
    // strip the `//` / `///` / `//!` opener, then leading whitespace
    let body = comment.trim_start_matches('/').trim_start_matches('!').trim_start();
    let rest = body.strip_prefix(DIRECTIVE)?.trim_start();
    let malformed = Suppression { line, rule: None, reason: None };
    let Some(rest) = rest.strip_prefix(':') else {
        return Some(malformed);
    };
    let rest = rest.trim_start();
    let Some(rest) = rest.strip_prefix("allow(") else {
        return Some(malformed);
    };
    let Some(close) = rest.find(')') else {
        return Some(malformed);
    };
    let rule = rest[..close].trim().to_string();
    if rule.is_empty() {
        return Some(malformed);
    }
    let tail = rest[close + 1..].trim();
    let reason = tail
        .strip_prefix("--")
        .map(str::trim)
        .filter(|r| !r.is_empty())
        .map(str::to_string);
    Some(Suppression { line, rule: Some(rule), reason })
}

/// Lex `src` into tokens + suppression directives.  Never fails: anything
/// unrecognized becomes single-byte punctuation, and unterminated
/// literals/comments run to end of input (the rules only need to stay
/// aligned on well-formed source, which `cargo build` guarantees).
pub fn lex(src: &str) -> Lexed<'_> {
    let mut s = Scanner::new(src);
    let mut out = Lexed::default();
    while let Some(c) = s.peek(0) {
        if c.is_ascii_whitespace() {
            s.bump();
            continue;
        }
        // line comment — scan for a suppression directive
        if c == b'/' && s.peek(1) == Some(b'/') {
            let (start, line) = (s.pos, s.line);
            while s.peek(0).is_some_and(|b| b != b'\n') {
                s.bump();
            }
            if let Some(d) = parse_directive(&s.src[start..s.pos], line) {
                out.suppressions.push(d);
            }
            continue;
        }
        // block comment, nested per Rust rules
        if c == b'/' && s.peek(1) == Some(b'*') {
            let mut depth = 0usize;
            while s.peek(0).is_some() {
                if s.peek(0) == Some(b'/') && s.peek(1) == Some(b'*') {
                    depth += 1;
                    s.bump_n(2);
                } else if s.peek(0) == Some(b'*') && s.peek(1) == Some(b'/') {
                    depth -= 1;
                    s.bump_n(2);
                    if depth == 0 {
                        break;
                    }
                } else {
                    s.bump();
                }
            }
            continue;
        }
        // raw strings r"…" / r#"…"# / br"…", and raw identifiers r#ident
        if c == b'r' || c == b'b' {
            let after_prefix =
                if c == b'b' && s.peek(1) == Some(b'r') { 2usize } else { 1 };
            let raw = c == b'r' || (c == b'b' && s.peek(1) == Some(b'r'));
            if raw && matches!(s.peek(after_prefix), Some(b'#') | Some(b'"')) {
                let mut hashes = 0usize;
                while s.peek(after_prefix + hashes) == Some(b'#') {
                    hashes += 1;
                }
                if s.peek(after_prefix + hashes) == Some(b'"') {
                    // raw string: body ends at `"` + the same hash count
                    let (line, col) = (s.line, s.col);
                    s.bump_n(after_prefix + hashes + 1);
                    let mut close = String::from('"');
                    for _ in 0..hashes {
                        close.push('#');
                    }
                    let end = s.src[s.pos..].find(&close).map(|r| r + close.len());
                    s.bump_n(end.unwrap_or(s.bytes.len() - s.pos));
                    let tok = Token { kind: TokKind::Str, text: "", line, col, in_test: false };
                    out.tokens.push(tok);
                    continue;
                }
                if c == b'r' && hashes == 1 && s.peek(2).is_some_and(is_ident_start) {
                    // raw identifier r#ident — token text excludes `r#`
                    let (line, col) = (s.line, s.col);
                    s.bump_n(2);
                    let start = s.pos;
                    while s.peek(0).is_some_and(is_ident_cont) {
                        s.bump();
                    }
                    let text = &s.src[start..s.pos];
                    let tok = Token { kind: TokKind::Ident, text, line, col, in_test: false };
                    out.tokens.push(tok);
                    continue;
                }
            }
            // otherwise: an ordinary identifier starting with r/b
        }
        if is_ident_start(c) {
            let (line, col, start) = (s.line, s.col, s.pos);
            while s.peek(0).is_some_and(is_ident_cont) {
                s.bump();
            }
            let text = &s.src[start..s.pos];
            out.tokens.push(Token { kind: TokKind::Ident, text, line, col, in_test: false });
            continue;
        }
        if c.is_ascii_digit() {
            let (line, col, start) = (s.line, s.col, s.pos);
            while let Some(b) = s.peek(0) {
                // stop before `..` so ranges stay punctuation
                if b == b'.' && s.peek(1) == Some(b'.') {
                    break;
                }
                if !(is_ident_cont(b) || b == b'.') {
                    break;
                }
                s.bump();
            }
            let text = &s.src[start..s.pos];
            out.tokens.push(Token { kind: TokKind::Number, text, line, col, in_test: false });
            continue;
        }
        if c == b'"' {
            let (line, col) = (s.line, s.col);
            s.bump();
            while let Some(b) = s.peek(0) {
                if b == b'\\' {
                    s.bump_n(2);
                } else if b == b'"' {
                    s.bump();
                    break;
                } else {
                    s.bump();
                }
            }
            out.tokens.push(Token { kind: TokKind::Str, text: "", line, col, in_test: false });
            continue;
        }
        if c == b'\'' {
            let (line, col) = (s.line, s.col);
            // `'a` (lifetime) vs `'a'` (char): a lifetime is a quote +
            // ident with no closing quote right after the first char
            if s.peek(1).is_some_and(is_ident_start) && s.peek(2) != Some(b'\'') {
                s.bump();
                let start = s.pos;
                while s.peek(0).is_some_and(is_ident_cont) {
                    s.bump();
                }
                let text = &s.src[start..s.pos];
                out.tokens.push(Token { kind: TokKind::Lifetime, text, line, col, in_test: false });
                continue;
            }
            s.bump();
            while let Some(b) = s.peek(0) {
                if b == b'\\' {
                    s.bump_n(2);
                } else if b == b'\'' {
                    s.bump();
                    break;
                } else {
                    s.bump();
                }
            }
            out.tokens.push(Token { kind: TokKind::Char, text: "", line, col, in_test: false });
            continue;
        }
        let (line, col, start) = (s.line, s.col, s.pos);
        s.bump();
        let text = &s.src[start..s.pos];
        out.tokens.push(Token { kind: TokKind::Punct, text, line, col, in_test: false });
    }
    mark_test_items(&mut out.tokens);
    out
}

/// Mark every token inside a `#[cfg(test)]` / `#[test]` item.  The walk
/// is token-shaped, not tree-shaped: on a test-ish attribute it skips any
/// further attributes, finds the item's `{` (bailing on `;` — a braceless
/// item like `#[cfg(test)] use …;`), and brace-matches to the item's end.
fn mark_test_items(tokens: &mut [Token<'_>]) {
    let n = tokens.len();
    let mut i = 0;
    while i < n {
        if !(tokens[i].text == "#" && i + 1 < n && tokens[i + 1].text == "[") {
            i += 1;
            continue;
        }
        // collect the attribute's ident sequence up to the matching `]`
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut idents: Vec<&str> = Vec::new();
        while j < n && depth > 0 {
            match tokens[j].text {
                "[" => depth += 1,
                "]" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            if tokens[j].kind == TokKind::Ident {
                idents.push(tokens[j].text);
            }
            j += 1;
        }
        let is_test = idents.as_slice() == ["test"]
            || (idents.len() >= 2
                && idents[0] == "cfg"
                && idents[1..].contains(&"test")
                && !idents[1..].contains(&"not"));
        if !is_test {
            i = j + 1;
            continue;
        }
        // skip any further attributes on the same item
        let mut k = j + 1;
        while k + 1 < n && tokens[k].text == "#" && tokens[k + 1].text == "[" {
            let mut d = 1usize;
            k += 2;
            while k < n && d > 0 {
                match tokens[k].text {
                    "[" => d += 1,
                    "]" => d -= 1,
                    _ => {}
                }
                k += 1;
            }
        }
        while k < n && tokens[k].text != "{" && tokens[k].text != ";" {
            k += 1;
        }
        if k >= n || tokens[k].text == ";" {
            i = k + 1;
            continue;
        }
        let mut d = 0usize;
        let mut m = k;
        while m < n {
            match tokens[m].text {
                "{" => d += 1,
                "}" => {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
                _ => {}
            }
            m += 1;
        }
        for t in tokens.iter_mut().take((m + 1).min(n)).skip(i) {
            t.in_test = true;
        }
        i = m + 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<&str> {
        lex(src)
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn literals_and_comments_hide_tokens() {
        let src = r##"
            let a = "partial_cmp inside a string";
            // partial_cmp inside a comment
            /* nested /* partial_cmp */ still comment */
            let b = r#"raw partial_cmp"#;
            let c = 'x';
            fn real() -> Ordering { a.partial_cmp(&b) }
        "##;
        let ids = idents(src);
        assert_eq!(ids.iter().filter(|t| **t == "partial_cmp").count(), 1);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let toks = lex("fn f<'a>(x: &'a str) -> char { 'b' }").tokens;
        let lifetimes: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Lifetime).map(|t| t.text).collect();
        assert_eq!(lifetimes, ["a", "a"]);
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Char).count(), 1);
    }

    #[test]
    fn raw_identifiers_lex_as_idents() {
        assert!(idents("let r#type = 1;").contains(&"type"));
    }

    #[test]
    fn cfg_test_items_are_marked() {
        let src = "
            fn lib_code() { x.unwrap(); }
            #[cfg(test)]
            mod tests {
                fn t() { y.unwrap(); }
            }
        ";
        let toks = lex(src).tokens;
        let unwraps: Vec<bool> =
            toks.iter().filter(|t| t.text == "unwrap").map(|t| t.in_test).collect();
        assert_eq!(unwraps, [false, true]);
    }

    #[test]
    fn braceless_cfg_test_item_does_not_swallow_next_item() {
        let src = "
            #[cfg(test)]
            use super::*;
            fn lib_code() { x.unwrap(); }
        ";
        let toks = lex(src).tokens;
        assert!(toks.iter().filter(|t| t.text == "unwrap").all(|t| !t.in_test));
    }

    #[test]
    fn directives_parse_with_and_without_reasons() {
        let src = "
            // skrull-lint: allow(panic-in-lib) -- invariant: guarded above
            // skrull-lint: allow(truncating-cast)
            // skrull-lint: typo(panic-in-lib)
            // plain comment
            // docs that mention the skrull-lint: allow(...) syntax mid-sentence
        ";
        let sups = lex(src).suppressions;
        assert_eq!(sups.len(), 3, "prose mentioning the marker is not a directive");
        assert_eq!(sups[0].rule.as_deref(), Some("panic-in-lib"));
        assert_eq!(sups[0].reason.as_deref(), Some("invariant: guarded above"));
        assert_eq!(sups[1].rule.as_deref(), Some("truncating-cast"));
        assert_eq!(sups[1].reason, None);
        assert_eq!(sups[2].rule, None, "unparseable directive is kept as malformed");
    }
}
