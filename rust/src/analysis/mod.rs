//! `skrull lint` — a repo-aware static analysis pass that turns the
//! scheduler's invariants into enforceable source-tree properties.
//!
//! Every rule encodes an invariant a past PR fixed or audits dynamically:
//! * `nan-unsafe-ord` — the PR 1 `partial_cmp().unwrap()` sort class;
//!   `f64::total_cmp` is the convention.
//! * `truncating-cast` — the PR 6 overflow class: narrowing `as` casts in
//!   scheduler/perfmodel/memplan/config accumulation paths.
//! * `hot-path-alloc` — the static complement of `tests/alloc_audit.rs`:
//!   allocation-capable constructs inside the declared hot-path set.
//! * `nondet-iteration` — HashMap/HashSet where byte-identical schedules
//!   and reports are load-bearing (PR 5/6 determinism gates).
//! * `wall-clock-in-pure-code` — `Instant`/`SystemTime` outside the
//!   sanctioned timing sites (the `--deterministic-timing` contract).
//! * `panic-in-lib` — `unwrap`/`expect`/`panic!` in library modules where
//!   `SchedError`/`Result` propagation is the convention (the PR 2
//!   `capacity_for` panic class).
//!
//! Deliberate exceptions are inline, auditable, and justified:
//! `// skrull-lint: allow(<rule>) -- <reason>` covers its own line and
//! the next; the reason is mandatory, unknown rules and unused or
//! reason-less directives are findings themselves.  The pass is
//! dependency-free (hand-rolled lexer — `syn` is unavailable offline)
//! and deterministic: files sorted by path, findings by position.

pub mod engine;
pub mod lexer;
pub mod report;
pub mod rules;

pub use engine::{lint_source, lint_tree, Finding, LintOutcome};
pub use report::{parse_report, render_human, render_json, validate_json};
pub use rules::{HOT_FUNCTIONS, RULES};
