//! Per-iteration peak-memory simulation.
//!
//! Plays an [`IterationSchedule`] against a [`MemPlan`] with the same
//! static-bucket execution semantics the run engine charges for padding:
//! every CP rank of a micro-batch executes a C-token buffer (or larger,
//! when a baseline policy overfills it), so its peak is
//! `Peak(max(C, local + Σ ceil(dist/cp)))`.  The result is per-GPU peak
//! bytes plus a structured would-be-OOM event for every (micro-batch, GPU)
//! whose modeled peak exceeds physical HBM — the signal `bench::e2e`
//! tracks as `peak_mem_fraction` / `oom_count` and the chrome trace draws
//! as a memory lane.

use crate::memplan::capacity::MemPlan;
use crate::scheduler::plan::IterationSchedule;

/// One modeled out-of-memory event: a (micro-batch, GPU) pair whose peak
/// exceeds physical HBM.
#[derive(Clone, Debug, PartialEq)]
pub struct OomEvent {
    pub iteration: usize,
    pub dp_rank: usize,
    pub cp_rank: usize,
    /// index of the micro-batch within its DP rank's list
    pub micro_batch: usize,
    pub peak_bytes: f64,
    pub hbm_bytes: f64,
}

impl std::fmt::Display for OomEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "OOM: iter {} dp{}/cp{} mb{} needs {:.2} GiB of {:.2} GiB HBM",
            self.iteration,
            self.dp_rank,
            self.cp_rank,
            self.micro_batch,
            self.peak_bytes / (1u64 << 30) as f64,
            self.hbm_bytes / (1u64 << 30) as f64,
        )
    }
}

/// Memory profile of one simulated iteration.
#[derive(Clone, Debug)]
pub struct IterationMemory {
    /// Peak bytes per GPU, indexed `dp_rank * cp + cp_rank`.  GPUs that
    /// executed nothing still hold the static state.
    pub rank_peak_bytes: Vec<f64>,
    /// Every (micro-batch, GPU) whose modeled peak exceeds HBM.
    pub events: Vec<OomEvent>,
}

impl IterationMemory {
    /// Iteration-wide peak over all GPUs.
    pub fn peak_bytes(&self) -> f64 {
        self.rank_peak_bytes.iter().copied().fold(0.0, f64::max)
    }
}

/// Simulate the peak memory of one iteration under static per-rank buckets
/// of `bucket_size` tokens.  `iteration` only labels the emitted events.
pub fn iteration_memory(
    sched: &IterationSchedule,
    plan: &MemPlan,
    bucket_size: u32,
    cp: usize,
    iteration: usize,
) -> IterationMemory {
    let cp = cp.max(1);
    let dp = sched.ranks.len();
    // params + optimizer shards are resident on every GPU at all times
    let mut rank_peak_bytes = vec![plan.static_bytes; dp * cp];
    let mut events = Vec::new();
    for (d, rank) in sched.ranks.iter().enumerate() {
        for (m, mb) in rank.micro_batches.iter().enumerate() {
            // the rank executes its C-token bucket; an overfilling baseline
            // runs what it scheduled (MicroBatch::rank_used_tokens_iter is
            // the one fill rule, shared with the run engine's padding)
            for (j, used) in mb.rank_used_tokens_iter(cp).enumerate() {
                let bucket_tokens = (bucket_size as u64).max(used);
                let peak = plan.peak_bytes(bucket_tokens);
                let slot = &mut rank_peak_bytes[d * cp + j];
                if peak > *slot {
                    *slot = peak;
                }
                if peak > plan.hbm_bytes {
                    events.push(OomEvent {
                        iteration,
                        dp_rank: d,
                        cp_rank: j,
                        micro_batch: m,
                        peak_bytes: peak,
                        hbm_bytes: plan.hbm_bytes,
                    });
                }
            }
        }
    }
    IterationMemory { rank_peak_bytes, events }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Sequence;
    use crate::memplan::capacity::MemoryConfig;
    use crate::model::ModelSpec;
    use crate::scheduler::plan::{DacpPlan, MicroBatch, RankSchedule, DISTRIBUTED};

    fn sched(lens: &[u32], assign: Vec<i32>) -> IterationSchedule {
        IterationSchedule {
            ranks: vec![RankSchedule {
                micro_batches: vec![MicroBatch {
                    seqs: lens
                        .iter()
                        .enumerate()
                        .map(|(i, &len)| Sequence { id: i as u64, len })
                        .collect(),
                    plan: DacpPlan { assign },
                }],
            }],
        }
    }

    fn plan(hbm_gb: f64) -> MemPlan {
        let mem = MemoryConfig { hbm_gb, ..Default::default() };
        MemPlan::new(&ModelSpec::qwen2_5_0_5b(), 1, 2, &mem)
    }

    #[test]
    fn static_bucket_floors_the_peak() {
        // a nearly-empty micro-batch still executes a full C-token bucket
        let p = plan(80.0);
        let s = sched(&[10], vec![0]);
        let m = iteration_memory(&s, &p, 1000, 2, 0);
        assert_eq!(m.rank_peak_bytes.len(), 2);
        for &b in &m.rank_peak_bytes {
            assert!((b - p.peak_bytes(1000)).abs() < 1e-6);
        }
        assert!(m.events.is_empty());
    }

    #[test]
    fn overfilled_bucket_raises_the_peak() {
        // baseline-style overfill: local 3000 > C=1000 on rank 0
        let p = plan(80.0);
        let m = iteration_memory(&sched(&[3000], vec![0]), &p, 1000, 2, 0);
        assert!((m.rank_peak_bytes[0] - p.peak_bytes(3000)).abs() < 1e-6);
        assert!((m.rank_peak_bytes[1] - p.peak_bytes(1000)).abs() < 1e-6);
        assert!(m.peak_bytes() >= m.rank_peak_bytes[1]);
    }

    #[test]
    fn distributed_sequences_charge_ceiling_shares() {
        let p = plan(80.0);
        // 101 tokens over cp=2 → 51 per rank, both ranks identical
        let m = iteration_memory(&sched(&[101], vec![DISTRIBUTED]), &p, 10, 2, 0);
        let expect = p.peak_bytes(51);
        assert!((m.rank_peak_bytes[0] - expect).abs() < 1e-6);
        assert!((m.rank_peak_bytes[1] - expect).abs() < 1e-6);
    }

    #[test]
    fn oom_events_flag_budget_busts_with_coordinates() {
        // 2 GiB HBM cannot hold a 26K-token bucket of the 0.5B model
        let p = plan(2.0);
        let m = iteration_memory(&sched(&[26_000], vec![0]), &p, 26 * 1024, 2, 7);
        assert!(!m.events.is_empty());
        let ev = &m.events[0];
        assert_eq!(ev.iteration, 7);
        assert_eq!(ev.dp_rank, 0);
        assert_eq!(ev.micro_batch, 0);
        assert!(ev.peak_bytes > ev.hbm_bytes);
        assert!(ev.to_string().contains("OOM"));
    }

    #[test]
    fn idle_gpus_hold_static_state_only() {
        let p = plan(80.0);
        let empty = IterationSchedule { ranks: vec![RankSchedule::default(); 3] };
        let m = iteration_memory(&empty, &p, 26 * 1024, 2, 0);
        assert_eq!(m.rank_peak_bytes.len(), 6);
        for &b in &m.rank_peak_bytes {
            assert_eq!(b, p.static_bytes);
        }
        assert!(m.events.is_empty());
        assert_eq!(m.peak_bytes(), p.static_bytes);
    }
}
