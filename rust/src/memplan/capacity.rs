//! HBM-derived token capacities: the inversion of the peak-memory model.
//!
//! Peak memory of one rank executing one static bucket of C tokens:
//!
//!   Peak(C) = Static + (α_act + α_ring)·C
//!
//! where Static is the ZeRO-2 (or PEFT) resident state and the α's come
//! from [`ActivationModel`].  [`MemPlan::derive_capacity`] solves
//! Peak(C) ≤ (1 − headroom)·HBM for the largest integer C — the BucketSize
//! the paper hand-tunes (Section 5: 26K/13K on 80 GB H100s), derived
//! instead of asserted.  [`CapacitySource`] keeps the hand-set path
//! (`Fixed`) available so pre-memplan schedules stay byte-identical.

use crate::memplan::activation::{ActivationModel, RecomputePolicy};
use crate::model::ModelSpec;
use crate::perfmodel::MemoryModel;

const GB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Where the scheduler's per-rank token capacity C comes from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CapacitySource {
    /// Hand-set `bucket_size` (the pre-memplan behaviour, reproducible
    /// byte-for-byte).
    Fixed,
    /// Derived from the HBM budget via [`MemPlan::derive_capacity`].
    HbmDerived,
}

impl CapacitySource {
    pub fn by_name(s: &str) -> Option<CapacitySource> {
        match s {
            "fixed" => Some(CapacitySource::Fixed),
            "hbm" | "hbm-derived" => Some(CapacitySource::HbmDerived),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CapacitySource::Fixed => "fixed",
            CapacitySource::HbmDerived => "hbm-derived",
        }
    }
}

/// Memory-subsystem configuration (the `[memory]` config table).
#[derive(Clone, Debug, PartialEq)]
pub struct MemoryConfig {
    pub source: CapacitySource,
    /// Per-GPU HBM in GiB (paper testbed: 80 GB H100).
    pub hbm_gb: f64,
    /// Heterogeneous clusters: per-*node* HBM in GiB (`hbm_gb = [80, 40]`
    /// in the `[memory]` table, or `--hbm-gb 80,40`).  A static bucket
    /// must fit on every rank, so the minimum-HBM node governs both the
    /// derived capacity and the OOM line; `None` = homogeneous `hbm_gb`.
    pub hbm_gb_nodes: Option<Vec<f64>>,
    pub recompute: RecomputePolicy,
    /// `Some(frac)` = LoRA-style PEFT with `frac` of params trainable
    /// (frees the sharded optimizer state); `None` = full fine-tuning.
    pub peft_frac: Option<f64>,
    /// Fraction of HBM reserved for fragmentation, NCCL workspaces and
    /// allocator slack — derivation targets (1 − headroom)·HBM, OOM
    /// flagging targets the full HBM (so small bucket overfills land in
    /// the headroom instead of a false OOM).
    pub headroom_frac: f64,
}

impl Default for MemoryConfig {
    fn default() -> Self {
        MemoryConfig {
            source: CapacitySource::Fixed,
            hbm_gb: 80.0,
            hbm_gb_nodes: None,
            recompute: RecomputePolicy::Selective,
            peft_frac: None,
            headroom_frac: 0.1,
        }
    }
}

impl MemoryConfig {
    /// The per-GPU HBM budget the plan runs against: the smallest node's
    /// HBM when a heterogeneous per-node list is set (the static bucket
    /// must fit everywhere), the homogeneous `hbm_gb` otherwise.
    pub fn effective_hbm_gb(&self) -> f64 {
        match &self.hbm_gb_nodes {
            Some(nodes) if !nodes.is_empty() => {
                nodes.iter().copied().fold(f64::INFINITY, f64::min)
            }
            _ => self.hbm_gb,
        }
    }
}

/// The resolved per-rank memory model: static bytes + activation curve
/// against an HBM budget.  Built once per experiment
/// ([`MemPlan::for_experiment`]) and consumed by the loader (capacity),
/// the run engine (peak simulation) and the trainer.
#[derive(Clone, Debug)]
pub struct MemPlan {
    /// Resident bytes independent of the bucket: params + sharded
    /// optimizer/gradient state.  ZeRO partitions over the *full* world
    /// group (CP ranks hold distinct shards too), so the shard count is
    /// dp·cp, not dp.
    pub static_bytes: f64,
    pub activation: ActivationModel,
    /// Full per-GPU HBM in bytes (the OOM line).
    pub hbm_bytes: f64,
    /// Reserved fraction of HBM (see [`MemoryConfig::headroom_frac`]).
    pub headroom_frac: f64,
}

impl MemPlan {
    pub fn new(spec: &ModelSpec, dp: usize, cp: usize, mem: &MemoryConfig) -> Self {
        let world = (dp.max(1)) * (cp.max(1));
        let static_bytes = match mem.peft_frac {
            Some(frac) => MemoryModel::peft_static_bytes(spec, world, frac.clamp(0.0, 1.0)),
            None => MemoryModel::zero2_static_bytes(spec, world),
        };
        MemPlan {
            static_bytes,
            activation: ActivationModel::new(spec, mem.recompute, cp),
            hbm_bytes: mem.effective_hbm_gb().max(0.0) * GB,
            headroom_frac: mem.headroom_frac.clamp(0.0, 0.9),
        }
    }

    /// Replace the analytic curve with calibrated coefficients (the
    /// `calib` subsystem's memory fit): measured static bytes and measured
    /// activation bytes per bucket token.  The fitted slope already
    /// includes whatever CP ring buffers the traced job carried, so the
    /// ring term folds into `bytes_per_token`.
    pub fn with_calibrated(&self, bytes_per_token: f64, static_bytes: f64) -> Self {
        let mut p = self.clone();
        p.static_bytes = static_bytes.max(0.0);
        p.activation = ActivationModel {
            bytes_per_token: bytes_per_token.max(0.0),
            ring_bytes_per_token: 0.0,
        };
        p
    }

    /// The plan for an experiment's model + parallel layout.
    pub fn for_experiment(cfg: &crate::config::ExperimentConfig) -> Self {
        Self::new(&cfg.model, cfg.cluster.dp, cfg.cluster.cp, &cfg.memory)
    }

    /// Bytes the derivation may fill (HBM minus the reserved headroom).
    pub fn usable_bytes(&self) -> f64 {
        self.hbm_bytes * (1.0 - self.headroom_frac)
    }

    /// Modeled peak bytes of one rank executing one `bucket_tokens` bucket.
    pub fn peak_bytes(&self, bucket_tokens: u64) -> f64 {
        self.static_bytes + self.activation.bucket_bytes(bucket_tokens)
    }

    /// Does a bucket of this many tokens fit inside the derivation target?
    pub fn admits(&self, bucket_tokens: u64) -> bool {
        self.peak_bytes(bucket_tokens) <= self.usable_bytes()
    }

    /// Would a bucket of this many tokens exceed physical HBM?
    pub fn would_oom(&self, bucket_tokens: u64) -> bool {
        self.peak_bytes(bucket_tokens) > self.hbm_bytes
    }

    /// Peak bytes as a fraction of physical HBM.
    pub fn fraction_of_hbm(&self, bytes: f64) -> f64 {
        if self.hbm_bytes > 0.0 {
            bytes / self.hbm_bytes
        } else {
            0.0
        }
    }

    /// Invert Peak(C) ≤ usable: the largest token capacity the budget
    /// admits, `None` when not even a 1-token bucket fits.  Clamped to
    /// 2^24 tokens (beyond any practical context window, and keeps
    /// C·cp well inside u32 for the scheduler's token arithmetic).
    pub fn derive_capacity(&self) -> Option<u32> {
        let per_token = self.activation.total_bytes_per_token();
        let budget = self.usable_bytes() - self.static_bytes;
        if per_token <= 0.0 || budget < per_token {
            return None;
        }
        let max_c = (1u32 << 24) as f64;
        // skrull-lint: allow(truncating-cast) -- .min(max_c) clamps to 2^24 before the cast, so the u32 conversion is exact
        Some((budget / per_token).min(max_c).floor() as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn plan(hbm_gb: f64) -> MemPlan {
        let mem = MemoryConfig { hbm_gb, ..Default::default() };
        MemPlan::new(&ModelSpec::qwen2_5_0_5b(), 4, 8, &mem)
    }

    #[test]
    fn paper_testbed_derivation_is_plausible() {
        // 0.5B on 80 GB: derived C must be at least the paper's hand-set
        // 26K (the published number includes framework overheads our
        // analytic α can't see, so it is conservative) and far below the
        // clamp.
        let c = plan(80.0).derive_capacity().unwrap();
        assert!(c >= 26 * 1024, "derived {c}");
        assert!(c < (1 << 24));
        // 7B on 80 GB still fits a usable bucket
        let mem = MemoryConfig::default();
        let c7 = MemPlan::new(&ModelSpec::qwen2_5_7b(), 4, 8, &mem)
            .derive_capacity()
            .unwrap();
        assert!(c7 >= 1024, "7B derived {c7}");
        assert!(c7 < c);
    }

    #[test]
    fn derived_capacity_monotone_in_hbm_budget() {
        // Property: more HBM never shrinks the derived capacity — over a
        // random budget ladder and every recompute policy.
        let mut rng = Rng::seed_from_u64(0x4E0);
        for policy in
            [RecomputePolicy::Full, RecomputePolicy::Selective, RecomputePolicy::None]
        {
            for _ in 0..100 {
                let lo = 2.0 + rng.f64() * 100.0;
                let hi = lo + rng.f64() * 400.0;
                let mk = |gb: f64| {
                    let mem =
                        MemoryConfig { hbm_gb: gb, recompute: policy, ..Default::default() };
                    MemPlan::new(&ModelSpec::qwen2_5_0_5b(), 4, 8, &mem).derive_capacity()
                };
                match (mk(lo), mk(hi)) {
                    (Some(a), Some(b)) => assert!(a <= b, "{policy:?}: C({lo})={a} > C({hi})={b}"),
                    (Some(a), None) => panic!("{policy:?}: C({lo})={a} but C({hi}) infeasible"),
                    (None, _) => {}
                }
            }
        }
    }

    #[test]
    fn derived_capacity_never_admits_a_bucket_over_budget() {
        // Property: Peak(C) ≤ usable ≤ HBM, and C is maximal (C+1 busts
        // the derivation target).
        let mut rng = Rng::seed_from_u64(0xADA);
        for spec in [ModelSpec::qwen2_5_0_5b(), ModelSpec::qwen2_5_7b(), ModelSpec::tiny()] {
            for _ in 0..100 {
                let mem = MemoryConfig {
                    hbm_gb: 1.0 + rng.f64() * 200.0,
                    ..Default::default()
                };
                let p = MemPlan::new(&spec, 4, 8, &mem);
                let Some(c) = p.derive_capacity() else { continue };
                assert!(p.admits(c as u64), "{}: C={c} over budget", spec.name);
                assert!(!p.would_oom(c as u64), "{}: C={c} OOMs", spec.name);
                if c < (1 << 24) {
                    assert!(!p.admits(c as u64 + 1), "{}: C={c} not maximal", spec.name);
                }
            }
        }
    }

    #[test]
    fn too_small_budget_is_infeasible_not_zero() {
        // 1 GB cannot even hold the 0.5B ZeRO-2 static state at world=32
        // plus one token of activations → None, never Some(0)
        assert_eq!(plan(1.0).derive_capacity(), None);
        assert_eq!(plan(0.0).derive_capacity(), None);
    }

    #[test]
    fn peft_extends_capacity() {
        // the paper's future-work lever: PEFT frees sharded optimizer
        // state, so the same HBM admits a larger bucket
        let full = MemPlan::new(&ModelSpec::qwen2_5_7b(), 4, 8, &MemoryConfig::default());
        let peft = MemPlan::new(
            &ModelSpec::qwen2_5_7b(),
            4,
            8,
            &MemoryConfig { peft_frac: Some(0.01), ..Default::default() },
        );
        assert!(peft.static_bytes < full.static_bytes);
        assert!(peft.derive_capacity().unwrap() > full.derive_capacity().unwrap());
    }

    #[test]
    fn recompute_trades_capacity() {
        let mk = |r| {
            let mem = MemoryConfig { recompute: r, ..Default::default() };
            MemPlan::new(&ModelSpec::qwen2_5_0_5b(), 4, 8, &mem).derive_capacity().unwrap()
        };
        let full = mk(RecomputePolicy::Full);
        let sel = mk(RecomputePolicy::Selective);
        let none = mk(RecomputePolicy::None);
        assert!(full > sel && sel > none, "{full} > {sel} > {none}");
    }

    #[test]
    fn smallest_hbm_node_governs_derived_capacity() {
        // ROADMAP item: heterogeneous HBM per node — a single small-HBM
        // node tightens the derived capacity to what *it* can hold.
        let homogeneous = plan(80.0).derive_capacity().unwrap();
        let mk = |nodes: Vec<f64>| {
            let mem = MemoryConfig { hbm_gb_nodes: Some(nodes), ..Default::default() };
            MemPlan::new(&ModelSpec::qwen2_5_0_5b(), 4, 8, &mem)
        };
        let mixed = mk(vec![80.0, 80.0, 40.0, 80.0]);
        let tight = mixed.derive_capacity().unwrap();
        assert!(tight < homogeneous, "mixed {tight} vs homogeneous {homogeneous}");
        // the min node is authoritative: identical to an all-40 cluster
        let all_small = mk(vec![40.0; 4]).derive_capacity().unwrap();
        assert_eq!(tight, all_small);
        // the OOM line tracks the small node too
        assert!((mixed.hbm_bytes - 40.0 * GB).abs() < 1.0);
        // an all-80 list is exactly the homogeneous default
        assert_eq!(mk(vec![80.0; 4]).derive_capacity().unwrap(), homogeneous);
        // effective budget helper
        let mem = MemoryConfig { hbm_gb_nodes: Some(vec![80.0, 24.0]), ..Default::default() };
        assert_eq!(mem.effective_hbm_gb(), 24.0);
        let empty = MemoryConfig { hbm_gb_nodes: Some(vec![]), ..Default::default() };
        assert_eq!(empty.effective_hbm_gb(), 80.0);
        assert_eq!(MemoryConfig::default().effective_hbm_gb(), 80.0);
    }

    #[test]
    fn calibrated_override_replaces_curve_and_static() {
        let base = plan(80.0);
        let cal = base.with_calibrated(5.0e4, 6.0e9);
        assert_eq!(cal.static_bytes, 6.0e9);
        assert_eq!(cal.activation.total_bytes_per_token(), 5.0e4);
        assert_eq!(cal.activation.ring_bytes_per_token, 0.0);
        // peak line follows the calibrated coefficients exactly
        assert!((cal.peak_bytes(1000) - (6.0e9 + 5.0e4 * 1000.0)).abs() < 1e-3);
        // the budget inversion uses them too
        let c = cal.derive_capacity().unwrap();
        let usable = cal.usable_bytes();
        assert!(cal.peak_bytes(c as u64) <= usable);
        assert!(cal.peak_bytes(c as u64 + 1) > usable);
        // negative inputs are clamped, not propagated
        let clamped = base.with_calibrated(-1.0, -1.0);
        assert_eq!(clamped.static_bytes, 0.0);
        assert_eq!(clamped.activation.total_bytes_per_token(), 0.0);
    }

    #[test]
    fn source_names_round_trip() {
        for s in [CapacitySource::Fixed, CapacitySource::HbmDerived] {
            assert_eq!(CapacitySource::by_name(s.name()), Some(s));
        }
        assert_eq!(CapacitySource::by_name("hbm"), Some(CapacitySource::HbmDerived));
        assert!(CapacitySource::by_name("vram").is_none());
    }
}
