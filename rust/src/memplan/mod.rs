//! Memory planning — the capacity authority.
//!
//! Skrull's joint optimization is memory-constrained at its core: DACP
//! chunks long sequences across CP ranks precisely because *activation
//! memory*, not FLOPs, caps what a rank can hold (Eq. 7/10/12).  The seed
//! reproduction took the per-rank token capacity C ("BucketSize") as a
//! hand-set number; this subsystem models where C actually comes from and
//! what happens when a schedule exceeds it:
//!
//! * [`activation`] — the activation curve: kept bytes per token under a
//!   recomputation policy, plus the CP K/V-exchange buffers that ride on
//!   top when a sequence is sharded.
//! * [`capacity`] — [`MemPlan`]: ZeRO-2/PEFT static bytes + the activation
//!   curve against an HBM budget, inverted to derive C
//!   ([`MemPlan::derive_capacity`]).  [`CapacitySource`] selects between
//!   the hand-set C (`Fixed`, reproducing the pre-memplan schedules
//!   byte-identically) and the derived one (`HbmDerived`).
//! * [`peak`] — per-iteration peak-memory simulation over an
//!   [`IterationSchedule`]: per-GPU peak bytes per micro-batch, headroom,
//!   and structured would-be-OOM events the run engine and the e2e sweep
//!   surface as `peak_mem_fraction` / `oom_count`.
//!
//! The thin Eq.-12 fit in `perfmodel::memory` remains the *estimator*
//! (offline profiling); `memplan` is the *authority* the scheduler,
//! loader, run engine and trainer consume.
//!
//! [`IterationSchedule`]: crate::scheduler::IterationSchedule

pub mod activation;
pub mod capacity;
pub mod peak;

pub use activation::{ActivationModel, RecomputePolicy};
pub use capacity::{CapacitySource, MemPlan, MemoryConfig};
pub use peak::{iteration_memory, IterationMemory, OomEvent};
