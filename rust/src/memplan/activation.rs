//! The activation curve: bytes a rank must hold per token of its packed
//! bucket, as a function of the recomputation policy, plus the K/V
//! exchange buffers context parallelism adds on top.
//!
//! With FlashAttention + sequence packing everything activation-side is
//! linear in tokens (Eq. 12), so the whole curve collapses to a
//! bytes-per-token slope — but that slope moves by ~an order of magnitude
//! between "keep everything" and "recompute everything", which is exactly
//! the lever HBM-derived capacities (capacity.rs) trade against.

use crate::model::ModelSpec;
use crate::perfmodel::memory;

/// What the backward pass recomputes (and therefore what the forward pass
/// must keep resident).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecomputePolicy {
    /// Full activation recomputation: only the per-layer inputs (residual
    /// stream) survive the forward pass.
    Full,
    /// Selective recomputation (the default the paper profiles against):
    /// attention is recomputed, linear-layer activations are kept.
    Selective,
    /// No recomputation: every intermediate the backward pass touches is
    /// kept resident.
    None,
}

impl RecomputePolicy {
    pub fn by_name(s: &str) -> Option<RecomputePolicy> {
        match s {
            "full" | "full-recompute" => Some(RecomputePolicy::Full),
            "selective" => Some(RecomputePolicy::Selective),
            "none" | "no-recompute" => Some(RecomputePolicy::None),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RecomputePolicy::Full => "full",
            RecomputePolicy::Selective => "selective",
            RecomputePolicy::None => "none",
        }
    }

    /// Kept activation *elements* per token per layer.  `Selective` is
    /// pinned to the same expression `perfmodel::memory` fits Eq. 12 with,
    /// so estimator and authority agree on the default policy.
    pub fn kept_elems_per_token_layer(&self, spec: &ModelSpec) -> f64 {
        let h = spec.hidden as f64;
        let ffn = spec.ffn as f64;
        let selective = memory::selective_kept_elems_per_token_layer(spec);
        match self {
            // only the two residual-stream snapshots per layer
            RecomputePolicy::Full => 2.0 * h,
            RecomputePolicy::Selective => selective,
            // + attention output and the activated SwiGLU product that
            // selective recomputation discards
            RecomputePolicy::None => selective + 2.0 * h + ffn,
        }
    }
}

/// The per-rank activation-memory model for one (model, policy, cp) tuple.
#[derive(Clone, Debug)]
pub struct ActivationModel {
    /// Kept activation bytes per bucket token (α of Eq. 12, bf16, all
    /// layers).
    pub bytes_per_token: f64,
    /// CP K/V exchange buffers per bucket token: ring attention
    /// double-buffers both K and V chunks of the in-flight neighbour
    /// (reused across layers, so no `layers` factor).  Zero when cp = 1 —
    /// no collective, no buffer.
    pub ring_bytes_per_token: f64,
}

impl ActivationModel {
    pub fn new(spec: &ModelSpec, recompute: RecomputePolicy, cp: usize) -> Self {
        const BF16: f64 = 2.0;
        let elems = recompute.kept_elems_per_token_layer(spec);
        let ring = if cp > 1 {
            // 2 buffers (double-buffered pipeline) × 2 tensors (K, V)
            2.0 * 2.0 * spec.kv_hidden() as f64 * BF16
        } else {
            0.0
        };
        ActivationModel {
            bytes_per_token: BF16 * elems * spec.layers as f64,
            ring_bytes_per_token: ring,
        }
    }

    /// Total activation-side bytes per bucket token.
    pub fn total_bytes_per_token(&self) -> f64 {
        self.bytes_per_token + self.ring_bytes_per_token
    }

    /// Activation bytes for a packed bucket of `tokens` tokens.
    pub fn bucket_bytes(&self, tokens: u64) -> f64 {
        self.total_bytes_per_token() * tokens as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::MemoryModel;

    #[test]
    fn policies_order_strictly() {
        // keep-everything > selective > full-recompute, for every model
        for spec in [ModelSpec::qwen2_5_0_5b(), ModelSpec::qwen2_5_7b(), ModelSpec::tiny()] {
            let full = ActivationModel::new(&spec, RecomputePolicy::Full, 8);
            let sel = ActivationModel::new(&spec, RecomputePolicy::Selective, 8);
            let none = ActivationModel::new(&spec, RecomputePolicy::None, 8);
            assert!(full.bytes_per_token < sel.bytes_per_token, "{}", spec.name);
            assert!(sel.bytes_per_token < none.bytes_per_token, "{}", spec.name);
        }
    }

    #[test]
    fn selective_matches_perfmodel_estimator() {
        // The authority's default slope is the estimator's α (Eq. 12):
        // memplan and perfmodel::memory must not drift apart.
        let spec = ModelSpec::qwen2_5_0_5b();
        let act = ActivationModel::new(&spec, RecomputePolicy::Selective, 1);
        let est = MemoryModel::for_model(&spec, 4, 80.0 * 1024.0 * 1024.0 * 1024.0);
        assert!((act.bytes_per_token - est.alpha_bytes_per_token).abs() < 1e-6);
    }

    #[test]
    fn ring_buffers_only_with_cp() {
        let spec = ModelSpec::qwen2_5_0_5b();
        let solo = ActivationModel::new(&spec, RecomputePolicy::Selective, 1);
        let cp8 = ActivationModel::new(&spec, RecomputePolicy::Selective, 8);
        assert_eq!(solo.ring_bytes_per_token, 0.0);
        // 2 buffers × 2 tensors × h_kv(128) × 2 bytes = 1024 B/token
        assert_eq!(cp8.ring_bytes_per_token, 1024.0);
        assert_eq!(solo.bytes_per_token, cp8.bytes_per_token);
    }

    #[test]
    fn bucket_bytes_linear_in_tokens() {
        let spec = ModelSpec::tiny();
        let m = ActivationModel::new(&spec, RecomputePolicy::Selective, 4);
        let b1 = m.bucket_bytes(1000);
        assert!((m.bucket_bytes(2000) - 2.0 * b1).abs() < 1e-6);
        assert_eq!(m.bucket_bytes(0), 0.0);
    }

    #[test]
    fn policy_names_round_trip() {
        for p in [RecomputePolicy::Full, RecomputePolicy::Selective, RecomputePolicy::None] {
            assert_eq!(RecomputePolicy::by_name(p.name()), Some(p));
        }
        assert!(RecomputePolicy::by_name("sometimes").is_none());
    }
}
