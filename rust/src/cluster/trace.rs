//! Chrome-trace (chrome://tracing / Perfetto) export of a simulated
//! iteration: one row per (DP rank, CP rank), duration events for local
//! compute, exposed communication and distributed compute — the Fig. 2(d)
//! timeline, inspectable.  Hand-rolled JSON (no serde in the image).

use crate::perfmodel::CostModel;
use crate::scheduler::plan::IterationSchedule;

/// Minimal JSON string escaping for event names.
fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

struct Event {
    name: String,
    pid: usize,
    tid: usize,
    /// microseconds
    ts: f64,
    dur: f64,
}

impl Event {
    fn render(&self) -> String {
        format!(
            r#"{{"name":"{}","ph":"X","pid":{},"tid":{},"ts":{:.3},"dur":{:.3},"cat":"sim"}}"#,
            esc(&self.name),
            self.pid,
            self.tid,
            self.ts,
            self.dur
        )
    }
}

/// Render one iteration's simulated timeline as a chrome trace JSON
/// string.  pid = DP rank, tid = CP rank.
pub fn iteration_trace(sched: &IterationSchedule, cost: &CostModel, cp: usize) -> String {
    let mut events = Vec::new();
    for (dp, rank) in sched.ranks.iter().enumerate() {
        let mut cursor = vec![0.0f64; cp]; // per-CP-rank clock, µs
        for (mb_idx, mb) in rank.micro_batches.iter().enumerate() {
            let lens = mb.lens();
            let times = cost.rank_times(&lens, &mb.plan, cp);
            let tdacp = times.iter().map(|t| t.total).fold(0.0, f64::max) * 1e6;
            for (j, t) in times.iter().enumerate() {
                let start = cursor[j];
                let local = t.local_comp * 1e6;
                let comm = t.comm * 1e6;
                let dist = t.dist_comp * 1e6;
                if local > 0.0 {
                    events.push(Event {
                        name: format!("mb{mb_idx} local ({} seqs)", mb.plan.locals_of(j).count()),
                        pid: dp,
                        tid: j,
                        ts: start,
                        dur: local,
                    });
                }
                if comm > 0.0 {
                    // comm overlaps local from the start of the micro-batch
                    events.push(Event {
                        name: format!("mb{mb_idx} kv-comm"),
                        pid: dp,
                        tid: j,
                        ts: start,
                        dur: comm,
                    });
                }
                if dist > 0.0 {
                    events.push(Event {
                        name: format!("mb{mb_idx} dist ({} shards)", mb.plan.num_distributed()),
                        pid: dp,
                        tid: j,
                        ts: start + local.max(comm),
                        dur: dist,
                    });
                }
                // CP group barrier: everyone advances to the makespan
                cursor[j] = start + tdacp;
            }
        }
    }
    let body: Vec<String> = events.iter().map(Event::render).collect();
    format!("{{\"traceEvents\":[\n{}\n]}}\n", body.join(",\n"))
}

/// Write the trace to a file.
pub fn write_iteration_trace(
    path: &str,
    sched: &IterationSchedule,
    cost: &CostModel,
    cp: usize,
) -> std::io::Result<()> {
    std::fs::write(path, iteration_trace(sched, cost, cp))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Sequence;
    use crate::model::ModelSpec;
    use crate::scheduler::plan::{DacpPlan, MicroBatch, RankSchedule, DISTRIBUTED};

    fn sched() -> IterationSchedule {
        IterationSchedule {
            ranks: vec![RankSchedule {
                micro_batches: vec![MicroBatch {
                    seqs: vec![
                        Sequence { id: 0, len: 20_000 },
                        Sequence { id: 1, len: 500 },
                    ],
                    plan: DacpPlan { assign: vec![DISTRIBUTED, 0] },
                }],
            }],
        }
    }

    #[test]
    fn trace_is_wellformed_json_with_expected_events() {
        let cost = CostModel::paper_default(&ModelSpec::qwen2_5_0_5b());
        let s = sched();
        let json = iteration_trace(&s, &cost, 2);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.trim_end().ends_with("]}"));
        // rank 0 has local work; both ranks have comm + dist
        assert!(json.contains("local (1 seqs)"));
        assert!(json.contains("kv-comm"));
        assert!(json.contains("dist (1 shards)"));
        // balanced braces / quotes sanity
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('"').count() % 2, 0);
    }

    #[test]
    fn dist_events_start_after_overlap_window() {
        let cost = CostModel::paper_default(&ModelSpec::qwen2_5_0_5b());
        let s = sched();
        let json = iteration_trace(&s, &cost, 2);
        // every dist event's ts must be > 0 (after max(local, comm))
        for line in json.lines().filter(|l| l.contains("dist (")) {
            let ts = line.split("\"ts\":").nth(1).unwrap();
            let ts: f64 = ts.split(',').next().unwrap().parse().unwrap();
            assert!(ts > 0.0, "{line}");
        }
    }

    #[test]
    fn write_creates_file() {
        let cost = CostModel::paper_default(&ModelSpec::qwen2_5_0_5b());
        let dir = std::env::temp_dir().join(format!("skrull_trace_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("it.json");
        write_iteration_trace(path.to_str().unwrap(), &sched(), &cost, 2).unwrap();
        assert!(path.exists());
        std::fs::remove_dir_all(dir).ok();
    }
}
