//! Chrome-trace (chrome://tracing / Perfetto) export of a simulated
//! iteration: one row per (DP rank, CP rank), duration events for local
//! compute, exposed communication and distributed compute — the Fig. 2(d)
//! timeline, inspectable.  Hand-rolled JSON (no serde in the image).

use crate::perfmodel::CostModel;
use crate::scheduler::plan::IterationSchedule;

/// Minimal JSON string escaping for event names.
fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

struct Event {
    name: String,
    pid: usize,
    tid: usize,
    /// microseconds
    ts: f64,
    dur: f64,
}

impl Event {
    fn render(&self) -> String {
        format!(
            r#"{{"name":"{}","ph":"X","pid":{},"tid":{},"ts":{:.3},"dur":{:.3},"cat":"sim"}}"#,
            esc(&self.name),
            self.pid,
            self.tid,
            self.ts,
            self.dur
        )
    }
}

/// Append one iteration's events starting at `base_us`.  (Iteration
/// *length* on the run timeline comes from the run engine's
/// `exec_seconds`, not from here — one source of truth.)
fn push_iteration_events(
    events: &mut Vec<Event>,
    sched: &IterationSchedule,
    cost: &CostModel,
    cp: usize,
    base_us: f64,
    prefix: &str,
) {
    for (dp, rank) in sched.ranks.iter().enumerate() {
        let mut cursor = vec![base_us; cp]; // per-CP-rank clock, µs
        for (mb_idx, mb) in rank.micro_batches.iter().enumerate() {
            let lens = mb.lens();
            let times = cost.rank_times(&lens, &mb.plan, cp);
            let tdacp = times.iter().map(|t| t.total).fold(0.0, f64::max) * 1e6;
            for (j, t) in times.iter().enumerate() {
                let start = cursor[j];
                let local = t.local_comp * 1e6;
                let comm = t.comm * 1e6;
                let dist = t.dist_comp * 1e6;
                if local > 0.0 {
                    events.push(Event {
                        name: format!(
                            "{prefix}mb{mb_idx} local ({} seqs)",
                            mb.plan.locals_of(j).count()
                        ),
                        pid: dp,
                        tid: j,
                        ts: start,
                        dur: local,
                    });
                }
                if comm > 0.0 {
                    // comm overlaps local from the start of the micro-batch
                    events.push(Event {
                        name: format!("{prefix}mb{mb_idx} kv-comm"),
                        pid: dp,
                        tid: j,
                        ts: start,
                        dur: comm,
                    });
                }
                if dist > 0.0 {
                    events.push(Event {
                        name: format!(
                            "{prefix}mb{mb_idx} dist ({} shards)",
                            mb.plan.num_distributed()
                        ),
                        pid: dp,
                        tid: j,
                        ts: start + local.max(comm),
                        dur: dist,
                    });
                }
                // CP group barrier: everyone advances to the makespan
                cursor[j] = start + tdacp;
            }
        }
    }
}

/// A counter event ("ph":"C") — Perfetto draws these as a value lane.
fn counter(name: &str, pid: usize, ts: f64, value: f64) -> String {
    format!(
        r#"{{"name":"{}","ph":"C","pid":{},"ts":{:.3},"args":{{"value":{:.6}}}}}"#,
        esc(name),
        pid,
        ts,
        value
    )
}

/// An instant event ("ph":"i") — a marker at a point in time.
fn instant(name: &str, pid: usize, tid: usize, ts: f64) -> String {
    format!(
        r#"{{"name":"{}","ph":"i","pid":{},"tid":{},"ts":{:.3},"s":"t","cat":"sim"}}"#,
        esc(name),
        pid,
        tid,
        ts
    )
}

fn render_lines(lines: Vec<String>) -> String {
    format!("{{\"traceEvents\":[\n{}\n]}}\n", lines.join(",\n"))
}

fn render_events(events: &[Event]) -> String {
    render_lines(events.iter().map(Event::render).collect())
}

/// Render one iteration's simulated timeline as a chrome trace JSON
/// string.  pid = DP rank, tid = CP rank.
pub fn iteration_trace(sched: &IterationSchedule, cost: &CostModel, cp: usize) -> String {
    let mut events = Vec::new();
    push_iteration_events(&mut events, sched, cost, cp, 0.0, "");
    render_events(&events)
}

/// Render a whole simulated run: consecutive iterations laid out on the
/// wall-clock produced by the run engine, plus a dedicated "dataloader"
/// process row (pid = dp) showing each iteration's scheduling span — in
/// pipelined mode it visibly overlaps the previous iteration's execution,
/// the Section 4.3 picture.  A **memory lane** rides along: one
/// `peak_mem_frac` counter per (iteration, DP rank) with the rank's worst
/// GPU's peak as a fraction of HBM, plus an instant `OOM` marker for every
/// modeled out-of-memory event.
pub fn run_trace(
    scheds: &[IterationSchedule],
    report: &crate::cluster::run::RunReport,
    cost: &CostModel,
) -> String {
    run_trace_iter(scheds.iter(), report, cost)
}

/// [`run_trace`] straight off a [`BuiltRun`]: the chrome-trace lane renders
/// from the same built schedules the report was priced from — no second
/// loader replay to collect them.
///
/// [`BuiltRun`]: crate::cluster::run::BuiltRun
pub fn run_trace_built(
    built: &crate::cluster::run::BuiltRun,
    report: &crate::cluster::run::RunReport,
    cost: &CostModel,
) -> String {
    run_trace_iter(built.schedules(), report, cost)
}

fn run_trace_iter<'a>(
    scheds: impl ExactSizeIterator<Item = &'a IterationSchedule>,
    report: &crate::cluster::run::RunReport,
    cost: &CostModel,
) -> String {
    assert_eq!(scheds.len(), report.iterations.len());
    let cp = report.cp;
    let loader_pid = report.dp; // one row past the last DP rank
    let mut events = Vec::new();
    let mut extra: Vec<String> = Vec::new();
    let mut clock_us = 0.0f64;
    for (i, (sched, rec)) in scheds.zip(&report.iterations).enumerate() {
        // scheduling of iteration i starts when the overlap window opens:
        // at the start of the previous iteration's execution (pipelined)
        // or right before its own execution (synchronous)
        let exec_start_us = clock_us + rec.exposed_sched_seconds * 1e6;
        let sched_start_us = match report.mode {
            crate::cluster::run::LoaderMode::Pipelined if i > 0 => {
                clock_us - report.iterations[i - 1].exec_seconds * 1e6
            }
            _ => clock_us,
        };
        events.push(Event {
            name: format!("sched iter{i}"),
            pid: loader_pid,
            tid: 0,
            ts: sched_start_us.max(0.0),
            dur: rec.sched_seconds * 1e6,
        });
        push_iteration_events(&mut events, sched, cost, cp, exec_start_us, &format!("it{i} "));
        if rec.grad_sync_seconds > 0.0 {
            events.push(Event {
                name: format!("grad-sync iter{i}"),
                pid: loader_pid,
                tid: 1,
                ts: exec_start_us + (rec.exec_seconds - rec.grad_sync_seconds) * 1e6,
                dur: rec.grad_sync_seconds * 1e6,
            });
        }
        // memory lane: per-DP-rank peak fraction for this iteration
        if report.hbm_bytes > 0.0 && rec.rank_peak_bytes.len() == report.dp * cp {
            for d in 0..report.dp {
                let peak = rec.rank_peak_bytes[d * cp..(d + 1) * cp]
                    .iter()
                    .copied()
                    .fold(0.0, f64::max);
                extra.push(counter("peak_mem_frac", d, exec_start_us, peak / report.hbm_bytes));
            }
        }
        for ev in report.oom_events.iter().filter(|e| e.iteration == i) {
            extra.push(instant(
                &format!("OOM mb{}", ev.micro_batch),
                ev.dp_rank,
                ev.cp_rank,
                exec_start_us,
            ));
        }
        clock_us = exec_start_us + rec.exec_seconds * 1e6;
    }
    let mut lines: Vec<String> = events.iter().map(Event::render).collect();
    lines.extend(extra);
    render_lines(lines)
}

/// Write the trace to a file.
pub fn write_iteration_trace(
    path: &str,
    sched: &IterationSchedule,
    cost: &CostModel,
    cp: usize,
) -> std::io::Result<()> {
    std::fs::write(path, iteration_trace(sched, cost, cp))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Sequence;
    use crate::model::ModelSpec;
    use crate::scheduler::plan::{DacpPlan, MicroBatch, RankSchedule, DISTRIBUTED};

    fn sched() -> IterationSchedule {
        IterationSchedule {
            ranks: vec![RankSchedule {
                micro_batches: vec![MicroBatch {
                    seqs: vec![
                        Sequence { id: 0, len: 20_000 },
                        Sequence { id: 1, len: 500 },
                    ],
                    plan: DacpPlan { assign: vec![DISTRIBUTED, 0] },
                }],
            }],
        }
    }

    #[test]
    fn trace_is_wellformed_json_with_expected_events() {
        let cost = CostModel::paper_default(&ModelSpec::qwen2_5_0_5b());
        let s = sched();
        let json = iteration_trace(&s, &cost, 2);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.trim_end().ends_with("]}"));
        // rank 0 has local work; both ranks have comm + dist
        assert!(json.contains("local (1 seqs)"));
        assert!(json.contains("kv-comm"));
        assert!(json.contains("dist (1 shards)"));
        // balanced braces / quotes sanity
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('"').count() % 2, 0);
    }

    #[test]
    fn dist_events_start_after_overlap_window() {
        let cost = CostModel::paper_default(&ModelSpec::qwen2_5_0_5b());
        let s = sched();
        let json = iteration_trace(&s, &cost, 2);
        // every dist event's ts must be > 0 (after max(local, comm))
        for line in json.lines().filter(|l| l.contains("dist (")) {
            let ts = line.split("\"ts\":").nth(1).unwrap();
            let ts: f64 = ts.split(',').next().unwrap().parse().unwrap();
            assert!(ts > 0.0, "{line}");
        }
    }

    #[test]
    fn run_trace_lays_out_iterations_with_a_dataloader_lane() {
        use crate::cluster::run::{simulate_run, RunConfig};
        use crate::config::ExperimentConfig;
        use crate::data::{Dataset, LengthDistribution};

        let cfg = {
            let mut c =
                ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "chatqa2");
            c.cluster.batch_size = 8;
            c
        };
        let ds = Dataset::synthesize(&LengthDistribution::chatqa2(), 1_000, 3)
            .truncated(cfg.bucket_size * cfg.cluster.cp as u32);
        let cost = CostModel::paper_default(&cfg.model);

        // collect the schedules by replaying the same loader sequence
        let mut scheds = Vec::new();
        let mut loader = crate::data::loader::ScheduledLoader::new(&ds, &cfg);
        loader
            .run_synchronous(3, |_, _, sched, _| scheds.push(sched.clone()))
            .unwrap();
        let report = simulate_run(&ds, &cfg, &cost, &RunConfig::new(3, true)).unwrap();

        let json = run_trace(&scheds, &report, &cost);
        assert!(json.starts_with("{\"traceEvents\":["));
        // one scheduling event per iteration on the dataloader row
        for i in 0..3 {
            assert!(json.contains(&format!("sched iter{i}")), "iter {i}");
            assert!(json.contains(&format!("it{i} mb0")), "iter {i} exec events");
        }
        assert!(json.contains("grad-sync iter0"));
        // the BuiltRun path renders the identical trace without a second
        // loader replay: same schedules, same report, same bytes
        let built =
            crate::cluster::run::build_run(&ds, &cfg, &RunConfig::new(3, true)).unwrap();
        let report2 = crate::cluster::run::price_run(&built, &cost, &built.topology);
        let from_built = run_trace_built(&built, &report2, &cost);
        let collected: Vec<IterationSchedule> = built.schedules().cloned().collect();
        assert_eq!(from_built, run_trace(&collected, &report2, &cost));
        // the memory lane rides along: one counter per (iteration, dp rank)
        assert_eq!(
            json.matches("\"peak_mem_frac\"").count(),
            3 * cfg.cluster.dp,
        );
        assert!(json.contains("\"ph\":\"C\""));
        // no OOM markers on the default 80 GB budget
        assert!(!json.contains("OOM"));
        // wellformed-ish
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('"').count() % 2, 0);
    }

    #[test]
    fn run_trace_marks_ooms_on_undersized_hbm() {
        use crate::cluster::run::{simulate_run, RunConfig};
        use crate::config::ExperimentConfig;
        use crate::data::{Dataset, LengthDistribution};

        let cfg = {
            let mut c = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "chatqa2");
            c.cluster.batch_size = 8;
            c.memory.hbm_gb = 4.0; // cannot hold a 26K bucket
            c
        };
        let ds = Dataset::synthesize(&LengthDistribution::chatqa2(), 1_000, 3)
            .truncated(cfg.bucket_size * cfg.cluster.cp as u32);
        let cost = CostModel::paper_default(&cfg.model);
        let mut scheds = Vec::new();
        let mut loader = crate::data::loader::ScheduledLoader::new(&ds, &cfg);
        loader
            .run_synchronous(2, |_, _, sched, _| scheds.push(sched.clone()))
            .unwrap();
        let report = simulate_run(&ds, &cfg, &cost, &RunConfig::new(2, true)).unwrap();
        assert!(report.oom_count() > 0);
        let json = run_trace(&scheds, &report, &cost);
        assert!(json.contains("OOM mb"));
        assert!(json.contains("\"ph\":\"i\""));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn write_creates_file() {
        let cost = CostModel::paper_default(&ModelSpec::qwen2_5_0_5b());
        let dir = std::env::temp_dir().join(format!("skrull_trace_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("it.json");
        write_iteration_trace(path.to_str().unwrap(), &sched(), &cost, 2).unwrap();
        assert!(path.exists());
        std::fs::remove_dir_all(dir).ok();
    }
}
